#!/usr/bin/env bash
# Executes every command README.md shows, so the docs cannot rot: CI runs
# this after the build (see .github/workflows/ci.yml, job `docs`).
set -euxo pipefail
cd "$(dirname "$0")/.."

export ARBORS_SCALE=quick
(cd rust && cargo build --release)
arbors() { rust/target/release/arbors "$@"; }

# Correctness tooling (ISSUE 7): the README's audit command, verbatim.
(cd rust && cargo run -p xtask -- audit)

arbors datasets

arbors train --dataset magic --n 2000 --trees 32 --leaves 32 --out /tmp/model.json

arbors accuracy --model /tmp/model.json --dataset magic --n 1000

# A tiny 10-feature batch (magic's dimensionality) for the predict example.
python3 - <<'EOF'
import random
random.seed(7)
with open("/tmp/batch.csv", "w") as f:
    f.write(",".join(f"f{i}" for i in range(10)) + ",label\n")
    for _ in range(64):
        f.write(",".join(f"{random.random():.4f}" for _ in range(10)) + ",0\n")
EOF
arbors predict --model /tmp/model.json --data /tmp/batch.csv --engine RS \
    --precision i8 --out /tmp/preds.csv
test -s /tmp/preds.csv

# FLInt carrier tier (ISSUE 8): integer threshold compares, bit-exact f32
# outputs — the flint predictions must equal the f32 ones byte-for-byte.
arbors predict --model /tmp/model.json --data /tmp/batch.csv --engine RS \
    --precision f32 --out /tmp/preds_f32.csv
arbors predict --model /tmp/model.json --data /tmp/batch.csv --engine RS \
    --precision flint --out /tmp/preds_flint.csv
cmp /tmp/preds_f32.csv /tmp/preds_flint.csv

# Early exit (ISSUE 9): exact mode scores trees in confidence order and
# stops once the margin bound proves the argmax — predictions must equal
# full scoring byte-for-byte.
arbors predict --model /tmp/model.json --data /tmp/batch.csv --engine RS \
    --early-exit exact --out /tmp/preds_ee.csv
cmp /tmp/preds_f32.csv /tmp/preds_ee.csv

arbors select --model /tmp/model.json --device a53 --threads 2

# --pin anchors exec workers to their topology cluster (graceful no-op
# where the kernel refuses the mask).
arbors serve --dataset magic --n 2000 --engine VQS --precision i8 \
    --requests 2000 --threads 2 --pin

arbors predict --model /tmp/model.json --data /tmp/batch.csv --engine RS \
    --threads 2 --pin --out /tmp/preds_pinned.csv
test -s /tmp/preds_pinned.csv

arbors bench --exp int8
# Per-engine f32-vs-FLInt latency table (bit-identity asserted inside).
arbors bench --exp flint --smoke
# Exact-mode agreement (asserted) + the approx threshold sweep.
arbors bench --exp early_exit --smoke
arbors bench --exp scaling --threads 2
arbors bench --exp serving --threads 2
# The adaptive-execution grid (static/adaptive × pinned/unpinned ×
# claim-1/claim-k) on a synthetic big.LITTLE topology; --smoke sizes it
# for CI while still crossing re-plan boundaries.
arbors bench --exp adaptive --threads 2 --smoke

# Robust serving (ISSUE 10): --degrade arms overload-triggered graceful
# degradation (NA is deliberately slow, so a cheaper >=99%-agreement
# fallback always exists for the selector to arm).
arbors serve --dataset magic --n 2000 --engine NA \
    --requests 500 --threads 2 --degrade

# Overload sweep, degradation off vs on; the magic/ovl* gate series go
# to a throwaway history file here, never the tracked one (direct binary
# call: env-prefixing a shell function would leak the assignment).
ARBORS_BENCH_DATA=/tmp/overload_data.js \
    rust/target/release/arbors bench --exp overload --threads 2 --smoke

# Observability (ISSUE 6): perf-history smoke grid + regression gate on a
# throwaway history file (never the tracked dev/bench/data.js), the
# tracing-overhead harness, the per-tier SIMD-op profile, and a span
# trace capture.
export ARBORS_BENCH_DATA=/tmp/bench_data.js
rm -f /tmp/bench_data.js
arbors bench --exp smoke --matrix
arbors bench --gate
unset ARBORS_BENCH_DATA
arbors bench --exp obs --threads 2
arbors bench --exp engine_micro
arbors trace --out /tmp/trace.json --requests 512 --threads 2
test -s /tmp/trace.json
python3 -c "import json; d=json.load(open('/tmp/trace.json')); assert d['traceEvents'], 'empty trace'"

echo "readme smoke: OK"
