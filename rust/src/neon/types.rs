//! ARM NEON 128-bit vector types, simulated as fixed-size arrays.
//!
//! The paper's V-QuickScorer and RapidScorer are specified directly in terms
//! of NEON registers and intrinsics (Algorithms 2 and 4). To execute those
//! algorithms *as written* on non-ARM hardware, this module models the
//! Q-register types (`float32x4_t`, `int16x8_t`, `uint8x16_t`, …) and the
//! D-register halves used by the widening moves (`int16x4_t`, `int32x2_t`).
//!
//! The simulation is bit-exact with the AArch64 semantics for every
//! intrinsic in [`super::ops`]; rustc/LLVM auto-vectorizes the arrays into
//! SSE/AVX on x86, so the simulated engines keep SIMD-like performance.

/// 16 × u8 (NEON `uint8x16_t`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct U8x16(pub [u8; 16]);

/// 16 × i8 (NEON `int8x16_t`) — the int8 precision tier's comparison lanes
/// (v = 16 for V-QuickScorer).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct I8x16(pub [i8; 16]);

/// 8 × i16 (NEON `int16x8_t`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct I16x8(pub [i16; 8]);

/// 8 × u16 (NEON `uint16x8_t`) — comparison-mask results for i16 lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct U16x8(pub [u16; 8]);

/// 4 × i32 (NEON `int32x4_t`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct I32x4(pub [i32; 4]);

/// 4 × u32 (NEON `uint32x4_t`) — comparison-mask results for f32 lanes and
/// QuickScorer bitvectors with L ≤ 32.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct U32x4(pub [u32; 4]);

/// 4 × f32 (NEON `float32x4_t`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
#[repr(transparent)]
pub struct F32x4(pub [f32; 4]);

/// 2 × u64 (NEON `uint64x2_t`) — QuickScorer bitvectors with L ≤ 64.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct U64x2(pub [u64; 2]);

/// 2 × i64 (NEON `int64x2_t`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct I64x2(pub [i64; 2]);

// --------------------------------------------------------------- D registers

/// 4 × i16 (NEON `int16x4_t`, a D register half).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct I16x4(pub [i16; 4]);

/// 2 × i32 (NEON `int32x2_t`, a D register half).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct I32x2(pub [i32; 2]);

/// 8 × u8 (NEON `uint8x8_t`, a D register half).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct U8x8(pub [u8; 8]);

/// 8 × i8 (NEON `int8x8_t`, a D register half) — feeds the i8 → i16
/// widening moves of the int8 tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct I8x8(pub [i8; 8]);

/// 4 × u16 (NEON `uint16x4_t`, a D register half).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct U16x4(pub [u16; 4]);

/// 2 × u32 (NEON `uint32x2_t`, a D register half).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
#[repr(transparent)]
pub struct U32x2(pub [u32; 2]);

/// ACLE-style aliases so engine code reads like the paper's listings.
#[allow(non_camel_case_types)]
pub mod acle {
    pub type uint8x16_t = super::U8x16;
    pub type int8x16_t = super::I8x16;
    pub type int8x8_t = super::I8x8;
    pub type int16x8_t = super::I16x8;
    pub type uint16x8_t = super::U16x8;
    pub type int32x4_t = super::I32x4;
    pub type uint32x4_t = super::U32x4;
    pub type float32x4_t = super::F32x4;
    pub type uint64x2_t = super::U64x2;
    pub type int64x2_t = super::I64x2;
    pub type int16x4_t = super::I16x4;
    pub type int32x2_t = super::I32x2;
    pub type uint8x8_t = super::U8x8;
    pub type uint16x4_t = super::U16x4;
    pub type uint32x2_t = super::U32x2;
}

macro_rules! impl_bytes {
    ($ty:ident, $elem:ty, $n:expr) => {
        impl $ty {
            /// Reinterpret as the raw 16 register bytes (little-endian lanes,
            /// matching AArch64 memory order).
            #[inline]
            pub fn to_bytes(self) -> [u8; 16] {
                let mut out = [0u8; 16];
                for (i, v) in self.0.iter().enumerate() {
                    let b = v.to_le_bytes();
                    out[i * (16 / $n)..(i + 1) * (16 / $n)].copy_from_slice(&b);
                }
                out
            }

            /// Build from raw register bytes.
            #[inline]
            pub fn from_bytes(bytes: [u8; 16]) -> Self {
                let mut out = [<$elem>::default(); $n];
                for i in 0..$n {
                    let w = 16 / $n;
                    let mut b = [0u8; 16 / $n];
                    b.copy_from_slice(&bytes[i * w..(i + 1) * w]);
                    out[i] = <$elem>::from_le_bytes(b);
                }
                $ty(out)
            }
        }
    };
}

impl_bytes!(U8x16, u8, 16);
impl_bytes!(I8x16, i8, 16);
impl_bytes!(I16x8, i16, 8);
impl_bytes!(U16x8, u16, 8);
impl_bytes!(I32x4, i32, 4);
impl_bytes!(U32x4, u32, 4);
impl_bytes!(F32x4, f32, 4);
impl_bytes!(U64x2, u64, 2);
impl_bytes!(I64x2, i64, 2);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_reinterpret_roundtrip() {
        let v = U32x4([0x01020304, 0xAABBCCDD, 0, u32::MAX]);
        assert_eq!(U32x4::from_bytes(v.to_bytes()), v);
        let w = I16x8([1, -2, 3, -4, 5, -6, 7, i16::MIN]);
        assert_eq!(I16x8::from_bytes(w.to_bytes()), w);
    }

    #[test]
    fn lane_order_little_endian() {
        // Lane 0 occupies the lowest bytes, as on AArch64.
        let v = U32x4([0x11223344, 0, 0, 0]);
        let b = v.to_bytes();
        assert_eq!(&b[0..4], &[0x44, 0x33, 0x22, 0x11]);
    }

    #[test]
    fn cross_type_reinterpret() {
        // u32 mask 0xFFFFFFFF reinterpreted as u8 lanes = 4 × 0xFF.
        let v = U32x4([u32::MAX, 0, 0, 0]);
        let u = U8x16::from_bytes(v.to_bytes());
        assert_eq!(&u.0[0..4], &[255, 255, 255, 255]);
        assert_eq!(&u.0[4..8], &[0, 0, 0, 0]);
    }
}
