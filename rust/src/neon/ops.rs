//! NEON intrinsics, simulated bit-exactly.
//!
//! Function names and semantics follow the ARM C Language Extensions (ACLE);
//! each function documents the AArch64 instruction it models. Only the
//! intrinsics used by the paper's Algorithms 2 and 4, its §5.1 quantized
//! variants, and our engines are provided — this is an engine substrate, not
//! a complete ISA.
//!
//! All functions are `#[inline]` and operate on plain arrays, so LLVM
//! vectorizes them into native SSE/AVX; the *algorithms* stay exactly the
//! NEON ones. The hottest ops of the int tiers and the FLInt carrier
//! (`vcgtq_s8`, `vaddq_s8`, `vcgtq_s16`, `vaddq_s16`, `vcgtq_s32`)
//! additionally dispatch to the real
//! `core::arch::aarch64` intrinsics on AArch64 hosts; their simulated
//! `*_sim` twins remain the bit-exact behavior contract, enforced by the
//! parity tests at the bottom of this file and by the `neon-parity` audit
//! lint (`cargo run -p xtask -- audit`).

use super::types::*;

// ---------------------------------------------------------------------------
// Broadcast / load / store
// ---------------------------------------------------------------------------

/// `DUP Vd.16B, rn` — broadcast a u8 to all 16 lanes.
#[inline]
pub fn vdupq_n_u8(v: u8) -> U8x16 {
    U8x16([v; 16])
}

/// `DUP Vd.16B, rn` — broadcast an i8 to all 16 lanes.
#[inline]
pub fn vdupq_n_s8(v: i8) -> I8x16 {
    I8x16([v; 16])
}

/// `DUP Vd.8H, rn` — broadcast an i16 to all 8 lanes.
#[inline]
pub fn vdupq_n_s16(v: i16) -> I16x8 {
    I16x8([v; 8])
}

/// `DUP Vd.4S, rn` — broadcast an i32 to all 4 lanes (FLInt-encoded
/// thresholds in the f32-carrier engines).
#[inline]
pub fn vdupq_n_s32(v: i32) -> I32x4 {
    I32x4([v; 4])
}

/// `DUP Vd.4S, rn` — broadcast a u32 to all 4 lanes.
#[inline]
pub fn vdupq_n_u32(v: u32) -> U32x4 {
    U32x4([v; 4])
}

/// `DUP Vd.4S, vn` — broadcast an f32 to all 4 lanes.
#[inline]
pub fn vdupq_n_f32(v: f32) -> F32x4 {
    F32x4([v; 4])
}

/// `DUP Vd.2D, rn` — broadcast a u64 to both lanes.
#[inline]
pub fn vdupq_n_u64(v: u64) -> U64x2 {
    U64x2([v; 2])
}

/// `LD1 {Vt.4S}` — load 4 contiguous f32.
#[inline]
pub fn vld1q_f32(p: &[f32]) -> F32x4 {
    F32x4([p[0], p[1], p[2], p[3]])
}

/// `LD1 {Vt.8H}` — load 8 contiguous i16.
#[inline]
pub fn vld1q_s16(p: &[i16]) -> I16x8 {
    I16x8([p[0], p[1], p[2], p[3], p[4], p[5], p[6], p[7]])
}

/// `LD1 {Vt.16B}` — load 16 contiguous i8.
#[inline]
pub fn vld1q_s8(p: &[i8]) -> I8x16 {
    let mut out = [0i8; 16];
    out.copy_from_slice(&p[..16]);
    I8x16(out)
}

/// `LD1 {Vt.16B}` — load 16 contiguous u8.
#[inline]
pub fn vld1q_u8(p: &[u8]) -> U8x16 {
    let mut out = [0u8; 16];
    out.copy_from_slice(&p[..16]);
    U8x16(out)
}

/// `LD1 {Vt.4S}` — load 4 contiguous i32 (FLInt-encoded features).
#[inline]
pub fn vld1q_s32(p: &[i32]) -> I32x4 {
    I32x4([p[0], p[1], p[2], p[3]])
}

/// `LD1 {Vt.4S}` — load 4 contiguous u32.
#[inline]
pub fn vld1q_u32(p: &[u32]) -> U32x4 {
    U32x4([p[0], p[1], p[2], p[3]])
}

/// `LD1 {Vt.2D}` — load 2 contiguous u64.
#[inline]
pub fn vld1q_u64(p: &[u64]) -> U64x2 {
    U64x2([p[0], p[1]])
}

/// `ST1 {Vt.16B}` — store 16 u8.
#[inline]
pub fn vst1q_u8(p: &mut [u8], v: U8x16) {
    p[..16].copy_from_slice(&v.0);
}

/// `ST1 {Vt.4S}` — store 4 u32.
#[inline]
pub fn vst1q_u32(p: &mut [u32], v: U32x4) {
    p[..4].copy_from_slice(&v.0);
}

/// `ST1 {Vt.2D}` — store 2 u64.
#[inline]
pub fn vst1q_u64(p: &mut [u64], v: U64x2) {
    p[..2].copy_from_slice(&v.0);
}

/// `ST1 {Vt.8H}` — store 8 i16.
#[inline]
pub fn vst1q_s16(p: &mut [i16], v: I16x8) {
    p[..8].copy_from_slice(&v.0);
}

/// `ST1 {Vt.4S}` — store 4 f32.
#[inline]
pub fn vst1q_f32(p: &mut [f32], v: F32x4) {
    p[..4].copy_from_slice(&v.0);
}

// ---------------------------------------------------------------------------
// Lane access
// ---------------------------------------------------------------------------

/// `UMOV` — extract u8 lane.
#[inline]
pub fn vgetq_lane_u8(v: U8x16, lane: usize) -> u8 {
    v.0[lane]
}

/// `UMOV` — extract u32 lane.
#[inline]
pub fn vgetq_lane_u32(v: U32x4, lane: usize) -> u32 {
    v.0[lane]
}

/// `UMOV` — extract u64 lane.
#[inline]
pub fn vgetq_lane_u64(v: U64x2, lane: usize) -> u64 {
    v.0[lane]
}

/// `INS` — insert f32 lane.
#[inline]
pub fn vsetq_lane_f32(v: f32, vec: F32x4, lane: usize) -> F32x4 {
    let mut out = vec;
    out.0[lane] = v;
    out
}

// ---------------------------------------------------------------------------
// Comparisons (result lanes are all-ones on true, zero on false)
// ---------------------------------------------------------------------------

/// `FCMGT Vd.4S` — per-lane `a > b` for f32.
#[inline]
pub fn vcgtq_f32(a: F32x4, b: F32x4) -> U32x4 {
    let mut out = [0u32; 4];
    for i in 0..4 {
        out[i] = if a.0[i] > b.0[i] { u32::MAX } else { 0 };
    }
    U32x4(out)
}

/// `CMGT Vd.8H` — per-lane `a > b` for i16. Issues the real instruction on
/// AArch64; [`vcgtq_s16_sim`] is the bit-exact contract everywhere else.
#[inline]
pub fn vcgtq_s16(a: I16x8, b: I16x8) -> U16x8 {
    // parity: native_cmgt_s16_matches_sim
    #[cfg(target_arch = "aarch64")]
    return vcgtq_s16_native(a, b);
    #[cfg(not(target_arch = "aarch64"))]
    vcgtq_s16_sim(a, b)
}

/// Simulated reference for [`vcgtq_s16`] (the only path off-ARM).
#[inline]
pub fn vcgtq_s16_sim(a: I16x8, b: I16x8) -> U16x8 {
    let mut out = [0u16; 8];
    for i in 0..8 {
        out[i] = if a.0[i] > b.0[i] { u16::MAX } else { 0 };
    }
    U16x8(out)
}

/// The real `CMGT Vd.8H, Vn.8H, Vm.8H`.
// parity: native_cmgt_s16_matches_sim
#[cfg(target_arch = "aarch64")]
#[inline]
fn vcgtq_s16_native(a: I16x8, b: I16x8) -> U16x8 {
    use core::arch::aarch64 as arm;
    // SAFETY: NEON (ASIMD) is baseline on AArch64; each ld1/st1 pointer
    // covers exactly one 16-byte register drawn from/into a local array.
    unsafe {
        let va = arm::vld1q_s16(a.0.as_ptr());
        let vb = arm::vld1q_s16(b.0.as_ptr());
        let mut out = [0u16; 8];
        arm::vst1q_u16(out.as_mut_ptr(), arm::vcgtq_s16(va, vb));
        U16x8(out)
    }
}

/// `CMGT Vd.4S` — per-lane `a > b` for i32: the FLInt carrier's threshold
/// compare, replacing `FCMGT` (`vcgtq_f32`) with the integer pipe while
/// producing the identical all-ones/zero `U32x4` mask, so the f32 engines'
/// mask-widening and score paths are reused unchanged. Issues the real
/// instruction on AArch64; [`vcgtq_s32_sim`] is the bit-exact contract
/// everywhere else.
#[inline]
pub fn vcgtq_s32(a: I32x4, b: I32x4) -> U32x4 {
    // parity: native_cmgt_s32_matches_sim
    #[cfg(target_arch = "aarch64")]
    return vcgtq_s32_native(a, b);
    #[cfg(not(target_arch = "aarch64"))]
    vcgtq_s32_sim(a, b)
}

/// Simulated reference for [`vcgtq_s32`] (the only path off-ARM).
#[inline]
pub fn vcgtq_s32_sim(a: I32x4, b: I32x4) -> U32x4 {
    let mut out = [0u32; 4];
    for i in 0..4 {
        out[i] = if a.0[i] > b.0[i] { u32::MAX } else { 0 };
    }
    U32x4(out)
}

/// The real `CMGT Vd.4S, Vn.4S, Vm.4S`.
// parity: native_cmgt_s32_matches_sim
#[cfg(target_arch = "aarch64")]
#[inline]
fn vcgtq_s32_native(a: I32x4, b: I32x4) -> U32x4 {
    use core::arch::aarch64 as arm;
    // SAFETY: NEON (ASIMD) is baseline on AArch64; each ld1/st1 pointer
    // covers exactly one 16-byte register drawn from/into a local array.
    unsafe {
        let va = arm::vld1q_s32(a.0.as_ptr());
        let vb = arm::vld1q_s32(b.0.as_ptr());
        let mut out = [0u32; 4];
        arm::vst1q_u32(out.as_mut_ptr(), arm::vcgtq_s32(va, vb));
        U32x4(out)
    }
}

/// `CMGT Vd.16B` — per-lane `a > b` for i8 (the int8 tier's 16-wide split
/// comparison). Issues the real instruction on AArch64; [`vcgtq_s8_sim`]
/// is the bit-exact contract everywhere else.
#[inline]
pub fn vcgtq_s8(a: I8x16, b: I8x16) -> U8x16 {
    // parity: native_cmgt_s8_matches_sim
    #[cfg(target_arch = "aarch64")]
    return vcgtq_s8_native(a, b);
    #[cfg(not(target_arch = "aarch64"))]
    vcgtq_s8_sim(a, b)
}

/// Simulated reference for [`vcgtq_s8`] (the only path off-ARM).
#[inline]
pub fn vcgtq_s8_sim(a: I8x16, b: I8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = if a.0[i] > b.0[i] { u8::MAX } else { 0 };
    }
    U8x16(out)
}

/// The real `CMGT Vd.16B, Vn.16B, Vm.16B`.
// parity: native_cmgt_s8_matches_sim
#[cfg(target_arch = "aarch64")]
#[inline]
fn vcgtq_s8_native(a: I8x16, b: I8x16) -> U8x16 {
    use core::arch::aarch64 as arm;
    // SAFETY: NEON (ASIMD) is baseline on AArch64; each ld1/st1 pointer
    // covers exactly one 16-byte register drawn from/into a local array.
    unsafe {
        let va = arm::vld1q_s8(a.0.as_ptr());
        let vb = arm::vld1q_s8(b.0.as_ptr());
        let mut out = [0u8; 16];
        arm::vst1q_u8(out.as_mut_ptr(), arm::vcgtq_s8(va, vb));
        U8x16(out)
    }
}

/// `CMEQ Vd.16B` — per-lane `a == b` for u8.
#[inline]
pub fn vceqq_u8(a: U8x16, b: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = if a.0[i] == b.0[i] { u8::MAX } else { 0 };
    }
    U8x16(out)
}

/// `CMTST Vd.16B` — per-lane `(a & b) != 0` for u8 (the paper's Alg. 4 uses
/// this against an all-ones vector to fuse "compare ≠ 0" with the negation).
#[inline]
pub fn vtstq_u8(a: U8x16, b: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = if a.0[i] & b.0[i] != 0 { u8::MAX } else { 0 };
    }
    U8x16(out)
}

// ---------------------------------------------------------------------------
// Bitwise
// ---------------------------------------------------------------------------

macro_rules! bitwise {
    ($and:ident, $orr:ident, $mvn:ident, $ty:ident, $n:expr) => {
        /// `AND Vd` — bitwise and.
        #[inline]
        pub fn $and(a: $ty, b: $ty) -> $ty {
            let mut out = a;
            for i in 0..$n {
                out.0[i] &= b.0[i];
            }
            out
        }

        /// `ORR Vd` — bitwise or.
        #[inline]
        pub fn $orr(a: $ty, b: $ty) -> $ty {
            let mut out = a;
            for i in 0..$n {
                out.0[i] |= b.0[i];
            }
            out
        }

        /// `MVN Vd` — bitwise not.
        #[inline]
        pub fn $mvn(a: $ty) -> $ty {
            let mut out = a;
            for i in 0..$n {
                out.0[i] = !out.0[i];
            }
            out
        }
    };
}

bitwise!(vandq_u8, vorrq_u8, vmvnq_u8, U8x16, 16);
bitwise!(vandq_u16, vorrq_u16, vmvnq_u16, U16x8, 8);
bitwise!(vandq_u32, vorrq_u32, vmvnq_u32, U32x4, 4);
bitwise!(vandq_u64, vorrq_u64, vmvnq_u64, U64x2, 2);

/// `BSL Vd.16B` — bitwise select: for each *bit*, `sel ? a : b`.
#[inline]
pub fn vbslq_u8(sel: U8x16, a: U8x16, b: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = (sel.0[i] & a.0[i]) | (!sel.0[i] & b.0[i]);
    }
    U8x16(out)
}

/// `BSL` on 32-bit lanes.
#[inline]
pub fn vbslq_u32(sel: U32x4, a: U32x4, b: U32x4) -> U32x4 {
    let mut out = [0u32; 4];
    for i in 0..4 {
        out[i] = (sel.0[i] & a.0[i]) | (!sel.0[i] & b.0[i]);
    }
    U32x4(out)
}

/// `BSL` on 64-bit lanes.
#[inline]
pub fn vbslq_u64(sel: U64x2, a: U64x2, b: U64x2) -> U64x2 {
    let mut out = [0u64; 2];
    for i in 0..2 {
        out[i] = (sel.0[i] & a.0[i]) | (!sel.0[i] & b.0[i]);
    }
    U64x2(out)
}

// ---------------------------------------------------------------------------
// Bit manipulation (the Alg. 4 exit-leaf search)
// ---------------------------------------------------------------------------

/// `RBIT Vd.16B` — reverse the bits *within each byte*.
#[inline]
pub fn vrbitq_u8(a: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a.0[i].reverse_bits();
    }
    U8x16(out)
}

/// `CLZ Vd.16B` — count leading zeros per byte.
#[inline]
pub fn vclzq_u8(a: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a.0[i].leading_zeros() as u8;
    }
    U8x16(out)
}

// ---------------------------------------------------------------------------
// Arithmetic
// ---------------------------------------------------------------------------

/// `MLA Vd.16B` — multiply-accumulate: `a + b * c` per u8 lane (wrapping).
#[inline]
pub fn vmlaq_u8(a: U8x16, b: U8x16, c: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a.0[i].wrapping_add(b.0[i].wrapping_mul(c.0[i]));
    }
    U8x16(out)
}

/// `FADD Vd.4S` — f32 add.
#[inline]
pub fn vaddq_f32(a: F32x4, b: F32x4) -> F32x4 {
    F32x4([a.0[0] + b.0[0], a.0[1] + b.0[1], a.0[2] + b.0[2], a.0[3] + b.0[3]])
}

/// `ADD Vd.8H` — i16 add (wrapping, as on hardware). Issues the real
/// instruction on AArch64; [`vaddq_s16_sim`] is the contract off-ARM.
#[inline]
pub fn vaddq_s16(a: I16x8, b: I16x8) -> I16x8 {
    // parity: native_add_s16_matches_sim
    #[cfg(target_arch = "aarch64")]
    return vaddq_s16_native(a, b);
    #[cfg(not(target_arch = "aarch64"))]
    vaddq_s16_sim(a, b)
}

/// Simulated reference for [`vaddq_s16`] (the only path off-ARM).
#[inline]
pub fn vaddq_s16_sim(a: I16x8, b: I16x8) -> I16x8 {
    let mut out = [0i16; 8];
    for i in 0..8 {
        out[i] = a.0[i].wrapping_add(b.0[i]);
    }
    I16x8(out)
}

/// The real `ADD Vd.8H, Vn.8H, Vm.8H`.
// parity: native_add_s16_matches_sim
#[cfg(target_arch = "aarch64")]
#[inline]
fn vaddq_s16_native(a: I16x8, b: I16x8) -> I16x8 {
    use core::arch::aarch64 as arm;
    // SAFETY: NEON (ASIMD) is baseline on AArch64; each ld1/st1 pointer
    // covers exactly one 16-byte register drawn from/into a local array.
    unsafe {
        let va = arm::vld1q_s16(a.0.as_ptr());
        let vb = arm::vld1q_s16(b.0.as_ptr());
        let mut out = [0i16; 8];
        arm::vst1q_s16(out.as_mut_ptr(), arm::vaddq_s16(va, vb));
        I16x8(out)
    }
}

/// `ADD Vd.16B` — i8 add (wrapping) — the int8 tier's native 16-lane score
/// accumulation ([`crate::quant::AccumMode::Native`]). Issues the real
/// instruction on AArch64; [`vaddq_s8_sim`] is the contract off-ARM.
#[inline]
pub fn vaddq_s8(a: I8x16, b: I8x16) -> I8x16 {
    // parity: native_add_s8_matches_sim
    #[cfg(target_arch = "aarch64")]
    return vaddq_s8_native(a, b);
    #[cfg(not(target_arch = "aarch64"))]
    vaddq_s8_sim(a, b)
}

/// Simulated reference for [`vaddq_s8`] (the only path off-ARM).
#[inline]
pub fn vaddq_s8_sim(a: I8x16, b: I8x16) -> I8x16 {
    let mut out = [0i8; 16];
    for i in 0..16 {
        out[i] = a.0[i].wrapping_add(b.0[i]);
    }
    I8x16(out)
}

/// The real `ADD Vd.16B, Vn.16B, Vm.16B`.
// parity: native_add_s8_matches_sim
#[cfg(target_arch = "aarch64")]
#[inline]
fn vaddq_s8_native(a: I8x16, b: I8x16) -> I8x16 {
    use core::arch::aarch64 as arm;
    // SAFETY: NEON (ASIMD) is baseline on AArch64; each ld1/st1 pointer
    // covers exactly one 16-byte register drawn from/into a local array.
    unsafe {
        let va = arm::vld1q_s8(a.0.as_ptr());
        let vb = arm::vld1q_s8(b.0.as_ptr());
        let mut out = [0i8; 16];
        arm::vst1q_s8(out.as_mut_ptr(), arm::vaddq_s8(va, vb));
        I8x16(out)
    }
}

/// `SADDW Vd.8H, Vn.8H, Vm.8B` — widening add: i16 accumulator += i8 half
/// register, sign-extended. The int8 tier's widened score accumulation
/// ([`crate::quant::AccumMode::Widened`]).
#[inline]
pub fn vaddw_s8(a: I16x8, b: I8x8) -> I16x8 {
    let mut out = [0i16; 8];
    for i in 0..8 {
        out[i] = a.0[i].wrapping_add(b.0[i] as i16);
    }
    I16x8(out)
}

/// `ADD Vd.4S` — i32 add (wrapping).
#[inline]
pub fn vaddq_s32(a: I32x4, b: I32x4) -> I32x4 {
    let mut out = [0i32; 4];
    for i in 0..4 {
        out[i] = a.0[i].wrapping_add(b.0[i]);
    }
    I32x4(out)
}

/// `SRSHR Vd.16B, Vn.16B, #n` — rounding arithmetic shift right per i8
/// lane: `(v + 2^(n-1)) >> n`, computed in wider precision (the hardware
/// rounding constant cannot wrap the lane). Applies the per-tree leaf
/// shift of the per-tree-scale quantization mode
/// ([`crate::quant::QForest::from_forest_per_tree`]); `n = 0` is the
/// identity (the instruction requires `n ≥ 1`).
#[inline]
pub fn vrshrq_n_s8(a: I8x16, n: u32) -> I8x16 {
    if n == 0 {
        return a;
    }
    let mut out = [0i8; 16];
    for i in 0..16 {
        out[i] = ((a.0[i] as i32 + (1 << (n - 1))) >> n) as i8;
    }
    I8x16(out)
}

/// `SRSHR Vd.8H, Vn.8H, #n` — rounding arithmetic shift right per i16 lane
/// (see [`vrshrq_n_s8`]).
#[inline]
pub fn vrshrq_n_s16(a: I16x8, n: u32) -> I16x8 {
    if n == 0 {
        return a;
    }
    let mut out = [0i16; 8];
    for i in 0..8 {
        out[i] = ((a.0[i] as i32 + (1 << (n - 1))) >> n) as i16;
    }
    I16x8(out)
}

// ---------------------------------------------------------------------------
// Narrowing / widening / halves (the §5.1 mask-extension chain)
// ---------------------------------------------------------------------------

/// Low 8 i8 lanes.
#[inline]
pub fn vget_low_s8(a: I8x16) -> I8x8 {
    let mut out = [0i8; 8];
    out.copy_from_slice(&a.0[..8]);
    I8x8(out)
}

/// High 8 i8 lanes.
#[inline]
pub fn vget_high_s8(a: I8x16) -> I8x8 {
    let mut out = [0i8; 8];
    out.copy_from_slice(&a.0[8..]);
    I8x8(out)
}

/// `SSHLL` — sign-extend 8 i8 to 8 i16. Applied to comparison masks
/// (all-ones/zero) this is the first step of the §5.1-style widening chain
/// for the int8 tier (i8 mask → i16 → i32 bitvector words).
#[inline]
pub fn vmovl_s8(a: I8x8) -> I16x8 {
    let mut out = [0i16; 8];
    for i in 0..8 {
        out[i] = a.0[i] as i16;
    }
    I16x8(out)
}

/// `DUP Vd.1D` (lower half) — low 4 i16 lanes.
#[inline]
pub fn vget_low_s16(a: I16x8) -> I16x4 {
    I16x4([a.0[0], a.0[1], a.0[2], a.0[3]])
}

/// Upper 4 i16 lanes.
#[inline]
pub fn vget_high_s16(a: I16x8) -> I16x4 {
    I16x4([a.0[4], a.0[5], a.0[6], a.0[7]])
}

/// `SSHLL` — sign-extend 4 i16 to 4 i32. Applied to comparison masks
/// (all-ones/zero) this yields 32-bit all-ones/zero lanes, which is exactly
/// how §5.1 widens an int16 compare mask to cover 32-bit bitvector words.
#[inline]
pub fn vmovl_s16(a: I16x4) -> I32x4 {
    I32x4([a.0[0] as i32, a.0[1] as i32, a.0[2] as i32, a.0[3] as i32])
}

/// Low 2 i32 lanes.
#[inline]
pub fn vget_low_s32(a: I32x4) -> I32x2 {
    I32x2([a.0[0], a.0[1]])
}

/// High 2 i32 lanes.
#[inline]
pub fn vget_high_s32(a: I32x4) -> I32x2 {
    I32x2([a.0[2], a.0[3]])
}

/// `SSHLL` — sign-extend 2 i32 to 2 i64.
#[inline]
pub fn vmovl_s32(a: I32x2) -> I64x2 {
    I64x2([a.0[0] as i64, a.0[1] as i64])
}

/// Low/high u32 halves (for widening f32-compare masks to u64 bitvectors).
#[inline]
pub fn vget_low_u32(a: U32x4) -> U32x2 {
    U32x2([a.0[0], a.0[1]])
}

/// High 2 u32 lanes.
#[inline]
pub fn vget_high_u32(a: U32x4) -> U32x2 {
    U32x2([a.0[2], a.0[3]])
}

/// `USHLL` — zero-extend... but for *masks* we sign-extend so all-ones stays
/// all-ones: implemented as arithmetic extension of the mask semantics.
#[inline]
pub fn vmovl_mask_u32(a: U32x2) -> U64x2 {
    U64x2([
        if a.0[0] != 0 { u64::MAX } else { 0 },
        if a.0[1] != 0 { u64::MAX } else { 0 },
    ])
}

/// `XTN` — narrow 4 u32 lanes to 4 u16 lanes (truncating).
#[inline]
pub fn vmovn_u32(a: U32x4) -> U16x4 {
    U16x4([a.0[0] as u16, a.0[1] as u16, a.0[2] as u16, a.0[3] as u16])
}

/// `XTN` — narrow 8 u16 lanes to 8 u8 lanes (truncating).
#[inline]
pub fn vmovn_u16(a: U16x8) -> U8x8 {
    let mut out = [0u8; 8];
    for i in 0..8 {
        out[i] = a.0[i] as u8;
    }
    U8x8(out)
}

/// Combine two D registers into a Q register.
#[inline]
pub fn vcombine_u16(lo: U16x4, hi: U16x4) -> U16x8 {
    U16x8([lo.0[0], lo.0[1], lo.0[2], lo.0[3], hi.0[0], hi.0[1], hi.0[2], hi.0[3]])
}

/// Combine two u8 D registers.
#[inline]
pub fn vcombine_u8(lo: U8x8, hi: U8x8) -> U8x16 {
    let mut out = [0u8; 16];
    out[..8].copy_from_slice(&lo.0);
    out[8..].copy_from_slice(&hi.0);
    U8x16(out)
}

// ---------------------------------------------------------------------------
// Horizontal reductions (mask-nonzero checks)
// ---------------------------------------------------------------------------

/// `UMAXV Bd, Vn.16B` — max across u8 lanes.
#[inline]
pub fn vmaxvq_u8(a: U8x16) -> u8 {
    a.0.iter().copied().max().unwrap()
}

/// `UMAXV Hd, Vn.8H` — max across u16 lanes.
#[inline]
pub fn vmaxvq_u16(a: U16x8) -> u16 {
    a.0.iter().copied().max().unwrap()
}

/// `UMAXV Sd, Vn.4S` — max across u32 lanes.
#[inline]
pub fn vmaxvq_u32(a: U32x4) -> u32 {
    a.0.iter().copied().max().unwrap()
}

/// `FADDP`-chain — horizontal f32 sum (used in score reduction).
#[inline]
pub fn vaddvq_f32(a: F32x4) -> f32 {
    (a.0[0] + a.0[1]) + (a.0[2] + a.0[3])
}

// ---------------------------------------------------------------------------
// Reinterpret casts (free on hardware)
// ---------------------------------------------------------------------------

/// `vreinterpretq_u8_u16` — no-op register cast.
#[inline]
pub fn vreinterpretq_u8_u16(a: U16x8) -> U8x16 {
    U8x16::from_bytes(a.to_bytes())
}

/// `vreinterpretq_u8_u32` — no-op register cast.
#[inline]
pub fn vreinterpretq_u8_u32(a: U32x4) -> U8x16 {
    U8x16::from_bytes(a.to_bytes())
}

/// `vreinterpretq_u32_s32` — no-op register cast.
#[inline]
pub fn vreinterpretq_u32_s32(a: I32x4) -> U32x4 {
    U32x4::from_bytes(a.to_bytes())
}

/// `vreinterpretq_u64_s64` — no-op register cast.
#[inline]
pub fn vreinterpretq_u64_s64(a: I64x2) -> U64x2 {
    U64x2::from_bytes(a.to_bytes())
}

/// `vreinterpretq_u16_s16`-of-compare: the u16 mask viewed as i16 lanes
/// (for feeding `vmovl_s16`).
#[inline]
pub fn vreinterpretq_s16_u16(a: U16x8) -> I16x8 {
    I16x8::from_bytes(a.to_bytes())
}

/// The u8 compare mask viewed as i8 lanes (for feeding `vmovl_s8`).
#[inline]
pub fn vreinterpretq_s8_u8(a: U8x16) -> I8x16 {
    I8x16::from_bytes(a.to_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compare_masks_all_ones() {
        let m = vcgtq_f32(F32x4([1.0, 0.0, 2.0, -1.0]), vdupq_n_f32(0.5));
        assert_eq!(m, U32x4([u32::MAX, 0, u32::MAX, 0]));
        let m = vcgtq_s16(I16x8([1, 0, -5, 7, 8, -1, 3, 2]), vdupq_n_s16(2));
        assert_eq!(m.0, [0, 0, 0, u16::MAX, u16::MAX, 0, u16::MAX, 0]);
    }

    #[test]
    fn nan_compares_false() {
        let m = vcgtq_f32(F32x4([f32::NAN, 1.0, f32::NAN, 2.0]), vdupq_n_f32(0.0));
        assert_eq!(m.0, [0, u32::MAX, 0, u32::MAX]);
    }

    #[test]
    fn i32_compare_mask_matches_f32_on_flint_encodings() {
        // The carrier contract in miniature: CMGT over FLInt-mapped lanes
        // produces the same U32x4 mask FCMGT produced over the floats.
        let xs = [3.5f32, -0.0, f32::NAN, 2e-40];
        let t = 0.5f32;
        let want = vcgtq_f32(F32x4(xs), vdupq_n_f32(t));
        let enc = xs.map(crate::quant::flint::encode_feature_gt);
        let got = vcgtq_s32(vld1q_s32(&enc), vdupq_n_s32(crate::quant::flint::encode_threshold(t)));
        assert_eq!(got, want);
        assert_eq!(vcgtq_s32(vdupq_n_s32(1), vdupq_n_s32(1)).0, [0; 4]);
        assert_eq!(vcgtq_s32(vdupq_n_s32(i32::MAX), vdupq_n_s32(i32::MIN)).0, [u32::MAX; 4]);
    }

    #[test]
    fn tst_vs_ceq() {
        let a = U8x16([0, 1, 2, 0, 255, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 4]);
        let ones = vdupq_n_u8(0xFF);
        // vtstq(a, ones) = "a != 0" mask — the fused negated-compare trick.
        let t = vtstq_u8(a, ones);
        let expect: Vec<u8> = a.0.iter().map(|&v| if v != 0 { 255 } else { 0 }).collect();
        assert_eq!(&t.0[..], &expect[..]);
        // and equals NOT(vceq(a, 0))
        let e = vmvnq_u8(vceqq_u8(a, vdupq_n_u8(0)));
        assert_eq!(t, e);
    }

    #[test]
    fn bsl_selects_bitwise() {
        let sel = U8x16([0xF0; 16]);
        let a = vdupq_n_u8(0xAA);
        let b = vdupq_n_u8(0x55);
        let r = vbslq_u8(sel, a, b);
        assert_eq!(r.0[0], (0xF0 & 0xAA) | (0x0F & 0x55));
    }

    #[test]
    fn rbit_clz_finds_lowest_set_bit() {
        // ctz(b) == clz(rbit(b)) — Alg. 4 line 7.
        for b in [1u8, 2, 4, 0b1010_0000, 0b0001_1000, 255] {
            let v = vdupq_n_u8(b);
            let ctz = vclzq_u8(vrbitq_u8(v));
            assert_eq!(ctz.0[0] as u32, b.trailing_zeros(), "byte {b:#010b}");
        }
    }

    #[test]
    fn clz_of_zero_is_eight() {
        assert_eq!(vclzq_u8(vdupq_n_u8(0)).0[0], 8);
    }

    #[test]
    fn mla_formula() {
        // c = c1 * 8 + c2 — the exit-leaf index combine (Alg. 4 line 8).
        let c2 = U8x16([3; 16]);
        let c1 = U8x16([2; 16]);
        let r = vmlaq_u8(c2, c1, vdupq_n_u8(8));
        assert_eq!(r.0[0], 19);
    }

    #[test]
    fn widening_mask_chain_s16() {
        // int16 compare mask -> two 32-bit masks, as §5.1 describes.
        let m = vcgtq_s16(I16x8([5, 0, 5, 0, 5, 0, 5, 0]), vdupq_n_s16(1));
        let mi = vreinterpretq_s16_u16(m);
        let lo = vreinterpretq_u32_s32(vmovl_s16(vget_low_s16(mi)));
        let hi = vreinterpretq_u32_s32(vmovl_s16(vget_high_s16(mi)));
        assert_eq!(lo, U32x4([u32::MAX, 0, u32::MAX, 0]));
        assert_eq!(hi, U32x4([u32::MAX, 0, u32::MAX, 0]));
        // ... and on to 64-bit masks for L=64.
        let lolo = vreinterpretq_u64_s64(vmovl_s32(vget_low_s32(
            super::super::ops::i32x4_from_u32(lo),
        )));
        assert_eq!(lolo, U64x2([u64::MAX, 0]));
    }

    #[test]
    fn narrow_combine_roundtrip() {
        let m0 = U32x4([u32::MAX, 0, u32::MAX, 0]);
        let m1 = U32x4([0, 0, u32::MAX, u32::MAX]);
        let n = vcombine_u16(vmovn_u32(m0), vmovn_u32(m1));
        assert_eq!(n.0, [0xFFFF, 0, 0xFFFF, 0, 0, 0, 0xFFFF, 0xFFFF]);
        let b = vmovn_u16(n);
        assert_eq!(b.0, [0xFF, 0, 0xFF, 0, 0, 0, 0xFF, 0xFF]);
    }

    #[test]
    fn reductions() {
        assert_eq!(vmaxvq_u8(vdupq_n_u8(0)), 0);
        assert_eq!(vmaxvq_u32(U32x4([0, 1, 0, 7])), 7);
        assert!((vaddvq_f32(F32x4([1.0, 2.0, 3.0, 4.0])) - 10.0).abs() < 1e-6);
    }

    #[test]
    fn wrapping_adds() {
        let r = vaddq_s16(vdupq_n_s16(i16::MAX), vdupq_n_s16(1));
        assert_eq!(r.0[0], i16::MIN);
        let r = vaddq_s8(vdupq_n_s8(i8::MAX), vdupq_n_s8(1));
        assert_eq!(r.0[0], i8::MIN);
    }

    #[test]
    fn i8_compare_mask() {
        let a = I8x16([3, -1, 0, 5, 2, 2, -8, 127, 0, 0, 0, 0, 0, 0, 0, 1]);
        let m = vcgtq_s8(a, vdupq_n_s8(1));
        assert_eq!(m.0[0], u8::MAX);
        assert_eq!(m.0[1], 0);
        assert_eq!(m.0[3], u8::MAX);
        assert_eq!(m.0[4], u8::MAX);
        assert_eq!(m.0[6], 0);
        assert_eq!(m.0[15], 0);
    }

    #[test]
    fn widening_mask_chain_s8() {
        // i8 compare mask -> i16 -> 32-bit masks: the int8-tier analogue of
        // the §5.1 chain, so a 16-lane compare drives u32 bitvector updates.
        let m = vcgtq_s8(I8x16([5, 0, 5, 0, 5, 0, 5, 0, 0, 5, 0, 5, 0, 5, 0, 5]), vdupq_n_s8(1));
        let mi = vreinterpretq_s8_u8(m);
        let lo16 = vmovl_s8(vget_low_s8(mi));
        let hi16 = vmovl_s8(vget_high_s8(mi));
        assert_eq!(lo16.0, [-1, 0, -1, 0, -1, 0, -1, 0]);
        assert_eq!(hi16.0, [0, -1, 0, -1, 0, -1, 0, -1]);
        let q0 = vreinterpretq_u32_s32(vmovl_s16(vget_low_s16(lo16)));
        assert_eq!(q0, U32x4([u32::MAX, 0, u32::MAX, 0]));
        let q3 = vreinterpretq_u32_s32(vmovl_s16(vget_high_s16(hi16)));
        assert_eq!(q3, U32x4([0, u32::MAX, 0, u32::MAX]));
    }

    #[test]
    fn rounding_shift_right_matches_scalar() {
        // SRSHR == (v + 2^(n-1)) >> n in wide arithmetic, for every i8 and
        // every shift — the per-tree-shift contract engines rely on.
        for n in 1..=7u32 {
            for v in i8::MIN..=i8::MAX {
                let want = ((v as i32 + (1 << (n - 1))) >> n) as i8;
                assert_eq!(vrshrq_n_s8(vdupq_n_s8(v), n).0[0], want, "v={v} n={n}");
            }
        }
        // The rounding constant cannot wrap the lane (wide intermediate).
        assert_eq!(vrshrq_n_s8(vdupq_n_s8(i8::MAX), 1).0[0], 64);
        assert_eq!(vrshrq_n_s8(vdupq_n_s8(i8::MIN), 1).0[0], -64);
        assert_eq!(vrshrq_n_s16(vdupq_n_s16(i16::MAX), 1).0[0], 16384);
        // n = 0 is the identity.
        assert_eq!(vrshrq_n_s8(vdupq_n_s8(-3), 0).0[0], -3);
        assert_eq!(vrshrq_n_s16(vdupq_n_s16(77), 0).0[0], 77);
    }

    #[test]
    fn widening_accumulate_s8() {
        // SADDW: i16 acc += sign-extended i8 lanes, no i8 wrap possible.
        let mut acc = vdupq_n_s16(100);
        for _ in 0..4 {
            acc = vaddw_s8(acc, vget_low_s8(vdupq_n_s8(120)));
        }
        assert_eq!(acc.0[0], 100 + 4 * 120); // 580 — would wrap an i8 acc
        let acc = vaddw_s8(vdupq_n_s16(0), vget_high_s8(vdupq_n_s8(-5)));
        assert_eq!(acc.0[7], -5);
    }
}

/// Helper used in tests: view a u32 mask register as i32 lanes.
#[inline]
pub fn i32x4_from_u32(a: U32x4) -> I32x4 {
    I32x4::from_bytes(a.to_bytes())
}

/// Native-vs-simulated parity, runnable only on AArch64 hosts (`cargo test`
/// on an ARM device). Each test is named by a `// parity:` comment above
/// and the audit's `neon-parity` lint verifies the pairing stays intact.
#[cfg(all(test, target_arch = "aarch64"))]
mod parity_tests {
    use super::*;

    /// Lane patterns that exercise sign boundaries, wrap, and mixed order.
    const I8_CASES: [[i8; 16]; 4] = [
        [0; 16],
        [i8::MIN, i8::MAX, -1, 1, 0, 64, -64, 127, -128, 3, -3, 100, -100, 7, -7, 2],
        [1; 16],
        [-1, -1, 0, 0, i8::MAX, i8::MAX, i8::MIN, i8::MIN, 5, -5, 50, -50, 9, -9, 11, -11],
    ];
    const I16_CASES: [[i16; 8]; 4] = [
        [0; 8],
        [i16::MIN, i16::MAX, -1, 1, 0, 1024, -1024, 32767],
        [1; 8],
        [-1, 0, i16::MAX, i16::MIN, 300, -300, 7, -7],
    ];
    /// Includes FLInt-mapped corner patterns: map(±0.0) = 0/-1,
    /// map(±inf) = ±0x7f80_0000, and the NaN saturations i32::MIN/MAX.
    const I32_CASES: [[i32; 4]; 4] = [
        [0; 4],
        [i32::MIN, i32::MAX, -1, 1],
        [0x7f80_0000, -0x7f80_0000, 8, -8],
        [-1, 0, i32::MAX, i32::MIN],
    ];

    #[test]
    fn native_cmgt_s8_matches_sim() {
        for a in I8_CASES {
            for b in I8_CASES {
                let (a, b) = (I8x16(a), I8x16(b));
                assert_eq!(vcgtq_s8_native(a, b), vcgtq_s8_sim(a, b), "{a:?} > {b:?}");
            }
        }
    }

    #[test]
    fn native_add_s8_matches_sim() {
        for a in I8_CASES {
            for b in I8_CASES {
                let (a, b) = (I8x16(a), I8x16(b));
                assert_eq!(vaddq_s8_native(a, b), vaddq_s8_sim(a, b), "{a:?} + {b:?}");
            }
        }
    }

    #[test]
    fn native_cmgt_s16_matches_sim() {
        for a in I16_CASES {
            for b in I16_CASES {
                let (a, b) = (I16x8(a), I16x8(b));
                assert_eq!(vcgtq_s16_native(a, b), vcgtq_s16_sim(a, b), "{a:?} > {b:?}");
            }
        }
    }

    #[test]
    fn native_cmgt_s32_matches_sim() {
        for a in I32_CASES {
            for b in I32_CASES {
                let (a, b) = (I32x4(a), I32x4(b));
                assert_eq!(vcgtq_s32_native(a, b), vcgtq_s32_sim(a, b), "{a:?} > {b:?}");
            }
        }
    }

    #[test]
    fn native_add_s16_matches_sim() {
        for a in I16_CASES {
            for b in I16_CASES {
                let (a, b) = (I16x8(a), I16x8(b));
                assert_eq!(vaddq_s16_native(a, b), vaddq_s16_sim(a, b), "{a:?} + {b:?}");
            }
        }
    }
}
