//! ARM NEON instruction-set simulation (DESIGN.md system S1).
//!
//! The paper's contribution is the *conversion* of the QuickScorer family to
//! ARM NEON; its SIMD algorithms are specified as NEON intrinsic sequences
//! (Algorithms 2 and 4, §5.1). Since this build environment has no ARM
//! hardware, [`types`] and [`ops`] model the NEON Q/D registers and the
//! needed intrinsics bit-exactly, so the engines in [`crate::engine`] execute
//! the paper's instruction sequences verbatim. [`trace`] provides the
//! operation-count substrate the per-device cost model consumes.

pub mod ops;
pub mod trace;
pub mod types;

pub use ops::*;
pub use trace::OpTrace;
pub use types::*;
