//! Operation traces for the device cost model.
//!
//! We do not own a Raspberry Pi or an Odroid-XU4, so per-device runtimes are
//! *estimated*: each engine can produce an [`OpTrace`] — exact dynamic counts
//! of the operations it would execute for a given batch — and
//! [`crate::device`] converts traces into cycle/time estimates using
//! per-microarchitecture cost tables. Counting lives outside the hot path
//! (separate `count_ops` walks), so benchmarks measure undisturbed code.

/// Dynamic operation counts for one engine invocation.
///
/// Categories are chosen to match the cost-table granularity of the ARM
/// software optimization guides: scalar ALU/branch/FP, NEON ALU/MUL/FP,
/// horizontal (cross-lane) NEON ops, and memory accesses split by expected
/// locality (sequential stream vs. data-dependent random access).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpTrace {
    /// Scalar integer ALU ops (add/and/shift/compare).
    pub scalar_alu: u64,
    /// Scalar float compares/adds (the NA/IE/QS per-node work).
    pub scalar_fp: u64,
    /// Conditional branches executed (tree-descent and loop branches).
    pub branch: u64,
    /// ... of which are hard-to-predict (data-dependent direction).
    pub branch_mispredictable: u64,
    /// 128-bit NEON integer/bitwise ops.
    pub neon_alu: u64,
    /// 128-bit NEON multiplies / multiply-accumulates.
    pub neon_mul: u64,
    /// 128-bit NEON float ops (compares, adds).
    pub neon_fp: u64,
    /// Cross-lane NEON ops (reductions, narrow/widen, combines).
    pub neon_horiz: u64,
    /// Sequential-stream loads (node arrays scanned in order), in bytes.
    pub stream_load_bytes: u64,
    /// Data-dependent loads (leaf-value gathers, pointer chasing), count.
    pub random_loads: u64,
    /// Stores, in bytes.
    pub store_bytes: u64,
    /// Threshold compares executed on the *integer* pipe (scalar or NEON;
    /// the int tiers and the FLInt carrier). Informational sub-count: these
    /// compares are already included in `scalar_alu`/`neon_alu`, so they
    /// are excluded from [`OpTrace::simd_ops`]/[`OpTrace::total_ops`] and
    /// the device cost model — they exist so `bench --exp engine_micro`
    /// can split the op mix by compare pipe.
    pub cmp_int: u64,
    /// Threshold compares executed on the *float* pipe (sub-count of
    /// `scalar_fp`/`neon_fp`, same exclusions as `cmp_int`).
    pub cmp_fp: u64,
}

impl OpTrace {
    pub fn new() -> OpTrace {
        OpTrace::default()
    }

    /// Element-wise sum of two traces.
    pub fn add(&self, other: &OpTrace) -> OpTrace {
        OpTrace {
            scalar_alu: self.scalar_alu + other.scalar_alu,
            scalar_fp: self.scalar_fp + other.scalar_fp,
            branch: self.branch + other.branch,
            branch_mispredictable: self.branch_mispredictable + other.branch_mispredictable,
            neon_alu: self.neon_alu + other.neon_alu,
            neon_mul: self.neon_mul + other.neon_mul,
            neon_fp: self.neon_fp + other.neon_fp,
            neon_horiz: self.neon_horiz + other.neon_horiz,
            stream_load_bytes: self.stream_load_bytes + other.stream_load_bytes,
            random_loads: self.random_loads + other.random_loads,
            store_bytes: self.store_bytes + other.store_bytes,
            cmp_int: self.cmp_int + other.cmp_int,
            cmp_fp: self.cmp_fp + other.cmp_fp,
        }
    }

    /// Scale all counts (e.g. per-instance trace × batch size).
    pub fn scale(&self, k: f64) -> OpTrace {
        let s = |v: u64| (v as f64 * k).round() as u64;
        OpTrace {
            scalar_alu: s(self.scalar_alu),
            scalar_fp: s(self.scalar_fp),
            branch: s(self.branch),
            branch_mispredictable: s(self.branch_mispredictable),
            neon_alu: s(self.neon_alu),
            neon_mul: s(self.neon_mul),
            neon_fp: s(self.neon_fp),
            neon_horiz: s(self.neon_horiz),
            stream_load_bytes: s(self.stream_load_bytes),
            random_loads: s(self.random_loads),
            store_bytes: s(self.store_bytes),
            cmp_int: s(self.cmp_int),
            cmp_fp: s(self.cmp_fp),
        }
    }

    /// 128-bit SIMD ops of any category — the headline "SIMD-ops" figure
    /// `bench --exp engine_micro` reports per row.
    pub fn simd_ops(&self) -> u64 {
        self.neon_alu + self.neon_mul + self.neon_fp + self.neon_horiz
    }

    /// Every counter as `(name, value)` in declaration order — the single
    /// source of truth for the obs export and for tests that assert over
    /// the counter set (no re-typed field lists to go stale).
    pub fn counters(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("scalar_alu", self.scalar_alu),
            ("scalar_fp", self.scalar_fp),
            ("branch", self.branch),
            ("branch_mispredictable", self.branch_mispredictable),
            ("neon_alu", self.neon_alu),
            ("neon_mul", self.neon_mul),
            ("neon_fp", self.neon_fp),
            ("neon_horiz", self.neon_horiz),
            ("stream_load_bytes", self.stream_load_bytes),
            ("random_loads", self.random_loads),
            ("store_bytes", self.store_bytes),
            ("cmp_int", self.cmp_int),
            ("cmp_fp", self.cmp_fp),
        ]
    }

    /// Total dynamic instruction estimate (memory counted per 16B line-ish
    /// access).
    pub fn total_ops(&self) -> u64 {
        self.scalar_alu
            + self.scalar_fp
            + self.branch
            + self.neon_alu
            + self.neon_mul
            + self.neon_fp
            + self.neon_horiz
            + self.stream_load_bytes / 16
            + self.random_loads
            + self.store_bytes / 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_scale() {
        let a = OpTrace { scalar_alu: 10, neon_fp: 4, ..Default::default() };
        let b = OpTrace { scalar_alu: 5, branch: 2, ..Default::default() };
        let c = a.add(&b);
        assert_eq!(c.scalar_alu, 15);
        assert_eq!(c.neon_fp, 4);
        assert_eq!(c.branch, 2);
        let d = c.scale(2.0);
        assert_eq!(d.scalar_alu, 30);
    }

    #[test]
    fn total_counts_memory_in_lines() {
        let t = OpTrace { stream_load_bytes: 160, ..Default::default() };
        assert_eq!(t.total_ops(), 10);
    }

    /// The compare sub-counts ride along in add/scale/counters but never
    /// perturb the aggregate figures the device cost model consumes.
    #[test]
    fn cmp_subcounts_are_informational_only() {
        let a = OpTrace { neon_alu: 8, cmp_int: 8, cmp_fp: 3, ..Default::default() };
        let b = a.add(&a).scale(0.5);
        assert_eq!(b.cmp_int, 8);
        assert_eq!(b.cmp_fp, 3);
        assert_eq!(a.simd_ops(), 8, "cmp_int must not double-count into simd_ops");
        assert_eq!(a.total_ops(), 8, "cmp sub-counts must not inflate total_ops");
        let names: Vec<&str> = a.counters().iter().map(|(n, _)| *n).collect();
        assert!(names.contains(&"cmp_int") && names.contains(&"cmp_fp"));
    }
}
