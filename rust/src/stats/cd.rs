//! Critical-difference diagrams (Demšar 2006), rendered as monospace text —
//! the paper's Figure 2.
//!
//! Pipeline: Friedman omnibus test → if significant, pairwise Wilcoxon
//! signed-rank tests with Holm correction → methods whose pairwise
//! differences are *not* significant are connected by a clique bar.

use super::friedman::{friedman_test, Friedman};
use super::wilcoxon::{holm_adjust, wilcoxon_signed_rank};

/// A computed CD analysis.
#[derive(Debug, Clone)]
pub struct CdDiagram {
    pub method_names: Vec<String>,
    pub friedman: Friedman,
    /// Maximal groups (by method index) that are statistically
    /// indistinguishable at `alpha`.
    pub cliques: Vec<Vec<usize>>,
    pub alpha: f64,
}

/// Build the CD analysis from a `datasets × methods` result matrix
/// (lower = better) at significance level `alpha` (the paper uses p = 0.95,
/// i.e. alpha = 0.05).
pub fn cd_analysis(names: &[String], results: &[Vec<f64>], alpha: f64) -> CdDiagram {
    let k = names.len();
    assert!(results.iter().all(|r| r.len() == k));
    let friedman = friedman_test(results);

    // Pairwise Wilcoxon p-values, Holm-adjusted.
    let mut pairs = Vec::new();
    let mut raw_p = Vec::new();
    for i in 0..k {
        for j in i + 1..k {
            let a: Vec<f64> = results.iter().map(|r| r[i]).collect();
            let b: Vec<f64> = results.iter().map(|r| r[j]).collect();
            pairs.push((i, j));
            raw_p.push(wilcoxon_signed_rank(&a, &b).p_value);
        }
    }
    let adj = holm_adjust(&raw_p);
    let mut indistinct = vec![vec![false; k]; k];
    // If the omnibus test is not significant, everything is one clique.
    let omnibus_significant = friedman.p_value < alpha;
    for (idx, &(i, j)) in pairs.iter().enumerate() {
        let nd = !omnibus_significant || adj[idx] >= alpha;
        indistinct[i][j] = nd;
        indistinct[j][i] = nd;
    }

    // Sort methods by average rank; cliques are maximal rank-contiguous
    // intervals whose pairs are all indistinct (the standard CD rendering).
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| friedman.avg_ranks[a].partial_cmp(&friedman.avg_ranks[b]).unwrap());
    let mut cliques: Vec<Vec<usize>> = Vec::new();
    for start in 0..k {
        let mut end = start;
        'grow: while end + 1 < k {
            for m in start..=end {
                if !indistinct[order[m]][order[end + 1]] {
                    break 'grow;
                }
            }
            end += 1;
        }
        if end > start {
            let clique: Vec<usize> = order[start..=end].to_vec();
            // Keep only maximal cliques.
            if !cliques.iter().any(|c| clique.iter().all(|m| c.contains(m))) {
                cliques.push(clique);
            }
        }
    }

    CdDiagram { method_names: names.to_vec(), friedman, cliques, alpha }
}

impl CdDiagram {
    /// Render as monospace text: a rank axis, one row per method (sorted by
    /// rank), and clique bars connecting indistinguishable methods.
    pub fn render(&self) -> String {
        let k = self.method_names.len();
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by(|&a, &b| {
            self.friedman.avg_ranks[a].partial_cmp(&self.friedman.avg_ranks[b]).unwrap()
        });

        let width = 64usize;
        let min_r = 1.0;
        let max_r = k as f64;
        let pos = |r: f64| -> usize {
            (((r - min_r) / (max_r - min_r).max(1e-9)) * (width - 1) as f64).round() as usize
        };

        let mut out = String::new();
        out.push_str(&format!(
            "Friedman: chi2={:.3} F={:.3} p={:.4} (alpha={}) -> {}\n",
            self.friedman.chi2,
            self.friedman.f_stat,
            self.friedman.p_value,
            self.alpha,
            if self.friedman.p_value < self.alpha {
                "methods differ; pairwise Wilcoxon-Holm below"
            } else {
                "no significant difference detected"
            }
        ));
        // Axis.
        let mut axis = vec![b' '; width];
        let mut labels = vec![b' '; width + 4];
        for r in 1..=k {
            let p = pos(r as f64);
            axis[p] = b'|';
            let s = r.to_string();
            for (i, ch) in s.bytes().enumerate() {
                if p + i < labels.len() {
                    labels[p + i] = ch;
                }
            }
        }
        out.push_str(&format!("  {}\n", String::from_utf8_lossy(&labels)));
        out.push_str(&format!("  {}\n", String::from_utf8_lossy(&axis)));

        // One row per method: marker at its rank + name.
        for &m in &order {
            let r = self.friedman.avg_ranks[m];
            let p = pos(r);
            let mut row = vec![b' '; width];
            row[p] = b'*';
            out.push_str(&format!(
                "  {} {} ({:.2})\n",
                String::from_utf8_lossy(&row),
                self.method_names[m],
                r
            ));
        }
        // Clique bars.
        for clique in &self.cliques {
            let lo = clique
                .iter()
                .map(|&m| self.friedman.avg_ranks[m])
                .fold(f64::INFINITY, f64::min);
            let hi = clique
                .iter()
                .map(|&m| self.friedman.avg_ranks[m])
                .fold(f64::NEG_INFINITY, f64::max);
            let (a, b) = (pos(lo), pos(hi));
            let mut row = vec![b' '; width];
            for slot in row.iter_mut().take(b + 1).skip(a) {
                *slot = b'=';
            }
            let names: Vec<&str> =
                clique.iter().map(|&m| self.method_names[m].as_str()).collect();
            out.push_str(&format!(
                "  {} [{}]\n",
                String::from_utf8_lossy(&row),
                names.join(" ")
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("M{i}")).collect()
    }

    #[test]
    fn clear_winner_separated() {
        // M0 always much faster; M1 and M2 shuffle.
        let mut rng = crate::util::Pcg32::seeded(5);
        let results: Vec<Vec<f64>> = (0..14)
            .map(|_| {
                let base = 10.0 + rng.f64();
                vec![1.0 + 0.1 * rng.f64(), base, base + 0.05 * rng.normal()]
            })
            .collect();
        let cd = cd_analysis(&names(3), &results, 0.05);
        assert!(cd.friedman.p_value < 0.05);
        // M0 should not share a clique with the others.
        for c in &cd.cliques {
            assert!(!c.contains(&0) || c.len() == 1, "cliques {:?}", cd.cliques);
        }
        let rendered = cd.render();
        assert!(rendered.contains("M0"));
    }

    #[test]
    fn all_equal_single_clique() {
        let mut rng = crate::util::Pcg32::seeded(6);
        let results: Vec<Vec<f64>> = (0..10)
            .map(|_| {
                let mut v = vec![1.0, 1.01, 0.99, 1.005];
                rng.shuffle(&mut v);
                v
            })
            .collect();
        let cd = cd_analysis(&names(4), &results, 0.05);
        // Omnibus not significant -> one clique of all methods.
        assert_eq!(cd.cliques.len(), 1);
        assert_eq!(cd.cliques[0].len(), 4);
    }

    #[test]
    fn render_contains_axis_and_ranks() {
        let results: Vec<Vec<f64>> =
            (0..8).map(|i| vec![1.0 + i as f64 * 0.1, 2.0, 3.0]).collect();
        let cd = cd_analysis(&names(3), &results, 0.05);
        let r = cd.render();
        assert!(r.contains("Friedman"));
        assert!(r.contains('*'));
    }
}
