//! Friedman test over a results matrix (Demšar 2006) — the omnibus test
//! behind the paper's critical-difference diagrams (Figure 2).

use super::dist::{chi2_cdf, f_cdf};

/// Average ranks per method from a `datasets × methods` result matrix
/// (**lower value = better**, as with runtimes). Ties share the average rank.
pub fn average_ranks(results: &[Vec<f64>]) -> Vec<f64> {
    let n_methods = results[0].len();
    let mut ranks = vec![0f64; n_methods];
    for row in results {
        assert_eq!(row.len(), n_methods);
        let mut order: Vec<usize> = (0..n_methods).collect();
        order.sort_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap());
        let mut i = 0;
        while i < n_methods {
            // Tie block [i, j).
            let mut j = i + 1;
            while j < n_methods && row[order[j]] == row[order[i]] {
                j += 1;
            }
            let avg_rank = ((i + 1 + j) as f64) / 2.0; // mean of ranks i+1..=j
            for &m in &order[i..j] {
                ranks[m] += avg_rank;
            }
            i = j;
        }
    }
    let n = results.len() as f64;
    ranks.iter_mut().for_each(|r| *r /= n);
    ranks
}

/// Friedman test result.
#[derive(Debug, Clone)]
pub struct Friedman {
    pub avg_ranks: Vec<f64>,
    /// Friedman chi-squared statistic.
    pub chi2: f64,
    /// Iman–Davenport F statistic (less conservative).
    pub f_stat: f64,
    /// p-value of the Iman–Davenport F test.
    pub p_value: f64,
}

/// Run the Friedman test on a `datasets × methods` matrix (lower = better).
pub fn friedman_test(results: &[Vec<f64>]) -> Friedman {
    let n = results.len() as f64; // datasets
    let k = results[0].len() as f64; // methods
    let avg_ranks = average_ranks(results);
    let sum_sq: f64 = avg_ranks.iter().map(|r| r * r).sum();
    let chi2 = 12.0 * n / (k * (k + 1.0)) * (sum_sq - k * (k + 1.0) * (k + 1.0) / 4.0);
    // Iman–Davenport correction.
    let f_stat = if (n * (k - 1.0) - chi2).abs() < 1e-12 {
        f64::INFINITY
    } else {
        (n - 1.0) * chi2 / (n * (k - 1.0) - chi2)
    };
    let d1 = k - 1.0;
    let d2 = (k - 1.0) * (n - 1.0);
    let p_value = if f_stat.is_infinite() { 0.0 } else { 1.0 - f_cdf(f_stat, d1, d2) };
    // chi2 p as fallback for tiny designs (kept for reference/debug).
    let _p_chi2 = 1.0 - chi2_cdf(chi2, k - 1.0);
    Friedman { avg_ranks, chi2, f_stat, p_value }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_simple() {
        let r = average_ranks(&[vec![1.0, 2.0, 3.0], vec![1.0, 2.0, 3.0]]);
        assert_eq!(r, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn ranks_with_ties() {
        let r = average_ranks(&[vec![1.0, 1.0, 3.0]]);
        assert_eq!(r, vec![1.5, 1.5, 3.0]);
    }

    #[test]
    fn friedman_detects_consistent_ordering() {
        // Method 0 always fastest, 2 always slowest, 10 datasets.
        let results: Vec<Vec<f64>> =
            (0..10).map(|i| vec![1.0 + i as f64, 2.0 + i as f64, 3.0 + i as f64]).collect();
        let f = friedman_test(&results);
        assert!(f.p_value < 0.01, "p = {}", f.p_value);
        assert!(f.avg_ranks[0] < f.avg_ranks[2]);
    }

    #[test]
    fn friedman_accepts_random_noise() {
        // Same method values permuted per dataset -> no consistent ranking.
        let mut rng = crate::util::Pcg32::seeded(3);
        let results: Vec<Vec<f64>> = (0..12)
            .map(|_| {
                let mut v = vec![1.0, 2.0, 3.0, 4.0];
                rng.shuffle(&mut v);
                v
            })
            .collect();
        let f = friedman_test(&results);
        assert!(f.p_value > 0.05, "p = {}", f.p_value);
    }

    #[test]
    fn chi2_matches_textbook_example() {
        // Demšar's worked example shape: k=4, n=14 gives chi2 in a known
        // range; here just sanity-check internal consistency.
        let results: Vec<Vec<f64>> = (0..14)
            .map(|i| vec![0.1 * i as f64, 0.1 * i as f64 + 0.01, 1.0, 2.0])
            .collect();
        let f = friedman_test(&results);
        assert!(f.chi2 > 0.0 && f.chi2 < 14.0 * 3.0 + 1.0);
    }
}
