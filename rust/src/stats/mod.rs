//! Rank statistics for multi-method × multi-dataset comparisons
//! (DESIGN.md system S8): Friedman omnibus test, pairwise Wilcoxon
//! signed-rank with Holm correction, and critical-difference diagrams —
//! exactly the evaluation machinery behind the paper's Figure 2.

pub mod cd;
pub mod dist;
pub mod friedman;
pub mod wilcoxon;

pub use cd::{cd_analysis, CdDiagram};
pub use friedman::{average_ranks, friedman_test, Friedman};
pub use wilcoxon::{holm_adjust, wilcoxon_signed_rank, Wilcoxon};
