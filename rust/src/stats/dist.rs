//! Probability distributions needed by the rank tests: standard normal CDF,
//! chi-squared CDF (via the regularized lower incomplete gamma), and the
//! F-distribution CDF (via the regularized incomplete beta).
//!
//! Implementations follow Numerical Recipes; accuracy is ~1e-10, far beyond
//! what p-value thresholding needs.

/// Natural log of the gamma function (Lanczos approximation).
pub fn ln_gamma(x: f64) -> f64 {
    const COF: [f64; 6] = [
        76.18009172947146,
        -86.50532032941677,
        24.01409824083091,
        -1.231739572450155,
        0.1208650973866179e-2,
        -0.5395239384953e-5,
    ];
    let mut y = x;
    let tmp = x + 5.5;
    let tmp = tmp - (x + 0.5) * tmp.ln();
    let mut ser = 1.000000000190015;
    for c in COF {
        y += 1.0;
        ser += c / y;
    }
    -tmp + (2.5066282746310005 * ser / x).ln()
}

/// Regularized lower incomplete gamma P(a, x).
pub fn gamma_p(a: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        // Series representation.
        let mut ap = a;
        let mut sum = 1.0 / a;
        let mut del = sum;
        for _ in 0..500 {
            ap += 1.0;
            del *= x / ap;
            sum += del;
            if del.abs() < sum.abs() * 1e-14 {
                break;
            }
        }
        sum * (-x + a * x.ln() - ln_gamma(a)).exp()
    } else {
        // Continued fraction for Q, then P = 1 - Q.
        1.0 - gamma_q_cf(a, x)
    }
}

fn gamma_q_cf(a: f64, x: f64) -> f64 {
    let fpmin = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / fpmin;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < fpmin {
            d = fpmin;
        }
        c = b + an / c;
        if c.abs() < fpmin {
            c = fpmin;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-14 {
            break;
        }
    }
    (-x + a * x.ln() - ln_gamma(a)).exp() * h
}

/// Chi-squared CDF with `k` degrees of freedom.
pub fn chi2_cdf(x: f64, k: f64) -> f64 {
    gamma_p(k / 2.0, x / 2.0)
}

/// Regularized incomplete beta I_x(a, b).
pub fn beta_inc(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let bt = (ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln()).exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        bt * betacf(a, b, x) / a
    } else {
        1.0 - bt * betacf(b, a, 1.0 - x) / b
    }
}

fn betacf(a: f64, b: f64, x: f64) -> f64 {
    let fpmin = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < fpmin {
        d = fpmin;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..300 {
        let m = m as f64;
        let m2 = 2.0 * m;
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < fpmin {
            d = fpmin;
        }
        c = 1.0 + aa / c;
        if c.abs() < fpmin {
            c = fpmin;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < fpmin {
            d = fpmin;
        }
        c = 1.0 + aa / c;
        if c.abs() < fpmin {
            c = fpmin;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-14 {
            break;
        }
    }
    h
}

/// F-distribution CDF with `(d1, d2)` degrees of freedom.
pub fn f_cdf(x: f64, d1: f64, d2: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    beta_inc(d1 / 2.0, d2 / 2.0, d1 * x / (d1 * x + d2))
}

/// Standard normal CDF (via erf; Abramowitz & Stegun 7.1.26-grade accuracy
/// is insufficient, so use the erfc continued-fraction-quality rational from
/// Numerical Recipes `erfcc`, |err| < 1.2e-7, fine for p-values).
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * erfc(-z / std::f64::consts::SQRT_2)
}

fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
            .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_factorials() {
        // Γ(n) = (n-1)!
        assert!((ln_gamma(5.0) - (24f64).ln()).abs() < 1e-10);
        assert!((ln_gamma(1.0)).abs() < 1e-10);
    }

    #[test]
    fn chi2_reference_values() {
        // chi2 cdf(x=3.841, k=1) ≈ 0.95
        assert!((chi2_cdf(3.841, 1.0) - 0.95).abs() < 1e-3);
        // cdf(x=9.488, k=4) ≈ 0.95
        assert!((chi2_cdf(9.488, 4.0) - 0.95).abs() < 1e-3);
    }

    #[test]
    fn normal_reference_values() {
        // erfcc's advertised accuracy is ~1.2e-7.
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-6);
        assert!((normal_cdf(1.959964) - 0.975).abs() < 1e-5);
        assert!((normal_cdf(-1.959964) - 0.025).abs() < 1e-5);
    }

    #[test]
    fn f_reference_values() {
        // F cdf at the 95th percentile for (5, 10) dof: F ≈ 3.326
        assert!((f_cdf(3.326, 5.0, 10.0) - 0.95).abs() < 2e-3);
    }

    #[test]
    fn beta_inc_symmetry() {
        let v = beta_inc(2.0, 3.0, 0.4) + beta_inc(3.0, 2.0, 0.6);
        assert!((v - 1.0).abs() < 1e-10);
    }
}
