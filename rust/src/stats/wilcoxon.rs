//! Wilcoxon signed-rank test (paired), with the normal approximation and
//! tie/zero handling (Pratt). Used for the pairwise post-hoc comparisons in
//! the critical-difference diagrams (Benavoli et al. 2016 recommend pairwise
//! Wilcoxon over mean-rank post-hocs — the paper follows this).

use super::dist::normal_cdf;

/// Result of a two-sided Wilcoxon signed-rank test.
#[derive(Debug, Clone, Copy)]
pub struct Wilcoxon {
    /// Sum of positive-difference ranks.
    pub w_plus: f64,
    /// Sum of negative-difference ranks.
    pub w_minus: f64,
    /// Effective sample size (zeros removed).
    pub n_eff: usize,
    /// Two-sided p-value (normal approximation with tie correction).
    pub p_value: f64,
}

/// Two-sided test that paired samples `a` and `b` come from the same
/// distribution.
pub fn wilcoxon_signed_rank(a: &[f64], b: &[f64]) -> Wilcoxon {
    assert_eq!(a.len(), b.len());
    let mut diffs: Vec<f64> =
        a.iter().zip(b).map(|(&x, &y)| x - y).filter(|d| *d != 0.0).collect();
    let n = diffs.len();
    if n == 0 {
        return Wilcoxon { w_plus: 0.0, w_minus: 0.0, n_eff: 0, p_value: 1.0 };
    }
    // Rank |d| with average ranks for ties.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| diffs[i].abs().partial_cmp(&diffs[j].abs()).unwrap());
    let mut ranks = vec![0f64; n];
    let mut tie_correction = 0f64;
    let mut i = 0;
    while i < n {
        let mut j = i + 1;
        while j < n && diffs[order[j]].abs() == diffs[order[i]].abs() {
            j += 1;
        }
        let avg = ((i + 1 + j) as f64) / 2.0;
        for &idx in &order[i..j] {
            ranks[idx] = avg;
        }
        let t = (j - i) as f64;
        tie_correction += t * t * t - t;
        i = j;
    }
    let mut w_plus = 0f64;
    let mut w_minus = 0f64;
    for (d, r) in diffs.drain(..).zip(&ranks) {
        if d > 0.0 {
            w_plus += r;
        } else {
            w_minus += r;
        }
    }
    let nf = n as f64;
    let mean = nf * (nf + 1.0) / 4.0;
    let var = nf * (nf + 1.0) * (2.0 * nf + 1.0) / 24.0 - tie_correction / 48.0;
    let w = w_plus.min(w_minus);
    let p_value = if var <= 0.0 {
        1.0
    } else {
        // Continuity-corrected z.
        let z = (w - mean + 0.5) / var.sqrt();
        (2.0 * normal_cdf(z)).min(1.0)
    };
    Wilcoxon { w_plus, w_minus, n_eff: n, p_value }
}

/// Holm step-down correction: given raw p-values, returns adjusted p-values
/// (same order as input).
pub fn holm_adjust(pvals: &[f64]) -> Vec<f64> {
    let m = pvals.len();
    let mut order: Vec<usize> = (0..m).collect();
    order.sort_by(|&i, &j| pvals[i].partial_cmp(&pvals[j]).unwrap());
    let mut adjusted = vec![0f64; m];
    let mut running_max = 0f64;
    for (rank, &idx) in order.iter().enumerate() {
        let adj = ((m - rank) as f64 * pvals[idx]).min(1.0);
        running_max = running_max.max(adj);
        adjusted[idx] = running_max;
    }
    adjusted
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_samples_p_one() {
        let a = vec![1.0, 2.0, 3.0];
        let w = wilcoxon_signed_rank(&a, &a);
        assert_eq!(w.n_eff, 0);
        assert_eq!(w.p_value, 1.0);
    }

    #[test]
    fn clearly_different_samples() {
        let a: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let b: Vec<f64> = a.iter().map(|x| x + 5.0).collect();
        let w = wilcoxon_signed_rank(&a, &b);
        assert!(w.p_value < 0.001, "p = {}", w.p_value);
        assert_eq!(w.w_plus, 0.0); // all diffs negative
    }

    #[test]
    fn symmetric_noise_not_significant() {
        let mut rng = crate::util::Pcg32::seeded(4);
        let a: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let b: Vec<f64> = a.iter().map(|x| x + 0.01 * rng.normal()).collect();
        let w = wilcoxon_signed_rank(&a, &b);
        assert!(w.p_value > 0.05, "p = {}", w.p_value);
    }

    #[test]
    fn holm_monotone_and_bounded() {
        let p = vec![0.01, 0.04, 0.03, 0.5];
        let adj = holm_adjust(&p);
        assert!(adj.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // smallest raw p gets multiplied by m
        assert!((adj[0] - 0.04).abs() < 1e-12);
        // adjusted values are monotone in raw order
        assert!(adj[1] >= adj[2]);
    }

    #[test]
    fn w_statistics_sum() {
        let a = vec![3.0, 1.0, 4.0, 1.5, 2.0];
        let b = vec![1.0, 2.0, 3.0, 4.0, 2.5];
        let w = wilcoxon_signed_rank(&a, &b);
        let n = w.n_eff as f64;
        assert!((w.w_plus + w.w_minus - n * (n + 1.0) / 2.0).abs() < 1e-9);
    }
}
