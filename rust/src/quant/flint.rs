//! FLInt carrier: order-preserving `f32 → i32` mapping for integer
//! threshold compares with **exact** float semantics (DESIGN.md §10).
//!
//! IEEE-754 floats of the same sign already order like integers when
//! their bit patterns are read as sign-magnitude numbers. FLInt
//! (Hakert et al., PAPERS.md) exploits this: one cheap fixup turns the
//! bit pattern into a two's-complement integer whose `<`/`>` order
//! matches the float order, so every threshold comparison in an f32
//! engine can run on the integer SIMD pipe (`vcgtq_s32` instead of
//! `vcgtq_f32`, or scalar int compares in if-else) with **zero**
//! quantization error and no scale-selection machinery. This module is
//! the whole carrier: [`map_f32`] (the fixup), [`encode_threshold`]
//! (model-build time, once per node) and [`encode_feature_le`] /
//! [`encode_feature_gt`] (once per row element at predict time).
//!
//! ## The map
//!
//! ```text
//! map(x) = bits(x)                      if sign(x) = 0   (x ≥ +0.0, +NaN)
//!          bits(x) XOR 0x7fff_ffff      if sign(x) = 1   (x ≤ -0.0, -NaN)
//! ```
//!
//! Positive floats keep their pattern (already ascending as i32);
//! negative floats get their magnitude bits flipped so bigger
//! magnitudes order *lower*, while the intact sign bit keeps every
//! negative below every positive. This is exactly the fixup inside
//! `f32::total_cmp`, so `map(a) < map(b) ⇔ a.total_cmp(&b) == Less`
//! for **all** 2³² bit patterns — including denormals (their patterns
//! sit, already ordered, between zero and the smallest normal; the map
//! never rounds or flushes them) and ±inf. The map is injective and an
//! involution on its own output, so [`unmap_i32`] is exact.
//!
//! ## The contract (±0.0, NaN)
//!
//! `total_cmp` is *finer* than the IEEE compares the f32 engines
//! execute: it separates -0.0 < +0.0 and orders NaNs, where `<=`/`>`
//! treat -0.0 == +0.0 and return false on any NaN. Two fixups restore
//! the engines' exact semantics:
//!
//! * **Thresholds** ([`encode_threshold`]): -0.0 is canonicalized to
//!   +0.0 before mapping. After that no stored threshold encodes to
//!   map(-0.0) = -1, and a ±0.0 *feature* (encoding to -1 or 0) falls
//!   on the same side of every threshold either way — matching
//!   `-0.0 == +0.0` without touching the feature hot path.
//! * **Features**: IEEE compares are false on NaN, and the two engine
//!   styles need opposite saturations to reproduce that. The
//!   `x <= t` traversals (NA, IE: false ⇒ go right) use
//!   [`encode_feature_le`], NaN → [`i32::MAX`]; the `x > t` mask scans
//!   (QS, VQS, RS: false ⇒ stop clearing masks) use
//!   [`encode_feature_gt`], NaN → [`i32::MIN`]. Each FLInt engine is
//!   bit-identical to *its own* f32 twin on NaN features; NA/IE and
//!   the QS family already disagree with each other there in plain
//!   f32, and the carrier inherits that split verbatim.
//! * **NaN thresholds** are out of contract (trained forests never
//!   produce them — thresholds are midpoints of finite feature
//!   values); they are mapped plainly, without canonicalization.
//!
//! Because the carrier changes *representation only*, outputs are
//! bit-identical to the f32 tier by construction — the selector
//! asserts 100% agreement instead of gating on it, and there is no
//! accuracy ablation to run.

/// The FLInt fixup: reinterpret `x`'s bits as i32 and flip the
/// non-sign bits when negative. Total order identical to
/// [`f32::total_cmp`]; injective over all bit patterns.
#[inline(always)]
pub fn map_f32(x: f32) -> i32 {
    let b = x.to_bits() as i32;
    // b >> 31 is all-ones for negatives; shifting the *unsigned* copy
    // right by 1 clears the sign bit, leaving the 0x7fff_ffff flip mask.
    b ^ ((((b >> 31) as u32) >> 1) as i32)
}

/// Exact inverse of [`map_f32`] (the fixup preserves the sign bit, so
/// applying it twice is the identity).
#[inline(always)]
pub fn unmap_i32(m: i32) -> f32 {
    let b = m ^ ((((m >> 31) as u32) >> 1) as i32);
    f32::from_bits(b as u32)
}

/// Encode one split threshold at model-build time: canonicalize -0.0
/// to +0.0 (restoring IEEE `-0.0 == +0.0` under integer compares),
/// then apply [`map_f32`].
#[inline(always)]
pub fn encode_threshold(t: f32) -> i32 {
    // `t == 0.0` is true for both zeros and false for NaN; the literal
    // is +0.0, so exactly -0.0 is rewritten.
    map_f32(if t == 0.0 { 0.0 } else { t })
}

/// Encode one feature value for the `x <= t` traversals (NA, IE).
/// NaN saturates to [`i32::MAX`] so `enc(x) <= enc(t)` is false
/// against every encoded threshold, matching IEEE `NaN <= t`.
#[inline(always)]
pub fn encode_feature_le(x: f32) -> i32 {
    if x.is_nan() {
        i32::MAX
    } else {
        map_f32(x)
    }
}

/// Encode one feature value for the `x > t` mask scans (QS, VQS, RS).
/// NaN saturates to [`i32::MIN`] so `enc(x) > enc(t)` is false
/// against every encoded threshold, matching IEEE `NaN > t`.
#[inline(always)]
pub fn encode_feature_gt(x: f32) -> i32 {
    if x.is_nan() {
        i32::MIN
    } else {
        map_f32(x)
    }
}

/// [`encode_threshold`] over a slice (model-build helper).
pub fn encode_thresholds(ts: &[f32]) -> Vec<i32> {
    ts.iter().map(|&t| encode_threshold(t)).collect()
}

/// [`encode_feature_le`] over a batch, reusing `out` (predict-time
/// helper for the scalar traversals).
pub fn encode_batch_le(x: &[f32], out: &mut Vec<i32>) {
    out.clear();
    out.extend(x.iter().map(|&v| encode_feature_le(v)));
}

/// [`encode_feature_gt`] over a batch, reusing `out` (predict-time
/// helper for the mask-scan engines; the transpose kernels consume the
/// encoded batch exactly like an f32 one).
pub fn encode_batch_gt(x: &[f32], out: &mut Vec<i32>) {
    out.clear();
    out.extend(x.iter().map(|&v| encode_feature_gt(v)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::Runner;
    use std::cmp::Ordering;

    /// Adversarial corner values: zeros, denormals (min positive, mid,
    /// max), normals around 1.0, ±inf, and NaNs with varied payloads
    /// and both signs.
    fn corner_values() -> Vec<f32> {
        let mut v = vec![
            0.0,
            -0.0,
            f32::MIN_POSITIVE,                 // smallest normal
            -f32::MIN_POSITIVE,
            f32::from_bits(0x0000_0001),       // smallest denormal
            f32::from_bits(0x8000_0001),
            f32::from_bits(0x0040_0000),       // mid denormal
            f32::from_bits(0x007f_ffff),       // largest denormal
            f32::from_bits(0x807f_ffff),
            1.0,
            -1.0,
            1.0 + f32::EPSILON,
            f32::MAX,
            f32::MIN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            -f32::NAN,
            f32::from_bits(0x7f80_0001),       // signalling-payload NaN
            f32::from_bits(0xffc0_1234),       // quiet -NaN, odd payload
            f32::from_bits(0x7fff_ffff),       // max-payload NaN
        ];
        v.extend([1e-30f32, -1e-30, 3.5e38, -3.5e38, 0.1, -0.1]);
        v
    }

    #[test]
    fn map_orders_exactly_like_total_cmp_on_corners() {
        let vals = corner_values();
        for &a in &vals {
            for &b in &vals {
                let int_ord = map_f32(a).cmp(&map_f32(b));
                assert_eq!(
                    int_ord,
                    a.total_cmp(&b),
                    "map order diverged from total_cmp on {a:?} ({:#010x}) vs {b:?} ({:#010x})",
                    a.to_bits(),
                    b.to_bits()
                );
            }
        }
    }

    /// Satellite: order preservation vs `total_cmp` over *random bit
    /// patterns* — every float class (normals, denormals, zeros, infs,
    /// NaN payloads) appears, nothing is excluded.
    #[test]
    fn property_map_matches_total_cmp_on_random_bit_patterns() {
        Runner::new(512).with_seed(0xF11A7).run(|rng, _| {
            let a = f32::from_bits(rng.next_u32());
            let b = f32::from_bits(rng.next_u32());
            let int_ord = map_f32(a).cmp(&map_f32(b));
            if int_ord != a.total_cmp(&b) {
                return Err(format!(
                    "order mismatch: {:#010x} vs {:#010x}: map {int_ord:?}, total_cmp {:?}",
                    a.to_bits(),
                    b.to_bits(),
                    a.total_cmp(&b)
                ));
            }
            Ok(())
        });
    }

    /// Round-trip: `unmap(map(x))` restores the exact bit pattern for
    /// every input class (the fixup is an involution).
    #[test]
    fn property_map_round_trips_bit_exactly() {
        for &v in &corner_values() {
            assert_eq!(unmap_i32(map_f32(v)).to_bits(), v.to_bits(), "{v:?}");
        }
        Runner::new(512).with_seed(0xF11B).run(|rng, _| {
            let bits = rng.next_u32();
            let back = unmap_i32(map_f32(f32::from_bits(bits))).to_bits();
            if back != bits {
                return Err(format!("round-trip {bits:#010x} -> {back:#010x}"));
            }
            Ok(())
        });
    }

    /// Monotonicity in the IEEE (not just total) order: for non-NaN
    /// a < b (float compare), map(a) < map(b). Complements the
    /// total_cmp test with the order the engines actually use.
    #[test]
    fn property_map_is_monotone_in_ieee_order() {
        Runner::new(512).with_seed(0xF11C).run(|rng, _| {
            let a = f32::from_bits(rng.next_u32());
            let b = f32::from_bits(rng.next_u32());
            if a.is_nan() || b.is_nan() {
                return Ok(());
            }
            if a < b && map_f32(a) >= map_f32(b) {
                return Err(format!("monotonicity broken: {a:?} < {b:?}"));
            }
            // IEEE equality (covers -0.0 == +0.0) must mean threshold
            // encodings agree even when raw maps differ.
            if a == b && encode_threshold(a) != encode_threshold(b) {
                return Err(format!("threshold encodings of equal floats differ: {a:?} {b:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn zero_handling_matches_ieee_compares() {
        // map separates the zeros (that is the point of canonicalizing
        // thresholds)...
        assert_eq!(map_f32(-0.0), -1);
        assert_eq!(map_f32(0.0), 0);
        // ...and encode_threshold folds them back together.
        assert_eq!(encode_threshold(-0.0), 0);
        assert_eq!(encode_threshold(0.0), 0);
        // Both encodings of a ±0.0 feature land on the same side of
        // every canonicalized threshold, for both compare styles.
        for &t in &corner_values() {
            if t.is_nan() {
                continue; // NaN thresholds are out of contract
            }
            let te = encode_threshold(t);
            for x in [0.0f32, -0.0] {
                assert_eq!(encode_feature_le(x) <= te, x <= t, "le: x={x:?} t={t:?}");
                assert_eq!(encode_feature_gt(x) > te, x > t, "gt: x={x:?} t={t:?}");
            }
        }
    }

    /// The headline carrier property, stated directly: for every
    /// feature/threshold pair (NaN features included, NaN thresholds
    /// out of contract), the integer compare reproduces the IEEE
    /// compare each engine style executes.
    #[test]
    fn property_encoded_compares_equal_float_compares() {
        let corners = corner_values();
        Runner::new(512).with_seed(0xF11D).run(|rng, _| {
            // Mix random patterns with corner draws so ±0/NaN/denormal
            // pairs appear constantly, not once in 2^32.
            let mut draw = |rng: &mut crate::util::Pcg32| {
                let r = rng.next_u32();
                if r % 4 == 0 {
                    corners[(r / 4) as usize % corners.len()]
                } else {
                    f32::from_bits(rng.next_u32())
                }
            };
            let x = draw(rng);
            let t = draw(rng);
            if t.is_nan() {
                return Ok(());
            }
            let te = encode_threshold(t);
            if (encode_feature_le(x) <= te) != (x <= t) {
                return Err(format!("le diverged: x={x:?} t={t:?}"));
            }
            if (encode_feature_gt(x) > te) != (x > t) {
                return Err(format!("gt diverged: x={x:?} t={t:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn nan_features_saturate_per_compare_style() {
        for nan in [f32::NAN, -f32::NAN, f32::from_bits(0x7f80_0001)] {
            assert_eq!(encode_feature_le(nan), i32::MAX);
            assert_eq!(encode_feature_gt(nan), i32::MIN);
        }
        // Against every encodable threshold, both styles come out
        // false — exactly IEEE NaN semantics (NA/IE descend right, the
        // QS family stops clearing masks).
        for &t in &corner_values() {
            if t.is_nan() {
                continue;
            }
            let te = encode_threshold(t);
            assert!(encode_feature_le(f32::NAN) > te, "NaN must not go left of {t:?}");
            assert!(encode_feature_gt(f32::NAN) <= te, "NaN must not set masks at {t:?}");
        }
    }

    #[test]
    fn denormals_and_infinities_are_exact() {
        // Denormals order strictly between zero and the smallest
        // normal, with no flush-to-zero collapse.
        let tiny = f32::from_bits(0x0000_0001);
        let big_denorm = f32::from_bits(0x007f_ffff);
        assert!(map_f32(0.0) < map_f32(tiny));
        assert!(map_f32(tiny) < map_f32(big_denorm));
        assert!(map_f32(big_denorm) < map_f32(f32::MIN_POSITIVE));
        assert!(map_f32(-tiny) < map_f32(-0.0));
        assert_eq!(map_f32(tiny) - map_f32(0.0), 1, "adjacent patterns stay adjacent");
        // ±inf sit beyond every finite value but inside the i32 range.
        assert!(map_f32(f32::MAX) < map_f32(f32::INFINITY));
        assert!(map_f32(f32::NEG_INFINITY) < map_f32(f32::MIN));
        assert_eq!(map_f32(f32::INFINITY), 0x7f80_0000);
    }

    #[test]
    fn batch_encoders_match_scalar_encoders() {
        let vals = corner_values();
        let mut le = Vec::new();
        let mut gt = Vec::new();
        encode_batch_le(&vals, &mut le);
        encode_batch_gt(&vals, &mut gt);
        assert_eq!(le.len(), vals.len());
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(le[i], encode_feature_le(v));
            assert_eq!(gt[i], encode_feature_gt(v));
        }
        // Buffer reuse clears stale contents.
        encode_batch_le(&[1.0], &mut le);
        assert_eq!(le, vec![map_f32(1.0)]);
        assert_eq!(encode_thresholds(&[0.5, -0.0]), vec![map_f32(0.5), 0]);
    }

    #[test]
    fn total_cmp_equality_only_for_identical_bits() {
        // Injectivity, spelled as the property the RS node-merging
        // path relies on: equal maps ⇔ equal bit patterns.
        let vals = corner_values();
        for &a in &vals {
            for &b in &vals {
                assert_eq!(
                    map_f32(a) == map_f32(b),
                    a.total_cmp(&b) == Ordering::Equal,
                    "{a:?} vs {b:?}"
                );
            }
        }
    }
}
