//! Fixed-point quantization of tree ensembles (paper §5).
//!
//! Quantization maps floats to integers via `q(x) = ⌊s·x⌋` (eq. 3) with a
//! positive scale `s`, applied to split thresholds, leaf values, and — at
//! inference time — feature values. The paper stores 16-bit integers
//! (`short`), which (a) removes all floating-point arithmetic from the
//! traversal (relevant on FPU-less MCUs, Table 1) and (b) doubles SIMD lane
//! parallelism: 8 int16 comparisons per NEON register instead of 4 float32
//! (§5.1).
//!
//! Scale selection (§5): `s ∈ [M, 2^B]`. The lower bound keeps RF leaf
//! probabilities (already scaled by 1/M) from flushing to zero; the upper
//! bound is representability. We additionally bound `s` so the *accumulated*
//! score cannot overflow an i16 accumulator — the paper's V-QuickScorer adds
//! scores with 8-lane 16-bit adds, so the whole forest sum must fit i16.

pub mod merge;

use crate::forest::{Forest, Task, Tree};

/// Fixed-point configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantConfig {
    /// The scale constant `s` in `q(x) = ⌊s·x⌋`.
    pub scale: f32,
}

impl QuantConfig {
    /// The paper's default for normalized features: `s = 2^15`.
    pub fn paper_default() -> QuantConfig {
        QuantConfig { scale: 32768.0 }
    }

    /// Quantize one value to i16 with saturation.
    #[inline]
    pub fn q(&self, x: f32) -> i16 {
        let v = (self.scale * x).floor();
        v.clamp(i16::MIN as f32, i16::MAX as f32) as i16
    }

    /// Quantize a feature row/batch.
    pub fn q_slice(&self, xs: &[f32], out: &mut Vec<i16>) {
        out.clear();
        out.extend(xs.iter().map(|&x| self.q(x)));
    }

    /// Dequantize a score.
    #[inline]
    pub fn dq(&self, v: i32) -> f32 {
        v as f32 / self.scale
    }
}

/// Which parts of the forest are quantized — Table 3 evaluates all four
/// combinations of {float, int16} splits × leaves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantParts {
    pub splits: bool,
    pub leaves: bool,
}

impl QuantParts {
    pub const BOTH: QuantParts = QuantParts { splits: true, leaves: true };
    pub const SPLITS_ONLY: QuantParts = QuantParts { splits: true, leaves: false };
    pub const LEAVES_ONLY: QuantParts = QuantParts { splits: false, leaves: true };
    pub const NONE: QuantParts = QuantParts { splits: false, leaves: false };
}

/// A fully int16-quantized forest (thresholds and leaf values), preserving
/// the float forest's topology. This is the model format the quantized
/// engines (qNA/qIE/qQS/qVQS/qRS) consume.
#[derive(Debug, Clone, PartialEq)]
pub struct QForest {
    pub trees: Vec<QTree>,
    pub n_features: usize,
    pub n_classes: usize,
    pub task: Task,
    /// Quantized base score (i32 — it participates in the i32 descale path).
    pub base_score: Vec<i32>,
    pub config: QuantConfig,
}

/// One quantized tree: same `Child` topology as [`Tree`], int16 payloads.
#[derive(Debug, Clone, PartialEq)]
pub struct QTree {
    pub features: Vec<u32>,
    pub thresholds: Vec<i16>,
    pub left: Vec<crate::forest::Child>,
    pub right: Vec<crate::forest::Child>,
    pub leaf_values: Vec<i16>,
    pub n_leaves: usize,
}

impl QForest {
    /// Quantize a forest with the given scale.
    pub fn from_forest(f: &Forest, config: QuantConfig) -> QForest {
        let trees = f
            .trees
            .iter()
            .map(|t| QTree {
                features: t.nodes.iter().map(|n| n.feature).collect(),
                thresholds: t.nodes.iter().map(|n| config.q(n.threshold)).collect(),
                left: t.nodes.iter().map(|n| n.left).collect(),
                right: t.nodes.iter().map(|n| n.right).collect(),
                leaf_values: t.leaf_values.iter().map(|&v| config.q(v)).collect(),
                n_leaves: t.n_leaves,
            })
            .collect();
        QForest {
            trees,
            n_features: f.n_features,
            n_classes: f.n_classes,
            task: f.task,
            base_score: f.base_score.iter().map(|&v| (config.scale * v).floor() as i32).collect(),
            config,
        }
    }

    /// Reference (naive-traversal) prediction on float inputs: features are
    /// quantized on the fly, scores accumulate in i32 and are descaled.
    /// Every quantized engine must agree with this bit-for-bit on scores
    /// before descaling.
    pub fn predict_batch(&self, x: &[f32]) -> Vec<f32> {
        let n = x.len() / self.n_features;
        let c = self.n_classes;
        let mut out = vec![0f32; n * c];
        let mut qx = Vec::new();
        for i in 0..n {
            self.config.q_slice(&x[i * self.n_features..(i + 1) * self.n_features], &mut qx);
            let mut acc = vec![0i32; c];
            for (j, &b) in self.base_score.iter().enumerate() {
                acc[j] = b;
            }
            for t in &self.trees {
                let leaf = t.exit_leaf_q(&qx);
                for j in 0..c {
                    acc[j] += t.leaf_values[leaf * c + j] as i32;
                }
            }
            for j in 0..c {
                out[i * c + j] = self.config.dq(acc[j]);
            }
        }
        out
    }

    /// Max leaf count (the QuickScorer `L`).
    pub fn max_leaves(&self) -> usize {
        self.trees.iter().map(|t| t.n_leaves).max().unwrap_or(1)
    }
}

impl QTree {
    /// Walk with already-quantized features (split is `q(x) <= q(t)`).
    pub fn exit_leaf_q(&self, qx: &[i16]) -> usize {
        use crate::forest::Child;
        if self.features.is_empty() {
            return 0;
        }
        let mut cur = Child::Inner(0);
        loop {
            match cur {
                Child::Leaf(l) => return l as usize,
                Child::Inner(i) => {
                    let i = i as usize;
                    cur = if qx[self.features[i] as usize] <= self.thresholds[i] {
                        self.left[i]
                    } else {
                        self.right[i]
                    };
                }
            }
        }
    }
}

/// Evaluate accuracy under a partial quantization (Table 3): splits and/or
/// leaves quantized, naive traversal. Float features are quantized only for
/// the split comparison when `parts.splits` is set.
pub fn accuracy_with_parts(
    f: &Forest,
    config: QuantConfig,
    parts: QuantParts,
    x: &[f32],
    labels: &[u32],
) -> f64 {
    let n = labels.len();
    let c = f.n_classes;
    let mut correct = 0usize;
    let mut qx = Vec::new();
    for i in 0..n {
        let row = &x[i * f.n_features..(i + 1) * f.n_features];
        config.q_slice(row, &mut qx);
        let mut scores = vec![0f64; c];
        for t in &f.trees {
            let leaf = exit_leaf_parts(t, row, &qx, config, parts.splits);
            for j in 0..c {
                let v = t.leaf_values[leaf * c + j];
                scores[j] += if parts.leaves { config.q(v) as f64 / config.scale as f64 } else { v as f64 };
            }
        }
        let mut best = 0usize;
        for j in 1..c {
            if scores[j] > scores[best] {
                best = j;
            }
        }
        if best as u32 == labels[i] {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

fn exit_leaf_parts(
    t: &Tree,
    row: &[f32],
    qrow: &[i16],
    config: QuantConfig,
    quant_splits: bool,
) -> usize {
    use crate::forest::Child;
    if t.nodes.is_empty() {
        return 0;
    }
    let mut cur = Child::Inner(0);
    loop {
        match cur {
            Child::Leaf(l) => return l as usize,
            Child::Inner(i) => {
                let n = &t.nodes[i as usize];
                let go_left = if quant_splits {
                    qrow[n.feature as usize] <= config.q(n.threshold)
                } else {
                    row[n.feature as usize] <= n.threshold
                };
                cur = if go_left { n.left } else { n.right };
            }
        }
    }
}

/// The largest scale for which the quantized engines' 16-bit SIMD score
/// accumulation (§5.1: `vaddq_s16`, 8 values at once) provably cannot wrap:
/// `i16::MAX / (|base| + Σ_trees max_leaf |v|)`, also bounding thresholds by
/// the feature range. Scales above this are *representable* but an
/// adversarial instance can overflow the i16 accumulator — exactly as it
/// would on the paper's hardware.
pub fn max_safe_scale(f: &Forest, max_abs_feature: f32) -> f32 {
    // Worst-case |score|: base + Σ_trees max_leaf |v|.
    let mut worst: f32 = f.base_score.iter().map(|v| v.abs()).fold(0.0, f32::max);
    for t in &f.trees {
        let mx = t.leaf_values.iter().map(|v| v.abs()).fold(0f32, f32::max);
        worst += mx;
    }
    let bound_scores = if worst > 0.0 { (i16::MAX as f32) / worst } else { f32::INFINITY };
    let bound_thresholds =
        if max_abs_feature > 0.0 { (i16::MAX as f32) / max_abs_feature } else { f32::INFINITY };
    bound_scores.min(bound_thresholds)
}

/// Choose a scale for a forest per §5: as large as possible within
/// `[M, 2^15]` while guaranteeing (a) thresholds fit i16 given the feature
/// range `max_abs_feature`, and (b) the worst-case accumulated score fits an
/// i16 SIMD accumulator (V-QuickScorer adds scores with 16-bit lanes).
pub fn choose_scale(f: &Forest, max_abs_feature: f32) -> QuantConfig {
    let m = f.n_trees().max(1) as f32;
    let s = max_safe_scale(f, max_abs_feature).min(32768.0).max(m);
    QuantConfig { scale: s }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    fn trained() -> (Forest, crate::data::Dataset) {
        let ds = DatasetId::Magic.generate(800, 17);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 16,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        (f, ds)
    }

    #[test]
    fn q_floor_semantics() {
        let c = QuantConfig { scale: 8.0 };
        assert_eq!(c.q(0.99), 7); // floor(7.92)
        assert_eq!(c.q(1.0), 8);
        assert_eq!(c.q(-0.1), -1); // floor(-0.8) = -1
        assert_eq!(c.q(0.0), 0);
    }

    #[test]
    fn q_saturates() {
        let c = QuantConfig::paper_default();
        assert_eq!(c.q(2.0), i16::MAX);
        assert_eq!(c.q(-2.0), i16::MIN);
    }

    #[test]
    fn qforest_predictions_close_to_float() {
        let (f, ds) = trained();
        let qf = QForest::from_forest(&f, QuantConfig::paper_default());
        let float_scores = f.predict_batch(&ds.x[..ds.d * 64]);
        let q_scores = qf.predict_batch(&ds.x[..ds.d * 64]);
        // Quantized scores should be close (not identical).
        let max_diff = float_scores
            .iter()
            .zip(&q_scores)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 0.05, "max diff {max_diff}");
    }

    #[test]
    fn accuracy_parts_none_matches_float() {
        let (f, ds) = trained();
        let cfg = QuantConfig::paper_default();
        let a_float = f.accuracy(&ds.x, &ds.labels);
        let a_none = accuracy_with_parts(&f, cfg, QuantParts::NONE, &ds.x, &ds.labels);
        assert!((a_float - a_none).abs() < 1e-12);
    }

    #[test]
    fn accuracy_quantized_near_float() {
        let (f, ds) = trained();
        let cfg = QuantConfig::paper_default();
        let a_float = f.accuracy(&ds.x, &ds.labels);
        let a_q = accuracy_with_parts(&f, cfg, QuantParts::BOTH, &ds.x, &ds.labels);
        assert!((a_float - a_q).abs() < 0.03, "float {a_float} vs quant {a_q}");
    }

    #[test]
    fn choose_scale_bounds() {
        let (f, _) = trained();
        let cfg = choose_scale(&f, 1.0);
        assert!(cfg.scale >= f.n_trees() as f32);
        assert!(cfg.scale <= 32768.0);
        // RF leaves are probs/M; worst total <= 1+eps so score bound allows
        // a large scale.
        assert!(cfg.scale > 1024.0, "scale {}", cfg.scale);
    }

    #[test]
    fn scores_fit_i16_accumulator() {
        let (f, ds) = trained();
        let cfg = choose_scale(&f, 1.0);
        let qf = QForest::from_forest(&f, cfg);
        // Accumulate worst-case per-instance scores and check i16 range.
        for i in 0..64 {
            let row = &ds.x[i * ds.d..(i + 1) * ds.d];
            let mut qx = Vec::new();
            cfg.q_slice(row, &mut qx);
            let mut acc = vec![0i32; qf.n_classes];
            for t in &qf.trees {
                let leaf = t.exit_leaf_q(&qx);
                for j in 0..qf.n_classes {
                    acc[j] += t.leaf_values[leaf * qf.n_classes + j] as i32;
                }
            }
            for &a in &acc {
                assert!(a >= i16::MIN as i32 && a <= i16::MAX as i32, "overflow {a}");
            }
        }
    }
}
