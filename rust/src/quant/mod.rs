//! Fixed-point quantization of tree ensembles (paper §5) — the precision-tier
//! subsystem.
//!
//! Quantization maps floats to integers via `q(x) = ⌊s·x⌋` (eq. 3) with a
//! positive scale `s`, applied to split thresholds, leaf values, and — at
//! inference time — feature values. The paper stores 16-bit integers
//! (`short`), which (a) removes all floating-point arithmetic from the
//! traversal (relevant on FPU-less MCUs, Table 1) and (b) doubles SIMD lane
//! parallelism: 8 int16 comparisons per NEON register instead of 4 float32
//! (§5.1).
//!
//! This module generalizes that analysis into **precision tiers**: the
//! storage integer is a type parameter ([`QuantInt`], implemented for `i16`
//! and `i8`), so [`QuantConfig`], [`QForest`] and [`QTree`] describe both
//! the paper's int16 tier and an int8 tier that doubles lane parallelism
//! again (16 comparisons per register, v = 16 for V-QuickScorer) and halves
//! model bytes once more — the direction integer-only inference systems
//! (InTreeger, FLInt) push further.
//!
//! # Scale selection (§5, redone per accumulator width)
//!
//! `s ∈ [M, S::MAX]`. The lower bound keeps RF leaf probabilities (already
//! scaled by 1/M) from flushing to zero. The upper bound is
//! *representability*: the largest scale for which `q` does not saturate
//! in-range inputs is `S::MAX` itself (32767 / 127), **not** `2^B` — the
//! paper's `s = 2^15` saturates `q(x)` at `|x| ≥ 1.0` because
//! `⌊2^15 · 1.0⌋ = 32768 > i16::MAX`. [`choose_scale`] therefore caps at
//! `i16::MAX`; [`QuantConfig::paper_default`] keeps the paper's constant and
//! documents the saturation.
//!
//! We additionally bound `s` so the *accumulated* score cannot overflow the
//! engines' SIMD accumulator ([`max_safe_scale_with`]):
//!
//! * **int16 tier**: V-QuickScorer adds scores with 8-lane 16-bit adds
//!   (`vaddq_s16`), so the whole forest sum must fit i16.
//! * **int8 tier**: a pure 8-bit accumulator (`vaddq_s8`, 16 lanes) holds at
//!   most ±127, which the worst-case sum of an M-tree forest rarely fits at
//!   a usable scale. [`choose_scale_i8`] first tries the native 8-bit
//!   budget; where the worst-case sum cannot fit i8, the engines *widen*
//!   accumulation i8→i16 (`vaddw_s8`, two registers instead of one —
//!   [`AccumMode::Widened`]) and only the i16 accumulator bound applies.
//!   Storage payloads (thresholds, leaves, quantized base) must still fit i8
//!   individually.
//!
//! The accumulator budget reserves `M + 1` counts of slack: `⌊s·x⌋` can
//! overshoot `s·|x|` by up to 1 for negative `x`, once per tree plus the
//! base score.
//!
//! # Per-tree leaf scales (InTreeger-style scale/shift)
//!
//! Global scaling couples two unrelated constraints through the single
//! scale `s`: leaf *resolution* (RF leaves live in `[0, 1/M]`, so `s < M`
//! flushes them to zero — the floor in [`choose_scale_i8`]) and accumulator
//! *safety* (`s · worst-sum + slack ≤ acc_max`). For large forests the two
//! collide and the tier falls back to [`AccumMode::Widened`].
//!
//! [`QForest::from_forest_per_tree`] decouples them: tree `t`'s leaves are
//! stored at their own scale `s·2^{k_t}` (the largest power-of-two multiple
//! that still fits the storage width — full 8-bit resolution per tree), and
//! the engines apply a per-tree **rounding shift** `(v + 2^{k_t-1}) ≫ k_t`
//! when summing (NEON `SRSHR`, [`shift_round`] in scalar code), which lands
//! every term back in the common accumulation scale `s`. The shifted term
//! approximates `s·v` to within 1 count (round-to-nearest on the finely
//! stored value, vs the global floor's one-sided truncation of the coarse
//! one), so the accumulator slack stays `M + 1` — but the leaf floor
//! `s ≥ M` disappears entirely: [`choose_scale_i8_per_tree`] can pick an
//! accumulation scale low enough for a **native** i8 accumulator on
//! forests whose global analysis required widening. The §5-style safety
//! proof is in DESIGN.md §6. Thresholds, features, the base score and the
//! final descale all stay at the common scale `s`; only leaf storage is
//! per-tree.

pub mod flint;
pub mod merge;

use std::marker::PhantomData;

use crate::forest::{Forest, Task, Tree};

/// A fixed-point storage integer — the scalar the quantized engines compare
/// and store. Implemented for `i16` (the paper's tier, v = 8) and `i8`
/// (v = 16).
pub trait QuantInt:
    Copy
    + Default
    + PartialEq
    + Eq
    + PartialOrd
    + Ord
    + std::hash::Hash
    + std::fmt::Debug
    + Send
    + Sync
    + 'static
{
    /// Storage width in bits (16 or 8).
    const BITS: u32;
    /// Largest representable value, as f32 (i16: 32767, i8: 127).
    const MAX_F: f32;
    /// Smallest representable value, as f32 (i16: -32768, i8: -128).
    const MIN_F: f32;
    /// Engine-name prefix for this tier (`q` = int16, `q8` = int8).
    const ENGINE_PREFIX: &'static str;

    /// Saturating `⌊v⌋`: NaN → 0, out-of-range → MIN/MAX. This is the one
    /// place eq. 3 meets finite storage; every quantization path (thresholds,
    /// leaves, features, base score) must go through it.
    fn from_f32_sat(v: f32) -> Self;

    /// Widen into the i32 accumulation/descale domain.
    fn to_i32(self) -> i32;
}

impl QuantInt for i16 {
    const BITS: u32 = 16;
    const MAX_F: f32 = i16::MAX as f32;
    const MIN_F: f32 = i16::MIN as f32;
    const ENGINE_PREFIX: &'static str = "q";

    #[inline]
    fn from_f32_sat(v: f32) -> i16 {
        // `as` saturates at the bounds and maps NaN to 0 (Rust guarantees).
        v.floor() as i16
    }

    #[inline]
    fn to_i32(self) -> i32 {
        self as i32
    }
}

impl QuantInt for i8 {
    const BITS: u32 = 8;
    const MAX_F: f32 = i8::MAX as f32;
    const MIN_F: f32 = i8::MIN as f32;
    const ENGINE_PREFIX: &'static str = "q8";

    #[inline]
    fn from_f32_sat(v: f32) -> i8 {
        v.floor() as i8
    }

    #[inline]
    fn to_i32(self) -> i32 {
        self as i32
    }
}

/// Fixed-point configuration for one storage tier.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantConfig<S: QuantInt = i16> {
    /// The scale constant `s` in `q(x) = ⌊s·x⌋`.
    pub scale: f32,
    _storage: PhantomData<S>,
}

impl<S: QuantInt> QuantConfig<S> {
    pub fn new(scale: f32) -> QuantConfig<S> {
        QuantConfig { scale, _storage: PhantomData }
    }

    /// Quantize one value with saturation (NaN → 0).
    #[inline]
    pub fn q(&self, x: f32) -> S {
        S::from_f32_sat(self.scale * x)
    }

    /// Quantize into the i32 descale domain — the base-score path. Same
    /// floor and NaN → 0 semantics as [`QuantConfig::q`], but saturating at
    /// half the i32 range instead of the storage width: the base score only
    /// ever participates in i32 accumulation (it is not stored in `S`), and
    /// the ±`i32::MAX/2` headroom guarantees base + any forest sum
    /// (|Σ| ≤ M·S::MAX < 2^30 for M ≤ 32768 trees) cannot overflow i32 —
    /// unlike the old bare `floor() as i32` cast, which could saturate at
    /// `i32::MAX` and then wrap when leaf values were added.
    #[inline]
    pub fn q_i32(&self, x: f32) -> i32 {
        let cap = (i32::MAX as f32) / 2.0;
        (self.scale * x).floor().clamp(-cap, cap) as i32
    }

    /// Quantize a feature row/batch.
    pub fn q_slice(&self, xs: &[f32], out: &mut Vec<S>) {
        out.clear();
        out.extend(xs.iter().map(|&x| self.q(x)));
    }

    /// Dequantize a score.
    #[inline]
    pub fn dq(&self, v: i32) -> f32 {
        v as f32 / self.scale
    }
}

impl QuantConfig {
    /// The paper's default for normalized features: `s = 2^15`. Note that at
    /// this scale `q(x)` saturates for `|x| ≥ 1.0` (`⌊2^15·1.0⌋ = 32768 >
    /// i16::MAX`); [`choose_scale`] caps at `i16::MAX` so a chosen scale
    /// never silently saturates in-range inputs.
    pub fn paper_default() -> QuantConfig {
        QuantConfig::new(32768.0)
    }
}

/// Which parts of the forest are quantized — Table 3 evaluates all four
/// combinations of {float, int} splits × leaves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantParts {
    pub splits: bool,
    pub leaves: bool,
}

impl QuantParts {
    pub const BOTH: QuantParts = QuantParts { splits: true, leaves: true };
    pub const SPLITS_ONLY: QuantParts = QuantParts { splits: true, leaves: false };
    pub const LEAVES_ONLY: QuantParts = QuantParts { splits: false, leaves: true };
    pub const NONE: QuantParts = QuantParts { splits: false, leaves: false };
}

/// How an int8 engine accumulates per-tree scores (§5 redone for 8-bit
/// accumulators).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccumMode {
    /// The worst-case forest sum provably fits the storage-width
    /// accumulator: 16 adds per register (`vaddq_s8`).
    Native,
    /// The sum can exceed i8: lanes widen i8 → i16 before accumulation
    /// (`vaddw_s8`), costing two accumulator registers instead of one.
    Widened,
}

impl AccumMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            AccumMode::Native => "native",
            AccumMode::Widened => "widened",
        }
    }
}

/// A fully quantized forest (thresholds and leaf values in `S`), preserving
/// the float forest's topology. This is the model format the quantized
/// engines (qNA/qIE/qQS/qVQS/qRS and the q8 tier) consume.
#[derive(Debug, Clone, PartialEq)]
pub struct QForest<S: QuantInt = i16> {
    pub trees: Vec<QTree<S>>,
    pub n_features: usize,
    pub n_classes: usize,
    pub task: Task,
    /// Quantized base score (i32 — it participates in the i32 descale path,
    /// never stored in `S`), via the saturating [`QuantConfig::q_i32`].
    pub base_score: Vec<i32>,
    pub config: QuantConfig<S>,
    /// Per-tree leaf shift `k_t`: tree `t`'s stored leaf values are at scale
    /// `config.scale · 2^{k_t}`, and every engine applies the rounding
    /// shift [`shift_round`]`(v, k_t)` when summing (module docs). All
    /// zeros under global scaling ([`QForest::from_forest`]).
    pub tree_shifts: Vec<u8>,
}

/// The per-tree leaf shift applied at sum time: `(v + 2^{k-1}) ≫ k`
/// (round-half-up; `k = 0` is the identity). This is the one definition of
/// the shift semantics — the SIMD engines' `SRSHR` emulation
/// ([`crate::neon::vrshrq_n_s8`]) is bit-identical to it for values that
/// fit the storage width.
#[inline]
pub fn shift_round(v: i32, k: u8) -> i32 {
    if k == 0 {
        v
    } else {
        (v + (1i32 << (k - 1))) >> k
    }
}

/// Largest `k` such that leaves of magnitude `max_abs` stored at
/// `scale · 2^k` still fit the storage width. Capped at `S::BITS`: ARM
/// `SRSHR` encodes shifts `#1..=#lane_bits` only, so a larger `k` could
/// not execute on real hardware (and a `BITS`-wide rounding shift of an
/// in-range value is already 0) — the cap keeps the simulated engines
/// portable to actual NEON intrinsics.
fn leaf_shift_for<S: QuantInt>(scale: f32, max_abs: f32) -> u8 {
    if max_abs <= 0.0 {
        return 0;
    }
    let cap = S::BITS as u8;
    let mut k = 0u8;
    while k < cap && scale * ((1u32 << (k + 1)) as f32) * max_abs <= S::MAX_F {
        k += 1;
    }
    k
}

/// One quantized tree: same `Child` topology as [`Tree`], integer payloads.
#[derive(Debug, Clone, PartialEq)]
pub struct QTree<S: QuantInt = i16> {
    pub features: Vec<u32>,
    pub thresholds: Vec<S>,
    pub left: Vec<crate::forest::Child>,
    pub right: Vec<crate::forest::Child>,
    pub leaf_values: Vec<S>,
    pub n_leaves: usize,
}

impl<S: QuantInt> QForest<S> {
    /// Quantize a forest with the given scale (global scaling: one scale
    /// for thresholds, leaves and features; all per-tree shifts zero).
    pub fn from_forest(f: &Forest, config: QuantConfig<S>) -> QForest<S> {
        Self::build(f, config, false)
    }

    /// Quantize with **per-tree leaf scales** (module docs): thresholds and
    /// features stay at `config.scale`, but tree `t`'s leaves are stored at
    /// `config.scale · 2^{k_t}` with the largest `k_t` that fits the
    /// storage width, and `tree_shifts[t] = k_t` tells the engines which
    /// rounding shift to apply at sum time.
    pub fn from_forest_per_tree(f: &Forest, config: QuantConfig<S>) -> QForest<S> {
        Self::build(f, config, true)
    }

    fn build(f: &Forest, config: QuantConfig<S>, per_tree: bool) -> QForest<S> {
        let mut tree_shifts = Vec::with_capacity(f.trees.len());
        let trees = f
            .trees
            .iter()
            .map(|t| {
                let k = if per_tree {
                    let mx = t.leaf_values.iter().map(|v| v.abs()).fold(0f32, f32::max);
                    leaf_shift_for::<S>(config.scale, mx)
                } else {
                    0
                };
                tree_shifts.push(k);
                let leaf_cfg: QuantConfig<S> =
                    QuantConfig::new(config.scale * (1u32 << k) as f32);
                QTree {
                    features: t.nodes.iter().map(|n| n.feature).collect(),
                    thresholds: t.nodes.iter().map(|n| config.q(n.threshold)).collect(),
                    left: t.nodes.iter().map(|n| n.left).collect(),
                    right: t.nodes.iter().map(|n| n.right).collect(),
                    leaf_values: t.leaf_values.iter().map(|&v| leaf_cfg.q(v)).collect(),
                    n_leaves: t.n_leaves,
                }
            })
            .collect();
        QForest {
            trees,
            n_features: f.n_features,
            n_classes: f.n_classes,
            task: f.task,
            base_score: f.base_score.iter().map(|&v| config.q_i32(v)).collect(),
            config,
            tree_shifts,
        }
    }

    /// Reference (naive-traversal) prediction on float inputs: features are
    /// quantized on the fly, scores accumulate in i32 (per-tree terms go
    /// through [`shift_round`]) and are descaled. Every quantized engine
    /// must agree with this bit-for-bit on scores before descaling.
    pub fn predict_batch(&self, x: &[f32]) -> Vec<f32> {
        let n = x.len() / self.n_features;
        let c = self.n_classes;
        let mut out = vec![0f32; n * c];
        let mut qx = Vec::new();
        for i in 0..n {
            self.config.q_slice(&x[i * self.n_features..(i + 1) * self.n_features], &mut qx);
            let mut acc = vec![0i32; c];
            for (j, &b) in self.base_score.iter().enumerate() {
                acc[j] = b;
            }
            for (ti, t) in self.trees.iter().enumerate() {
                let leaf = t.exit_leaf_q(&qx);
                let k = self.tree_shifts[ti];
                for j in 0..c {
                    acc[j] += shift_round(t.leaf_values[leaf * c + j].to_i32(), k);
                }
            }
            for j in 0..c {
                out[i * c + j] = self.config.dq(acc[j]);
            }
        }
        out
    }

    /// Max leaf count (the QuickScorer `L`).
    pub fn max_leaves(&self) -> usize {
        self.trees.iter().map(|t| t.n_leaves).max().unwrap_or(1)
    }

    /// Worst-case |accumulated score| before descaling, from the *quantized*
    /// payloads (exact, unlike the float analysis in
    /// [`max_safe_scale_with`]): max over classes of |base| + Σ_trees
    /// max_leaf |`shift_round(v, k_t)`| — the shifted terms are what the
    /// engines actually add.
    pub fn worst_abs_acc(&self) -> i64 {
        let c = self.n_classes;
        (0..c)
            .map(|j| {
                let mut w = (self.base_score[j] as i64).abs();
                for (ti, t) in self.trees.iter().enumerate() {
                    let k = self.tree_shifts[ti];
                    let mx = (0..t.n_leaves)
                        .map(|l| {
                            (shift_round(t.leaf_values[l * c + j].to_i32(), k) as i64).abs()
                        })
                        .max()
                        .unwrap_or(0);
                    w += mx;
                }
                w
            })
            .max()
            .unwrap_or(0)
    }

    /// Whether any tree stores leaves at a per-tree scale (at least one
    /// non-zero shift).
    pub fn has_per_tree_scales(&self) -> bool {
        self.tree_shifts.iter().any(|&k| k != 0)
    }
}

impl QForest<i8> {
    /// Whether the int8 engines can accumulate natively in i8 or must widen
    /// to i16 — decided from the quantized model itself, so the choice is
    /// exact rather than an estimate.
    pub fn accum_mode(&self) -> AccumMode {
        if self.worst_abs_acc() <= i8::MAX as i64 {
            AccumMode::Native
        } else {
            AccumMode::Widened
        }
    }
}

impl<S: QuantInt> QTree<S> {
    /// Walk with already-quantized features (split is `q(x) <= q(t)`).
    pub fn exit_leaf_q(&self, qx: &[S]) -> usize {
        use crate::forest::Child;
        if self.features.is_empty() {
            return 0;
        }
        let mut cur = Child::Inner(0);
        loop {
            match cur {
                Child::Leaf(l) => return l as usize,
                Child::Inner(i) => {
                    let i = i as usize;
                    cur = if qx[self.features[i] as usize] <= self.thresholds[i] {
                        self.left[i]
                    } else {
                        self.right[i]
                    };
                }
            }
        }
    }
}

/// Evaluate accuracy under a partial quantization (Table 3): splits and/or
/// leaves quantized, naive traversal. Float features are quantized only for
/// the split comparison when `parts.splits` is set. Thresholds are
/// pre-quantized once per call, not re-quantized per node visit.
pub fn accuracy_with_parts<S: QuantInt>(
    f: &Forest,
    config: QuantConfig<S>,
    parts: QuantParts,
    x: &[f32],
    labels: &[u32],
) -> f64 {
    let n = labels.len();
    let c = f.n_classes;
    // Hoisted threshold quantization (one pass over the forest instead of
    // one `q` per node *visit*).
    let qthresholds: Vec<Vec<S>> = if parts.splits {
        f.trees
            .iter()
            .map(|t| t.nodes.iter().map(|nd| config.q(nd.threshold)).collect())
            .collect()
    } else {
        Vec::new()
    };
    let mut correct = 0usize;
    let mut qx = Vec::new();
    for i in 0..n {
        let row = &x[i * f.n_features..(i + 1) * f.n_features];
        if parts.splits {
            config.q_slice(row, &mut qx);
        }
        let mut scores = vec![0f64; c];
        for (ti, t) in f.trees.iter().enumerate() {
            let qth = if parts.splits { Some(qthresholds[ti].as_slice()) } else { None };
            let leaf = exit_leaf_parts(t, row, &qx, qth);
            for j in 0..c {
                let v = t.leaf_values[leaf * c + j];
                scores[j] += if parts.leaves {
                    config.q(v).to_i32() as f64 / config.scale as f64
                } else {
                    v as f64
                };
            }
        }
        let mut best = 0usize;
        for j in 1..c {
            if scores[j] > scores[best] {
                best = j;
            }
        }
        if best as u32 == labels[i] {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

/// Walk one tree with optional pre-quantized thresholds (`qth` set iff
/// splits are quantized; then `qrow` holds the quantized features).
fn exit_leaf_parts<S: QuantInt>(t: &Tree, row: &[f32], qrow: &[S], qth: Option<&[S]>) -> usize {
    use crate::forest::Child;
    if t.nodes.is_empty() {
        return 0;
    }
    let mut cur = Child::Inner(0);
    loop {
        match cur {
            Child::Leaf(l) => return l as usize,
            Child::Inner(i) => {
                let i = i as usize;
                let nd = &t.nodes[i];
                let go_left = match qth {
                    Some(qt) => qrow[nd.feature as usize] <= qt[i],
                    None => row[nd.feature as usize] <= nd.threshold,
                };
                cur = if go_left { nd.left } else { nd.right };
            }
        }
    }
}

/// The largest scale for which (a) every stored payload fits the storage
/// width (`storage_max`) and (b) the engines' SIMD score accumulation
/// cannot wrap an accumulator holding at most `acc_max`:
///
/// * thresholds: `s ≤ storage_max / max_abs_feature`;
/// * individual leaf values: `s ≤ storage_max / max|v|` (binding when the
///   accumulator is wider than storage — the widened i8 tier). The base
///   score is *not* stored in `S` (it lives in the i32 descale path via
///   [`QuantConfig::q_i32`]), so it does not constrain storage;
/// * accumulated score: `s·(|base| + Σ_trees max_leaf |v|) + M + 1 ≤
///   acc_max` — the `M + 1` slack covers the ⌊·⌋ overshoot of up to one
///   count per negative term. (Including the base here is conservative:
///   the engines add it in i32, outside the narrow SIMD accumulator.)
///
/// Scales above this are *representable* but an adversarial instance can
/// overflow the accumulator — exactly as it would on the paper's hardware.
pub fn max_safe_scale_with(
    f: &Forest,
    max_abs_feature: f32,
    storage_max: f32,
    acc_max: f32,
) -> f32 {
    let max_base: f32 = f.base_score.iter().map(|v| v.abs()).fold(0.0, f32::max);
    let mut worst: f32 = max_base;
    let mut max_value: f32 = 0.0;
    for t in &f.trees {
        let mx = t.leaf_values.iter().map(|v| v.abs()).fold(0f32, f32::max);
        worst += mx;
        max_value = max_value.max(mx);
    }
    let slack = (f.n_trees() + 1) as f32;
    let bound_acc =
        if worst > 0.0 { (acc_max - slack).max(1.0) / worst } else { f32::INFINITY };
    let bound_thresholds =
        if max_abs_feature > 0.0 { storage_max / max_abs_feature } else { f32::INFINITY };
    let bound_values = if max_value > 0.0 { storage_max / max_value } else { f32::INFINITY };
    bound_acc.min(bound_thresholds).min(bound_values)
}

/// [`max_safe_scale_with`] for the paper's int16 tier: i16 storage, i16 SIMD
/// accumulation (§5.1: `vaddq_s16`, 8 values at once).
pub fn max_safe_scale(f: &Forest, max_abs_feature: f32) -> f32 {
    max_safe_scale_with(f, max_abs_feature, i16::MAX as f32, i16::MAX as f32)
}

/// Choose an int16 scale for a forest per §5: as large as possible within
/// `[M, i16::MAX]` while guaranteeing (a) thresholds fit i16 given the
/// feature range `max_abs_feature`, and (b) the worst-case accumulated score
/// fits an i16 SIMD accumulator. The representability cap is `i16::MAX`
/// (32767), **not** the paper's 2^15: a scale of 32768 silently saturates
/// `q(1.0)`.
pub fn choose_scale(f: &Forest, max_abs_feature: f32) -> QuantConfig {
    let m = f.n_trees().max(1) as f32;
    let s = max_safe_scale(f, max_abs_feature).min(i16::MAX as f32).max(m);
    QuantConfig::new(s)
}

/// Choose an int8 scale (§5 redone for 8-bit storage): prefer a scale whose
/// worst-case sum fits a *native* i8 accumulator; where that would push the
/// scale below the leaf-preserving lower bound `M`, fall back to the i16
/// accumulator budget and let the engines widen accumulation i8 → i16
/// ([`AccumMode::Widened`], decided per-model by [`QForest::accum_mode`]).
///
/// The lower bound `M` never overrides *storage* safety: a scale that
/// saturates thresholds or leaves destroys score ordering, which is
/// strictly worse than coarse leaves, so the per-value storage bound is a
/// hard ceiling (relevant for GBT-like forests whose leaf magnitudes
/// exceed `127/M`).
pub fn choose_scale_i8(f: &Forest, max_abs_feature: f32) -> QuantConfig<i8> {
    let m = (f.n_trees().max(1) as f32).min(i8::MAX as f32);
    // Per-value storage bound alone (no accumulator constraint).
    let storage = max_safe_scale_with(f, max_abs_feature, i8::MAX as f32, f32::INFINITY)
        .min(i8::MAX as f32);
    let native = max_safe_scale_with(f, max_abs_feature, i8::MAX as f32, i8::MAX as f32);
    let widened = max_safe_scale_with(f, max_abs_feature, i8::MAX as f32, i16::MAX as f32);
    let preferred = if native >= m { native } else { widened };
    // The leaf-preserving floor M, then the hard ceilings: representability,
    // per-value storage, and the widened i16 accumulator budget (for very
    // large forests, M ≥ ~128, the floor could otherwise exceed it and the
    // engines' i16 accumulation would wrap against the i32 reference).
    QuantConfig::new(preferred.max(m).min(i8::MAX as f32).min(storage).min(widened))
}

/// Choose an int8 *accumulation* scale for per-tree leaf scaling (module
/// docs, DESIGN.md §6): the largest scale whose worst-case sum of rounded
/// per-tree terms fits a **native** i8 accumulator.
///
/// Unlike [`choose_scale_i8`] there is **no leaf-preserving floor `M`** —
/// leaves keep their resolution at the per-tree scale `s·2^{k_t}` chosen by
/// [`QForest::from_forest_per_tree`], so the accumulation scale is bounded
/// only by threshold representability and the native accumulator budget.
/// The slack stays `M + 1`: a rounded term `(⌊s·2^k·v⌋ + 2^{k-1}) ≫ k`
/// lies within 1 count of `s·v` (½ from rounding plus the stored value's
/// scaled-down floor error), once per tree plus the base-score floor.
/// Per-value leaf storage needs no separate bound: the accumulator bound
/// already implies `s · max_t max|v| ≤ 127` (the sum dominates any single
/// tree), and `k_t` only ever *raises* the leaf scale toward the storage
/// limit.
///
/// For forests so large that the slack alone exceeds the i8 budget
/// (`M ≥ ~126`) the returned scale degenerates toward 1; the *a-priori*
/// analysis is conservative, so the resulting [`QForest::accum_mode`] —
/// computed exactly from the quantized payloads — may still come out
/// Native where the float bound could not prove it. Callers (e.g.
/// `engine::build`) adopt the per-tree config only when that exact
/// per-model check says Native.
pub fn choose_scale_i8_per_tree(f: &Forest, max_abs_feature: f32) -> QuantConfig<i8> {
    QuantConfig::new(per_tree_accum_scale(f, max_abs_feature, i8::MAX as f32))
}

/// Choose an int16 *accumulation* scale for per-tree leaf scaling — the
/// i16 tier's analogue of [`choose_scale_i8_per_tree`] (the shift
/// machinery is tier-generic; only the build paths differed until ISSUE
/// 5's satellite added this one).
///
/// The i16 tier never needs widening (its accumulator *is* the storage
/// width), so the win here is different from i8's Native-restoration:
/// dropping the leaf floor `M` and re-scaling each tree's leaves to the
/// full 16-bit range preserves **leaf resolution** on forests with wildly
/// uneven leaf magnitudes (boosted ensembles whose late trees carry tiny
/// corrections that a single global scale floors away). Consumed by
/// [`crate::engine::build_i16_per_tree`] and ranked by the selector as the
/// `qVQS+pt` candidate.
pub fn choose_scale_i16_per_tree(f: &Forest, max_abs_feature: f32) -> QuantConfig<i16> {
    QuantConfig::new(per_tree_accum_scale(f, max_abs_feature, i16::MAX as f32))
}

/// Shared per-tree accumulation-scale bound: the largest scale whose
/// worst-case sum of rounded per-tree terms fits `acc_max`, with no leaf
/// floor (per-tree shifts preserve leaf resolution independently) and the
/// threshold-representability ceiling (`acc_max` is also the storage max
/// for both supported tiers).
fn per_tree_accum_scale(f: &Forest, max_abs_feature: f32, acc_max: f32) -> f32 {
    let max_base: f32 = f.base_score.iter().map(|v| v.abs()).fold(0.0, f32::max);
    let mut worst: f32 = max_base;
    for t in &f.trees {
        worst += t.leaf_values.iter().map(|v| v.abs()).fold(0f32, f32::max);
    }
    let slack = (f.n_trees() + 1) as f32;
    let bound_acc =
        if worst > 0.0 { (acc_max - slack).max(1.0) / worst } else { f32::INFINITY };
    let bound_thresholds =
        if max_abs_feature > 0.0 { acc_max / max_abs_feature } else { f32::INFINITY };
    bound_acc.min(bound_thresholds).min(acc_max).max(1.0)
}

/// The i8 auto-quantization **policy** — the one place it is defined, used
/// by `engine::build` for `Precision::I8` with `quant: None` (and by tests
/// constructing the matching reference): quantize globally
/// ([`choose_scale_i8`]); when the exact per-model check says the global
/// config must widen, try per-tree leaf scales and adopt them **only** if
/// the exact check then proves a native i8 accumulator (faster: one
/// accumulator register instead of a widened pair).
pub fn quantize_i8_auto(f: &Forest, max_abs_feature: f32) -> QForest<i8> {
    let qf = QForest::<i8>::from_forest(f, choose_scale_i8(f, max_abs_feature));
    if qf.accum_mode() == AccumMode::Widened {
        let pt =
            QForest::<i8>::from_forest_per_tree(f, choose_scale_i8_per_tree(f, max_abs_feature));
        if pt.accum_mode() == AccumMode::Native {
            return pt;
        }
    }
    qf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    fn trained() -> (Forest, crate::data::Dataset) {
        let ds = DatasetId::Magic.generate(800, 17);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 16,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        (f, ds)
    }

    /// A forest with explicit base score and one constant tree per value.
    fn leaf_forest(base: Vec<f32>, leaves: &[f32]) -> Forest {
        let c = base.len();
        let mut f = Forest::new(2, c, Task::Ranking);
        f.base_score = base;
        for &v in leaves {
            f.trees.push(Tree::leaf(vec![v; c]));
        }
        f
    }

    #[test]
    fn q_floor_semantics() {
        let c: QuantConfig = QuantConfig::new(8.0);
        assert_eq!(c.q(0.99), 7); // floor(7.92)
        assert_eq!(c.q(1.0), 8);
        assert_eq!(c.q(-0.1), -1); // floor(-0.8) = -1
        assert_eq!(c.q(0.0), 0);
    }

    #[test]
    fn q_saturates() {
        let c = QuantConfig::paper_default();
        assert_eq!(c.q(2.0), i16::MAX);
        assert_eq!(c.q(-2.0), i16::MIN);
        assert_eq!(c.q(f32::NAN), 0);
    }

    #[test]
    fn q_i8_semantics() {
        let c: QuantConfig<i8> = QuantConfig::new(8.0);
        assert_eq!(c.q(0.99), 7i8);
        assert_eq!(c.q(-0.1), -1i8);
        assert_eq!(c.q(100.0), i8::MAX);
        assert_eq!(c.q(-100.0), i8::MIN);
        assert_eq!(c.q(f32::NAN), 0i8);
    }

    /// Regression (saturation bug #1): the representable-scale cap is
    /// i16::MAX = 32767, not 2^15 = 32768 — at the old cap `q(1.0)`
    /// silently saturated and `dq(q(1.0))` lost exactness.
    #[test]
    fn choose_scale_never_saturates_in_range_inputs() {
        // Tiny payloads so the representability cap (not the accumulator
        // bound) is what binds.
        let f = leaf_forest(vec![0.0], &[0.001]);
        let cfg = choose_scale(&f, 1.0);
        assert_eq!(cfg.scale, i16::MAX as f32, "cap must bind at 32767");
        // q(1.0) is exactly representable — no clamp involved.
        assert_eq!(cfg.q(1.0), i16::MAX);
        assert_eq!(cfg.dq(cfg.q(1.0) as i32), 1.0);
        // ... whereas the paper's 2^15 scale saturates there.
        let paper = QuantConfig::paper_default();
        assert!(paper.dq(paper.q(1.0) as i32) < 1.0);
        // Every in-range input stays strictly inside the clamp bounds.
        for x in [-1.0f32, -0.5, 0.0, 0.5, 0.999, 1.0] {
            let v = cfg.scale * x;
            assert!(v >= i16::MIN as f32 && v <= i16::MAX as f32, "{x} saturates");
        }
    }

    /// Regression (saturation bug #2): base_score goes through the shared
    /// saturating helper — NaN → 0 like `QuantConfig::q`, saturation at
    /// half the i32 range (not `i32::MAX`, where adding leaf values would
    /// wrap; not the storage width, which would shift legitimately large
    /// finite bases).
    #[test]
    fn base_score_quantization_is_saturating_and_headroomed() {
        let f = leaf_forest(vec![f32::NAN, 1e10, -1e10], &[0.0]);
        let qf = QForest::from_forest(&f, QuantConfig::paper_default());
        let cap = ((i32::MAX as f32) / 2.0) as i32;
        assert_eq!(qf.base_score, vec![0, cap, -cap]);
        // The descale path stays finite and the i32 accumulation cannot
        // wrap even with worst-case leaf sums on top.
        let scores = qf.predict_batch(&[0.25, 0.5]);
        assert!(scores.iter().all(|v| v.is_finite()));
        // Finite large bases keep their exact quantized value (no storage
        // clamp): base 2.0 at s = 2^15 is 65536, well beyond i16::MAX.
        let f2 = leaf_forest(vec![2.0], &[0.0]);
        let qf2 = QForest::from_forest(&f2, QuantConfig::paper_default());
        assert_eq!(qf2.base_score, vec![65536]);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn qforest_predictions_close_to_float() {
        let (f, ds) = trained();
        let qf = QForest::from_forest(&f, QuantConfig::paper_default());
        let float_scores = f.predict_batch(&ds.x[..ds.d * 64]);
        let q_scores = qf.predict_batch(&ds.x[..ds.d * 64]);
        // Quantized scores should be close (not identical).
        let max_diff = float_scores
            .iter()
            .zip(&q_scores)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 0.05, "max diff {max_diff}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn accuracy_parts_none_matches_float() {
        let (f, ds) = trained();
        let cfg = QuantConfig::paper_default();
        let a_float = f.accuracy(&ds.x, &ds.labels);
        let a_none = accuracy_with_parts(&f, cfg, QuantParts::NONE, &ds.x, &ds.labels);
        assert!((a_float - a_none).abs() < 1e-12);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn accuracy_quantized_near_float() {
        let (f, ds) = trained();
        let cfg = QuantConfig::paper_default();
        let a_float = f.accuracy(&ds.x, &ds.labels);
        let a_q = accuracy_with_parts(&f, cfg, QuantParts::BOTH, &ds.x, &ds.labels);
        assert!((a_float - a_q).abs() < 0.03, "float {a_float} vs quant {a_q}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn accuracy_i8_tier_usable() {
        let (f, ds) = trained();
        let cfg = choose_scale_i8(&f, 1.0);
        let a_float = f.accuracy(&ds.x, &ds.labels);
        let a_q8 = accuracy_with_parts(&f, cfg, QuantParts::BOTH, &ds.x, &ds.labels);
        assert!((a_float - a_q8).abs() < 0.15, "float {a_float} vs int8 {a_q8}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn choose_scale_bounds() {
        let (f, _) = trained();
        let cfg = choose_scale(&f, 1.0);
        assert!(cfg.scale >= f.n_trees() as f32);
        assert!(cfg.scale <= i16::MAX as f32);
        // RF leaves are probs/M; worst total <= 1+eps so score bound allows
        // a large scale.
        assert!(cfg.scale > 1024.0, "scale {}", cfg.scale);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn choose_scale_i8_bounds_and_native_mode() {
        let (f, _) = trained();
        let cfg = choose_scale_i8(&f, 1.0);
        assert!(cfg.scale >= f.n_trees() as f32, "scale {}", cfg.scale);
        assert!(cfg.scale <= i8::MAX as f32, "scale {}", cfg.scale);
        // RF worst-case sum ≈ 1.0: the native 8-bit budget suffices and the
        // quantized sums provably fit i8.
        let qf = QForest::<i8>::from_forest(&f, cfg);
        assert_eq!(qf.accum_mode(), AccumMode::Native);
        assert!(qf.worst_abs_acc() <= i8::MAX as i64, "worst {}", qf.worst_abs_acc());
    }

    #[test]
    fn choose_scale_i8_widens_when_sum_exceeds_i8() {
        // 10 constant trees of 3.0: worst sum = 30, so a native i8 budget
        // would force the scale below M = 10 — the tier must widen instead.
        let f = leaf_forest(vec![0.0], &[3.0; 10]);
        let cfg = choose_scale_i8(&f, 1.0);
        assert!(cfg.scale >= 10.0, "scale {} below leaf-preserving bound", cfg.scale);
        let qf = QForest::<i8>::from_forest(&f, cfg);
        assert_eq!(qf.accum_mode(), AccumMode::Widened);
        // Individual payloads still fit i8 storage: the stored value is the
        // unclamped floor, not a saturated one.
        let expect = (cfg.scale * 3.0).floor();
        assert!(expect <= i8::MAX as f32, "scale violates the storage bound");
        assert!(qf
            .trees
            .iter()
            .all(|t| t.leaf_values.iter().all(|&v| v as f32 == expect)));
        // ... and the widened i16 accumulator holds the worst-case sum.
        assert!(qf.worst_abs_acc() <= i16::MAX as i64);
    }

    /// Regression (review finding): the base score is never stored in `S`
    /// (it lives in the i32 descale path), so it must not cap the storage
    /// bound — only leaf magnitudes and the feature range do.
    #[test]
    fn base_score_does_not_cap_the_storage_bound() {
        let f = leaf_forest(vec![5.0], &[0.1; 50]);
        let cfg = choose_scale_i8(&f, 1.0);
        // Old behavior capped at 127/5 = 25.4; the leaf bound allows 127.
        assert!(cfg.scale >= 100.0, "scale {} capped by the unstored base", cfg.scale);
        let qf = QForest::<i8>::from_forest(&f, cfg);
        assert!(qf.trees.iter().all(|t| t.leaf_values.iter().all(|&v| v < i8::MAX)));
        // Accumulation stays wrap-free: quantized base + leaf sums fit i16.
        assert!(qf.worst_abs_acc() <= i16::MAX as i64);
    }

    /// Regression (review finding): the leaf-preserving floor `M` must not
    /// lift the i8 scale above the *widened i16 accumulator* budget — on a
    /// 300-tree forest the floor (min(M, 127) = 127) exceeds
    /// `(32767 - 301)/300 ≈ 108`, and the engines' wrapping i16
    /// accumulation would diverge from the i32 reference.
    #[test]
    fn choose_scale_i8_respects_widened_accumulator_for_huge_forests() {
        let f = leaf_forest(vec![0.0], &[1.0; 300]);
        let cfg = choose_scale_i8(&f, 1.0);
        let qf = QForest::<i8>::from_forest(&f, cfg);
        assert_eq!(qf.accum_mode(), AccumMode::Widened);
        assert!(
            qf.worst_abs_acc() <= i16::MAX as i64,
            "worst {} wraps the widened accumulator (scale {})",
            qf.worst_abs_acc(),
            cfg.scale
        );
    }

    /// Regression (review finding): the leaf-preserving floor `M` must not
    /// lift the i8 scale above the per-value storage bound — on a GBT-like
    /// forest (M = 50 trees, |leaf| up to 5.0) the old `.max(M)` forced
    /// s = 50 and saturated every leaf (`⌊250⌋ → 127`), silently destroying
    /// score ordering.
    #[test]
    fn choose_scale_i8_storage_bound_beats_leaf_floor() {
        let f = leaf_forest(vec![0.0], &[5.0; 50]);
        let cfg = choose_scale_i8(&f, 1.0);
        assert!(cfg.scale <= 127.0 / 5.0 + 1e-3, "scale {}", cfg.scale);
        let qf = QForest::<i8>::from_forest(&f, cfg);
        let expect = (cfg.scale * 5.0).floor();
        assert!(expect <= i8::MAX as f32);
        assert!(qf
            .trees
            .iter()
            .all(|t| t.leaf_values.iter().all(|&v| v as f32 == expect)));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn scores_fit_i16_accumulator() {
        let (f, ds) = trained();
        let cfg = choose_scale(&f, 1.0);
        let qf = QForest::from_forest(&f, cfg);
        // The exact worst-case bound implies every instance fits.
        assert!(qf.worst_abs_acc() <= i16::MAX as i64);
        // Accumulate per-instance scores and check i16 range empirically.
        for i in 0..64 {
            let row = &ds.x[i * ds.d..(i + 1) * ds.d];
            let mut qx = Vec::new();
            cfg.q_slice(row, &mut qx);
            let mut acc = vec![0i32; qf.n_classes];
            for t in &qf.trees {
                let leaf = t.exit_leaf_q(&qx);
                for j in 0..qf.n_classes {
                    acc[j] += t.leaf_values[leaf * qf.n_classes + j] as i32;
                }
            }
            for &a in &acc {
                assert!(a >= i16::MIN as i32 && a <= i16::MAX as i32, "overflow {a}");
            }
        }
    }

    #[test]
    fn shift_round_semantics() {
        assert_eq!(shift_round(70, 6), 1); // (70 + 32) >> 6
        assert_eq!(shift_round(96, 6), 2); // (96 + 32) >> 6 = 128 >> 6
        assert_eq!(shift_round(-70, 6), -1); // (-70 + 32) >> 6 = -38 >> 6
        assert_eq!(shift_round(5, 0), 5); // k = 0 is the identity
        assert_eq!(shift_round(-5, 0), -5);
        // Round-half-up at the midpoint.
        assert_eq!(shift_round(1, 1), 1);
        assert_eq!(shift_round(-1, 1), 0);
        // Matches the SRSHR emulation for every storable i8.
        for k in 0..=7u8 {
            for v in i8::MIN..=i8::MAX {
                let simd = crate::neon::vrshrq_n_s8(crate::neon::vdupq_n_s8(v), k as u32);
                assert_eq!(simd.0[0] as i32, shift_round(v as i32, k), "v={v} k={k}");
            }
        }
    }

    /// The headline property of per-tree scaling: a forest whose *global*
    /// analysis forced widened accumulation (the leaf floor `M` exceeds the
    /// native budget) flips to Native under per-tree leaf scales, because
    /// the floor disappears — while storage stays in-range and leaves keep
    /// real resolution.
    #[test]
    fn per_tree_scaling_flips_widened_to_native() {
        // 60 trees × max|leaf| = 1/30: worst sum = 2.0. Global: the floor
        // M = 60 exceeds the native bound (127 - 61)/2 = 33 → Widened.
        let f = leaf_forest(vec![0.0], &[1.0 / 30.0; 60]);
        let qf_global = QForest::<i8>::from_forest(&f, choose_scale_i8(&f, 1.0));
        assert_eq!(qf_global.accum_mode(), AccumMode::Widened);
        assert!(!qf_global.has_per_tree_scales());

        let cfg = choose_scale_i8_per_tree(&f, 1.0);
        assert!(cfg.scale <= 33.0 + 1e-3, "scale {}", cfg.scale);
        let qf = QForest::<i8>::from_forest_per_tree(&f, cfg);
        assert!(qf.has_per_tree_scales());
        assert_eq!(qf.accum_mode(), AccumMode::Native, "worst {}", qf.worst_abs_acc());
        assert!(qf.worst_abs_acc() <= i8::MAX as i64);
        // Stored leaves use the full storage range (resolution retained):
        // at the global scale 33 they would all quantize to ⌊33/30⌋ = 1.
        for (t, &k) in qf.trees.iter().zip(&qf.tree_shifts) {
            assert!(k > 0, "expected a non-zero per-tree shift");
            for &v in &t.leaf_values {
                assert!(v > 1, "leaf {v} lost its per-tree resolution");
                // ... and the shifted term is what the accumulator sees.
                assert!(shift_round(v as i32, k) <= 2);
            }
        }
    }

    /// Per-tree shifts never push stored leaves out of the storage width,
    /// and the reference prediction stays finite and close to float.
    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn per_tree_reference_close_to_float() {
        let (f, ds) = trained();
        let cfg = choose_scale_i8_per_tree(&f, 1.0);
        let qf = QForest::<i8>::from_forest_per_tree(&f, cfg);
        // The per-tree shift never raises a leaf scale past the storage
        // width (the k_t selection rule): the largest original leaf of each
        // tree still floors inside i8.
        for (ft, (t, &k)) in f.trees.iter().zip(qf.trees.iter().zip(&qf.tree_shifts)) {
            let leaf_scale = cfg.scale * (1u32 << k) as f32;
            let mx = ft.leaf_values.iter().map(|v| v.abs()).fold(0f32, f32::max);
            assert!(
                (leaf_scale * mx).floor() <= i8::MAX as f32,
                "tree saturates: scale {leaf_scale} × max |leaf| {mx}"
            );
            assert_eq!(t.leaf_values.len(), ft.leaf_values.len());
        }
        let float_scores = f.predict_batch(&ds.x[..ds.d * 64]);
        let q_scores = qf.predict_batch(&ds.x[..ds.d * 64]);
        let max_diff = float_scores
            .iter()
            .zip(&q_scores)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 0.3, "max diff {max_diff}");
        // Argmax agreement stays high (rounded terms are unbiased).
        let a = Forest::argmax(&q_scores, qf.n_classes);
        let b = Forest::argmax(&float_scores, f.n_classes);
        let agree = a.iter().zip(&b).filter(|(x, y)| x == y).count();
        // Same floor as the global-scale sanity check (75%): rounding shifts
        // are never worse than flooring in expectation.
        assert!(agree >= 48, "only {agree}/64 argmax agreements");
    }

    /// The i16 per-tree analogue: no leaf floor, bounded by the i16
    /// accumulator budget, and the reference prediction recovers leaf
    /// resolution a global scale would floor away.
    #[test]
    fn choose_scale_i16_per_tree_bounds_and_resolution() {
        // GBT-like: one huge-leaf tree forces the global accumulator bound
        // down to ~32767/100 ≈ 327, flooring the tiny 1e-4 leaves of the
        // other trees to 0. Per-tree shifts must recover them.
        let mut leaves = vec![100.0f32];
        leaves.extend(std::iter::repeat(1e-4).take(9));
        let f = leaf_forest(vec![0.0], &leaves);
        let cfg = choose_scale_i16_per_tree(&f, 1.0);
        assert!(cfg.scale <= i16::MAX as f32);
        let qf = QForest::<i16>::from_forest_per_tree(&f, cfg);
        assert!(qf.worst_abs_acc() <= i16::MAX as i64, "worst {}", qf.worst_abs_acc());
        assert!(qf.has_per_tree_scales());
        // The tiny-leaf trees keep non-zero stored payloads...
        for (t, &k) in qf.trees.iter().zip(&qf.tree_shifts).skip(1) {
            assert!(k > 0, "tiny-leaf tree got no shift");
            assert!(t.leaf_values[0] > 0, "tiny leaf floored to zero");
        }
        // ... whereas the global config at the same scale floors them.
        let qf_global = QForest::<i16>::from_forest(&f, cfg);
        assert!(qf_global.trees[1].leaf_values[0] == 0);
        // Reference prediction stays finite and close to float.
        let got = qf.predict_batch(&[0.5, 0.5]);
        let want = f.predict_batch(&[0.5, 0.5]);
        assert!((got[0] - want[0]).abs() / want[0] < 1e-2, "{got:?} vs {want:?}");
    }

    /// Both per-tree tiers come from one bound: i16's is the i8 one with a
    /// wider budget, so it always admits a ≥ scale.
    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn per_tree_scale_tiers_are_ordered() {
        let (f, _) = trained();
        let s8 = choose_scale_i8_per_tree(&f, 1.0).scale;
        let s16 = choose_scale_i16_per_tree(&f, 1.0).scale;
        assert!(s16 >= s8, "i16 budget {s16} below i8 budget {s8}");
        assert!(s16 <= i16::MAX as f32);
    }

    /// Zero-shift per-tree quantization is exactly global quantization: on
    /// a forest whose leaves already fill the storage width (k_t = 0
    /// everywhere), the two constructors agree bit-for-bit.
    #[test]
    fn per_tree_with_zero_shifts_equals_global() {
        let f = leaf_forest(vec![0.5], &[1.0, -1.0, 0.75]);
        let cfg: QuantConfig<i8> = QuantConfig::new(100.0);
        let a = QForest::<i8>::from_forest(&f, cfg);
        let b = QForest::<i8>::from_forest_per_tree(&f, cfg);
        assert!(!b.has_per_tree_scales());
        assert_eq!(a, b);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn i8_qforest_reference_runs() {
        let (f, ds) = trained();
        let cfg = choose_scale_i8(&f, 1.0);
        let qf = QForest::<i8>::from_forest(&f, cfg);
        let scores = qf.predict_batch(&ds.x[..ds.d * 32]);
        assert_eq!(scores.len(), 32 * qf.n_classes);
        assert!(scores.iter().all(|v| v.is_finite()));
        // Same argmax as float on most rows (coarse sanity, not exactness).
        let float_scores = f.predict_batch(&ds.x[..ds.d * 32]);
        let a = Forest::argmax(&scores, qf.n_classes);
        let b = Forest::argmax(&float_scores, f.n_classes);
        let agree = a.iter().zip(&b).filter(|(x, y)| x == y).count();
        assert!(agree >= 24, "only {agree}/32 argmax agreements");
    }
}
