//! Node-merging statistics (paper Table 4).
//!
//! RapidScorer merges "equivalent nodes" — nodes across the whole forest
//! testing the same `(feature, threshold)` pair — so only one comparison is
//! executed per unique pair (§3). Quantization can *collapse* formerly
//! distinct thresholds into one fixed-point value, increasing merging; on
//! datasets whose informative thresholds live in a narrow band (EEG) this is
//! dramatic and costs accuracy (Tables 3 & 4).

use std::collections::HashSet;

use crate::forest::Forest;
use crate::quant::{QForest, QuantInt};

/// Fraction of nodes that remain after merging equivalent `(feature,
/// threshold)` float nodes, i.e. `unique pairs / total nodes`.
pub fn unique_node_fraction(f: &Forest) -> f64 {
    let mut set: HashSet<(u32, u32)> = HashSet::new();
    let mut total = 0usize;
    for t in &f.trees {
        for n in &t.nodes {
            set.insert((n.feature, n.threshold.to_bits()));
            total += 1;
        }
    }
    if total == 0 {
        1.0
    } else {
        set.len() as f64 / total as f64
    }
}

/// Same statistic on a quantized forest — any storage tier. Collapse is
/// more aggressive at 8 bits (fewer representable thresholds), amplifying
/// Table 4's effect.
pub fn unique_node_fraction_quant<S: QuantInt>(qf: &QForest<S>) -> f64 {
    let mut set: HashSet<(u32, S)> = HashSet::new();
    let mut total = 0usize;
    for t in &qf.trees {
        for (&f, &thr) in t.features.iter().zip(&t.thresholds) {
            set.insert((f, thr));
            total += 1;
        }
    }
    if total == 0 {
        1.0
    } else {
        set.len() as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};
    use crate::quant::QuantConfig;

    fn rf(ds: &crate::data::Dataset, n_trees: usize, seed: u64) -> Forest {
        train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                seed,
                ..Default::default()
            },
        )
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn fraction_in_unit_interval() {
        let ds = DatasetId::Magic.generate(600, 3);
        let f = rf(&ds, 8, 1);
        let u = unique_node_fraction(&f);
        assert!(u > 0.0 && u <= 1.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn adult_merges_more_than_magic() {
        // Binary one-hot features => few unique thresholds (paper Table 4:
        // Adult 6-12% vs Magic 58-89%).
        let adult = DatasetId::Adult.generate(800, 3);
        let magic = DatasetId::Magic.generate(800, 3);
        let fa = rf(&adult, 12, 2);
        let fm = rf(&magic, 12, 2);
        let ua = unique_node_fraction(&fa);
        let um = unique_node_fraction(&fm);
        assert!(ua < um, "adult {ua} should merge more than magic {um}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn quantization_only_decreases_uniqueness() {
        let ds = DatasetId::Eeg.generate(800, 4);
        let f = rf(&ds, 12, 5);
        let qf = QForest::from_forest(&f, QuantConfig::paper_default());
        let u = unique_node_fraction(&f);
        let uq = unique_node_fraction_quant(&qf);
        assert!(uq <= u + 1e-12, "quant {uq} vs float {u}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn eeg_collapses_under_quantization() {
        // The paper's EEG anomaly: quantization halves the unique-node
        // fraction (Table 4: 52.2% -> 28.6% at 128 trees).
        let ds = DatasetId::Eeg.generate(1500, 7);
        let f = rf(&ds, 16, 6);
        let qf = QForest::from_forest(&f, QuantConfig::paper_default());
        let u = unique_node_fraction(&f);
        let uq = unique_node_fraction_quant(&qf);
        assert!(uq < 0.75 * u, "expected collapse: float {u}, quant {uq}");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn i8_collapses_at_least_as_much_as_i16() {
        // 8-bit thresholds have 256 representable values: merging can only
        // increase vs the i16 tier (Table 4's effect amplified).
        for id in [DatasetId::Eeg, DatasetId::Magic] {
            let ds = id.generate(900, 11);
            let f = rf(&ds, 12, 9);
            let qf16 = QForest::from_forest(&f, crate::quant::choose_scale(&f, 1.0));
            let qf8 =
                QForest::<i8>::from_forest(&f, crate::quant::choose_scale_i8(&f, 1.0));
            let u16v = unique_node_fraction_quant(&qf16);
            let u8v = unique_node_fraction_quant(&qf8);
            assert!(u8v <= u16v + 1e-12, "{}: i8 {u8v} vs i16 {u16v}", id.name());
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn mnist_unaffected_by_quantization() {
        // Pixel grid spacing (1/255) is far above the quantization step
        // (2^-15), so uniqueness barely moves (paper: identical columns).
        let ds = DatasetId::Mnist.generate(400, 8);
        let f = rf(&ds, 8, 7);
        let qf = QForest::from_forest(&f, QuantConfig::paper_default());
        let u = unique_node_fraction(&f);
        let uq = unique_node_fraction_quant(&qf);
        assert!((u - uq).abs() < 0.02, "float {u} vs quant {uq}");
    }
}
