//! `arbors` — CLI entrypoint for the tree-ensemble inference system.
//!
//! Commands:
//!   train      train a Random Forest / GBT and save it as JSON
//!   predict    run a saved model over a CSV with a chosen engine
//!   accuracy   accuracy of a model (float + quantized variants)
//!   select     auto-select the best engine for a model (+ device profiles)
//!   bench      regenerate a paper table/figure (table2..5, fig1, fig2, ...)
//!              or run the perf-history smoke grid / regression gate
//!   serve      demo serving loop with the dynamic batcher
//!   trace      capture a chrome-tracing span trace of the serving path
//!   datasets   list the built-in synthetic datasets
//!
//! Run `arbors <command> --help` semantics are documented in README.md.

use std::path::PathBuf;
use std::sync::atomic::Ordering;

use anyhow::{bail, Context, Result};

use arbors::bench::experiments;
use arbors::bench::harness::Scale;
use arbors::cli::Args;
use arbors::coordinator::{select_engine_early_exit, thread_budgets, BatchConfig, Server};
use arbors::data::{csv, DatasetId};
use arbors::device::DeviceProfile;
use arbors::engine::{build_early_exit, build_parallel, EarlyExitMode, EngineKind, Precision};
use arbors::forest::builder::{
    train_gbt, train_random_forest, GbtParams, RfParams, TreeParams,
};
use arbors::forest::{io, Forest};
use arbors::quant::{accuracy_with_parts, QuantConfig, QuantParts};

fn main() {
    let args = Args::from_env();
    let result = match args.command.as_str() {
        "train" => cmd_train(&args),
        "predict" => cmd_predict(&args),
        "accuracy" => cmd_accuracy(&args),
        "select" => cmd_select(&args),
        "bench" => cmd_bench(&args),
        "serve" => cmd_serve(&args),
        "trace" => cmd_trace(&args),
        "datasets" => cmd_datasets(&args),
        "" | "help" | "--help" => {
            print!("{}", USAGE);
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown command '{other}'\n{USAGE}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "\
arbors — fast inference of tree ensembles (QuickScorer family on simulated ARM NEON)

USAGE: arbors <command> [flags]

  train    --dataset <magic|adult|eeg|mnist|fashion|msn> | --data <csv>
           --trees N --leaves N --out model.json [--gbt] [--n N] [--seed S]
  predict  --model model.json --data in.csv --engine <NA|IE|QS|VQS|RS>
           [--precision f32|i16|i8|flint] [--early-exit off|exact|approx]
           [--quant] [--threads N] [--pin] [--out scores.csv]
           (--quant is shorthand for --precision i16; int8 covers all five
           engines and auto-upgrades to per-tree leaf scales when the
           global analysis would widen accumulation; flint runs integer
           threshold compares with bit-exact f32 outputs; --early-exit
           scores trees in confidence order and stops decided rows —
           exact keeps the argmax identical to full scoring; --pin anchors
           exec workers to their topology cluster, Linux only)
  accuracy --model model.json --dataset <name> | --data <csv>
  select   --model model.json [--device a53|exynos] [--n N] [--threads N]
           [--precision f32|i16|i8|flint] [--early-exit off|exact|approx]
           (--precision restricts the ranking to one tier; --threads adds
           row-sharded candidates like RS×4t; the qVQS+pt candidate ranks
           i16 per-tree leaf scales; --early-exit adds ee/ea staged-scoring
           candidates under the same ≥99% agreement gate)
  bench    --exp <table2|table3|table4|table5|fig1|fig2|ablation|tensor|scaling|int8|flint|early_exit|serving|adaptive|overload|smoke|obs|engine_micro>
           [--threads N] [--precision P] [--pin] [--smoke] [--matrix] | --gate
           (scale via ARBORS_SCALE=quick|default|full;
           int8 -> results/int8_tiers.json; flint compares f32 vs FLInt
           per engine -> results/flint.json, --smoke shrinks it for CI;
           early_exit ablates exact-mode agreement + the approx threshold
           sweep, trees evaluated vs accuracy -> results/early_exit.json,
           --smoke shrinks it, --early-exit narrows it to one mode;
           serving drives a 2-model server,
           shared-pool vs separate-pools, -> results/serving.json; adaptive
           runs the static/adaptive x pinned/unpinned x claim-1/claim-k grid
           on a synthetic big.LITTLE topology -> results/adaptive.json,
           --smoke shrinks it for CI; --pin applies to scaling;
           overload sweeps offered-load multiples with degradation off vs
           on (p50/p99/shed rate/argmax agreement) -> results/overload.json,
           --smoke shrinks it and appends the magic/ovl* gate series;
           smoke appends the perf-history grid to dev/bench/data.js, path
           overridable via ARBORS_BENCH_DATA, --matrix widens the grid to
           the full named version matrix (pr1-f32 .. pr8-flint); obs
           measures serving
           throughput with tracing off vs on; engine_micro reports
           SIMD-ops/row per engine tier -> results/engine_micro.json;
           --gate skips the experiment and fails on any series >15% worse
           than its rolling median)
  serve    --dataset <name> [--engine E] [--precision P | --quant]
           [--early-exit off|exact|approx] [--requests N]
           [--threads N] [--budget B] [--pin] [--listen 127.0.0.1:7878]
           [--degrade]
           (--threads sizes the server-wide shared exec pool, default = host
           cores; --budget is this model's worker entitlement on it,
           default = pool size; --pin pins pool workers to their cluster;
           JSON-over-TCP via coordinator::net; live introspection via
           {\"cmd\":\"stats\",\"mode\":\"json\"}, {\"cmd\":\"stats\",\"mode\":\"trace\"}
           and {\"cmd\":\"health\"}; --degrade arms overload-triggered
           graceful degradation onto a selector-ranked cheaper fallback
           from the >=99%-agreement set)
  trace    [--out trace.json] [--requests N] [--threads N]
           (enables span tracing, drives an in-process serving workload,
           writes chrome-tracing JSON for chrome://tracing / Perfetto)
  datasets
";

/// The optional `--precision {f32,i16,i8,flint}` flag.
fn precision_flag(args: &Args) -> Result<Option<Precision>> {
    match args.get("precision") {
        Some(p) => Precision::from_name(p)
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("unknown --precision '{p}' (f32|i16|i8|flint)")),
        None => Ok(None),
    }
}

/// The optional `--early-exit {off,exact,approx}` flag (`None` when
/// absent). Orthogonal to `--precision`: any tier can be wrapped in
/// calibration-ordered staged scoring.
fn early_exit_flag(args: &Args) -> Result<Option<EarlyExitMode>> {
    match args.get("early-exit") {
        Some(m) => EarlyExitMode::from_name(m)
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("unknown --early-exit '{m}' (off|exact|approx)")),
        None => Ok(None),
    }
}

/// `--precision` with `--quant` kept as an i16 shorthand (explicit
/// `--precision` wins when both are given).
fn parse_precision(args: &Args) -> Result<Precision> {
    let quant = args.switch("quant");
    Ok(match precision_flag(args)? {
        Some(p) => p,
        None if quant => Precision::I16,
        None => Precision::F32,
    })
}

fn scale() -> Scale {
    Scale::from_env()
}

fn load_or_generate(args: &Args) -> Result<arbors::data::Dataset> {
    if let Some(path) = args.get("data") {
        return csv::read_dataset(&PathBuf::from(path), "csv");
    }
    let name = args.get_or("dataset", "magic");
    let id = DatasetId::from_name(&name)
        .with_context(|| format!("unknown dataset '{name}'"))?;
    let n = args.usize_or("n", id.default_n())?;
    Ok(id.generate(n, args.usize_or("seed", 0xD5)? as u64))
}

fn cmd_train(args: &Args) -> Result<()> {
    let trees = args.usize_or("trees", 128)?;
    let leaves = args.usize_or("leaves", 64)?;
    let seed = args.usize_or("seed", 0x5eed)? as u64;
    let out = PathBuf::from(args.get_or("out", "model.json"));
    let forest = if args.get_or("dataset", "") == "msn" || args.switch("gbt") {
        let q = args.usize_or("queries", 100)?;
        let docs = args.usize_or("docs", 20)?;
        let ds = arbors::data::ranking::msn_like(q, docs, seed);
        args.finish()?;
        println!("training GBT: {trees} trees x {leaves} leaves on msn-like ({} rows)", ds.n);
        train_gbt(
            &ds.x,
            &ds.relevance,
            ds.d,
            GbtParams {
                n_trees: trees,
                tree: TreeParams { max_leaves: leaves, min_samples_leaf: 2, mtry: 32 },
                learning_rate: 0.1,
                subsample: 0.7,
                seed,
            },
        )
    } else {
        let ds = load_or_generate(args)?;
        args.finish()?;
        println!("training RF: {trees} trees x {leaves} leaves on {} (n={})", ds.name, ds.n);
        train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: trees,
                tree: TreeParams { max_leaves: leaves, min_samples_leaf: 2, mtry: 0 },
                seed,
                ..Default::default()
            },
        )
    };
    io::save(&forest, &out)?;
    let (lmin, lmean, lmax) = forest.leaf_stats();
    println!(
        "saved {out:?}: {} trees, {} nodes, leaves/tree {lmin}/{lmean:.1}/{lmax}",
        forest.n_trees(),
        forest.n_nodes()
    );
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let model = io::load(&PathBuf::from(
        args.get("model").context("--model required")?,
    ))?;
    let ds = csv::read_dataset(
        &PathBuf::from(args.get("data").context("--data required")?),
        "input",
    )?;
    if ds.d != model.n_features {
        bail!("model expects {} features, data has {}", model.n_features, ds.d);
    }
    let kind = EngineKind::from_short(&args.get_or("engine", "RS"))
        .context("bad --engine")?;
    let precision = parse_precision(args)?;
    let ee_mode = early_exit_flag(args)?.unwrap_or(EarlyExitMode::Off);
    let threads = args.usize_or("threads", 1)?;
    let pin = args.switch("pin");
    let out_path = args.get("out").map(PathBuf::from);
    args.finish()?;

    // `--pin` places the exec workers onto the detected topology's
    // clusters (graceful no-op off Linux / with refused masks). Wrapping
    // the serial engine is exactly `build_parallel`'s Exact path, plus the
    // pinned pool config. `--early-exit` wraps the chosen tier in
    // calibration-ordered staged scoring (the tree order is calibrated on
    // the first rows of the input batch; exact mode keeps the argmax
    // identical to full scoring for any calibration).
    let engine: Box<dyn arbors::engine::Engine> = if ee_mode != EarlyExitMode::Off {
        let cal = &ds.x[..ds.d * ds.n.min(256)];
        let ee = build_early_exit(kind, precision, &model, cal, ee_mode)?;
        if threads > 1 {
            Box::new(arbors::exec::ParallelEngine::wrap_with(
                std::sync::Arc::new(ee),
                arbors::exec::PoolConfig::new(threads).pin(pin),
            ))
        } else {
            Box::new(ee)
        }
    } else if pin && threads > 1 {
        let serial: std::sync::Arc<dyn arbors::engine::Engine> =
            std::sync::Arc::from(arbors::engine::build(kind, precision, &model, None)?);
        Box::new(arbors::exec::ParallelEngine::wrap_with(
            serial,
            arbors::exec::PoolConfig::new(threads).pin(true),
        ))
    } else {
        build_parallel(kind, precision, &model, None, threads)?
    };
    let scores = engine.predict(&ds.x);
    let preds = Forest::argmax(&scores, model.n_classes);
    if let Some(p) = out_path {
        let mut text = String::from("prediction\n");
        for v in &preds {
            text.push_str(&format!("{v}\n"));
        }
        std::fs::write(&p, text)?;
        println!("wrote {} predictions to {p:?}", preds.len());
    } else {
        for v in preds.iter().take(20) {
            println!("{v}");
        }
        if preds.len() > 20 {
            println!("... ({} total; use --out to save all)", preds.len());
        }
    }
    Ok(())
}

fn cmd_accuracy(args: &Args) -> Result<()> {
    let model = io::load(&PathBuf::from(
        args.get("model").context("--model required")?,
    ))?;
    let ds = load_or_generate(args)?;
    args.finish()?;
    let cfg = QuantConfig::paper_default();
    println!("accuracy of {} on {} (n={}):", model.n_trees(), ds.name, ds.n);
    for (label, parts) in [
        ("float/float", QuantParts::NONE),
        ("float/int16", QuantParts::LEAVES_ONLY),
        ("int16/float", QuantParts::SPLITS_ONLY),
        ("int16/int16", QuantParts::BOTH),
    ] {
        let acc = accuracy_with_parts(&model, cfg, parts, &ds.x, &ds.labels);
        println!("  split/leaf {label}: {:.2}%", acc * 100.0);
    }
    let cfg8 = arbors::quant::choose_scale_i8(&model, 1.0);
    let acc8 = accuracy_with_parts(&model, cfg8, QuantParts::BOTH, &ds.x, &ds.labels);
    println!("  split/leaf int8/int8: {:.2}% (s={:.1})", acc8 * 100.0, cfg8.scale);
    // Per-tree leaf scales (the ablation knob `bench --exp int8` records).
    let cfg8pt = arbors::quant::choose_scale_i8_per_tree(&model, 1.0);
    let qf8pt = arbors::quant::QForest::<i8>::from_forest_per_tree(&model, cfg8pt);
    let preds = Forest::argmax(&qf8pt.predict_batch(&ds.x), model.n_classes);
    let correct = preds.iter().zip(&ds.labels).filter(|(p, l)| p == l).count();
    println!(
        "  int8 per-tree scales: {:.2}% (s={:.1}, {} accumulation)",
        100.0 * correct as f64 / ds.labels.len().max(1) as f64,
        cfg8pt.scale,
        qf8pt.accum_mode().as_str()
    );
    Ok(())
}

fn cmd_select(args: &Args) -> Result<()> {
    let model = io::load(&PathBuf::from(
        args.get("model").context("--model required")?,
    ))?;
    let device = match args.get("device") {
        None => None,
        Some("a53") => Some(DeviceProfile::cortex_a53()),
        Some("exynos") => Some(DeviceProfile::exynos_5422_big()),
        Some("a7") => Some(DeviceProfile::exynos_5422_little()),
        Some(other) => bail!("unknown device '{other}' (a53|exynos|a7)"),
    };
    let n = args.usize_or("n", 256)?;
    let threads = args.usize_or("threads", 1)?;
    let tier = precision_flag(args)?;
    let ee_mode = early_exit_flag(args)?.unwrap_or(EarlyExitMode::Off);
    args.finish()?;
    let mut rng = arbors::util::Pcg32::seeded(0xCA11);
    let calibration: Vec<f32> =
        (0..n * model.n_features).map(|_| rng.f32()).collect();
    // With a tier filter, excluded variants are never built or timed; with
    // `--early-exit`, ee/ea staged-scoring candidates rank alongside.
    let sel = select_engine_early_exit(
        &model,
        &calibration,
        device.as_ref(),
        3,
        &thread_budgets(threads),
        tier,
        ee_mode,
    )?;
    anyhow::ensure!(
        !sel.candidates.is_empty(),
        "no candidates for this model{}",
        tier.map(|p| format!(" at --precision {}", p.name())).unwrap_or_default()
    );
    print!("{}", sel.report());
    // Same gate as Server::deploy_auto: fastest with ≥ 99% argmax
    // agreement vs the float reference, not fastest outright.
    println!("recommended: {}", sel.recommended().name);
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    // `--gate` short-circuits: no experiment, just the rolling-median
    // regression check over the perf history (CI runs this on PRs).
    if args.switch("gate") {
        args.finish()?;
        let path = arbors::obs::bench_data::default_path();
        let report = arbors::obs::bench_data::gate(&path)?;
        print!("perf gate over {}:\n{report}", path.display());
        println!("perf gate: ok");
        return Ok(());
    }
    let exp = args.get_or("exp", "table5");
    // Only the scaling/serving/adaptive/obs experiments are threaded (and
    // only scaling precision-filtered and pinnable, only adaptive
    // smokable); leaving the flags unconsumed elsewhere makes `finish()`
    // reject them loudly instead of silently ignoring them.
    let threads = if exp == "scaling"
        || exp == "serving"
        || exp == "adaptive"
        || exp == "obs"
        || exp == "overload"
    {
        args.usize_or("threads", 4)?
    } else {
        1
    };
    let precision = if exp == "scaling" { precision_flag(args)? } else { None };
    let pin = if exp == "scaling" { args.switch("pin") } else { false };
    let smoke = if exp == "adaptive" || exp == "flint" || exp == "early_exit" || exp == "overload"
    {
        args.switch("smoke")
    } else {
        false
    };
    // `--early-exit` narrows the ablation to one mode's rows (both by
    // default); elsewhere the flag is rejected by `finish()`.
    let ee_only = if exp == "early_exit" { early_exit_flag(args)? } else { None };
    let matrix = if exp == "smoke" { args.switch("matrix") } else { false };
    args.finish()?;
    let s = scale();
    let text = match exp.as_str() {
        "table2" => experiments::table2(&s),
        "table3" => experiments::table3(&s),
        "table4" => experiments::table4(&s),
        "table5" => experiments::table5(&s, 64),
        "table5-l32" => experiments::table5(&s, 32),
        "fig1" => experiments::fig1(&s),
        "fig2" => experiments::fig2(&s),
        "ablation" => experiments::ablation_rs(&s),
        "tensor" => experiments::tensor_vs_native(s.repeats)?,
        "scaling" => experiments::scaling(&s, threads, precision, pin),
        "int8" => experiments::int8_tiers(&s),
        "flint" => experiments::flint(&s, smoke),
        "early_exit" => experiments::early_exit(&s, smoke, ee_only),
        "serving" => experiments::serving(&s, threads),
        "adaptive" => experiments::adaptive(&s, threads, smoke),
        "overload" => experiments::overload(&s, threads, smoke),
        "smoke" => {
            experiments::smoke(&s, &arbors::obs::bench_data::default_path(), matrix)?
        }
        "obs" => experiments::obs(&s, threads),
        "engine_micro" => experiments::engine_micro(&s),
        other => bail!("unknown experiment '{other}'"),
    };
    experiments::archive(&exp, &text);
    println!("{text}");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let ds = load_or_generate(args)?;
    let trees = args.usize_or("trees", 128)?;
    let leaves = args.usize_or("leaves", 64)?;
    let kind = EngineKind::from_short(&args.get_or("engine", "RS"))
        .context("bad --engine")?;
    let precision = parse_precision(args)?;
    let ee_mode = early_exit_flag(args)?.unwrap_or(EarlyExitMode::Off);
    let n_requests = args.usize_or("requests", 10_000)?;
    // --threads sizes the server-wide shared pool (default: host cores);
    // --budget is this model's worker entitlement on it (default: the whole
    // pool — a single model may use every worker).
    let pool_size = match args.usize_opt("threads")? {
        Some(t) => t.max(1),
        None => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    };
    let budget = args.usize_opt("budget")?.unwrap_or(pool_size).max(1);
    let pin = args.switch("pin");
    let listen = args.get("listen").map(str::to_string);
    let degrade = args.switch("degrade");
    args.finish()?;
    let config = BatchConfig { exec_threads: budget, ..BatchConfig::default() };
    // `--pin` anchors the shared pool's workers to their topology cluster
    // so the batcher's big.LITTLE-weighted chunks land where planned.
    let pool_config = arbors::exec::PoolConfig::new(pool_size).pin(pin);

    if let Some(addr) = listen {
        // Network mode: train, deploy, and serve the JSON-over-TCP protocol
        // until interrupted.
        let (train, _test) = ds.split(0.2, 7);
        println!("training {trees} x {leaves} RF on {} ...", train.name);
        let forest = arbors::bench::harness::cached_rf(&train, trees, leaves);
        let server = std::sync::Arc::new(Server::with_pool_config(pool_config.clone()));
        if ee_mode == EarlyExitMode::Off {
            server.deploy("model", &forest, kind, precision, config)?;
        } else {
            // Staged scoring drops into the fused batcher like any engine:
            // flush chunks are row-disjoint, so per-row exits are intact.
            let cal = &train.x[..train.d * train.n.min(256)];
            let ee = build_early_exit(kind, precision, &forest, cal, ee_mode)?;
            server.deploy_engine("model", &forest, std::sync::Arc::new(ee), config)?;
        }
        if degrade {
            let cal = &train.x[..train.d * train.n.min(256)];
            let fb = server.enable_degrade(
                "model",
                &forest,
                cal,
                arbors::coordinator::DegradeConfig::default(),
            )?;
            println!("degradation armed: overload fallback is {fb}");
        }
        let net = arbors::coordinator::NetServer::start(server.clone(), &addr)?;
        println!(
            "serving model 'model' on {} — protocol: {{\"model\": \"model\", \"x\": [...]}}",
            net.addr()
        );
        loop {
            std::thread::sleep(std::time::Duration::from_secs(10));
            print!("{}", server.report());
        }
    }

    let (train, test) = ds.split(0.2, 7);
    println!("training {} x {} RF on {} ...", trees, leaves, train.name);
    let forest = arbors::bench::harness::cached_rf(&train, trees, leaves);
    let server = Server::with_pool_config(pool_config);
    if ee_mode == EarlyExitMode::Off {
        server.deploy("model", &forest, kind, precision, config)?;
    } else {
        let cal = &train.x[..train.d * train.n.min(256)];
        let ee = build_early_exit(kind, precision, &forest, cal, ee_mode)?;
        server.deploy_engine("model", &forest, std::sync::Arc::new(ee), config)?;
    }
    if degrade {
        let cal = &train.x[..train.d * train.n.min(256)];
        let fb = server.enable_degrade(
            "model",
            &forest,
            cal,
            arbors::coordinator::DegradeConfig::default(),
        )?;
        println!("degradation armed: overload fallback is {fb}");
    }
    println!(
        "serving {n_requests} requests through the fused batcher \
         (pool {pool_size} workers, {} pinned, budget {budget}) ...",
        server.pinned_workers()
    );

    let dep = server.model("model").unwrap();
    let sw = arbors::util::Stopwatch::start();
    let mut correct = 0usize;
    let mut replies = Vec::with_capacity(1024);
    for i in 0..n_requests {
        let row = test.row(i % test.n).to_vec();
        replies.push((i % test.n, dep.batcher.submit(row)));
        if replies.len() == 1024 || i + 1 == n_requests {
            for (j, r) in replies.drain(..) {
                let scores = r?.recv().map_err(|_| anyhow::anyhow!("server gone"))??;
                let pred = Forest::argmax(&scores, forest.n_classes)[0];
                if pred == test.labels[j] {
                    correct += 1;
                }
            }
        }
    }
    let total_s = sw.micros() / 1e6;
    println!(
        "done: {:.0} req/s, accuracy {:.2}%",
        n_requests as f64 / total_s,
        100.0 * correct as f64 / n_requests as f64
    );
    println!("{}", server.report());
    let m = &dep.batcher.metrics;
    println!(
        "batches executed: {} (mean size {:.1})",
        m.batches.load(Ordering::Relaxed),
        m.mean_batch_size()
    );
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get_or("out", "trace.json"));
    let n_requests = args.usize_or("requests", 2048)?;
    let threads = args.usize_or("threads", 2)?.max(1);
    args.finish()?;

    // Enable span recording, drive a small in-process serving workload so
    // every stage of the request→lane path emits spans, then export the
    // rings as chrome-tracing JSON (DESIGN.md §8 span taxonomy).
    let ds = DatasetId::Magic.generate(4000, 0xD5);
    let (train, test) = ds.split(0.2, 7);
    let forest = arbors::bench::harness::cached_rf(&train, 32, 32);
    let server = Server::with_pool_size(threads);
    let config = BatchConfig { exec_threads: threads, ..BatchConfig::default() };
    server.deploy("model", &forest, EngineKind::Vqs, Precision::I16, config)?;
    let dep = server.model("model").expect("deployed");

    arbors::obs::span::set_enabled(true);
    arbors::obs::span::clear();
    let mut inflight = Vec::with_capacity(64);
    for i in 0..n_requests {
        if let Ok(rx) = dep.batcher.submit(test.row(i % test.n).to_vec()) {
            inflight.push(rx);
        }
        if inflight.len() >= 64 {
            for rx in inflight.drain(..) {
                let _ = rx.recv();
            }
        }
    }
    for rx in inflight.drain(..) {
        let _ = rx.recv();
    }
    let doc = arbors::obs::span::export_chrome();
    arbors::obs::span::set_enabled(false);
    std::fs::write(&out, doc.pretty())?;
    let n_events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .map_or(0, |a| a.len());
    println!(
        "wrote {n_events} trace events to {} — load in chrome://tracing or Perfetto",
        out.display()
    );
    Ok(())
}

fn cmd_datasets(args: &Args) -> Result<()> {
    args.finish()?;
    println!("{:<10} {:>6} {:>8} {:>8}  notes", "name", "d", "classes", "default_n");
    for id in DatasetId::ALL {
        let ds = id.generate(200, 1);
        println!(
            "{:<10} {:>6} {:>8} {:>8}  {}",
            id.name(),
            ds.d,
            ds.n_classes,
            id.default_n(),
            match id {
                DatasetId::Adult => "one-hot binary features (heavy RS merging)",
                DatasetId::Eeg => "narrow band + outliers (quantization collapse)",
                DatasetId::Mnist | DatasetId::Fashion => "256-level pixel grid",
                DatasetId::Magic => "smooth continuous features",
            }
        );
    }
    println!("{:<10} {:>6} {:>8} {:>8}  ranking (graded relevance, query groups)", "msn", 136, 5, 2000);
    Ok(())
}
