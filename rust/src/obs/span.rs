//! Per-thread ring-buffer span tracing for the request→lane path
//! (DESIGN.md §8).
//!
//! Each thread that records spans owns a fixed-capacity ring (drop-oldest
//! at [`RING_CAP`]), registered once in a process-wide registry so an
//! exporter can walk every ring without stopping the world. The hot path
//! is deliberately boring:
//!
//! * **Disabled** (the default): [`SpanTimer::start`] is one relaxed
//!   atomic load and returns an inert timer — no clock read, no lock, no
//!   allocation. This is the overhead budget the serving path pays per
//!   span site.
//! * **Enabled**: start reads the monotonic clock; finish takes the
//!   thread-local ring's (uncontended) mutex and writes one fixed-size
//!   record into preallocated storage. Nothing allocates after the ring's
//!   one-time creation.
//!
//! Spans recorded on a pool worker thread are tagged with the worker's
//! topology class ([`crate::exec::current_worker_class`]), so a trace
//! shows *which cluster* executed each shard. Export is chrome-tracing
//! JSON (`chrome://tracing`, Perfetto): `arbors trace --out trace.json`
//! or the wire command `{"cmd":"stats","mode":"trace"}`.
//!
//! The span taxonomy the coordinator emits is documented in DESIGN.md §8:
//! `admission`, `assemble`, `flush_plan`, `queue_wait`, `claim`,
//! `shard_exec`, `reply`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::Json;

/// Per-thread ring capacity (spans). At serving rates of ~10k spans/s per
/// thread this holds a few hundred milliseconds of history — enough for a
/// trace snapshot — in ~256 KiB per thread.
pub const RING_CAP: usize = 4096;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Every ring ever registered, labelled with its thread's name. Entries
/// are never removed (a dead thread's ring simply stops growing); rings
/// are only created while tracing is enabled, so an untraced process
/// registers nothing.
static REGISTRY: Mutex<Vec<(String, Arc<Mutex<Ring>>)>> = Mutex::new(Vec::new());

thread_local! {
    static LOCAL: Arc<Mutex<Ring>> = {
        let name = std::thread::current().name().unwrap_or("unnamed").to_string();
        let ring = Arc::new(Mutex::new(Ring::new()));
        REGISTRY.lock().unwrap().push((name, ring.clone()));
        ring
    };
}

/// One completed span. `start` stays an [`Instant`]; the exporter rebases
/// onto the earliest span it sees, so recording never needs a global
/// epoch.
#[derive(Clone, Debug)]
pub struct Span {
    pub name: &'static str,
    pub start: Instant,
    pub dur_us: f64,
    /// Topology class of the pool worker that recorded the span, if any
    /// (captured from [`crate::exec::current_worker_class`] at record
    /// time).
    pub class: Option<usize>,
    /// One optional numeric payload, e.g. `("rows", 64.0)`.
    pub arg: Option<(&'static str, f64)>,
}

struct Ring {
    spans: Vec<Span>,
    /// Oldest element (= next overwrite position) once the ring is full.
    head: usize,
}

impl Ring {
    fn new() -> Ring {
        Ring { spans: Vec::with_capacity(RING_CAP), head: 0 }
    }

    fn push(&mut self, s: Span) {
        if self.spans.len() < RING_CAP {
            self.spans.push(s);
        } else {
            self.spans[self.head] = s;
            self.head = (self.head + 1) % RING_CAP;
        }
    }

    /// Contents oldest-first.
    fn ordered(&self) -> Vec<Span> {
        let mut out = Vec::with_capacity(self.spans.len());
        out.extend_from_slice(&self.spans[self.head..]);
        out.extend_from_slice(&self.spans[..self.head]);
        out
    }
}

/// Turn span recording on or off, process-wide.
pub fn set_enabled(on: bool) {
    // Release pairs with the Acquire loads in `enabled`/`SpanTimer::start`:
    // whatever the toggling thread set up before enabling (cleared rings,
    // test fixtures) is visible to workers that observe the flag.
    ENABLED.store(on, Ordering::Release);
}

/// Is span recording currently on?
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Acquire)
}

/// Empty every registered ring (rings stay registered).
pub fn clear() {
    for (_, ring) in REGISTRY.lock().unwrap().iter() {
        let mut r = ring.lock().unwrap();
        r.spans.clear();
        r.head = 0;
    }
}

/// `Some(Instant::now())` when tracing is enabled, else `None` — for call
/// sites that stamp a time in one place and record the span in another
/// (e.g. `queue_wait`, measured from flush planning to task start).
#[inline]
pub fn now_if_enabled() -> Option<Instant> {
    if enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

fn record(name: &'static str, start: Instant, dur_us: f64, arg: Option<(&'static str, f64)>) {
    let class = crate::exec::current_worker_class().map(|(_, c)| c);
    LOCAL.with(|ring| {
        ring.lock().unwrap().push(Span { name, start, dur_us, class, arg });
    });
}

/// Record a span between two explicit instants (tracing must be enabled —
/// pair with [`now_if_enabled`]).
pub fn record_between(
    name: &'static str,
    start: Instant,
    end: Instant,
    arg: Option<(&'static str, f64)>,
) {
    if enabled() {
        record(name, start, end.saturating_duration_since(start).as_secs_f64() * 1e6, arg);
    }
}

/// Scoped span timer. `start` is free when tracing is off (one atomic
/// load); an unfinished timer records nothing.
pub struct SpanTimer(Option<(&'static str, Instant)>);

impl SpanTimer {
    #[inline]
    pub fn start(name: &'static str) -> SpanTimer {
        // Acquire pairs with the Release store in `set_enabled`.
        if ENABLED.load(Ordering::Acquire) {
            SpanTimer(Some((name, Instant::now())))
        } else {
            SpanTimer(None)
        }
    }

    /// End the span and record it.
    #[inline]
    pub fn finish(self) {
        self.finish_opt(None);
    }

    /// End the span with one numeric payload.
    #[inline]
    pub fn finish_with(self, key: &'static str, v: f64) {
        self.finish_opt(Some((key, v)));
    }

    fn finish_opt(self, arg: Option<(&'static str, f64)>) {
        if let Some((name, t0)) = self.0 {
            record(name, t0, t0.elapsed().as_secs_f64() * 1e6, arg);
        }
    }
}

/// Snapshot every ring: `(thread name, spans oldest-first)`.
pub fn snapshot() -> Vec<(String, Vec<Span>)> {
    REGISTRY
        .lock()
        .unwrap()
        .iter()
        .map(|(name, ring)| (name.clone(), ring.lock().unwrap().ordered()))
        .collect()
}

/// Export every recorded span as a chrome-tracing JSON document
/// (`{"traceEvents": [...]}`), loadable in `chrome://tracing` / Perfetto.
/// Timestamps are rebased onto the earliest recorded span.
pub fn export_chrome() -> Json {
    let rings = snapshot();
    let mut t0: Option<Instant> = None;
    for (_, spans) in &rings {
        for s in spans {
            t0 = Some(match t0 {
                Some(t) if t <= s.start => t,
                _ => s.start,
            });
        }
    }
    let mut events = Vec::new();
    for (tid, (tname, spans)) in rings.iter().enumerate() {
        if spans.is_empty() {
            continue;
        }
        events.push(Json::from_pairs(vec![
            ("name", Json::Str("thread_name".to_string())),
            ("ph", Json::Str("M".to_string())),
            ("pid", Json::Num(0.0)),
            ("tid", Json::Num(tid as f64)),
            ("args", Json::from_pairs(vec![("name", Json::Str(tname.clone()))])),
        ]));
        for s in spans {
            let base = t0.expect("t0 set: spans exist");
            let ts = s.start.saturating_duration_since(base).as_secs_f64() * 1e6;
            let mut args = Json::obj();
            if let Some(c) = s.class {
                args.set("class", Json::Num(c as f64));
            }
            if let Some((k, v)) = s.arg {
                args.set(k, Json::Num(v));
            }
            events.push(Json::from_pairs(vec![
                ("name", Json::Str(s.name.to_string())),
                ("cat", Json::Str("arbors".to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(ts)),
                ("dur", Json::Num(s.dur_us)),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(tid as f64)),
                ("args", args),
            ]));
        }
    }
    Json::from_pairs(vec![("traceEvents", Json::Arr(events))])
}

/// Tracing state is process-global; every test that flips it (here and in
/// `bench::experiments`) holds this lock so enable/clear/snapshot phases
/// cannot interleave across the test binary.
#[cfg(test)]
pub(crate) static TEST_LOCK: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::SharedPool;

    use super::TEST_LOCK as LOCK;

    fn spans_named(name: &str) -> Vec<Span> {
        snapshot().into_iter().flat_map(|(_, s)| s).filter(|s| s.name == name).collect()
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        clear();
        SpanTimer::start("obs_test_disabled").finish();
        record_between("obs_test_disabled", Instant::now(), Instant::now(), None);
        assert!(now_if_enabled().is_none());
        assert!(spans_named("obs_test_disabled").is_empty());
    }

    #[test]
    fn ring_drops_oldest_on_overflow() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        clear();
        let extra = 10;
        for i in 0..RING_CAP + extra {
            SpanTimer::start("obs_test_overflow").finish_with("i", i as f64);
        }
        set_enabled(false);
        let spans = spans_named("obs_test_overflow");
        assert_eq!(spans.len(), RING_CAP, "ring must cap at RING_CAP");
        // Drop-oldest: the survivors are the *last* RING_CAP records, in
        // order.
        for (j, s) in spans.iter().enumerate() {
            assert_eq!(s.arg, Some(("i", (extra + j) as f64)));
        }
    }

    #[test]
    fn worker_spans_tagged_with_current_class() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        clear();
        let pool = SharedPool::new(1);
        let client = SharedPool::register(&pool, "obs-span-test", 1);
        let (tx, rx) = std::sync::mpsc::channel();
        client.run(vec![Box::new(move || {
            let expect = crate::exec::current_worker_class().map(|(_, c)| c);
            SpanTimer::start("obs_test_class").finish();
            tx.send(expect).unwrap();
        })]);
        let expect = rx.recv().unwrap();
        set_enabled(false);
        assert!(expect.is_some(), "task must run on a pool worker");
        let spans = spans_named("obs_test_class");
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].class, expect, "span class must match current_worker_class");
        // Off-worker spans carry no class (this thread is not a worker).
        assert_eq!(crate::exec::current_worker_class(), None);
    }

    #[test]
    fn chrome_export_rebases_and_labels() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        clear();
        SpanTimer::start("obs_test_export").finish_with("rows", 3.0);
        set_enabled(false);
        let doc = export_chrome();
        let events = doc.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        let ev = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("obs_test_export"))
            .expect("exported span present");
        assert_eq!(ev.get("ph").and_then(|p| p.as_str()), Some("X"));
        assert!(ev.get("ts").and_then(|t| t.as_f64()).unwrap() >= 0.0);
        assert!(ev.get("dur").and_then(|d| d.as_f64()).unwrap() >= 0.0);
        assert_eq!(
            ev.get("args").and_then(|a| a.get("rows")).and_then(|r| r.as_f64()),
            Some(3.0)
        );
        // The metadata event names this ring's thread.
        assert!(events
            .iter()
            .any(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M")));
    }
}
