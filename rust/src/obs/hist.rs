//! Log-bucketed latency histogram with atomic buckets (DESIGN.md §8).
//!
//! A DDSketch-style sketch: bucket `i` counts values in
//! `[MIN_VALUE·γ^i, MIN_VALUE·γ^(i+1))`, so any reported quantile is within
//! `(γ−1)/(γ+1)` ≈ 2% *relative* error of the exact sample quantile,
//! independent of how many samples were recorded. This replaces the
//! coordinator's old capped `Vec` reservoirs, which silently stopped
//! sampling after 65,536 entries (long-run p99 reflected only startup).
//!
//! Properties the coordinator relies on:
//! - `record` is lock-free: one `fetch_add` per bucket plus min/max CAS.
//! - Histograms are mergeable by bucket addition (`merge`), so per-thread
//!   or per-deployment sketches can be folded into one report.
//! - Memory is fixed: [`BUCKETS`] × 8 bytes, regardless of run length.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::Summary;

/// Bucket growth factor γ: bucket `i` covers `[MIN_VALUE·γ^i, MIN_VALUE·γ^(i+1))`.
pub const GAMMA: f64 = 1.04;

/// Smallest distinguishable value (in the caller's unit — the coordinator
/// records microseconds). Everything at or below this clamps into bucket 0.
pub const MIN_VALUE: f64 = 1e-3;

/// Bucket count. `MIN_VALUE·γ^BUCKETS` ≈ 1.2e10, i.e. ~3.4 hours when the
/// unit is microseconds — far beyond any single-request latency.
pub const BUCKETS: usize = 768;

/// Worst-case relative error of any reported quantile: (γ−1)/(γ+1) ≈ 1.96%.
pub const RELATIVE_ERROR: f64 = (GAMMA - 1.0) / (GAMMA + 1.0);

// `f64::ln` is not const; the literal is checked against `GAMMA.ln()` by
// `ln_gamma_constant_matches`.
const LN_GAMMA: f64 = 0.039_220_713_153_281_33;

/// Fixed-size, thread-safe log-bucketed histogram.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Exact observed min/max, stored as f64 bit patterns and updated by
    /// CAS, so quantiles can be clamped to the true sample range (the
    /// bucket representative would otherwise overshoot `max` by up to γ).
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Buckets are elided: 768 atomics would drown any debug dump.
impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count())
            .field("min", &self.min())
            .field("max", &self.max())
            .finish()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }

    fn bucket_index(v: f64) -> usize {
        if v <= MIN_VALUE {
            return 0;
        }
        let i = ((v / MIN_VALUE).ln() / LN_GAMMA) as usize;
        i.min(BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i` — within [`RELATIVE_ERROR`] of
    /// every value the bucket can hold.
    fn representative(i: usize) -> f64 {
        MIN_VALUE * GAMMA.powi(i as i32) * (1.0 + GAMMA) / 2.0
    }

    /// Record one value. Non-finite values are clamped to 0 (bucket 0) so
    /// a pathological measurement cannot poison the sketch.
    pub fn record(&self, v: f64) {
        let v = if v.is_finite() { v.max(0.0) } else { 0.0 };
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.update_min(v);
        self.update_max(v);
    }

    fn update_min(&self, v: f64) {
        let mut cur = self.min_bits.load(Ordering::Relaxed);
        while v < f64::from_bits(cur) {
            match self.min_bits.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    fn update_max(&self, v: f64) {
        let mut cur = self.max_bits.load(Ordering::Relaxed);
        while v > f64::from_bits(cur) {
            match self.max_bits.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Total number of recorded values.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact smallest recorded value (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count() == 0 {
            return 0.0;
        }
        f64::from_bits(self.min_bits.load(Ordering::Relaxed))
    }

    /// Exact largest recorded value (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count() == 0 {
            return 0.0;
        }
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Quantile `q ∈ [0,1]`, within [`RELATIVE_ERROR`] of the exact sample
    /// quantile (and clamped to the exact observed `[min, max]`).
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        let mut v = self.max();
        for (i, b) in self.buckets.iter().enumerate() {
            // relaxed: bucket counters are independent statistics; a reader
            // racing recorders gets a torn-but-valid snapshot by design.
            cum += b.load(Ordering::Relaxed);
            if cum >= rank {
                v = Self::representative(i);
                break;
            }
        }
        v.clamp(self.min(), self.max())
    }

    /// Bucket-count snapshot (torn-but-valid under concurrent recording,
    /// like [`Histogram::quantile`]): successive snapshots let a caller
    /// compute **windowed** quantiles via [`Histogram::quantile_between`].
    /// The lifetime quantiles are cumulative — after hours of traffic a
    /// burst barely moves them — so overload detection (the degrade
    /// controller, DESIGN.md §12) needs the quantile of *recent* samples.
    pub fn snapshot(&self) -> Vec<u64> {
        // relaxed: bucket counters are independent statistics; a reader
        // racing recorders gets a torn-but-valid snapshot by design.
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Quantile `q ∈ [0,1]` of the observations recorded between two
    /// [`Histogram::snapshot`]s (bucket-wise difference). Returns 0 for an
    /// empty window. Values are bucket representatives (the usual
    /// [`RELATIVE_ERROR`] contract) without the exact min/max clamp — the
    /// window has no exact extrema of its own.
    pub fn quantile_between(prev: &[u64], cur: &[u64], q: f64) -> f64 {
        let n: u64 =
            cur.iter().zip(prev).map(|(c, p)| c.saturating_sub(*p)).sum();
        if n == 0 {
            return 0.0;
        }
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (i, (c, p)) in cur.iter().zip(prev).enumerate() {
            cum += c.saturating_sub(*p);
            if cum >= rank {
                return Self::representative(i);
            }
        }
        0.0
    }

    /// Fold `other`'s observations into `self` (bucket-wise addition).
    pub fn merge(&self, other: &Histogram) {
        let c = other.count();
        if c == 0 {
            return;
        }
        for (b, o) in self.buckets.iter().zip(other.buckets.iter()) {
            // relaxed: statistics merge — bucket counters are independent.
            let k = o.load(Ordering::Relaxed);
            if k > 0 {
                // relaxed: same — both sides tolerate concurrent recording.
                b.fetch_add(k, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(c, Ordering::Relaxed);
        self.update_min(other.min());
        self.update_max(other.max());
    }

    /// Summary statistics compatible with [`crate::util::Summary`]. Mean and
    /// std are computed from bucket representatives (same error contract as
    /// quantiles); min/max are exact.
    pub fn summary(&self) -> Summary {
        let (mut sum, mut sumsq, mut total) = (0.0f64, 0.0f64, 0u64);
        for (i, b) in self.buckets.iter().enumerate() {
            // relaxed: bucket counters are independent statistics (see
            // `quantile`) — summaries are best-effort snapshots.
            let c = b.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            let r = Self::representative(i);
            total += c;
            sum += c as f64 * r;
            sumsq += c as f64 * r * r;
        }
        if total == 0 {
            return Summary::of(&[]);
        }
        let mean = sum / total as f64;
        let var = (sumsq / total as f64 - mean * mean).max(0.0);
        Summary {
            n: total as usize,
            mean,
            std: var.sqrt(),
            min: self.min(),
            median: self.quantile(0.50),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            max: self.max(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;
    use std::sync::Arc;

    #[test]
    fn ln_gamma_constant_matches() {
        assert!((GAMMA.ln() - LN_GAMMA).abs() < 1e-15);
    }

    #[test]
    fn empty_histogram_is_zeroed() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        let s = h.summary();
        assert_eq!(s.n, 0);
        assert_eq!(s.max, 0.0);
    }

    /// Satellite 3: every quantile stays within the advertised relative
    /// error of the exact sorted-sample quantile, on log-uniform data
    /// spanning five decades.
    #[test]
    fn quantile_error_bounded_vs_exact_sort() {
        let mut rng = Pcg32::seeded(0x0b5);
        let h = Histogram::new();
        let n = 10_000usize;
        let mut xs: Vec<f64> = (0..n)
            .map(|_| 10f64.powf(rng.f64() * 5.0 - 1.0)) // 0.1 .. 1e4 µs
            .collect();
        for &v in &xs {
            h.record(v);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.01, 0.10, 0.50, 0.90, 0.95, 0.99, 1.0] {
            let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
            let exact = xs[rank - 1];
            let got = h.quantile(q);
            let rel = (got - exact).abs() / exact;
            assert!(
                rel <= RELATIVE_ERROR + 1e-9,
                "q={q}: got {got}, exact {exact}, rel err {rel}"
            );
        }
        assert_eq!(h.min(), xs[0]);
        assert_eq!(h.max(), xs[n - 1]);
    }

    /// Satellite 3: concurrent writers into one shared histogram lose
    /// nothing, and merging per-thread histograms reproduces the shared
    /// one bucket-for-bucket.
    #[test]
    fn concurrent_writers_and_merge_agree() {
        let shared = Arc::new(Histogram::new());
        let threads = 4;
        let per = 20_000usize;
        let mut locals = Vec::new();
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || {
                    let local = Histogram::new();
                    let mut rng = Pcg32::new(0xC0FFEE, t as u64);
                    for _ in 0..per {
                        let v = 10f64.powf(rng.f64() * 4.0);
                        shared.record(v);
                        local.record(v);
                    }
                    local
                })
            })
            .collect();
        for hd in handles {
            locals.push(hd.join().unwrap());
        }
        let merged = Histogram::new();
        for l in &locals {
            merged.merge(l);
        }
        assert_eq!(shared.count(), (threads * per) as u64);
        assert_eq!(merged.count(), shared.count());
        assert_eq!(merged.min(), shared.min());
        assert_eq!(merged.max(), shared.max());
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(merged.quantile(q), shared.quantile(q));
        }
    }

    #[test]
    fn extremes_clamp_instead_of_poisoning() {
        let h = Histogram::new();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(-5.0);
        h.record(0.0);
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), 0.0);
        // Non-finite and negative values all landed in bucket 0.
        assert!(h.quantile(1.0) <= MIN_VALUE);
    }

    /// Windowed quantiles see only the samples recorded between snapshots —
    /// the property the degrade controller's overload signal rests on
    /// (cumulative p99 barely moves under a fresh burst; the window p99
    /// must).
    #[test]
    fn quantile_between_isolates_the_window() {
        let h = Histogram::new();
        // A long healthy history at ~100.
        for _ in 0..10_000 {
            h.record(100.0);
        }
        let s0 = h.snapshot();
        // A short burst at ~10_000: cumulative p99 stays at the old level,
        // but the window is pure burst.
        for _ in 0..100 {
            h.record(10_000.0);
        }
        let s1 = h.snapshot();
        let cum = h.quantile(0.99);
        assert!(cum < 150.0, "cumulative p99 should stay near 100, got {cum}");
        let win = Histogram::quantile_between(&s0, &s1, 0.99);
        assert!(
            (win / 10_000.0 - 1.0).abs() < 3.0 * RELATIVE_ERROR + 0.02,
            "window p99 must see the burst, got {win}"
        );
        // Empty window → 0.
        assert_eq!(Histogram::quantile_between(&s1, &s1, 0.99), 0.0);
    }
}
