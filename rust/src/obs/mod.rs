//! Observability: stage tracing, histogram metrics and perf history
//! (DESIGN.md §8).
//!
//! Three std-only pieces wired through the request→SIMD-lane path:
//!
//! * [`span`] — per-thread ring-buffer span recording for the serving
//!   pipeline (admission → assemble → flush_plan → queue_wait → claim →
//!   shard_exec → reply), class-tagged on pool workers and exportable as
//!   chrome-tracing JSON. Off by default; disabled cost is one atomic
//!   load per span site.
//! * [`hist`] — log-bucketed atomic histograms (~2% relative error,
//!   mergeable, fixed memory) backing `coordinator::Metrics` and the pool
//!   counters, replacing the old capped `Vec` reservoirs.
//! * [`bench_data`] — append-only per-commit perf history in
//!   `github-action-benchmark` format (`dev/bench/data.js`) plus the
//!   rolling-median regression gate behind `bench --gate`.

pub mod bench_data;
pub mod hist;
pub mod span;

pub use hist::Histogram;
pub use span::SpanTimer;
