//! Per-commit perf history (`dev/bench/data.js`) and the regression gate
//! (DESIGN.md §8).
//!
//! History is stored in the `github-action-benchmark` format — a single
//! tracked file assigning `window.BENCHMARK_DATA = {...}` so the same
//! file doubles as data for a static dashboard page. `bench --exp smoke`
//! (and any experiment that opts in) appends one entry per run, stamped
//! with the current commit; `bench --gate` compares every series' newest
//! value against the rolling median of its last [`GATE_WINDOW`] prior
//! entries and fails on a >[`GATE_THRESHOLD`] regression. The direction
//! of "worse" is inferred from the unit: throughput units (containing
//! `/s`) regress downward, everything else (latency) regresses upward.
//!
//! The file location defaults to `<repo root>/dev/bench/data.js` and is
//! overridable with `ARBORS_BENCH_DATA` (CI smoke runs point it at a temp
//! path so doc checks never dirty the tracked history).

use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::process::Command;

use crate::util::Json;

/// History file location relative to the repository root.
pub const DEFAULT_REL_PATH: &str = "dev/bench/data.js";

/// Fail the gate when a series is worse than its rolling median by more
/// than this fraction.
pub const GATE_THRESHOLD: f64 = 0.15;

/// Rolling-median window: prior entries considered per series.
pub const GATE_WINDOW: usize = 5;

const PREFIX: &str = "window.BENCHMARK_DATA = ";

/// Required fields of every entry, entry `commit` object and bench record
/// in the github-action-benchmark schema. Schema tests iterate these
/// (satellite 6: assertions derive from the source of truth, not
/// re-typed literals).
pub const ENTRY_FIELDS: [&str; 4] = ["commit", "date", "tool", "benches"];
pub const COMMIT_FIELDS: [&str; 8] =
    ["author", "committer", "distinct", "id", "message", "timestamp", "tree_id", "url"];
pub const BENCH_FIELDS: [&str; 4] = ["name", "value", "range", "unit"];

/// One measurement appended to a series.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub name: String,
    pub value: f64,
    /// Spread (one standard deviation), rendered as `"± N"`.
    pub range: f64,
    pub unit: String,
}

impl BenchRecord {
    pub fn new(name: &str, value: f64, range: f64, unit: &str) -> BenchRecord {
        BenchRecord { name: name.to_string(), value, range, unit: unit.to_string() }
    }
}

fn resolve_path(env_override: Option<String>) -> PathBuf {
    if let Some(p) = env_override {
        if !p.is_empty() {
            return PathBuf::from(p);
        }
    }
    match Path::new(env!("CARGO_MANIFEST_DIR")).parent() {
        Some(root) => root.join(DEFAULT_REL_PATH),
        None => PathBuf::from(DEFAULT_REL_PATH),
    }
}

/// `ARBORS_BENCH_DATA` if set, else `<repo root>/dev/bench/data.js`.
pub fn default_path() -> PathBuf {
    resolve_path(std::env::var("ARBORS_BENCH_DATA").ok())
}

fn git(args: &[&str]) -> Option<String> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent()?.to_path_buf();
    let out = Command::new("git").args(args).current_dir(root).output().ok()?;
    if !out.status.success() {
        return None;
    }
    let s = String::from_utf8(out.stdout).ok()?.trim().to_string();
    if s.is_empty() {
        None
    } else {
        Some(s)
    }
}

fn or_unknown(v: Option<String>) -> String {
    v.unwrap_or_else(|| "unknown".to_string())
}

/// Current HEAD in the schema's `commit` shape; every field degrades to
/// `"unknown"` outside a git checkout.
fn commit_json() -> Json {
    let id = or_unknown(git(&["rev-parse", "HEAD"]));
    let tree = or_unknown(git(&["rev-parse", "HEAD^{tree}"]));
    let message = or_unknown(git(&["log", "-1", "--format=%s"]));
    let timestamp = or_unknown(git(&["log", "-1", "--format=%cI"]));
    let name = or_unknown(git(&["log", "-1", "--format=%an"]));
    let email = or_unknown(git(&["log", "-1", "--format=%ae"]));
    let who = |name: &str, email: &str| {
        Json::from_pairs(vec![
            ("email", Json::Str(email.to_string())),
            ("name", Json::Str(name.to_string())),
            ("username", Json::Str(name.to_string())),
        ])
    };
    Json::from_pairs(vec![
        ("author", who(&name, &email)),
        ("committer", who(&name, &email)),
        ("distinct", Json::Bool(true)),
        ("id", Json::Str(id.clone())),
        ("message", Json::Str(message)),
        ("timestamp", Json::Str(timestamp)),
        ("tree_id", Json::Str(tree)),
        ("url", Json::Str(format!("local/commit/{id}"))),
    ])
}

fn skeleton() -> Json {
    Json::from_pairs(vec![
        ("lastUpdate", Json::Num(0.0)),
        ("repoUrl", Json::Str(String::new())),
        ("entries", Json::obj()),
    ])
}

/// Parse an existing history file; a missing or malformed file yields the
/// empty skeleton (history is append-only and self-healing).
pub fn load(path: &Path) -> Json {
    let text = std::fs::read_to_string(path).unwrap_or_default();
    let body = text.trim_start();
    let body = body.strip_prefix(PREFIX).unwrap_or(body);
    let body = body.trim_end().trim_end_matches(';');
    Json::parse(body).unwrap_or_else(|_| skeleton())
}

fn now_epoch_ms() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as f64)
        .unwrap_or(0.0)
}

/// Append one entry (current commit, all `benches`) to `entries[group]`
/// and rewrite the file.
pub fn append(path: &Path, group: &str, benches: &[BenchRecord]) -> anyhow::Result<()> {
    let mut data = load(path);
    let now = now_epoch_ms();
    let bench_arr = Json::Arr(
        benches
            .iter()
            .map(|b| {
                Json::from_pairs(vec![
                    ("name", Json::Str(b.name.clone())),
                    ("value", Json::Num(b.value)),
                    ("range", Json::Str(format!("± {:.4}", b.range))),
                    ("unit", Json::Str(b.unit.clone())),
                ])
            })
            .collect(),
    );
    let entry = Json::from_pairs(vec![
        ("commit", commit_json()),
        ("date", Json::Num(now)),
        ("tool", Json::Str("cargo".to_string())),
        ("benches", bench_arr),
    ]);
    let mut entries = data.get("entries").cloned().unwrap_or_else(Json::obj);
    let mut series: Vec<Json> =
        entries.get(group).and_then(|a| a.as_arr()).map(|s| s.to_vec()).unwrap_or_default();
    series.push(entry);
    entries.set(group, Json::Arr(series));
    data.set("entries", entries);
    data.set("lastUpdate", Json::Num(now));
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow::anyhow!("create {}: {e}", dir.display()))?;
    }
    std::fs::write(path, format!("{PREFIX}{}\n", data.pretty()))
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
    Ok(())
}

/// Validate a parsed history document against the schema (used by tests
/// and `bench --gate`): top-level keys, and every entry / commit / bench
/// field in [`ENTRY_FIELDS`] / [`COMMIT_FIELDS`] / [`BENCH_FIELDS`].
pub fn validate(data: &Json) -> anyhow::Result<()> {
    for k in ["lastUpdate", "repoUrl", "entries"] {
        data.req(k).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    let entries = match data.get("entries") {
        Some(Json::Obj(m)) => m,
        _ => anyhow::bail!("'entries' must be an object"),
    };
    for (group, arr) in entries {
        let arr = arr.as_arr().ok_or_else(|| anyhow::anyhow!("entries['{group}'] not an array"))?;
        for entry in arr {
            for k in ENTRY_FIELDS {
                entry.req(k).map_err(|e| anyhow::anyhow!("entry in '{group}': {e}"))?;
            }
            let commit = entry.req("commit").map_err(|e| anyhow::anyhow!("{e}"))?;
            for k in COMMIT_FIELDS {
                commit.req(k).map_err(|e| anyhow::anyhow!("commit in '{group}': {e}"))?;
            }
            let benches = entry.get("benches").and_then(|b| b.as_arr()).unwrap_or(&[]);
            for b in benches {
                for k in BENCH_FIELDS {
                    b.req(k).map_err(|e| anyhow::anyhow!("bench in '{group}': {e}"))?;
                }
            }
        }
    }
    Ok(())
}

/// Is a bigger value better for this unit? Throughput units (`req/s`,
/// `rows/s`, ...) regress downward; latency/size units regress upward.
fn bigger_is_better(unit: &str) -> bool {
    unit.contains("/s")
}

/// Run the rolling-median regression gate over the history at `path`.
///
/// Returns the per-series report; `Err` lists every series whose newest
/// value is more than [`GATE_THRESHOLD`] worse than the median of its up
/// to [`GATE_WINDOW`] prior entries. Series with fewer than 2 entries
/// pass (no baseline yet).
pub fn gate(path: &Path) -> anyhow::Result<String> {
    let data = load(path);
    validate(&data)?;
    let entries = match data.get("entries") {
        Some(Json::Obj(m)) => m,
        _ => return Ok("perf gate: no history\n".to_string()),
    };
    let mut report = String::new();
    let mut failures: Vec<String> = Vec::new();
    for (group, arr) in entries {
        let arr = arr.as_arr().unwrap_or(&[]);
        // series name -> (values in commit order, unit)
        let mut series: std::collections::BTreeMap<String, (Vec<f64>, String)> =
            std::collections::BTreeMap::new();
        for entry in arr {
            for b in entry.get("benches").and_then(|b| b.as_arr()).unwrap_or(&[]) {
                let name = b.get("name").and_then(|n| n.as_str()).unwrap_or("?").to_string();
                let value = b.get("value").and_then(|v| v.as_f64()).unwrap_or(0.0);
                let unit = b.get("unit").and_then(|u| u.as_str()).unwrap_or("").to_string();
                let slot = series.entry(name).or_insert_with(|| (Vec::new(), unit.clone()));
                slot.0.push(value);
            }
        }
        for (name, (values, unit)) in &series {
            if values.len() < 2 {
                let _ = writeln!(report, "  {group}/{name}: {} entry(s), no baseline", values.len());
                continue;
            }
            let last = *values.last().expect("len >= 2");
            let prior = &values[..values.len() - 1];
            let window = &prior[prior.len().saturating_sub(GATE_WINDOW)..];
            let mut sorted = window.to_vec();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let baseline = crate::util::percentile(&sorted, 0.5);
            if baseline <= 0.0 {
                let _ = writeln!(report, "  {group}/{name}: baseline <= 0, skipped");
                continue;
            }
            let regression = if bigger_is_better(unit) {
                (baseline - last) / baseline
            } else {
                (last - baseline) / baseline
            };
            let verdict = if regression > GATE_THRESHOLD { "FAIL" } else { "ok" };
            let _ = writeln!(
                report,
                "  {group}/{name}: last {last:.4} {unit} vs median({}) {baseline:.4} \
                 — {:+.1}% {verdict}",
                window.len(),
                regression * 100.0,
            );
            if regression > GATE_THRESHOLD {
                failures.push(format!(
                    "{group}/{name} regressed {:.1}% (> {:.0}%)",
                    regression * 100.0,
                    GATE_THRESHOLD * 100.0
                ));
            }
        }
    }
    if failures.is_empty() {
        Ok(report)
    } else {
        anyhow::bail!("perf gate failed:\n  {}\n{report}", failures.join("\n  "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("arbors_bench_{}_{}.js", name, std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn append_roundtrips_and_validates_schema() {
        let path = tmp("roundtrip");
        let recs = [
            BenchRecord::new("serving/shared", 12_345.6, 10.0, "req/s"),
            BenchRecord::new("lat/p99", 880.0, 5.0, "µs/req"),
        ];
        append(&path, "smoke", &recs).unwrap();
        append(&path, "smoke", &recs).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with(PREFIX), "data.js must assign window.BENCHMARK_DATA");
        let data = load(&path);
        // Satellite 6: schema assertions iterate the exported field lists.
        validate(&data).unwrap();
        let smoke = data.get("entries").and_then(|e| e.get("smoke")).unwrap();
        assert_eq!(smoke.as_arr().unwrap().len(), 2);
        assert!(data.get("lastUpdate").and_then(|l| l.as_f64()).unwrap() > 0.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_or_garbage_file_loads_as_skeleton() {
        let path = tmp("skeleton");
        let data = load(&path);
        validate(&data).unwrap();
        std::fs::write(&path, "not json at all").unwrap();
        let data = load(&path);
        validate(&data).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    fn write_history(path: &Path, series: &[(&str, &str, &[f64])]) {
        // Minimal-but-schema-complete history: one entry per index, each
        // carrying every series' i-th value.
        let n = series.iter().map(|(_, _, v)| v.len()).max().unwrap_or(0);
        let mut arr = Vec::new();
        for i in 0..n {
            let benches: Vec<Json> = series
                .iter()
                .filter(|(_, _, v)| i < v.len())
                .map(|(name, unit, v)| {
                    Json::from_pairs(vec![
                        ("name", Json::Str(name.to_string())),
                        ("value", Json::Num(v[i])),
                        ("range", Json::Str("± 0".to_string())),
                        ("unit", Json::Str(unit.to_string())),
                    ])
                })
                .collect();
            arr.push(Json::from_pairs(vec![
                ("commit", commit_json()),
                ("date", Json::Num(i as f64)),
                ("tool", Json::Str("cargo".to_string())),
                ("benches", Json::Arr(benches)),
            ]));
        }
        let mut entries = Json::obj();
        entries.set("smoke", Json::Arr(arr));
        let mut data = skeleton();
        data.set("entries", entries);
        std::fs::write(path, format!("{PREFIX}{}\n", data.pretty())).unwrap();
    }

    /// Acceptance: the gate demonstrably fails on a synthetic 20%
    /// regression and passes within-noise drift, in both unit directions.
    #[test]
    fn gate_fails_synthetic_regression_and_passes_noise() {
        let path = tmp("gate");
        // Latency series (smaller better): 20% up = regression.
        write_history(&path, &[("lat", "µs/req", &[100.0, 100.0, 100.0, 100.0, 100.0, 120.0][..])]);
        assert!(gate(&path).is_err(), "20% latency regression must fail");
        write_history(&path, &[("lat", "µs/req", &[100.0, 100.0, 100.0, 100.0, 100.0, 103.0][..])]);
        gate(&path).expect("3% drift must pass");
        // Throughput series (bigger better): 20% down = regression.
        write_history(&path, &[("thr", "req/s", &[100.0, 100.0, 100.0, 100.0, 100.0, 80.0][..])]);
        assert!(gate(&path).is_err(), "20% throughput drop must fail");
        write_history(&path, &[("thr", "req/s", &[100.0, 100.0, 100.0, 100.0, 100.0, 120.0][..])]);
        gate(&path).expect("throughput improvement must pass");
        // A single entry has no baseline: always passes.
        write_history(&path, &[("new", "µs/req", &[42.0][..])]);
        gate(&path).expect("single entry must pass");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn gate_median_absorbs_one_outlier() {
        let path = tmp("median");
        // One bad historical run must not poison the baseline (mean would).
        write_history(
            &path,
            &[("lat", "µs/req", &[100.0, 100.0, 500.0, 100.0, 100.0, 105.0][..])],
        );
        gate(&path).expect("median baseline must absorb the outlier");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn resolve_path_prefers_env_override() {
        assert_eq!(resolve_path(Some("/tmp/x.js".to_string())), PathBuf::from("/tmp/x.js"));
        let def = resolve_path(None);
        assert!(def.ends_with(DEFAULT_REL_PATH), "default must end with {DEFAULT_REL_PATH}");
        assert_eq!(resolve_path(Some(String::new())), def, "empty override is ignored");
    }
}
