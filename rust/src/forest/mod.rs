//! Additive tree ensembles (paper §2, eq. 1).
//!
//! A [`Forest`] is a sum of trees: `f(x) = Σ_i h_i(x)`. Ensemble weights (RF
//! majority vote `1/M`, boosting learning rate) are **pre-scaled into the leaf
//! values** during construction, exactly as the paper describes in §2, so
//! inference is a plain unweighted sum — "the only arithmetic operation
//! required to execute the entire tree ensemble" (§5).

pub mod builder;
pub mod io;
pub mod tree;

pub use builder::{AdaBoostParams, GbtParams, RfParams, TreeParams};
pub use tree::{Child, Node, Tree};

/// What the ensemble was trained for; decides how raw scores are interpreted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// `n_classes >= 2`, scores are (soft) votes; prediction = argmax.
    Classification,
    /// `n_classes == 1`, score is the ranking/regression output.
    Ranking,
}

impl Task {
    pub fn as_str(&self) -> &'static str {
        match self {
            Task::Classification => "classification",
            Task::Ranking => "ranking",
        }
    }

    pub fn from_str(s: &str) -> Option<Task> {
        match s {
            "classification" => Some(Task::Classification),
            "ranking" => Some(Task::Ranking),
            _ => None,
        }
    }
}

/// An additive ensemble of axis-aligned decision trees.
#[derive(Debug, Clone, PartialEq)]
pub struct Forest {
    pub trees: Vec<Tree>,
    pub n_features: usize,
    pub n_classes: usize,
    pub task: Task,
    /// Added to every prediction (e.g. boosting base score); length
    /// `n_classes`.
    pub base_score: Vec<f32>,
}

impl Forest {
    pub fn new(n_features: usize, n_classes: usize, task: Task) -> Forest {
        Forest { trees: Vec::new(), n_features, n_classes, task, base_score: vec![0.0; n_classes] }
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Maximum leaf count over all trees — the `L` that sizes QuickScorer
    /// bitvectors.
    pub fn max_leaves(&self) -> usize {
        self.trees.iter().map(|t| t.n_leaves).max().unwrap_or(1)
    }

    /// Total inner-node count.
    pub fn n_nodes(&self) -> usize {
        self.trees.iter().map(|t| t.nodes.len()).sum()
    }

    /// Reference prediction for one instance into `out` (len `n_classes`).
    pub fn predict_into(&self, x: &[f32], out: &mut [f32]) {
        out.copy_from_slice(&self.base_score);
        for t in &self.trees {
            t.predict_into(x, out);
        }
    }

    /// Reference prediction for a row-major batch `[n × n_features]`;
    /// returns row-major scores `[n × n_classes]`.
    pub fn predict_batch(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len() % self.n_features, 0);
        let n = x.len() / self.n_features;
        let mut out = vec![0.0f32; n * self.n_classes];
        for i in 0..n {
            self.predict_into(
                &x[i * self.n_features..(i + 1) * self.n_features],
                &mut out[i * self.n_classes..(i + 1) * self.n_classes],
            );
        }
        out
    }

    /// Argmax class per instance from a score matrix.
    pub fn argmax(scores: &[f32], n_classes: usize) -> Vec<u32> {
        scores
            .chunks(n_classes)
            .map(|row| {
                let mut best = 0usize;
                for (c, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = c;
                    }
                }
                best as u32
            })
            .collect()
    }

    /// Classification accuracy of this forest on `(x, labels)`.
    pub fn accuracy(&self, x: &[f32], labels: &[u32]) -> f64 {
        let scores = self.predict_batch(x);
        let preds = Self::argmax(&scores, self.n_classes);
        let correct = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
        correct as f64 / labels.len() as f64
    }

    /// Validate every tree and the forest-level invariants.
    pub fn validate(&self) -> Result<(), String> {
        if self.base_score.len() != self.n_classes {
            return Err("base_score length != n_classes".into());
        }
        for (i, t) in self.trees.iter().enumerate() {
            if t.n_classes != self.n_classes {
                return Err(format!("tree {i}: n_classes {} != {}", t.n_classes, self.n_classes));
            }
            for n in &t.nodes {
                if n.feature as usize >= self.n_features {
                    return Err(format!("tree {i}: feature {} out of range", n.feature));
                }
            }
            t.validate().map_err(|e| format!("tree {i}: {e}"))?;
        }
        Ok(())
    }

    /// Histogram of (min, mean, max) leaf counts — used in reports.
    pub fn leaf_stats(&self) -> (usize, f64, usize) {
        let mut min = usize::MAX;
        let mut max = 0usize;
        let mut sum = 0usize;
        for t in &self.trees {
            min = min.min(t.n_leaves);
            max = max.max(t.n_leaves);
            sum += t.n_leaves;
        }
        if self.trees.is_empty() {
            (0, 0.0, 0)
        } else {
            (min, sum as f64 / self.trees.len() as f64, max)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::tree::{Child, Node};
    use super::*;

    fn two_tree_forest() -> Forest {
        let t1 = Tree {
            nodes: vec![Node {
                feature: 0,
                threshold: 0.5,
                left: Child::Leaf(0),
                right: Child::Leaf(1),
            }],
            leaf_values: vec![1.0, 0.0, 0.0, 1.0],
            n_leaves: 2,
            n_classes: 2,
        };
        let t2 = Tree {
            nodes: vec![Node {
                feature: 1,
                threshold: 0.0,
                left: Child::Leaf(0),
                right: Child::Leaf(1),
            }],
            leaf_values: vec![0.5, 0.5, 0.0, 1.0],
            n_leaves: 2,
            n_classes: 2,
        };
        Forest {
            trees: vec![t1, t2],
            n_features: 2,
            n_classes: 2,
            task: Task::Classification,
            base_score: vec![0.0, 0.0],
        }
    }

    #[test]
    fn forest_sums_trees() {
        let f = two_tree_forest();
        let mut out = vec![0.0; 2];
        f.predict_into(&[0.0, 1.0], &mut out);
        assert_eq!(out, vec![1.0, 1.0]); // t1 -> [1,0], t2 -> [0,1]
    }

    #[test]
    fn batch_matches_single() {
        let f = two_tree_forest();
        let x = vec![0.0, 1.0, 0.9, -1.0];
        let batch = f.predict_batch(&x);
        let mut single = vec![0.0; 2];
        f.predict_into(&x[2..4], &mut single);
        assert_eq!(&batch[2..4], &single[..]);
    }

    #[test]
    fn argmax_ties_pick_first() {
        assert_eq!(Forest::argmax(&[0.5, 0.5, 0.2, 0.7], 2), vec![0, 1]);
    }

    #[test]
    fn validate_catches_bad_feature() {
        let mut f = two_tree_forest();
        f.trees[0].nodes[0].feature = 99;
        assert!(f.validate().is_err());
    }

    #[test]
    fn accuracy_perfect_on_trivial() {
        let f = two_tree_forest();
        // class = 1 iff x1 > 0 for x0<=0.5 region combined with t1
        let x = vec![0.0, -1.0, 0.0, 1.0];
        let acc = f.accuracy(&x, &[0, 1]);
        assert!(acc >= 0.5);
    }

    #[test]
    fn base_score_applied() {
        let mut f = two_tree_forest();
        f.base_score = vec![10.0, 20.0];
        let mut out = vec![0.0; 2];
        f.predict_into(&[0.0, 1.0], &mut out);
        assert_eq!(out, vec![11.0, 21.0]);
    }
}
