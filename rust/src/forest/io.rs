//! Forest (de)serialization.
//!
//! Forests are stored as JSON — one object per forest with flat per-tree
//! arrays — so the same model file is consumed by the Rust engines, the
//! Python AOT pipeline (`python/compile/aot.py --forest`), and the examples.
//! A small binary cache layer keys trained models by their configuration so
//! the benchmark suite trains each forest exactly once.

use std::path::Path;

use super::tree::{Child, Node, Tree};
use super::{Forest, Task};
use crate::util::Json;

/// Encode a child reference: inner nodes as non-negative ids, leaf `l` as
/// `-(l+1)` (a compact convention shared with the Python loader).
fn child_to_num(c: Child) -> f64 {
    match c {
        Child::Inner(i) => i as f64,
        Child::Leaf(l) => -((l as f64) + 1.0),
    }
}

fn num_to_child(n: f64) -> Child {
    if n >= 0.0 {
        Child::Inner(n as u32)
    } else {
        Child::Leaf((-n - 1.0) as u32)
    }
}

/// Serialize a forest to a JSON value.
pub fn forest_to_json(f: &Forest) -> Json {
    let trees: Vec<Json> = f
        .trees
        .iter()
        .map(|t| {
            Json::from_pairs(vec![
                (
                    "feature",
                    Json::Arr(t.nodes.iter().map(|n| Json::Num(n.feature as f64)).collect()),
                ),
                (
                    "threshold",
                    Json::Arr(t.nodes.iter().map(|n| Json::Num(n.threshold as f64)).collect()),
                ),
                ("left", Json::Arr(t.nodes.iter().map(|n| Json::Num(child_to_num(n.left))).collect())),
                (
                    "right",
                    Json::Arr(t.nodes.iter().map(|n| Json::Num(child_to_num(n.right))).collect()),
                ),
                ("leaf_values", Json::array_f32(&t.leaf_values)),
                ("n_leaves", Json::Num(t.n_leaves as f64)),
            ])
        })
        .collect();
    Json::from_pairs(vec![
        ("format", Json::Str("arbors-forest-v1".into())),
        ("task", Json::Str(f.task.as_str().into())),
        ("n_features", Json::Num(f.n_features as f64)),
        ("n_classes", Json::Num(f.n_classes as f64)),
        ("base_score", Json::array_f32(&f.base_score)),
        ("trees", Json::Arr(trees)),
    ])
}

/// Deserialize a forest from a JSON value; validates the result.
pub fn forest_from_json(j: &Json) -> Result<Forest, String> {
    let fmt = j.get("format").and_then(|v| v.as_str()).unwrap_or("");
    if fmt != "arbors-forest-v1" {
        return Err(format!("unknown forest format '{fmt}'"));
    }
    let task = Task::from_str(j.req("task").map_err(|e| e.to_string())?.as_str().unwrap_or(""))
        .ok_or("bad task")?;
    let n_features = j.req("n_features").map_err(|e| e.to_string())?.as_usize().ok_or("n_features")?;
    let n_classes = j.req("n_classes").map_err(|e| e.to_string())?.as_usize().ok_or("n_classes")?;
    let base_score = j.req("base_score").map_err(|e| e.to_string())?.to_f32_vec().ok_or("base_score")?;
    let mut forest = Forest::new(n_features, n_classes, task);
    forest.base_score = base_score;

    for (ti, tj) in j.req("trees").map_err(|e| e.to_string())?.as_arr().ok_or("trees")?.iter().enumerate()
    {
        let feature = tj.req("feature").map_err(|e| e.to_string())?.to_usize_vec().ok_or("feature")?;
        let threshold = tj.req("threshold").map_err(|e| e.to_string())?.to_f32_vec().ok_or("threshold")?;
        let left: Vec<f64> = tj
            .req("left")
            .map_err(|e| e.to_string())?
            .as_arr()
            .ok_or("left")?
            .iter()
            .map(|v| v.as_f64().ok_or("left"))
            .collect::<Result<_, _>>()?;
        let right: Vec<f64> = tj
            .req("right")
            .map_err(|e| e.to_string())?
            .as_arr()
            .ok_or("right")?
            .iter()
            .map(|v| v.as_f64().ok_or("right"))
            .collect::<Result<_, _>>()?;
        let leaf_values = tj.req("leaf_values").map_err(|e| e.to_string())?.to_f32_vec().ok_or("leaf_values")?;
        let n_leaves = tj.req("n_leaves").map_err(|e| e.to_string())?.as_usize().ok_or("n_leaves")?;
        if feature.len() != threshold.len() || feature.len() != left.len() || feature.len() != right.len() {
            return Err(format!("tree {ti}: ragged node arrays"));
        }
        let nodes: Vec<Node> = (0..feature.len())
            .map(|i| Node {
                feature: feature[i] as u32,
                threshold: threshold[i],
                left: num_to_child(left[i]),
                right: num_to_child(right[i]),
            })
            .collect();
        forest.trees.push(Tree { nodes, leaf_values, n_leaves, n_classes });
    }
    forest.validate()?;
    Ok(forest)
}

/// Save a forest to a file (compact JSON).
pub fn save(f: &Forest, path: &Path) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, forest_to_json(f).dump())?;
    Ok(())
}

/// Load a forest from a file.
pub fn load(path: &Path) -> anyhow::Result<Forest> {
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
    forest_from_json(&j).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))
}

/// Load from cache or train-and-save: the bench suite's "train once" helper.
pub fn cached<F: FnOnce() -> Forest>(cache_dir: &Path, key: &str, train: F) -> Forest {
    let path = cache_dir.join(format!("{key}.json"));
    if path.exists() {
        if let Ok(f) = load(&path) {
            return f;
        }
        // Corrupt cache entry: retrain below.
    }
    let f = train();
    if let Err(e) = save(&f, &path) {
        eprintln!("warning: could not cache model {key}: {e}");
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::builder::{train_random_forest, RfParams};
    use crate::util::Pcg32;

    fn small_forest() -> Forest {
        let mut rng = Pcg32::seeded(21);
        let n = 120;
        let d = 4;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let label = rng.below(3) as u32;
            for f in 0..d {
                x.push(rng.f32() + if f == 0 { label as f32 } else { 0.0 });
            }
            y.push(label);
        }
        train_random_forest(&x, &y, d, 3, RfParams { n_trees: 5, ..Default::default() })
    }

    #[test]
    fn json_roundtrip_exact() {
        let f = small_forest();
        let j = forest_to_json(&f);
        let f2 = forest_from_json(&j).unwrap();
        // Thresholds go through f64 in JSON; f32 -> f64 -> f32 is exact.
        assert_eq!(f, f2);
    }

    #[test]
    fn file_roundtrip() {
        let f = small_forest();
        let dir = std::env::temp_dir().join("arbors_io_test");
        let path = dir.join("forest.json");
        save(&f, &path).unwrap();
        let f2 = load(&path).unwrap();
        assert_eq!(f, f2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_format() {
        let j = Json::parse(r#"{"format": "nope"}"#).unwrap();
        assert!(forest_from_json(&j).is_err());
    }

    #[test]
    fn cached_trains_once() {
        let dir = std::env::temp_dir().join(format!("arbors_cache_{}", std::process::id()));
        let mut calls = 0;
        let f1 = cached(&dir, "k", || {
            calls += 1;
            small_forest()
        });
        let f2 = cached(&dir, "k", || {
            calls += 1;
            small_forest()
        });
        assert_eq!(calls, 1);
        assert_eq!(f1, f2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn child_encoding_roundtrip() {
        for c in [Child::Inner(0), Child::Inner(7), Child::Leaf(0), Child::Leaf(31)] {
            assert_eq!(num_to_child(child_to_num(c)), c);
        }
    }
}
