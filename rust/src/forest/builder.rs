//! Forest trainers: CART, Random Forest, gradient-boosted trees.
//!
//! The paper trains its models with scikit-learn (Random Forests for
//! classification) and XGBoost (gradient-boosted trees for MSN ranking); this
//! module provides equivalent from-scratch trainers, since only the
//! *pre-trained model artifact* matters for inference benchmarking
//! (DESIGN.md §1 "Substitutions").
//!
//! Trees are grown **best-first** (highest impurity decrease next), bounded
//! by `max_leaves` — the same growth strategy as scikit-learn's
//! `max_leaf_nodes` and LightGBM's `num_leaves`, and the one that produces the
//! paper's "at most {32, 64} leaves" forests. Split thresholds are midpoints
//! between consecutive distinct feature values, so threshold distributions
//! (and therefore RapidScorer node-merging behaviour, Table 4) match
//! exact-split trainers rather than histogram-binned ones.

use super::tree::{Child, Node, Tree};
use super::{Forest, Task};
use crate::util::Pcg32;

/// Per-tree growth parameters.
#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    /// Maximum number of leaves (best-first growth stops here).
    pub max_leaves: usize,
    /// Minimum samples on each side of a split.
    pub min_samples_leaf: usize,
    /// Number of candidate features per split; `0` means all features
    /// (boosting default). Random Forests use `sqrt(d)`.
    pub mtry: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams { max_leaves: 64, min_samples_leaf: 1, mtry: 0 }
    }
}

/// Random-Forest training parameters.
#[derive(Debug, Clone, Copy)]
pub struct RfParams {
    pub n_trees: usize,
    pub tree: TreeParams,
    /// Bootstrap sample size as a fraction of N.
    pub bootstrap_frac: f64,
    pub seed: u64,
}

impl Default for RfParams {
    fn default() -> Self {
        RfParams { n_trees: 128, tree: TreeParams::default(), bootstrap_frac: 1.0, seed: 0x5eed }
    }
}

/// Gradient-boosting parameters (squared loss, pointwise — the setup the
/// paper's MSN ranking forests approximate).
#[derive(Debug, Clone, Copy)]
pub struct GbtParams {
    pub n_trees: usize,
    pub tree: TreeParams,
    pub learning_rate: f32,
    /// Row subsample per boosting round (stochastic gradient boosting).
    pub subsample: f64,
    pub seed: u64,
}

impl Default for GbtParams {
    fn default() -> Self {
        GbtParams {
            n_trees: 100,
            tree: TreeParams { max_leaves: 64, min_samples_leaf: 1, mtry: 0 },
            learning_rate: 0.1,
            subsample: 1.0,
            seed: 0xb005,
        }
    }
}

// ---------------------------------------------------------------------------
// CART (single tree, best-first)
// ---------------------------------------------------------------------------

/// Node in the growth arena (pre leaf-renumbering).
enum Grown {
    Leaf { value: Vec<f32> },
    Split { feature: u32, threshold: f32, left: usize, right: usize },
}

struct Candidate {
    arena_slot: usize,
    samples: Vec<u32>,
    gain: f64,
    feature: u32,
    threshold: f32,
}

/// Target abstraction so one grower serves both gini classification and
/// mse regression.
trait Target {
    /// Leaf prediction vector for the given samples.
    fn leaf_value(&self, samples: &[u32]) -> Vec<f32>;
    /// Impurity * n for the given samples (so gain = parent - left - right).
    /// Exposed for diagnostics; split search uses the fused incremental
    /// version in `best_split`.
    #[allow(dead_code)]
    fn weighted_impurity(&self, samples: &[u32]) -> f64;
    /// Best split of `samples` on `feature`: returns (gain, threshold).
    fn best_split(&self, xcol: impl Fn(u32) -> f32, samples: &[u32], min_leaf: usize)
        -> Option<(f64, f32)>;
}

/// Gini-impurity classification target; leaf value = class distribution
/// scaled by `leaf_scale` (RF pre-scales the 1/M vote weight into leaves).
struct GiniTarget<'a> {
    labels: &'a [u32],
    n_classes: usize,
    leaf_scale: f32,
}

impl Target for GiniTarget<'_> {
    fn leaf_value(&self, samples: &[u32]) -> Vec<f32> {
        let mut counts = vec![0f64; self.n_classes];
        for &s in samples {
            counts[self.labels[s as usize] as usize] += 1.0;
        }
        let total = samples.len() as f64;
        counts.iter().map(|&c| (c / total) as f32 * self.leaf_scale).collect()
    }

    fn weighted_impurity(&self, samples: &[u32]) -> f64 {
        let mut counts = vec![0f64; self.n_classes];
        for &s in samples {
            counts[self.labels[s as usize] as usize] += 1.0;
        }
        let n = samples.len() as f64;
        let sq: f64 = counts.iter().map(|c| c * c).sum();
        n - sq / n // n * gini
    }

    fn best_split(
        &self,
        xcol: impl Fn(u32) -> f32,
        samples: &[u32],
        min_leaf: usize,
    ) -> Option<(f64, f32)> {
        let n = samples.len();
        let mut vals: Vec<(f32, u32)> =
            samples.iter().map(|&s| (xcol(s), self.labels[s as usize])).collect();
        vals.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        let mut total = vec![0f64; self.n_classes];
        for &(_, l) in &vals {
            total[l as usize] += 1.0;
        }
        let total_sq: f64 = total.iter().map(|c| c * c).sum();
        let parent = n as f64 - total_sq / n as f64;

        let mut left = vec![0f64; self.n_classes];
        let mut left_sq = 0f64;
        let mut best: Option<(f64, f32)> = None;
        for i in 0..n - 1 {
            let l = vals[i].1 as usize;
            // Incremental sum-of-squares update.
            left_sq += 2.0 * left[l] + 1.0;
            left[l] += 1.0;
            if vals[i].0 == vals[i + 1].0 {
                continue; // can't split between equal values
            }
            let nl = (i + 1) as f64;
            let nr = (n - i - 1) as f64;
            if (i + 1) < min_leaf || (n - i - 1) < min_leaf {
                continue;
            }
            // right counts sq = sum (total-left)^2 = total_sq - 2*dot + left_sq
            let dot: f64 = total.iter().zip(&left).map(|(t, l)| t * l).sum();
            let right_sq = total_sq - 2.0 * dot + left_sq;
            let child = nl - left_sq / nl + nr - right_sq / nr;
            let gain = parent - child;
            let thr = midpoint(vals[i].0, vals[i + 1].0);
            if best.map_or(true, |(g, _)| gain > g) {
                best = Some((gain, thr));
            }
        }
        best.filter(|&(g, _)| g > 1e-12)
    }
}

/// Variance-reduction regression target (squared loss); leaf value =
/// `leaf_scale * mean(target)`.
struct MseTarget<'a> {
    y: &'a [f32],
    leaf_scale: f32,
}

impl Target for MseTarget<'_> {
    fn leaf_value(&self, samples: &[u32]) -> Vec<f32> {
        let sum: f64 = samples.iter().map(|&s| self.y[s as usize] as f64).sum();
        vec![(sum / samples.len() as f64) as f32 * self.leaf_scale]
    }

    fn weighted_impurity(&self, samples: &[u32]) -> f64 {
        let n = samples.len() as f64;
        let sum: f64 = samples.iter().map(|&s| self.y[s as usize] as f64).sum();
        let sq: f64 = samples.iter().map(|&s| (self.y[s as usize] as f64).powi(2)).sum();
        sq - sum * sum / n // n * variance
    }

    fn best_split(
        &self,
        xcol: impl Fn(u32) -> f32,
        samples: &[u32],
        min_leaf: usize,
    ) -> Option<(f64, f32)> {
        let n = samples.len();
        let mut vals: Vec<(f32, f32)> =
            samples.iter().map(|&s| (xcol(s), self.y[s as usize])).collect();
        vals.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        let total_sum: f64 = vals.iter().map(|&(_, y)| y as f64).sum();
        let total_sq: f64 = vals.iter().map(|&(_, y)| (y as f64).powi(2)).sum();
        let parent = total_sq - total_sum * total_sum / n as f64;

        let mut lsum = 0f64;
        let mut lsq = 0f64;
        let mut best: Option<(f64, f32)> = None;
        for i in 0..n - 1 {
            let y = vals[i].1 as f64;
            lsum += y;
            lsq += y * y;
            if vals[i].0 == vals[i + 1].0 {
                continue;
            }
            let nl = (i + 1) as f64;
            let nr = (n - i - 1) as f64;
            if (i + 1) < min_leaf || (n - i - 1) < min_leaf {
                continue;
            }
            let rsum = total_sum - lsum;
            let rsq = total_sq - lsq;
            let child = (lsq - lsum * lsum / nl) + (rsq - rsum * rsum / nr);
            let gain = parent - child;
            let thr = midpoint(vals[i].0, vals[i + 1].0);
            if best.map_or(true, |(g, _)| gain > g) {
                best = Some((gain, thr));
            }
        }
        best.filter(|&(g, _)| g > 1e-12)
    }
}

fn midpoint(a: f32, b: f32) -> f32 {
    let m = a + (b - a) * 0.5;
    // Guard against rounding collapsing the midpoint onto `b` (split is
    // `x <= t`, so t must be < b to separate the two).
    if m >= b {
        a
    } else {
        m
    }
}

/// Grow one tree with best-first expansion; generic over the target.
fn grow_tree<T: Target>(
    x: &[f32],
    n_features: usize,
    target: &T,
    samples: Vec<u32>,
    params: TreeParams,
    rng: &mut Pcg32,
) -> Tree {
    let xcol = |f: u32| move |s: u32| x[s as usize * n_features + f as usize];

    let mut arena: Vec<Grown> = Vec::new();
    // Best-first frontier (simple vec-scan max; frontier is tiny: <= leaves).
    let mut frontier: Vec<Candidate> = Vec::new();
    let mut n_leaves = 1usize;

    arena.push(Grown::Leaf { value: target.leaf_value(&samples) });
    if let Some(c) = make_candidate(x, n_features, target, 0, samples, params, rng) {
        frontier.push(c);
    }

    while n_leaves < params.max_leaves {
        // Pop highest-gain candidate.
        let Some(best_idx) = frontier
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.gain.partial_cmp(&b.1.gain).unwrap())
            .map(|(i, _)| i)
        else {
            break;
        };
        let cand = frontier.swap_remove(best_idx);

        // Partition samples.
        let f = cand.feature;
        let t = cand.threshold;
        let (ls, rs): (Vec<u32>, Vec<u32>) =
            cand.samples.iter().partition(|&&s| xcol(f)(s) <= t);
        debug_assert!(!ls.is_empty() && !rs.is_empty());

        let li = arena.len();
        arena.push(Grown::Leaf { value: target.leaf_value(&ls) });
        let ri = arena.len();
        arena.push(Grown::Leaf { value: target.leaf_value(&rs) });
        arena[cand.arena_slot] =
            Grown::Split { feature: f, threshold: t, left: li, right: ri };
        n_leaves += 1;

        if let Some(c) = make_candidate(x, n_features, target, li, ls, params, rng) {
            frontier.push(c);
        }
        if let Some(c) = make_candidate(x, n_features, target, ri, rs, params, rng) {
            frontier.push(c);
        }
    }

    arena_to_tree(&arena)
}

fn make_candidate<T: Target>(
    x: &[f32],
    n_features: usize,
    target: &T,
    arena_slot: usize,
    samples: Vec<u32>,
    params: TreeParams,
    rng: &mut Pcg32,
) -> Option<Candidate> {
    if samples.len() < 2 * params.min_samples_leaf.max(1) {
        return None;
    }
    let mtry = if params.mtry == 0 { n_features } else { params.mtry.min(n_features) };
    let feats: Vec<usize> = if mtry == n_features {
        (0..n_features).collect()
    } else {
        rng.sample_indices(n_features, mtry)
    };
    let mut best: Option<(f64, u32, f32)> = None;
    for f in feats {
        let col = |s: u32| x[s as usize * n_features + f];
        if let Some((gain, thr)) = target.best_split(col, &samples, params.min_samples_leaf) {
            if best.map_or(true, |(g, _, _)| gain > g) {
                best = Some((gain, f as u32, thr));
            }
        }
    }
    best.map(|(gain, feature, threshold)| Candidate {
        arena_slot,
        samples,
        gain,
        feature,
        threshold,
    })
}

/// Convert the growth arena into the canonical [`Tree`] representation with
/// left-to-right leaf numbering.
fn arena_to_tree(arena: &[Grown]) -> Tree {
    let n_classes = match &arena[0] {
        Grown::Leaf { value } => value.len(),
        _ => arena
            .iter()
            .find_map(|g| match g {
                Grown::Leaf { value } => Some(value.len()),
                _ => None,
            })
            .unwrap(),
    };
    let mut nodes: Vec<Node> = Vec::new();
    let mut leaf_values: Vec<f32> = Vec::new();
    let mut n_leaves = 0u32;

    fn convert(
        arena: &[Grown],
        slot: usize,
        nodes: &mut Vec<Node>,
        leaf_values: &mut Vec<f32>,
        n_leaves: &mut u32,
    ) -> Child {
        match &arena[slot] {
            Grown::Leaf { value } => {
                let id = *n_leaves;
                *n_leaves += 1;
                leaf_values.extend_from_slice(value);
                Child::Leaf(id)
            }
            Grown::Split { feature, threshold, left, right } => {
                let idx = nodes.len();
                nodes.push(Node {
                    feature: *feature,
                    threshold: *threshold,
                    left: Child::Leaf(u32::MAX), // patched below
                    right: Child::Leaf(u32::MAX),
                });
                let l = convert(arena, *left, nodes, leaf_values, n_leaves);
                let r = convert(arena, *right, nodes, leaf_values, n_leaves);
                nodes[idx].left = l;
                nodes[idx].right = r;
                Child::Inner(idx as u32)
            }
        }
    }

    convert(arena, 0, &mut nodes, &mut leaf_values, &mut n_leaves);
    Tree { nodes, leaf_values, n_leaves: n_leaves as usize, n_classes }
}

// ---------------------------------------------------------------------------
// Random Forest
// ---------------------------------------------------------------------------

/// Train a Random Forest classifier. Leaf values are class-probability
/// vectors pre-scaled by `1/n_trees`, so the forest sum is the ensemble's
/// soft majority vote (paper §2).
pub fn train_random_forest(
    x: &[f32],
    labels: &[u32],
    n_features: usize,
    n_classes: usize,
    params: RfParams,
) -> Forest {
    assert_eq!(x.len(), labels.len() * n_features);
    let n = labels.len();
    let mut rng = Pcg32::seeded(params.seed);
    let mut forest = Forest::new(n_features, n_classes, Task::Classification);
    let mtry = if params.tree.mtry == 0 {
        (n_features as f64).sqrt().ceil() as usize
    } else {
        params.tree.mtry
    };
    let tree_params = TreeParams { mtry, ..params.tree };
    let leaf_scale = 1.0 / params.n_trees as f32;
    let boot = ((n as f64) * params.bootstrap_frac).round().max(1.0) as usize;

    for _ in 0..params.n_trees {
        let mut trng = rng.split();
        let samples: Vec<u32> = (0..boot).map(|_| trng.below(n) as u32).collect();
        let target = GiniTarget { labels, n_classes, leaf_scale };
        let tree = grow_tree(x, n_features, &target, samples, tree_params, &mut trng);
        forest.trees.push(tree);
    }
    forest
}

// ---------------------------------------------------------------------------
// Gradient boosting (squared loss)
// ---------------------------------------------------------------------------

/// Train gradient-boosted regression trees on scalar targets (used for the
/// MSN-style ranking experiments; graded relevance is regressed pointwise).
/// Learning rate is pre-scaled into leaf values; `base_score` is the target
/// mean.
pub fn train_gbt(x: &[f32], y: &[f32], n_features: usize, params: GbtParams) -> Forest {
    assert_eq!(x.len(), y.len() * n_features);
    let n = y.len();
    let mut rng = Pcg32::seeded(params.seed);
    let mut forest = Forest::new(n_features, 1, Task::Ranking);

    let base = y.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
    forest.base_score = vec![base as f32];

    // Current prediction per sample.
    let mut pred = vec![base as f32; n];
    let mut residual = vec![0f32; n];

    for _ in 0..params.n_trees {
        let mut trng = rng.split();
        for i in 0..n {
            residual[i] = y[i] - pred[i];
        }
        let samples: Vec<u32> = if params.subsample >= 1.0 {
            (0..n as u32).collect()
        } else {
            let k = ((n as f64) * params.subsample).round().max(2.0) as usize;
            trng.sample_indices(n, k).into_iter().map(|i| i as u32).collect()
        };
        let target = MseTarget { y: &residual, leaf_scale: params.learning_rate };
        let tree = grow_tree(x, n_features, &target, samples, params.tree, &mut trng);
        // Update predictions with the new (already lr-scaled) tree.
        for i in 0..n {
            let mut out = [0f32];
            tree.predict_into(&x[i * n_features..(i + 1) * n_features], &mut out);
            pred[i] += out[0];
        }
        forest.trees.push(tree);
    }
    forest
}


// ---------------------------------------------------------------------------
// AdaBoost (SAMME)
// ---------------------------------------------------------------------------

/// AdaBoost parameters (SAMME, resampling variant).
#[derive(Debug, Clone, Copy)]
pub struct AdaBoostParams {
    pub n_trees: usize,
    pub tree: TreeParams,
    pub seed: u64,
}

impl Default for AdaBoostParams {
    fn default() -> Self {
        AdaBoostParams {
            n_trees: 64,
            tree: TreeParams { max_leaves: 8, min_samples_leaf: 1, mtry: 0 },
            seed: 0xada,
        }
    }
}

/// Train an AdaBoost.SAMME classifier — the paper's §2 "weighted ensemble"
/// case (`f(x) = Σ w_i h'_i(x)`): each round trains a shallow tree on a
/// weight-resampled bootstrap, and the stage weight `α_m` is **pre-scaled
/// into the leaf values** (leaf vector = α_m · onehot(leaf majority class)),
/// so inference stays the plain unweighted sum every engine implements.
pub fn train_adaboost(
    x: &[f32],
    labels: &[u32],
    n_features: usize,
    n_classes: usize,
    params: AdaBoostParams,
) -> Forest {
    assert!(n_classes >= 2);
    let n = labels.len();
    let mut rng = Pcg32::seeded(params.seed);
    let mut forest = Forest::new(n_features, n_classes, Task::Classification);
    let mut weights = vec![1.0f64 / n as f64; n];

    for _ in 0..params.n_trees {
        let mut trng = rng.split();
        // Weighted resampling via the cumulative distribution.
        let mut cum = Vec::with_capacity(n);
        let mut acc = 0f64;
        for &w in &weights {
            acc += w;
            cum.push(acc);
        }
        let total = acc.max(1e-300);
        let samples: Vec<u32> = (0..n)
            .map(|_| {
                let u = trng.f64() * total;
                cum.partition_point(|&c| c < u).min(n - 1) as u32
            })
            .collect();

        // Unit-scale tree on the resample; gini target.
        let target = GiniTarget { labels, n_classes, leaf_scale: 1.0 };
        let tree = grow_tree(x, n_features, &target, samples, params.tree, &mut trng);

        // Weighted error of the hard prediction on the full set.
        let mut predicted = vec![0u32; n];
        let mut err = 0f64;
        for i in 0..n {
            let leaf = tree.exit_leaf(&x[i * n_features..(i + 1) * n_features]);
            let row = tree.leaf_row(leaf);
            let mut best = 0usize;
            for (c, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = c;
                }
            }
            predicted[i] = best as u32;
            if predicted[i] != labels[i] {
                err += weights[i];
            }
        }
        err = err.clamp(1e-10, 1.0 - 1e-10);
        let alpha = (((1.0 - err) / err).ln() + ((n_classes - 1) as f64).ln()).max(0.0);
        if alpha == 0.0 {
            continue; // worse than chance: skip this stage (weights untouched)
        }

        // Re-weight: misclassified samples up by e^alpha; renormalize.
        for i in 0..n {
            if predicted[i] != labels[i] {
                weights[i] *= alpha.exp();
            }
        }
        let z: f64 = weights.iter().sum();
        weights.iter_mut().for_each(|w| *w /= z);

        // Stage tree: leaves become alpha * onehot(majority class).
        let mut stage = tree;
        let mut new_leaves = vec![0f32; stage.n_leaves * n_classes];
        for leaf in 0..stage.n_leaves {
            let row = stage.leaf_row(leaf);
            let mut best = 0usize;
            for (c, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = c;
                }
            }
            new_leaves[leaf * n_classes + best] = alpha as f32;
        }
        stage.leaf_values = new_leaves;
        forest.trees.push(stage);
    }
    forest
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    /// Tiny 2-class dataset separable on feature 0.
    fn toy_classification(n: usize) -> (Vec<f32>, Vec<u32>) {
        let mut rng = Pcg32::seeded(99);
        let mut x = Vec::with_capacity(n * 3);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let label = rng.below(2) as u32;
            let f0 = if label == 0 { rng.f32() * 0.4 } else { 0.6 + rng.f32() * 0.4 };
            x.extend_from_slice(&[f0, rng.f32(), rng.f32()]);
            y.push(label);
        }
        (x, y)
    }

    #[test]
    fn rf_learns_separable_data() {
        let (x, y) = toy_classification(400);
        let params = RfParams {
            n_trees: 16,
            tree: TreeParams { max_leaves: 8, min_samples_leaf: 1, mtry: 0 },
            ..Default::default()
        };
        let f = train_random_forest(&x, &y, 3, 2, params);
        assert_eq!(f.n_trees(), 16);
        f.validate().unwrap();
        assert!(f.accuracy(&x, &y) > 0.95, "acc = {}", f.accuracy(&x, &y));
    }

    #[test]
    fn rf_respects_max_leaves() {
        let (x, y) = toy_classification(300);
        let params = RfParams {
            n_trees: 8,
            tree: TreeParams { max_leaves: 4, min_samples_leaf: 1, mtry: 0 },
            ..Default::default()
        };
        let f = train_random_forest(&x, &y, 3, 2, params);
        assert!(f.trees.iter().all(|t| t.n_leaves <= 4));
    }

    #[test]
    fn rf_leaf_values_sum_to_vote() {
        // With leaf scale 1/M, summed class scores are a probability dist.
        let (x, y) = toy_classification(200);
        let f = train_random_forest(
            &x,
            &y,
            3,
            2,
            RfParams { n_trees: 8, ..Default::default() },
        );
        let scores = f.predict_batch(&x[..3 * 5]);
        for row in scores.chunks(2) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row sums to {s}");
        }
    }

    #[test]
    fn gbt_fits_linear_target() {
        let mut rng = Pcg32::seeded(4);
        let n = 500;
        let mut x = Vec::with_capacity(n * 2);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let a = rng.f32();
            let b = rng.f32();
            x.extend_from_slice(&[a, b]);
            y.push(2.0 * a - b);
        }
        let params = GbtParams {
            n_trees: 60,
            tree: TreeParams { max_leaves: 8, min_samples_leaf: 2, mtry: 0 },
            learning_rate: 0.2,
            ..Default::default()
        };
        let f = train_gbt(&x, &y, 2, params);
        f.validate().unwrap();
        let pred = f.predict_batch(&x);
        let mse: f64 = pred
            .iter()
            .zip(&y)
            .map(|(&p, &t)| ((p - t) as f64).powi(2))
            .sum::<f64>()
            / n as f64;
        assert!(mse < 0.01, "mse = {mse}");
    }

    #[test]
    fn trees_are_valid_and_leaves_in_order() {
        let (x, y) = toy_classification(300);
        let f = train_random_forest(
            &x,
            &y,
            3,
            2,
            RfParams { n_trees: 4, ..Default::default() },
        );
        for t in &f.trees {
            t.validate().unwrap();
            // left ranges must be computable (asserts in-order numbering)
            let _ = t.left_leaf_ranges();
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = toy_classification(200);
        let p = RfParams { n_trees: 4, seed: 7, ..Default::default() };
        let f1 = train_random_forest(&x, &y, 3, 2, p);
        let f2 = train_random_forest(&x, &y, 3, 2, p);
        assert_eq!(f1, f2);
    }


    #[test]
    fn adaboost_learns_separable_data() {
        let (x, y) = toy_classification(500);
        let f = train_adaboost(
            &x,
            &y,
            3,
            2,
            AdaBoostParams {
                n_trees: 24,
                tree: TreeParams { max_leaves: 4, min_samples_leaf: 2, mtry: 0 },
                seed: 1,
            },
        );
        f.validate().unwrap();
        assert!(f.n_trees() > 0);
        let acc = f.accuracy(&x, &y);
        assert!(acc > 0.95, "acc {acc}");
    }

    #[test]
    fn adaboost_leaves_are_alpha_onehot() {
        let (x, y) = toy_classification(300);
        let f = train_adaboost(&x, &y, 3, 2, AdaBoostParams::default());
        for t in &f.trees {
            for leaf in 0..t.n_leaves {
                let row = t.leaf_row(leaf);
                let nonzero = row.iter().filter(|&&v| v != 0.0).count();
                assert!(nonzero <= 1, "leaf must be alpha * onehot: {row:?}");
                assert!(row.iter().all(|&v| v >= 0.0));
            }
        }
    }

    #[test]
    fn adaboost_engines_agree() {
        // The weighted ensemble runs through the same engines untouched.
        let (x, y) = toy_classification(300);
        let f = train_adaboost(&x, &y, 3, 2, AdaBoostParams::default());
        let want = f.predict_batch(&x[..3 * 50]);
        for kind in crate::engine::EngineKind::ALL {
            let e = crate::engine::build(kind, crate::engine::Precision::F32, &f, None).unwrap();
            let got = e.predict(&x[..3 * 50]);
            crate::testing::assert_close(&got, &want, 1e-4, 1e-4)
                .unwrap_or_else(|m| panic!("{}: {m}", kind.short()));
        }
    }

    #[test]
    fn midpoint_never_reaches_upper() {
        let a = 1.0f32;
        let b = a + f32::EPSILON;
        let m = midpoint(a, b);
        assert!(m < b);
    }
}
