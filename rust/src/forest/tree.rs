//! Axis-aligned binary decision trees (paper §2, eq. 2).
//!
//! A tree is stored as a flat array of inner nodes plus a flat leaf-value
//! table. Leaves are numbered **left-to-right** (in-order over the tree
//! structure); this ordering is what makes the QuickScorer bitvector encoding
//! work: the exit leaf is the *leftmost* leaf not masked out, i.e. the lowest
//! set bit when leaf `i` maps to bit `i`.

/// Child reference: either another inner node or a leaf id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Child {
    /// Index into [`Tree::nodes`].
    Inner(u32),
    /// Index into the leaf table (`0..n_leaves`).
    Leaf(u32),
}

/// An inner node performing the axis-aligned split `x[feature] <= threshold`
/// (true ⇒ go left, false ⇒ go right — the paper's `1{x_k ≤ t}` convention).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Node {
    pub feature: u32,
    pub threshold: f32,
    pub left: Child,
    pub right: Child,
}

/// A single decision tree with `C`-dimensional leaf predictions.
///
/// `leaf_values` is row-major `[n_leaves × n_classes]`. A degenerate tree with
/// no inner nodes has exactly one leaf.
#[derive(Debug, Clone, PartialEq)]
pub struct Tree {
    pub nodes: Vec<Node>,
    pub leaf_values: Vec<f32>,
    pub n_leaves: usize,
    pub n_classes: usize,
}

impl Tree {
    /// A single-leaf tree predicting `value`.
    pub fn leaf(value: Vec<f32>) -> Tree {
        let n_classes = value.len();
        Tree { nodes: Vec::new(), leaf_values: value, n_leaves: 1, n_classes }
    }

    /// Leaf prediction row.
    #[inline]
    pub fn leaf_row(&self, leaf: usize) -> &[f32] {
        &self.leaf_values[leaf * self.n_classes..(leaf + 1) * self.n_classes]
    }

    /// Walk the tree for one instance; returns the exit-leaf id.
    ///
    /// This is the *oracle* traversal every optimized engine is tested
    /// against.
    pub fn exit_leaf(&self, x: &[f32]) -> usize {
        if self.nodes.is_empty() {
            return 0;
        }
        let mut cur = Child::Inner(0);
        loop {
            match cur {
                Child::Leaf(l) => return l as usize,
                Child::Inner(i) => {
                    let n = &self.nodes[i as usize];
                    cur = if x[n.feature as usize] <= n.threshold { n.left } else { n.right };
                }
            }
        }
    }

    /// Accumulate this tree's prediction for `x` into `out` (len `n_classes`).
    pub fn predict_into(&self, x: &[f32], out: &mut [f32]) {
        let leaf = self.exit_leaf(x);
        for (o, v) in out.iter_mut().zip(self.leaf_row(leaf)) {
            *o += v;
        }
    }

    /// Maximum root-to-leaf depth (leaf-only tree has depth 0).
    pub fn depth(&self) -> usize {
        fn go(t: &Tree, c: Child) -> usize {
            match c {
                Child::Leaf(_) => 0,
                Child::Inner(i) => {
                    1 + go(t, t.nodes[i as usize].left).max(go(t, t.nodes[i as usize].right))
                }
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            go(self, Child::Inner(0))
        }
    }

    /// For every inner node, the contiguous range `[begin, end)` of leaf ids
    /// in its **left** subtree. This is exactly the set of leaves a
    /// QuickScorer "false node" (one with `x[k] > t`) removes from the
    /// candidate set (paper §3, Algorithm 1 line 8).
    pub fn left_leaf_ranges(&self) -> Vec<(u32, u32)> {
        let mut out = vec![(0u32, 0u32); self.nodes.len()];
        if !self.nodes.is_empty() {
            self.leaf_span(Child::Inner(0), &mut out);
        }
        out
    }

    /// Leaf span `[begin, end)` of the subtree rooted at `c`, filling
    /// left-subtree ranges along the way.
    fn leaf_span(&self, c: Child, out: &mut Vec<(u32, u32)>) -> (u32, u32) {
        match c {
            Child::Leaf(l) => (l, l + 1),
            Child::Inner(i) => {
                let n = self.nodes[i as usize];
                let (lb, le) = self.leaf_span(n.left, out);
                let (rb, re) = self.leaf_span(n.right, out);
                debug_assert_eq!(le, rb, "leaves must be numbered left-to-right");
                out[i as usize] = (lb, le);
                (lb, re)
            }
        }
    }

    /// Structural validation: every leaf id in `0..n_leaves` appears exactly
    /// once, children indices are in range, leaf numbering is in-order, and
    /// the leaf table has the right shape. Returns a description of the first
    /// violation.
    pub fn validate(&self) -> Result<(), String> {
        if self.leaf_values.len() != self.n_leaves * self.n_classes {
            return Err(format!(
                "leaf table shape {} != {}x{}",
                self.leaf_values.len(),
                self.n_leaves,
                self.n_classes
            ));
        }
        if self.nodes.is_empty() {
            return if self.n_leaves == 1 { Ok(()) } else { Err("no nodes but >1 leaf".into()) };
        }
        if self.nodes.len() + 1 != self.n_leaves {
            return Err(format!(
                "binary tree must have n_leaves = n_nodes+1 ({} vs {})",
                self.n_leaves,
                self.nodes.len()
            ));
        }
        // In-order walk must visit leaves 0,1,2,... and each inner node once.
        let mut next_leaf = 0u32;
        let mut visited = vec![false; self.nodes.len()];
        let mut err = None;
        self.walk_inorder(Child::Inner(0), &mut next_leaf, &mut visited, &mut err);
        if let Some(e) = err {
            return Err(e);
        }
        if next_leaf as usize != self.n_leaves {
            return Err(format!("visited {next_leaf} leaves, expected {}", self.n_leaves));
        }
        if !visited.iter().all(|&v| v) {
            return Err("unreachable inner node".into());
        }
        Ok(())
    }

    fn walk_inorder(
        &self,
        c: Child,
        next_leaf: &mut u32,
        visited: &mut [bool],
        err: &mut Option<String>,
    ) {
        if err.is_some() {
            return;
        }
        match c {
            Child::Leaf(l) => {
                if l != *next_leaf {
                    *err = Some(format!("leaf {l} out of order (expected {next_leaf})"));
                }
                *next_leaf += 1;
            }
            Child::Inner(i) => {
                let i = i as usize;
                if i >= self.nodes.len() {
                    *err = Some(format!("node index {i} out of range"));
                    return;
                }
                if visited[i] {
                    *err = Some(format!("node {i} visited twice"));
                    return;
                }
                visited[i] = true;
                self.walk_inorder(self.nodes[i].left, next_leaf, visited, err);
                self.walk_inorder(self.nodes[i].right, next_leaf, visited, err);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A hand-built 4-leaf tree:
    ///          n0: x0 <= 0.5
    ///         /            \
    ///    n1: x1 <= 0.25    n2: x0 <= 0.75
    ///    /      \          /      \
    ///  L0       L1       L2       L3
    pub fn sample_tree() -> Tree {
        Tree {
            nodes: vec![
                Node { feature: 0, threshold: 0.5, left: Child::Inner(1), right: Child::Inner(2) },
                Node { feature: 1, threshold: 0.25, left: Child::Leaf(0), right: Child::Leaf(1) },
                Node { feature: 0, threshold: 0.75, left: Child::Leaf(2), right: Child::Leaf(3) },
            ],
            leaf_values: vec![1.0, 2.0, 3.0, 4.0],
            n_leaves: 4,
            n_classes: 1,
        }
    }

    #[test]
    fn exit_leaves() {
        let t = sample_tree();
        assert_eq!(t.exit_leaf(&[0.0, 0.0]), 0);
        assert_eq!(t.exit_leaf(&[0.0, 0.9]), 1);
        assert_eq!(t.exit_leaf(&[0.6, 0.0]), 2);
        assert_eq!(t.exit_leaf(&[0.9, 0.0]), 3);
    }

    #[test]
    fn boundary_goes_left() {
        let t = sample_tree();
        // split is x <= t, so exactly-at-threshold goes left
        assert_eq!(t.exit_leaf(&[0.5, 0.25]), 0);
    }

    #[test]
    fn left_ranges() {
        let t = sample_tree();
        assert_eq!(t.left_leaf_ranges(), vec![(0, 2), (0, 1), (2, 3)]);
    }

    #[test]
    fn validates() {
        assert!(sample_tree().validate().is_ok());
        assert!(Tree::leaf(vec![1.0]).validate().is_ok());
    }

    #[test]
    fn invalid_leaf_order_detected() {
        let mut t = sample_tree();
        // Swap leaf ids 0 and 1 -> out of order.
        t.nodes[1].left = Child::Leaf(1);
        t.nodes[1].right = Child::Leaf(0);
        assert!(t.validate().is_err());
    }

    #[test]
    fn depth() {
        assert_eq!(sample_tree().depth(), 2);
        assert_eq!(Tree::leaf(vec![0.0]).depth(), 0);
    }

    #[test]
    fn predict_accumulates() {
        let t = sample_tree();
        let mut out = vec![10.0];
        t.predict_into(&[0.9, 0.0], &mut out);
        assert_eq!(out, vec![14.0]);
    }
}
