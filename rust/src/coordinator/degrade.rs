//! Overload-triggered graceful degradation (DESIGN.md §12).
//!
//! Under sustained overload a deployment has exactly two levers: shed
//! harder, or serve cheaper. The selector already knows how to serve
//! cheaper *without* giving up accuracy — its candidate table ranks every
//! precision tier and early-exit wrapper by measured latency with a
//! calibration argmax-agreement column — so overload should flip the
//! deployment onto its agreement-gated fallback engine instead of
//! drowning in `Overloaded` rejections.
//!
//! A [`DegradeController`] watches two signals per deployment: the shared
//! pool's queue depth for its label, and the **windowed** p99 of the
//! serving latency histogram ([`Histogram::quantile_between`] between
//! poll-tick snapshots — a cumulative p99 barely moves under a fresh burst
//! after hours of healthy traffic, so it can neither detect overload
//! promptly nor observe recovery). The decision itself is a small
//! hysteresis state machine, [`Hysteresis`], kept clock-explicit so tests
//! drive it deterministically:
//!
//! * **enter fast** — [`DegradeConfig::enter_after`] consecutive hot polls
//!   (default 2, ≈40 ms at the default poll rate) flip to the fallback;
//!   overload compounds quickly, so hesitating is expensive;
//! * **exit slow** — [`DegradeConfig::exit_after`] consecutive cool polls
//!   *and* [`DegradeConfig::min_dwell`] since entry are required to return
//!   to the primary; exiting is cheap to delay and flapping re-quantizes
//!   the serving path every few ticks.
//!
//! The actual engine swap is [`Batcher::swap_engine`]: in-flight flushes
//! finish on the engine they captured, later flushes plan for the new one,
//! and the determinism contract (replies bit-identical to a serial
//! `predict_batch` on the engine that served them) holds on both sides.
//! The fallback must come from the selection's ≥ 99%-agreement set
//! ([`crate::coordinator::Selection::agreement_set`]) — degradation trades
//! tail latency, never served accuracy.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

use super::batcher::Batcher;
use super::Deployment;
use crate::engine::Engine;
use crate::exec::SharedPool;
use crate::obs::Histogram;
use crate::util::Json;

/// Overload thresholds and hysteresis shape for one deployment.
#[derive(Debug, Clone, Copy)]
pub struct DegradeConfig {
    /// Pool queue depth (tasks waiting under this deployment's label) at or
    /// above which a poll counts as hot.
    pub queue_high: usize,
    /// Windowed p99 request latency (µs) at or above which a poll counts
    /// as hot. An empty window never counts.
    pub p99_high_us: f64,
    /// Consecutive hot polls before entering degraded mode (enter fast).
    pub enter_after: u32,
    /// Consecutive cool polls before exiting degraded mode (exit slow).
    pub exit_after: u32,
    /// Minimum time spent degraded before an exit is allowed — with
    /// `exit_after`, the anti-flap guarantee: at most one enter/exit pair
    /// per dwell period no matter how pathological the load pattern.
    pub min_dwell: Duration,
    /// Ticker poll period (also the p99 window length).
    pub poll_every: Duration,
}

impl Default for DegradeConfig {
    fn default() -> Self {
        DegradeConfig {
            queue_high: 256,
            p99_high_us: 50_000.0,
            enter_after: 2,
            exit_after: 20,
            min_dwell: Duration::from_secs(1),
            poll_every: Duration::from_millis(20),
        }
    }
}

/// The pure enter-fast/exit-slow state machine. The clock is an argument,
/// never sampled — unit tests replay exact schedules against it.
#[derive(Debug)]
pub struct Hysteresis {
    cfg: DegradeConfig,
    degraded: bool,
    hot: u32,
    cool: u32,
    entered_at: Option<Instant>,
}

impl Hysteresis {
    pub fn new(cfg: DegradeConfig) -> Hysteresis {
        Hysteresis { cfg, degraded: false, hot: 0, cool: 0, entered_at: None }
    }

    pub fn degraded(&self) -> bool {
        self.degraded
    }

    /// Feed one poll observation; returns `Some(true)` on the transition
    /// into degraded mode, `Some(false)` on the transition out, `None`
    /// otherwise.
    pub fn observe(&mut self, overloaded: bool, now: Instant) -> Option<bool> {
        if overloaded {
            self.hot += 1;
            self.cool = 0;
        } else {
            self.cool += 1;
            self.hot = 0;
        }
        if !self.degraded {
            if self.hot >= self.cfg.enter_after {
                self.degraded = true;
                self.entered_at = Some(now);
                self.hot = 0;
                self.cool = 0;
                return Some(true);
            }
        } else if self.cool >= self.cfg.exit_after
            && self
                .entered_at
                .map_or(true, |t| now.duration_since(t) >= self.cfg.min_dwell)
        {
            self.degraded = false;
            self.hot = 0;
            self.cool = 0;
            return Some(false);
        }
        None
    }
}

/// Per-deployment degradation: the primary and fallback engines, the
/// hysteresis state, and transition counters for `stats`/`health`.
pub struct DegradeController {
    cfg: DegradeConfig,
    primary: Arc<dyn Engine>,
    fallback: Arc<dyn Engine>,
    primary_name: String,
    fallback_name: String,
    /// The fallback candidate's measured calibration argmax agreement with
    /// the float reference (≥ 0.99 by construction).
    fallback_agreement: f64,
    degraded: AtomicBool,
    entries: AtomicU64,
    exits: AtomicU64,
    state: Mutex<Hysteresis>,
    stop: Arc<AtomicBool>,
    ticker: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl DegradeController {
    pub fn new(
        primary: Arc<dyn Engine>,
        fallback: Arc<dyn Engine>,
        fallback_name: String,
        fallback_agreement: f64,
        cfg: DegradeConfig,
    ) -> DegradeController {
        DegradeController {
            cfg,
            primary_name: primary.name(),
            fallback_name,
            fallback_agreement,
            primary,
            fallback,
            degraded: AtomicBool::new(false),
            entries: AtomicU64::new(0),
            exits: AtomicU64::new(0),
            state: Mutex::new(Hysteresis::new(cfg)),
            stop: Arc::new(AtomicBool::new(false)),
            ticker: Mutex::new(None),
        }
    }

    pub fn config(&self) -> DegradeConfig {
        self.cfg
    }

    pub fn degraded(&self) -> bool {
        self.degraded.load(Ordering::SeqCst)
    }

    pub fn entries(&self) -> u64 {
        self.entries.load(Ordering::SeqCst)
    }

    pub fn exits(&self) -> u64 {
        self.exits.load(Ordering::SeqCst)
    }

    pub fn primary_name(&self) -> &str {
        &self.primary_name
    }

    pub fn fallback_name(&self) -> &str {
        &self.fallback_name
    }

    pub fn fallback_agreement(&self) -> f64 {
        self.fallback_agreement
    }

    /// Feed one poll sample through the hysteresis; on a transition, update
    /// the published flag and counters and return it (the caller performs
    /// the engine swap — the controller never holds a batcher reference, so
    /// drop order between it and the deployment is a non-issue).
    pub fn tick(&self, queue_depth: usize, p99_us: f64, now: Instant) -> Option<bool> {
        let hot = queue_depth >= self.cfg.queue_high
            || (p99_us > 0.0 && p99_us >= self.cfg.p99_high_us);
        let transition = self.state.lock().unwrap().observe(hot, now);
        match transition {
            Some(true) => {
                self.degraded.store(true, Ordering::SeqCst);
                self.entries.fetch_add(1, Ordering::SeqCst);
            }
            Some(false) => {
                self.degraded.store(false, Ordering::SeqCst);
                self.exits.fetch_add(1, Ordering::SeqCst);
            }
            None => {}
        }
        transition
    }

    /// Apply a [`DegradeController::tick`] transition to the deployment's
    /// batcher: degraded → fallback engine, recovered → primary.
    pub fn apply(&self, batcher: &Batcher, entered: bool) {
        let engine =
            if entered { self.fallback.clone() } else { self.primary.clone() };
        // Shapes were validated at enable time; a failure here means the
        // batcher is already draining, which makes the swap moot.
        let _ = batcher.swap_engine(engine);
    }

    /// Degradation state for `stats --json` and the `health` probe.
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("degraded", Json::Bool(self.degraded())),
            ("entries", Json::Num(self.entries() as f64)),
            ("exits", Json::Num(self.exits() as f64)),
            ("primary", Json::Str(self.primary_name.clone())),
            ("fallback", Json::Str(self.fallback_name.clone())),
            ("fallback_agreement", Json::Num(self.fallback_agreement)),
        ])
    }

    /// One-line human status for `Server::report`.
    pub fn status(&self) -> String {
        format!(
            "{} (fallback {} agree={:.1}% entries={} exits={})",
            if self.degraded() { "DEGRADED" } else { "primary" },
            self.fallback_name,
            100.0 * self.fallback_agreement,
            self.entries(),
            self.exits(),
        )
    }

    fn take_ticker(&self) -> Option<std::thread::JoinHandle<()>> {
        self.ticker.lock().unwrap().take()
    }
}

impl Drop for DegradeController {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.take_ticker() {
            // The ticker holds only a Weak deployment handle, but its
            // transient upgrade can make it the thread that drops the last
            // `Arc<Deployment>` — and with it this controller. Joining
            // *ourselves* would deadlock; the thread is already past its
            // loop when that happens, so skipping the join is sound.
            if h.thread().id() != std::thread::current().id() {
                let _ = h.join();
            }
        }
    }
}

/// Spawn the poll ticker for an enabled deployment. The thread samples the
/// pool queue depth under `label` and the windowed latency p99, feeds them
/// through the controller, and applies transitions to the batcher. It
/// holds only a [`Weak`] deployment handle, so it can never keep a
/// torn-down deployment (or its pool registration) alive — it exits on the
/// first failed upgrade, or when the controller's stop flag is set.
pub fn spawn_ticker(
    ctrl: &Arc<DegradeController>,
    dep: &Arc<Deployment>,
    pool: &Arc<SharedPool>,
    label: &str,
) {
    let weak: Weak<Deployment> = Arc::downgrade(dep);
    let ctrl2 = ctrl.clone();
    let pool = pool.clone();
    let label = label.to_string();
    let poll = ctrl.cfg.poll_every;
    let stop = ctrl.stop.clone();
    let h = std::thread::Builder::new()
        .name("degrade-ticker".into())
        .spawn(move || {
            let mut prev: Vec<u64> = Vec::new();
            loop {
                std::thread::sleep(poll);
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                let Some(dep) = weak.upgrade() else { return };
                let cur = dep.batcher.metrics.latency_buckets();
                let p99 = if prev.is_empty() {
                    0.0
                } else {
                    Histogram::quantile_between(&prev, &cur, 0.99)
                };
                prev = cur;
                let depth = pool
                    .stats()
                    .deployments
                    .iter()
                    .find(|d| d.label == label)
                    .map_or(0, |d| d.queue_depth);
                if let Some(entered) = ctrl2.tick(depth, p99, Instant::now()) {
                    ctrl2.apply(&dep.batcher, entered);
                }
            }
        })
        .expect("spawn degrade ticker");
    *ctrl.ticker.lock().unwrap() = Some(h);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DegradeConfig {
        DegradeConfig {
            queue_high: 100,
            p99_high_us: 10_000.0,
            enter_after: 2,
            exit_after: 3,
            min_dwell: Duration::from_millis(500),
            poll_every: Duration::from_millis(20),
        }
    }

    /// Deterministic replay of the hysteresis contract: enter after
    /// `enter_after` consecutive hot polls (not before, and a cool poll
    /// resets the streak), exit only after `exit_after` consecutive cool
    /// polls *and* the dwell.
    #[test]
    fn hysteresis_enters_fast_and_exits_slow() {
        let t0 = Instant::now();
        let mut h = Hysteresis::new(cfg());
        assert_eq!(h.observe(true, t0), None, "one hot poll is not overload");
        assert_eq!(h.observe(false, t0), None, "cool poll resets the streak");
        assert_eq!(h.observe(true, t0), None);
        assert_eq!(h.observe(true, t0), Some(true), "second consecutive hot enters");
        assert!(h.degraded());
        // Cool polls immediately after entry: streak satisfied at the third
        // poll, but the dwell blocks the exit…
        let t1 = t0 + Duration::from_millis(100);
        for _ in 0..5 {
            assert_eq!(h.observe(false, t1), None, "dwell must block early exit");
        }
        // …past the dwell, the cool streak must be rebuilt consecutively: a
        // hot poll resets it.
        let t2 = t0 + Duration::from_secs(1);
        assert_eq!(h.observe(false, t2), Some(false), "streak + dwell satisfied");
        assert!(!h.degraded());
    }

    #[test]
    fn hysteresis_hot_poll_resets_cool_streak() {
        let t0 = Instant::now();
        let mut h = Hysteresis::new(cfg());
        h.observe(true, t0);
        assert_eq!(h.observe(true, t0), Some(true));
        let late = t0 + Duration::from_secs(2);
        assert_eq!(h.observe(false, late), None);
        assert_eq!(h.observe(false, late), None);
        assert_eq!(h.observe(true, late), None, "hot poll mid-recovery");
        assert_eq!(h.observe(false, late), None, "cool streak restarted at 1");
        assert_eq!(h.observe(false, late), None);
        assert_eq!(h.observe(false, late), Some(false), "3 consecutive cools");
    }

    /// The controller's published state tracks tick transitions exactly:
    /// queue depth and windowed p99 are each sufficient to run hot, an
    /// empty p99 window is never hot, and entries/exits count transitions
    /// (not hot polls).
    #[test]
    fn controller_tick_publishes_transitions() {
        let ds = crate::data::DatasetId::Magic.generate(200, 11);
        let f = crate::forest::builder::train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            crate::forest::builder::RfParams {
                n_trees: 4,
                tree: crate::forest::builder::TreeParams {
                    max_leaves: 8,
                    min_samples_leaf: 2,
                    mtry: 0,
                },
                ..Default::default()
            },
        );
        let eng: Arc<dyn Engine> = Arc::from(
            crate::engine::build(
                crate::engine::EngineKind::Rs,
                crate::engine::Precision::F32,
                &f,
                None,
            )
            .unwrap(),
        );
        let c = DegradeController::new(eng.clone(), eng, "fb".into(), 1.0, cfg());
        let t0 = Instant::now();
        assert!(!c.degraded());
        // p99 alone (window non-empty) runs hot; zero-window p99 does not.
        assert_eq!(c.tick(0, 0.0, t0), None);
        assert_eq!(c.tick(0, 20_000.0, t0), None);
        assert_eq!(c.tick(0, 20_000.0, t0), Some(true));
        assert!(c.degraded());
        assert_eq!((c.entries(), c.exits()), (1, 0));
        // Staying hot produces no further transitions.
        assert_eq!(c.tick(500, 0.0, t0), None);
        assert_eq!((c.entries(), c.exits()), (1, 0));
        let late = t0 + Duration::from_secs(1);
        assert_eq!(c.tick(0, 0.0, late), None);
        assert_eq!(c.tick(0, 0.0, late), None);
        assert_eq!(c.tick(0, 0.0, late), Some(false));
        assert!(!c.degraded());
        assert_eq!((c.entries(), c.exits()), (1, 1));
        let j = c.to_json();
        assert_eq!(j.get("degraded").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(j.get("entries").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("fallback").and_then(|v| v.as_str()), Some("fb"));
        assert!(c.status().contains("fallback fb"));
    }
}
