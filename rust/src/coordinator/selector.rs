//! Engine auto-selection.
//!
//! The paper's central operational finding: *"for the best performance, the
//! combination between forest, device and implementation is important"*
//! (§6.1) — no engine wins everywhere. The selector makes that executable:
//! given a forest and a calibration batch it measures every candidate
//! engine on the host and/or scores them with a device cost model, and
//! returns a ranked recommendation.

use std::sync::Arc;

use crate::device::{model_working_set, DeviceProfile};
use crate::engine::{build, variant_name, Engine, EngineKind, Precision};
use crate::forest::Forest;
use crate::util::Stopwatch;

/// How a candidate scored.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub name: String,
    pub kind: EngineKind,
    pub precision: Precision,
    /// Measured host wall-clock per instance (µs).
    pub host_us_per_instance: f64,
    /// Cost-model estimate per instance (µs) for the target device, if one
    /// was given.
    pub device_us_per_instance: Option<f64>,
}

/// Selection report: candidates sorted best-first by the active criterion.
#[derive(Debug, Clone)]
pub struct Selection {
    pub candidates: Vec<Candidate>,
    pub device: Option<String>,
}

impl Selection {
    pub fn best(&self) -> &Candidate {
        &self.candidates[0]
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        let target = self.device.as_deref().unwrap_or("host");
        out.push_str(&format!("engine selection (target: {target})\n"));
        out.push_str(&format!(
            "  {:<6} {:>14} {:>16}\n",
            "engine", "host µs/inst", "device µs/inst"
        ));
        for c in &self.candidates {
            out.push_str(&format!(
                "  {:<6} {:>14.2} {:>16}\n",
                c.name,
                c.host_us_per_instance,
                c.device_us_per_instance
                    .map(|v| format!("{v:.2}"))
                    .unwrap_or_else(|| "-".into()),
            ));
        }
        out
    }
}

/// Measure every engine variant on `calibration` (row-major batch) and rank.
///
/// With a `device` profile, ranking uses the cost-model estimate (the
/// deployment target); otherwise host wall-clock. `repeats` controls the
/// median-of-k timing.
pub fn select_engine(
    forest: &Forest,
    calibration: &[f32],
    device: Option<&DeviceProfile>,
    repeats: usize,
) -> anyhow::Result<Selection> {
    let n = calibration.len() / forest.n_features;
    anyhow::ensure!(n > 0, "calibration batch is empty");
    let mut candidates = Vec::new();
    for (kind, precision) in crate::engine::all_variants() {
        let engine: Arc<dyn Engine> = match build(kind, precision, forest, None) {
            Ok(e) => Arc::from(e),
            Err(_) => continue, // e.g. >64 leaves: QS family unavailable
        };
        let mut out = vec![0f32; n * forest.n_classes];
        // Warmup + median-of-k.
        engine.predict_batch(calibration, &mut out);
        let mut times = Vec::with_capacity(repeats);
        for _ in 0..repeats.max(1) {
            let sw = Stopwatch::start();
            engine.predict_batch(calibration, &mut out);
            times.push(sw.micros() / n as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let host = times[times.len() / 2];
        let device_est = device.map(|dev| {
            let trace = engine.count_ops(calibration);
            let bytes_per_scalar = match precision {
                Precision::F32 => 4,
                Precision::I16 => 2,
            };
            let ws = model_working_set(
                forest.n_nodes(),
                forest.n_trees(),
                forest.max_leaves().next_power_of_two().max(32),
                forest.n_classes,
                bytes_per_scalar,
            );
            dev.estimate_us(&trace, ws) / n as f64
        });
        candidates.push(Candidate {
            name: variant_name(kind, precision),
            kind,
            precision,
            host_us_per_instance: host,
            device_us_per_instance: device_est,
        });
    }
    candidates.sort_by(|a, b| {
        let ka = a.device_us_per_instance.unwrap_or(a.host_us_per_instance);
        let kb = b.device_us_per_instance.unwrap_or(b.host_us_per_instance);
        ka.partial_cmp(&kb).unwrap()
    });
    Ok(Selection { candidates, device: device.map(|d| d.name.to_string()) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    #[test]
    fn selects_and_ranks() {
        let ds = DatasetId::Magic.generate(600, 21);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 24,
                tree: TreeParams { max_leaves: 32, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let sel = select_engine(&f, &ds.x[..ds.d * 256], None, 3).unwrap();
        assert_eq!(sel.candidates.len(), 10);
        // sorted ascending by µs/instance
        for w in sel.candidates.windows(2) {
            assert!(w[0].host_us_per_instance <= w[1].host_us_per_instance);
        }
        assert!(sel.report().contains("engine selection"));
    }

    #[test]
    fn device_estimates_populated() {
        let ds = DatasetId::Eeg.generate(400, 22);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 8,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let dev = DeviceProfile::cortex_a53();
        let sel = select_engine(&f, &ds.x[..ds.d * 64], Some(&dev), 1).unwrap();
        assert!(sel.candidates.iter().all(|c| c.device_us_per_instance.is_some()));
        assert!(sel.device.as_deref().unwrap().contains("A53"));
    }
}
