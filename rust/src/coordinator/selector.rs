//! Engine auto-selection.
//!
//! The paper's central operational finding: *"for the best performance, the
//! combination between forest, device and implementation is important"*
//! (§6.1) — no engine wins everywhere. The selector makes that executable:
//! given a forest and a calibration batch it measures every candidate
//! engine on the host and/or scores them with a device cost model, and
//! returns a ranked recommendation.

use std::sync::Arc;

use crate::device::{model_working_set, DeviceProfile};
use crate::engine::{build, build_early_exit, EarlyExitMode, Engine, EngineKind, Precision};
use crate::exec::ParallelEngine;
use crate::forest::Forest;
use crate::util::Stopwatch;

/// How a candidate scored.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub name: String,
    pub kind: EngineKind,
    pub precision: Precision,
    /// Exec-thread budget this candidate ran with (1 = serial).
    pub threads: usize,
    /// i16 per-tree-leaf-scale quantization (the `+pt` suffix): rebuilt via
    /// [`crate::engine::build_i16_per_tree`] rather than `build(kind, ..)`.
    pub per_tree: bool,
    /// Early-exit wrapper candidate (the `ee`/`ea` prefix): rebuilt via
    /// [`crate::engine::build_early_exit`] rather than `build(kind, ..)` —
    /// only enumerated by [`select_engine_early_exit`].
    pub early_exit: bool,
    /// Measured host wall-clock per instance (µs).
    pub host_us_per_instance: f64,
    /// Cost-model estimate per instance (µs) for the target device, if one
    /// was given.
    pub device_us_per_instance: Option<f64>,
    /// Fraction of calibration instances whose argmax matches the float
    /// reference traversal — the accuracy signal quantized tiers trade
    /// latency against (1.0 for exact engines).
    pub agreement: f64,
}

/// Selection report: candidates sorted best-first by the active criterion.
#[derive(Debug, Clone)]
pub struct Selection {
    pub candidates: Vec<Candidate>,
    pub device: Option<String>,
}

impl Selection {
    /// Fastest candidate by the active criterion (latency only).
    pub fn best(&self) -> &Candidate {
        &self.candidates[0]
    }

    /// Fastest candidate that also clears the prediction-quality gate:
    /// ≥ 99% calibration argmax agreement with the float reference. Falls
    /// back to [`Selection::best`] when nothing clears it (tiny forests,
    /// extreme quantization). This is what `Server::deploy_auto` deploys
    /// and what the CLI recommends — latency alone must not pick a tier
    /// that degrades served accuracy.
    pub fn recommended(&self) -> &Candidate {
        self.candidates
            .iter()
            .find(|c| c.agreement >= 0.99)
            .unwrap_or_else(|| self.best())
    }

    /// Every candidate clearing the ≥ 99% argmax-agreement gate, in rank
    /// order (fastest first). This is the pool the degrade controller may
    /// pick an overload fallback from: degradation trades latency, never
    /// served accuracy ([`crate::coordinator::degrade`]).
    pub fn agreement_set(&self) -> Vec<&Candidate> {
        self.candidates.iter().filter(|c| c.agreement >= 0.99).collect()
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        let target = self.device.as_deref().unwrap_or("host");
        out.push_str(&format!("engine selection (target: {target})\n"));
        // Width 12 fits threaded per-tree names like `qVQS+pt×16t` next to
        // serial ones.
        out.push_str(&format!(
            "  {:<12} {:>14} {:>16} {:>8}\n",
            "engine", "host µs/inst", "device µs/inst", "argmax%"
        ));
        for c in &self.candidates {
            out.push_str(&format!(
                "  {:<12} {:>14.2} {:>16} {:>8.1}\n",
                c.name,
                c.host_us_per_instance,
                c.device_us_per_instance
                    .map(|v| format!("{v:.2}"))
                    .unwrap_or_else(|| "-".into()),
                100.0 * c.agreement,
            ));
        }
        out
    }
}

/// Measure every (serial) engine variant on `calibration` and rank — the
/// paper's ten candidates plus the int8 tier. See [`select_engine_with`]
/// for threaded candidates.
pub fn select_engine(
    forest: &Forest,
    calibration: &[f32],
    device: Option<&DeviceProfile>,
    repeats: usize,
) -> anyhow::Result<Selection> {
    select_engine_with(forest, calibration, device, repeats, &[1])
}

/// The thread budgets worth measuring for a deployment budget: 1, the
/// powers of two in between, and the budget itself.
///
/// A "budget" here is the deployment's worker entitlement on the
/// server-shared pool (see [`crate::exec::SharedPool`]), not a private
/// thread count: `Server::deploy_auto` measures each candidate at these
/// budgets and registers the winner's budget with the shared scheduler.
/// Measurement itself runs on transient standalone pools so it cannot
/// perturb live deployments.
pub fn thread_budgets(max_threads: usize) -> Vec<usize> {
    let mut budgets = vec![1usize];
    let mut t = 2usize;
    while t < max_threads {
        budgets.push(t);
        match t.checked_mul(2) {
            Some(next) => t = next,
            None => break, // absurd budgets must not wrap into a 0 loop
        }
    }
    if max_threads > 1 {
        budgets.push(max_threads);
    }
    budgets
}

/// Measure every engine variant × thread budget on `calibration` (row-major
/// batch) and rank. Threaded candidates run as row-sharded
/// [`crate::exec::ParallelEngine`]s (bit-exact with serial), named
/// paper-style plus a thread suffix, e.g. `RS×4t`.
///
/// With a `device` profile, ranking uses the cost-model estimate (the
/// deployment target); the single-core estimate is scaled by the device's
/// usable parallelism (capped at its core count, with a 3%-per-extra-thread
/// coordination penalty). `repeats` controls the median-of-k timing.
pub fn select_engine_with(
    forest: &Forest,
    calibration: &[f32],
    device: Option<&DeviceProfile>,
    repeats: usize,
    thread_budgets: &[usize],
) -> anyhow::Result<Selection> {
    select_engine_tier(forest, calibration, device, repeats, thread_budgets, None)
}

/// [`select_engine_with`] restricted to one precision tier when `tier` is
/// set — excluded variants are never built or timed.
pub fn select_engine_tier(
    forest: &Forest,
    calibration: &[f32],
    device: Option<&DeviceProfile>,
    repeats: usize,
    thread_budgets: &[usize],
    tier: Option<Precision>,
) -> anyhow::Result<Selection> {
    let n = calibration.len() / forest.n_features;
    anyhow::ensure!(n > 0, "calibration batch is empty");
    let mut budgets: Vec<usize> = thread_budgets.iter().map(|&t| t.max(1)).collect();
    budgets.sort_unstable();
    budgets.dedup();
    if budgets.is_empty() {
        budgets.push(1);
    }
    // Float-reference argmax for the agreement column (the accuracy signal
    // the quantized tiers trade latency against).
    let ref_argmax =
        Forest::argmax(&forest.predict_batch(calibration), forest.n_classes);
    let mut candidates = Vec::new();
    // The paper's ten variants plus the int8 and FLInt tiers, each built
    // once; plus the i16 per-tree-scale candidate (`qVQS+pt`) — same VQS
    // traversal, leaves at per-tree scales.
    let mut entries: Vec<(EngineKind, Precision, bool, Arc<dyn Engine>)> = Vec::new();
    for (kind, precision) in crate::engine::all_variants_with_i8() {
        if tier.is_some_and(|p| p != precision) {
            continue;
        }
        // Build the serial engine once per variant; threaded candidates
        // wrap the same instance (Exact row sharding), so RS/QS model
        // preparation and quantization are not repeated per budget.
        match build(kind, precision, forest, None) {
            Ok(e) => entries.push((kind, precision, false, Arc::from(e))),
            Err(_) => continue, // e.g. >64 leaves: QS family unavailable
        }
    }
    if tier.map_or(true, |p| p == Precision::I16) {
        if let Ok(e) = crate::engine::build_i16_per_tree(EngineKind::Vqs, forest) {
            entries.push((EngineKind::Vqs, Precision::I16, true, Arc::from(e)));
        }
    }
    for (kind, precision, per_tree, serial) in entries {
        // The op trace is a workload property, identical for every thread
        // budget (ParallelEngine::count_ops delegates to the serial
        // engine) — compute the single-core device estimate once per
        // variant, not once per budget. Likewise the argmax agreement
        // (threaded candidates are bit-exact with serial).
        let mut single_us_est: Option<f64> = None;
        let mut agreement: Option<f64> = None;
        // `+pt` distinguishes the per-tree candidate from plain qVQS.
        let display = if per_tree {
            format!("{}+pt", serial.name())
        } else {
            serial.name()
        };
        for &threads in &budgets {
            let engine: Arc<dyn Engine> = if threads <= 1 {
                serial.clone()
            } else {
                Arc::new(ParallelEngine::wrap(serial.clone(), threads))
            };
            let mut out = vec![0f32; n * forest.n_classes];
            // Warmup + median-of-k.
            engine.predict_batch(calibration, &mut out);
            let agreement = *agreement.get_or_insert_with(|| {
                let got = Forest::argmax(&out, forest.n_classes);
                let same = got.iter().zip(&ref_argmax).filter(|(a, b)| a == b).count();
                same as f64 / ref_argmax.len().max(1) as f64
            });
            let mut times = Vec::with_capacity(repeats);
            for _ in 0..repeats.max(1) {
                let sw = Stopwatch::start();
                engine.predict_batch(calibration, &mut out);
                times.push(sw.micros() / n as f64);
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let host = times[times.len() / 2];
            let device_est = device.map(|dev| {
                let single = *single_us_est.get_or_insert_with(|| {
                    let trace = engine.count_ops(calibration);
                    let bytes_per_scalar = precision.scalar_bytes();
                    let ws = model_working_set(
                        forest.n_nodes(),
                        forest.n_trees(),
                        forest.max_leaves().next_power_of_two().max(32),
                        forest.n_classes,
                        bytes_per_scalar,
                    );
                    dev.estimate_us(&trace, ws) / n as f64
                });
                // Row sharding parallelizes near-linearly up to the core
                // count; charge a small coordination penalty per extra
                // thread.
                let p = threads.min(dev.cores).max(1) as f64;
                single / p * (1.0 + 0.03 * (threads.saturating_sub(1)) as f64)
            });
            candidates.push(Candidate {
                // Serial engines render the paper-style name (plus `+pt`
                // for the per-tree candidate); threaded ones add `×Nt`.
                name: if threads <= 1 {
                    display.clone()
                } else {
                    format!("{display}×{threads}t")
                },
                kind,
                precision,
                threads,
                per_tree,
                early_exit: false,
                host_us_per_instance: host,
                device_us_per_instance: device_est,
                agreement,
            });
        }
    }
    // FLInt engines are bit-identical to their f32 twins by construction,
    // so a flint candidate's agreement is *definitionally* its f32 twin's —
    // assert it rather than gate on it (a mismatch is a carrier bug, not a
    // precision trade-off). A tier filter that excludes f32 leaves no twin
    // to compare against.
    for fl in candidates.iter().filter(|c| c.precision == Precision::F32Flint) {
        if let Some(twin) = candidates.iter().find(|c| {
            c.precision == Precision::F32
                && c.kind == fl.kind
                && c.threads == fl.threads
                && !c.per_tree
                && !c.early_exit
        }) {
            assert_eq!(
                fl.agreement, twin.agreement,
                "{}: FLInt agreement diverged from its f32 twin {}",
                fl.name, twin.name
            );
        }
    }
    candidates.sort_by(|a, b| {
        let ka = a.device_us_per_instance.unwrap_or(a.host_us_per_instance);
        let kb = b.device_us_per_instance.unwrap_or(b.host_us_per_instance);
        ka.partial_cmp(&kb).unwrap()
    });
    Ok(Selection { candidates, device: device.map(|d| d.name.to_string()) })
}

/// [`select_engine_tier`] plus early-exit candidates.
///
/// With `mode` other than [`EarlyExitMode::Off`], every variant is
/// additionally wrapped in an [`crate::engine::EarlyExitEngine`]
/// (calibration-ordered staged scoring, `ee`/`ea` prefix) and measured at
/// every thread budget next to the plain candidates. The default entry
/// points never enumerate these — early-exit is opt-in per selection — and
/// [`Selection::recommended`]'s ≥ 99% agreement gate applies to approx-mode
/// candidates exactly like any quantized tier, so an aggressive exit
/// threshold cannot win a deployment it would degrade. Exit rates are
/// data-dependent, so early-exit candidates carry no device cost-model
/// estimate: they rank by measured host latency even under `--device`.
pub fn select_engine_early_exit(
    forest: &Forest,
    calibration: &[f32],
    device: Option<&DeviceProfile>,
    repeats: usize,
    thread_budgets: &[usize],
    tier: Option<Precision>,
    mode: EarlyExitMode,
) -> anyhow::Result<Selection> {
    let mut sel =
        select_engine_tier(forest, calibration, device, repeats, thread_budgets, tier)?;
    if mode == EarlyExitMode::Off {
        return Ok(sel);
    }
    let n = calibration.len() / forest.n_features;
    let ref_argmax =
        Forest::argmax(&forest.predict_batch(calibration), forest.n_classes);
    let mut budgets: Vec<usize> = thread_budgets.iter().map(|&t| t.max(1)).collect();
    budgets.sort_unstable();
    budgets.dedup();
    if budgets.is_empty() {
        budgets.push(1);
    }
    for (kind, precision) in crate::engine::all_variants_with_i8() {
        if tier.is_some_and(|p| p != precision) {
            continue;
        }
        // Non-classification forests and QS-family leaf caps surface here
        // as build errors — skip the variant, exactly like the base loop.
        let Ok(ee) = build_early_exit(kind, precision, forest, calibration, mode) else {
            continue;
        };
        let serial: Arc<dyn Engine> = Arc::new(ee);
        let display = serial.name();
        for &threads in &budgets {
            let engine: Arc<dyn Engine> = if threads <= 1 {
                serial.clone()
            } else {
                // Row sharding keeps per-row exit decisions intact: each
                // chunk sees its own rows, so the threaded candidate's
                // scores are bit-identical to the serial wrapper's.
                Arc::new(ParallelEngine::wrap(serial.clone(), threads))
            };
            let mut out = vec![0f32; n * forest.n_classes];
            engine.predict_batch(calibration, &mut out);
            let got = Forest::argmax(&out, forest.n_classes);
            let same = got.iter().zip(&ref_argmax).filter(|(a, b)| a == b).count();
            let agreement = same as f64 / ref_argmax.len().max(1) as f64;
            let mut times = Vec::with_capacity(repeats);
            for _ in 0..repeats.max(1) {
                let sw = Stopwatch::start();
                engine.predict_batch(calibration, &mut out);
                times.push(sw.micros() / n as f64);
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            sel.candidates.push(Candidate {
                name: if threads <= 1 {
                    display.clone()
                } else {
                    format!("{display}×{threads}t")
                },
                kind,
                precision,
                threads,
                per_tree: false,
                early_exit: true,
                host_us_per_instance: times[times.len() / 2],
                device_us_per_instance: None,
                agreement,
            });
        }
    }
    sel.candidates.sort_by(|a, b| {
        let ka = a.device_us_per_instance.unwrap_or(a.host_us_per_instance);
        let kb = b.device_us_per_instance.unwrap_or(b.host_us_per_instance);
        ka.partial_cmp(&kb).unwrap()
    });
    Ok(sel)
}

/// Rebuild the concrete engine a [`Candidate`] was measured as — the same
/// dispatch `deploy_auto` uses: per-tree and early-exit candidates need
/// their special constructors, and threaded candidates wrap the serial
/// engine in a row-sharded [`ParallelEngine`] (bit-exact with serial).
/// `mode` only matters for early-exit candidates (the mode the selection
/// ran with); `calibration` likewise (exit-stage ordering).
pub fn build_candidate(
    c: &Candidate,
    forest: &Forest,
    calibration: &[f32],
    mode: EarlyExitMode,
) -> anyhow::Result<Arc<dyn Engine>> {
    let serial: Arc<dyn Engine> = if c.early_exit {
        Arc::new(build_early_exit(c.kind, c.precision, forest, calibration, mode)?)
    } else if c.per_tree {
        Arc::from(crate::engine::build_i16_per_tree(c.kind, forest)?)
    } else {
        Arc::from(build(c.kind, c.precision, forest, None)?)
    };
    Ok(if c.threads <= 1 {
        serial
    } else {
        Arc::new(ParallelEngine::wrap(serial, c.threads))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    #[test]
    fn selects_and_ranks() {
        let ds = DatasetId::Magic.generate(600, 21);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 24,
                tree: TreeParams { max_leaves: 32, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let sel = select_engine(&f, &ds.x[..ds.d * 256], None, 3).unwrap();
        // The full registered tier × engine matrix plus the one i16
        // per-tree candidate — derived, not a literal: the hard-coded
        // count went stale twice as tiers grew.
        assert_eq!(sel.candidates.len(), crate::engine::all_variants_with_i8().len() + 1);
        assert!(sel.candidates.iter().any(|c| c.name == "q8VQS"));
        assert!(sel.candidates.iter().any(|c| c.name == "q8RS"));
        assert!(sel.candidates.iter().any(|c| c.name == "q8IE"));
        let pt = sel.candidates.iter().find(|c| c.name == "qVQS+pt").unwrap();
        assert!(pt.per_tree && pt.precision == Precision::I16);
        assert!(sel.candidates.iter().filter(|c| c.per_tree).count() == 1);
        // sorted ascending by µs/instance
        for w in sel.candidates.windows(2) {
            assert!(w[0].host_us_per_instance <= w[1].host_us_per_instance);
        }
        assert!(sel.report().contains("engine selection"));
        assert!(sel.report().contains("argmax%"));
        // Exact engines agree perfectly with the float reference; every
        // agreement is a valid fraction.
        let na = sel.candidates.iter().find(|c| c.name == "NA").unwrap();
        assert_eq!(na.agreement, 1.0);
        assert!(sel.candidates.iter().all(|c| (0.0..=1.0).contains(&c.agreement)));
    }

    #[test]
    fn recommended_gates_on_agreement() {
        let mk = |name: &str, us: f64, agreement: f64| Candidate {
            name: name.into(),
            kind: EngineKind::Naive,
            precision: Precision::F32,
            threads: 1,
            per_tree: false,
            early_exit: false,
            host_us_per_instance: us,
            device_us_per_instance: None,
            agreement,
        };
        let sel = Selection {
            candidates: vec![
                mk("q8VQS", 1.0, 0.8), // fastest but below the gate
                mk("qRS", 2.0, 0.995),
                mk("NA", 9.0, 1.0),
            ],
            device: None,
        };
        assert_eq!(sel.best().name, "q8VQS");
        assert_eq!(sel.recommended().name, "qRS");
        // Nothing clears the gate → fall back to the fastest overall.
        let sel2 = Selection {
            candidates: vec![mk("a", 1.0, 0.5), mk("b", 2.0, 0.6)],
            device: None,
        };
        assert_eq!(sel2.recommended().name, "a");
    }

    #[test]
    fn tier_filter_restricts_candidates() {
        let ds = DatasetId::Magic.generate(400, 24);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 8,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let sel = super::select_engine_tier(
            &f,
            &ds.x[..ds.d * 64],
            None,
            1,
            &[1],
            Some(Precision::I8),
        )
        .unwrap();
        assert_eq!(sel.candidates.len(), crate::engine::i8_variants().len());
        assert!(sel.candidates.iter().all(|c| c.precision == Precision::I8));

        // The flint tier filter likewise ranks exactly the five FLInt
        // engines — and their agreement with the float reference matches
        // plain f32 (same argmax tie-breaks, bit-identical scores).
        let self32 = super::select_engine_tier(
            &f,
            &ds.x[..ds.d * 64],
            None,
            1,
            &[1],
            Some(Precision::F32),
        )
        .unwrap();
        let selfl = super::select_engine_tier(
            &f,
            &ds.x[..ds.d * 64],
            None,
            1,
            &[1],
            Some(Precision::F32Flint),
        )
        .unwrap();
        assert_eq!(selfl.candidates.len(), crate::engine::flint_variants().len());
        assert!(selfl.candidates.iter().all(|c| c.precision == Precision::F32Flint));
        for fl in &selfl.candidates {
            let twin = self32.candidates.iter().find(|c| c.kind == fl.kind).unwrap();
            assert_eq!(fl.agreement, twin.agreement, "{}", fl.name);
        }
    }

    /// Early-exit candidates only appear through the opt-in entry point,
    /// mode Off is a passthrough, and exact-mode f32 candidates keep
    /// perfect argmax agreement (the bound proof, observed end-to-end).
    #[test]
    fn early_exit_candidates_appended_and_exact() {
        let ds = DatasetId::Magic.generate(500, 29);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 12,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let cal = &ds.x[..ds.d * 96];
        let base = super::select_engine_early_exit(
            &f,
            cal,
            None,
            1,
            &[1],
            Some(Precision::F32),
            EarlyExitMode::Off,
        )
        .unwrap();
        let n_f32 = crate::engine::all_variants_with_i8()
            .iter()
            .filter(|(_, p)| *p == Precision::F32)
            .count();
        assert_eq!(base.candidates.len(), n_f32);
        assert!(base.candidates.iter().all(|c| !c.early_exit));

        let sel = super::select_engine_early_exit(
            &f,
            cal,
            None,
            1,
            &[1, 2],
            Some(Precision::F32),
            EarlyExitMode::Exact,
        )
        .unwrap();
        // Base candidates at both budgets, plus one ee candidate per f32
        // variant per budget.
        assert_eq!(sel.candidates.len(), 4 * n_f32);
        let ee: Vec<_> = sel.candidates.iter().filter(|c| c.early_exit).collect();
        assert_eq!(ee.len(), 2 * n_f32);
        assert!(ee.iter().all(|c| c.name.starts_with("ee")));
        assert!(ee.iter().any(|c| c.threads == 2 && c.name.ends_with("×2t")));
        // Exact mode provably preserves argmax; on the f32 tier the full
        // scoring *is* the float reference, so agreement is exactly 1.
        for c in &ee {
            assert_eq!(c.agreement, 1.0, "{} lost argmax agreement", c.name);
        }
        // Approx candidates carry the ea prefix and rank under the same
        // ≥99% gate as quantized tiers.
        let approx = super::select_engine_early_exit(
            &f,
            cal,
            None,
            1,
            &[1],
            Some(Precision::F32),
            EarlyExitMode::Approx,
        )
        .unwrap();
        assert!(approx
            .candidates
            .iter()
            .any(|c| c.early_exit && c.name.starts_with("ea")));
        assert!(approx.recommended().agreement >= 0.99 || approx.candidates.iter().all(|c| c.agreement < 0.99));
    }

    /// `agreement_set` is the rank-ordered ≥99% pool, and `build_candidate`
    /// reconstructs an engine that reproduces the candidate's measured
    /// scores (bit-exact for plain and threaded candidates alike).
    #[test]
    fn agreement_set_and_build_candidate_round_trip() {
        let ds = DatasetId::Magic.generate(400, 27);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 8,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let cal = &ds.x[..ds.d * 64];
        let sel = super::select_engine_early_exit(
            &f,
            cal,
            None,
            1,
            &[1, 2],
            None,
            EarlyExitMode::Exact,
        )
        .unwrap();
        let set = sel.agreement_set();
        assert!(!set.is_empty());
        assert!(set.iter().all(|c| c.agreement >= 0.99));
        assert_eq!(set[0].name, sel.recommended().name);
        // Rebuild a plain, a threaded, a per-tree and an early-exit
        // candidate; each must score the calibration batch identically to
        // a fresh serial build of the same variant (the selector's own
        // bit-exactness contract for threaded wrappers).
        for c in [
            sel.candidates.iter().find(|c| !c.early_exit && !c.per_tree && c.threads == 1),
            sel.candidates.iter().find(|c| c.threads == 2),
            sel.candidates.iter().find(|c| c.per_tree),
            sel.candidates.iter().find(|c| c.early_exit),
        ]
        .into_iter()
        .flatten()
        {
            let eng = super::build_candidate(c, &f, cal, EarlyExitMode::Exact).unwrap();
            assert_eq!(eng.n_features(), ds.d, "{}", c.name);
            let mut out = vec![0f32; 64 * ds.n_classes];
            eng.predict_batch(cal, &mut out);
            let got = Forest::argmax(&out, ds.n_classes);
            let expect = Forest::argmax(&f.predict_batch(cal), ds.n_classes);
            let same = got.iter().zip(&expect).filter(|(a, b)| a == b).count();
            assert!(
                same as f64 / expect.len() as f64 >= c.agreement - 1e-9,
                "{} rebuilt below its measured agreement",
                c.name
            );
        }
    }

    #[test]
    fn device_estimates_populated() {
        let ds = DatasetId::Eeg.generate(400, 22);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 8,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let dev = DeviceProfile::cortex_a53();
        let sel = select_engine(&f, &ds.x[..ds.d * 64], Some(&dev), 1).unwrap();
        assert!(sel.candidates.iter().all(|c| c.device_us_per_instance.is_some()));
        assert!(sel.device.as_deref().unwrap().contains("A53"));
    }

    #[test]
    fn thread_budget_enumeration() {
        assert_eq!(thread_budgets(1), vec![1]);
        assert_eq!(thread_budgets(2), vec![1, 2]);
        assert_eq!(thread_budgets(4), vec![1, 2, 4]);
        assert_eq!(thread_budgets(6), vec![1, 2, 4, 6]);
    }

    #[test]
    fn threaded_candidates_enumerated_and_named() {
        let ds = DatasetId::Magic.generate(400, 23);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 12,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let sel = select_engine_with(&f, &ds.x[..ds.d * 128], None, 1, &[1, 2]).unwrap();
        // Every registered variant (plus the per-tree candidate) × 2
        // budgets (count derived from the engine registry, not a literal).
        assert_eq!(
            sel.candidates.len(),
            2 * (crate::engine::all_variants_with_i8().len() + 1)
        );
        assert!(sel.candidates.iter().any(|c| c.threads == 2 && c.name.ends_with("×2t")));
        assert!(sel.candidates.iter().any(|c| c.threads == 1 && c.name == "RS"));
        assert!(sel.candidates.iter().any(|c| c.threads == 2 && c.name == "qVQS+pt×2t"));
    }
}
