//! Dynamic batcher fused with the exec scheduler: request chunks flow from
//! the batch assembler straight onto the shared pool's worker deques.
//!
//! The paper's SIMD engines evaluate `v` instances per block (VQS v=4/8,
//! RS v=16, the int8 tier v=16); serving one request at a time would waste
//! (v-1)/v of each register. The batcher collects requests until either
//! `max_batch` is reached or the oldest request has waited `max_delay`.
//! Historically a flush then called `predict_batch` on a private worker
//! thread, and a `ParallelEngine` underneath re-sharded the batch onto its
//! own private pool — two schedulers and one pool per deployment. The fused
//! design collapses both: a flush *plans* lane-aligned row chunks (the same
//! `exec::shard` math) and enqueues one shard task per chunk directly onto
//! the deployment's [`PoolClient`]; whichever worker finishes a batch's
//! last chunk pairs the score rows back onto their requesters. The
//! collector thread never executes model code, so collection continues
//! while shards run.
//!
//! # Adaptive planning (DESIGN.md §7)
//!
//! Chunk weights start at the pool topology's prior
//! (`chunk_weights(pool.topology(), budget)` — the same assignment workers
//! are pinned by, see `exec::pool`) and, with [`BatchConfig::adaptive`]
//! (default on), are re-derived from **measured** per-slot shard
//! throughput every [`REPLAN_EVERY_FLUSHES`] flushes: each executed chunk
//! reports `(slot, rows, µs)` into an [`crate::exec::Feedback`] EWMA. A
//! topology guess that is wrong — or becomes wrong (throttling,
//! co-tenants) — is corrected by the loop instead of persisting for the
//! deployment's lifetime.
//!
//! # Determinism
//!
//! Chunk boundaries are lane-aligned (`ShardPolicy::Exact` row plans only),
//! so each chunk's SIMD blocking is exactly the serial blocking of those
//! rows: every request's scores are **bit-identical** to a serial
//! `Engine::predict_batch` over the same assembled batch — regardless of
//! pool size, per-deployment budget, or concurrent deployments. Adaptive
//! re-planning preserves this: weights change only chunk **sizes**, never
//! the lane alignment that the contract rests on.
//!
//! # Backpressure and shutdown
//!
//! The submit queue is bounded: when full, `submit` fails fast with
//! [`ServeError::Overloaded`]. Shutdown is a *drain*, not a race: dropping
//! the batcher stops intake, replies [`ServeError::Shutdown`] to every
//! request still queued or assembling (they would otherwise race teardown),
//! and blocks until every already-flushed batch has delivered its real
//! replies before the pool client unregisters. With
//! [`BatchConfig::drain_timeout`] set, that wait is bounded: straggler
//! batches (a slow or hung engine) are downgraded to
//! [`ServeError::Internal`] at the deadline and pool teardown moves to a
//! detached reaper thread, so undeploy/redeploy cannot stall forever.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use crate::engine::Engine;
use crate::obs::span::{self, SpanTimer};
use crate::exec::pool::{MutPtr, Task};
use crate::exec::{
    chunk_weights, weighted_row_chunks_slotted, Feedback, PoolClient, SharedPool,
};

/// With [`BatchConfig::adaptive`] set, chunk weights are re-derived from
/// the feedback loop's measured shard throughput every this many flushes.
pub const REPLAN_EVERY_FLUSHES: u64 = 32;

/// Server-wide accounting of detached drain-reaper threads (ISSUE 5
/// satellite; ROADMAP item exposed by the PR 4 drain deadline).
///
/// A drain-timeout abandon hands pool teardown to a detached reaper so a
/// hung engine cannot stall undeploy — but a *permanently* hung engine
/// parks that reaper forever, leaking one thread per abandon. This
/// registry caps the process-wide number of live reapers at
/// [`reaper::CAP`]: past the cap, teardown contexts are leaked outright
/// (no thread), and the refusal is counted. `Server::report` surfaces all
/// three counters; per-deployment spawns land in
/// [`Metrics::reaper_threads`].
pub mod reaper {
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    /// Max live reaper threads process-wide. Each parked reaper costs one
    /// OS thread (~8 KiB kernel + default stack mapping, mostly untouched)
    /// — 64 bounds the damage of a pathological hung-engine storm while
    /// never binding in healthy operation (reapers exit as soon as their
    /// stragglers finish).
    pub const CAP: usize = 64;

    static LIVE: AtomicUsize = AtomicUsize::new(0);
    static SPAWNED: AtomicU64 = AtomicU64::new(0);
    static REFUSED: AtomicU64 = AtomicU64::new(0);

    /// Reaper threads currently parked on straggler drains.
    pub fn live() -> usize {
        LIVE.load(Ordering::SeqCst)
    }

    /// Reaper threads ever spawned (monotone).
    pub fn spawned() -> u64 {
        SPAWNED.load(Ordering::SeqCst)
    }

    /// Abandons that could not get a reaper (cap hit or spawn failure):
    /// their teardown context was leaked without a tracking thread.
    pub fn refused() -> u64 {
        REFUSED.load(Ordering::SeqCst)
    }

    /// Reserve a reaper slot; `false` at the cap (counted as refused).
    pub(super) fn try_begin() -> bool {
        loop {
            let cur = LIVE.load(Ordering::SeqCst);
            if cur >= CAP {
                REFUSED.fetch_add(1, Ordering::SeqCst);
                return false;
            }
            if LIVE.compare_exchange(cur, cur + 1, Ordering::SeqCst, Ordering::SeqCst).is_ok()
            {
                SPAWNED.fetch_add(1, Ordering::SeqCst);
                return true;
            }
        }
    }

    /// Release a slot (reaper finished, or its spawn failed).
    pub(super) fn end() {
        LIVE.fetch_sub(1, Ordering::SeqCst);
    }

    /// A spawn that was counted but never ran converts to a refusal: the
    /// live slot is released and the spawned count rolled back, so
    /// `spawned()` only ever counts reaper threads that actually exist(ed).
    pub(super) fn spawn_failed() {
        end();
        SPAWNED.fetch_sub(1, Ordering::SeqCst);
        REFUSED.fetch_add(1, Ordering::SeqCst);
    }
}

/// Batching configuration.
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Maximum instances per executed batch (rounded up to the engine's
    /// lane width internally).
    pub max_batch: usize,
    /// Maximum time the oldest queued request may wait before a flush.
    pub max_delay: Duration,
    /// Bounded queue capacity (backpressure limit).
    pub queue_cap: usize,
    /// **Deprecated alias** for [`BatchConfig::exec_threads`]: the
    /// pre-fusion batcher ran this many private `predict_batch` worker
    /// threads. The fused scheduler has none — the effective thread budget
    /// is `max(workers, exec_threads)` (see [`BatchConfig::thread_budget`]).
    pub workers: usize,
    /// Exec thread budget: the deployment's worker entitlement on the
    /// shared pool (weighted fair stealing; see [`crate::exec::SharedPool`])
    /// and the number of slots its flushes are chunked for.
    pub exec_threads: usize,
    /// Upper bound on how long the shutdown drain waits for in-flight
    /// flushes. `None` (default) waits unboundedly — the pre-deadline
    /// behavior, where a hung engine stalls undeploy forever. With a
    /// deadline, straggler batches are downgraded: their requesters
    /// receive [`ServeError::Internal`] immediately (counted in
    /// `Metrics::failed`), and pool teardown is handed to a detached
    /// reaper thread (capped and counted by [`reaper`]) so the drop
    /// returns.
    pub drain_timeout: Option<Duration>,
    /// Adaptive shard planning (default **on**): executed chunks report
    /// measured throughput into an [`crate::exec::Feedback`] loop, and
    /// chunk weights are re-derived every [`REPLAN_EVERY_FLUSHES`] flushes
    /// — construction-time topology weights are only the prior. Plans stay
    /// lane-aligned Exact row chunks throughout, so replies remain
    /// bit-identical to serial execution (the batcher's determinism
    /// contract is unaffected; only chunk *sizes* adapt).
    pub adaptive: bool,
}

impl BatchConfig {
    /// The deployment's effective exec thread budget: `exec_threads`, with
    /// the deprecated `workers` knob folded in for old callers (≥ 1).
    pub fn thread_budget(&self) -> usize {
        self.exec_threads.max(self.workers).max(1)
    }
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_batch: 64,
            max_delay: Duration::from_micros(500),
            queue_cap: 4096,
            workers: 1,
            exec_threads: 1,
            drain_timeout: None,
            adaptive: true,
        }
    }
}

/// One queued request.
pub struct Request {
    pub x: Vec<f32>,
    pub enqueued: Instant,
    /// Absolute client deadline: once passed, the request's reply is
    /// worthless, so the collector sheds it at flush time instead of
    /// spending pool SIMD lanes on it ([`ServeError::DeadlineExceeded`]).
    /// `None` = wait however long serving takes (the pre-ISSUE-10
    /// contract).
    pub deadline: Option<Instant>,
    pub reply: mpsc::Sender<Result<Vec<f32>, ServeError>>,
}

impl Request {
    /// Whether the client deadline has passed as of `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Serving errors surfaced to clients.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    Overloaded,
    Shutdown,
    BadInput(String),
    /// The request's client deadline passed before execution started (at
    /// admission, or while it waited in the queue); it was shed without
    /// touching the pool.
    DeadlineExceeded,
    /// A shard task died mid-batch (engine panic); the request was executed
    /// but its scores are not trustworthy.
    Internal,
}

impl ServeError {
    /// Stable machine-readable code for the wire protocol (`net`): clients
    /// key retry policy off this, never off the human message.
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::Overloaded => "overloaded",
            ServeError::Shutdown => "shutdown",
            ServeError::BadInput(_) => "bad_input",
            ServeError::DeadlineExceeded => "deadline",
            ServeError::Internal => "internal",
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded => write!(f, "queue full (backpressure)"),
            ServeError::Shutdown => write!(f, "model is shutting down"),
            ServeError::BadInput(msg) => write!(f, "bad input: {msg}"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before execution"),
            ServeError::Internal => write!(f, "internal execution error"),
        }
    }
}

impl std::error::Error for ServeError {}

/// A running batcher for one deployment.
pub struct Batcher {
    tx: SyncSender<Request>,
    collector: Option<std::thread::JoinHandle<()>>,
    /// `Option` so the drain-deadline path can hand the context (and with
    /// it the pool client / pool teardown) to a detached reaper thread
    /// instead of blocking the drop on a hung worker.
    ctx: Option<Arc<FlushCtx>>,
    /// Set by `Drop` before closing `tx`: the collector must shed — not
    /// execute — everything still queued, even if a full batch's worth is
    /// buffered in the channel.
    closing: Arc<AtomicBool>,
    pub metrics: Arc<Metrics>,
    n_features: usize,
    budget: usize,
    drain_timeout: Option<Duration>,
}

impl Batcher {
    /// Standalone batcher: spawns a private pool sized to the config's
    /// thread budget. Server deployments share one pool instead — see
    /// [`Batcher::start_shared`].
    pub fn start(engine: Arc<dyn Engine>, config: BatchConfig) -> Batcher {
        let pool = SharedPool::new(config.thread_budget());
        let client = SharedPool::register(&pool, "batcher", config.thread_budget());
        Self::start_with_client(engine, client, config)
    }

    /// Batcher fused onto a server-shared pool: flushes enqueue lane-aligned
    /// shard tasks under `label`'s registration, with
    /// `config.thread_budget()` as the deployment's budget.
    pub fn start_shared(
        engine: Arc<dyn Engine>,
        pool: &Arc<SharedPool>,
        label: &str,
        config: BatchConfig,
    ) -> Batcher {
        let client = SharedPool::register(pool, label, config.thread_budget());
        Self::start_with_client(engine, client, config)
    }

    fn start_with_client(
        engine: Arc<dyn Engine>,
        client: PoolClient,
        config: BatchConfig,
    ) -> Batcher {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::sync_channel::<Request>(config.queue_cap);

        // Round the batch size up to a lane multiple so SIMD blocks are full.
        let lanes = engine.lanes().max(1);
        let max_batch = config.max_batch.div_ceil(lanes) * lanes;
        let budget = client.budget();
        // The chunk-slot weight *prior* comes from the pool's own topology
        // (so plans agree with worker placement/pinning — and the
        // feedback's class attribution lines up with the pool's worker
        // classes); with `config.adaptive` the live weights are re-derived
        // from measured shard throughput every REPLAN_EVERY_FLUSHES
        // flushes.
        let weights = chunk_weights(client.pool().topology(), budget);
        let feedback = Arc::new(Feedback::for_pool(client.pool(), budget));

        let ctx = Arc::new(FlushCtx {
            engine: Mutex::new(engine.clone()),
            client,
            budget,
            feedback,
            weights: Mutex::new(weights),
            adaptive: config.adaptive,
            flushes: AtomicU64::new(0),
            metrics: metrics.clone(),
            inflight: Arc::new(Inflight {
                count: Mutex::new(0),
                idle: Condvar::new(),
                states: Mutex::new(Vec::new()),
            }),
        });
        let closing = Arc::new(AtomicBool::new(false));
        let collector = {
            let ctx = ctx.clone();
            let closing = closing.clone();
            std::thread::Builder::new()
                .name("batcher-collector".into())
                .spawn(move || collect_loop(rx, ctx, closing, max_batch, config.max_delay))
                .expect("spawn collector")
        };

        Batcher {
            tx,
            collector: Some(collector),
            ctx: Some(ctx),
            closing,
            metrics,
            n_features: engine.n_features(),
            budget,
            drain_timeout: config.drain_timeout,
        }
    }

    /// Submit one instance; returns the reply channel. Fails fast under
    /// backpressure.
    pub fn submit(
        &self,
        x: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>, ServeError>>, ServeError> {
        self.submit_with_deadline(x, None)
    }

    /// [`Batcher::submit`] with an absolute client deadline: a request whose
    /// deadline has passed is refused at admission, and one that expires
    /// while queued is shed at flush time — either way it receives
    /// [`ServeError::DeadlineExceeded`] (counted in
    /// [`Metrics::deadline_exceeded`]) and never reaches the pool.
    pub fn submit_with_deadline(
        &self,
        x: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>, ServeError>>, ServeError> {
        if x.len() != self.n_features {
            return Err(ServeError::BadInput(format!(
                "expected {} features, got {}",
                self.n_features,
                x.len()
            )));
        }
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        if deadline.is_some_and(|d| Instant::now() >= d) {
            self.metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::DeadlineExceeded);
        }
        // `admission` span: validation through enqueue (recorded only for
        // accepted requests; an unfinished timer records nothing).
        let admission = SpanTimer::start("admission");
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request { x, enqueued: Instant::now(), deadline, reply: reply_tx };
        match self.tx.try_send(req) {
            Ok(()) => {
                admission.finish();
                Ok(reply_rx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(ServeError::Overloaded)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServeError::Shutdown),
        }
    }

    /// Submit and wait for the scores (convenience).
    pub fn predict(&self, x: Vec<f32>) -> Result<Vec<f32>, ServeError> {
        let rx = self.submit(x)?;
        rx.recv().map_err(|_| ServeError::Shutdown)?
    }

    /// The deployment's exec thread budget on its pool.
    pub fn thread_budget(&self) -> usize {
        self.budget
    }

    /// Adaptive re-plans performed so far (0 when `adaptive` is off or the
    /// budget is 1 — diagnostics for the feedback loop).
    pub fn replans(&self) -> u64 {
        self.ctx.as_ref().map_or(0, |c| c.feedback.replans())
    }

    /// The feedback loop's current per-class EWMA throughputs (rows/µs;
    /// `None` = class never observed). Introspection for `stats --json`.
    pub fn class_rates(&self) -> Vec<Option<f64>> {
        self.ctx.as_ref().map_or_else(Vec::new, |c| c.feedback.class_rates())
    }

    /// The engine currently serving flushes — the primary, or the degrade
    /// fallback while degraded ([`Batcher::swap_engine`]).
    pub fn engine(&self) -> Option<Arc<dyn Engine>> {
        self.ctx.as_ref().map(|c| c.current_engine())
    }

    /// Swap the serving engine (degradation enter/exit). In-flight flushes
    /// finish on the engine they captured at flush time; only *later*
    /// flushes see the replacement — so the determinism contract (replies
    /// bit-identical to a serial `predict_batch` on the engine that served
    /// them) holds on both sides of the swap. The replacement must serve
    /// the same model shape (feature/class counts) or the swap is refused.
    pub fn swap_engine(&self, engine: Arc<dyn Engine>) -> Result<(), ServeError> {
        let Some(ctx) = self.ctx.as_ref() else {
            return Err(ServeError::Shutdown);
        };
        let cur = ctx.current_engine();
        if engine.n_features() != cur.n_features() || engine.n_classes() != cur.n_classes() {
            return Err(ServeError::BadInput(format!(
                "engine shape mismatch: {}×{} features/classes, deployment serves {}×{}",
                engine.n_features(),
                engine.n_classes(),
                cur.n_features(),
                cur.n_classes()
            )));
        }
        *ctx.engine.lock().unwrap() = engine;
        Ok(())
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // 1. Stop intake: the flag makes the collector shed instead of
        //    flush (a channel backlog ≥ max_batch would otherwise still
        //    assemble into executable batches), and closing `tx` wakes it.
        self.closing.store(true, Ordering::Release);
        drop(std::mem::replace(&mut self.tx, {
            let (t, _r) = mpsc::sync_channel(1);
            t
        }));
        if let Some(c) = self.collector.take() {
            let _ = c.join();
        }
        // 2. Drain: wait for already-flushed batches so every accepted
        //    request receives its real reply before the pool client (owned
        //    by `ctx`) unregisters.
        let Some(ctx) = self.ctx.take() else { return };
        match self.drain_timeout {
            None => ctx.inflight.wait_idle(),
            Some(deadline) => {
                if !ctx.inflight.wait_idle_timeout(deadline) {
                    // Deadline expired with flushes still outstanding: a
                    // slow or hung engine must not stall undeploy. Every
                    // straggler batch is claimed and its requesters get an
                    // immediate `Internal` (their scores, if they ever
                    // materialize, are discarded by the `replied` guard).
                    ctx.inflight.abandon_stragglers();
                    // Pool teardown (client unregister; for standalone
                    // batchers the whole pool, whose drop joins workers)
                    // would block on the hung task — hand the last ctx
                    // reference to a detached reaper instead. If the
                    // engine never returns, the reaper leaks one parked
                    // thread; the deployment itself is gone either way.
                    // Reapers are capped and counted process-wide (the
                    // `reaper` registry): at the cap, or on spawn failure
                    // (thread exhaustion), the context is *leaked* without
                    // a thread — tearing it down inline would re-introduce
                    // the unbounded stall the deadline exists to prevent.
                    if !reaper::try_begin() {
                        std::mem::forget(ctx);
                        return;
                    }
                    self.metrics.reaper_threads.fetch_add(1, Ordering::Relaxed);
                    struct LeakOnDrop(Option<Arc<FlushCtx>>, Arc<Metrics>);
                    impl Drop for LeakOnDrop {
                        fn drop(&mut self) {
                            // Only reached if the closure below never ran
                            // (spawn failure): leak the context, convert
                            // the counted spawn to a refusal, and roll the
                            // per-deployment metric back so accounting
                            // only ever reflects threads that existed.
                            if let Some(c) = self.0.take() {
                                std::mem::forget(c);
                                reaper::spawn_failed();
                                self.1.reaper_threads.fetch_sub(1, Ordering::Relaxed);
                            }
                        }
                    }
                    let guard = LeakOnDrop(Some(ctx), self.metrics.clone());
                    let _ = std::thread::Builder::new()
                        .name("batcher-drain-reaper".into())
                        .spawn(move || {
                            let mut guard = guard;
                            let ctx = guard.0.take().expect("guard holds the context");
                            ctx.inflight.wait_idle();
                            drop(ctx);
                            reaper::end();
                        });
                }
            }
        }
    }
}

/// Everything a flush needs, shared by the batcher handle and the collector
/// thread. Owns the deployment's pool client — and is deliberately **not**
/// referenced by in-flight shard tasks (they hold only engine / metrics /
/// inflight handles), so pool teardown can never run on, and self-join, a
/// worker thread.
struct FlushCtx {
    /// The deployment's live engine. The degrade controller swaps this
    /// between flushes (enter: fallback tier, exit: primary); each flush
    /// captures one engine for its whole lifetime, and plans lane-aligned
    /// chunks for *that* engine — the determinism contract (replies
    /// bit-identical to a serial `predict_batch` on the same engine) holds
    /// on both sides of a swap.
    engine: Mutex<Arc<dyn Engine>>,
    client: PoolClient,
    budget: usize,
    /// Live per-chunk-slot weights (2× budget slots, big cores first).
    /// Fixed at the topology prior when `adaptive` is off; re-derived from
    /// `feedback` every [`REPLAN_EVERY_FLUSHES`] flushes when on.
    weights: Mutex<Vec<f64>>,
    /// Measured per-slot shard throughput (EWMA) feeding re-plans.
    feedback: Arc<Feedback>,
    adaptive: bool,
    flushes: AtomicU64,
    metrics: Arc<Metrics>,
    inflight: Arc<Inflight>,
}

impl FlushCtx {
    /// Clone out the live engine (the guard dies inside the call — flushes
    /// never hold the slot lock across planning or execution).
    fn current_engine(&self) -> Arc<dyn Engine> {
        self.engine.lock().unwrap().clone()
    }
}

/// Shutdown-drain latch: flushed-but-incomplete batch count, plus weak
/// handles to the in-flight batches so a drain deadline can downgrade
/// stragglers.
struct Inflight {
    count: Mutex<usize>,
    idle: Condvar,
    states: Mutex<Vec<std::sync::Weak<FlushState>>>,
}

impl Inflight {
    fn begin(&self, state: &Arc<FlushState>) {
        *self.count.lock().unwrap() += 1;
        let mut states = self.states.lock().unwrap();
        states.retain(|w| w.strong_count() > 0);
        states.push(Arc::downgrade(state));
    }

    fn end(&self) {
        let mut n = self.count.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.idle.notify_all();
        }
    }

    /// Block until no flushed batch is outstanding.
    fn wait_idle(&self) {
        let mut n = self.count.lock().unwrap();
        while *n > 0 {
            n = self.idle.wait(n).unwrap();
        }
    }

    /// Like [`Inflight::wait_idle`] with an upper bound; returns whether
    /// the drain completed (false: stragglers remain).
    fn wait_idle_timeout(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut n = self.count.lock().unwrap();
        while *n > 0 {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.idle.wait_timeout(n, deadline - now).unwrap();
            n = guard;
        }
        true
    }

    /// Downgrade every still-in-flight batch: claim its reply right
    /// (`replied`) and answer `Internal` now. A straggler chunk that later
    /// finishes sees the claim in `FlushState::complete` and only releases
    /// its latch slot.
    fn abandon_stragglers(&self) {
        // Snapshot the live states first: replying on a requester's channel
        // can run arbitrary receiver-side code, and holding the registry
        // lock across it would deadlock against a chunk completing (the
        // audit's lock-span lint enforces this shape).
        let live: Vec<Arc<FlushState>> = {
            let states = self.states.lock().unwrap();
            states.iter().filter_map(|w| w.upgrade()).collect()
        };
        for st in live {
            if st.replied.swap(true, Ordering::AcqRel) {
                continue; // completed (or already abandoned) concurrently
            }
            st.metrics.failed.fetch_add(st.requests.len() as u64, Ordering::Relaxed);
            for r in &st.requests {
                let _ = r.reply.send(Err(ServeError::Internal));
            }
        }
    }
}

/// Enqueue one assembled batch as lane-aligned shard tasks on the
/// deployment's pool client. Never blocks on execution.
fn flush_batch(ctx: &Arc<FlushCtx>, mut batch: Vec<Request>) {
    let n = batch.len();
    if n == 0 {
        return;
    }
    // One engine per flush: captured here, used for planning, execution
    // and reply pairing alike (a concurrent degrade swap only affects
    // *later* flushes).
    let engine = ctx.current_engine();
    let d = engine.n_features();
    let c = engine.n_classes();
    let lanes = engine.lanes().max(1);
    // `flush_plan` span: input concatenation plus chunk apportionment —
    // everything between batch assembly and the tasks hitting the pool.
    let plan_span = SpanTimer::start("flush_plan");
    // Drain (not copy) each row into the concatenated buffer: the rows are
    // never read again (replies only need `reply`/`enqueued`), and a batch
    // stays alive for its whole pool lifetime — no point pinning two
    // copies of the input.
    let mut x = Vec::with_capacity(n * d);
    for r in &mut batch {
        x.append(&mut r.x);
    }
    // Budget 1 never shards; skip the apportionment math on that hot path
    // (mirrors ParallelEngine's threads <= 1 early-out).
    let chunks = if ctx.budget <= 1 {
        vec![(0, n, 0)]
    } else {
        let planned = {
            let weights = ctx.weights.lock().unwrap();
            weighted_row_chunks_slotted(n, lanes, &weights)
        };
        if planned.len() <= 1 {
            vec![(0, n, 0)]
        } else {
            planned
        }
    };
    plan_span.finish_with("chunks", chunks.len() as f64);
    // Stamped once per flush (tracing on only): each chunk task measures
    // `queue_wait` — pool time between planning and its first instruction.
    let planned_at = span::now_if_enabled();
    // Feedback only learns from genuinely sharded flushes (a lone chunk
    // measures batch arrival, not relative slot speed).
    let record = ctx.adaptive && chunks.len() > 1;
    let state = Arc::new(FlushState {
        engine,
        metrics: ctx.metrics.clone(),
        inflight: ctx.inflight.clone(),
        x,
        out: UnsafeCell::new(vec![0f32; n * c]),
        requests: batch,
        remaining: AtomicUsize::new(chunks.len()),
        failed: AtomicBool::new(false),
        replied: AtomicBool::new(false),
        exec_start: Mutex::new(None),
    });
    ctx.inflight.begin(&state);
    // SAFETY: the base pointer is taken once, pre-spawn, while this thread
    // is the sole owner of `out`; tasks do raw offset writes into disjoint
    // `[a*c, b*c)` ranges and never read, so no aliasing write overlaps.
    let out_ptr = MutPtr(unsafe { (*state.out.get()).as_mut_ptr() });
    let tasks: Vec<Task> = chunks
        .into_iter()
        .map(|(a, b, slot)| {
            let st = state.clone();
            let feedback = record.then(|| ctx.feedback.clone());
            Box::new(move || {
                // The guard publishes chunk completion even if the engine
                // panics, so a batch can never strand its requesters or
                // the shutdown drain.
                let guard = ChunkGuard { st };
                let st = &guard.st;
                if let Some(t0) = planned_at {
                    span::record_between(
                        "queue_wait",
                        t0,
                        Instant::now(),
                        Some(("rows", (b - a) as f64)),
                    );
                }
                // Batch execution time is measured from the *first chunk
                // starting* to the last finishing — pool queue wait (which
                // grows with multi-deployment contention) belongs to
                // request latency, not `batch_us`.
                {
                    let mut t0 = st.exec_start.lock().unwrap();
                    if t0.is_none() {
                        *t0 = Some(Instant::now());
                    }
                }
                let xs = &st.x[a * d..b * d];
                // SAFETY: chunks are disjoint, in-bounds row ranges of
                // `out`, and the buffer outlives every task (owned by the
                // Arc each task holds).
                let os =
                    unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(a * c), (b - a) * c) };
                // Same clock discipline as the selector's candidate timing
                // (wall-clock Stopwatch around the engine call) so the
                // feedback EWMA and the selector measure the same thing.
                // `shard_exec` span: the engine call itself, tagged with
                // the executing worker's topology class at record time.
                let exec_span = SpanTimer::start("shard_exec");
                let t0 = feedback.is_some().then(|| st.engine.cost_counters()).flatten();
                let sw = crate::util::Stopwatch::start();
                st.engine.predict_batch(xs, os);
                exec_span.finish_with("rows", (b - a) as f64);
                if let Some(f) = feedback {
                    f.record(slot, b - a, sw.micros());
                    // Heterogeneous per-task cost: early-exit engines report
                    // cumulative (rows, tree evals); the EWMA delta feeds
                    // `Feedback::trees_per_row` (concurrent chunks may blend
                    // deltas — fine for an EWMA).
                    if let (Some((r0, e0)), Some((r1, e1))) = (t0, st.engine.cost_counters()) {
                        f.record_trees(e1.saturating_sub(e0), r1.saturating_sub(r0));
                    }
                }
            }) as Task
        })
        .collect();
    ctx.client.spawn(tasks);
    // Re-plan tick: fold measured throughput back into the weights every
    // N flushes (off the per-chunk path; one lock swap per N flushes).
    if record {
        let flushed = ctx.flushes.fetch_add(1, Ordering::Relaxed) + 1;
        if flushed % REPLAN_EVERY_FLUSHES == 0 {
            *ctx.weights.lock().unwrap() = ctx.feedback.replan();
        }
    }
}

/// One flushed batch in flight on the pool. Holds no pool references (see
/// [`FlushCtx`]).
struct FlushState {
    engine: Arc<dyn Engine>,
    metrics: Arc<Metrics>,
    inflight: Arc<Inflight>,
    x: Vec<f32>,
    /// Written by chunk tasks through raw pointers into disjoint ranges;
    /// read by `complete` strictly after the `remaining` AcqRel chain.
    out: UnsafeCell<Vec<f32>>,
    requests: Vec<Request>,
    remaining: AtomicUsize,
    failed: AtomicBool,
    /// Reply-right claim: exactly one of the completing worker and the
    /// drain-deadline abandon path answers the requesters (whoever swaps
    /// this first).
    replied: AtomicBool,
    /// Stamped by whichever chunk starts executing first.
    exec_start: Mutex<Option<Instant>>,
}

// SAFETY: `out` is only mutated through disjoint, planner-assigned ranges,
// and only read after all writers completed (see `remaining`).
unsafe impl Sync for FlushState {}

impl FlushState {
    /// Runs on whichever worker finishes the batch's last chunk: pair score
    /// rows back onto their requesters, record metrics, release the
    /// in-flight slot.
    fn complete(&self) {
        if self.replied.swap(true, Ordering::AcqRel) {
            // The drain deadline already answered these requesters with
            // `Internal` — discard the late scores, release the latch slot.
            self.inflight.end();
            return;
        }
        let now = Instant::now();
        if self.failed.load(Ordering::Acquire) {
            // A chunk panicked: these requests ran but their scores are
            // not trustworthy. They count as failures — not completions —
            // so stats cannot report a 100% success rate after a panic.
            self.metrics.failed.fetch_add(self.requests.len() as u64, Ordering::Relaxed);
            for r in &self.requests {
                let _ = r.reply.send(Err(ServeError::Internal));
            }
            self.inflight.end();
            return;
        }
        let c = self.engine.n_classes();
        let exec_start = *self.exec_start.lock().unwrap();
        let exec_us = exec_start
            .map(|t0| now.duration_since(t0).as_secs_f64() * 1e6)
            .unwrap_or(0.0);
        self.metrics.record_batch(self.requests.len(), exec_us);
        // `reply` span: pairing score rows back onto their requesters.
        let reply_span = SpanTimer::start("reply");
        // SAFETY: every chunk writer finished (the final `remaining`
        // decrement, AcqRel, happens-before this call).
        let out = unsafe { &*self.out.get() };
        for (i, r) in self.requests.iter().enumerate() {
            self.metrics
                .record_latency(now.duration_since(r.enqueued).as_secs_f64() * 1e6);
            let _ = r.reply.send(Ok(out[i * c..(i + 1) * c].to_vec()));
        }
        reply_span.finish_with("rows", self.requests.len() as f64);
        self.inflight.end();
    }
}

/// Publishes one chunk's completion on drop — including panic unwinds.
struct ChunkGuard {
    st: Arc<FlushState>,
}

impl Drop for ChunkGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.st.failed.store(true, Ordering::Release);
        }
        if self.st.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.st.complete();
        }
    }
}

fn collect_loop(
    rx: Receiver<Request>,
    ctx: Arc<FlushCtx>,
    closing: Arc<AtomicBool>,
    max_batch: usize,
    max_delay: Duration,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(max_batch);
    loop {
        if pending.is_empty() {
            // Block for the first request (or shutdown with an empty queue).
            match rx.recv() {
                Ok(r) => pending.push(r),
                Err(_) => return,
            }
        }
        // `assemble` span: from the first queued request to the flush (or
        // nothing, if shutdown sheds the batch instead).
        let assemble_start = span::now_if_enabled();
        // Fill until max_batch or the oldest request's deadline.
        let deadline = pending[0].enqueued + max_delay;
        while pending.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    // The channel is closed *and* empty: `pending` holds
                    // every accepted-but-unflushed request.
                    shed_all(&ctx, pending, &rx);
                    return;
                }
            }
        }
        // Shutdown drain: once the batcher is closing, *nothing* unflushed
        // executes — including a channel backlog big enough to assemble
        // into full batches. Shedding must win that race, not lose it.
        if closing.load(Ordering::Acquire) {
            shed_all(&ctx, pending, &rx);
            return;
        }
        if let Some(t0) = assemble_start {
            span::record_between(
                "assemble",
                t0,
                Instant::now(),
                Some(("rows", pending.len() as f64)),
            );
        }
        // Flush-time deadline shed: a reply nobody is waiting for must not
        // burn SIMD lanes. Per-request, so the rest of the batch still
        // executes; an empty remainder skips the flush entirely.
        shed_expired(&ctx, &mut pending);
        flush_batch(&ctx, std::mem::take(&mut pending));
    }
}

/// Shed every expired request out of an assembled batch (the flush-time
/// deadline check): each receives [`ServeError::DeadlineExceeded`] now, the
/// unexpired remainder stays in `pending` in arrival order.
fn shed_expired(ctx: &FlushCtx, pending: &mut Vec<Request>) {
    let now = Instant::now();
    if pending.iter().all(|r| !r.expired(now)) {
        return; // hot path: nothing expired, no reshuffle
    }
    for r in std::mem::take(pending) {
        if r.expired(now) {
            shed_deadline(ctx, r);
        } else {
            pending.push(r);
        }
    }
}

fn shed_deadline(ctx: &FlushCtx, r: Request) {
    ctx.metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
    let _ = r.reply.send(Err(ServeError::DeadlineExceeded));
}

/// Reply `Shutdown` to every request that will never execute: the assembled
/// batch plus anything still buffered in the channel.
fn shed_all(ctx: &FlushCtx, pending: Vec<Request>, rx: &Receiver<Request>) {
    for r in pending {
        shed(ctx, r);
    }
    while let Ok(r) = rx.try_recv() {
        shed(ctx, r);
    }
}

fn shed(ctx: &FlushCtx, r: Request) {
    ctx.metrics.shed_shutdown.fetch_add(1, Ordering::Relaxed);
    let _ = r.reply.send(Err(ServeError::Shutdown));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::engine::{build, EngineKind, Precision};
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    fn engine() -> (Arc<dyn Engine>, crate::data::Dataset) {
        let ds = DatasetId::Magic.generate(400, 55);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 8,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        (Arc::from(build(EngineKind::Rs, Precision::F32, &f, None).unwrap()), ds)
    }

    #[test]
    fn batched_results_match_direct() {
        let (eng, ds) = engine();
        let direct = eng.predict(&ds.x[..ds.d * 20]);
        let b = Batcher::start(eng.clone(), BatchConfig::default());
        // Submit 20 requests concurrently, gather replies in order.
        let replies: Vec<_> =
            (0..20).map(|i| b.submit(ds.row(i).to_vec()).unwrap()).collect();
        for (i, r) in replies.into_iter().enumerate() {
            let scores = r.recv().unwrap().unwrap();
            assert_eq!(&scores[..], &direct[i * ds.n_classes..(i + 1) * ds.n_classes]);
        }
    }

    #[test]
    fn fused_multichunk_flush_is_bit_exact() {
        // A budget > 1 splits flushes into several lane-aligned shard tasks;
        // replies must still be bit-identical to the serial engine.
        let (eng, ds) = engine();
        let direct = eng.predict(&ds.x[..ds.d * 50]);
        let b = Batcher::start(
            eng.clone(),
            BatchConfig {
                max_batch: 64,
                max_delay: Duration::from_millis(2),
                queue_cap: 4096,
                workers: 1,
                exec_threads: 4,
                drain_timeout: None,
                adaptive: true,
            },
        );
        assert_eq!(b.thread_budget(), 4);
        let replies: Vec<_> =
            (0..50).map(|i| b.submit(ds.row(i).to_vec()).unwrap()).collect();
        for (i, r) in replies.into_iter().enumerate() {
            let scores = r.recv().unwrap().unwrap();
            assert_eq!(&scores[..], &direct[i * ds.n_classes..(i + 1) * ds.n_classes]);
        }
    }

    #[test]
    fn rejects_wrong_width() {
        let (eng, _) = engine();
        let b = Batcher::start(eng, BatchConfig::default());
        let err = b.submit(vec![0.0; 3]).unwrap_err();
        assert!(matches!(err, ServeError::BadInput(_)));
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let (eng, ds) = engine();
        // Tiny queue + long delay so the queue definitely fills.
        let b = Batcher::start(
            eng,
            BatchConfig {
                max_batch: 1024,
                max_delay: Duration::from_millis(250),
                queue_cap: 4,
                workers: 1,
                exec_threads: 1,
                drain_timeout: None,
                adaptive: true,
            },
        );
        let mut overloaded = false;
        let mut replies = Vec::new();
        for i in 0..64 {
            match b.submit(ds.row(i % ds.n).to_vec()) {
                Ok(r) => replies.push(r),
                Err(ServeError::Overloaded) => {
                    overloaded = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(overloaded, "queue_cap=4 must trigger backpressure");
        // Queued requests still complete.
        for r in replies {
            r.recv().unwrap().unwrap();
        }
    }

    #[test]
    fn metrics_progress() {
        let (eng, ds) = engine();
        let b = Batcher::start(eng, BatchConfig::default());
        for i in 0..10 {
            b.predict(ds.row(i).to_vec()).unwrap();
        }
        assert_eq!(b.metrics.completed.load(Ordering::Relaxed), 10);
        assert!(b.metrics.mean_batch_size() >= 1.0);
    }

    /// The adaptive loop engages end-to-end in serving: sharded flushes
    /// record shard throughput, weights are re-derived on the flush
    /// schedule, and replies stay bit-identical to the serial engine
    /// across re-plan boundaries (the batcher's determinism contract).
    #[test]
    fn adaptive_batcher_replans_and_stays_bit_exact() {
        let (_, ds) = engine();
        // Naive f32 has lanes == 1, so even small flushes shard across the
        // budget-2 slots and count toward the re-plan schedule.
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 8,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let naive: Arc<dyn Engine> =
            Arc::from(build(EngineKind::Naive, Precision::F32, &f, None).unwrap());
        let direct = naive.predict(&ds.x);
        let b = Batcher::start(
            naive.clone(),
            BatchConfig {
                max_batch: 8,
                max_delay: Duration::from_millis(2),
                queue_cap: 4096,
                workers: 1,
                exec_threads: 2,
                drain_timeout: None,
                adaptive: true,
            },
        );
        // 3× the re-plan interval in waves of 8; every reply must match
        // the serial engine bit-for-bit, before and after re-plans.
        let waves = 3 * REPLAN_EVERY_FLUSHES as usize;
        for w in 0..waves {
            let rows: Vec<usize> = (0..8).map(|i| (w * 8 + i) % ds.n).collect();
            let replies: Vec<_> =
                rows.iter().map(|&i| b.submit(ds.row(i).to_vec()).unwrap()).collect();
            for (&i, r) in rows.iter().zip(replies) {
                let scores = r.recv().unwrap().unwrap();
                assert_eq!(
                    &scores[..],
                    &direct[i * ds.n_classes..(i + 1) * ds.n_classes],
                    "row {i} diverged after adaptive re-planning"
                );
            }
        }
        assert!(b.replans() >= 1, "feedback loop never re-planned");
    }

    /// `adaptive: false` freezes the topology prior for the deployment's
    /// lifetime (the pre-ISSUE-5 behavior).
    #[test]
    fn adaptive_off_never_replans() {
        let (eng, ds) = engine();
        let b = Batcher::start(
            eng,
            BatchConfig {
                max_batch: 8,
                max_delay: Duration::from_micros(200),
                queue_cap: 4096,
                workers: 1,
                exec_threads: 2,
                drain_timeout: None,
                adaptive: false,
            },
        );
        for i in 0..64 {
            b.predict(ds.row(i % ds.n).to_vec()).unwrap();
        }
        assert_eq!(b.replans(), 0);
    }

    #[test]
    fn deprecated_workers_knob_folds_into_budget() {
        let cfg = BatchConfig { workers: 3, exec_threads: 1, ..BatchConfig::default() };
        assert_eq!(cfg.thread_budget(), 3);
        let cfg = BatchConfig { workers: 1, exec_threads: 4, ..BatchConfig::default() };
        assert_eq!(cfg.thread_budget(), 4);
        assert_eq!(BatchConfig::default().thread_budget(), 1);
    }

    #[test]
    fn shutdown_sheds_queued_requests() {
        // Regression (ISSUE 3): shutdown used to race in-flight flushes
        // with queued requests. It must drain: every accepted-but-unflushed
        // request gets an explicit `Shutdown` reply before the collector
        // exits, and the drop blocks until that has happened.
        let (eng, ds) = engine();
        let b = Batcher::start(
            eng,
            BatchConfig {
                max_batch: 1024,
                // Far deadline: nothing flushes before the drop below.
                max_delay: Duration::from_secs(30),
                queue_cap: 1024,
                workers: 1,
                exec_threads: 1,
                drain_timeout: None,
                adaptive: true,
            },
        );
        let metrics = b.metrics.clone();
        let replies: Vec<_> =
            (0..16).map(|i| b.submit(ds.row(i).to_vec()).unwrap()).collect();
        // Let the collector absorb some of the queue into its assembling
        // batch — the drain must cover both the channel and the assembly.
        std::thread::sleep(Duration::from_millis(20));
        drop(b);
        for r in replies {
            assert_eq!(r.recv().unwrap(), Err(ServeError::Shutdown));
        }
        assert_eq!(metrics.shed_shutdown.load(Ordering::Relaxed), 16);
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn shutdown_with_backlog_never_loses_replies() {
        // Drop mid-burst with small batches racing through the pipeline:
        // whatever was flushed before the close is served, everything else
        // is shed — and the two sets exactly partition the submissions
        // (nothing lost, nothing hung, nothing executed after shedding
        // began). Exercises the closing-flag path that stops a channel
        // backlog from assembling into executable batches at shutdown.
        let (eng, ds) = engine();
        let b = Batcher::start(
            eng,
            BatchConfig {
                max_batch: 1, // rounds up to one RS lane-block (16)
                max_delay: Duration::from_millis(5),
                queue_cap: 4096,
                workers: 1,
                exec_threads: 2,
                drain_timeout: None,
                adaptive: true,
            },
        );
        let metrics = b.metrics.clone();
        let replies: Vec<_> =
            (0..256).map(|i| b.submit(ds.row(i % ds.n).to_vec()).unwrap()).collect();
        drop(b);
        let mut served = 0u64;
        let mut shutdown = 0u64;
        for r in replies {
            match r.recv().unwrap() {
                Ok(_) => served += 1,
                Err(ServeError::Shutdown) => shutdown += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert_eq!(served + shutdown, 256);
        assert_eq!(metrics.completed.load(Ordering::Relaxed), served);
        assert_eq!(metrics.shed_shutdown.load(Ordering::Relaxed), shutdown);
    }

    /// An engine that blocks inside `predict_batch` until released —
    /// stands in for a hung/wedged model at shutdown.
    struct HangingEngine {
        inner: Arc<dyn Engine>,
        gate: Arc<AtomicBool>,
    }

    impl Engine for HangingEngine {
        fn name(&self) -> String {
            "hang".into()
        }
        fn lanes(&self) -> usize {
            self.inner.lanes()
        }
        fn n_features(&self) -> usize {
            self.inner.n_features()
        }
        fn n_classes(&self) -> usize {
            self.inner.n_classes()
        }
        fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
            while !self.gate.load(Ordering::Acquire) {
                std::thread::sleep(Duration::from_millis(1));
            }
            self.inner.predict_batch(x, out);
        }
    }

    /// Regression (ROADMAP, exposed by the fused drain): without a
    /// deadline, a hung engine stalls the batcher drop — and with it
    /// undeploy/redeploy — forever. With `drain_timeout` set, the drop
    /// returns at the deadline, stragglers' requesters get an immediate
    /// `Internal`, and the late real scores are discarded.
    #[test]
    fn drain_deadline_downgrades_hung_flushes() {
        let (inner, ds) = engine();
        let gate = Arc::new(AtomicBool::new(false));
        let reapers_before = reaper::spawned();
        let eng: Arc<dyn Engine> =
            Arc::new(HangingEngine { inner, gate: gate.clone() });
        let b = Batcher::start(
            eng,
            BatchConfig {
                max_batch: 4,
                max_delay: Duration::from_micros(100),
                queue_cap: 64,
                workers: 1,
                exec_threads: 1,
                drain_timeout: Some(Duration::from_millis(50)),
                adaptive: true,
            },
        );
        let metrics = b.metrics.clone();
        let replies: Vec<_> =
            (0..4).map(|i| b.submit(ds.row(i).to_vec()).unwrap()).collect();
        // Let the deadline flush the batch onto the (hung) pool.
        std::thread::sleep(Duration::from_millis(20));
        let t0 = Instant::now();
        drop(b); // must return at the drain deadline, not block on the hang
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "drop blocked past the drain deadline"
        );
        for r in replies {
            assert_eq!(r.recv().unwrap(), Err(ServeError::Internal));
        }
        assert_eq!(metrics.failed.load(Ordering::Relaxed), 4);
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 0);
        // Reaper accounting (ISSUE 5 satellite): the abandon handed
        // teardown to exactly one registered reaper thread, and the
        // deployment's metrics carry its share.
        assert_eq!(reaper::spawned() - reapers_before, 1);
        assert_eq!(metrics.reaper_threads.load(Ordering::Relaxed), 1);
        assert!(metrics.report().contains("reapers=1"), "{}", metrics.report());
        // Unhang the engine so the reaper can finish pool teardown; the
        // late completion must not double-reply or count as completed.
        gate.store(true, Ordering::Release);
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 0);
        // The reaper exits once its stragglers finish (live count drains).
        let deadline = Instant::now() + Duration::from_secs(5);
        while reaper::live() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(reaper::live(), 0, "reaper never released its slot");
    }

    /// A drain deadline generous enough for the work changes nothing:
    /// flushed batches still deliver real scores.
    #[test]
    fn drain_deadline_noop_when_engine_healthy() {
        let (eng, ds) = engine();
        let direct = eng.predict(&ds.x[..ds.d * 8]);
        let b = Batcher::start(
            eng.clone(),
            BatchConfig {
                max_batch: 8,
                max_delay: Duration::from_micros(100),
                queue_cap: 1024,
                workers: 1,
                exec_threads: 2,
                drain_timeout: Some(Duration::from_secs(30)),
                adaptive: true,
            },
        );
        let replies: Vec<_> =
            (0..8).map(|i| b.submit(ds.row(i).to_vec()).unwrap()).collect();
        std::thread::sleep(Duration::from_millis(50));
        drop(b);
        for (i, r) in replies.into_iter().enumerate() {
            let scores = r.recv().unwrap().unwrap();
            assert_eq!(&scores[..], &direct[i * ds.n_classes..(i + 1) * ds.n_classes]);
        }
    }

    #[test]
    fn shutdown_still_delivers_flushed_batches() {
        // Requests flushed before the drop get real scores, not Shutdown.
        let (eng, ds) = engine();
        let direct = eng.predict(&ds.x[..ds.d * 8]);
        let b = Batcher::start(
            eng.clone(),
            BatchConfig {
                max_batch: 8,
                max_delay: Duration::from_micros(100),
                queue_cap: 1024,
                workers: 1,
                exec_threads: 2,
                drain_timeout: None,
                adaptive: true,
            },
        );
        let replies: Vec<_> =
            (0..8).map(|i| b.submit(ds.row(i).to_vec()).unwrap()).collect();
        // Wait out the 100 µs deadline so the batch is flushed (not merely
        // queued) before the drop.
        std::thread::sleep(Duration::from_millis(50));
        drop(b); // must block until the flush delivered
        for (i, r) in replies.into_iter().enumerate() {
            let scores = r.recv().unwrap().unwrap();
            assert_eq!(&scores[..], &direct[i * ds.n_classes..(i + 1) * ds.n_classes]);
        }
    }

    /// Exhaustive interleavings of the reply-right claim: two chunk guards
    /// dropping (the last one runs `FlushState::complete`) × the drain
    /// deadline's `abandon_stragglers`, in every order
    /// ([`crate::testing::sched::explore`] — the three steps are single
    /// atomic swaps/decrements, so a schedule is a real interleaving).
    /// Whatever the order: each requester hears back exactly once — a late
    /// `Internal` beats a lost reply, a double reply is a protocol bug —
    /// and the in-flight latch always returns to zero.
    #[test]
    fn reply_right_interleavings_answer_exactly_once() {
        let (eng, ds) = engine();
        let metrics = Arc::new(Metrics::new());
        let inflight = Arc::new(Inflight {
            count: Mutex::new(0),
            idle: Condvar::new(),
            states: Mutex::new(Vec::new()),
        });
        let c = eng.n_classes();
        let schedules = crate::testing::explore(&[1, 1, 1], usize::MAX, |sched| {
            let (txs, rxs): (Vec<_>, Vec<_>) = (0..2).map(|_| mpsc::channel()).unzip();
            let requests: Vec<Request> = txs
                .into_iter()
                .map(|tx| Request {
                    x: ds.row(0).to_vec(),
                    enqueued: Instant::now(),
                    deadline: None,
                    reply: tx,
                })
                .collect();
            let st = Arc::new(FlushState {
                engine: eng.clone(),
                metrics: metrics.clone(),
                inflight: inflight.clone(),
                x: Vec::new(),
                out: UnsafeCell::new(vec![0f32; 2 * c]),
                requests,
                remaining: AtomicUsize::new(2),
                failed: AtomicBool::new(false),
                replied: AtomicBool::new(false),
                exec_start: Mutex::new(None),
            });
            inflight.begin(&st);
            let mut guards =
                vec![Some(ChunkGuard { st: st.clone() }), Some(ChunkGuard { st: st.clone() })];
            for &actor in sched {
                match actor {
                    0 | 1 => drop(guards[actor].take()),
                    _ => inflight.abandon_stragglers(),
                }
            }
            for rx in &rxs {
                rx.recv_timeout(Duration::from_secs(5)).expect("a reply must arrive");
                assert!(rx.try_recv().is_err(), "double reply under {sched:?}");
            }
            // Both guards dropped in every schedule, so the latch is back
            // to zero (abandoning never releases the straggler's slot).
            assert_eq!(*inflight.count.lock().unwrap(), 0, "latch leaked under {sched:?}");
        });
        assert_eq!(schedules, 6, "3 distinct single-step actors");
    }

    /// Flush-time deadline shed (ISSUE 10): requests whose client deadline
    /// passes while they sit in the assembling batch are answered
    /// `DeadlineExceeded` at flush time and never reach the pool; unexpired
    /// requests in the same batch still execute bit-exactly.
    #[test]
    fn expired_requests_are_shed_at_flush_time() {
        let (eng, ds) = engine();
        let direct = eng.predict(&ds.x[..ds.d * 4]);
        let b = Batcher::start(
            eng.clone(),
            BatchConfig {
                max_batch: 1024,
                // The flush fires on this delay — well past the 5 ms client
                // deadlines below, so those requests are expired by then.
                max_delay: Duration::from_millis(50),
                queue_cap: 1024,
                workers: 1,
                exec_threads: 1,
                drain_timeout: None,
                adaptive: true,
            },
        );
        let doomed: Vec<_> = (0..4)
            .map(|i| {
                b.submit_with_deadline(
                    ds.row(i).to_vec(),
                    Some(Instant::now() + Duration::from_millis(5)),
                )
                .unwrap()
            })
            .collect();
        let live: Vec<_> =
            (0..4).map(|i| b.submit_with_deadline(ds.row(i).to_vec(), None).unwrap()).collect();
        for r in doomed {
            assert_eq!(r.recv().unwrap(), Err(ServeError::DeadlineExceeded));
        }
        for (i, r) in live.into_iter().enumerate() {
            let scores = r.recv().unwrap().unwrap();
            assert_eq!(&scores[..], &direct[i * ds.n_classes..(i + 1) * ds.n_classes]);
        }
        assert_eq!(b.metrics.deadline_exceeded.load(Ordering::Relaxed), 4);
        assert_eq!(b.metrics.completed.load(Ordering::Relaxed), 4);
        assert!(b.metrics.report().contains("ddl=4"), "{}", b.metrics.report());
    }

    /// A deadline already in the past is refused at admission — no queue
    /// slot, no reply channel, counted the same as a flush-time shed.
    #[test]
    fn admission_refuses_already_expired_deadline() {
        let (eng, ds) = engine();
        let b = Batcher::start(eng, BatchConfig::default());
        let err = b
            .submit_with_deadline(ds.row(0).to_vec(), Some(Instant::now() - Duration::from_millis(1)))
            .unwrap_err();
        assert_eq!(err, ServeError::DeadlineExceeded);
        assert_eq!(b.metrics.deadline_exceeded.load(Ordering::Relaxed), 1);
        assert_eq!(b.metrics.requests.load(Ordering::Relaxed), 1);
    }

    /// The deadline-shed vs flush race, exhaustively interleaved: one
    /// expired request, one "reaper" actor running the real
    /// [`shed_deadline`] claim and one "flush" actor running the real
    /// [`flush_batch`], racing on an owned slot (the same move-out-of-
    /// `pending` discipline the collector uses). Whichever wins, the
    /// requester hears back exactly once — `DeadlineExceeded` if the shed
    /// won, real scores if the flush did — never twice, never zero times.
    #[test]
    fn deadline_shed_vs_flush_interleavings_reply_exactly_once() {
        let (eng, ds) = engine();
        let b = Batcher::start(eng.clone(), BatchConfig::default());
        let ctx = b.ctx.as_ref().unwrap().clone();
        let schedules = crate::testing::explore(&[1, 1], usize::MAX, |sched| {
            let (tx, rx) = mpsc::channel();
            let slot = Mutex::new(Some(Request {
                x: ds.row(0).to_vec(),
                enqueued: Instant::now(),
                deadline: Some(Instant::now() - Duration::from_millis(1)),
                reply: tx,
            }));
            for &actor in sched {
                let claimed = slot.lock().unwrap().take();
                let Some(r) = claimed else { continue };
                match actor {
                    0 => {
                        // Reaper step: shed only if actually expired
                        // (mirrors `shed_expired`'s per-request check).
                        if r.expired(Instant::now()) {
                            shed_deadline(&ctx, r);
                        } else {
                            *slot.lock().unwrap() = Some(r);
                        }
                    }
                    _ => flush_batch(&ctx, vec![r]),
                }
            }
            match rx.recv_timeout(Duration::from_secs(5)).expect("a reply must arrive") {
                Ok(_) | Err(ServeError::DeadlineExceeded) => {}
                other => panic!("unexpected reply {other:?} under {sched:?}"),
            }
            assert!(rx.try_recv().is_err(), "double reply under {sched:?}");
        });
        assert_eq!(schedules, 2, "shed-first and flush-first orders");
        // Wait out in-flight flushes before `ctx` (and its pool client)
        // drops at end of scope.
        ctx.inflight.wait_idle();
    }

    /// Conservation law over every shed/reply path: each submission lands
    /// in exactly one of {completed, rejected, shed_shutdown,
    /// deadline_exceeded, failed}, the per-class counters equal the
    /// observed replies of that class, and their sum equals `requests`.
    #[test]
    fn counter_conservation_over_shed_paths() {
        let (eng, ds) = engine();
        let b = Batcher::start(
            eng,
            BatchConfig {
                max_batch: 1024,
                max_delay: Duration::from_millis(20),
                queue_cap: 4096,
                workers: 1,
                exec_threads: 1,
                drain_timeout: None,
                adaptive: true,
            },
        );
        let metrics = b.metrics.clone();
        let (mut done, mut ddl, mut shut) = (0u64, 0u64, 0u64);
        // Phase A: plain requests that complete.
        for i in 0..8 {
            b.predict(ds.row(i).to_vec()).unwrap();
            done += 1;
        }
        // Phase B: refused at admission (deadline already past).
        for i in 0..4 {
            let err = b
                .submit_with_deadline(
                    ds.row(i).to_vec(),
                    Some(Instant::now() - Duration::from_millis(1)),
                )
                .unwrap_err();
            assert_eq!(err, ServeError::DeadlineExceeded);
            ddl += 1;
        }
        // Phase C: expire in the queue, shed at flush time.
        let doomed: Vec<_> = (0..16)
            .map(|i| {
                b.submit_with_deadline(
                    ds.row(i % ds.n).to_vec(),
                    Some(Instant::now() + Duration::from_millis(2)),
                )
                .unwrap()
            })
            .collect();
        // Phase D: no deadline — flushes alongside phase C's shed (or, if
        // the drop wins the race, is shed as Shutdown; both are counted).
        let racing: Vec<_> =
            (0..8).map(|i| b.submit(ds.row(i).to_vec()).unwrap()).collect();
        // Let the 20 ms flush fire so phase C is shed at flush time rather
        // than swallowed by the shutdown drain.
        std::thread::sleep(Duration::from_millis(60));
        drop(b);
        for r in doomed.into_iter().chain(racing) {
            match r.recv().unwrap() {
                Ok(_) => done += 1,
                Err(ServeError::DeadlineExceeded) => ddl += 1,
                Err(ServeError::Shutdown) => shut += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert_eq!(metrics.completed.load(Ordering::Relaxed), done);
        assert_eq!(metrics.deadline_exceeded.load(Ordering::Relaxed), ddl);
        assert_eq!(metrics.shed_shutdown.load(Ordering::Relaxed), shut);
        assert_eq!(metrics.failed.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 0);
        assert_eq!(
            metrics.requests.load(Ordering::Relaxed),
            done + ddl + shut,
            "every request accounted for exactly once"
        );
    }

    /// Engine swap mid-stream (the degrade controller's mechanism): waves
    /// served before the swap are bit-exact to the old engine's serial
    /// predictions, waves after to the new engine's — even though the two
    /// engines have different lane widths (RS 16 vs naive 1), because each
    /// flush captures one engine and plans chunks for *its* lanes.
    #[test]
    fn swap_engine_mid_stream_stays_bit_exact() {
        let (rs, ds) = engine();
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 8,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let naive: Arc<dyn Engine> =
            Arc::from(build(EngineKind::Naive, Precision::F32, &f, None).unwrap());
        let rs_direct = rs.predict(&ds.x[..ds.d * 16]);
        let naive_direct = naive.predict(&ds.x[..ds.d * 16]);
        let b = Batcher::start(
            rs.clone(),
            BatchConfig {
                max_batch: 16,
                max_delay: Duration::from_millis(2),
                queue_cap: 4096,
                workers: 1,
                exec_threads: 2,
                drain_timeout: None,
                adaptive: true,
            },
        );
        let wave: Vec<_> = (0..16).map(|i| b.submit(ds.row(i).to_vec()).unwrap()).collect();
        for (i, r) in wave.into_iter().enumerate() {
            let scores = r.recv().unwrap().unwrap();
            assert_eq!(&scores[..], &rs_direct[i * ds.n_classes..(i + 1) * ds.n_classes]);
        }
        b.swap_engine(naive.clone()).unwrap();
        assert_eq!(b.engine().unwrap().name(), naive.name());
        let wave: Vec<_> = (0..16).map(|i| b.submit(ds.row(i).to_vec()).unwrap()).collect();
        for (i, r) in wave.into_iter().enumerate() {
            let scores = r.recv().unwrap().unwrap();
            assert_eq!(
                &scores[..],
                &naive_direct[i * ds.n_classes..(i + 1) * ds.n_classes],
                "row {i} not served by the swapped-in engine"
            );
        }
    }

    /// A replacement with a different model shape is refused — the swap
    /// must never let a deployment silently answer with the wrong width.
    #[test]
    fn swap_engine_refuses_shape_mismatch() {
        let (eng, ds) = engine();
        let b = Batcher::start(eng, BatchConfig::default());
        // Same shape (a second forest over the same dataset) succeeds…
        let same = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 2,
                tree: TreeParams { max_leaves: 4, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let ok: Arc<dyn Engine> =
            Arc::from(build(EngineKind::Naive, Precision::F32, &same, None).unwrap());
        b.swap_engine(ok).unwrap();
        // …but an engine over Eeg (14 features vs Magic's 10) is refused.
        let other = DatasetId::Eeg.generate(100, 7);
        assert_ne!(other.d, ds.d);
        let of = train_random_forest(
            &other.x,
            &other.labels,
            other.d,
            other.n_classes,
            RfParams {
                n_trees: 2,
                tree: TreeParams { max_leaves: 4, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let bad: Arc<dyn Engine> =
            Arc::from(build(EngineKind::Naive, Precision::F32, &of, None).unwrap());
        let err = b.swap_engine(bad).unwrap_err();
        assert!(matches!(err, ServeError::BadInput(_)));
    }
}
