//! Dynamic batcher: groups single-instance requests into SIMD-width-aligned
//! batches under a latency budget.
//!
//! The paper's SIMD engines evaluate `v` instances per block (VQS v=4/8,
//! RS v=16); serving one request at a time would waste (v-1)/v of each
//! register. The batcher collects requests until either `max_batch` is
//! reached or the oldest request has waited `max_delay`, then hands the
//! assembled batch to the execution workers. Backpressure is a bounded
//! queue: when full, `submit` fails fast instead of queueing unboundedly.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use crate::engine::Engine;
use crate::util::Stopwatch;

/// Batching configuration.
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Maximum instances per executed batch (rounded up to the engine's
    /// lane width internally).
    pub max_batch: usize,
    /// Maximum time the oldest queued request may wait before a flush.
    pub max_delay: Duration,
    /// Bounded queue capacity (backpressure limit).
    pub queue_cap: usize,
    /// Execution worker threads.
    pub workers: usize,
    /// Thread budget for the engine itself: with a value > 1,
    /// [`crate::coordinator::Server::deploy`] wraps the engine in a
    /// [`crate::exec::ParallelEngine`] so each executed batch is sharded
    /// across that many exec workers (bit-exact with the serial engine).
    pub exec_threads: usize,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_batch: 64,
            max_delay: Duration::from_micros(500),
            queue_cap: 4096,
            workers: 1,
            exec_threads: 1,
        }
    }
}

/// One queued request.
pub struct Request {
    pub x: Vec<f32>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Result<Vec<f32>, ServeError>>,
}

/// Serving errors surfaced to clients.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    Overloaded,
    Shutdown,
    BadInput(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded => write!(f, "queue full (backpressure)"),
            ServeError::Shutdown => write!(f, "model is shutting down"),
            ServeError::BadInput(msg) => write!(f, "bad input: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// A running batcher for one engine.
pub struct Batcher {
    tx: SyncSender<Request>,
    collector: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    n_features: usize,
}

impl Batcher {
    pub fn start(engine: Arc<dyn Engine>, config: BatchConfig) -> Batcher {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::sync_channel::<Request>(config.queue_cap);
        let (batch_tx, batch_rx) = mpsc::channel::<Vec<Request>>();
        let batch_rx = Arc::new(std::sync::Mutex::new(batch_rx));

        // Round the batch size up to a lane multiple so SIMD blocks are full.
        let lanes = engine.lanes().max(1);
        let max_batch = config.max_batch.div_ceil(lanes) * lanes;

        let collector = {
            let metrics = metrics.clone();
            std::thread::Builder::new()
                .name("batcher-collector".into())
                .spawn(move || collect_loop(rx, batch_tx, max_batch, config.max_delay, metrics))
                .expect("spawn collector")
        };

        let workers = (0..config.workers.max(1))
            .map(|wi| {
                let engine = engine.clone();
                let metrics = metrics.clone();
                let batch_rx = batch_rx.clone();
                std::thread::Builder::new()
                    .name(format!("batcher-worker-{wi}"))
                    .spawn(move || worker_loop(engine, batch_rx, metrics))
                    .expect("spawn worker")
            })
            .collect();

        Batcher {
            tx,
            collector: Some(collector),
            workers,
            metrics,
            n_features: engine.n_features(),
        }
    }

    /// Submit one instance; returns the reply channel. Fails fast under
    /// backpressure.
    pub fn submit(&self, x: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>, ServeError>>, ServeError> {
        if x.len() != self.n_features {
            return Err(ServeError::BadInput(format!(
                "expected {} features, got {}",
                self.n_features,
                x.len()
            )));
        }
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request { x, enqueued: Instant::now(), reply: reply_tx };
        match self.tx.try_send(req) {
            Ok(()) => Ok(reply_rx),
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(ServeError::Overloaded)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServeError::Shutdown),
        }
    }

    /// Submit and wait for the scores (convenience).
    pub fn predict(&self, x: Vec<f32>) -> Result<Vec<f32>, ServeError> {
        let rx = self.submit(x)?;
        rx.recv().map_err(|_| ServeError::Shutdown)?
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Closing `tx` ends the collector; it drops `batch_tx`, ending the
        // workers.
        drop(std::mem::replace(&mut self.tx, {
            let (t, _r) = mpsc::sync_channel(1);
            t
        }));
        if let Some(c) = self.collector.take() {
            let _ = c.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn collect_loop(
    rx: Receiver<Request>,
    batch_tx: mpsc::Sender<Vec<Request>>,
    max_batch: usize,
    max_delay: Duration,
    _metrics: Arc<Metrics>,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(max_batch);
    loop {
        if pending.is_empty() {
            // Block for the first request (or shutdown).
            match rx.recv() {
                Ok(r) => pending.push(r),
                Err(_) => return,
            }
        }
        // Fill until max_batch or the oldest request's deadline.
        let deadline = pending[0].enqueued + max_delay;
        while pending.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    if !pending.is_empty() {
                        let _ = batch_tx.send(std::mem::take(&mut pending));
                    }
                    return;
                }
            }
        }
        if batch_tx.send(std::mem::take(&mut pending)).is_err() {
            return;
        }
    }
}

fn worker_loop(
    engine: Arc<dyn Engine>,
    batch_rx: Arc<std::sync::Mutex<Receiver<Vec<Request>>>>,
    metrics: Arc<Metrics>,
) {
    let d = engine.n_features();
    let c = engine.n_classes();
    loop {
        let batch = {
            let rx = batch_rx.lock().unwrap();
            match rx.recv() {
                Ok(b) => b,
                Err(_) => return,
            }
        };
        let n = batch.len();
        let mut x = Vec::with_capacity(n * d);
        for r in &batch {
            x.extend_from_slice(&r.x);
        }
        let sw = Stopwatch::start();
        let mut out = vec![0f32; n * c];
        engine.predict_batch(&x, &mut out);
        metrics.record_batch(n, sw.micros());
        let now = Instant::now();
        for (i, r) in batch.into_iter().enumerate() {
            let scores = out[i * c..(i + 1) * c].to_vec();
            metrics
                .record_latency(now.duration_since(r.enqueued).as_secs_f64() * 1e6);
            let _ = r.reply.send(Ok(scores));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::engine::{build, EngineKind, Precision};
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    fn engine() -> (Arc<dyn Engine>, crate::data::Dataset) {
        let ds = DatasetId::Magic.generate(400, 55);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 8,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        (Arc::from(build(EngineKind::Rs, Precision::F32, &f, None).unwrap()), ds)
    }

    #[test]
    fn batched_results_match_direct() {
        let (eng, ds) = engine();
        let direct = eng.predict(&ds.x[..ds.d * 20]);
        let b = Batcher::start(eng.clone(), BatchConfig::default());
        // Submit 20 requests concurrently, gather replies in order.
        let replies: Vec<_> =
            (0..20).map(|i| b.submit(ds.row(i).to_vec()).unwrap()).collect();
        for (i, r) in replies.into_iter().enumerate() {
            let scores = r.recv().unwrap().unwrap();
            assert_eq!(&scores[..], &direct[i * ds.n_classes..(i + 1) * ds.n_classes]);
        }
    }

    #[test]
    fn rejects_wrong_width() {
        let (eng, _) = engine();
        let b = Batcher::start(eng, BatchConfig::default());
        let err = b.submit(vec![0.0; 3]).unwrap_err();
        assert!(matches!(err, ServeError::BadInput(_)));
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let (eng, ds) = engine();
        // Tiny queue + long delay so the queue definitely fills.
        let b = Batcher::start(
            eng,
            BatchConfig {
                max_batch: 1024,
                max_delay: Duration::from_millis(250),
                queue_cap: 4,
                workers: 1,
                exec_threads: 1,
            },
        );
        let mut overloaded = false;
        let mut replies = Vec::new();
        for i in 0..64 {
            match b.submit(ds.row(i % ds.n).to_vec()) {
                Ok(r) => replies.push(r),
                Err(ServeError::Overloaded) => {
                    overloaded = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(overloaded, "queue_cap=4 must trigger backpressure");
        // Queued requests still complete.
        for r in replies {
            r.recv().unwrap().unwrap();
        }
    }

    #[test]
    fn metrics_progress() {
        let (eng, ds) = engine();
        let b = Batcher::start(eng, BatchConfig::default());
        for i in 0..10 {
            b.predict(ds.row(i).to_vec()).unwrap();
        }
        assert_eq!(b.metrics.completed.load(Ordering::Relaxed), 10);
        assert!(b.metrics.mean_batch_size() >= 1.0);
    }
}
