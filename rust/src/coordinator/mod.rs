//! L3 coordinator: the serving layer (DESIGN.md system S9).
//!
//! A [`Server`] hosts named models and owns exactly **one** work-stealing
//! exec pool ([`crate::exec::SharedPool`]) shared by every deployment. Each
//! model gets an [`Engine`] (picked explicitly or by the auto-[`selector`]),
//! a SIMD-width-aware dynamic [`batcher`] fused onto the shared pool with a
//! per-deployment thread *budget* (weighted fair stealing), bounded-queue
//! backpressure, and per-model [`metrics`]. Clients submit single instances
//! and receive score vectors; the batcher turns the request stream into
//! full SIMD blocks and enqueues their lane-aligned shards straight onto
//! the pool — request to SIMD lane through a single scheduler.
//!
//! # Load-bearing contracts
//!
//! * **Determinism** — every reply is **bit-identical** to a serial
//!   `Engine::predict_batch` over the same assembled batch, regardless of
//!   pool size, budget, or concurrent deployments (flushes emit only
//!   lane-aligned row chunks; enforced end-to-end by
//!   `rust/tests/serving_fused.rs`).
//! * **Backpressure** — the submit queue is bounded; when full, `submit`
//!   fails fast with [`ServeError::Overloaded`] instead of queueing
//!   unboundedly.
//! * **Shutdown drain** — undeploy/redeploy/drop answers every accepted
//!   request: unflushed requests get [`ServeError::Shutdown`], flushed
//!   batches deliver real scores before the pool registration drops, and
//!   [`BatchConfig::drain_timeout`] bounds the wait (stragglers from a
//!   hung engine downgrade to [`ServeError::Internal`]).
//! * **Accuracy gate** — [`Server::deploy_auto`] deploys the fastest
//!   candidate whose calibration argmax agreement with the float
//!   reference is ≥ 99%, so latency ranking cannot silently pick a
//!   quantized tier that degrades served predictions.

pub mod batcher;
pub mod degrade;
pub mod metrics;
pub mod net;
pub mod selector;

pub use batcher::{BatchConfig, Batcher, ServeError};
pub use degrade::{DegradeConfig, DegradeController};
pub use metrics::Metrics;
pub use net::{NetClient, NetConfig, NetServer};
pub use selector::{
    build_candidate, select_engine, select_engine_early_exit, select_engine_tier,
    select_engine_with, thread_budgets, Candidate, Selection,
};

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

use crate::engine::{build, build_i16_per_tree, EarlyExitMode, Engine, EngineKind, Precision};
use crate::exec::{PoolConfig, SharedPool};
use crate::forest::{Forest, Task};
use crate::util::Json;

/// A deployed model: its engine's batcher plus descriptive metadata.
pub struct Deployment {
    pub batcher: Batcher,
    pub engine_name: String,
    pub n_features: usize,
    pub n_classes: usize,
    pub task: Task,
    /// Overload degradation, when enabled ([`Server::enable_degrade`]).
    degrade: Mutex<Option<Arc<DegradeController>>>,
}

impl Deployment {
    /// The deployment's degrade controller, if degradation is enabled.
    pub fn degrade(&self) -> Option<Arc<DegradeController>> {
        self.degrade.lock().unwrap().clone()
    }
}

/// The serving coordinator: model registry + per-model batchers, all fused
/// onto one shared worker pool.
pub struct Server {
    models: RwLock<HashMap<String, Arc<Deployment>>>,
    pool: Arc<SharedPool>,
}

impl Default for Server {
    fn default() -> Server {
        Server::new()
    }
}

impl Server {
    /// A server whose shared pool is sized to the host's parallelism.
    pub fn new() -> Server {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Server::with_pool_size(n)
    }

    /// A server owning exactly one work-stealing pool of `threads` workers,
    /// shared by every deployment. Per-deployment budgets
    /// ([`BatchConfig::exec_threads`]) arbitrate the workers under
    /// contention; idle budgets are stolen (see [`crate::exec::SharedPool`]).
    pub fn with_pool_size(threads: usize) -> Server {
        Self::with_pool_config(PoolConfig::new(threads))
    }

    /// A server whose shared pool is built from an explicit
    /// [`PoolConfig`] — core topology, worker pinning (`serve --pin`),
    /// and the batch-claim limit.
    pub fn with_pool_config(config: PoolConfig) -> Server {
        Server { models: RwLock::new(HashMap::new()), pool: SharedPool::with_config(config) }
    }

    /// Worker threads in the server-shared pool — the only exec threads
    /// serving spawns, no matter how many models are deployed.
    pub fn pool_threads(&self) -> usize {
        self.pool.threads()
    }

    /// Pool workers whose affinity mask stuck (0 when pinning is off).
    pub fn pinned_workers(&self) -> usize {
        self.pool.pinned_workers()
    }

    /// Deployments currently registered on the shared pool.
    pub fn pool_deployments(&self) -> usize {
        self.pool.registered()
    }

    /// Deploy a forest under `name` with an explicit engine choice. The
    /// serial engine is built once; `config.exec_threads` becomes the
    /// deployment's thread budget on the server's shared pool, and the
    /// fused batcher shards each flushed batch across it (lane-aligned, so
    /// bit-exact with the serial engine).
    pub fn deploy(
        &self,
        name: &str,
        forest: &Forest,
        kind: EngineKind,
        precision: Precision,
        config: BatchConfig,
    ) -> anyhow::Result<()> {
        let engine: Arc<dyn Engine> = Arc::from(build(kind, precision, forest, None)?);
        self.deploy_engine(name, forest, engine, config)
    }

    /// Deploy with a pre-built engine (e.g. a tensor engine or a
    /// selector-chosen one). Registers the deployment on the shared pool;
    /// redeploying under an existing name tears the old deployment down
    /// cleanly (its batcher drains, then its pool registration drops).
    pub fn deploy_engine(
        &self,
        name: &str,
        forest: &Forest,
        engine: Arc<dyn Engine>,
        config: BatchConfig,
    ) -> anyhow::Result<()> {
        let budget = config.thread_budget();
        let engine_name = if budget > 1 {
            format!("{}×{budget}t", engine.name())
        } else {
            engine.name()
        };
        let dep = Deployment {
            engine_name,
            n_features: engine.n_features(),
            n_classes: engine.n_classes(),
            task: forest.task,
            batcher: Batcher::start_shared(engine, &self.pool, name, config),
            degrade: Mutex::new(None),
        };
        // The write-guard temporary drops at the end of the `let`, so a
        // replaced deployment's teardown (batcher drain) runs *after* the
        // registry lock is released — a slow drain must not stall lookups
        // on other models.
        let replaced = self.models.write().unwrap().insert(name.to_string(), Arc::new(dep));
        drop(replaced);
        Ok(())
    }

    /// Deploy using the auto-selector on a calibration batch. With a thread
    /// budget above 1 (`config.thread_budget()`), threaded candidates (e.g.
    /// `RS×4t`) are measured next to the serial ones and the winner's
    /// thread count becomes the deployment's budget on the shared pool.
    ///
    /// Ranking is by latency, but deployment is gated on prediction
    /// quality: the fastest candidate whose calibration argmax agreement
    /// with the float reference is ≥ 99% wins, so a heavily-quantized tier
    /// (int8 at a coarse scale) cannot silently degrade served accuracy.
    /// If no candidate clears the gate (tiny forests, extreme
    /// quantization), the overall fastest is used.
    pub fn deploy_auto(
        &self,
        name: &str,
        forest: &Forest,
        calibration: &[f32],
        config: BatchConfig,
    ) -> anyhow::Result<Selection> {
        let budgets = selector::thread_budgets(config.thread_budget());
        let sel = selector::select_engine_with(forest, calibration, None, 3, &budgets)?;
        let best = sel.recommended();
        let config = BatchConfig { exec_threads: best.threads, workers: 1, ..config };
        if best.per_tree {
            // The i16 per-tree-scale candidate is not reachable through
            // `build(kind, precision, ..)` — rebuild it the way the
            // selector measured it.
            let engine: Arc<dyn Engine> = Arc::from(build_i16_per_tree(best.kind, forest)?);
            self.deploy_engine(name, forest, engine, config)?;
        } else {
            self.deploy(name, forest, best.kind, best.precision, config)?;
        }
        Ok(sel)
    }

    /// Enable overload-triggered graceful degradation for a deployed model
    /// (`serve --degrade`). Ranks fallback candidates with the approx
    /// early-exit dimension opened (the one cheap axis the primary
    /// deployment didn't use), and picks the **fastest serial candidate in
    /// the ≥ 99%-agreement set that measured cheaper than the primary** —
    /// degradation must buy latency without selling accuracy. Fails if no
    /// such candidate exists (the primary is already the floor). Spawns the
    /// poll ticker; returns the fallback's candidate name.
    pub fn enable_degrade(
        &self,
        name: &str,
        forest: &Forest,
        calibration: &[f32],
        cfg: DegradeConfig,
    ) -> anyhow::Result<String> {
        let dep = self
            .model(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{name}'"))?;
        let primary = dep
            .batcher
            .engine()
            .ok_or_else(|| anyhow::anyhow!("deployment '{name}' is draining"))?;
        let sel = selector::select_engine_early_exit(
            forest,
            calibration,
            None,
            3,
            &[1],
            None,
            EarlyExitMode::Approx,
        )?;
        // The primary's measured cost, by its serial engine name (threaded
        // deployments wrap a serial engine; the budget lives in the pool
        // registration, not the engine). Unknown primaries (e.g. a tensor
        // engine the selector doesn't enumerate) rank as infinitely
        // expensive, so any agreeing candidate qualifies.
        let primary_cost = sel
            .candidates
            .iter()
            .find(|c| c.name == primary.name())
            .map_or(f64::INFINITY, |c| c.host_us_per_instance);
        let fallback_c = sel
            .agreement_set()
            .into_iter()
            .find(|c| {
                c.threads == 1
                    && c.name != primary.name()
                    && c.host_us_per_instance < primary_cost
            })
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no ≥99%-agreement fallback cheaper than '{}' for model '{name}'",
                    primary.name()
                )
            })?
            .clone();
        let fallback =
            build_candidate(&fallback_c, forest, calibration, EarlyExitMode::Approx)?;
        anyhow::ensure!(
            fallback.n_features() == dep.n_features
                && fallback.n_classes() == dep.n_classes,
            "fallback '{}' shape mismatch",
            fallback_c.name
        );
        let ctrl = Arc::new(DegradeController::new(
            primary,
            fallback,
            fallback_c.name.clone(),
            fallback_c.agreement,
            cfg,
        ));
        degrade::spawn_ticker(&ctrl, &dep, &self.pool, name);
        // Replacing an existing controller drops it (its ticker joins)
        // outside any registry lock.
        let replaced = std::mem::replace(&mut *dep.degrade.lock().unwrap(), Some(ctrl));
        drop(replaced);
        Ok(fallback_c.name)
    }

    /// Look up a deployment.
    pub fn model(&self, name: &str) -> Option<Arc<Deployment>> {
        self.models.read().unwrap().get(name).cloned()
    }

    /// Remove a deployment (its batcher drains and stops on drop).
    pub fn undeploy(&self, name: &str) -> bool {
        // Bind before testing: the removed Arc must outlive the statement's
        // write-guard temporary so the drain runs outside the registry lock.
        let removed = self.models.write().unwrap().remove(name);
        removed.is_some()
    }

    pub fn list(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Blocking single prediction against a deployed model.
    pub fn predict(&self, name: &str, x: Vec<f32>) -> Result<Vec<f32>, ServeError> {
        let dep = self
            .model(name)
            .ok_or_else(|| ServeError::BadInput(format!("unknown model '{name}'")))?;
        dep.batcher.predict(x)
    }

    /// [`Server::predict`] with an optional client deadline: if the
    /// deadline passes before the request reaches an engine (at admission
    /// or while queued), the batcher sheds it with
    /// [`ServeError::DeadlineExceeded`] instead of burning pool lanes on a
    /// reply nobody is waiting for.
    pub fn predict_deadline(
        &self,
        name: &str,
        x: Vec<f32>,
        deadline: Option<std::time::Instant>,
    ) -> Result<Vec<f32>, ServeError> {
        let dep = self
            .model(name)
            .ok_or_else(|| ServeError::BadInput(format!("unknown model '{name}'")))?;
        let rx = dep.batcher.submit_with_deadline(x, deadline)?;
        rx.recv().map_err(|_| ServeError::Shutdown)?
    }

    /// Classification helper: argmax over the score vector.
    pub fn classify(&self, name: &str, x: Vec<f32>) -> Result<u32, ServeError> {
        let scores = self.predict(name, x)?;
        let mut best = 0usize;
        for (i, &s) in scores.iter().enumerate() {
            if s > scores[best] {
                best = i;
            }
        }
        Ok(best as u32)
    }

    /// Metrics report for every deployed model (plus the shared pool and
    /// the server-wide reaper accounting).
    pub fn report(&self) -> String {
        let mut out = format!(
            "pool: {} workers shared by {} deployment(s), {} pinned\n",
            self.pool_threads(),
            self.pool_deployments(),
            self.pinned_workers()
        );
        out.push_str(&format!(
            "reapers: {} live / {} spawned / {} refused (cap {})\n",
            batcher::reaper::live(),
            batcher::reaper::spawned(),
            batcher::reaper::refused(),
            batcher::reaper::CAP
        ));
        for name in self.list() {
            if let Some(dep) = self.model(&name) {
                out.push_str(&format!(
                    "{name} [{}] {}\n",
                    dep.engine_name,
                    dep.batcher.metrics.report()
                ));
                if let Some(ctrl) = dep.degrade() {
                    out.push_str(&format!("{name} degrade: {}\n", ctrl.status()));
                }
            }
        }
        out
    }

    /// Machine-readable snapshot of the whole server (`stats --json`, wire
    /// `{"cmd":"stats","mode":"json"}`): the shared pool's scheduler
    /// counters (claims, steals, claim-size distribution, per-deployment
    /// queue depth and vtime lag), server-wide reaper accounting, and per
    /// model the full [`Metrics`] export plus the adaptive loop's re-plan
    /// count and current per-class throughput weights.
    pub fn stats_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("pool", self.pool.stats().to_json());
        j.set(
            "reapers",
            Json::from_pairs(vec![
                ("live", Json::Num(batcher::reaper::live() as f64)),
                ("spawned", Json::Num(batcher::reaper::spawned() as f64)),
                ("refused", Json::Num(batcher::reaper::refused() as f64)),
                ("cap", Json::Num(batcher::reaper::CAP as f64)),
            ]),
        );
        let mut models = Json::obj();
        for name in self.list() {
            if let Some(dep) = self.model(&name) {
                let mut m = dep.batcher.metrics.to_json();
                m.set("engine", Json::Str(dep.engine_name.clone()));
                m.set(
                    "degrade",
                    dep.degrade().map_or(Json::Null, |c| c.to_json()),
                );
                m.set("replans", Json::Num(dep.batcher.replans() as f64));
                m.set(
                    "class_rates",
                    Json::Arr(
                        dep.batcher
                            .class_rates()
                            .into_iter()
                            .map(|r| r.map_or(Json::Null, Json::Num))
                            .collect(),
                    ),
                );
                models.set(&name, m);
            }
        }
        j.set("models", models);
        j
    }

    /// The `{"cmd":"health"}` probe payload: per-model pool queue depth,
    /// the engine currently serving (the fallback while degraded), and
    /// degradation state — the cheap snapshot a load balancer polls, next
    /// to the full `stats_json`. `status` is `"degraded"` if any model is
    /// degraded, else `"ok"`.
    pub fn health_json(&self) -> Json {
        let pool_stats = self.pool.stats();
        let mut degraded_any = false;
        let mut models = Json::obj();
        for name in self.list() {
            if let Some(dep) = self.model(&name) {
                let queue_depth = pool_stats
                    .deployments
                    .iter()
                    .find(|d| d.label == name)
                    .map_or(0, |d| d.queue_depth);
                let mut m = Json::obj();
                m.set(
                    "engine",
                    Json::Str(
                        dep.batcher
                            .engine()
                            .map_or_else(|| dep.engine_name.clone(), |e| e.name()),
                    ),
                );
                m.set("queue_depth", Json::Num(queue_depth as f64));
                match dep.degrade() {
                    Some(ctrl) => {
                        degraded_any |= ctrl.degraded();
                        m.set("degrade", ctrl.to_json());
                    }
                    None => m.set("degrade", Json::Null),
                }
                models.set(&name, m);
            }
        }
        let mut j = Json::obj();
        j.set(
            "status",
            Json::Str(if degraded_any { "degraded".into() } else { "ok".into() }),
        );
        j.set(
            "pool",
            Json::from_pairs(vec![
                ("threads", Json::Num(self.pool_threads() as f64)),
                ("deployments", Json::Num(self.pool_deployments() as f64)),
            ]),
        );
        j.set("models", models);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    fn forest() -> (Forest, crate::data::Dataset) {
        let ds = DatasetId::Magic.generate(500, 61);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 12,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        (f, ds)
    }

    #[test]
    fn deploy_predict_undeploy() {
        let (f, ds) = forest();
        let server = Server::new();
        server
            .deploy("magic", &f, EngineKind::Vqs, Precision::F32, BatchConfig::default())
            .unwrap();
        assert_eq!(server.list(), vec!["magic".to_string()]);
        let scores = server.predict("magic", ds.row(0).to_vec()).unwrap();
        let want = f.predict_batch(ds.row(0));
        crate::testing::assert_close(&scores, &want, 1e-5, 1e-5).unwrap();
        assert!(server.undeploy("magic"));
        assert!(server.predict("magic", ds.row(0).to_vec()).is_err());
    }

    #[test]
    fn concurrent_clients_agree_with_reference() {
        let (f, ds) = forest();
        let server = Arc::new(Server::new());
        server
            .deploy("m", &f, EngineKind::Rs, Precision::F32, BatchConfig::default())
            .unwrap();
        let want = f.predict_batch(&ds.x);
        let mut handles = Vec::new();
        for t in 0..4 {
            let server = server.clone();
            let ds = ds.clone();
            let want = want.clone();
            handles.push(std::thread::spawn(move || {
                for i in (t..80).step_by(4) {
                    let got = server.predict("m", ds.row(i).to_vec()).unwrap();
                    crate::testing::assert_close(
                        &got,
                        &want[i * ds.n_classes..(i + 1) * ds.n_classes],
                        1e-5,
                        1e-5,
                    )
                    .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let dep = server.model("m").unwrap();
        assert_eq!(dep.batcher.metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 80);
    }

    #[test]
    fn auto_deploy_picks_something() {
        let (f, ds) = forest();
        let server = Server::new();
        let sel = server
            .deploy_auto("auto", &f, &ds.x[..ds.d * 128], BatchConfig::default())
            .unwrap();
        // Every registered variant plus the i16 per-tree candidate —
        // derived from the engine registry (the literal here went stale
        // twice as tiers grew: 10 → 13 → 15).
        assert_eq!(sel.candidates.len(), crate::engine::all_variants_with_i8().len() + 1);
        let c = server.classify("auto", ds.row(3).to_vec()).unwrap();
        assert!(c < 2);
    }

    #[test]
    fn shared_pool_is_singular() {
        let (f, ds) = forest();
        let server = Server::with_pool_size(2);
        server
            .deploy(
                "a",
                &f,
                EngineKind::Rs,
                Precision::F32,
                BatchConfig { exec_threads: 2, ..BatchConfig::default() },
            )
            .unwrap();
        server
            .deploy("b", &f, EngineKind::Qs, Precision::I16, BatchConfig::default())
            .unwrap();
        assert_eq!(server.pool_threads(), 2);
        assert_eq!(server.pool_deployments(), 2);
        assert!(server.predict("a", ds.row(0).to_vec()).is_ok());
        assert!(server.predict("b", ds.row(1).to_vec()).is_ok());
        assert!(server.report().contains("pool: 2 workers"), "{}", server.report());
    }

    /// `stats --json` exposes the shared scheduler and every model's
    /// metrics; the per-model key set is checked against the metrics
    /// counter list itself (satellite 6 — no re-typed field names).
    #[test]
    fn stats_json_covers_pool_and_models() {
        let (f, ds) = forest();
        let server = Server::with_pool_size(2);
        server
            .deploy(
                "m",
                &f,
                EngineKind::Rs,
                Precision::F32,
                BatchConfig { exec_threads: 2, ..BatchConfig::default() },
            )
            .unwrap();
        for i in 0..8 {
            server.predict("m", ds.row(i).to_vec()).unwrap();
        }
        let j = server.stats_json();
        let pool = j.get("pool").expect("pool section");
        assert_eq!(pool.get("threads").and_then(|v| v.as_usize()), Some(2));
        assert!(pool.get("claims").and_then(|v| v.as_f64()).unwrap() >= 1.0);
        assert_eq!(
            pool.get("claim_sizes").and_then(|v| v.as_arr()).unwrap().len(),
            crate::exec::CLAIM_SIZE_SLOTS
        );
        let deps = pool.get("deployments").and_then(|v| v.as_arr()).unwrap();
        assert!(deps
            .iter()
            .any(|d| d.get("label").and_then(|l| l.as_str()) == Some("m")));
        assert!(j.get("reapers").and_then(|r| r.get("cap")).is_some());
        let m = j.get("models").and_then(|ms| ms.get("m")).expect("model section");
        let dep = server.model("m").unwrap();
        for (name, _) in dep.batcher.metrics.counters() {
            assert!(m.get(name).is_some(), "stats_json missing counter {name}");
        }
        assert_eq!(m.get("completed").and_then(|v| v.as_usize()), Some(8));
        assert!(m.get("class_rates").and_then(|v| v.as_arr()).is_some());
        assert!(m.get("latency_us").and_then(|l| l.get("p99")).is_some());
    }

    /// End-to-end degradation: with a zero queue threshold every poll runs
    /// hot, so the ticker flips the deployment onto the fallback engine —
    /// replies become bit-exact to the *fallback's* serial predictions,
    /// health/stats report the degraded state, and a huge `min_dwell` keeps
    /// it latched for the test's lifetime.
    #[test]
    fn enable_degrade_swaps_to_fallback_under_load() {
        let (f, ds) = forest();
        let server = Server::new();
        // Deploy the slowest exact engine so a cheaper ≥99% fallback is
        // guaranteed to exist in the candidate table.
        server
            .deploy("m", &f, EngineKind::Naive, Precision::F32, BatchConfig::default())
            .unwrap();
        let cal = &ds.x[..ds.d * 96];
        let cfg = DegradeConfig {
            queue_high: 0, // every poll is hot
            enter_after: 1,
            min_dwell: std::time::Duration::from_secs(3600),
            poll_every: std::time::Duration::from_millis(5),
            ..DegradeConfig::default()
        };
        let fallback_name = server.enable_degrade("m", &f, cal, cfg).unwrap();
        assert_ne!(fallback_name, "NA");
        let dep = server.model("m").unwrap();
        let ctrl = dep.degrade().expect("controller registered");
        assert_eq!(ctrl.fallback_name(), fallback_name);
        assert!(ctrl.fallback_agreement() >= 0.99);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while !ctrl.degraded() && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(ctrl.degraded(), "ticker never entered degraded mode");
        assert!(ctrl.entries() >= 1);
        // Served replies now come from the fallback engine, bit-exactly.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while server.model("m").unwrap().batcher.engine().unwrap().name() == "NA"
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let live = dep.batcher.engine().unwrap();
        assert_ne!(live.name(), "NA", "engine never swapped");
        let want = live.predict(ds.row(5));
        let got = server.predict("m", ds.row(5).to_vec()).unwrap();
        assert_eq!(got, want, "reply not bit-exact to the fallback engine");
        // Degradation state is visible in health, stats and the report.
        let h = server.health_json();
        assert_eq!(h.get("status").and_then(|s| s.as_str()), Some("degraded"));
        let hm = h.get("models").and_then(|m| m.get("m")).unwrap();
        assert!(hm.get("queue_depth").and_then(|v| v.as_f64()).is_some());
        assert_eq!(
            hm.get("degrade").and_then(|d| d.get("degraded")).and_then(|v| v.as_bool()),
            Some(true)
        );
        let sm = server.stats_json();
        let sd = sm.get("models").and_then(|m| m.get("m")).and_then(|m| m.get("degrade"));
        assert_eq!(
            sd.and_then(|d| d.get("fallback")).and_then(|v| v.as_str()),
            Some(fallback_name.as_str())
        );
        assert!(server.report().contains("DEGRADED"), "{}", server.report());
    }

    /// Without degradation enabled, health reports ok with a null degrade
    /// section; enabling on an unknown model fails.
    #[test]
    fn health_json_without_degrade() {
        let (f, ds) = forest();
        let server = Server::new();
        server
            .deploy("m", &f, EngineKind::Rs, Precision::F32, BatchConfig::default())
            .unwrap();
        server.predict("m", ds.row(0).to_vec()).unwrap();
        let h = server.health_json();
        assert_eq!(h.get("status").and_then(|s| s.as_str()), Some("ok"));
        let hm = h.get("models").and_then(|m| m.get("m")).unwrap();
        assert!(matches!(hm.get("degrade"), Some(Json::Null)));
        assert_eq!(hm.get("engine").and_then(|e| e.as_str()), Some("RS"));
        assert!(h.get("pool").and_then(|p| p.get("threads")).is_some());
        assert!(server
            .enable_degrade("nope", &f, &ds.x[..ds.d * 32], DegradeConfig::default())
            .is_err());
    }

    #[test]
    fn classify_matches_argmax() {
        let (f, ds) = forest();
        let server = Server::new();
        server
            .deploy("m", &f, EngineKind::Qs, Precision::F32, BatchConfig::default())
            .unwrap();
        let scores = f.predict_batch(ds.row(7));
        let want = Forest::argmax(&scores, f.n_classes)[0];
        assert_eq!(server.classify("m", ds.row(7).to_vec()).unwrap(), want);
    }
}
