//! Network front end: newline-delimited JSON over TCP, bounded everywhere.
//!
//! A deliberately small wire protocol (no HTTP stack offline) that makes the
//! coordinator an actual network service:
//!
//! ```text
//! → {"model": "magic", "x": [0.1, 0.2, ...], "deadline_ms": 50}
//! ← {"scores": [0.93, 0.07], "class": 0}
//! → {"cmd": "list"}
//! ← {"models": ["magic"]}
//! → {"cmd": "health"}
//! ← {"status": "ok", "pool": {...}, "models": {...}, "net": {...}}
//! → {"cmd": "stats", "model": "magic"}
//! ← {"report": "..."}
//! ```
//!
//! One line per request/response. Errors are machine-readable objects —
//! `{"error": {"message": "...", "code": "overloaded", "retry_after_ms": 10}}`
//! — with `code` from [`ServeError::code`], so clients key retry policy off
//! a stable token, never off prose ([`NetClient::with_retry`]).
//!
//! # Robustness bounds (ISSUE 10)
//!
//! The original front was a thread-per-connection loop with two unbounded
//! resources: `BufReader::lines` buffered a newline-free client's bytes
//! forever (a remote OOM), and every connection spawned a *detached*
//! handler thread — unjoinable at shutdown, uncounted under load. This
//! version bounds both:
//!
//! * request lines are read through a hard [`NetConfig::max_line`] cap; an
//!   over-long line gets a typed `bad_input` error and the connection is
//!   closed (the read never buffers more than the cap + 1 bytes);
//! * handler threads live in a per-server [`HandlerRegistry`]
//!   (live/spawned/refused counters, modeled on the batcher's reaper
//!   registry): past [`NetConfig::max_conns`] a connection is refused with
//!   a typed `overloaded` error before a thread is spawned, and
//!   [`NetServer::shutdown`] closes every live socket and joins every
//!   handler within a deadline — no leaked threads, no shutdown deadlock
//!   against connected clients.
//!
//! Prediction itself goes through the dynamic batcher, so concurrent
//! connections share SIMD blocks; a request's optional `deadline_ms` rides
//! through [`crate::coordinator::Batcher::submit_with_deadline`] so expired
//! requests shed instead of burning pool lanes.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::forest::Forest;
use crate::util::Json;

use super::batcher::ServeError;
use super::Server;

/// Front-end bounds. Defaults are generous for tests and small fleets;
/// `serve` exposes them as flags.
#[derive(Debug, Clone, Copy)]
pub struct NetConfig {
    /// Maximum concurrent handler threads (= live connections). Beyond it,
    /// new connections receive a typed `overloaded` refusal and are closed
    /// without spawning anything.
    pub max_conns: usize,
    /// Maximum request line length in bytes. A line that exceeds it gets a
    /// typed `bad_input` error and the connection is closed — the server
    /// never buffers more than this (+1 byte) per connection.
    pub max_line: usize,
    /// How long shutdown waits for handlers to exit after closing their
    /// sockets before detaching the stragglers.
    pub join_deadline: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_conns: 256,
            max_line: 1 << 20, // 1 MiB: ~100k-feature rows fit comfortably
            join_deadline: Duration::from_secs(5),
        }
    }
}

/// Per-server accounting of live handler threads (ISSUE 10 satellite; the
/// shape mirrors [`crate::coordinator::batcher::reaper`], but per-server
/// rather than process-wide so concurrent servers don't share a cap).
pub struct HandlerRegistry {
    cap: usize,
    live: AtomicUsize,
    spawned: AtomicU64,
    refused: AtomicU64,
    /// Socket clone + join handle per live connection: shutdown closes the
    /// sockets (unblocking reads) and joins the handles. Finished entries
    /// are reaped opportunistically by the accept loop.
    conns: Mutex<Vec<(TcpStream, std::thread::JoinHandle<()>)>>,
}

impl HandlerRegistry {
    fn new(cap: usize) -> HandlerRegistry {
        HandlerRegistry {
            cap,
            live: AtomicUsize::new(0),
            spawned: AtomicU64::new(0),
            refused: AtomicU64::new(0),
            conns: Mutex::new(Vec::new()),
        }
    }

    /// Handler threads currently serving a connection.
    pub fn live(&self) -> usize {
        self.live.load(Ordering::SeqCst)
    }

    /// Handler threads ever spawned (monotone).
    pub fn spawned(&self) -> u64 {
        self.spawned.load(Ordering::SeqCst)
    }

    /// Connections refused at the cap (each got a typed `overloaded` reply).
    pub fn refused(&self) -> u64 {
        self.refused.load(Ordering::SeqCst)
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Reserve a handler slot; `false` at the cap (counted as refused).
    fn try_begin(&self) -> bool {
        loop {
            let cur = self.live.load(Ordering::SeqCst);
            if cur >= self.cap {
                self.refused.fetch_add(1, Ordering::SeqCst);
                return false;
            }
            if self
                .live
                .compare_exchange(cur, cur + 1, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                self.spawned.fetch_add(1, Ordering::SeqCst);
                return true;
            }
        }
    }

    fn end(&self) {
        self.live.fetch_sub(1, Ordering::SeqCst);
    }

    /// Join handlers that already exited, dropping their socket clones.
    /// Called from the accept loop so a long-lived server doesn't
    /// accumulate finished-thread bookkeeping.
    fn reap_finished(&self) {
        let finished: Vec<std::thread::JoinHandle<()>> = {
            let mut conns = self.conns.lock().unwrap();
            let mut out = Vec::new();
            let mut i = 0;
            while i < conns.len() {
                if conns[i].1.is_finished() {
                    out.push(conns.swap_remove(i).1);
                } else {
                    i += 1;
                }
            }
            out
        };
        for h in finished {
            let _ = h.join();
        }
    }

    /// Close every live connection and join its handler, waiting at most
    /// `deadline` overall. Returns whether every handler was joined
    /// (stragglers past the deadline are detached, their sockets already
    /// closed).
    fn shutdown_conns(&self, deadline: Duration) -> bool {
        let drained: Vec<(TcpStream, std::thread::JoinHandle<()>)> = {
            let mut conns = self.conns.lock().unwrap();
            conns.drain(..).collect()
        };
        for (s, _) in &drained {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        let t0 = Instant::now();
        let mut all = true;
        for (_, h) in drained {
            while !h.is_finished() && t0.elapsed() < deadline {
                std::thread::sleep(Duration::from_millis(1));
            }
            if h.is_finished() {
                let _ = h.join();
            } else {
                all = false; // dropping the handle detaches the straggler
            }
        }
        all
    }

    /// Registry counters for the `health` probe.
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("live", Json::Num(self.live() as f64)),
            ("spawned", Json::Num(self.spawned() as f64)),
            ("refused", Json::Num(self.refused() as f64)),
            ("cap", Json::Num(self.cap as f64)),
        ])
    }
}

/// Decrements the live-handler count when a handler exits — on any path,
/// including panics (a panicking handler must not strand its slot).
struct HandlerGuard(Arc<HandlerRegistry>);

impl Drop for HandlerGuard {
    fn drop(&mut self) {
        self.0.end();
    }
}

/// A running TCP front end.
pub struct NetServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    registry: Arc<HandlerRegistry>,
    join_deadline: Duration,
}

impl NetServer {
    /// Start listening with default bounds; `addr` like `"127.0.0.1:0"`
    /// (port 0 = ephemeral).
    pub fn start(server: Arc<Server>, addr: &str) -> anyhow::Result<NetServer> {
        Self::start_with(server, addr, NetConfig::default())
    }

    /// [`NetServer::start`] with explicit connection/line bounds.
    pub fn start_with(
        server: Arc<Server>,
        addr: &str,
        config: NetConfig,
    ) -> anyhow::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let registry = Arc::new(HandlerRegistry::new(config.max_conns.max(1)));
        let registry2 = registry.clone();
        listener.set_nonblocking(true)?;
        let accept_thread = std::thread::Builder::new()
            .name("net-accept".into())
            .spawn(move || {
                // Acquire pairs with the Release stores in
                // `shutdown`/`Drop`: the accept loop observes everything
                // the stopping thread did before raising the flag.
                while !stop2.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            accept_one(&server, &registry2, stream, config.max_line);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            registry2.reap_finished();
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(NetServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
            registry,
            join_deadline: config.join_deadline,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// The live-handler registry (chaos tests assert its counters).
    pub fn handlers(&self) -> &HandlerRegistry {
        &self.registry
    }

    /// Owning handle to the registry — outlives [`NetServer::shutdown`]
    /// so tests can assert the counters drained after teardown.
    pub fn handlers_arc(&self) -> Arc<HandlerRegistry> {
        self.registry.clone()
    }

    /// Stop accepting, close every live connection, and join the accept
    /// loop plus all handler threads within the configured deadline.
    /// Returns whether every handler was joined (false: stragglers were
    /// detached with their sockets already closed).
    pub fn shutdown(mut self) -> bool {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> bool {
        // Release pairs with the accept loop's Acquire load.
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        self.registry.shutdown_conns(self.join_deadline)
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Admit or refuse one accepted connection. Refusals happen *before* any
/// thread is spawned: the client gets a one-line typed `overloaded` error
/// and the socket is dropped.
fn accept_one(
    server: &Arc<Server>,
    registry: &Arc<HandlerRegistry>,
    stream: TcpStream,
    max_line: usize,
) {
    // Accepted sockets may inherit the listener's non-blocking mode on
    // some platforms; handlers want plain blocking reads.
    let _ = stream.set_nonblocking(false);
    if !registry.try_begin() {
        let refusal = wire_error(
            format!("connection limit reached ({})", registry.cap()),
            "overloaded",
            Some(50),
        );
        let mut s = stream;
        let _ = s.write_all(refusal.dump().as_bytes());
        let _ = s.write_all(b"\n");
        return;
    }
    let guard = HandlerGuard(registry.clone());
    let conn = match stream.try_clone() {
        Ok(c) => c,
        Err(_) => return, // guard releases the slot
    };
    let server = server.clone();
    let spawned = std::thread::Builder::new().name("net-handler".into()).spawn(move || {
        let _guard = guard;
        let _ = handle_conn(server, stream, max_line);
    });
    match spawned {
        Ok(h) => registry.conns.lock().unwrap().push((conn, h)),
        Err(_) => {} // spawn failure: the moved guard released the slot
    }
}

/// Outcome of one bounded line read.
enum LineRead {
    Line,
    Eof,
    TooLong,
}

/// Read one newline-terminated line into `buf`, never buffering more than
/// `max_line + 1` bytes. The satellite-1 fix: `BufReader::lines` would
/// buffer a newline-free client's bytes without bound.
fn read_line_bounded(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    max_line: usize,
) -> std::io::Result<LineRead> {
    buf.clear();
    let n = reader.by_ref().take(max_line as u64 + 1).read_until(b'\n', buf)?;
    if n == 0 {
        return Ok(LineRead::Eof);
    }
    if buf.last() != Some(&b'\n') && buf.len() > max_line {
        return Ok(LineRead::TooLong);
    }
    Ok(LineRead::Line)
}

fn handle_conn(
    server: Arc<Server>,
    stream: TcpStream,
    max_line: usize,
) -> std::io::Result<()> {
    stream.set_nodelay(true)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::new();
    loop {
        match read_line_bounded(&mut reader, &mut buf, max_line)? {
            LineRead::Eof => return Ok(()),
            LineRead::TooLong => {
                // Typed reply, then close: the connection's framing is
                // unrecoverable (we cannot tell where the line ends).
                let resp = wire_error(
                    format!("line too long (max {max_line} bytes)"),
                    "bad_input",
                    None,
                );
                writer.write_all(resp.dump().as_bytes())?;
                writer.write_all(b"\n")?;
                return Ok(());
            }
            LineRead::Line => {
                let line = String::from_utf8_lossy(&buf);
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                let response = handle_line(&server, line);
                writer.write_all(response.dump().as_bytes())?;
                writer.write_all(b"\n")?;
            }
        }
    }
}

/// A machine-readable wire error:
/// `{"error": {"message", "code"[, "retry_after_ms"]}}`.
fn wire_error(message: String, code: &str, retry_after_ms: Option<u64>) -> Json {
    let mut e = Json::from_pairs(vec![
        ("message", Json::Str(message)),
        ("code", Json::Str(code.to_string())),
    ]);
    if let Some(ms) = retry_after_ms {
        e.set("retry_after_ms", Json::Num(ms as f64));
    }
    Json::from_pairs(vec![("error", e)])
}

/// Every [`ServeError`] as a wire error. Retry hints only on the variants
/// a retry can actually help: `overloaded` (queue full now, likely not in
/// 10 ms), `deadline` (resubmit with a fresh deadline), `shutdown` (the
/// model may be redeploying).
fn serve_error_json(e: &ServeError) -> Json {
    let retry = match e {
        ServeError::Overloaded => Some(10),
        ServeError::DeadlineExceeded => Some(5),
        ServeError::Shutdown => Some(100),
        ServeError::BadInput(_) | ServeError::Internal => None,
    };
    wire_error(e.to_string(), e.code(), retry)
}

/// Process one request line (exposed for tests).
pub fn handle_line(server: &Server, line: &str) -> Json {
    let err = |msg: String| wire_error(msg, "bad_input", None);
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return err(format!("bad json: {e}")),
    };
    match req.get("cmd").and_then(|c| c.as_str()) {
        Some("list") => {
            let models = server.list().into_iter().map(Json::Str).collect();
            Json::from_pairs(vec![("models", Json::Arr(models))])
        }
        Some("health") => server.health_json(),
        Some("stats") => {
            // Whole-server modes (no model lookup): `"mode":"json"` is the
            // machine-readable scheduler + metrics snapshot, `"mode":"trace"`
            // exports the span rings as chrome-tracing JSON.
            match req.get("mode").and_then(|m| m.as_str()) {
                Some("json") => return server.stats_json(),
                Some("trace") => return crate::obs::span::export_chrome(),
                Some(other) => return err(format!("unknown stats mode '{other}'")),
                None => {}
            }
            let name = req.get("model").and_then(|m| m.as_str()).unwrap_or("");
            match server.model(name) {
                Some(dep) => Json::from_pairs(vec![
                    (
                        "report",
                        Json::Str(format!(
                            "[{}] {}",
                            dep.engine_name,
                            dep.batcher.metrics.report()
                        )),
                    ),
                    // The shared scheduler behind every model on this server.
                    ("pool_threads", Json::Num(server.pool_threads() as f64)),
                    ("pool_deployments", Json::Num(server.pool_deployments() as f64)),
                ]),
                None => err(format!("unknown model '{name}'")),
            }
        }
        Some(other) => err(format!("unknown cmd '{other}'")),
        None => {
            // Prediction request.
            let Some(name) = req.get("model").and_then(|m| m.as_str()) else {
                return err("missing 'model'".into());
            };
            let Some(x) = req.get("x").and_then(|x| x.to_f32_vec()) else {
                return err("missing or non-numeric 'x'".into());
            };
            // Optional relative client deadline: expired requests shed in
            // the batcher instead of burning pool lanes.
            let deadline = req
                .get("deadline_ms")
                .and_then(|d| d.as_f64())
                .filter(|ms| *ms >= 0.0)
                .map(|ms| Instant::now() + Duration::from_micros((ms * 1000.0) as u64));
            match server.predict_deadline(name, x, deadline) {
                Ok(scores) => {
                    let class = Forest::argmax(&scores, scores.len())[0];
                    Json::from_pairs(vec![
                        ("scores", Json::array_f32(&scores)),
                        ("class", Json::Num(class as f64)),
                    ])
                }
                Err(e) => serve_error_json(&e),
            }
        }
    }
}

/// Bounded jittered-backoff retry policy for [`NetClient`] — off by
/// default; see [`NetClient::with_retry`].
#[derive(Debug, Clone, Copy)]
struct RetryPolicy {
    max_retries: u32,
    base: Duration,
}

/// One wire error, decoded from either shape (the structured object, or
/// the legacy bare string some older peers may still emit).
struct WireError {
    message: String,
    code: Option<String>,
    retry_after_ms: Option<u64>,
}

fn decode_error(resp: &Json) -> Option<WireError> {
    let e = resp.get("error")?;
    if let Some(s) = e.as_str() {
        return Some(WireError {
            message: s.to_string(),
            code: None,
            retry_after_ms: None,
        });
    }
    Some(WireError {
        message: e
            .get("message")
            .and_then(|m| m.as_str())
            .unwrap_or("unknown error")
            .to_string(),
        code: e.get("code").and_then(|c| c.as_str()).map(str::to_string),
        retry_after_ms: e.get("retry_after_ms").and_then(|r| r.as_f64()).map(|v| v as u64),
    })
}

/// Minimal blocking client for examples/tests.
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    retry: Option<RetryPolicy>,
}

impl NetClient {
    pub fn connect(addr: std::net::SocketAddr) -> anyhow::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(NetClient {
            writer: stream.try_clone()?,
            reader: BufReader::new(stream),
            retry: None,
        })
    }

    /// Enable bounded jittered-backoff retry on `overloaded`/`deadline`
    /// error codes (satellite 3; **off by default** — retrying is a policy
    /// decision, and an uncoordinated retry storm makes overload worse).
    /// Attempt `k` sleeps `base·2^k` plus up to one extra `base` of jitter
    /// (or the server's `retry_after_ms` hint, whichever is larger).
    pub fn with_retry(mut self, max_retries: u32, base: Duration) -> NetClient {
        self.retry = Some(RetryPolicy { max_retries, base });
        self
    }

    pub fn request(&mut self, req: &Json) -> anyhow::Result<Json> {
        self.writer.write_all(req.dump().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        anyhow::ensure!(!line.is_empty(), "connection closed by server");
        Ok(Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))?)
    }

    pub fn predict(&mut self, model: &str, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.predict_deadline(model, x, None)
    }

    /// [`NetClient::predict`] with a relative deadline the server enforces
    /// (`deadline_ms` wire field). With [`NetClient::with_retry`] set,
    /// retryable error codes are retried with exponential backoff.
    pub fn predict_deadline(
        &mut self,
        model: &str,
        x: &[f32],
        deadline_ms: Option<u64>,
    ) -> anyhow::Result<Vec<f32>> {
        let mut req = Json::from_pairs(vec![
            ("model", Json::Str(model.to_string())),
            ("x", Json::array_f32(x)),
        ]);
        if let Some(ms) = deadline_ms {
            req.set("deadline_ms", Json::Num(ms as f64));
        }
        let mut attempt = 0u32;
        loop {
            let resp = self.request(&req)?;
            let Some(e) = decode_error(&resp) else {
                return resp
                    .get("scores")
                    .and_then(|s| s.to_f32_vec())
                    .ok_or_else(|| anyhow::anyhow!("no scores in response"));
            };
            let retryable =
                matches!(e.code.as_deref(), Some("overloaded") | Some("deadline"));
            let Some(p) = self.retry else {
                anyhow::bail!("server error: {}", e.message);
            };
            if !retryable || attempt >= p.max_retries {
                anyhow::bail!("server error: {}", e.message);
            }
            let backoff = p.base.saturating_mul(1 << attempt.min(16));
            let hinted = Duration::from_millis(e.retry_after_ms.unwrap_or(0));
            // Jitter from the subsecond clock — enough to decorrelate
            // concurrent clients without a PRNG dependency.
            let jitter_ns = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map_or(0, |d| d.subsec_nanos() as u64)
                % p.base.as_nanos().max(1) as u64;
            std::thread::sleep(backoff.max(hinted) + Duration::from_nanos(jitter_ns));
            attempt += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BatchConfig;
    use crate::data::DatasetId;
    use crate::engine::{EngineKind, Precision};
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    fn serving() -> (Arc<Server>, Forest, crate::data::Dataset) {
        let ds = DatasetId::Magic.generate(400, 0x7C9);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 6,
                tree: TreeParams { max_leaves: 8, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let server = Arc::new(Server::new());
        server
            .deploy("magic", &f, EngineKind::Vqs, Precision::F32, BatchConfig::default())
            .unwrap();
        (server, f, ds)
    }

    #[test]
    fn end_to_end_over_tcp() {
        let (server, f, ds) = serving();
        let net = NetServer::start(server, "127.0.0.1:0").unwrap();
        let mut client = NetClient::connect(net.addr()).unwrap();
        for i in 0..10 {
            let scores = client.predict("magic", ds.row(i)).unwrap();
            let want = f.predict_batch(ds.row(i));
            crate::testing::assert_close(&scores, &want, 1e-5, 1e-5).unwrap();
        }
        assert!(net.shutdown(), "shutdown failed to join all handlers");
    }

    #[test]
    fn protocol_commands() {
        let (server, _, ds) = serving();
        // list
        let r = handle_line(&server, r#"{"cmd": "list"}"#);
        assert_eq!(r.get("models").unwrap().as_arr().unwrap().len(), 1);
        // health
        let r = handle_line(&server, r#"{"cmd": "health"}"#);
        assert_eq!(r.get("status").and_then(|s| s.as_str()), Some("ok"));
        assert!(r
            .get("models")
            .and_then(|m| m.get("magic"))
            .and_then(|m| m.get("queue_depth"))
            .is_some());
        // stats
        let r = handle_line(&server, r#"{"cmd": "stats", "model": "magic"}"#);
        assert!(r.get("report").is_some());
        assert!(r.get("pool_threads").and_then(|v| v.as_usize()).unwrap() >= 1);
        assert_eq!(r.get("pool_deployments").and_then(|v| v.as_usize()), Some(1));
        // stats mode=json: whole-server machine-readable snapshot
        let r = handle_line(&server, r#"{"cmd": "stats", "mode": "json"}"#);
        assert!(r.get("pool").and_then(|p| p.get("claims")).is_some());
        assert!(r.get("models").and_then(|m| m.get("magic")).is_some());
        // stats mode=trace: chrome-tracing document (empty unless enabled)
        let r = handle_line(&server, r#"{"cmd": "stats", "mode": "trace"}"#);
        assert!(r.get("traceEvents").and_then(|e| e.as_arr()).is_some());
        // unknown mode is an error
        assert!(handle_line(&server, r#"{"cmd": "stats", "mode": "bogus"}"#)
            .get("error")
            .is_some());
        // predict via handle_line
        let req = Json::from_pairs(vec![
            ("model", Json::Str("magic".into())),
            ("x", Json::array_f32(ds.row(0))),
        ]);
        let r = handle_line(&server, &req.dump());
        assert!(r.get("scores").is_some());
        assert!(r.get("class").unwrap().as_usize().unwrap() < 2);
        // predict with a generous deadline still succeeds
        let mut req = req;
        req.set("deadline_ms", Json::Num(60_000.0));
        assert!(handle_line(&server, &req.dump()).get("scores").is_some());
    }

    /// Satellite 3: every error is a machine-readable object with a stable
    /// `code`; retryable codes carry a `retry_after_ms` hint.
    #[test]
    fn protocol_errors_are_structured() {
        let (server, _, ds) = serving();
        let code = |r: &Json| {
            r.get("error")
                .and_then(|e| e.get("code"))
                .and_then(|c| c.as_str())
                .map(str::to_string)
        };
        let r = handle_line(&server, "not json");
        assert_eq!(code(&r).as_deref(), Some("bad_input"));
        assert!(r
            .get("error")
            .and_then(|e| e.get("message"))
            .and_then(|m| m.as_str())
            .unwrap()
            .contains("bad json"));
        let r = handle_line(&server, r#"{"x": [1]}"#);
        assert_eq!(code(&r).as_deref(), Some("bad_input"));
        let r = handle_line(&server, r#"{"model": "nope", "x": [1]}"#);
        assert_eq!(code(&r).as_deref(), Some("bad_input"));
        let r = handle_line(&server, r#"{"cmd": "bogus"}"#);
        assert_eq!(code(&r).as_deref(), Some("bad_input"));
        // wrong feature count: the ServeError::BadInput path
        let r = handle_line(&server, r#"{"model": "magic", "x": [1, 2]}"#);
        assert_eq!(code(&r).as_deref(), Some("bad_input"));
        // already-expired deadline: code "deadline" with a retry hint
        let req = Json::from_pairs(vec![
            ("model", Json::Str("magic".into())),
            ("x", Json::array_f32(ds.row(0))),
            ("deadline_ms", Json::Num(0.0)),
        ]);
        // deadline_ms: 0 → expires immediately (admission check races the
        // clock; retry a few times to see the shed deterministically).
        let mut saw_deadline = false;
        for _ in 0..10 {
            let r = handle_line(&server, &req.dump());
            if code(&r).as_deref() == Some("deadline") {
                assert!(r
                    .get("error")
                    .and_then(|e| e.get("retry_after_ms"))
                    .and_then(|v| v.as_f64())
                    .is_some());
                saw_deadline = true;
                break;
            }
        }
        assert!(saw_deadline, "deadline_ms:0 never produced a deadline error");
        // serve_error_json covers every variant with its stable code
        for (e, c) in [
            (ServeError::Overloaded, "overloaded"),
            (ServeError::Shutdown, "shutdown"),
            (ServeError::BadInput("x".into()), "bad_input"),
            (ServeError::DeadlineExceeded, "deadline"),
            (ServeError::Internal, "internal"),
        ] {
            let j = serve_error_json(&e);
            assert_eq!(
                j.get("error").and_then(|e| e.get("code")).and_then(|c| c.as_str()),
                Some(c)
            );
        }
    }

    /// Satellite 1 regression: a multi-megabyte newline-free payload must
    /// get a typed `bad_input` reply and a closed connection — not an
    /// unbounded buffer — and the server must keep serving other clients.
    #[test]
    fn overlong_line_is_refused_and_connection_closed() {
        let (server, _, ds) = serving();
        let net = NetServer::start_with(
            server,
            "127.0.0.1:0",
            NetConfig { max_line: 2 << 20, ..NetConfig::default() },
        )
        .unwrap();
        let mut s = TcpStream::connect(net.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        // A multi-megabyte payload with no newline anywhere — one byte
        // over the cap, so the server consumes all of it (never buffering
        // more than cap+1) and its close is a clean FIN: the typed reply
        // is reliably readable (unread bytes at close would RST and could
        // discard it).
        let blob = vec![b'a'; (2 << 20) + 1];
        s.write_all(&blob).unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        let e = resp.get("error").expect("typed error reply");
        assert_eq!(e.get("code").and_then(|c| c.as_str()), Some("bad_input"));
        assert!(e
            .get("message")
            .and_then(|m| m.as_str())
            .unwrap()
            .contains("line too long"));
        // The connection is closed after the reply.
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "connection must close");
        // And the server still serves well-behaved clients.
        let mut client = NetClient::connect(net.addr()).unwrap();
        assert!(client.predict("magic", ds.row(0)).is_ok());
        assert!(net.shutdown());
    }

    /// Satellite 2: past the connection cap, new connections get a typed
    /// `overloaded` refusal without a handler thread; shutdown closes live
    /// connections and joins every handler (registry drains to zero).
    #[test]
    fn connection_cap_refuses_with_typed_error() {
        let (server, _, ds) = serving();
        let net = NetServer::start_with(
            server,
            "127.0.0.1:0",
            NetConfig { max_conns: 2, ..NetConfig::default() },
        )
        .unwrap();
        // Two idle clients pin both handler slots.
        let c1 = NetClient::connect(net.addr()).unwrap();
        let c2 = NetClient::connect(net.addr()).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while net.handlers().live() < 2 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(net.handlers().live(), 2);
        // The third is refused with code "overloaded" and a retry hint.
        let s = TcpStream::connect(net.addr()).unwrap();
        let mut reader = BufReader::new(s);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        let e = resp.get("error").expect("typed refusal");
        assert_eq!(e.get("code").and_then(|c| c.as_str()), Some("overloaded"));
        assert!(e.get("retry_after_ms").and_then(|v| v.as_f64()).is_some());
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0);
        assert!(net.handlers().refused() >= 1);
        // A slot freed by a disconnect is reusable.
        drop(c1);
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut c3 = loop {
            if let Ok(mut c) = NetClient::connect(net.addr()) {
                if c.predict("magic", ds.row(0)).is_ok() {
                    break c;
                }
            }
            assert!(Instant::now() < deadline, "slot never freed");
            std::thread::sleep(Duration::from_millis(5));
        };
        assert!(c3.predict("magic", ds.row(1)).is_ok());
        // Shutdown with clients still connected: their sockets are closed
        // server-side, every handler joins, nothing leaks.
        let registry = net.handlers_arc();
        assert!(net.shutdown(), "handlers not joined within deadline");
        assert_eq!(registry.live(), 0);
        drop(c2);
        drop(c3);
    }

    /// Satellite 3: with_retry retries `overloaded`/`deadline` codes with
    /// bounded attempts, and gives up with the server's message once the
    /// budget is exhausted; non-retryable codes fail immediately.
    #[test]
    fn client_retry_on_retryable_codes() {
        let (server, _, ds) = serving();
        let net = NetServer::start(server, "127.0.0.1:0").unwrap();
        // deadline_ms: 0 always sheds → the retry budget is consumed, then
        // the typed error surfaces. 2 retries at 1 ms base ≈ 3 attempts.
        let mut client =
            NetClient::connect(net.addr()).unwrap().with_retry(2, Duration::from_millis(1));
        let err = client
            .predict_deadline("magic", ds.row(0), Some(0))
            .expect_err("deadline 0 must fail");
        assert!(err.to_string().contains("deadline"), "{err}");
        // Non-retryable: unknown model fails on the first attempt (no
        // observable way to count attempts here, but the path returns
        // immediately with the bad_input message).
        let err = client.predict("nope", ds.row(0)).expect_err("unknown model");
        assert!(err.to_string().contains("unknown model"), "{err}");
        // And a retry-enabled client still succeeds on healthy requests.
        assert!(client.predict("magic", ds.row(0)).is_ok());
        assert!(net.shutdown());
    }

    #[test]
    fn concurrent_clients() {
        let (server, f, ds) = serving();
        let net = NetServer::start(server, "127.0.0.1:0").unwrap();
        let addr = net.addr();
        let want = Arc::new(f.predict_batch(&ds.x));
        let ds = Arc::new(ds);
        let mut handles = Vec::new();
        for t in 0..4 {
            let want = want.clone();
            let ds = ds.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = NetClient::connect(addr).unwrap();
                for i in (t..40).step_by(4) {
                    let got = client.predict("magic", ds.row(i)).unwrap();
                    crate::testing::assert_close(
                        &got,
                        &want[i * ds.n_classes..(i + 1) * ds.n_classes],
                        1e-5,
                        1e-5,
                    )
                    .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(net.handlers().spawned(), 4);
        let registry = net.handlers_arc();
        assert!(net.shutdown());
        assert_eq!(registry.live(), 0);
    }
}
