//! Network front end: newline-delimited JSON over TCP.
//!
//! A deliberately small wire protocol (no HTTP stack offline) that makes the
//! coordinator an actual network service:
//!
//! ```text
//! → {"model": "magic", "x": [0.1, 0.2, ...]}
//! ← {"scores": [0.93, 0.07], "class": 0}
//! → {"cmd": "list"}
//! ← {"models": ["magic"]}
//! → {"cmd": "stats", "model": "magic"}
//! ← {"report": "..."}
//! ```
//!
//! One line per request/response; errors come back as `{"error": "..."}`.
//! Each connection gets a handler thread; prediction itself goes through the
//! dynamic batcher, so concurrent connections share SIMD blocks.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::forest::Forest;
use crate::util::Json;

use super::Server;

/// A running TCP front end.
pub struct NetServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Start listening; `addr` like `"127.0.0.1:0"` (port 0 = ephemeral).
    pub fn start(server: Arc<Server>, addr: &str) -> anyhow::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        listener.set_nonblocking(true)?;
        let accept_thread = std::thread::Builder::new()
            .name("net-accept".into())
            .spawn(move || {
                // Acquire pairs with the Release stores in
                // `shutdown`/`Drop`: the accept loop observes everything
                // the stopping thread did before raising the flag.
                while !stop2.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let server = server.clone();
                            // Handler threads are detached: they exit when
                            // their client hangs up. Joining them here would
                            // deadlock shutdown against still-connected
                            // clients.
                            std::thread::spawn(move || {
                                let _ = handle_conn(server, stream);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(NetServer { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Signal shutdown and join the accept loop.
    pub fn shutdown(mut self) {
        // Release pairs with the accept loop's Acquire load.
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        // Release pairs with the accept loop's Acquire load.
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(server: Arc<Server>, stream: TcpStream) -> std::io::Result<()> {
    stream.set_nodelay(true)?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = handle_line(&server, &line);
        writer.write_all(response.dump().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

/// Process one request line (exposed for tests).
pub fn handle_line(server: &Server, line: &str) -> Json {
    let err = |msg: String| Json::from_pairs(vec![("error", Json::Str(msg))]);
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return err(format!("bad json: {e}")),
    };
    match req.get("cmd").and_then(|c| c.as_str()) {
        Some("list") => {
            let models = server.list().into_iter().map(Json::Str).collect();
            Json::from_pairs(vec![("models", Json::Arr(models))])
        }
        Some("stats") => {
            // Whole-server modes (no model lookup): `"mode":"json"` is the
            // machine-readable scheduler + metrics snapshot, `"mode":"trace"`
            // exports the span rings as chrome-tracing JSON.
            match req.get("mode").and_then(|m| m.as_str()) {
                Some("json") => return server.stats_json(),
                Some("trace") => return crate::obs::span::export_chrome(),
                Some(other) => return err(format!("unknown stats mode '{other}'")),
                None => {}
            }
            let name = req.get("model").and_then(|m| m.as_str()).unwrap_or("");
            match server.model(name) {
                Some(dep) => Json::from_pairs(vec![
                    (
                        "report",
                        Json::Str(format!(
                            "[{}] {}",
                            dep.engine_name,
                            dep.batcher.metrics.report()
                        )),
                    ),
                    // The shared scheduler behind every model on this server.
                    ("pool_threads", Json::Num(server.pool_threads() as f64)),
                    ("pool_deployments", Json::Num(server.pool_deployments() as f64)),
                ]),
                None => err(format!("unknown model '{name}'")),
            }
        }
        Some(other) => err(format!("unknown cmd '{other}'")),
        None => {
            // Prediction request.
            let Some(name) = req.get("model").and_then(|m| m.as_str()) else {
                return err("missing 'model'".into());
            };
            let Some(x) = req.get("x").and_then(|x| x.to_f32_vec()) else {
                return err("missing or non-numeric 'x'".into());
            };
            match server.predict(name, x) {
                Ok(scores) => {
                    let class = Forest::argmax(&scores, scores.len())[0];
                    Json::from_pairs(vec![
                        ("scores", Json::array_f32(&scores)),
                        ("class", Json::Num(class as f64)),
                    ])
                }
                Err(e) => err(e.to_string()),
            }
        }
    }
}

/// Minimal blocking client for examples/tests.
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl NetClient {
    pub fn connect(addr: std::net::SocketAddr) -> anyhow::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(NetClient { writer: stream.try_clone()?, reader: BufReader::new(stream) })
    }

    pub fn request(&mut self, req: &Json) -> anyhow::Result<Json> {
        self.writer.write_all(req.dump().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))?)
    }

    pub fn predict(&mut self, model: &str, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        let req = Json::from_pairs(vec![
            ("model", Json::Str(model.to_string())),
            ("x", Json::array_f32(x)),
        ]);
        let resp = self.request(&req)?;
        if let Some(e) = resp.get("error").and_then(|e| e.as_str()) {
            anyhow::bail!("server error: {e}");
        }
        resp.get("scores")
            .and_then(|s| s.to_f32_vec())
            .ok_or_else(|| anyhow::anyhow!("no scores in response"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BatchConfig;
    use crate::data::DatasetId;
    use crate::engine::{EngineKind, Precision};
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    fn serving() -> (Arc<Server>, Forest, crate::data::Dataset) {
        let ds = DatasetId::Magic.generate(400, 0x7C9);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 6,
                tree: TreeParams { max_leaves: 8, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let server = Arc::new(Server::new());
        server
            .deploy("magic", &f, EngineKind::Vqs, Precision::F32, BatchConfig::default())
            .unwrap();
        (server, f, ds)
    }

    #[test]
    fn end_to_end_over_tcp() {
        let (server, f, ds) = serving();
        let net = NetServer::start(server, "127.0.0.1:0").unwrap();
        let mut client = NetClient::connect(net.addr()).unwrap();
        for i in 0..10 {
            let scores = client.predict("magic", ds.row(i)).unwrap();
            let want = f.predict_batch(ds.row(i));
            crate::testing::assert_close(&scores, &want, 1e-5, 1e-5).unwrap();
        }
        net.shutdown();
    }

    #[test]
    fn protocol_commands() {
        let (server, _, ds) = serving();
        // list
        let r = handle_line(&server, r#"{"cmd": "list"}"#);
        assert_eq!(r.get("models").unwrap().as_arr().unwrap().len(), 1);
        // stats
        let r = handle_line(&server, r#"{"cmd": "stats", "model": "magic"}"#);
        assert!(r.get("report").is_some());
        assert!(r.get("pool_threads").and_then(|v| v.as_usize()).unwrap() >= 1);
        assert_eq!(r.get("pool_deployments").and_then(|v| v.as_usize()), Some(1));
        // stats mode=json: whole-server machine-readable snapshot
        let r = handle_line(&server, r#"{"cmd": "stats", "mode": "json"}"#);
        assert!(r.get("pool").and_then(|p| p.get("claims")).is_some());
        assert!(r.get("models").and_then(|m| m.get("magic")).is_some());
        // stats mode=trace: chrome-tracing document (empty unless enabled)
        let r = handle_line(&server, r#"{"cmd": "stats", "mode": "trace"}"#);
        assert!(r.get("traceEvents").and_then(|e| e.as_arr()).is_some());
        // unknown mode is an error
        assert!(handle_line(&server, r#"{"cmd": "stats", "mode": "bogus"}"#)
            .get("error")
            .is_some());
        // predict via handle_line
        let req = Json::from_pairs(vec![
            ("model", Json::Str("magic".into())),
            ("x", Json::array_f32(ds.row(0))),
        ]);
        let r = handle_line(&server, &req.dump());
        assert!(r.get("scores").is_some());
        assert!(r.get("class").unwrap().as_usize().unwrap() < 2);
    }

    #[test]
    fn protocol_errors() {
        let (server, _, _) = serving();
        assert!(handle_line(&server, "not json").get("error").is_some());
        assert!(handle_line(&server, r#"{"x": [1]}"#).get("error").is_some());
        assert!(handle_line(&server, r#"{"model": "nope", "x": [1]}"#)
            .get("error")
            .is_some());
        assert!(handle_line(&server, r#"{"cmd": "bogus"}"#).get("error").is_some());
        // wrong feature count
        assert!(handle_line(&server, r#"{"model": "magic", "x": [1, 2]}"#)
            .get("error")
            .is_some());
    }

    #[test]
    fn concurrent_clients() {
        let (server, f, ds) = serving();
        let net = NetServer::start(server, "127.0.0.1:0").unwrap();
        let addr = net.addr();
        let want = Arc::new(f.predict_batch(&ds.x));
        let ds = Arc::new(ds);
        let mut handles = Vec::new();
        for t in 0..4 {
            let want = want.clone();
            let ds = ds.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = NetClient::connect(addr).unwrap();
                for i in (t..40).step_by(4) {
                    let got = client.predict("magic", ds.row(i)).unwrap();
                    crate::testing::assert_close(
                        &got,
                        &want[i * ds.n_classes..(i + 1) * ds.n_classes],
                        1e-5,
                        1e-5,
                    )
                    .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        net.shutdown();
    }
}
