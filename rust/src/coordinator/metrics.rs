//! Serving metrics: counters and latency reservoirs, lock-cheap enough for
//! the request path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::Summary;

/// Per-model serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    /// Requests answered `ServeError::Shutdown` by the drain instead of
    /// being executed (accepted but never flushed before teardown).
    pub shed_shutdown: AtomicU64,
    /// Requests answered `ServeError::Internal` because a shard task died
    /// mid-batch (engine panic). Not counted in `completed`.
    pub failed: AtomicU64,
    /// Drain-timeout abandons that handed pool teardown to a detached
    /// reaper thread — each may be parked (leaked) for as long as its hung
    /// engine stays hung. Server-wide live/spawned/refused totals are in
    /// `coordinator::batcher::reaper`; this is the per-deployment share.
    pub reaper_threads: AtomicU64,
    pub batches: AtomicU64,
    pub batched_instances: AtomicU64,
    /// End-to-end request latencies in µs (bounded reservoir).
    latencies_us: Mutex<Vec<f64>>,
    /// Batch execution times in µs.
    batch_us: Mutex<Vec<f64>>,
}

const RESERVOIR: usize = 65_536;

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_latency(&self, us: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let mut l = self.latencies_us.lock().unwrap();
        if l.len() < RESERVOIR {
            l.push(us);
        }
    }

    pub fn record_batch(&self, size: usize, us: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_instances.fetch_add(size as u64, Ordering::Relaxed);
        let mut b = self.batch_us.lock().unwrap();
        if b.len() < RESERVOIR {
            b.push(us);
        }
    }

    /// Latency summary (µs).
    pub fn latency_summary(&self) -> Summary {
        Summary::of(&self.latencies_us.lock().unwrap())
    }

    /// Batch-execution summary (µs).
    pub fn batch_summary(&self) -> Summary {
        Summary::of(&self.batch_us.lock().unwrap())
    }

    /// Mean instances per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_instances.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// One-line human report.
    pub fn report(&self) -> String {
        let lat = self.latency_summary();
        format!(
            "req={} done={} rej={} shed={} failed={} reapers={} batches={} mean_batch={:.1} lat_us(p50={:.0} p95={:.0} p99={:.0} max={:.0})",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.shed_shutdown.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.reaper_threads.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            lat.median,
            lat.p95,
            lat.p99,
            lat.max,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record_latency(100.0);
        m.record_latency(200.0);
        m.record_batch(2, 150.0);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.mean_batch_size(), 2.0);
        let s = m.latency_summary();
        assert_eq!(s.n, 2);
        assert!(m.report().contains("batches=1"));
    }
}
