//! Serving metrics: counters and latency histograms, lock-free on the
//! request path.
//!
//! Latency and batch-time distributions are [`crate::obs::Histogram`]s —
//! log-bucketed, atomic, fixed-memory — so quantiles stay accurate (~2%
//! relative error, DESIGN.md §8) over unbounded runs. The previous capped
//! `Vec` reservoirs silently stopped sampling after 65,536 entries, so a
//! long-running server's p99 reflected only its startup; the regression
//! test below pins the fix.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::Histogram;
use crate::util::{Json, Summary};

/// Per-model serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    /// Requests answered `ServeError::Shutdown` by the drain instead of
    /// being executed (accepted but never flushed before teardown).
    pub shed_shutdown: AtomicU64,
    /// Requests answered `ServeError::DeadlineExceeded`: their client
    /// deadline had already passed at admission or at flush time, so the
    /// pool never spent SIMD time on them. Not counted in `completed`.
    pub deadline_exceeded: AtomicU64,
    /// Requests answered `ServeError::Internal` because a shard task died
    /// mid-batch (engine panic). Not counted in `completed`.
    pub failed: AtomicU64,
    /// Drain-timeout abandons that handed pool teardown to a detached
    /// reaper thread — each may be parked (leaked) for as long as its hung
    /// engine stays hung. Server-wide live/spawned/refused totals are in
    /// `coordinator::batcher::reaper`; this is the per-deployment share.
    pub reaper_threads: AtomicU64,
    pub batches: AtomicU64,
    pub batched_instances: AtomicU64,
    /// End-to-end request latencies in µs.
    latencies_us: Histogram,
    /// Batch execution times in µs.
    batch_us: Histogram,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_latency(&self, us: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies_us.record(us);
    }

    pub fn record_batch(&self, size: usize, us: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_instances.fetch_add(size as u64, Ordering::Relaxed);
        self.batch_us.record(us);
    }

    /// Latency summary (µs).
    pub fn latency_summary(&self) -> Summary {
        self.latencies_us.summary()
    }

    /// Batch-execution summary (µs).
    pub fn batch_summary(&self) -> Summary {
        self.batch_us.summary()
    }

    /// Bucket snapshot of the latency histogram. Successive snapshots give
    /// a **windowed** p99 via [`Histogram::quantile_between`] — the degrade
    /// controller's overload signal (a cumulative p99 barely moves under a
    /// fresh burst after hours of healthy traffic).
    pub fn latency_buckets(&self) -> Vec<u64> {
        self.latencies_us.snapshot()
    }

    /// Mean instances per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_instances.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Every exported counter as `(name, value)`, in a stable order — the
    /// single source of truth for [`Metrics::to_json`] and for tests that
    /// assert over the counter set (no re-typed field lists to go stale).
    pub fn counters(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("requests", self.requests.load(Ordering::Relaxed)),
            ("completed", self.completed.load(Ordering::Relaxed)),
            ("rejected", self.rejected.load(Ordering::Relaxed)),
            ("shed_shutdown", self.shed_shutdown.load(Ordering::Relaxed)),
            ("deadline_exceeded", self.deadline_exceeded.load(Ordering::Relaxed)),
            ("failed", self.failed.load(Ordering::Relaxed)),
            ("reaper_threads", self.reaper_threads.load(Ordering::Relaxed)),
            ("batches", self.batches.load(Ordering::Relaxed)),
            ("batched_instances", self.batched_instances.load(Ordering::Relaxed)),
        ]
    }

    /// Machine-readable snapshot: every counter plus latency/batch
    /// summaries (consumed by `Server::stats_json` / `stats --json`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        for (k, v) in self.counters() {
            j.set(k, Json::Num(v as f64));
        }
        j.set("mean_batch_size", Json::Num(self.mean_batch_size()));
        j.set("latency_us", summary_json(&self.latency_summary()));
        j.set("batch_us", summary_json(&self.batch_summary()));
        j
    }

    /// One-line human report.
    pub fn report(&self) -> String {
        let lat = self.latency_summary();
        format!(
            "req={} done={} rej={} shed={} ddl={} failed={} reapers={} batches={} mean_batch={:.1} lat_us(p50={:.0} p95={:.0} p99={:.0} max={:.0})",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.shed_shutdown.load(Ordering::Relaxed),
            self.deadline_exceeded.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.reaper_threads.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            lat.median,
            lat.p95,
            lat.p99,
            lat.max,
        )
    }
}

/// A [`Summary`] as a JSON object (shared by metrics and pool stats).
pub fn summary_json(s: &Summary) -> Json {
    Json::from_pairs(vec![
        ("n", Json::Num(s.n as f64)),
        ("mean", Json::Num(s.mean)),
        ("std", Json::Num(s.std)),
        ("min", Json::Num(s.min)),
        ("median", Json::Num(s.median)),
        ("p95", Json::Num(s.p95)),
        ("p99", Json::Num(s.p99)),
        ("max", Json::Num(s.max)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record_latency(100.0);
        m.record_latency(200.0);
        m.record_batch(2, 150.0);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.mean_batch_size(), 2.0);
        let s = m.latency_summary();
        assert_eq!(s.n, 2);
        assert!(m.report().contains("batches=1"));
    }

    /// Satellite 1 regression: the old `Vec` reservoir stopped sampling at
    /// 65,536 entries, so quantiles froze at startup values. With
    /// histograms, samples recorded *past* that point must still move the
    /// quantiles.
    #[test]
    fn quantiles_keep_moving_past_old_reservoir_size() {
        const OLD_RESERVOIR: usize = 65_536;
        let m = Metrics::new();
        for _ in 0..OLD_RESERVOIR {
            m.record_latency(100.0);
        }
        let before = m.latency_summary();
        assert!((before.p95 - 100.0).abs() / 100.0 < 0.03, "p95 near 100, got {}", before.p95);
        // The old implementation dropped every one of these on the floor.
        for _ in 0..OLD_RESERVOIR {
            m.record_latency(1000.0);
        }
        let after = m.latency_summary();
        assert_eq!(after.n, 2 * OLD_RESERVOIR, "every sample must be counted");
        assert!(
            after.p95 > 900.0,
            "p95 must reflect post-reservoir samples, got {}",
            after.p95
        );
        assert_eq!(after.max, 1000.0, "max is tracked exactly");
    }

    /// Satellite 6: the JSON export is checked against the exported
    /// counter list itself, not a re-typed copy of the field names.
    #[test]
    fn json_export_covers_every_counter() {
        let m = Metrics::new();
        m.record_latency(50.0);
        m.record_batch(4, 75.0);
        let j = m.to_json();
        let counters = m.counters();
        assert!(!counters.is_empty());
        for (name, value) in counters {
            let got = j.get(name).and_then(|v| v.as_f64());
            assert_eq!(got, Some(value as f64), "to_json missing/mismatched counter {name}");
        }
        for k in ["mean_batch_size", "latency_us", "batch_us"] {
            assert!(j.get(k).is_some(), "to_json missing {k}");
        }
        assert_eq!(
            j.get("latency_us").and_then(|l| l.get("n")).and_then(|n| n.as_f64()),
            Some(1.0)
        );
    }
}
