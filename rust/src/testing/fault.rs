//! Deterministic fault injection for the serving stack (ISSUE 10).
//!
//! The chaos test (`rust/tests/chaos.rs`) drives the full
//! net → batcher → pool path while these injectors misbehave on purpose:
//!
//! * [`PanicEngine`] — panics inside `predict_batch` on exactly the n-th
//!   batch (the pool's catch-unwind path must convert it to
//!   `ServeError::Internal`, not kill the server);
//! * [`StallEngine`] — stalls the first n batches for a fixed duration
//!   (long enough to push a drain past `give_back_after` or a deadline
//!   past its budget — a wedged model, not a dead one);
//! * [`disconnect_mid_request`] — sends a request and drops the socket
//!   without reading the reply (the handler's write must fail quietly and
//!   release its registry slot);
//! * [`poisoned_rows`] / [`POISONED_LINES`] — malformed payloads at the
//!   vector level (NaN/∞/wrong width) and the wire level (broken JSON,
//!   wrong types), each of which must produce exactly one typed error
//!   reply, never a hang or a crash.
//!
//! Everything here is deterministic — faults fire on counted calls, not
//! timers or randomness — so a chaos-test failure replays.

use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::engine::Engine;

/// Wraps an engine and panics on exactly the `panic_on`-th call to
/// `predict_batch` (1-based); every other call delegates. The panic fires
/// once — batches after it succeed, so a test can assert the server
/// *recovers*, not merely that it fails.
pub struct PanicEngine {
    inner: Arc<dyn Engine>,
    panic_on: u64,
    calls: AtomicU64,
}

impl PanicEngine {
    pub fn new(inner: Arc<dyn Engine>, panic_on: u64) -> PanicEngine {
        PanicEngine { inner, panic_on: panic_on.max(1), calls: AtomicU64::new(0) }
    }

    /// Batches attempted so far (including the one that panicked).
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::SeqCst)
    }
}

impl Engine for PanicEngine {
    fn name(&self) -> String {
        format!("panic@{}({})", self.panic_on, self.inner.name())
    }
    fn lanes(&self) -> usize {
        self.inner.lanes()
    }
    fn n_features(&self) -> usize {
        self.inner.n_features()
    }
    fn n_classes(&self) -> usize {
        self.inner.n_classes()
    }
    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let call = self.calls.fetch_add(1, Ordering::SeqCst) + 1;
        if call == self.panic_on {
            panic!("injected engine panic (batch {call})");
        }
        self.inner.predict_batch(x, out);
    }
}

/// Wraps an engine and stalls the first `stall_batches` calls for `stall`
/// each before delegating — a deterministically slow model. Results stay
/// correct; only latency is injected.
pub struct StallEngine {
    inner: Arc<dyn Engine>,
    stall: Duration,
    stall_batches: u64,
    calls: AtomicU64,
}

impl StallEngine {
    pub fn new(inner: Arc<dyn Engine>, stall: Duration, stall_batches: u64) -> StallEngine {
        StallEngine { inner, stall, stall_batches, calls: AtomicU64::new(0) }
    }
}

impl Engine for StallEngine {
    fn name(&self) -> String {
        format!("stall({})", self.inner.name())
    }
    fn lanes(&self) -> usize {
        self.inner.lanes()
    }
    fn n_features(&self) -> usize {
        self.inner.n_features()
    }
    fn n_classes(&self) -> usize {
        self.inner.n_classes()
    }
    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let call = self.calls.fetch_add(1, Ordering::SeqCst) + 1;
        if call <= self.stall_batches {
            std::thread::sleep(self.stall);
        }
        self.inner.predict_batch(x, out);
    }
}

/// Connect, send one request line, and drop the socket without reading
/// the reply — a client that vanished mid-request. The server handler's
/// reply write lands on a closed/closing socket; the handler must treat
/// that as end-of-connection, not a crash.
pub fn disconnect_mid_request(
    addr: std::net::SocketAddr,
    line: &str,
) -> std::io::Result<()> {
    let mut s = std::net::TcpStream::connect(addr)?;
    s.set_nodelay(true)?;
    s.write_all(line.as_bytes())?;
    s.write_all(b"\n")?;
    Ok(()) // drop closes the socket with the reply unread
}

/// Malformed feature vectors for a `d`-feature model, labeled for
/// assertion messages. Wrong-width rows must be refused (`bad_input`);
/// non-finite rows are width-correct and must produce a normal scored
/// reply (engines are total over f32) — either way, exactly one reply.
pub fn poisoned_rows(d: usize) -> Vec<(&'static str, Vec<f32>)> {
    vec![
        ("nan-row", vec![f32::NAN; d]),
        ("pos-inf-row", vec![f32::INFINITY; d]),
        ("neg-inf-row", vec![f32::NEG_INFINITY; d]),
        ("empty-row", Vec::new()),
        ("short-row", vec![0.5; d.saturating_sub(1).max(1)]),
        ("long-row", vec![0.5; d + 3]),
    ]
}

/// Malformed wire lines (model-independent). Each must get exactly one
/// typed error reply on an otherwise healthy connection.
pub const POISONED_LINES: &[&str] = &[
    "not json at all",
    "{\"model\": \"magic\", \"x\": ",
    "{\"model\": 7, \"x\": [1]}",
    "{\"model\": \"magic\", \"x\": \"strings\"}",
    "{\"cmd\": \"no-such-cmd\"}",
    "{}",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::engine::{build, EngineKind, Precision};
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    fn small_engine() -> (Arc<dyn Engine>, crate::data::Dataset) {
        let ds = DatasetId::Magic.generate(64, 0xFA17);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 4,
                tree: TreeParams { max_leaves: 8, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let e: Arc<dyn Engine> =
            Arc::from(build(EngineKind::Naive, Precision::F32, &f, None).unwrap());
        (e, ds)
    }

    #[test]
    fn panic_engine_fires_on_exactly_the_nth_batch() {
        let (inner, ds) = small_engine();
        let e = PanicEngine::new(inner.clone(), 2);
        // Batch 1 delegates and matches the inner engine bit-for-bit.
        let got = e.predict(ds.row(0));
        assert_eq!(got, inner.predict(ds.row(0)));
        // Batch 2 panics.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e.predict(ds.row(1));
        }));
        assert!(caught.is_err(), "batch 2 must panic");
        // Batch 3 recovers.
        assert_eq!(e.predict(ds.row(2)), inner.predict(ds.row(2)));
        assert_eq!(e.calls(), 3);
    }

    #[test]
    fn stall_engine_delays_then_recovers_with_exact_results() {
        let (inner, ds) = small_engine();
        let e = StallEngine::new(inner.clone(), Duration::from_millis(30), 1);
        let t0 = std::time::Instant::now();
        let got = e.predict(ds.row(0));
        assert!(t0.elapsed() >= Duration::from_millis(30), "first batch must stall");
        assert_eq!(got, inner.predict(ds.row(0)));
        let t0 = std::time::Instant::now();
        assert_eq!(e.predict(ds.row(1)), inner.predict(ds.row(1)));
        assert!(t0.elapsed() < Duration::from_millis(30), "second batch must not stall");
    }

    #[test]
    fn poisoned_rows_cover_width_and_value_faults() {
        let rows = poisoned_rows(10);
        assert!(rows.iter().any(|(_, r)| r.iter().any(|v| v.is_nan())));
        assert!(rows.iter().any(|(_, r)| r.len() != 10));
        assert!(rows.iter().any(|(_, r)| r.is_empty()));
    }
}
