//! Minimal property-based testing harness.
//!
//! `proptest` is unavailable offline, so this module provides the small core
//! the test suite needs: run a property over many seeded random cases and, on
//! failure, report the failing seed so the case can be replayed exactly
//! (`Runner::replay`). There is no structural shrinking; instead generators
//! are asked for progressively *smaller* cases first, so the earliest failure
//! tends to be near-minimal.

use crate::util::Pcg32;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed; each case `i` runs with `Pcg32::new(seed, i)`.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xa11ce }
    }
}

/// Property runner. A "size" parameter grows from 1 toward `max_size` across
/// the run so early cases are small (cheap, near-minimal counterexamples) and
/// later cases stress larger inputs.
pub struct Runner {
    pub config: Config,
    pub max_size: usize,
}

impl Runner {
    pub fn new(cases: usize) -> Self {
        // Miri runs the interpreter ~2–3 orders of magnitude slower than
        // native; 4 cases keep every property exercised (including the
        // unsafe code paths Miri exists to check) at tractable cost. The
        // ramp still starts at size 1, so the cases kept are the small,
        // near-minimal ones.
        #[cfg(miri)]
        let cases = cases.min(4);
        Runner { config: Config { cases, ..Default::default() }, max_size: 64 }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.config.seed = seed;
        self
    }

    pub fn with_max_size(mut self, max_size: usize) -> Self {
        self.max_size = max_size;
        self
    }

    /// Run `prop(rng, size)` for each case; panics with the failing case id
    /// and seed on the first `Err`.
    pub fn run<F>(&self, mut prop: F)
    where
        F: FnMut(&mut Pcg32, usize) -> Result<(), String>,
    {
        for case in 0..self.config.cases {
            let size = self.size_for(case);
            let mut rng = Pcg32::new(self.config.seed, case as u64);
            if let Err(msg) = prop(&mut rng, size) {
                panic!(
                    "property failed at case {case} (size {size}, seed {:#x}, stream {case}): {msg}\n\
                     replay with Runner::replay({:#x}, {case})",
                    self.config.seed, self.config.seed
                );
            }
        }
    }

    /// Re-run a single failing case (same rng stream as the failed run).
    pub fn replay<F>(seed: u64, case: usize, size: usize, mut prop: F)
    where
        F: FnMut(&mut Pcg32, usize) -> Result<(), String>,
    {
        let mut rng = Pcg32::new(seed, case as u64);
        if let Err(msg) = prop(&mut rng, size) {
            panic!("replayed property failure: {msg}");
        }
    }

    fn size_for(&self, case: usize) -> usize {
        // Ramp from 1 to max_size over the run.
        let n = self.config.cases.max(1);
        1 + (self.max_size.saturating_sub(1)) * case / n
    }
}

/// Assert two f32 slices match within absolute + relative tolerance, with a
/// useful message naming the first mismatching index.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!("index {i}: {x} vs {y} (|diff|={} > tol={tol})", (x - y).abs()));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Runner::new(32).run(|rng, size| {
            let n = rng.range(1, size + 2);
            let v: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            if v.len() == n {
                Ok(())
            } else {
                Err("len".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        Runner::new(16).run(|rng, _| {
            if rng.below(4) == 3 {
                Err("hit 3".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn sizes_ramp() {
        let r = Runner::new(10).with_max_size(100);
        assert_eq!(r.size_for(0), 1);
        assert!(r.size_for(9) > r.size_for(0));
        assert!(r.size_for(9) <= 100);
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0], &[1.0], 1e-6, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-6, 0.0).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-6, 0.0).is_err());
    }
}
