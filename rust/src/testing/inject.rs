//! Shared adversarial-value injection helpers for exactness suites.
//!
//! Hoisted from `rust/tests/flint_exact.rs` so every bit-exactness contract
//! (FLInt carriers, early-exit staging, future tiers) seeds batches from
//! the *same* corner-value set — a new suite must not quietly test a
//! weaker adversary.

/// Adversarial f32 values every batch gets seeded with: both zeros, quiet
/// NaN, the smallest denormals, both infinities, and values straddling the
/// sign boundary (the regime sign-magnitude fixups exist for).
pub const ADVERSARIAL: [f32; 12] = [
    0.0,
    -0.0,
    f32::NAN,
    f32::INFINITY,
    f32::NEG_INFINITY,
    f32::MIN_POSITIVE,            // smallest normal
    1.0e-40,                      // denormal
    -1.0e-40,                     // negative denormal
    f32::MAX,
    f32::MIN,
    1.0,
    -1.0,
];

/// Raw-bit view for bit-identity comparison (NaN-safe, ±0.0-distinguishing
/// — `==` on f32 is neither).
pub fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adversarial_covers_the_corner_classes() {
        assert!(ADVERSARIAL.iter().any(|v| v.is_nan()));
        assert!(ADVERSARIAL.iter().any(|v| v.is_infinite() && *v > 0.0));
        assert!(ADVERSARIAL.iter().any(|v| v.is_infinite() && *v < 0.0));
        assert!(ADVERSARIAL.iter().any(|v| v.to_bits() == 0)); // +0.0
        assert!(ADVERSARIAL.iter().any(|v| v.to_bits() == 0x8000_0000)); // -0.0
        assert!(ADVERSARIAL.iter().any(|v| *v != 0.0 && v.abs() < f32::MIN_POSITIVE));
    }

    #[test]
    fn bits_distinguishes_what_eq_conflates() {
        // ±0.0 compare equal but have different bits; NaN != NaN but its
        // bits are stable.
        assert_eq!(0.0f32, -0.0f32);
        assert_ne!(bits(&[0.0]), bits(&[-0.0]));
        assert_eq!(bits(&[f32::NAN]), bits(&[f32::NAN]));
    }
}
