//! In-repo property-testing harness (no proptest offline — see DESIGN.md).

pub mod prop;
pub mod sched;

pub use prop::{assert_close, Runner};
pub use sched::explore;
