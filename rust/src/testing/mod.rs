//! In-repo property-testing harness (no proptest offline — see DESIGN.md).

pub mod prop;

pub use prop::{assert_close, Runner};
