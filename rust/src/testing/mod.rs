//! In-repo property-testing harness (no proptest offline — see DESIGN.md).

pub mod fault;
pub mod inject;
pub mod prop;
pub mod sched;

pub use inject::{bits, ADVERSARIAL};
pub use prop::{assert_close, Runner};
pub use sched::explore;
