//! Deterministic-interleaving harness (a bounded mini-loom).
//!
//! [`explore`] enumerates every interleaving of a fixed set of logical
//! actors, where actor `i` performs `counts[i]` atomic steps in order, and
//! replays the scenario under test once per schedule. A *schedule* is the
//! sequence of actor IDs in execution order — a merge of the per-actor step
//! sequences. The scenario callback rebuilds its state from scratch and
//! dispatches each `(actor, step_index)` pair onto the state machine under
//! test, asserting its invariants as it goes.
//!
//! This turns "claim/steal/unregister can interleave with a worker
//! finishing" from a tsan-and-hope property into an exhaustively checked
//! one, for the state machines whose transitions are lock-protected and
//! therefore *are* atomic steps: the pool's `PoolState`
//! (claim/enqueue/finish/close, `rust/src/exec/pool.rs`) and the batcher's
//! `FlushState` reply-right claim (`rust/src/coordinator/batcher.rs`).
//! DESIGN.md §9 maps scenarios to schedules covered.
//!
//! `max_preemptions` bounds context switches *away from a runnable actor*,
//! which is what makes larger scenarios tractable: most concurrency bugs
//! need only a couple of preemptions (the insight behind bounded-preemption
//! model checkers such as CHESS). `usize::MAX` means every merge.

/// Run `f` once per schedule of `counts` (see module docs). Returns the
/// number of schedules executed.
///
/// `f` receives the schedule as `&[usize]` — actor IDs in execution order;
/// actor `i` appears exactly `counts[i]` times. Panics inside `f` (failed
/// asserts) propagate with the schedule attached via a panic note, so a
/// failing interleaving is printed and can be replayed directly.
pub fn explore<F: FnMut(&[usize])>(counts: &[usize], max_preemptions: usize, mut f: F) -> usize {
    let mut remaining: Vec<usize> = counts.to_vec();
    let mut schedule: Vec<usize> = Vec::with_capacity(counts.iter().sum());
    let mut ran = 0usize;
    dfs(&mut remaining, &mut schedule, None, max_preemptions, &mut f, &mut ran);
    ran
}

fn dfs<F: FnMut(&[usize])>(
    remaining: &mut Vec<usize>,
    schedule: &mut Vec<usize>,
    last: Option<usize>,
    switches_left: usize,
    f: &mut F,
    ran: &mut usize,
) {
    if remaining.iter().all(|&r| r == 0) {
        run_one(schedule, f);
        *ran += 1;
        return;
    }
    for actor in 0..remaining.len() {
        if remaining[actor] == 0 {
            continue;
        }
        // Scheduling a different actor while `last` could still run is a
        // preemption; continuing `last`, or switching after it finished,
        // is free.
        let preempts = match last {
            Some(l) => l != actor && remaining[l] > 0,
            None => false,
        };
        let budget = if preempts {
            if switches_left == 0 {
                continue;
            }
            switches_left - 1
        } else {
            switches_left
        };
        remaining[actor] -= 1;
        schedule.push(actor);
        dfs(remaining, schedule, Some(actor), budget, f, ran);
        schedule.pop();
        remaining[actor] += 1;
    }
}

fn run_one<F: FnMut(&[usize])>(schedule: &[usize], f: &mut F) {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(schedule)));
    if let Err(payload) = result {
        eprintln!("sched::explore: failing schedule {schedule:?}");
        std::panic::resume_unwind(payload);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Multinomial coefficient — the number of distinct merges.
    fn merges(counts: &[usize]) -> usize {
        fn fact(n: usize) -> usize {
            (1..=n).product::<usize>().max(1)
        }
        let total: usize = counts.iter().sum();
        counts.iter().fold(fact(total), |acc, &c| acc / fact(c))
    }

    #[test]
    fn unbounded_explore_counts_all_merges() {
        // C(4, 2) = 6 merges of two 2-step actors.
        assert_eq!(explore(&[2, 2], usize::MAX, |_| {}), 6);
        assert_eq!(merges(&[2, 2]), 6);
        // 3 actors: 6!/(2!2!2!) = 90.
        assert_eq!(explore(&[2, 2, 2], usize::MAX, |_| {}), merges(&[2, 2, 2]));
        // Degenerate: a single actor has exactly one schedule.
        assert_eq!(explore(&[3], usize::MAX, |_| {}), 1);
    }

    #[test]
    fn schedules_are_valid_merges_and_distinct() {
        let mut seen: Vec<Vec<usize>> = Vec::new();
        explore(&[2, 1, 1], usize::MAX, |s| {
            assert_eq!(s.len(), 4);
            assert_eq!(s.iter().filter(|&&a| a == 0).count(), 2);
            assert_eq!(s.iter().filter(|&&a| a == 1).count(), 1);
            assert_eq!(s.iter().filter(|&&a| a == 2).count(), 1);
            assert!(!seen.contains(&s.to_vec()), "duplicate schedule {s:?}");
            seen.push(s.to_vec());
        });
        assert_eq!(seen.len(), merges(&[2, 1, 1]));
    }

    #[test]
    fn zero_preemptions_runs_actors_to_completion() {
        // With no preemptions each actor runs as an uninterrupted block:
        // the schedules are exactly the actor orderings (n! of them).
        let mut seen = 0;
        explore(&[2, 2, 2], 0, |s| {
            seen += 1;
            // Each actor's steps must be contiguous.
            for w in [0, 1, 2] {
                let first = s.iter().position(|&a| a == w).unwrap();
                assert_eq!(s[first + 1], w, "actor {w} interrupted in {s:?}");
            }
        });
        assert_eq!(seen, 6); // 3!
    }

    #[test]
    fn bounded_preemptions_grow_monotonically() {
        let unbounded = explore(&[3, 3], usize::MAX, |_| {});
        let mut prev = 0;
        for p in 0..=4 {
            let n = explore(&[3, 3], p, |_| {});
            assert!(n >= prev, "schedule count shrank at bound {p}");
            prev = n;
        }
        // C(6,3) = 20; by 4 preemptions every merge of two 3-step actors
        // is reachable (a merge of two sequences alternates at most 5
        // times, and the final switch is free because one side is done).
        assert_eq!(prev, unbounded);
        assert_eq!(unbounded, 20);
    }

    #[test]
    fn failing_schedule_is_reported() {
        let caught = std::panic::catch_unwind(|| {
            explore(&[1, 1], usize::MAX, |s| {
                assert_ne!(s, [1, 0], "injected failure");
            });
        });
        assert!(caught.is_err());
    }
}
