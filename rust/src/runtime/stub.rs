//! Offline stand-in for the `xla` crate (xla-rs).
//!
//! The real PJRT backend requires the out-of-tree `xla` crate and its
//! `xla_extension` native download, neither of which is reachable from the
//! offline build environment. This stub mirrors exactly the API surface
//! [`crate::runtime`] uses so the crate always compiles; every entry point
//! returns [`XlaUnavailable`] at runtime. Enable the `xla` cargo feature
//! (and add the real dependency — see Cargo.toml) to link the real backend.
//!
//! All artifact-dependent tests skip when `artifacts/manifest.json` is
//! absent, so the default test suite never reaches these error paths.

#![allow(dead_code)]

use std::fmt;

/// Error returned by every stubbed XLA entry point.
#[derive(Debug)]
pub struct XlaUnavailable;

impl fmt::Display for XlaUnavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "XLA/PJRT backend unavailable: built without the `xla` feature \
             (offline stub; see rust/Cargo.toml)"
        )
    }
}

impl std::error::Error for XlaUnavailable {}

type Result<T> = std::result::Result<T, XlaUnavailable>;

/// Element dtypes the artifacts use.
pub enum ElementType {
    F32,
    S32,
    U32,
    S16,
}

/// Stub of `xla::Literal` (host tensor).
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(XlaUnavailable)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaUnavailable)
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(XlaUnavailable)
    }
}

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaUnavailable)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaUnavailable)
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaUnavailable)
    }
}

/// Stub of `xla::PjRtBuffer` (device buffer returned by `execute`).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaUnavailable)
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(XlaUnavailable)
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
