//! PJRT runtime: load AOT artifacts (HLO text lowered by
//! `python/compile/aot.py`) and execute them on the CPU PJRT client via the
//! `xla` crate (DESIGN.md system S10).
//!
//! HLO *text* is the interchange format — jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md). Python never runs on the
//! request path: artifacts are compiled once at startup and executed from
//! the Rust hot loop.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// Without the `xla` cargo feature the real crate is replaced by an in-tree
/// stub with the same API that errors at runtime (offline environment; see
/// [`stub`]).
#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
use stub as xla;

// The feature only switches which crate the `xla::` paths resolve to — the
// dependency itself cannot be vendored offline. Fail loudly at compile time
// with instructions instead of leaving E0433s for every `xla::` path.
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` feature needs the real backend: add \
     `xla = { git = \"https://github.com/LaurentMazare/xla-rs\", optional = true }` \
     to rust/Cargo.toml [dependencies], change the feature to \
     `xla = [\"dep:xla\"]`, and delete this compile_error! guard \
     (rust/src/runtime/mod.rs) — the opt-in CI `xla` job applies this patch"
);

/// Numeric representation of an artifact (mirrors `Precision`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactDtype {
    F32,
    I16,
}

/// One entry of `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub hlo: String,
    pub forest: String,
    pub batch: usize,
    pub n_trees: usize,
    pub k: usize,
    pub leaf_words: usize,
    pub d: usize,
    pub c: usize,
    pub dtype: ArtifactDtype,
    pub scale: f32,
    pub vmem_bytes: usize,
}

/// Parse `manifest.json` from an artifacts directory.
pub fn load_manifest(dir: &Path) -> Result<Vec<ModelMeta>> {
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
    if j.get("format").and_then(|v| v.as_str()) != Some("arbors-artifacts-v1") {
        bail!("{path:?}: unknown manifest format");
    }
    let mut out = Vec::new();
    for m in j.req("models").map_err(|e| anyhow::anyhow!("{e}"))?.as_arr().unwrap_or(&[]) {
        let s = |k: &str| -> Result<String> {
            Ok(m.req(k).map_err(|e| anyhow::anyhow!("{e}"))?.as_str().unwrap_or("").to_string())
        };
        let u = |k: &str| -> Result<usize> {
            m.req(k)
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("manifest field {k} not a number"))
        };
        let dtype = match s("dtype")?.as_str() {
            "f32" => ArtifactDtype::F32,
            "i16" => ArtifactDtype::I16,
            other => bail!("unknown artifact dtype {other}"),
        };
        out.push(ModelMeta {
            name: s("name")?,
            hlo: s("hlo")?,
            forest: s("forest")?,
            batch: u("batch")?,
            n_trees: u("n_trees")?,
            k: u("k")?,
            leaf_words: u("leaf_words")?,
            d: u("d")?,
            c: u("c")?,
            dtype,
            scale: m.get("scale").and_then(|v| v.as_f32()).unwrap_or(1.0),
            vmem_bytes: m.get("vmem_bytes").and_then(|v| v.as_usize()).unwrap_or(0),
        });
    }
    Ok(out)
}

/// A PJRT client plus the artifact directory it loads from.
pub struct Runtime {
    client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
}

/// A compiled executable with its manifest entry.
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ModelMeta,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn cpu(artifacts_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, artifacts_dir: artifacts_dir.to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one manifest entry.
    pub fn load(&self, meta: &ModelMeta) -> Result<LoadedModel> {
        let path = self.artifacts_dir.join(&meta.hlo);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {path:?}"))?;
        Ok(LoadedModel { exe, meta: meta.clone() })
    }

    /// Load every model in the manifest.
    pub fn load_all(&self) -> Result<Vec<LoadedModel>> {
        load_manifest(&self.artifacts_dir)?.iter().map(|m| self.load(m)).collect()
    }
}

impl LoadedModel {
    /// Execute with the given input literals; the lowered entry returns a
    /// 1-tuple whose only element is the score matrix.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple1()?)
    }
}

// ---------------------------------------------------------------------------
// Literal constructors for the dtypes the artifacts use
// ---------------------------------------------------------------------------

fn bytes_of<T: Copy>(data: &[T]) -> &[u8] {
    // SAFETY: reinterpreting `T: Copy` values as their raw bytes — the
    // pointer and byte length come from the same live slice, `u8` has no
    // alignment requirement, and the returned slice borrows `data`.
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    }
}

/// `f32[dims]` literal from a flat slice.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes_of(data),
    )?)
}

/// `s32[dims]` literal.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        dims,
        bytes_of(data),
    )?)
}

/// `u32[dims]` literal.
pub fn lit_u32(data: &[u32], dims: &[usize]) -> Result<xla::Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::U32,
        dims,
        bytes_of(data),
    )?)
}

/// `s16[dims]` literal.
pub fn lit_i16(data: &[i16], dims: &[usize]) -> Result<xla::Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S16,
        dims,
        bytes_of(data),
    )?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> PathBuf {
        // Tests run from the crate root; `make artifacts` must have run.
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let models = load_manifest(&artifacts()).unwrap();
        assert!(!models.is_empty());
        assert!(models.iter().any(|m| m.dtype == ArtifactDtype::F32));
        assert!(models.iter().any(|m| m.dtype == ArtifactDtype::I16));
    }

    // Literal construction needs the real backend — the stub errors. This
    // test (like the whole `xla` feature) only compiles once the real
    // dependency is wired in per the compile_error! guard above; until
    // then it is intentionally dormant.
    #[cfg(feature = "xla")]
    #[test]
    fn literal_roundtrip() {
        let lit = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let lit = lit_u32(&[7, 8], &[2]).unwrap();
        assert_eq!(lit.to_vec::<u32>().unwrap(), vec![7, 8]);
        let lit = lit_i16(&[-1, 5], &[2]).unwrap();
        assert_eq!(lit.to_vec::<i16>().unwrap(), vec![-1, 5]);
    }

    #[test]
    fn load_and_execute_artifact() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::cpu(&artifacts()).unwrap();
        let metas = load_manifest(&artifacts()).unwrap();
        let meta = metas.iter().find(|m| m.dtype == ArtifactDtype::F32).unwrap();
        let model = rt.load(meta).unwrap();
        // Zero inputs of the right shapes execute and give a [B, C] output.
        let x = lit_f32(&vec![0.0; meta.batch * meta.d], &[meta.batch, meta.d]).unwrap();
        let thr = lit_f32(&vec![f32::INFINITY; meta.n_trees * meta.k], &[meta.n_trees, meta.k])
            .unwrap();
        let fid = lit_i32(&vec![0; meta.n_trees * meta.k], &[meta.n_trees, meta.k]).unwrap();
        let mask = lit_u32(&vec![u32::MAX; meta.n_trees * meta.k], &[meta.n_trees, meta.k])
            .unwrap();
        let mask2 = lit_u32(&vec![u32::MAX; meta.n_trees * meta.k], &[meta.n_trees, meta.k])
            .unwrap();
        let leaves = lit_f32(
            &vec![0.0; meta.n_trees * meta.leaf_words * meta.c],
            &[meta.n_trees, meta.leaf_words, meta.c],
        )
        .unwrap();
        let out = model.execute(&[x, thr, fid, mask, mask2, leaves]).unwrap();
        let v = out.to_vec::<f32>().unwrap();
        assert_eq!(v.len(), meta.batch * meta.c);
        assert!(v.iter().all(|&s| s == 0.0));
    }
}
