//! QS — QuickScorer (Lucchese et al. 2015), scalar version (paper Alg. 1).
//!
//! The forest is traversed feature-wise: for each feature `k`, the nodes of
//! *all* trees testing `k` are scanned in ascending-threshold order. Every
//! node with `x[k] > t` is a "false node": the leaves of its left subtree
//! cannot be the exit leaf, so the tree's bitvector is ANDed with the node's
//! mask. Since thresholds ascend, the scan `break`s at the first true node.
//! The exit leaf of each tree is then the lowest set bit of its bitvector,
//! and a table lookup accumulates the score. Classification (C ≥ 2) adds the
//! per-class inner loop of §4.2.

use super::common::QsModel;
use super::Engine;
use crate::forest::Forest;
use crate::neon::OpTrace;
use crate::quant::{QForest, QuantConfig, QuantInt};

/// Float scalar QuickScorer.
pub struct QsEngine {
    m: QsModel<f32, f32>,
}

impl QsEngine {
    pub fn new(f: &Forest) -> QsEngine {
        QsEngine { m: QsModel::from_forest(f) }
    }

    /// Access to the prepared model (used by benches/ablations).
    pub fn model(&self) -> &QsModel<f32, f32> {
        &self.m
    }
}

/// Shared mask-computation + trace logic, generic over the scalar type.
/// Returns the per-tree exit-leaf bitvectors in `leafidx`.
#[inline]
fn mask_computation<T: Copy + PartialOrd>(
    m: &QsModel<T, impl Copy>,
    row: impl Fn(usize) -> T,
    leafidx: &mut [u64],
) {
    leafidx.fill(u64::MAX);
    for k in 0..m.n_features {
        let r = m.feature_range(k);
        if r.is_empty() {
            continue;
        }
        let x = row(k);
        // Zipped slice iteration: one bounds check per feature instead of
        // three per node (§Perf iteration 1).
        let ths = &m.thresholds[r.clone()];
        let trees = &m.tree_ids[r.clone()];
        let masks = &m.masks[r];
        for ((&t, &tree), &mask) in ths.iter().zip(trees).zip(masks) {
            // Thresholds ascend, so the first `x <= t` terminates the
            // feature (all later nodes are true nodes).
            if x > t {
                leafidx[tree as usize] &= mask;
            } else {
                break;
            }
        }
    }
}

/// Count visited nodes per feature for trace purposes.
fn visited_nodes<T: Copy + PartialOrd>(
    m: &QsModel<T, impl Copy>,
    row: impl Fn(usize) -> T,
) -> (u64, u64) {
    let mut visited = 0u64;
    let mut false_nodes = 0u64;
    for k in 0..m.n_features {
        for idx in m.feature_range(k) {
            visited += 1;
            if row(k) > m.thresholds[idx] {
                false_nodes += 1;
            } else {
                break;
            }
        }
    }
    (visited, false_nodes)
}

impl Engine for QsEngine {
    fn name(&self) -> String {
        "QS".into()
    }

    fn lanes(&self) -> usize {
        1
    }

    fn n_features(&self) -> usize {
        self.m.n_features
    }

    fn n_classes(&self) -> usize {
        self.m.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let d = self.m.n_features;
        let c = self.m.n_classes;
        let n = x.len() / d;
        let mut leafidx = vec![u64::MAX; self.m.n_trees];
        for i in 0..n {
            let row = &x[i * d..(i + 1) * d];
            mask_computation(&self.m, |k| row[k], &mut leafidx);
            // Score computation (Alg. 1 lines 15-20, classification §4.2).
            let o = &mut out[i * c..(i + 1) * c];
            o.copy_from_slice(&self.m.base_f32);
            for (ti, &bits) in leafidx.iter().enumerate() {
                let j = bits.trailing_zeros() as usize;
                for (dst, &v) in o.iter_mut().zip(self.m.leaf_row(ti, j)) {
                    *dst += v;
                }
            }
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        qs_trace(&self.m, x, false)
    }

    fn memory_bytes(&self) -> usize {
        self.m.memory_bytes()
    }
}

/// FLInt scalar QuickScorer (flQS): the float model with thresholds FLInt-
/// encoded to i32 ([`crate::quant::flint`]); each row is encoded once with
/// the `>`-style map (NaN → `i32::MIN`, so a NaN feature never clears masks
/// — exactly like `NaN > t` being false in [`QsEngine`]). Mask computation
/// runs on integer compares; leaf lookup and f32 accumulation are the
/// untouched float path, so outputs are **bit-identical** to [`QsEngine`].
pub struct FlintQsEngine {
    m: QsModel<i32, f32>,
}

impl FlintQsEngine {
    pub fn new(f: &Forest) -> FlintQsEngine {
        FlintQsEngine { m: QsModel::from_forest(f).to_flint() }
    }
}

impl Engine for FlintQsEngine {
    fn name(&self) -> String {
        "flQS".into()
    }

    fn lanes(&self) -> usize {
        1
    }

    fn n_features(&self) -> usize {
        self.m.n_features
    }

    fn n_classes(&self) -> usize {
        self.m.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let d = self.m.n_features;
        let c = self.m.n_classes;
        let n = x.len() / d;
        let mut ex = Vec::with_capacity(x.len());
        crate::quant::flint::encode_batch_gt(x, &mut ex);
        let mut leafidx = vec![u64::MAX; self.m.n_trees];
        for i in 0..n {
            let row = &ex[i * d..(i + 1) * d];
            mask_computation(&self.m, |k| row[k], &mut leafidx);
            let o = &mut out[i * c..(i + 1) * c];
            o.copy_from_slice(&self.m.base_f32);
            for (ti, &bits) in leafidx.iter().enumerate() {
                let j = bits.trailing_zeros() as usize;
                for (dst, &v) in o.iter_mut().zip(self.m.leaf_row(ti, j)) {
                    *dst += v;
                }
            }
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        qs_flint_trace(&self.m, x)
    }

    fn memory_bytes(&self) -> usize {
        self.m.memory_bytes()
    }
}

/// Quantized scalar QuickScorer (qQS / q8QS), generic over the storage tier.
pub struct QQsEngine<S: QuantInt = i16> {
    m: QsModel<S, S>,
    config: QuantConfig<S>,
}

impl<S: QuantInt> QQsEngine<S> {
    pub fn new(qf: &QForest<S>) -> QQsEngine<S> {
        QQsEngine { m: QsModel::from_qforest(qf), config: qf.config }
    }
}

impl<S: QuantInt> Engine for QQsEngine<S> {
    fn name(&self) -> String {
        format!("{}QS", S::ENGINE_PREFIX)
    }

    fn lanes(&self) -> usize {
        1
    }

    fn n_features(&self) -> usize {
        self.m.n_features
    }

    fn n_classes(&self) -> usize {
        self.m.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let d = self.m.n_features;
        let c = self.m.n_classes;
        let n = x.len() / d;
        let mut qx = Vec::with_capacity(x.len());
        self.config.q_slice(x, &mut qx);
        let mut leafidx = vec![u64::MAX; self.m.n_trees];
        let mut acc = vec![0i32; c];
        for i in 0..n {
            let row = &qx[i * d..(i + 1) * d];
            mask_computation(&self.m, |k| row[k], &mut leafidx);
            acc.copy_from_slice(&self.m.base_i32);
            for (ti, &bits) in leafidx.iter().enumerate() {
                let j = bits.trailing_zeros() as usize;
                let sh = self.m.tree_shifts[ti];
                for (dst, &v) in acc.iter_mut().zip(self.m.leaf_row(ti, j)) {
                    *dst += crate::quant::shift_round(v.to_i32(), sh);
                }
            }
            for (o, &a) in out[i * c..(i + 1) * c].iter_mut().zip(acc.iter()) {
                *o = self.config.dq(a);
            }
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        let mut qx = Vec::new();
        self.config.q_slice(x, &mut qx);
        let d = self.m.n_features;
        let n = x.len() / d;
        let mut tr = qsi_trace(&self.m, &qx, n);
        tr.scalar_fp += (n * d) as u64 * 2; // feature quantization
        tr.store_bytes += (n * d * std::mem::size_of::<S>()) as u64;
        tr
    }

    fn memory_bytes(&self) -> usize {
        self.m.memory_bytes()
    }
}

fn qs_trace(m: &QsModel<f32, f32>, x: &[f32], _quant: bool) -> OpTrace {
    let d = m.n_features;
    let c = m.n_classes as u64;
    let n = x.len() / d;
    let mut tr = OpTrace::new();
    let entry = m.node_entry_bytes();
    for i in 0..n {
        let row = &x[i * d..(i + 1) * d];
        let (visited, false_nodes) = visited_nodes(m, |k| row[k]);
        tr.stream_load_bytes += visited * entry;
        tr.scalar_fp += visited; // compares
        tr.cmp_fp += visited;
        tr.branch += visited;
        tr.branch_mispredictable += d as u64; // one break misprediction/feature
        tr.scalar_alu += false_nodes; // AND + leafidx update
        tr.store_bytes += 8 * (m.n_trees as u64); // leafidx init
        // Score computation.
        tr.scalar_alu += m.n_trees as u64; // trailing_zeros
        tr.random_loads += m.n_trees as u64; // leaf rows
        tr.scalar_fp += m.n_trees as u64 * c;
    }
    tr
}

fn qs_flint_trace(m: &QsModel<i32, f32>, x: &[f32]) -> OpTrace {
    let d = m.n_features;
    let c = m.n_classes as u64;
    let n = x.len() / d;
    let mut ex = Vec::new();
    crate::quant::flint::encode_batch_gt(x, &mut ex);
    let mut tr = OpTrace::new();
    let entry = m.node_entry_bytes();
    // Feature encoding: one integer fixup + store per value (no FP).
    tr.scalar_alu += (n * d) as u64;
    tr.store_bytes += (n * d * std::mem::size_of::<i32>()) as u64;
    for i in 0..n {
        let row = &ex[i * d..(i + 1) * d];
        let (visited, false_nodes) = visited_nodes(m, |k| row[k]);
        tr.stream_load_bytes += visited * entry;
        tr.scalar_alu += visited; // integer compares
        tr.cmp_int += visited;
        tr.branch += visited;
        tr.branch_mispredictable += d as u64;
        tr.scalar_alu += false_nodes;
        tr.store_bytes += 8 * (m.n_trees as u64);
        tr.scalar_alu += m.n_trees as u64;
        tr.random_loads += m.n_trees as u64;
        tr.scalar_fp += m.n_trees as u64 * c; // f32 leaf adds
    }
    tr
}

fn qsi_trace<S: QuantInt>(m: &QsModel<S, S>, qx: &[S], n: usize) -> OpTrace {
    let d = m.n_features;
    let c = m.n_classes as u64;
    let mut tr = OpTrace::new();
    let entry = m.node_entry_bytes();
    for i in 0..n {
        let row = &qx[i * d..(i + 1) * d];
        let (visited, false_nodes) = visited_nodes(m, |k| row[k]);
        tr.stream_load_bytes += visited * entry;
        tr.scalar_alu += visited; // integer compares
        tr.cmp_int += visited;
        tr.branch += visited;
        tr.branch_mispredictable += d as u64;
        tr.scalar_alu += false_nodes;
        tr.store_bytes += 8 * (m.n_trees as u64);
        tr.scalar_alu += m.n_trees as u64;
        tr.random_loads += m.n_trees as u64;
        tr.scalar_alu += m.n_trees as u64 * c;
    }
    tr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};
    use crate::testing::assert_close;

    fn setup(leaves: usize, seed: u64) -> (Forest, crate::data::Dataset) {
        let ds = DatasetId::Magic.generate(900, seed);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 14,
                tree: TreeParams { max_leaves: leaves, min_samples_leaf: 2, mtry: 0 },
                seed,
                ..Default::default()
            },
        );
        (f, ds)
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn qs_matches_reference_l32() {
        let (f, ds) = setup(32, 1);
        let e = QsEngine::new(&f);
        assert_close(&e.predict(&ds.x), &f.predict_batch(&ds.x), 1e-5, 1e-5).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn qs_matches_reference_l64() {
        let (f, ds) = setup(64, 2);
        assert!(f.max_leaves() > 32, "want an L=64 forest");
        let e = QsEngine::new(&f);
        assert_close(&e.predict(&ds.x), &f.predict_batch(&ds.x), 1e-5, 1e-5).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn qqs_matches_qforest() {
        let (f, ds) = setup(32, 3);
        let qf = QForest::from_forest(&f, QuantConfig::paper_default());
        let e = QQsEngine::new(&qf);
        assert_eq!(e.name(), "qQS");
        assert_eq!(e.predict(&ds.x), qf.predict_batch(&ds.x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8qs_matches_qforest() {
        for leaves in [32usize, 64] {
            let (f, ds) = setup(leaves, 7);
            let qf =
                QForest::<i8>::from_forest(&f, crate::quant::choose_scale_i8(&f, 1.0));
            let e = QQsEngine::new(&qf);
            assert_eq!(e.name(), "q8QS");
            assert_eq!(e.predict(&ds.x), qf.predict_batch(&ds.x), "L={leaves}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn flint_qs_bit_identical_to_float_qs() {
        for leaves in [32usize, 64] {
            let (f, ds) = setup(leaves, 6);
            let fl = FlintQsEngine::new(&f);
            let fe = QsEngine::new(&f);
            assert_eq!(fl.name(), "flQS");
            assert_eq!(fl.predict(&ds.x), fe.predict(&ds.x), "L={leaves}");

            // Adversarial rows: NaN must stop mask-clearing exactly as the
            // float engine's `NaN > t == false` does; ±0.0/denormal/-inf
            // must take identical sides.
            let mut adv = ds.x[..4 * ds.d].to_vec();
            adv[0] = f32::NAN;
            adv[ds.d] = -0.0;
            adv[2 * ds.d] = f32::from_bits(0x0000_0001);
            adv[3 * ds.d] = f32::NEG_INFINITY;
            assert_eq!(fl.predict(&adv), fe.predict(&adv), "L={leaves} adversarial");

            let tr = fl.count_ops(&ds.x[..4 * ds.d]);
            assert!(tr.cmp_int > 0);
            assert_eq!(tr.cmp_fp, 0);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn argmax_agreement_with_naive() {
        let (f, ds) = setup(64, 4);
        let e = QsEngine::new(&f);
        let got = Forest::argmax(&e.predict(&ds.x), f.n_classes);
        let want = Forest::argmax(&f.predict_batch(&ds.x), f.n_classes);
        assert_eq!(got, want);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn trace_counts_reasonable() {
        let (f, ds) = setup(32, 5);
        let e = QsEngine::new(&f);
        let tr = e.count_ops(&ds.x[..ds.d * 4]);
        assert!(tr.scalar_fp > 0);
        assert!(tr.stream_load_bytes > 0);
        // QS never visits more nodes than the forest has, per instance.
        assert!(tr.scalar_fp <= 4 * (f.n_nodes() as u64 + f.n_trees() as u64 * 2 + 100));
    }
}
