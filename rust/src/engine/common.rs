//! Shared model-preparation structures for the QuickScorer engine family.
//!
//! QuickScorer (§3) re-organizes a forest into *feature-ordered node lists*:
//! for each feature `k`, all nodes of all trees testing `k`, sorted by
//! ascending threshold, each carrying the bitvector mask of leaves its
//! "false" outcome removes. Leaf `i` of a tree maps to bit `i` of the
//! bitvector (bit 0 = leftmost leaf), so the exit leaf — the *leftmost*
//! remaining leaf — is the lowest set bit.

use crate::forest::Forest;
use crate::quant::{QForest, QTree, QuantInt};

/// Maximum leaves supported by the bitvector engines (one u64 word).
pub const MAX_LEAVES: usize = 64;

/// Feature-ordered node lists plus the leaf-value table, generic over the
/// threshold scalar `T` (f32 or i16) and leaf scalar `V` (f32 or i16).
#[derive(Debug, Clone)]
pub struct QsModel<T: Copy, V: Copy> {
    pub n_features: usize,
    pub n_classes: usize,
    pub n_trees: usize,
    /// Bitvector width: 32 if every tree has ≤ 32 leaves, else 64 — chooses
    /// between the u32 and u64 SIMD paths, as the paper distinguishes
    /// L=32 / L=64.
    pub leaf_words: usize,
    /// Leaf-dimension padding (`L` = `leaf_words`): leaf tables are
    /// `[n_trees × L × n_classes]`.
    pub offsets: Vec<u32>,
    /// Node thresholds, ascending within each feature's segment.
    pub thresholds: Vec<T>,
    /// Owning tree of each node.
    pub tree_ids: Vec<u32>,
    /// Bitvector masks: zeros over the node's left-subtree leaves, ones
    /// elsewhere (bits ≥ L stay 1).
    pub masks: Vec<u64>,
    /// Row-major `[n_trees × L × n_classes]` leaf values (padded rows are 0).
    pub leaf_values: Vec<V>,
    /// Base score added to every prediction (f32 engines) or its quantized
    /// i32 counterpart (i16 engines use `base_i32`).
    pub base_f32: Vec<f32>,
    pub base_i32: Vec<i32>,
    /// Dequantization scale for i16 models (1.0 for float models).
    pub scale: f32,
    /// Per-tree leaf shifts ([`crate::quant::QForest::tree_shifts`]): the
    /// rounding shift each engine applies to tree `t`'s gathered leaf
    /// values before accumulation. All zeros for float and
    /// globally-scaled models.
    pub tree_shifts: Vec<u8>,
}

/// Compute the mask for a false node whose left subtree covers leaves
/// `[begin, end)`.
#[inline]
pub fn left_range_mask(begin: u32, end: u32) -> u64 {
    debug_assert!(end > begin && end <= 64);
    let width = end - begin;
    let ones = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
    !(ones << begin)
}

/// Items collected per node before sorting into feature lists.
struct RawNode<T> {
    feature: u32,
    threshold: T,
    tree: u32,
    mask: u64,
}

fn build_lists<T: Copy + PartialOrd>(
    n_features: usize,
    mut raw: Vec<RawNode<T>>,
) -> (Vec<u32>, Vec<T>, Vec<u32>, Vec<u64>) {
    // Sort by (feature, threshold) — stable so equal thresholds keep tree
    // order, which RapidScorer's merging relies on.
    raw.sort_by(|a, b| {
        a.feature.cmp(&b.feature).then(a.threshold.partial_cmp(&b.threshold).unwrap())
    });
    let mut offsets = vec![0u32; n_features + 1];
    let mut thresholds = Vec::with_capacity(raw.len());
    let mut tree_ids = Vec::with_capacity(raw.len());
    let mut masks = Vec::with_capacity(raw.len());
    for n in &raw {
        offsets[n.feature as usize + 1] += 1;
        thresholds.push(n.threshold);
        tree_ids.push(n.tree);
        masks.push(n.mask);
    }
    for f in 0..n_features {
        offsets[f + 1] += offsets[f];
    }
    (offsets, thresholds, tree_ids, masks)
}

fn leaf_words_for(max_leaves: usize) -> usize {
    assert!(max_leaves <= MAX_LEAVES, "QuickScorer engines support <= 64 leaves");
    if max_leaves <= 32 {
        32
    } else {
        64
    }
}

impl QsModel<f32, f32> {
    /// Prepare the float QuickScorer structures from a forest.
    pub fn from_forest(f: &Forest) -> QsModel<f32, f32> {
        let leaf_words = leaf_words_for(f.max_leaves());
        let c = f.n_classes;
        let mut raw = Vec::with_capacity(f.n_nodes());
        let mut leaf_values = vec![0f32; f.n_trees() * leaf_words * c];
        for (ti, t) in f.trees.iter().enumerate() {
            let ranges = t.left_leaf_ranges();
            for (n, &(b, e)) in t.nodes.iter().zip(&ranges) {
                raw.push(RawNode {
                    feature: n.feature,
                    threshold: n.threshold,
                    tree: ti as u32,
                    mask: left_range_mask(b, e),
                });
            }
            let dst = &mut leaf_values[ti * leaf_words * c..];
            dst[..t.leaf_values.len()].copy_from_slice(&t.leaf_values);
        }
        let (offsets, thresholds, tree_ids, masks) = build_lists(f.n_features, raw);
        QsModel {
            n_features: f.n_features,
            n_classes: c,
            n_trees: f.n_trees(),
            leaf_words,
            offsets,
            thresholds,
            tree_ids,
            masks,
            leaf_values,
            base_f32: f.base_score.clone(),
            base_i32: Vec::new(),
            scale: 1.0,
            tree_shifts: vec![0; f.n_trees()],
        }
    }
}

impl QsModel<f32, f32> {
    /// Re-encode a prepared float model through the FLInt carrier
    /// ([`crate::quant::flint`]): thresholds become order-preserving i32s
    /// (`encode_threshold`, -0.0 canonicalized), everything else — masks,
    /// offsets, f32 leaf tables, base scores — is shared verbatim, so the
    /// carrier engines reuse the f32 score paths untouched.
    ///
    /// The per-feature ascending threshold order survives the re-encoding
    /// (the map is strictly monotone and IEEE-equal thresholds encode
    /// equal), so QuickScorer's break-at-first-false scan stays valid.
    pub fn to_flint(&self) -> QsModel<i32, f32> {
        QsModel {
            n_features: self.n_features,
            n_classes: self.n_classes,
            n_trees: self.n_trees,
            leaf_words: self.leaf_words,
            offsets: self.offsets.clone(),
            thresholds: crate::quant::flint::encode_thresholds(&self.thresholds),
            tree_ids: self.tree_ids.clone(),
            masks: self.masks.clone(),
            leaf_values: self.leaf_values.clone(),
            base_f32: self.base_f32.clone(),
            base_i32: Vec::new(),
            scale: 1.0,
            tree_shifts: self.tree_shifts.clone(),
        }
    }
}

impl<S: QuantInt> QsModel<S, S> {
    /// Prepare the fixed-point QuickScorer structures from a quantized
    /// forest (any storage tier: i16 or i8).
    pub fn from_qforest(qf: &QForest<S>) -> QsModel<S, S> {
        let leaf_words = leaf_words_for(qf.max_leaves());
        let c = qf.n_classes;
        let mut raw = Vec::new();
        let mut leaf_values = vec![S::default(); qf.trees.len() * leaf_words * c];
        for (ti, t) in qf.trees.iter().enumerate() {
            let ranges = qtree_left_ranges(t);
            for i in 0..t.features.len() {
                let (b, e) = ranges[i];
                raw.push(RawNode {
                    feature: t.features[i],
                    threshold: t.thresholds[i],
                    tree: ti as u32,
                    mask: left_range_mask(b, e),
                });
            }
            let dst = &mut leaf_values[ti * leaf_words * c..];
            dst[..t.leaf_values.len()].copy_from_slice(&t.leaf_values);
        }
        let (offsets, thresholds, tree_ids, masks) = build_lists(qf.n_features, raw);
        QsModel {
            n_features: qf.n_features,
            n_classes: c,
            n_trees: qf.trees.len(),
            leaf_words,
            offsets,
            thresholds,
            tree_ids,
            masks,
            leaf_values,
            base_f32: Vec::new(),
            base_i32: qf.base_score.clone(),
            scale: qf.config.scale,
            tree_shifts: qf.tree_shifts.clone(),
        }
    }
}

/// Left-subtree leaf ranges for a quantized tree (same walk as
/// [`crate::forest::Tree::left_leaf_ranges`], over the QTree layout).
pub fn qtree_left_ranges<S: QuantInt>(t: &QTree<S>) -> Vec<(u32, u32)> {
    use crate::forest::Child;
    let mut out = vec![(0u32, 0u32); t.features.len()];
    if t.features.is_empty() {
        return out;
    }
    fn span<S: QuantInt>(
        t: &QTree<S>,
        c: Child,
        out: &mut [(u32, u32)],
    ) -> (u32, u32) {
        match c {
            Child::Leaf(l) => (l, l + 1),
            Child::Inner(i) => {
                let i = i as usize;
                let (lb, le) = span(t, t.left[i], out);
                let (_, re) = span(t, t.right[i], out);
                out[i] = (lb, le);
                (lb, re)
            }
        }
    }
    span(t, Child::Inner(0), &mut out);
    out
}

impl<T: Copy, V: Copy> QsModel<T, V> {
    /// Nodes testing feature `k`, as an index range.
    #[inline]
    pub fn feature_range(&self, k: usize) -> std::ops::Range<usize> {
        self.offsets[k] as usize..self.offsets[k + 1] as usize
    }

    /// Leaf-value row for `(tree, leaf)`.
    #[inline]
    pub fn leaf_row(&self, tree: usize, leaf: usize) -> &[V] {
        let c = self.n_classes;
        let start = (tree * self.leaf_words + leaf) * c;
        &self.leaf_values[start..start + c]
    }

    /// Bytes of one node entry in the feature lists (for stream-load
    /// accounting in op traces).
    pub fn node_entry_bytes(&self) -> u64 {
        (std::mem::size_of::<T>() + std::mem::size_of::<u32>() + std::mem::size_of::<u64>()) as u64
    }

    /// Resident bytes of the prepared model.
    pub fn memory_bytes(&self) -> usize {
        self.offsets.len() * 4
            + self.thresholds.len() * std::mem::size_of::<T>()
            + self.tree_ids.len() * 4
            + self.masks.len() * 8
            + self.leaf_values.len() * std::mem::size_of::<V>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    #[test]
    fn mask_shapes() {
        // Node whose left subtree covers leaves [1,3): zeros at bits 1,2.
        assert_eq!(left_range_mask(1, 3), !0b110u64);
        assert_eq!(left_range_mask(0, 1), !1u64);
        assert_eq!(left_range_mask(0, 64), 0);
    }

    fn model() -> (Forest, QsModel<f32, f32>) {
        let ds = DatasetId::Magic.generate(500, 5);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 8,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let m = QsModel::from_forest(&f);
        (f, m)
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn thresholds_ascend_per_feature() {
        let (_, m) = model();
        for k in 0..m.n_features {
            let r = m.feature_range(k);
            let th = &m.thresholds[r];
            for w in th.windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn node_count_preserved() {
        let (f, m) = model();
        assert_eq!(m.thresholds.len(), f.n_nodes());
        assert_eq!(*m.offsets.last().unwrap() as usize, f.n_nodes());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn scalar_qs_on_lists_matches_tree_walk() {
        // Emulate Algorithm 1 directly on the prepared lists and check the
        // exit leaf against the tree oracle for a few instances.
        let (f, m) = model();
        let ds = DatasetId::Magic.generate(40, 6);
        for i in 0..ds.n {
            let x = ds.row(i);
            let mut leafidx = vec![u64::MAX; m.n_trees];
            for k in 0..m.n_features {
                for idx in m.feature_range(k) {
                    if x[k] > m.thresholds[idx] {
                        leafidx[m.tree_ids[idx] as usize] &= m.masks[idx];
                    } else {
                        break;
                    }
                }
            }
            for (ti, t) in f.trees.iter().enumerate() {
                let expect = t.exit_leaf(x);
                let got = leafidx[ti].trailing_zeros() as usize;
                assert_eq!(got, expect, "instance {i} tree {ti}");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn leaf_rows_padded() {
        let (f, m) = model();
        assert_eq!(m.leaf_words, 32);
        // Row for a real leaf matches the tree's leaf table.
        let t0 = &f.trees[0];
        for leaf in 0..t0.n_leaves {
            assert_eq!(m.leaf_row(0, leaf), t0.leaf_row(leaf));
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn i16_model_buildable() {
        let (f, _) = model();
        let qf = crate::quant::QForest::from_forest(&f, crate::quant::QuantConfig::paper_default());
        let qm = QsModel::from_qforest(&qf);
        assert_eq!(qm.thresholds.len(), f.n_nodes());
        assert!(qm.scale > 1.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn i8_model_buildable_and_half_the_payload() {
        let (f, _) = model();
        let qf16 =
            crate::quant::QForest::from_forest(&f, crate::quant::choose_scale(&f, 1.0));
        let qf8 = crate::quant::QForest::<i8>::from_forest(
            &f,
            crate::quant::choose_scale_i8(&f, 1.0),
        );
        let m16 = QsModel::from_qforest(&qf16);
        let m8 = QsModel::from_qforest(&qf8);
        assert_eq!(m8.thresholds.len(), f.n_nodes());
        // Same node count, half the scalar payload bytes.
        assert_eq!(m8.masks.len(), m16.masks.len());
        assert!(m8.memory_bytes() < m16.memory_bytes());
    }
}
