//! NA — the "Native" baseline (Asadi et al.'s PRED): a while-loop over
//! contiguous node arrays (struct-of-arrays layout for data locality).
//!
//! This is the reference implementation every speed-up in the paper's tables
//! is measured against.

use super::common::QsModel; // only for sizing helpers in traces
use super::Engine;
use crate::forest::{Child, Forest};
use crate::neon::OpTrace;
use crate::quant::{QForest, QuantConfig, QuantInt};

/// Child encoded as i32: `>= 0` → node index, `< 0` → leaf `-(v+1)`.
#[inline]
fn enc(c: Child) -> i32 {
    match c {
        Child::Inner(i) => i as i32,
        Child::Leaf(l) => -(l as i32) - 1,
    }
}

/// Flattened struct-of-arrays forest for while-loop traversal.
struct FlatForest<T: Copy, V: Copy> {
    /// Per-tree start offset into the node arrays; `tree_offsets[M]` = total.
    tree_offsets: Vec<u32>,
    features: Vec<u32>,
    thresholds: Vec<T>,
    left: Vec<i32>,
    right: Vec<i32>,
    /// Per-tree start offset into `leaf_values` (in rows).
    leaf_offsets: Vec<u32>,
    leaf_values: Vec<V>,
    /// Per-tree leaf shifts (per-tree-scale quantization; all zeros for
    /// float / globally-scaled models).
    tree_shifts: Vec<u8>,
    n_features: usize,
    n_classes: usize,
}

impl<T: Copy, V: Copy> FlatForest<T, V> {
    /// Walk tree `ti` for quantifiable features via a comparison closure.
    #[inline]
    fn exit_leaf(&self, ti: usize, le: impl Fn(u32, T) -> bool) -> usize {
        let base = self.tree_offsets[ti] as usize;
        let end = self.tree_offsets[ti + 1] as usize;
        if base == end {
            return 0; // single-leaf tree
        }
        let mut cur = 0i32;
        loop {
            let i = base + cur as usize;
            cur = if le(self.features[i], self.thresholds[i]) { self.left[i] } else { self.right[i] };
            if cur < 0 {
                return (-cur - 1) as usize;
            }
        }
    }

    /// Depth walked for tree `ti` (for op traces).
    fn walk_depth(&self, ti: usize, le: impl Fn(u32, T) -> bool) -> u64 {
        let base = self.tree_offsets[ti] as usize;
        let end = self.tree_offsets[ti + 1] as usize;
        if base == end {
            return 0;
        }
        let mut cur = 0i32;
        let mut depth = 0u64;
        loop {
            let i = base + cur as usize;
            depth += 1;
            cur = if le(self.features[i], self.thresholds[i]) { self.left[i] } else { self.right[i] };
            if cur < 0 {
                return depth;
            }
        }
    }

    fn n_trees(&self) -> usize {
        self.tree_offsets.len() - 1
    }

    fn leaf_row(&self, ti: usize, leaf: usize) -> &[V] {
        let start = (self.leaf_offsets[ti] as usize + leaf) * self.n_classes;
        &self.leaf_values[start..start + self.n_classes]
    }
}

impl<T: Copy, V: Copy> FlatForest<T, V> {
    fn memory_bytes(&self) -> usize {
        self.tree_offsets.len() * 4
            + self.features.len() * 4
            + self.thresholds.len() * std::mem::size_of::<T>()
            + (self.left.len() + self.right.len()) * 4
            + self.leaf_offsets.len() * 4
            + self.leaf_values.len() * std::mem::size_of::<V>()
            + self.tree_shifts.len()
    }
}

fn flatten_f32(f: &Forest) -> FlatForest<f32, f32> {
    let mut out = FlatForest {
        tree_offsets: vec![0],
        features: Vec::new(),
        thresholds: Vec::new(),
        left: Vec::new(),
        right: Vec::new(),
        leaf_offsets: vec![0],
        leaf_values: Vec::new(),
        tree_shifts: vec![0; f.n_trees()],
        n_features: f.n_features,
        n_classes: f.n_classes,
    };
    for t in &f.trees {
        for n in &t.nodes {
            out.features.push(n.feature);
            out.thresholds.push(n.threshold);
            out.left.push(enc(n.left));
            out.right.push(enc(n.right));
        }
        out.tree_offsets.push(out.features.len() as u32);
        out.leaf_values.extend_from_slice(&t.leaf_values);
        out.leaf_offsets.push(out.leaf_offsets.last().unwrap() + t.n_leaves as u32);
    }
    out
}

/// Re-encode a flattened float forest through the FLInt carrier: thresholds
/// become order-preserving i32s ([`crate::quant::flint::encode_threshold`]),
/// the f32 leaf tables and topology move over untouched.
fn flatten_flint(flat: FlatForest<f32, f32>) -> FlatForest<i32, f32> {
    FlatForest {
        tree_offsets: flat.tree_offsets,
        features: flat.features,
        thresholds: crate::quant::flint::encode_thresholds(&flat.thresholds),
        left: flat.left,
        right: flat.right,
        leaf_offsets: flat.leaf_offsets,
        leaf_values: flat.leaf_values,
        tree_shifts: flat.tree_shifts,
        n_features: flat.n_features,
        n_classes: flat.n_classes,
    }
}

fn flatten_q<S: QuantInt>(qf: &QForest<S>) -> FlatForest<S, S> {
    let mut out = FlatForest {
        tree_offsets: vec![0],
        features: Vec::new(),
        thresholds: Vec::new(),
        left: Vec::new(),
        right: Vec::new(),
        leaf_offsets: vec![0],
        leaf_values: Vec::new(),
        tree_shifts: qf.tree_shifts.clone(),
        n_features: qf.n_features,
        n_classes: qf.n_classes,
    };
    for t in &qf.trees {
        for i in 0..t.features.len() {
            out.features.push(t.features[i]);
            out.thresholds.push(t.thresholds[i]);
            out.left.push(enc(t.left[i]));
            out.right.push(enc(t.right[i]));
        }
        out.tree_offsets.push(out.features.len() as u32);
        out.leaf_values.extend_from_slice(&t.leaf_values);
        out.leaf_offsets.push(out.leaf_offsets.last().unwrap() + t.n_leaves as u32);
    }
    out
}

/// Float NA engine.
pub struct NaiveEngine {
    flat: FlatForest<f32, f32>,
    base: Vec<f32>,
}

impl NaiveEngine {
    pub fn new(f: &Forest) -> NaiveEngine {
        NaiveEngine { flat: flatten_f32(f), base: f.base_score.clone() }
    }
}

impl Engine for NaiveEngine {
    fn name(&self) -> String {
        "NA".into()
    }

    fn lanes(&self) -> usize {
        1
    }

    fn n_features(&self) -> usize {
        self.flat.n_features
    }

    fn n_classes(&self) -> usize {
        self.flat.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let d = self.flat.n_features;
        let c = self.flat.n_classes;
        let n = x.len() / d;
        debug_assert_eq!(out.len(), n * c);
        for i in 0..n {
            let row = &x[i * d..(i + 1) * d];
            let o = &mut out[i * c..(i + 1) * c];
            o.copy_from_slice(&self.base);
            for ti in 0..self.flat.n_trees() {
                let leaf = self.flat.exit_leaf(ti, |f, t| row[f as usize] <= t);
                for (dst, &v) in o.iter_mut().zip(self.flat.leaf_row(ti, leaf)) {
                    *dst += v;
                }
            }
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        let d = self.flat.n_features;
        let c = self.flat.n_classes as u64;
        let n = x.len() / d;
        let mut tr = OpTrace::new();
        for i in 0..n {
            let row = &x[i * d..(i + 1) * d];
            for ti in 0..self.flat.n_trees() {
                let depth = self.flat.walk_depth(ti, |f, t| row[f as usize] <= t);
                // Per node: load node record (16B, data-dependent), load
                // feature, fp compare, data-dependent branch.
                tr.random_loads += 2 * depth;
                tr.scalar_fp += depth;
                tr.cmp_fp += depth;
                tr.branch += depth;
                tr.branch_mispredictable += depth / 2; // ~random directions
                // Leaf: load row + C adds.
                tr.random_loads += 1;
                tr.scalar_fp += c;
            }
        }
        tr
    }

    fn memory_bytes(&self) -> usize {
        self.flat.memory_bytes()
    }
}

/// FLInt NA engine (flNA): the exact [`NaiveEngine`] traversal and f32
/// leaf/score path, but thresholds are FLInt-encoded i32s and each batch is
/// encoded once ([`crate::quant::flint::encode_batch_le`], NaN →
/// `i32::MAX`), so every split compare runs on the integer pipe while the
/// outputs stay **bit-identical** to the float engine.
pub struct FlintNaiveEngine {
    flat: FlatForest<i32, f32>,
    base: Vec<f32>,
}

impl FlintNaiveEngine {
    pub fn new(f: &Forest) -> FlintNaiveEngine {
        FlintNaiveEngine { flat: flatten_flint(flatten_f32(f)), base: f.base_score.clone() }
    }
}

impl Engine for FlintNaiveEngine {
    fn name(&self) -> String {
        "flNA".into()
    }

    fn lanes(&self) -> usize {
        1
    }

    fn n_features(&self) -> usize {
        self.flat.n_features
    }

    fn n_classes(&self) -> usize {
        self.flat.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let d = self.flat.n_features;
        let c = self.flat.n_classes;
        let n = x.len() / d;
        debug_assert_eq!(out.len(), n * c);
        let mut ex = Vec::with_capacity(x.len());
        crate::quant::flint::encode_batch_le(x, &mut ex);
        for i in 0..n {
            let row = &ex[i * d..(i + 1) * d];
            let o = &mut out[i * c..(i + 1) * c];
            o.copy_from_slice(&self.base);
            for ti in 0..self.flat.n_trees() {
                let leaf = self.flat.exit_leaf(ti, |f, t| row[f as usize] <= t);
                for (dst, &v) in o.iter_mut().zip(self.flat.leaf_row(ti, leaf)) {
                    *dst += v;
                }
            }
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        let d = self.flat.n_features;
        let c = self.flat.n_classes as u64;
        let n = x.len() / d;
        let mut ex = Vec::new();
        crate::quant::flint::encode_batch_le(x, &mut ex);
        let mut tr = OpTrace::new();
        // Feature encoding: one integer fixup + store per value (no FP).
        tr.scalar_alu += (n * d) as u64;
        tr.store_bytes += (n * d * std::mem::size_of::<i32>()) as u64;
        for i in 0..n {
            let row = &ex[i * d..(i + 1) * d];
            for ti in 0..self.flat.n_trees() {
                let depth = self.flat.walk_depth(ti, |f, t| row[f as usize] <= t);
                tr.random_loads += 2 * depth;
                tr.scalar_alu += depth; // integer threshold compares
                tr.cmp_int += depth;
                tr.branch += depth;
                tr.branch_mispredictable += depth / 2;
                tr.random_loads += 1;
                tr.scalar_fp += c; // leaf adds stay f32
            }
        }
        tr
    }

    fn memory_bytes(&self) -> usize {
        self.flat.memory_bytes()
    }
}

/// Quantized NA engine (qNA / q8NA): fixed-point thresholds/leaves in the
/// tier's storage width, i32 accumulation, features quantized once per
/// batch.
pub struct QNaiveEngine<S: QuantInt = i16> {
    flat: FlatForest<S, S>,
    base: Vec<i32>,
    config: QuantConfig<S>,
}

impl<S: QuantInt> QNaiveEngine<S> {
    pub fn new(qf: &QForest<S>) -> QNaiveEngine<S> {
        QNaiveEngine { flat: flatten_q(qf), base: qf.base_score.clone(), config: qf.config }
    }
}

impl<S: QuantInt> Engine for QNaiveEngine<S> {
    fn name(&self) -> String {
        format!("{}NA", S::ENGINE_PREFIX)
    }

    fn lanes(&self) -> usize {
        1
    }

    fn n_features(&self) -> usize {
        self.flat.n_features
    }

    fn n_classes(&self) -> usize {
        self.flat.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let d = self.flat.n_features;
        let c = self.flat.n_classes;
        let n = x.len() / d;
        debug_assert_eq!(out.len(), n * c);
        let mut qx = Vec::with_capacity(x.len());
        self.config.q_slice(x, &mut qx);
        let mut acc = vec![0i32; c];
        for i in 0..n {
            let row = &qx[i * d..(i + 1) * d];
            acc.copy_from_slice(&self.base);
            for ti in 0..self.flat.n_trees() {
                let leaf = self.flat.exit_leaf(ti, |f, t| row[f as usize] <= t);
                let k = self.flat.tree_shifts[ti];
                for (dst, &v) in acc.iter_mut().zip(self.flat.leaf_row(ti, leaf)) {
                    *dst += crate::quant::shift_round(v.to_i32(), k);
                }
            }
            for (o, &a) in out[i * c..(i + 1) * c].iter_mut().zip(acc.iter()) {
                *o = self.config.dq(a);
            }
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        let d = self.flat.n_features;
        let c = self.flat.n_classes as u64;
        let n = x.len() / d;
        let mut qx = Vec::new();
        self.config.q_slice(x, &mut qx);
        let mut tr = OpTrace::new();
        // Feature quantization: one fp mul + floor + store per value.
        tr.scalar_fp += (n * d) as u64 * 2;
        tr.store_bytes += (n * d * std::mem::size_of::<S>()) as u64;
        for i in 0..n {
            let row = &qx[i * d..(i + 1) * d];
            for ti in 0..self.flat.n_trees() {
                let depth = self.flat.walk_depth(ti, |f, t| row[f as usize] <= t);
                tr.random_loads += 2 * depth;
                tr.scalar_alu += depth; // integer compares — no FPU
                tr.cmp_int += depth;
                tr.branch += depth;
                tr.branch_mispredictable += depth / 2;
                tr.random_loads += 1;
                tr.scalar_alu += c;
            }
        }
        tr
    }

    fn memory_bytes(&self) -> usize {
        self.flat.memory_bytes()
    }
}

// Silence unused-import lint for the doc reference above.
#[allow(unused)]
fn _doc(_: &QsModel<f32, f32>) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    fn setup() -> (Forest, crate::data::Dataset) {
        let ds = DatasetId::Magic.generate(400, 31);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 12,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        (f, ds)
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn na_matches_reference() {
        let (f, ds) = setup();
        let e = NaiveEngine::new(&f);
        let got = e.predict(&ds.x);
        let want = f.predict_batch(&ds.x);
        assert_eq!(got, want); // identical op order -> bitwise equal
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn flint_na_bit_identical_to_float_na() {
        let (f, ds) = setup();
        let fl = FlintNaiveEngine::new(&f);
        assert_eq!(fl.name(), "flNA");
        let want = NaiveEngine::new(&f).predict(&ds.x);
        assert_eq!(fl.predict(&ds.x), want); // carrier changes representation only
        // Adversarial feature values route identically too.
        let mut x = ds.x[..ds.d * 4].to_vec();
        x[0] = f32::NAN;
        x[1] = -0.0;
        x[ds.d] = f32::from_bits(0x0000_0001); // denormal
        x[ds.d + 1] = f32::NEG_INFINITY;
        assert_eq!(fl.predict(&x), NaiveEngine::new(&f).predict(&x));
        // Op mix: compares moved to the int pipe, leaf adds stayed f32.
        let tr = fl.count_ops(&ds.x[..ds.d * 4]);
        assert!(tr.cmp_int > 0 && tr.cmp_fp == 0);
        assert!(tr.scalar_fp > 0, "leaf adds remain float ops");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn qna_matches_qforest_reference() {
        let (f, ds) = setup();
        let qf = QForest::from_forest(&f, QuantConfig::paper_default());
        let e = QNaiveEngine::new(&qf);
        assert_eq!(e.name(), "qNA");
        let got = e.predict(&ds.x);
        let want = qf.predict_batch(&ds.x);
        assert_eq!(got, want);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8na_matches_qforest_reference() {
        let (f, ds) = setup();
        let qf = QForest::<i8>::from_forest(&f, crate::quant::choose_scale_i8(&f, 1.0));
        let e = QNaiveEngine::new(&qf);
        assert_eq!(e.name(), "q8NA");
        assert_eq!(e.predict(&ds.x), qf.predict_batch(&ds.x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn trace_nonempty_and_scales() {
        let (f, ds) = setup();
        let e = NaiveEngine::new(&f);
        let t1 = e.count_ops(&ds.x[..ds.d * 4]);
        let t2 = e.count_ops(&ds.x[..ds.d * 8]);
        assert!(t1.scalar_fp > 0);
        assert!(t2.total_ops() > t1.total_ops());
    }

    #[test]
    fn single_leaf_tree_ok() {
        let mut f = Forest::new(2, 1, crate::forest::Task::Ranking);
        f.trees.push(crate::forest::Tree::leaf(vec![2.5]));
        let e = NaiveEngine::new(&f);
        assert_eq!(e.predict(&[0.0, 0.0]), vec![2.5]);
    }
}
