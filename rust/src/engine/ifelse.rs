//! IE — the "If-Else" baseline: each tree decomposed into its branch
//! structure (Asadi et al. 2014).
//!
//! The paper's IE is *generated C++* — nested `if/else` blocks with
//! thresholds embedded as immediates, statically compiled per model
//! (FastInference). Without runtime codegen we model the same traversal
//! shape with a pointer-linked node graph walked by direct branching: like
//! compiled if-else, there is no index arithmetic and the children are
//! reached by following the branch taken; unlike NA's flat arrays, node
//! records live wherever the allocator placed them (an instruction-cache
//! analogue of scattered basic blocks). The substitution is recorded in
//! DESIGN.md §1.

use super::Engine;
use crate::forest::{Child, Forest};
use crate::neon::OpTrace;
use crate::quant::{shift_round, QForest, QuantConfig, QuantInt};

/// A boxed branch-structure node.
enum IeNode<T: Copy, V: Copy> {
    Split { feature: u32, threshold: T, left: Box<IeNode<T, V>>, right: Box<IeNode<T, V>> },
    Leaf { value: Vec<V> },
}

impl<T: Copy, V: Copy> IeNode<T, V> {
    #[inline]
    fn walk(&self, le: &impl Fn(u32, T) -> bool) -> &[V] {
        let mut cur = self;
        loop {
            match cur {
                IeNode::Leaf { value } => return value,
                IeNode::Split { feature, threshold, left, right } => {
                    cur = if le(*feature, *threshold) { left } else { right };
                }
            }
        }
    }

    fn depth_walk(&self, le: &impl Fn(u32, T) -> bool) -> u64 {
        let mut cur = self;
        let mut depth = 0;
        loop {
            match cur {
                IeNode::Leaf { .. } => return depth,
                IeNode::Split { feature, threshold, left, right } => {
                    depth += 1;
                    cur = if le(*feature, *threshold) { left } else { right };
                }
            }
        }
    }
}

fn build_f32(t: &crate::forest::Tree, c: Child) -> IeNode<f32, f32> {
    match c {
        Child::Leaf(l) => IeNode::Leaf { value: t.leaf_row(l as usize).to_vec() },
        Child::Inner(i) => {
            let n = &t.nodes[i as usize];
            IeNode::Split {
                feature: n.feature,
                threshold: n.threshold,
                left: Box::new(build_f32(t, n.left)),
                right: Box::new(build_f32(t, n.right)),
            }
        }
    }
}

/// Branch structure with FLInt-encoded immediates: thresholds become
/// order-preserving i32s, leaf rows stay f32 — representation only.
fn build_flint(t: &crate::forest::Tree, c: Child) -> IeNode<i32, f32> {
    match c {
        Child::Leaf(l) => IeNode::Leaf { value: t.leaf_row(l as usize).to_vec() },
        Child::Inner(i) => {
            let n = &t.nodes[i as usize];
            IeNode::Split {
                feature: n.feature,
                threshold: crate::quant::flint::encode_threshold(n.threshold),
                left: Box::new(build_flint(t, n.left)),
                right: Box::new(build_flint(t, n.right)),
            }
        }
    }
}

fn build_q<S: QuantInt>(
    t: &crate::quant::QTree<S>,
    c: Child,
    n_classes: usize,
) -> IeNode<S, S> {
    match c {
        Child::Leaf(l) => {
            let l = l as usize;
            IeNode::Leaf { value: t.leaf_values[l * n_classes..(l + 1) * n_classes].to_vec() }
        }
        Child::Inner(i) => {
            let i = i as usize;
            IeNode::Split {
                feature: t.features[i],
                threshold: t.thresholds[i],
                left: Box::new(build_q(t, t.left[i], n_classes)),
                right: Box::new(build_q(t, t.right[i], n_classes)),
            }
        }
    }
}

/// Float IE engine.
pub struct IfElseEngine {
    roots: Vec<IeNode<f32, f32>>,
    base: Vec<f32>,
    n_features: usize,
    n_classes: usize,
    mem_bytes: usize,
}

impl IfElseEngine {
    pub fn new(f: &Forest) -> IfElseEngine {
        let roots = f
            .trees
            .iter()
            .map(|t| {
                if t.nodes.is_empty() {
                    IeNode::Leaf { value: t.leaf_values.clone() }
                } else {
                    build_f32(t, Child::Inner(0))
                }
            })
            .collect();
        // Pointer-linked nodes: each split is a boxed enum (~32 B + two
        // child pointers), each leaf a boxed Vec of C values.
        let splits = f.n_nodes();
        let leaves: usize = f.trees.iter().map(|t| t.n_leaves).sum();
        let mem_bytes = splits * 40 + leaves * (32 + f.n_classes * 4);
        IfElseEngine {
            roots,
            base: f.base_score.clone(),
            n_features: f.n_features,
            n_classes: f.n_classes,
            mem_bytes,
        }
    }
}

impl Engine for IfElseEngine {
    fn name(&self) -> String {
        "IE".into()
    }

    fn lanes(&self) -> usize {
        1
    }

    fn n_features(&self) -> usize {
        self.n_features
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let d = self.n_features;
        let c = self.n_classes;
        let n = x.len() / d;
        for i in 0..n {
            let row = &x[i * d..(i + 1) * d];
            let o = &mut out[i * c..(i + 1) * c];
            o.copy_from_slice(&self.base);
            let le = |f: u32, t: f32| row[f as usize] <= t;
            for root in &self.roots {
                for (dst, &v) in o.iter_mut().zip(root.walk(&le)) {
                    *dst += v;
                }
            }
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        let d = self.n_features;
        let c = self.n_classes as u64;
        let n = x.len() / d;
        let mut tr = OpTrace::new();
        for i in 0..n {
            let row = &x[i * d..(i + 1) * d];
            let le = |f: u32, t: f32| row[f as usize] <= t;
            for root in &self.roots {
                let depth = root.depth_walk(&le);
                // Codegen if-else: threshold is an immediate (no data load),
                // but taken-branch-heavy code with poor prediction; x access
                // is one load per node.
                tr.random_loads += depth;
                tr.scalar_fp += depth;
                tr.cmp_fp += depth;
                tr.branch += 2 * depth; // if + jump-over-else
                tr.branch_mispredictable += depth / 2;
                tr.scalar_fp += c;
            }
        }
        tr
    }

    fn memory_bytes(&self) -> usize {
        self.mem_bytes
    }
}

/// FLInt IE engine (flIE): the [`IfElseEngine`] branch structure with
/// integer immediates — each row is FLInt-encoded once
/// ([`crate::quant::flint::encode_batch_le`], NaN → `i32::MAX`) and every
/// split compares i32s; leaf accumulation is the untouched f32 path, so
/// outputs are **bit-identical** to the float engine.
pub struct FlintIfElseEngine {
    roots: Vec<IeNode<i32, f32>>,
    base: Vec<f32>,
    n_features: usize,
    n_classes: usize,
    mem_bytes: usize,
}

impl FlintIfElseEngine {
    pub fn new(f: &Forest) -> FlintIfElseEngine {
        let roots = f
            .trees
            .iter()
            .map(|t| {
                if t.nodes.is_empty() {
                    IeNode::Leaf { value: t.leaf_values.clone() }
                } else {
                    build_flint(t, Child::Inner(0))
                }
            })
            .collect();
        let splits = f.n_nodes();
        let leaves: usize = f.trees.iter().map(|t| t.n_leaves).sum();
        let mem_bytes = splits * 40 + leaves * (32 + f.n_classes * 4);
        FlintIfElseEngine {
            roots,
            base: f.base_score.clone(),
            n_features: f.n_features,
            n_classes: f.n_classes,
            mem_bytes,
        }
    }
}

impl Engine for FlintIfElseEngine {
    fn name(&self) -> String {
        "flIE".into()
    }

    fn lanes(&self) -> usize {
        1
    }

    fn n_features(&self) -> usize {
        self.n_features
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let d = self.n_features;
        let c = self.n_classes;
        let n = x.len() / d;
        let mut ex = Vec::with_capacity(x.len());
        crate::quant::flint::encode_batch_le(x, &mut ex);
        for i in 0..n {
            let row = &ex[i * d..(i + 1) * d];
            let o = &mut out[i * c..(i + 1) * c];
            o.copy_from_slice(&self.base);
            let le = |f: u32, t: i32| row[f as usize] <= t;
            for root in &self.roots {
                for (dst, &v) in o.iter_mut().zip(root.walk(&le)) {
                    *dst += v;
                }
            }
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        let d = self.n_features;
        let c = self.n_classes as u64;
        let n = x.len() / d;
        let mut ex = Vec::new();
        crate::quant::flint::encode_batch_le(x, &mut ex);
        let mut tr = OpTrace::new();
        // Feature encoding: one integer fixup + store per value (no FP).
        tr.scalar_alu += (n * d) as u64;
        tr.store_bytes += (n * d * std::mem::size_of::<i32>()) as u64;
        for i in 0..n {
            let row = &ex[i * d..(i + 1) * d];
            let le = |f: u32, t: i32| row[f as usize] <= t;
            for root in &self.roots {
                let depth = root.depth_walk(&le);
                tr.random_loads += depth;
                tr.scalar_alu += depth; // integer compares on immediates
                tr.cmp_int += depth;
                tr.branch += 2 * depth;
                tr.branch_mispredictable += depth / 2;
                tr.scalar_fp += c; // leaf adds stay f32
            }
        }
        tr
    }

    fn memory_bytes(&self) -> usize {
        self.mem_bytes
    }
}

/// Quantized IE engine (qIE / q8IE), generic over the storage tier. The
/// branch structure is identical across tiers; only the immediates narrow.
pub struct QIfElseEngine<S: QuantInt = i16> {
    roots: Vec<IeNode<S, S>>,
    base: Vec<i32>,
    config: QuantConfig<S>,
    /// Per-tree leaf shifts (per-tree-scale quantization; all zeros under
    /// global scaling).
    shifts: Vec<u8>,
    n_features: usize,
    n_classes: usize,
    mem_bytes: usize,
}

impl<S: QuantInt> QIfElseEngine<S> {
    pub fn new(qf: &QForest<S>) -> QIfElseEngine<S> {
        let roots = qf
            .trees
            .iter()
            .map(|t| {
                if t.features.is_empty() {
                    IeNode::Leaf { value: t.leaf_values.clone() }
                } else {
                    build_q(t, Child::Inner(0), qf.n_classes)
                }
            })
            .collect();
        let splits: usize = qf.trees.iter().map(|t| t.features.len()).sum();
        let leaves: usize = qf.trees.iter().map(|t| t.n_leaves).sum();
        let mem_bytes =
            splits * 40 + leaves * (32 + qf.n_classes * std::mem::size_of::<S>());
        QIfElseEngine {
            roots,
            base: qf.base_score.clone(),
            config: qf.config,
            shifts: qf.tree_shifts.clone(),
            n_features: qf.n_features,
            n_classes: qf.n_classes,
            mem_bytes,
        }
    }
}

impl<S: QuantInt> Engine for QIfElseEngine<S> {
    fn name(&self) -> String {
        format!("{}IE", S::ENGINE_PREFIX)
    }

    fn lanes(&self) -> usize {
        1
    }

    fn n_features(&self) -> usize {
        self.n_features
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let d = self.n_features;
        let c = self.n_classes;
        let n = x.len() / d;
        let mut qx = Vec::with_capacity(x.len());
        self.config.q_slice(x, &mut qx);
        let mut acc = vec![0i32; c];
        for i in 0..n {
            let row = &qx[i * d..(i + 1) * d];
            acc.copy_from_slice(&self.base);
            let le = |f: u32, t: S| row[f as usize] <= t;
            for (root, &sh) in self.roots.iter().zip(&self.shifts) {
                for (dst, &v) in acc.iter_mut().zip(root.walk(&le)) {
                    *dst += shift_round(v.to_i32(), sh);
                }
            }
            for (o, &a) in out[i * c..(i + 1) * c].iter_mut().zip(acc.iter()) {
                *o = self.config.dq(a);
            }
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        let d = self.n_features;
        let c = self.n_classes as u64;
        let n = x.len() / d;
        let mut qx = Vec::new();
        self.config.q_slice(x, &mut qx);
        let mut tr = OpTrace::new();
        tr.scalar_fp += (n * d) as u64 * 2; // feature quantization
        tr.store_bytes += (n * d * std::mem::size_of::<S>()) as u64;
        for i in 0..n {
            let row = &qx[i * d..(i + 1) * d];
            let le = |f: u32, t: S| row[f as usize] <= t;
            for root in &self.roots {
                let depth = root.depth_walk(&le);
                tr.random_loads += depth;
                tr.scalar_alu += depth;
                tr.cmp_int += depth;
                tr.branch += 2 * depth;
                tr.branch_mispredictable += depth / 2;
                tr.scalar_alu += c;
            }
        }
        tr
    }

    fn memory_bytes(&self) -> usize {
        self.mem_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    fn setup() -> (Forest, crate::data::Dataset) {
        let ds = DatasetId::Eeg.generate(400, 13);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 10,
                tree: TreeParams { max_leaves: 32, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        (f, ds)
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn ie_matches_reference() {
        let (f, ds) = setup();
        let e = IfElseEngine::new(&f);
        assert_eq!(e.predict(&ds.x), f.predict_batch(&ds.x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn qie_matches_qforest() {
        let (f, ds) = setup();
        let qf = QForest::from_forest(&f, QuantConfig::paper_default());
        let e = QIfElseEngine::new(&qf);
        assert_eq!(e.name(), "qIE");
        assert_eq!(e.predict(&ds.x), qf.predict_batch(&ds.x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8ie_matches_qforest() {
        let (f, ds) = setup();
        let qf = QForest::<i8>::from_forest(&f, crate::quant::choose_scale_i8(&f, 1.0));
        let e = QIfElseEngine::new(&qf);
        assert_eq!(e.name(), "q8IE");
        assert_eq!(e.predict(&ds.x), qf.predict_batch(&ds.x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8ie_per_tree_shifts_match_reference() {
        let (f, ds) = setup();
        let cfg = crate::quant::choose_scale_i8_per_tree(&f, 1.0);
        let qf = QForest::<i8>::from_forest_per_tree(&f, cfg);
        let e = QIfElseEngine::new(&qf);
        assert_eq!(e.predict(&ds.x), qf.predict_batch(&ds.x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn flint_ie_bit_identical_to_float_ie() {
        let (f, ds) = setup();
        let fl = FlintIfElseEngine::new(&f);
        let fe = IfElseEngine::new(&f);
        assert_eq!(fl.name(), "flIE");
        assert_eq!(fl.predict(&ds.x), fe.predict(&ds.x));

        // Adversarial rows: NaN, -0.0, a denormal and -inf must all route
        // exactly as the float engine routes them.
        let mut adv = ds.x[..4 * ds.d].to_vec();
        adv[0] = f32::NAN;
        adv[ds.d] = -0.0;
        adv[2 * ds.d] = f32::from_bits(0x0000_0001);
        adv[3 * ds.d] = f32::NEG_INFINITY;
        assert_eq!(fl.predict(&adv), fe.predict(&adv));

        let tr = fl.count_ops(&ds.x[..4 * ds.d]);
        assert!(tr.cmp_int > 0);
        assert_eq!(tr.cmp_fp, 0);
        assert!(tr.scalar_fp > 0); // leaf adds stay float
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn ie_and_na_agree() {
        let (f, ds) = setup();
        let ie = IfElseEngine::new(&f);
        let na = super::super::naive::NaiveEngine::new(&f);
        assert_eq!(ie.predict(&ds.x), na.predict(&ds.x));
    }
}
