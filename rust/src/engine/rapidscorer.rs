//! RS — RapidScorer (Ye et al. 2018) on ARM NEON (paper §3, §4.1, §5.1).
//!
//! RapidScorer improves V-QuickScorer threefold:
//!
//! 1. **Epitomes**: a node's bitvector mask is stored as only the byte range
//!    that actually contains zeros, shrinking the model and the number of
//!    byte rows touched per false node.
//! 2. **Node merging**: all nodes in the forest testing the same
//!    `(feature, threshold)` are merged into one group — the threshold
//!    comparison executes once per group instead of once per node.
//! 3. **Byte-transposed leafidx** (`leafidx↓`): with v = 16 instances, byte
//!    `m` of every instance's bitvector lives in one `uint8x16_t` register
//!    (instance = column), so mask application and the exit-leaf search run
//!    as bytewise NEON ops across all 16 instances at once.
//!
//! The exit-leaf search is the paper's Algorithm 4 — `vtstq_u8`/`vceqq_u8`/
//! `vbslq_u8` to find the first non-zero byte per column, then
//! `vclzq_u8(vrbitq_u8(b))` for the first set bit within it (the paper's
//! line 7 prints the two intrinsics in the reverse order; as printed it
//! would compute `rbit(clz(b))`, which is not a bit index — we use the
//! evidently intended composition), and `vmlaq_u8` to combine byte and bit
//! indices.
//!
//! Float thresholds compare 16 instances via 4 × `vcgtq_f32`; int16
//! fixed-point needs only 2 × `vcgtq_s16` (§5.1) — the promised halving of
//! comparison work. The int8 tier goes one width further: RapidScorer's
//! block width already equals the i8 lane count (v = 16), so **one**
//! `vcgtq_s8` covers the whole block, and the epitome machinery is
//! untouched — epitomes are byte-wise regardless of the threshold width,
//! which is why the layout ports to 8-bit thresholds for free (only the
//! group records shrink). Scores accumulate through the same
//! native-or-widened i8 chain as q8VQS ([`crate::quant::AccumMode`]).

use super::common::{qtree_left_ranges, left_range_mask, QsModel};
use super::vqs::Acc8;
use super::Engine;
use crate::forest::Forest;
use crate::neon::*;
use crate::quant::{AccumMode, QForest, QuantConfig, QuantInt};

/// Instances per RapidScorer block: one byte lane per instance.
pub(crate) const V_RS: usize = 16;

/// One merged node group: a unique `(feature, threshold)` with the epitomes
/// it applies on a false outcome.
#[derive(Debug, Clone)]
struct Group<T> {
    threshold: T,
    /// Range into the entry arrays.
    entries: std::ops::Range<u32>,
}

/// One epitome entry, packed into 16 bytes: the owning tree, the first
/// bitvector byte row the epitome touches, its length, and the epitome
/// bytes inline (a 64-leaf mask spans at most 8 bytes). Inline storage
/// keeps the false-node hot loop on a single cache stream (§Perf it. 2).
#[derive(Debug, Clone, Copy)]
struct RsEntry {
    tree: u32,
    row: u8,
    len: u8,
    bytes: [u8; 8],
}

/// The RapidScorer model: merged feature-ordered groups + epitome store +
/// padded leaf table (shared shape with [`QsModel`]).
pub struct RsModel<T: Copy, V: Copy> {
    n_features: usize,
    n_classes: usize,
    n_trees: usize,
    leaf_words: usize,
    /// Per-feature offsets into `groups`.
    feat_offsets: Vec<u32>,
    groups: Vec<Group<T>>,
    entries: Vec<RsEntry>,
    leaf_values: Vec<V>,
    base_f32: Vec<f32>,
    base_i32: Vec<i32>,
    /// Per-tree leaf shifts (per-tree-scale quantization; all zeros for
    /// float / globally-scaled models).
    tree_shifts: Vec<u8>,
}

/// Build the merged epitome model from raw per-node lists. `merge = false`
/// disables node merging (each node is its own group) — the ablation knob
/// for quantifying RapidScorer's merging contribution (Table 4's mechanism).
fn build_rs<T: Copy + PartialEq + PartialOrd, V: Copy>(
    n_features: usize,
    n_classes: usize,
    n_trees: usize,
    leaf_words: usize,
    // (feature, threshold, tree, mask) sorted by (feature, threshold).
    nodes: &[(u32, T, u32, u64)],
    leaf_values: Vec<V>,
    base_f32: Vec<f32>,
    base_i32: Vec<i32>,
    tree_shifts: Vec<u8>,
    merge: bool,
) -> RsModel<T, V> {
    let mut m = RsModel {
        n_features,
        n_classes,
        n_trees,
        leaf_words,
        feat_offsets: vec![0u32; n_features + 1],
        groups: Vec::new(),
        entries: Vec::new(),
        leaf_values,
        base_f32,
        base_i32,
        tree_shifts,
    };

    let mut i = 0usize;
    while i < nodes.len() {
        let (feat, thr, _, _) = nodes[i];
        // Collect the merged group [i, j): same feature & threshold.
        let mut j = i;
        // Per-tree combined mask: equivalent nodes of the *same* tree are
        // false together, so their masks AND into one epitome.
        let mut per_tree: Vec<(u32, u64)> = Vec::new();
        let limit = if merge { nodes.len() } else { i + 1 };
        while j < limit.min(nodes.len()) && nodes[j].0 == feat && nodes[j].1 == thr {
            let (_, _, tree, mask) = nodes[j];
            match per_tree.iter_mut().find(|(t, _)| *t == tree) {
                Some((_, m)) => *m &= mask,
                None => per_tree.push((tree, mask)),
            }
            j += 1;
        }
        let entry_start = m.entries.len() as u32;
        for (tree, mask) in per_tree {
            // Epitome: byte range [lo, hi] containing all zero bits.
            let zeros = !mask;
            debug_assert!(zeros != 0);
            let lo = (zeros.trailing_zeros() / 8) as usize;
            let hi = (63 - zeros.leading_zeros()) as usize / 8;
            let all = mask.to_le_bytes();
            let mut bytes = [0u8; 8];
            bytes[..hi - lo + 1].copy_from_slice(&all[lo..=hi]);
            m.entries.push(RsEntry { tree, row: lo as u8, len: (hi - lo + 1) as u8, bytes });
        }
        m.groups.push(Group { threshold: thr, entries: entry_start..m.entries.len() as u32 });
        m.feat_offsets[feat as usize + 1] += 1;
        i = j;
    }
    for f in 0..n_features {
        m.feat_offsets[f + 1] += m.feat_offsets[f];
    }
    m
}

impl<T: Copy, V: Copy> RsModel<T, V> {
    #[inline]
    fn feature_groups(&self, k: usize) -> std::ops::Range<usize> {
        self.feat_offsets[k] as usize..self.feat_offsets[k + 1] as usize
    }

    /// Kept as the readable reference for the offset arithmetic inlined in
    /// the score loops (§Perf iteration 3).
    #[allow(dead_code)]
    #[inline]
    fn leaf_row(&self, tree: usize, leaf: usize) -> &[V] {
        let c = self.n_classes;
        let start = (tree * self.leaf_words + leaf) * c;
        &self.leaf_values[start..start + c]
    }

    /// Bitvector byte rows per tree.
    #[inline]
    fn rows(&self) -> usize {
        self.leaf_words / 8
    }

    /// Merged-group count (the paper's "unique nodes kept", Table 4).
    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Resident bytes: groups + packed epitome entries + leaf table.
    pub fn memory_bytes(&self) -> usize {
        self.feat_offsets.len() * 4
            + self.groups.len() * (std::mem::size_of::<T>() + 8)
            + self.entries.len() * std::mem::size_of::<RsEntry>()
            + self.leaf_values.len() * std::mem::size_of::<V>()
            + self.tree_shifts.len()
    }
}

impl RsModel<f32, f32> {
    pub fn from_forest(f: &Forest) -> RsModel<f32, f32> {
        Self::from_forest_opts(f, true)
    }

    /// `merge = false` builds the no-merging ablation variant.
    pub fn from_forest_opts(f: &Forest, merge: bool) -> RsModel<f32, f32> {
        // Reuse QsModel prep for sorting + leaf padding, then merge.
        let qs = QsModel::<f32, f32>::from_forest(f);
        let mut nodes = Vec::with_capacity(qs.thresholds.len());
        for k in 0..qs.n_features {
            for idx in qs.feature_range(k) {
                nodes.push((k as u32, qs.thresholds[idx], qs.tree_ids[idx], qs.masks[idx]));
            }
        }
        build_rs(
            qs.n_features,
            qs.n_classes,
            qs.n_trees,
            qs.leaf_words,
            &nodes,
            qs.leaf_values,
            qs.base_f32,
            Vec::new(),
            qs.tree_shifts,
            merge,
        )
    }
}

impl RsModel<i32, f32> {
    /// FLInt model: the float prep (sort + leaf padding) re-encoded to
    /// order-preserving i32 thresholds before merging. Equal floats encode
    /// equal and distinct floats encode distinct (the map is injective
    /// after −0.0 canonicalization), so the merged groups, epitomes, and
    /// scan order are exactly the float model's.
    pub fn from_forest(f: &Forest) -> RsModel<i32, f32> {
        let qs = QsModel::<f32, f32>::from_forest(f).to_flint();
        let mut nodes = Vec::with_capacity(qs.thresholds.len());
        for k in 0..qs.n_features {
            for idx in qs.feature_range(k) {
                nodes.push((k as u32, qs.thresholds[idx], qs.tree_ids[idx], qs.masks[idx]));
            }
        }
        build_rs(
            qs.n_features,
            qs.n_classes,
            qs.n_trees,
            qs.leaf_words,
            &nodes,
            qs.leaf_values,
            qs.base_f32,
            Vec::new(),
            qs.tree_shifts,
            true,
        )
    }
}

impl<S: QuantInt> RsModel<S, S> {
    /// Build the merged epitome model from a quantized forest — any storage
    /// tier. Quantization collapses thresholds (Table 4), so the i8 tier
    /// merges *more* aggressively than i16; the epitome bytes themselves
    /// are width-independent.
    pub fn from_qforest(qf: &QForest<S>) -> RsModel<S, S> {
        let qs = QsModel::<S, S>::from_qforest(qf);
        let mut nodes = Vec::with_capacity(qs.thresholds.len());
        for k in 0..qs.n_features {
            for idx in qs.feature_range(k) {
                nodes.push((k as u32, qs.thresholds[idx], qs.tree_ids[idx], qs.masks[idx]));
            }
        }
        build_rs(
            qs.n_features,
            qs.n_classes,
            qs.n_trees,
            qs.leaf_words,
            &nodes,
            qs.leaf_values,
            Vec::new(),
            qs.base_i32,
            qs.tree_shifts,
            true,
        )
    }
}

// ---------------------------------------------------------------------------
// Shared block machinery
// ---------------------------------------------------------------------------

/// Apply one merged group's epitomes to the transposed leafidx under the
/// 16-lane byte mask.
#[inline]
fn apply_group<T: Copy, V: Copy>(
    m: &RsModel<T, V>,
    g: &Group<T>,
    mask: U8x16,
    leafidx: &mut [U8x16],
) {
    let rows = m.rows();
    let entries = &m.entries[g.entries.start as usize..g.entries.end as usize];
    for e in entries {
        let base = e.tree as usize * rows + e.row as usize;
        for (r, &byte) in e.bytes[..e.len as usize].iter().enumerate() {
            let cur = leafidx[base + r];
            let y = vandq_u8(vdupq_n_u8(byte), cur);
            leafidx[base + r] = vbslq_u8(mask, y, cur);
        }
    }
}

/// VECTORIZED_FINDLEAFINDEX (paper Algorithm 4): the exit-leaf index of all
/// 16 instances for one tree, from its transposed bitvector rows.
#[inline]
fn find_leaf_index(rows: &[U8x16]) -> U8x16 {
    let ones = vdupq_n_u8(0xFF);
    let zero = vdupq_n_u8(0);
    let mut b = zero;
    let mut c1 = zero;
    for (mi, &row) in rows.iter().enumerate() {
        // y: lanes whose byte m is non-zero.
        let y = vtstq_u8(row, ones);
        // z: lanes that are non-zero now and had no byte selected yet.
        let z = vandq_u8(y, vceqq_u8(b, zero));
        b = vbslq_u8(z, row, b);
        c1 = vbslq_u8(z, vdupq_n_u8(mi as u8), c1);
    }
    // First set bit within the selected byte: ctz = clz ∘ rbit.
    let c2 = vclzq_u8(vrbitq_u8(b));
    // leaf = c1 * 8 + c2.
    vmlaq_u8(c2, c1, vdupq_n_u8(8))
}

/// Reset the transposed bitvectors to all-ones.
#[inline]
fn reset_leafidx(leafidx: &mut [U8x16]) {
    leafidx.fill(vdupq_n_u8(0xFF));
}

/// Combine 4 f32 compare masks into a 16-lane byte mask.
#[inline]
fn bytes_mask_f32(xt: &[f32], k: usize, gamma: f32) -> U8x16 {
    let g = vdupq_n_f32(gamma);
    let m0 = vcgtq_f32(vld1q_f32(&xt[k * V_RS..]), g);
    let m1 = vcgtq_f32(vld1q_f32(&xt[k * V_RS + 4..]), g);
    let m2 = vcgtq_f32(vld1q_f32(&xt[k * V_RS + 8..]), g);
    let m3 = vcgtq_f32(vld1q_f32(&xt[k * V_RS + 12..]), g);
    let lo = vcombine_u16(vmovn_u32(m0), vmovn_u32(m1));
    let hi = vcombine_u16(vmovn_u32(m2), vmovn_u32(m3));
    vcombine_u8(vmovn_u16(lo), vmovn_u16(hi))
}

/// Combine 4 FLInt i32 compare masks into a 16-lane byte mask — the float
/// chain with `vcgtq_s32` in place of `vcgtq_f32`; the narrow/combine
/// stages are untouched.
#[inline]
fn bytes_mask_s32(xt: &[i32], k: usize, gamma: i32) -> U8x16 {
    let g = vdupq_n_s32(gamma);
    let m0 = vcgtq_s32(vld1q_s32(&xt[k * V_RS..]), g);
    let m1 = vcgtq_s32(vld1q_s32(&xt[k * V_RS + 4..]), g);
    let m2 = vcgtq_s32(vld1q_s32(&xt[k * V_RS + 8..]), g);
    let m3 = vcgtq_s32(vld1q_s32(&xt[k * V_RS + 12..]), g);
    let lo = vcombine_u16(vmovn_u32(m0), vmovn_u32(m1));
    let hi = vcombine_u16(vmovn_u32(m2), vmovn_u32(m3));
    vcombine_u8(vmovn_u16(lo), vmovn_u16(hi))
}

/// Combine 2 i16 compare masks into a 16-lane byte mask (§5.1: half the
/// comparisons of the float path).
#[inline]
fn bytes_mask_i16(xt: &[i16], k: usize, gamma: i16) -> U8x16 {
    let g = vdupq_n_s16(gamma);
    let m0 = vcgtq_s16(vld1q_s16(&xt[k * V_RS..]), g);
    let m1 = vcgtq_s16(vld1q_s16(&xt[k * V_RS + 8..]), g);
    vcombine_u8(vmovn_u16(m0), vmovn_u16(m1))
}

/// Int8 tier: RapidScorer's block width equals the i8 lane count, so a
/// *single* `vcgtq_s8` yields the 16-lane byte mask directly — no
/// narrow/combine chain at all (vs 2 compares + combine for i16, 4 + two
/// combine stages for f32).
#[inline]
fn bytes_mask_i8(xt: &[i8], k: usize, gamma: i8) -> U8x16 {
    vcgtq_s8(vld1q_s8(&xt[k * V_RS..]), vdupq_n_s8(gamma))
}

fn transpose_rs<T: Copy>(x: &[T], d: usize, n: usize, base: usize, xt: &mut [T]) {
    for lane in 0..V_RS {
        let i = (base + lane).min(n - 1);
        let row = &x[i * d..(i + 1) * d];
        for k in 0..d {
            xt[k * V_RS + lane] = row[k];
        }
    }
}

// ---------------------------------------------------------------------------
// Float RS engine
// ---------------------------------------------------------------------------

/// Float RapidScorer.
pub struct RsEngine {
    m: RsModel<f32, f32>,
}

impl RsEngine {
    pub fn new(f: &Forest) -> RsEngine {
        RsEngine { m: RsModel::from_forest(f) }
    }

    /// Ablation variant with node merging disabled (one group per node).
    pub fn new_unmerged(f: &Forest) -> RsEngine {
        RsEngine { m: RsModel::from_forest_opts(f, false) }
    }

    pub fn model(&self) -> &RsModel<f32, f32> {
        &self.m
    }
}

impl Engine for RsEngine {
    fn name(&self) -> String {
        "RS".into()
    }

    fn lanes(&self) -> usize {
        V_RS
    }

    fn n_features(&self) -> usize {
        self.m.n_features
    }

    fn n_classes(&self) -> usize {
        self.m.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let m = &self.m;
        let d = m.n_features;
        let c = m.n_classes;
        let n = x.len() / d;
        let rows = m.rows();
        let mut xt = vec![0f32; d * V_RS];
        let mut leafidx = vec![U8x16([0; 16]); m.n_trees * rows];
        let mut acc = vec![[F32x4([0.0; 4]); 4]; c];

        let mut base = 0usize;
        while base < n {
            transpose_rs(x, d, n, base, &mut xt);
            reset_leafidx(&mut leafidx);
            // Mask computation over merged groups.
            for k in 0..d {
                let gr = m.feature_groups(k);
                if gr.is_empty() {
                    continue;
                }
                for gi in gr {
                    let g = &m.groups[gi];
                    let mask = bytes_mask_f32(&xt, k, g.threshold);
                    if vmaxvq_u8(mask) == 0 {
                        break;
                    }
                    apply_group(m, g, mask, &mut leafidx);
                }
            }
            // Score computation: Alg. 4 per tree, then per-class gather+add.
            acc.iter_mut().for_each(|a| *a = [F32x4([0.0; 4]); 4]);
            for ti in 0..m.n_trees {
                let leaves = find_leaf_index(&leafidx[ti * rows..(ti + 1) * rows]);
                // Row offsets once per tree (not per class per lane).
                let mut offs = [0usize; V_RS];
                for (lane, o) in offs.iter_mut().enumerate() {
                    *o = (ti * m.leaf_words + vgetq_lane_u8(leaves, lane) as usize) * c;
                }
                for (cls, a) in acc.iter_mut().enumerate() {
                    for q in 0..4 {
                        let vals = F32x4([
                            m.leaf_values[offs[q * 4] + cls],
                            m.leaf_values[offs[q * 4 + 1] + cls],
                            m.leaf_values[offs[q * 4 + 2] + cls],
                            m.leaf_values[offs[q * 4 + 3] + cls],
                        ]);
                        a[q] = vaddq_f32(a[q], vals);
                    }
                }
            }
            for lane in 0..V_RS {
                let i = base + lane;
                if i >= n {
                    break;
                }
                for cls in 0..c {
                    out[i * c + cls] = acc[cls][lane / 4].0[lane % 4] + m.base_f32[cls];
                }
            }
            base += V_RS;
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        rs_trace(&self.m, x, |xt, k, thr| {
            (0..V_RS).any(|lane| xt[k * V_RS + lane] > thr)
        }, 4)
    }

    fn memory_bytes(&self) -> usize {
        self.m.memory_bytes()
    }
}

// ---------------------------------------------------------------------------
// FLInt RS engine
// ---------------------------------------------------------------------------

/// FLInt RapidScorer (flRS): [`RsEngine`] with the 4 × `vcgtq_f32` group
/// compare replaced by 4 × `vcgtq_s32` over FLInt-encoded features
/// ([`crate::quant::flint`], `>`-style map, NaN → `i32::MIN`). Epitomes,
/// Algorithm 4, and the f32 score gather are byte-for-byte the float
/// engine's, so outputs are **bit-identical** to [`RsEngine`].
pub struct FlintRsEngine {
    m: RsModel<i32, f32>,
}

impl FlintRsEngine {
    pub fn new(f: &Forest) -> FlintRsEngine {
        FlintRsEngine { m: RsModel::<i32, f32>::from_forest(f) }
    }

    pub fn model(&self) -> &RsModel<i32, f32> {
        &self.m
    }
}

impl Engine for FlintRsEngine {
    fn name(&self) -> String {
        "flRS".into()
    }

    fn lanes(&self) -> usize {
        V_RS
    }

    fn n_features(&self) -> usize {
        self.m.n_features
    }

    fn n_classes(&self) -> usize {
        self.m.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let m = &self.m;
        let d = m.n_features;
        let c = m.n_classes;
        let n = x.len() / d;
        let rows = m.rows();
        let mut ex = Vec::with_capacity(x.len());
        crate::quant::flint::encode_batch_gt(x, &mut ex);
        let mut xt = vec![0i32; d * V_RS];
        let mut leafidx = vec![U8x16([0; 16]); m.n_trees * rows];
        let mut acc = vec![[F32x4([0.0; 4]); 4]; c];

        let mut base = 0usize;
        while base < n {
            transpose_rs(&ex, d, n, base, &mut xt);
            reset_leafidx(&mut leafidx);
            for k in 0..d {
                let gr = m.feature_groups(k);
                if gr.is_empty() {
                    continue;
                }
                for gi in gr {
                    let g = &m.groups[gi];
                    let mask = bytes_mask_s32(&xt, k, g.threshold);
                    if vmaxvq_u8(mask) == 0 {
                        break;
                    }
                    apply_group(m, g, mask, &mut leafidx);
                }
            }
            acc.iter_mut().for_each(|a| *a = [F32x4([0.0; 4]); 4]);
            for ti in 0..m.n_trees {
                let leaves = find_leaf_index(&leafidx[ti * rows..(ti + 1) * rows]);
                let mut offs = [0usize; V_RS];
                for (lane, o) in offs.iter_mut().enumerate() {
                    *o = (ti * m.leaf_words + vgetq_lane_u8(leaves, lane) as usize) * c;
                }
                for (cls, a) in acc.iter_mut().enumerate() {
                    for q in 0..4 {
                        let vals = F32x4([
                            m.leaf_values[offs[q * 4] + cls],
                            m.leaf_values[offs[q * 4 + 1] + cls],
                            m.leaf_values[offs[q * 4 + 2] + cls],
                            m.leaf_values[offs[q * 4 + 3] + cls],
                        ]);
                        a[q] = vaddq_f32(a[q], vals);
                    }
                }
            }
            for lane in 0..V_RS {
                let i = base + lane;
                if i >= n {
                    break;
                }
                for cls in 0..c {
                    out[i * c + cls] = acc[cls][lane / 4].0[lane % 4] + m.base_f32[cls];
                }
            }
            base += V_RS;
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        rs_trace_flint(&self.m, x)
    }

    fn memory_bytes(&self) -> usize {
        self.m.memory_bytes()
    }
}

// ---------------------------------------------------------------------------
// Quantized RS engine
// ---------------------------------------------------------------------------

/// Quantized RapidScorer (qRS): int16 thresholds (2 compares per group) and
/// int16 leaf values accumulated in 16-bit lanes.
pub struct QRsEngine {
    m: RsModel<i16, i16>,
    config: QuantConfig,
}

impl QRsEngine {
    pub fn new(qf: &QForest) -> QRsEngine {
        QRsEngine { m: RsModel::from_qforest(qf), config: qf.config }
    }

    pub fn model(&self) -> &RsModel<i16, i16> {
        &self.m
    }
}

impl Engine for QRsEngine {
    fn name(&self) -> String {
        "qRS".into()
    }

    fn lanes(&self) -> usize {
        V_RS
    }

    fn n_features(&self) -> usize {
        self.m.n_features
    }

    fn n_classes(&self) -> usize {
        self.m.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let m = &self.m;
        let d = m.n_features;
        let c = m.n_classes;
        let n = x.len() / d;
        let rows = m.rows();
        let mut qx = Vec::with_capacity(x.len());
        self.config.q_slice(x, &mut qx);
        let mut xt = vec![0i16; d * V_RS];
        let mut leafidx = vec![U8x16([0; 16]); m.n_trees * rows];
        let mut acc = vec![[I16x8([0; 8]); 2]; c];

        let mut base = 0usize;
        while base < n {
            transpose_rs(&qx, d, n, base, &mut xt);
            reset_leafidx(&mut leafidx);
            for k in 0..d {
                for gi in m.feature_groups(k) {
                    let g = &m.groups[gi];
                    let mask = bytes_mask_i16(&xt, k, g.threshold);
                    if vmaxvq_u8(mask) == 0 {
                        break;
                    }
                    apply_group(m, g, mask, &mut leafidx);
                }
            }
            // Score: two I16x8 accumulators per class (16 lanes); per-tree
            // leaf shifts round via SRSHR (identity at shift 0).
            acc.iter_mut().for_each(|a| *a = [I16x8([0; 8]); 2]);
            for ti in 0..m.n_trees {
                let leaves = find_leaf_index(&leafidx[ti * rows..(ti + 1) * rows]);
                let mut offs = [0usize; V_RS];
                for (lane, o) in offs.iter_mut().enumerate() {
                    *o = (ti * m.leaf_words + vgetq_lane_u8(leaves, lane) as usize) * c;
                }
                let sh = m.tree_shifts[ti] as u32;
                for (cls, a) in acc.iter_mut().enumerate() {
                    for h in 0..2 {
                        let mut vals = I16x8([0; 8]);
                        for lane in 0..8 {
                            vals.0[lane] = m.leaf_values[offs[h * 8 + lane] + cls];
                        }
                        a[h] = vaddq_s16(a[h], vrshrq_n_s16(vals, sh));
                    }
                }
            }
            for lane in 0..V_RS {
                let i = base + lane;
                if i >= n {
                    break;
                }
                for cls in 0..c {
                    let v = acc[cls][lane / 8].0[lane % 8] as i32 + m.base_i32[cls];
                    out[i * c + cls] = self.config.dq(v);
                }
            }
            base += V_RS;
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        let mut qx = Vec::new();
        self.config.q_slice(x, &mut qx);
        let d = self.m.n_features;
        let n = x.len() / d;
        // 2 × vcgtq_s16 per group, 2 × vaddq_s16 per (tree, class).
        let mut tr = rs_trace_q(&self.m, &qx, n, 2, 2);
        tr.scalar_fp += (n * d) as u64 * 2;
        tr.store_bytes += (n * d * 2) as u64;
        tr
    }

    fn memory_bytes(&self) -> usize {
        self.m.memory_bytes()
    }
}

// ---------------------------------------------------------------------------
// Int8 RS engine (q8RS)
// ---------------------------------------------------------------------------

/// Int8 RapidScorer (q8RS): 8-bit thresholds — one `vcgtq_s8` per merged
/// group covers the whole v = 16 block — over the unchanged byte-wise
/// epitome layout, with q8VQS's native-or-widened score accumulation
/// (`Acc8`, shared with `engine::vqs`). Quantization collapses thresholds
/// harder at 8 bits, so the merged-group count only shrinks vs qRS
/// (Table 4 amplified).
pub struct QRs8Engine {
    m: RsModel<i8, i8>,
    config: QuantConfig<i8>,
    mode: AccumMode,
}

impl QRs8Engine {
    pub fn new(qf: &QForest<i8>) -> QRs8Engine {
        QRs8Engine { m: RsModel::from_qforest(qf), config: qf.config, mode: qf.accum_mode() }
    }

    /// The accumulation mode chosen at construction
    /// ([`QForest::accum_mode`], exact per-model).
    pub fn accum_mode(&self) -> AccumMode {
        self.mode
    }

    pub fn model(&self) -> &RsModel<i8, i8> {
        &self.m
    }
}

impl Engine for QRs8Engine {
    fn name(&self) -> String {
        "q8RS".into()
    }

    fn lanes(&self) -> usize {
        V_RS
    }

    fn n_features(&self) -> usize {
        self.m.n_features
    }

    fn n_classes(&self) -> usize {
        self.m.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let m = &self.m;
        let d = m.n_features;
        let c = m.n_classes;
        let n = x.len() / d;
        let rows = m.rows();
        let mut qx = Vec::with_capacity(x.len());
        self.config.q_slice(x, &mut qx);
        let mut xt = vec![0i8; d * V_RS];
        let mut leafidx = vec![U8x16([0; 16]); m.n_trees * rows];

        let mut base = 0usize;
        while base < n {
            transpose_rs(&qx, d, n, base, &mut xt);
            reset_leafidx(&mut leafidx);
            for k in 0..d {
                for gi in m.feature_groups(k) {
                    let g = &m.groups[gi];
                    let mask = bytes_mask_i8(&xt, k, g.threshold);
                    if vmaxvq_u8(mask) == 0 {
                        break;
                    }
                    apply_group(m, g, mask, &mut leafidx);
                }
            }
            // Score: Alg. 4 per tree, then a 16-lane i8 gather rounded by
            // the per-tree shift and accumulated natively or widening
            // (same chain as q8VQS).
            let mut acc = Acc8::new(c, self.mode);
            for ti in 0..m.n_trees {
                let leaves = find_leaf_index(&leafidx[ti * rows..(ti + 1) * rows]);
                let mut offs = [0usize; V_RS];
                for (lane, o) in offs.iter_mut().enumerate() {
                    *o = (ti * m.leaf_words + vgetq_lane_u8(leaves, lane) as usize) * c;
                }
                let sh = m.tree_shifts[ti] as u32;
                for cls in 0..c {
                    let mut vals = I8x16([0; 16]);
                    for lane in 0..V_RS {
                        vals.0[lane] = m.leaf_values[offs[lane] + cls];
                    }
                    acc.add(cls, vrshrq_n_s8(vals, sh));
                }
            }
            for lane in 0..V_RS {
                let i = base + lane;
                if i >= n {
                    break;
                }
                for cls in 0..c {
                    let v = self.m.base_i32[cls] + acc.lane(cls, lane);
                    out[i * c + cls] = self.config.dq(v);
                }
            }
            base += V_RS;
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        let mut qx = Vec::new();
        self.config.q_slice(x, &mut qx);
        let d = self.m.n_features;
        let n = x.len() / d;
        // 1 × vcgtq_s8 per group; 1 (native) or 2 (widened) adds per
        // (tree, class).
        let acc_adds = match self.mode {
            AccumMode::Native => 1,
            AccumMode::Widened => 2,
        };
        let mut tr = rs_trace_q(&self.m, &qx, n, 1, acc_adds);
        tr.scalar_fp += (n * d) as u64 * 2;
        tr.store_bytes += (n * d) as u64; // 1 byte per quantized feature
        tr
    }

    fn memory_bytes(&self) -> usize {
        self.m.memory_bytes()
    }
}

// ---------------------------------------------------------------------------
// Op traces
// ---------------------------------------------------------------------------

fn rs_trace<V: Copy>(
    m: &RsModel<f32, V>,
    x: &[f32],
    any_gt: impl Fn(&[f32], usize, f32) -> bool,
    compares_per_group: u64,
) -> OpTrace {
    let d = m.n_features;
    let n = x.len() / d;
    let c = m.n_classes as u64;
    let mut tr = OpTrace::new();
    let mut xt = vec![0f32; d * V_RS];
    let rows = m.rows() as u64;
    let mut base = 0usize;
    while base < n {
        transpose_rs(x, d, n, base, &mut xt);
        for k in 0..d {
            for gi in m.feature_groups(k) {
                let g = &m.groups[gi];
                tr.neon_fp += compares_per_group; // vcgtq per sub-register
                tr.cmp_fp += compares_per_group;
                tr.neon_horiz += 3; // narrow/combine chain
                tr.neon_horiz += 1; // vmaxvq
                tr.branch += 1;
                tr.stream_load_bytes += 8; // group record
                if !any_gt(&xt, k, g.threshold) {
                    break;
                }
                for e in &m.entries[g.entries.start as usize..g.entries.end as usize] {
                    let len = e.len as u64;
                    tr.neon_alu += 3 * len; // dup + and + bsl per byte row
                    tr.stream_load_bytes += 16; // packed entry
                    tr.store_bytes += 16 * len;
                }
            }
        }
        // Alg. 4 + score.
        tr.neon_alu += m.n_trees as u64 * (4 * rows + 3);
        tr.random_loads += m.n_trees as u64 * V_RS as u64;
        tr.neon_fp += m.n_trees as u64 * c * 4;
        tr.store_bytes += m.n_trees as u64 * rows * 16; // leafidx reset
        tr.scalar_alu += (d * V_RS) as u64; // transpose
        base += V_RS;
    }
    tr
}

fn rs_trace_flint(m: &RsModel<i32, f32>, x: &[f32]) -> OpTrace {
    let d = m.n_features;
    let n = x.len() / d;
    let c = m.n_classes as u64;
    let mut ex = Vec::new();
    crate::quant::flint::encode_batch_gt(x, &mut ex);
    let mut tr = OpTrace::new();
    // Feature encoding: one integer fixup + store per value (no FP).
    tr.scalar_alu += (n * d) as u64;
    tr.store_bytes += (n * d * std::mem::size_of::<i32>()) as u64;
    let mut xt = vec![0i32; d * V_RS];
    let rows = m.rows() as u64;
    let mut base = 0usize;
    while base < n {
        transpose_rs(&ex, d, n, base, &mut xt);
        for k in 0..d {
            for gi in m.feature_groups(k) {
                let g = &m.groups[gi];
                tr.neon_alu += 4; // 4 × vcgtq_s32 (integer pipe)
                tr.cmp_int += 4;
                tr.neon_horiz += 3; // narrow/combine chain
                tr.neon_horiz += 1; // vmaxvq
                tr.branch += 1;
                tr.stream_load_bytes += 8; // group record
                if !(0..V_RS).any(|lane| xt[k * V_RS + lane] > g.threshold) {
                    break;
                }
                for e in &m.entries[g.entries.start as usize..g.entries.end as usize] {
                    let len = e.len as u64;
                    tr.neon_alu += 3 * len;
                    tr.stream_load_bytes += 16;
                    tr.store_bytes += 16 * len;
                }
            }
        }
        tr.neon_alu += m.n_trees as u64 * (4 * rows + 3);
        tr.random_loads += m.n_trees as u64 * V_RS as u64;
        tr.neon_fp += m.n_trees as u64 * c * 4; // f32 leaf adds, unchanged
        tr.store_bytes += m.n_trees as u64 * rows * 16;
        tr.scalar_alu += (d * V_RS) as u64;
        base += V_RS;
    }
    tr
}

/// Trace for the fixed-point RS engines, generic over the storage tier:
/// `compares` is the `vcgtq` count per merged group (2 for i16, 1 for i8),
/// `acc_adds` the score adds per (tree, class) (2 i16 registers, or the
/// i8 tier's native 1 / widened 2).
fn rs_trace_q<S: QuantInt>(
    m: &RsModel<S, S>,
    qx: &[S],
    n: usize,
    compares: u64,
    acc_adds: u64,
) -> OpTrace {
    let d = m.n_features;
    let c = m.n_classes as u64;
    let mut tr = OpTrace::new();
    let mut xt = vec![S::default(); d * V_RS];
    let rows = m.rows() as u64;
    let entry_bytes = (std::mem::size_of::<S>() + 4) as u64;
    let mut base = 0usize;
    while base < n {
        transpose_rs(qx, d, n, base, &mut xt);
        for k in 0..d {
            for gi in m.feature_groups(k) {
                let g = &m.groups[gi];
                tr.neon_alu += compares; // vcgtq_s16 / vcgtq_s8 (§5.1)
                tr.cmp_int += compares;
                tr.neon_horiz += compares; // narrow/combine + vmaxvq
                tr.branch += 1;
                tr.stream_load_bytes += entry_bytes;
                if !(0..V_RS).any(|lane| xt[k * V_RS + lane] > g.threshold) {
                    break;
                }
                for e in &m.entries[g.entries.start as usize..g.entries.end as usize] {
                    let len = e.len as u64;
                    tr.neon_alu += 3 * len;
                    tr.stream_load_bytes += 16;
                    tr.store_bytes += 16 * len;
                }
            }
        }
        tr.neon_alu += m.n_trees as u64 * (4 * rows + 3);
        tr.random_loads += m.n_trees as u64 * V_RS as u64;
        tr.neon_alu += m.n_trees as u64 * c * acc_adds;
        tr.store_bytes += m.n_trees as u64 * rows * 16;
        tr.scalar_alu += (d * V_RS) as u64;
        base += V_RS;
    }
    tr
}

// Re-exported for the ablation bench: a RS variant with merging disabled is
// constructed by perturbing thresholds so no two are equal; see
// rust/benches/ablation_rs.rs.
#[allow(unused)]
fn _keep(_: fn(u32, u32) -> u64) {}
const _: () = {
    let _ = left_range_mask;
    let _: fn(&crate::quant::QTree) -> Vec<(u32, u32)> = qtree_left_ranges;
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};
    use crate::testing::assert_close;

    fn setup(ds_id: DatasetId, leaves: usize, seed: u64, n: usize) -> (Forest, crate::data::Dataset) {
        // Train on a bigger sample so max_leaves=64 trees really exceed 32
        // leaves; evaluation uses the first `n` rows.
        let ds = ds_id.generate(n.max(900), seed);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 11,
                tree: TreeParams { max_leaves: leaves, min_samples_leaf: 2, mtry: 0 },
                seed,
                ..Default::default()
            },
        );
        (f, ds)
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn rs_matches_reference_l32() {
        let (f, ds) = setup(DatasetId::Magic, 32, 1, 150); // non-multiple of 16
        let e = RsEngine::new(&f);
        let x = &ds.x[..ds.d * 150];
        assert_close(&e.predict(x), &f.predict_batch(x), 1e-5, 1e-5).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn rs_matches_reference_l64() {
        let (f, ds) = setup(DatasetId::Magic, 64, 2, 100);
        assert!(f.max_leaves() > 32);
        let e = RsEngine::new(&f);
        let x = &ds.x[..ds.d * 100];
        assert_close(&e.predict(x), &f.predict_batch(x), 1e-5, 1e-5).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn flint_rs_bit_identical_to_float_rs() {
        // Both leaf widths, non-multiple-of-16 batches (padding lanes), and
        // adversarial features; the merged-group count must also match the
        // float model's (the encoding is injective).
        for (leaves, seed, n) in [(32usize, 1u64, 150usize), (64, 2, 100)] {
            let (f, ds) = setup(DatasetId::Magic, leaves, seed, n);
            let fl = FlintRsEngine::new(&f);
            let fe = RsEngine::new(&f);
            assert_eq!(fl.name(), "flRS");
            assert_eq!(fl.lanes(), V_RS);
            assert_eq!(fl.model().n_groups(), fe.model().n_groups(), "L={leaves}");
            let x = &ds.x[..ds.d * n];
            assert_eq!(fl.predict(x), fe.predict(x), "L={leaves}");

            let mut adv = ds.x[..4 * ds.d].to_vec();
            adv[0] = f32::NAN;
            adv[ds.d] = -0.0;
            adv[2 * ds.d] = f32::from_bits(0x0000_0001);
            adv[3 * ds.d] = f32::NEG_INFINITY;
            assert_eq!(fl.predict(&adv), fe.predict(&adv), "L={leaves} adversarial");

            let tr = fl.count_ops(&ds.x[..4 * ds.d]);
            assert!(tr.cmp_int > 0);
            assert_eq!(tr.cmp_fp, 0);
            assert!(tr.neon_fp > 0); // f32 leaf adds stay on the FP pipe
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn rs_merging_on_adult() {
        // Binary features -> heavy merging. With few trees the effect is
        // smaller than the paper's 128-tree 12%, but must be clearly present.
        let (f, _) = setup(DatasetId::Adult, 32, 3, 400);
        let e = RsEngine::new(&f);
        let total_nodes = f.n_nodes();
        assert!(
            (e.model().n_groups() as f64) < 0.8 * total_nodes as f64,
            "groups {} vs nodes {total_nodes}",
            e.model().n_groups()
        );
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn qrs_matches_qforest_l32() {
        let (f, ds) = setup(DatasetId::Eeg, 32, 4, 77);
        let qf = QForest::from_forest(&f, QuantConfig::paper_default());
        let e = QRsEngine::new(&qf);
        let x = &ds.x[..ds.d * 77];
        assert_eq!(e.predict(x), qf.predict_batch(x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn qrs_matches_qforest_l64() {
        let (f, ds) = setup(DatasetId::Magic, 64, 5, 49);
        let qf = QForest::from_forest(&f, QuantConfig::paper_default());
        let e = QRsEngine::new(&qf);
        let x = &ds.x[..ds.d * 49];
        assert_eq!(e.predict(x), qf.predict_batch(x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8rs_matches_qforest_l32() {
        let (f, ds) = setup(DatasetId::Eeg, 32, 4, 77);
        let qf = QForest::<i8>::from_forest(&f, crate::quant::choose_scale_i8(&f, 1.0));
        let e = QRs8Engine::new(&qf);
        assert_eq!(e.name(), "q8RS");
        assert_eq!(e.lanes(), 16);
        let x = &ds.x[..ds.d * 77];
        assert_eq!(e.predict(x), qf.predict_batch(x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8rs_matches_qforest_l64() {
        let (f, ds) = setup(DatasetId::Magic, 64, 5, 49);
        assert!(f.max_leaves() > 32);
        let qf = QForest::<i8>::from_forest(&f, crate::quant::choose_scale_i8(&f, 1.0));
        let e = QRs8Engine::new(&qf);
        let x = &ds.x[..ds.d * 49];
        assert_eq!(e.predict(x), qf.predict_batch(x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8rs_widened_mode_exact() {
        // Inflated leaves force the widened i8→i16 accumulation chain.
        let (mut f, ds) = setup(DatasetId::Magic, 32, 6, 64);
        for t in &mut f.trees {
            for v in &mut t.leaf_values {
                *v *= 40.0;
            }
        }
        let qf = QForest::<i8>::from_forest(&f, crate::quant::choose_scale_i8(&f, 1.0));
        let e = QRs8Engine::new(&qf);
        assert_eq!(e.accum_mode(), crate::quant::AccumMode::Widened);
        let x = &ds.x[..ds.d * 64];
        assert_eq!(e.predict(x), qf.predict_batch(x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8rs_per_tree_shifts_exact() {
        let (f, ds) = setup(DatasetId::Magic, 32, 7, 77);
        let cfg = crate::quant::choose_scale_i8_per_tree(&f, 1.0);
        let qf = QForest::<i8>::from_forest_per_tree(&f, cfg);
        assert!(qf.has_per_tree_scales());
        let e = QRs8Engine::new(&qf);
        let x = &ds.x[..ds.d * 77];
        assert_eq!(e.predict(x), qf.predict_batch(x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8rs_merges_at_least_as_much_as_qrs() {
        // 8-bit thresholds collapse at least as hard as 16-bit ones, so
        // q8RS never keeps more merged groups than qRS.
        let (f, _) = setup(DatasetId::Eeg, 32, 8, 200);
        let qf16 = QForest::from_forest(&f, crate::quant::choose_scale(&f, 1.0));
        let qf8 = QForest::<i8>::from_forest(&f, crate::quant::choose_scale_i8(&f, 1.0));
        let e16 = QRsEngine::new(&qf16);
        let e8 = QRs8Engine::new(&qf8);
        assert!(
            e8.model().n_groups() <= e16.model().n_groups(),
            "q8RS groups {} vs qRS {}",
            e8.model().n_groups(),
            e16.model().n_groups()
        );
    }

    #[test]
    fn find_leaf_index_matches_scalar() {
        // Random bitvectors: Alg. 4 must equal trailing_zeros.
        let mut rng = crate::util::Pcg32::seeded(8);
        for _ in 0..200 {
            let rows_n = if rng.bool(0.5) { 4 } else { 8 };
            let mut bits = [0u64; 16];
            let mut rows = vec![U8x16([0; 16]); rows_n];
            for lane in 0..16 {
                // Ensure at least one set bit in the valid range.
                let l = rows_n * 8;
                let b = rng.below(l);
                bits[lane] = (rng.next_u64() | (1u64 << b)) & if l == 64 { u64::MAX } else { (1u64 << l) - 1 };
                let bytes = bits[lane].to_le_bytes();
                for r in 0..rows_n {
                    rows[r].0[lane] = bytes[r];
                }
            }
            let leaves = find_leaf_index(&rows);
            for lane in 0..16 {
                assert_eq!(
                    leaves.0[lane] as u32,
                    bits[lane].trailing_zeros(),
                    "lane {lane} bits {:#x}",
                    bits[lane]
                );
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn trace_counts_present() {
        let (f, ds) = setup(DatasetId::Magic, 32, 6, 32);
        let e = RsEngine::new(&f);
        let tr = e.count_ops(&ds.x);
        assert!(tr.neon_fp > 0 && tr.neon_alu > 0 && tr.neon_horiz > 0);
    }
}
