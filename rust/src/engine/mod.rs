//! Inference engines — the paper's five traversal strategies in float32 and
//! int16 fixed-point variants (DESIGN.md system S6).
//!
//! | engine | paper name      | strategy                                            |
//! |--------|-----------------|-----------------------------------------------------|
//! | NA     | Native/PRED     | while-loop over contiguous node arrays              |
//! | IE     | If-Else         | branchy per-node structure (codegen'd if-else analogue) |
//! | QS     | QuickScorer     | feature-ordered scan + bitvector masking (Alg. 1)   |
//! | VQS    | V-QuickScorer   | QS vectorized over v=4 (f32) / v=8 (i16) instances (Alg. 2) |
//! | RS     | RapidScorer     | epitomes + node merging + byte-transposed leafidx, v=16 (Alg. 3/4) |
//!
//! Prefix `q` (e.g. `qRS`) marks the int16 fixed-point variant (§5).
//! All engines implement [`Engine`] and must agree with the naive reference
//! ([`crate::forest::Forest::predict_batch`] /
//! [`crate::quant::QForest::predict_batch`]) — enforced by the integration
//! and property test suites.

pub mod common;
pub mod ifelse;
pub mod naive;
pub mod quickscorer;
pub mod rapidscorer;
pub mod tensor;
pub mod vqs;

use crate::forest::Forest;
use crate::neon::OpTrace;
use crate::quant::{choose_scale, QForest, QuantConfig};

/// A prepared tree-ensemble inference engine.
///
/// Engines are immutable once built (`Send + Sync`), so the coordinator can
/// serve one model from many worker threads.
pub trait Engine: Send + Sync {
    /// Short display name, e.g. `"RS"` or `"qVQS"`.
    fn name(&self) -> String;

    /// Preferred batch width: the number of instances processed per SIMD
    /// block (1 for scalar engines). The coordinator's batcher pads/pools to
    /// a multiple of this.
    fn lanes(&self) -> usize;

    fn n_features(&self) -> usize;
    fn n_classes(&self) -> usize;

    /// Predict a row-major batch `[n × n_features]` into row-major scores
    /// `[n × n_classes]`. `out` must be exactly `n * n_classes` long.
    fn predict_batch(&self, x: &[f32], out: &mut [f32]);

    /// Convenience allocating wrapper.
    fn predict(&self, x: &[f32]) -> Vec<f32> {
        let n = x.len() / self.n_features();
        let mut out = vec![0f32; n * self.n_classes()];
        self.predict_batch(x, &mut out);
        out
    }

    /// Exact dynamic operation counts for evaluating this batch — consumed
    /// by the per-device cost model ([`crate::device`]). Runs *outside* the
    /// hot path. Default: no trace available.
    fn count_ops(&self, _x: &[f32]) -> OpTrace {
        OpTrace::default()
    }

    /// Resident model size in bytes (prepared data structures, excluding
    /// per-batch scratch). Grounds the paper's memory-footprint discussion
    /// (RapidScorer's epitomes/merging vs QuickScorer's full masks; int16
    /// halving, §5). Default: unknown (0).
    fn memory_bytes(&self) -> usize {
        0
    }
}

/// The five traversal strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    Naive,
    IfElse,
    Qs,
    Vqs,
    Rs,
}

impl EngineKind {
    pub const ALL: [EngineKind; 5] =
        [EngineKind::Rs, EngineKind::Vqs, EngineKind::Qs, EngineKind::IfElse, EngineKind::Naive];

    pub fn short(&self) -> &'static str {
        match self {
            EngineKind::Naive => "NA",
            EngineKind::IfElse => "IE",
            EngineKind::Qs => "QS",
            EngineKind::Vqs => "VQS",
            EngineKind::Rs => "RS",
        }
    }

    pub fn from_short(s: &str) -> Option<EngineKind> {
        let up = s.trim_start_matches('q').to_ascii_uppercase();
        Self::ALL.iter().copied().find(|k| k.short() == up)
    }
}

/// Numeric representation (paper §5: float vs 16-bit fixed point).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    F32,
    I16,
}

/// Build an engine for `forest`. For [`Precision::I16`], the forest is
/// quantized with `quant` (or an automatically chosen scale, §5).
///
/// Fails if the forest shape is unsupported (QuickScorer-family engines
/// require ≤ 64 leaves per tree).
pub fn build(
    kind: EngineKind,
    precision: Precision,
    forest: &Forest,
    quant: Option<QuantConfig>,
) -> anyhow::Result<Box<dyn Engine>> {
    let max_leaves = forest.max_leaves();
    if matches!(kind, EngineKind::Qs | EngineKind::Vqs | EngineKind::Rs) && max_leaves > 64 {
        anyhow::bail!(
            "{} requires <= 64 leaves per tree (forest has {max_leaves})",
            kind.short()
        );
    }
    Ok(match precision {
        Precision::F32 => match kind {
            EngineKind::Naive => Box::new(naive::NaiveEngine::new(forest)),
            EngineKind::IfElse => Box::new(ifelse::IfElseEngine::new(forest)),
            EngineKind::Qs => Box::new(quickscorer::QsEngine::new(forest)),
            EngineKind::Vqs => Box::new(vqs::VqsEngine::new(forest)),
            EngineKind::Rs => Box::new(rapidscorer::RsEngine::new(forest)),
        },
        Precision::I16 => {
            let cfg = quant.unwrap_or_else(|| choose_scale(forest, 1.0));
            let qf = QForest::from_forest(forest, cfg);
            match kind {
                EngineKind::Naive => Box::new(naive::QNaiveEngine::new(&qf)),
                EngineKind::IfElse => Box::new(ifelse::QIfElseEngine::new(&qf)),
                EngineKind::Qs => Box::new(quickscorer::QQsEngine::new(&qf)),
                EngineKind::Vqs => Box::new(vqs::QVqsEngine::new(&qf)),
                EngineKind::Rs => Box::new(rapidscorer::QRsEngine::new(&qf)),
            }
        }
    })
}

/// Build an engine with a thread budget: `threads <= 1` returns the plain
/// serial engine; otherwise the engine is wrapped in a
/// [`crate::exec::ParallelEngine`] running row-sharded over a work-stealing
/// pool (bit-exact with the serial engine — [`crate::exec::ShardPolicy::Exact`]).
pub fn build_parallel(
    kind: EngineKind,
    precision: Precision,
    forest: &Forest,
    quant: Option<QuantConfig>,
    threads: usize,
) -> anyhow::Result<Box<dyn Engine>> {
    if threads <= 1 {
        return build(kind, precision, forest, quant);
    }
    Ok(Box::new(crate::exec::ParallelEngine::from_forest(
        kind,
        precision,
        forest,
        quant,
        threads,
        crate::exec::ShardPolicy::Exact,
    )?))
}

/// All ten (kind, precision) combinations the paper benchmarks in Table 5.
pub fn all_variants() -> Vec<(EngineKind, Precision)> {
    let mut out = Vec::new();
    for p in [Precision::F32, Precision::I16] {
        for k in EngineKind::ALL {
            out.push((k, p));
        }
    }
    out
}

/// Display name for a variant, paper-style (`qRS` = quantized RapidScorer).
pub fn variant_name(kind: EngineKind, precision: Precision) -> String {
    match precision {
        Precision::F32 => kind.short().to_string(),
        Precision::I16 => format!("q{}", kind.short()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in EngineKind::ALL {
            assert_eq!(EngineKind::from_short(k.short()), Some(k));
        }
        assert_eq!(EngineKind::from_short("qRS"), Some(EngineKind::Rs));
        assert_eq!(EngineKind::from_short("nope"), None);
    }

    #[test]
    fn ten_variants() {
        assert_eq!(all_variants().len(), 10);
        assert_eq!(variant_name(EngineKind::Rs, Precision::I16), "qRS");
        assert_eq!(variant_name(EngineKind::Naive, Precision::F32), "NA");
    }
}
