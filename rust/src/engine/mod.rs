//! Inference engines — the paper's five traversal strategies in float32,
//! int16 and int8 fixed-point variants (DESIGN.md system S6).
//!
//! | engine | paper name      | strategy                                            |
//! |--------|-----------------|-----------------------------------------------------|
//! | NA     | Native/PRED     | while-loop over contiguous node arrays              |
//! | IE     | If-Else         | branchy per-node structure (codegen'd if-else analogue) |
//! | QS     | QuickScorer     | feature-ordered scan + bitvector masking (Alg. 1)   |
//! | VQS    | V-QuickScorer   | QS vectorized over v=4 (f32) / v=8 (i16) / v=16 (i8) instances (Alg. 2) |
//! | RS     | RapidScorer     | epitomes + node merging + byte-transposed leafidx, v=16 (Alg. 3/4) |
//!
//! Prefix `q` (e.g. `qRS`) marks the int16 fixed-point variant (§5); `q8`
//! (e.g. `q8VQS`) the int8 tier built on the same analysis with 8-bit
//! storage and a native-or-widened accumulator
//! ([`crate::quant::AccumMode`]). The int8 tier covers **all five**
//! traversal strategies; when the global §5 analysis would force widened
//! accumulation, [`build`] re-quantizes with per-tree leaf scales
//! ([`crate::quant::QForest::from_forest_per_tree`]) if that provably
//! restores a native i8 accumulator.
//! Prefix `fl` (e.g. `flVQS`) marks the FLInt carrier tier
//! ([`crate::quant::flint`]): threshold compares move to the integer pipe
//! via an order-preserving f32 → i32 bit trick while leaves stay f32, so
//! outputs are bit-identical to the float variants — a virtual precision,
//! not a quantization.
//! All engines implement [`Engine`] and must agree with the naive reference
//! ([`crate::forest::Forest::predict_batch`] /
//! [`crate::quant::QForest::predict_batch`] over the same quantized
//! forest) — enforced by the integration and property test suites.

pub mod common;
pub mod early_exit;
pub mod ifelse;
pub mod naive;
pub mod quickscorer;
pub mod rapidscorer;
pub mod tensor;
pub mod vqs;

use crate::forest::Forest;
use crate::neon::OpTrace;
use crate::quant::{
    choose_scale, choose_scale_i16_per_tree, quantize_i8_auto, QForest, QuantConfig,
};

pub use early_exit::{build_early_exit, EarlyExitEngine, EarlyExitMode};

/// A prepared tree-ensemble inference engine.
///
/// Engines are immutable once built (`Send + Sync`), so the coordinator can
/// serve one model from many worker threads.
pub trait Engine: Send + Sync {
    /// Short display name, e.g. `"RS"` or `"qVQS"`.
    fn name(&self) -> String;

    /// Preferred batch width: the number of instances processed per SIMD
    /// block (1 for scalar engines). The coordinator's batcher pads/pools to
    /// a multiple of this.
    fn lanes(&self) -> usize;

    fn n_features(&self) -> usize;
    fn n_classes(&self) -> usize;

    /// Predict a row-major batch `[n × n_features]` into row-major scores
    /// `[n × n_classes]`. `out` must be exactly `n * n_classes` long.
    fn predict_batch(&self, x: &[f32], out: &mut [f32]);

    /// Convenience allocating wrapper.
    fn predict(&self, x: &[f32]) -> Vec<f32> {
        let n = x.len() / self.n_features();
        let mut out = vec![0f32; n * self.n_classes()];
        self.predict_batch(x, &mut out);
        out
    }

    /// Exact dynamic operation counts for evaluating this batch — consumed
    /// by the per-device cost model ([`crate::device`]). Runs *outside* the
    /// hot path. Default: no trace available.
    fn count_ops(&self, _x: &[f32]) -> OpTrace {
        OpTrace::default()
    }

    /// Resident model size in bytes (prepared data structures, excluding
    /// per-batch scratch). Grounds the paper's memory-footprint discussion
    /// (RapidScorer's epitomes/merging vs QuickScorer's full masks; int16
    /// halving, §5). Default: unknown (0).
    fn memory_bytes(&self) -> usize {
        0
    }

    /// Cumulative `(rows scored, tree evaluations)` since build, for
    /// engines whose per-row cost varies ([`EarlyExitEngine`]). The exec
    /// feedback loop samples this around each chunk to learn the cost
    /// distribution ([`crate::exec::Feedback::record_trees`]). Default:
    /// fixed-cost engine, no counters.
    fn cost_counters(&self) -> Option<(u64, u64)> {
        None
    }
}

/// The five traversal strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    Naive,
    IfElse,
    Qs,
    Vqs,
    Rs,
}

impl EngineKind {
    pub const ALL: [EngineKind; 5] =
        [EngineKind::Rs, EngineKind::Vqs, EngineKind::Qs, EngineKind::IfElse, EngineKind::Naive];

    pub fn short(&self) -> &'static str {
        match self {
            EngineKind::Naive => "NA",
            EngineKind::IfElse => "IE",
            EngineKind::Qs => "QS",
            EngineKind::Vqs => "VQS",
            EngineKind::Rs => "RS",
        }
    }

    pub fn from_short(s: &str) -> Option<EngineKind> {
        let bare = s
            .strip_prefix("fl")
            .or_else(|| s.strip_prefix("q8"))
            .or_else(|| s.strip_prefix('q'))
            .unwrap_or(s);
        let up = bare.to_ascii_uppercase();
        Self::ALL.iter().copied().find(|k| k.short() == up)
    }
}

/// Numeric representation: float, the paper's 16-bit fixed point (§5), the
/// int8 tier (v = 16, half the model bytes again), or the FLInt carrier
/// tier — f32 semantics carried on i32 compares ([`crate::quant::flint`]),
/// bit-identical to [`Precision::F32`] by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    F32,
    I16,
    I8,
    /// Virtual tier: thresholds/features FLInt-encoded to i32 for the
    /// compare, leaves and accumulation unchanged f32.
    F32Flint,
}

impl Precision {
    /// CLI name (`--precision {f32,i16,i8,flint}`).
    pub fn name(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::I16 => "i16",
            Precision::I8 => "i8",
            Precision::F32Flint => "flint",
        }
    }

    pub fn from_name(s: &str) -> Option<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "float" => Some(Precision::F32),
            "i16" | "int16" => Some(Precision::I16),
            "i8" | "int8" => Some(Precision::I8),
            "flint" => Some(Precision::F32Flint),
            _ => None,
        }
    }

    /// Bytes per stored scalar (threshold / leaf payload).
    pub fn scalar_bytes(&self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::I16 => 2,
            Precision::I8 => 1,
            // i32 thresholds, f32 leaves — 4 bytes either way.
            Precision::F32Flint => 4,
        }
    }
}

/// Build an engine for `forest`. For [`Precision::I16`], the forest is
/// quantized with `quant` (or an automatically chosen scale, §5).
///
/// Fails if the forest shape is unsupported (QuickScorer-family engines
/// require ≤ 64 leaves per tree).
/// The QuickScorer-family shape constraint, shared by every build path so
/// it cannot drift between them.
fn ensure_leaf_capacity(kind: EngineKind, forest: &Forest) -> anyhow::Result<()> {
    let max_leaves = forest.max_leaves();
    if matches!(kind, EngineKind::Qs | EngineKind::Vqs | EngineKind::Rs) && max_leaves > 64 {
        anyhow::bail!(
            "{} requires <= 64 leaves per tree (forest has {max_leaves})",
            kind.short()
        );
    }
    Ok(())
}

pub fn build(
    kind: EngineKind,
    precision: Precision,
    forest: &Forest,
    quant: Option<QuantConfig>,
) -> anyhow::Result<Box<dyn Engine>> {
    ensure_leaf_capacity(kind, forest)?;
    Ok(match precision {
        Precision::F32 => match kind {
            EngineKind::Naive => Box::new(naive::NaiveEngine::new(forest)),
            EngineKind::IfElse => Box::new(ifelse::IfElseEngine::new(forest)),
            EngineKind::Qs => Box::new(quickscorer::QsEngine::new(forest)),
            EngineKind::Vqs => Box::new(vqs::VqsEngine::new(forest)),
            EngineKind::Rs => Box::new(rapidscorer::RsEngine::new(forest)),
        },
        // FLInt carrier: no quantization happens — `quant` is ignored like
        // it is for plain f32.
        Precision::F32Flint => match kind {
            EngineKind::Naive => Box::new(naive::FlintNaiveEngine::new(forest)),
            EngineKind::IfElse => Box::new(ifelse::FlintIfElseEngine::new(forest)),
            EngineKind::Qs => Box::new(quickscorer::FlintQsEngine::new(forest)),
            EngineKind::Vqs => Box::new(vqs::FlintVqsEngine::new(forest)),
            EngineKind::Rs => Box::new(rapidscorer::FlintRsEngine::new(forest)),
        },
        Precision::I16 => {
            let cfg = quant.unwrap_or_else(|| choose_scale(forest, 1.0));
            let qf = QForest::from_forest(forest, cfg);
            match kind {
                EngineKind::Naive => Box::new(naive::QNaiveEngine::new(&qf)),
                EngineKind::IfElse => Box::new(ifelse::QIfElseEngine::new(&qf)),
                EngineKind::Qs => Box::new(quickscorer::QQsEngine::new(&qf)),
                EngineKind::Vqs => Box::new(vqs::QVqsEngine::new(&qf)),
                EngineKind::Rs => Box::new(rapidscorer::QRsEngine::new(&qf)),
            }
        }
        Precision::I8 => {
            // A caller-supplied i16-carrier config contributes its scale
            // (global scaling, exactly as given); otherwise redo the §5
            // analysis for 8-bit storage. An i16-tier scale (e.g. 2^15)
            // would silently saturate every i8 payload — reject it instead
            // of serving garbage.
            let qf = match quant {
                Some(c) => {
                    anyhow::ensure!(
                        c.scale <= i8::MAX as f32,
                        "quant scale {} saturates int8 storage (max {}); pass None \
                         to let choose_scale_i8 pick an 8-bit scale",
                        c.scale,
                        i8::MAX
                    );
                    QForest::<i8>::from_forest(forest, QuantConfig::<i8>::new(c.scale))
                }
                // Global scaling, upgraded to per-tree leaf scales exactly
                // when that provably restores a native i8 accumulator —
                // the policy lives in `quant` so tests can construct the
                // matching reference (quant module docs / DESIGN.md §6).
                None => quantize_i8_auto(forest, 1.0),
            };
            match kind {
                EngineKind::Naive => Box::new(naive::QNaiveEngine::new(&qf)),
                EngineKind::IfElse => Box::new(ifelse::QIfElseEngine::new(&qf)),
                EngineKind::Qs => Box::new(quickscorer::QQsEngine::new(&qf)),
                EngineKind::Vqs => Box::new(vqs::QVqs8Engine::new(&qf)),
                EngineKind::Rs => Box::new(rapidscorer::QRs8Engine::new(&qf)),
            }
        }
    })
}

/// Build an i16 engine with **per-tree leaf scales**
/// ([`crate::quant::choose_scale_i16_per_tree`]): tree `t`'s leaves are
/// stored at `s·2^{k_t}` and rounding-shifted at sum time, so boosted
/// forests with wildly uneven leaf magnitudes keep per-tree resolution a
/// single global scale would floor away. The shift machinery is
/// tier-generic (every quantized engine applies `tree_shifts`); this is
/// the i16 build path the ROADMAP noted was missing. Ranked by the
/// selector as the `+pt`-suffixed candidate and deployable through
/// `Server::deploy_auto`.
pub fn build_i16_per_tree(kind: EngineKind, forest: &Forest) -> anyhow::Result<Box<dyn Engine>> {
    ensure_leaf_capacity(kind, forest)?;
    let qf = QForest::<i16>::from_forest_per_tree(forest, choose_scale_i16_per_tree(forest, 1.0));
    Ok(match kind {
        EngineKind::Naive => Box::new(naive::QNaiveEngine::new(&qf)),
        EngineKind::IfElse => Box::new(ifelse::QIfElseEngine::new(&qf)),
        EngineKind::Qs => Box::new(quickscorer::QQsEngine::new(&qf)),
        EngineKind::Vqs => Box::new(vqs::QVqsEngine::new(&qf)),
        EngineKind::Rs => Box::new(rapidscorer::QRsEngine::new(&qf)),
    })
}

/// Build an engine with a thread budget: `threads <= 1` returns the plain
/// serial engine; otherwise the engine is wrapped in a
/// [`crate::exec::ParallelEngine`] running row-sharded over a work-stealing
/// pool (bit-exact with the serial engine — [`crate::exec::ShardPolicy::Exact`]).
///
/// This is the *standalone* path (CLI `predict`, selector measurement,
/// benches): the wrapper owns a private pool. The serving path does not use
/// it — `Server` deployments build the serial engine and let the fused
/// batcher shard batches onto the server-shared pool with the same
/// lane-aligned plans (see `coordinator::batcher`).
pub fn build_parallel(
    kind: EngineKind,
    precision: Precision,
    forest: &Forest,
    quant: Option<QuantConfig>,
    threads: usize,
) -> anyhow::Result<Box<dyn Engine>> {
    if threads <= 1 {
        return build(kind, precision, forest, quant);
    }
    Ok(Box::new(crate::exec::ParallelEngine::from_forest(
        kind,
        precision,
        forest,
        quant,
        threads,
        crate::exec::ShardPolicy::Exact,
    )?))
}

/// All ten (kind, precision) combinations the paper benchmarks in Table 5.
pub fn all_variants() -> Vec<(EngineKind, Precision)> {
    let mut out = Vec::new();
    for p in [Precision::F32, Precision::I16] {
        for k in EngineKind::ALL {
            out.push((k, p));
        }
    }
    out
}

/// The int8-tier variants — all five traversal strategies at 8-bit
/// storage.
pub fn i8_variants() -> Vec<(EngineKind, Precision)> {
    EngineKind::ALL.iter().map(|&k| (k, Precision::I8)).collect()
}

/// The FLInt carrier variants — all five traversal strategies with
/// integer threshold compares and bit-exact f32 outputs.
pub fn flint_variants() -> Vec<(EngineKind, Precision)> {
    EngineKind::ALL.iter().map(|&k| (k, Precision::F32Flint)).collect()
}

/// The paper's ten variants plus the int8 and FLInt tiers — the selector
/// candidate set. Tests and the selector derive expected candidate counts
/// from this registry (`all_variants_with_i8().len()`), never from
/// literals: the count has gone stale twice as tiers grew.
pub fn all_variants_with_i8() -> Vec<(EngineKind, Precision)> {
    let mut out = all_variants();
    out.extend(i8_variants());
    out.extend(flint_variants());
    out
}

/// Display name for a variant, paper-style (`qRS` = quantized RapidScorer,
/// `q8VQS` = int8 V-QuickScorer, `flRS` = FLInt RapidScorer).
pub fn variant_name(kind: EngineKind, precision: Precision) -> String {
    match precision {
        Precision::F32 => kind.short().to_string(),
        Precision::I16 => format!("q{}", kind.short()),
        Precision::I8 => format!("q8{}", kind.short()),
        Precision::F32Flint => format!("fl{}", kind.short()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in EngineKind::ALL {
            assert_eq!(EngineKind::from_short(k.short()), Some(k));
        }
        assert_eq!(EngineKind::from_short("qRS"), Some(EngineKind::Rs));
        assert_eq!(EngineKind::from_short("q8VQS"), Some(EngineKind::Vqs));
        assert_eq!(EngineKind::from_short("q8na"), Some(EngineKind::Naive));
        assert_eq!(EngineKind::from_short("flVQS"), Some(EngineKind::Vqs));
        assert_eq!(EngineKind::from_short("flqs"), Some(EngineKind::Qs));
        assert_eq!(EngineKind::from_short("nope"), None);
    }

    #[test]
    fn ten_variants() {
        assert_eq!(all_variants().len(), 10);
        assert_eq!(variant_name(EngineKind::Rs, Precision::I16), "qRS");
        assert_eq!(variant_name(EngineKind::Naive, Precision::F32), "NA");
    }

    #[test]
    fn i8_variant_set() {
        // The registry IS the tier × engine matrix: every tier covers all
        // five engine families, and the full set is their disjoint union —
        // derived, never a literal.
        assert_eq!(i8_variants().len(), EngineKind::ALL.len());
        assert_eq!(flint_variants().len(), EngineKind::ALL.len());
        assert_eq!(
            all_variants_with_i8().len(),
            all_variants().len() + i8_variants().len() + flint_variants().len()
        );
        assert_eq!(variant_name(EngineKind::Vqs, Precision::I8), "q8VQS");
        assert_eq!(variant_name(EngineKind::Rs, Precision::I8), "q8RS");
        assert_eq!(variant_name(EngineKind::IfElse, Precision::I8), "q8IE");
        assert_eq!(variant_name(EngineKind::Vqs, Precision::F32Flint), "flVQS");
        assert_eq!(variant_name(EngineKind::Naive, Precision::F32Flint), "flNA");
        // Every variant name round-trips back to its kind.
        for (kind, p) in all_variants_with_i8() {
            assert_eq!(EngineKind::from_short(&variant_name(kind, p)), Some(kind));
        }
    }

    #[test]
    fn precision_names_roundtrip() {
        for p in [Precision::F32, Precision::I16, Precision::I8, Precision::F32Flint] {
            assert_eq!(Precision::from_name(p.name()), Some(p));
        }
        assert_eq!(Precision::from_name("int8"), Some(Precision::I8));
        assert_eq!(Precision::from_name("bf16"), None);
        assert_eq!(Precision::I8.scalar_bytes(), 1);
        assert_eq!(Precision::F32Flint.scalar_bytes(), 4);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn i8_build_paths() {
        use crate::data::DatasetId;
        use crate::forest::builder::{train_random_forest, RfParams, TreeParams};
        let ds = DatasetId::Magic.generate(400, 88);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 6,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        for (kind, p) in i8_variants() {
            let e = build(kind, p, &f, None).unwrap();
            assert!(e.name().starts_with("q8"), "{}", e.name());
        }
        // An i16-tier carrier scale must be rejected, not silently saturated.
        let carrier: QuantConfig = QuantConfig::new(32768.0);
        assert!(build(EngineKind::Naive, Precision::I8, &f, Some(carrier)).is_err());
        assert!(build(EngineKind::Naive, Precision::I8, &f, Some(QuantConfig::new(64.0))).is_ok());
    }

    /// The FLInt build path: every engine family builds under
    /// `Precision::F32Flint` and is bit-identical to its f32 twin — the
    /// tier's defining contract.
    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn flint_build_paths_bit_identical_to_f32() {
        use crate::data::DatasetId;
        use crate::forest::builder::{train_random_forest, RfParams, TreeParams};
        let ds = DatasetId::Magic.generate(400, 21);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 6,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        for (kind, p) in flint_variants() {
            let e = build(kind, p, &f, None).unwrap();
            let twin = build(kind, Precision::F32, &f, None).unwrap();
            assert_eq!(e.name(), variant_name(kind, p));
            assert!(e.name().starts_with("fl"), "{}", e.name());
            assert_eq!(e.predict(&ds.x), twin.predict(&ds.x), "{}", e.name());
        }
    }

    /// The i16 per-tree build path: every engine family agrees bit-for-bit
    /// with the per-tree i16 reference on a forest with genuinely uneven
    /// leaf magnitudes (non-zero shifts engaged).
    #[test]
    fn i16_per_tree_engines_match_reference() {
        use crate::forest::{Task, Tree};
        let mut f = Forest::new(2, 1, Task::Ranking);
        // One dominant tree plus tiny-correction trees — the regime the
        // per-tree path exists for.
        f.trees.push(Tree::leaf(vec![40.0]));
        for i in 0..6 {
            f.trees.push(Tree::leaf(vec![0.001 * (1.0 + i as f32)]));
        }
        let qf = QForest::<i16>::from_forest_per_tree(&f, choose_scale_i16_per_tree(&f, 1.0));
        assert!(qf.has_per_tree_scales(), "shifts never engaged");
        let x = [0.3, 0.7, 0.9, 0.1];
        let want = qf.predict_batch(&x);
        for kind in EngineKind::ALL {
            let e = build_i16_per_tree(kind, &f).unwrap();
            assert_eq!(e.name(), variant_name(kind, Precision::I16));
            assert_eq!(
                e.predict(&x),
                want,
                "{} per-tree i16 disagrees with the reference",
                kind.short()
            );
        }
    }

    /// `build` upgrades to per-tree leaf scales exactly when the global §5
    /// analysis widened and per-tree provably restores a native
    /// accumulator — and all five engines then agree with the per-tree
    /// reference.
    #[test]
    fn i8_build_upgrades_widened_forests_to_per_tree_native() {
        use crate::forest::{Task, Tree};
        use crate::quant::{choose_scale_i8, choose_scale_i8_per_tree};
        // 60 constant trees, max |leaf| = 1/30: global scaling widens
        // (floor M = 60 > native bound 33); per-tree lands Native.
        let mut f = Forest::new(2, 1, Task::Ranking);
        for i in 0..60 {
            f.trees.push(Tree::leaf(vec![(1.0 + (i % 3) as f32) / 90.0]));
        }
        let qf_global = QForest::<i8>::from_forest(&f, choose_scale_i8(&f, 1.0));
        assert_eq!(qf_global.accum_mode(), crate::quant::AccumMode::Widened);
        let qf_pt =
            QForest::<i8>::from_forest_per_tree(&f, choose_scale_i8_per_tree(&f, 1.0));
        assert_eq!(qf_pt.accum_mode(), crate::quant::AccumMode::Native);
        let want = qf_pt.predict_batch(&[0.3, 0.7]);
        for (kind, p) in i8_variants() {
            let e = build(kind, p, &f, None).unwrap();
            assert_eq!(
                e.predict(&[0.3, 0.7]),
                want,
                "{} did not take the per-tree path",
                variant_name(kind, p)
            );
        }
    }
}
