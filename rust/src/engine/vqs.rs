//! VQS — V-QuickScorer (Lucchese et al. 2016) on ARM NEON (paper §4.1, §5.1).
//!
//! The mask-computation loop of QuickScorer is vectorized over instances:
//! one NEON register holds the same feature of `v` instances, one compare
//! (`vcgtq_f32` / `vcgtq_s16`) tests them against the node threshold, and the
//! node's bitvector mask is applied per-lane with `vandq` + `vbslq`
//! (Algorithm 2 lines 9–16). With NEON's 128-bit registers:
//!
//! * float32 → **v = 4** instances;
//! * int16 fixed-point → **v = 8** instances (§5.1) — masks are widened to
//!   the 32/64-bit bitvector lanes via the `vget_low/high` + `vmovl` chain.
//!
//! The feature scan `break`s only when *every* lane is a true node
//! (`mask == 0`), so vectorized traversal can visit more nodes than scalar
//! QS for divergent instances — the price of lockstep execution.

use super::common::QsModel;
use super::Engine;
use crate::forest::Forest;
use crate::neon::*;
use crate::quant::{AccumMode, QForest, QuantConfig};

/// Transpose `v` rows of `x` (row-major, `d` columns) starting at `base`
/// into feature-major `xt[k*v + lane]`. Rows beyond `n` replicate row
/// `n - 1` (tail padding; outputs for padded lanes are discarded).
fn transpose_block<T: Copy>(x: &[T], d: usize, n: usize, base: usize, v: usize, xt: &mut [T]) {
    for lane in 0..v {
        let i = (base + lane).min(n - 1);
        let row = &x[i * d..(i + 1) * d];
        for k in 0..d {
            xt[k * v + lane] = row[k];
        }
    }
}

// ---------------------------------------------------------------------------
// Float VQS (v = 4)
// ---------------------------------------------------------------------------

/// Float V-QuickScorer.
pub struct VqsEngine {
    m: QsModel<f32, f32>,
}

impl VqsEngine {
    pub fn new(f: &Forest) -> VqsEngine {
        VqsEngine { m: QsModel::from_forest(f) }
    }
}

pub(crate) const V_F32: usize = 4;

impl Engine for VqsEngine {
    fn name(&self) -> String {
        "VQS".into()
    }

    fn lanes(&self) -> usize {
        V_F32
    }

    fn n_features(&self) -> usize {
        self.m.n_features
    }

    fn n_classes(&self) -> usize {
        self.m.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let m = &self.m;
        let d = m.n_features;
        let n = x.len() / d;
        let mut xt = vec![0f32; d * V_F32];
        // leafidx: per tree, 4 lanes of u32 (L<=32) or u64 (L<=64).
        let mut idx32 = vec![U32x4([0; 4]); if m.leaf_words == 32 { m.n_trees } else { 0 }];
        let mut idx64 = vec![[U64x2([0; 2]); 2]; if m.leaf_words == 64 { m.n_trees } else { 0 }];

        let mut base = 0usize;
        while base < n {
            transpose_block(x, d, n, base, V_F32, &mut xt);
            if m.leaf_words == 32 {
                self.block32(&xt, &mut idx32, out, base, n);
            } else {
                self.block64(&xt, &mut idx64, out, base, n);
            }
            base += V_F32;
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        vqs_trace_f32(&self.m, x)
    }

    fn memory_bytes(&self) -> usize {
        self.m.memory_bytes()
    }
}

impl VqsEngine {
    /// Mask + score computation for one block of 4 instances, L ≤ 32.
    fn block32(&self, xt: &[f32], leafidx: &mut [U32x4], out: &mut [f32], base: usize, n: usize) {
        let m = &self.m;
        leafidx.fill(vdupq_n_u32(u32::MAX));
        // {Mask Computation} — Alg. 2 lines 7-21.
        for k in 0..m.n_features {
            let r = m.feature_range(k);
            if r.is_empty() {
                continue;
            }
            let xv = vld1q_f32(&xt[k * V_F32..]);
            let ths = &m.thresholds[r.clone()];
            let trees = &m.tree_ids[r.clone()];
            let masks = &m.masks[r];
            for ((&t, &tree), &mk) in ths.iter().zip(trees).zip(masks) {
                let gamma = vdupq_n_f32(t);
                let mask = vcgtq_f32(xv, gamma);
                if vmaxvq_u32(mask) == 0 {
                    break;
                }
                let tree = tree as usize;
                let mvec = vdupq_n_u32(mk as u32);
                let b = leafidx[tree];
                let y = vandq_u32(mvec, b);
                leafidx[tree] = vbslq_u32(mask, y, b);
            }
        }
        self.score32(leafidx, out, base, n);
    }

    /// Score computation (Alg. 2 lines 22-31) for L ≤ 32.
    fn score32(&self, leafidx: &[U32x4], out: &mut [f32], base: usize, n: usize) {
        let m = &self.m;
        let c = m.n_classes;
        // Per-class SIMD accumulators over the 4 lanes (§4.2 transposed
        // score layout).
        let mut acc = vec![F32x4([0.0; 4]); c];
        for (ti, idx) in leafidx.iter().enumerate() {
            // Leaf-row offsets once per tree.
            let mut offs = [0usize; V_F32];
            for (lane, o) in offs.iter_mut().enumerate() {
                let j = vgetq_lane_u32(*idx, lane).trailing_zeros() as usize;
                *o = (ti * m.leaf_words + j) * c;
            }
            for (cls, a) in acc.iter_mut().enumerate() {
                let vals = F32x4([
                    m.leaf_values[offs[0] + cls],
                    m.leaf_values[offs[1] + cls],
                    m.leaf_values[offs[2] + cls],
                    m.leaf_values[offs[3] + cls],
                ]);
                *a = vaddq_f32(*a, vals);
            }
        }
        write_scores_f32(&acc, &m.base_f32, out, base, n, c);
    }

    /// Mask + score computation for one block of 4 instances, L ≤ 64:
    /// the u32 compare mask is widened to two u64-lane registers.
    fn block64(
        &self,
        xt: &[f32],
        leafidx: &mut [[U64x2; 2]],
        out: &mut [f32],
        base: usize,
        n: usize,
    ) {
        let m = &self.m;
        leafidx.fill([vdupq_n_u64(u64::MAX); 2]);
        for k in 0..m.n_features {
            let r = m.feature_range(k);
            if r.is_empty() {
                continue;
            }
            let xv = vld1q_f32(&xt[k * V_F32..]);
            let ths = &m.thresholds[r.clone()];
            let trees = &m.tree_ids[r.clone()];
            let masks = &m.masks[r];
            for ((&t, &tree), &mk) in ths.iter().zip(trees).zip(masks) {
                let gamma = vdupq_n_f32(t);
                let mask = vcgtq_f32(xv, gamma);
                if vmaxvq_u32(mask) == 0 {
                    break;
                }
                // Widen 4×u32 mask → 2 × (2×u64) — the §5.1 extension chain.
                let mlo = vmovl_mask_u32(vget_low_u32(mask));
                let mhi = vmovl_mask_u32(vget_high_u32(mask));
                let tree = tree as usize;
                let mvec = vdupq_n_u64(mk);
                let [b0, b1] = leafidx[tree];
                let y0 = vandq_u64(mvec, b0);
                let y1 = vandq_u64(mvec, b1);
                leafidx[tree] = [vbslq_u64(mlo, y0, b0), vbslq_u64(mhi, y1, b1)];
            }
        }
        // Score computation.
        let c = m.n_classes;
        let mut acc = vec![F32x4([0.0; 4]); c];
        for (ti, regs) in leafidx.iter().enumerate() {
            let mut js = [0usize; 4];
            for lane in 0..2 {
                js[lane] = vgetq_lane_u64(regs[0], lane).trailing_zeros() as usize;
                js[2 + lane] = vgetq_lane_u64(regs[1], lane).trailing_zeros() as usize;
            }
            for cls in 0..c {
                let mut vals = F32x4([0.0; 4]);
                for lane in 0..V_F32 {
                    vals = vsetq_lane_f32(self.m.leaf_row(ti, js[lane])[cls], vals, lane);
                }
                acc[cls] = vaddq_f32(acc[cls], vals);
            }
        }
        write_scores_f32(&acc, &m.base_f32, out, base, n, c);
    }
}

fn write_scores_f32(
    acc: &[F32x4],
    base_score: &[f32],
    out: &mut [f32],
    base: usize,
    n: usize,
    c: usize,
) {
    for lane in 0..V_F32 {
        let i = base + lane;
        if i >= n {
            break; // padded tail lane
        }
        for cls in 0..c {
            out[i * c + cls] = acc[cls].0[lane] + base_score[cls];
        }
    }
}

// ---------------------------------------------------------------------------
// FLInt VQS (v = 4, integer compares with exact f32 semantics)
// ---------------------------------------------------------------------------

/// FLInt V-QuickScorer (flVQS): [`VqsEngine`] with the threshold compare
/// moved to the integer SIMD pipe. Thresholds are FLInt-encoded i32s
/// ([`crate::quant::flint`]), the batch is encoded once with the `>`-style
/// map (NaN → `i32::MIN`), and `vcgtq_s32` replaces `vcgtq_f32` — the mask
/// register and everything downstream (widen, AND, select, f32 leaf gather,
/// `vaddq_f32`) are byte-for-byte the float engine's, so outputs are
/// **bit-identical** to [`VqsEngine`].
pub struct FlintVqsEngine {
    m: QsModel<i32, f32>,
}

impl FlintVqsEngine {
    pub fn new(f: &Forest) -> FlintVqsEngine {
        FlintVqsEngine { m: QsModel::from_forest(f).to_flint() }
    }
}

impl Engine for FlintVqsEngine {
    fn name(&self) -> String {
        "flVQS".into()
    }

    fn lanes(&self) -> usize {
        V_F32
    }

    fn n_features(&self) -> usize {
        self.m.n_features
    }

    fn n_classes(&self) -> usize {
        self.m.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let m = &self.m;
        let d = m.n_features;
        let n = x.len() / d;
        let mut ex = Vec::with_capacity(x.len());
        crate::quant::flint::encode_batch_gt(x, &mut ex);
        let mut xt = vec![0i32; d * V_F32];
        let mut idx32 = vec![U32x4([0; 4]); if m.leaf_words == 32 { m.n_trees } else { 0 }];
        let mut idx64 = vec![[U64x2([0; 2]); 2]; if m.leaf_words == 64 { m.n_trees } else { 0 }];

        let mut base = 0usize;
        while base < n {
            transpose_block(&ex, d, n, base, V_F32, &mut xt);
            if m.leaf_words == 32 {
                self.block32(&xt, &mut idx32, out, base, n);
            } else {
                self.block64(&xt, &mut idx64, out, base, n);
            }
            base += V_F32;
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        vqs_trace_flint(&self.m, x)
    }

    fn memory_bytes(&self) -> usize {
        self.m.memory_bytes()
    }
}

impl FlintVqsEngine {
    /// Mask + score computation for one block of 4 instances, L ≤ 32 —
    /// the float `block32` with `vcgtq_s32` in place of `vcgtq_f32`.
    fn block32(&self, xt: &[i32], leafidx: &mut [U32x4], out: &mut [f32], base: usize, n: usize) {
        let m = &self.m;
        leafidx.fill(vdupq_n_u32(u32::MAX));
        for k in 0..m.n_features {
            let r = m.feature_range(k);
            if r.is_empty() {
                continue;
            }
            let xv = vld1q_s32(&xt[k * V_F32..]);
            let ths = &m.thresholds[r.clone()];
            let trees = &m.tree_ids[r.clone()];
            let masks = &m.masks[r];
            for ((&t, &tree), &mk) in ths.iter().zip(trees).zip(masks) {
                let gamma = vdupq_n_s32(t);
                let mask = vcgtq_s32(xv, gamma);
                if vmaxvq_u32(mask) == 0 {
                    break;
                }
                let tree = tree as usize;
                let mvec = vdupq_n_u32(mk as u32);
                let b = leafidx[tree];
                let y = vandq_u32(mvec, b);
                leafidx[tree] = vbslq_u32(mask, y, b);
            }
        }
        self.score32(leafidx, out, base, n);
    }

    /// Score computation for L ≤ 32 — identical to the float engine's.
    fn score32(&self, leafidx: &[U32x4], out: &mut [f32], base: usize, n: usize) {
        let m = &self.m;
        let c = m.n_classes;
        let mut acc = vec![F32x4([0.0; 4]); c];
        for (ti, idx) in leafidx.iter().enumerate() {
            let mut offs = [0usize; V_F32];
            for (lane, o) in offs.iter_mut().enumerate() {
                let j = vgetq_lane_u32(*idx, lane).trailing_zeros() as usize;
                *o = (ti * m.leaf_words + j) * c;
            }
            for (cls, a) in acc.iter_mut().enumerate() {
                let vals = F32x4([
                    m.leaf_values[offs[0] + cls],
                    m.leaf_values[offs[1] + cls],
                    m.leaf_values[offs[2] + cls],
                    m.leaf_values[offs[3] + cls],
                ]);
                *a = vaddq_f32(*a, vals);
            }
        }
        write_scores_f32(&acc, &m.base_f32, out, base, n, c);
    }

    /// L ≤ 64 — the float `block64` with integer compares; the u32 mask
    /// widens through the same `vmovl_mask_u32` chain.
    fn block64(
        &self,
        xt: &[i32],
        leafidx: &mut [[U64x2; 2]],
        out: &mut [f32],
        base: usize,
        n: usize,
    ) {
        let m = &self.m;
        leafidx.fill([vdupq_n_u64(u64::MAX); 2]);
        for k in 0..m.n_features {
            let r = m.feature_range(k);
            if r.is_empty() {
                continue;
            }
            let xv = vld1q_s32(&xt[k * V_F32..]);
            let ths = &m.thresholds[r.clone()];
            let trees = &m.tree_ids[r.clone()];
            let masks = &m.masks[r];
            for ((&t, &tree), &mk) in ths.iter().zip(trees).zip(masks) {
                let gamma = vdupq_n_s32(t);
                let mask = vcgtq_s32(xv, gamma);
                if vmaxvq_u32(mask) == 0 {
                    break;
                }
                let mlo = vmovl_mask_u32(vget_low_u32(mask));
                let mhi = vmovl_mask_u32(vget_high_u32(mask));
                let tree = tree as usize;
                let mvec = vdupq_n_u64(mk);
                let [b0, b1] = leafidx[tree];
                let y0 = vandq_u64(mvec, b0);
                let y1 = vandq_u64(mvec, b1);
                leafidx[tree] = [vbslq_u64(mlo, y0, b0), vbslq_u64(mhi, y1, b1)];
            }
        }
        let c = m.n_classes;
        let mut acc = vec![F32x4([0.0; 4]); c];
        for (ti, regs) in leafidx.iter().enumerate() {
            let mut js = [0usize; 4];
            for lane in 0..2 {
                js[lane] = vgetq_lane_u64(regs[0], lane).trailing_zeros() as usize;
                js[2 + lane] = vgetq_lane_u64(regs[1], lane).trailing_zeros() as usize;
            }
            for cls in 0..c {
                let mut vals = F32x4([0.0; 4]);
                for lane in 0..V_F32 {
                    vals = vsetq_lane_f32(self.m.leaf_row(ti, js[lane])[cls], vals, lane);
                }
                acc[cls] = vaddq_f32(acc[cls], vals);
            }
        }
        write_scores_f32(&acc, &m.base_f32, out, base, n, c);
    }
}

// ---------------------------------------------------------------------------
// Quantized VQS (v = 8, int16)
// ---------------------------------------------------------------------------

/// Quantized V-QuickScorer: 8 instances per block (§5.1).
pub struct QVqsEngine {
    m: QsModel<i16, i16>,
    config: QuantConfig,
}

pub(crate) const V_I16: usize = 8;

impl QVqsEngine {
    pub fn new(qf: &QForest) -> QVqsEngine {
        QVqsEngine { m: QsModel::from_qforest(qf), config: qf.config }
    }
}

impl Engine for QVqsEngine {
    fn name(&self) -> String {
        "qVQS".into()
    }

    fn lanes(&self) -> usize {
        V_I16
    }

    fn n_features(&self) -> usize {
        self.m.n_features
    }

    fn n_classes(&self) -> usize {
        self.m.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let m = &self.m;
        let d = m.n_features;
        let c = m.n_classes;
        let n = x.len() / d;
        let mut qx = Vec::with_capacity(x.len());
        self.config.q_slice(x, &mut qx);
        let mut xt = vec![0i16; d * V_I16];
        let mut idx32 = vec![[U32x4([0; 4]); 2]; if m.leaf_words == 32 { m.n_trees } else { 0 }];
        let mut idx64 = vec![[U64x2([0; 2]); 4]; if m.leaf_words == 64 { m.n_trees } else { 0 }];

        let mut base = 0usize;
        while base < n {
            transpose_block(&qx, d, n, base, V_I16, &mut xt);
            if m.leaf_words == 32 {
                self.block32(&xt, &mut idx32, out, base, n, c);
            } else {
                self.block64(&xt, &mut idx64, out, base, n, c);
            }
            base += V_I16;
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        let mut qx = Vec::new();
        self.config.q_slice(x, &mut qx);
        let d = self.m.n_features;
        let n = x.len() / d;
        let mut tr = vqs_trace_i16(&self.m, &qx, n);
        tr.scalar_fp += (n * d) as u64 * 2;
        tr.store_bytes += (n * d * 2) as u64;
        tr
    }

    fn memory_bytes(&self) -> usize {
        self.m.memory_bytes()
    }
}

impl QVqsEngine {
    /// L ≤ 32: each tree's 8 lanes live in two u32x4 registers; the i16
    /// compare mask widens through `vmovl_s16` (§5.1).
    fn block32(
        &self,
        xt: &[i16],
        leafidx: &mut [[U32x4; 2]],
        out: &mut [f32],
        base: usize,
        n: usize,
        c: usize,
    ) {
        let m = &self.m;
        leafidx.fill([vdupq_n_u32(u32::MAX); 2]);
        for k in 0..m.n_features {
            let r = m.feature_range(k);
            if r.is_empty() {
                continue;
            }
            let xv = vld1q_s16(&xt[k * V_I16..]);
            let ths = &m.thresholds[r.clone()];
            let trees = &m.tree_ids[r.clone()];
            let masks = &m.masks[r];
            for ((&t, &tree), &mk) in ths.iter().zip(trees).zip(masks) {
                let gamma = vdupq_n_s16(t);
                let mask = vcgtq_s16(xv, gamma);
                if vmaxvq_u16(mask) == 0 {
                    break;
                }
                let mi = vreinterpretq_s16_u16(mask);
                let mlo = vreinterpretq_u32_s32(vmovl_s16(vget_low_s16(mi)));
                let mhi = vreinterpretq_u32_s32(vmovl_s16(vget_high_s16(mi)));
                let tree = tree as usize;
                let mvec = vdupq_n_u32(mk as u32);
                let [b0, b1] = leafidx[tree];
                leafidx[tree] = [
                    vbslq_u32(mlo, vandq_u32(mvec, b0), b0),
                    vbslq_u32(mhi, vandq_u32(mvec, b1), b1),
                ];
            }
        }
        // Score: per-class i16 accumulation over 8 lanes (vaddq_s16 —
        // "adding eight 16 bit values at once", §5.1). Per-tree leaf shifts
        // round via SRSHR before the add (identity when the shift is 0).
        let mut acc = vec![I16x8([0; 8]); c];
        for (ti, regs) in leafidx.iter().enumerate() {
            let mut vals = vec![I16x8([0; 8]); c];
            for lane in 0..V_I16 {
                let word = vgetq_lane_u32(regs[lane / 4], lane % 4);
                let j = word.trailing_zeros() as usize;
                let row = m.leaf_row(ti, j);
                for cls in 0..c {
                    vals[cls].0[lane] = row[cls];
                }
            }
            let sh = m.tree_shifts[ti] as u32;
            for cls in 0..c {
                acc[cls] = vaddq_s16(acc[cls], vrshrq_n_s16(vals[cls], sh));
            }
        }
        self.write_scores(&acc, out, base, n, c);
    }

    /// L ≤ 64: four u64x2 registers per tree; the mask widens twice
    /// (s16 → s32 → s64, §5.1).
    fn block64(
        &self,
        xt: &[i16],
        leafidx: &mut [[U64x2; 4]],
        out: &mut [f32],
        base: usize,
        n: usize,
        c: usize,
    ) {
        let m = &self.m;
        leafidx.fill([vdupq_n_u64(u64::MAX); 4]);
        for k in 0..m.n_features {
            let r = m.feature_range(k);
            if r.is_empty() {
                continue;
            }
            let xv = vld1q_s16(&xt[k * V_I16..]);
            let ths = &m.thresholds[r.clone()];
            let trees = &m.tree_ids[r.clone()];
            let masks = &m.masks[r];
            for ((&t, &tree), &mk) in ths.iter().zip(trees).zip(masks) {
                let gamma = vdupq_n_s16(t);
                let mask = vcgtq_s16(xv, gamma);
                if vmaxvq_u16(mask) == 0 {
                    break;
                }
                let mi = vreinterpretq_s16_u16(mask);
                let m32 = [
                    vmovl_s16(vget_low_s16(mi)),
                    vmovl_s16(vget_high_s16(mi)),
                ];
                let tree = tree as usize;
                let mvec = vdupq_n_u64(mk);
                let regs = leafidx[tree];
                let mut next = regs;
                for half in 0..2 {
                    let lo64 = vreinterpretq_u64_s64(vmovl_s32(vget_low_s32(m32[half])));
                    let hi64 = vreinterpretq_u64_s64(vmovl_s32(vget_high_s32(m32[half])));
                    let b0 = regs[half * 2];
                    let b1 = regs[half * 2 + 1];
                    next[half * 2] = vbslq_u64(lo64, vandq_u64(mvec, b0), b0);
                    next[half * 2 + 1] = vbslq_u64(hi64, vandq_u64(mvec, b1), b1);
                }
                leafidx[tree] = next;
            }
        }
        let mut acc = vec![I16x8([0; 8]); c];
        for (ti, regs) in leafidx.iter().enumerate() {
            let mut vals = vec![I16x8([0; 8]); c];
            for lane in 0..V_I16 {
                let word = vgetq_lane_u64(regs[lane / 2], lane % 2);
                let j = word.trailing_zeros() as usize;
                let row = m.leaf_row(ti, j);
                for cls in 0..c {
                    vals[cls].0[lane] = row[cls];
                }
            }
            let sh = m.tree_shifts[ti] as u32;
            for cls in 0..c {
                acc[cls] = vaddq_s16(acc[cls], vrshrq_n_s16(vals[cls], sh));
            }
        }
        self.write_scores(&acc, out, base, n, c);
    }

    fn write_scores(&self, acc: &[I16x8], out: &mut [f32], base: usize, n: usize, c: usize) {
        for lane in 0..V_I16 {
            let i = base + lane;
            if i >= n {
                break;
            }
            for cls in 0..c {
                let total = self.m.base_i32[cls] + acc[cls].0[lane] as i32;
                out[i * c + cls] = self.config.dq(total);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Quantized VQS, int8 tier (v = 16)
// ---------------------------------------------------------------------------

/// Int8 V-QuickScorer: 16 instances per block — the §5.1 lane-doubling taken
/// one width further. The i8 compare mask (`vcgtq_s8`) widens through the
/// `vmovl_s8` / `vmovl_s16` (/ `vmovl_s32` for L ≤ 64) chain to the 32/64-bit
/// bitvector lanes. Scores accumulate natively in i8 (`vaddq_s8`) when the
/// worst-case forest sum provably fits i8, else with widening i8 → i16 adds
/// (`vaddw_s8`, two accumulator registers instead of one) — see
/// [`crate::quant::AccumMode`].
pub struct QVqs8Engine {
    m: QsModel<i8, i8>,
    config: QuantConfig<i8>,
    mode: AccumMode,
}

pub(crate) const V_I8: usize = 16;

impl QVqs8Engine {
    pub fn new(qf: &QForest<i8>) -> QVqs8Engine {
        QVqs8Engine { m: QsModel::from_qforest(qf), config: qf.config, mode: qf.accum_mode() }
    }

    /// The accumulation mode chosen at construction (from the exact
    /// quantized worst-case sum, [`QForest::accum_mode`]).
    pub fn accum_mode(&self) -> AccumMode {
        self.mode
    }
}

impl Engine for QVqs8Engine {
    fn name(&self) -> String {
        "q8VQS".into()
    }

    fn lanes(&self) -> usize {
        V_I8
    }

    fn n_features(&self) -> usize {
        self.m.n_features
    }

    fn n_classes(&self) -> usize {
        self.m.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let m = &self.m;
        let d = m.n_features;
        let c = m.n_classes;
        let n = x.len() / d;
        let mut qx = Vec::with_capacity(x.len());
        self.config.q_slice(x, &mut qx);
        let mut xt = vec![0i8; d * V_I8];
        let mut idx32 =
            vec![[U32x4([0; 4]); 4]; if m.leaf_words == 32 { m.n_trees } else { 0 }];
        let mut idx64 =
            vec![[U64x2([0; 2]); 8]; if m.leaf_words == 64 { m.n_trees } else { 0 }];

        let mut base = 0usize;
        while base < n {
            transpose_block(&qx, d, n, base, V_I8, &mut xt);
            if m.leaf_words == 32 {
                self.block32(&xt, &mut idx32, out, base, n, c);
            } else {
                self.block64(&xt, &mut idx64, out, base, n, c);
            }
            base += V_I8;
        }
    }

    fn count_ops(&self, x: &[f32]) -> OpTrace {
        let mut qx = Vec::new();
        self.config.q_slice(x, &mut qx);
        let d = self.m.n_features;
        let n = x.len() / d;
        let mut tr = vqs_trace_i8(&self.m, &qx, n, self.mode);
        tr.scalar_fp += (n * d) as u64 * 2;
        tr.store_bytes += (n * d) as u64; // 1 byte per quantized feature
        tr
    }

    fn memory_bytes(&self) -> usize {
        self.m.memory_bytes()
    }
}

/// Per-class score accumulators for one 16-lane block: one i8 register in
/// [`AccumMode::Native`], an i16 register pair in [`AccumMode::Widened`].
/// Shared with the int8 RapidScorer (`engine::rapidscorer`), whose score
/// loop gathers the same 16-lane i8 leaf registers.
pub(crate) struct Acc8 {
    native: bool,
    i8acc: Vec<I8x16>,
    lo: Vec<I16x8>,
    hi: Vec<I16x8>,
}

impl Acc8 {
    pub(crate) fn new(c: usize, mode: AccumMode) -> Acc8 {
        let native = mode == AccumMode::Native;
        Acc8 {
            native,
            i8acc: vec![I8x16([0; 16]); if native { c } else { 0 }],
            lo: vec![I16x8([0; 8]); if native { 0 } else { c }],
            hi: vec![I16x8([0; 8]); if native { 0 } else { c }],
        }
    }

    #[inline]
    pub(crate) fn add(&mut self, cls: usize, vals: I8x16) {
        if self.native {
            self.i8acc[cls] = vaddq_s8(self.i8acc[cls], vals);
        } else {
            self.lo[cls] = vaddw_s8(self.lo[cls], vget_low_s8(vals));
            self.hi[cls] = vaddw_s8(self.hi[cls], vget_high_s8(vals));
        }
    }

    #[inline]
    pub(crate) fn lane(&self, cls: usize, lane: usize) -> i32 {
        if self.native {
            self.i8acc[cls].0[lane] as i32
        } else if lane < 8 {
            self.lo[cls].0[lane] as i32
        } else {
            self.hi[cls].0[lane - 8] as i32
        }
    }
}

impl QVqs8Engine {
    /// L ≤ 32: each tree's 16 lanes live in four u32x4 registers; the i8
    /// compare mask widens twice (s8 → s16 → s32).
    fn block32(
        &self,
        xt: &[i8],
        leafidx: &mut [[U32x4; 4]],
        out: &mut [f32],
        base: usize,
        n: usize,
        c: usize,
    ) {
        let m = &self.m;
        leafidx.fill([vdupq_n_u32(u32::MAX); 4]);
        for k in 0..m.n_features {
            let r = m.feature_range(k);
            if r.is_empty() {
                continue;
            }
            let xv = vld1q_s8(&xt[k * V_I8..]);
            let ths = &m.thresholds[r.clone()];
            let trees = &m.tree_ids[r.clone()];
            let masks = &m.masks[r];
            for ((&t, &tree), &mk) in ths.iter().zip(trees).zip(masks) {
                let gamma = vdupq_n_s8(t);
                let mask = vcgtq_s8(xv, gamma);
                if vmaxvq_u8(mask) == 0 {
                    break;
                }
                let mi = vreinterpretq_s8_u8(mask);
                let m16 = [vmovl_s8(vget_low_s8(mi)), vmovl_s8(vget_high_s8(mi))];
                let tree = tree as usize;
                let mvec = vdupq_n_u32(mk as u32);
                let regs = leafidx[tree];
                let mut next = regs;
                for (half, half16) in m16.iter().enumerate() {
                    let lo = vreinterpretq_u32_s32(vmovl_s16(vget_low_s16(*half16)));
                    let hi = vreinterpretq_u32_s32(vmovl_s16(vget_high_s16(*half16)));
                    let b0 = regs[half * 2];
                    let b1 = regs[half * 2 + 1];
                    next[half * 2] = vbslq_u32(lo, vandq_u32(mvec, b0), b0);
                    next[half * 2 + 1] = vbslq_u32(hi, vandq_u32(mvec, b1), b1);
                }
                leafidx[tree] = next;
            }
        }
        // Score: 16-lane i8 leaf gather per (tree, class), rounded down by
        // the per-tree shift (SRSHR; identity at shift 0), accumulated
        // natively or via the widening add.
        let mut acc = Acc8::new(c, self.mode);
        for (ti, regs) in leafidx.iter().enumerate() {
            let mut vals = vec![I8x16([0; 16]); c];
            for lane in 0..V_I8 {
                let word = vgetq_lane_u32(regs[lane / 4], lane % 4);
                let j = word.trailing_zeros() as usize;
                let row = m.leaf_row(ti, j);
                for cls in 0..c {
                    vals[cls].0[lane] = row[cls];
                }
            }
            let sh = m.tree_shifts[ti] as u32;
            for (cls, v) in vals.iter().enumerate() {
                acc.add(cls, vrshrq_n_s8(*v, sh));
            }
        }
        self.write_scores(&acc, out, base, n, c);
    }

    /// L ≤ 64: eight u64x2 registers per tree; the mask widens three times
    /// (s8 → s16 → s32 → s64).
    fn block64(
        &self,
        xt: &[i8],
        leafidx: &mut [[U64x2; 8]],
        out: &mut [f32],
        base: usize,
        n: usize,
        c: usize,
    ) {
        let m = &self.m;
        leafidx.fill([vdupq_n_u64(u64::MAX); 8]);
        for k in 0..m.n_features {
            let r = m.feature_range(k);
            if r.is_empty() {
                continue;
            }
            let xv = vld1q_s8(&xt[k * V_I8..]);
            let ths = &m.thresholds[r.clone()];
            let trees = &m.tree_ids[r.clone()];
            let masks = &m.masks[r];
            for ((&t, &tree), &mk) in ths.iter().zip(trees).zip(masks) {
                let gamma = vdupq_n_s8(t);
                let mask = vcgtq_s8(xv, gamma);
                if vmaxvq_u8(mask) == 0 {
                    break;
                }
                let mi = vreinterpretq_s8_u8(mask);
                let m16 = [vmovl_s8(vget_low_s8(mi)), vmovl_s8(vget_high_s8(mi))];
                let tree = tree as usize;
                let mvec = vdupq_n_u64(mk);
                let regs = leafidx[tree];
                let mut next = regs;
                for (half, half16) in m16.iter().enumerate() {
                    let m32 =
                        [vmovl_s16(vget_low_s16(*half16)), vmovl_s16(vget_high_s16(*half16))];
                    for (q, quarter) in m32.iter().enumerate() {
                        let lo64 = vreinterpretq_u64_s64(vmovl_s32(vget_low_s32(*quarter)));
                        let hi64 = vreinterpretq_u64_s64(vmovl_s32(vget_high_s32(*quarter)));
                        let idx = half * 4 + q * 2;
                        let b0 = regs[idx];
                        let b1 = regs[idx + 1];
                        next[idx] = vbslq_u64(lo64, vandq_u64(mvec, b0), b0);
                        next[idx + 1] = vbslq_u64(hi64, vandq_u64(mvec, b1), b1);
                    }
                }
                leafidx[tree] = next;
            }
        }
        let mut acc = Acc8::new(c, self.mode);
        for (ti, regs) in leafidx.iter().enumerate() {
            let mut vals = vec![I8x16([0; 16]); c];
            for lane in 0..V_I8 {
                let word = vgetq_lane_u64(regs[lane / 2], lane % 2);
                let j = word.trailing_zeros() as usize;
                let row = m.leaf_row(ti, j);
                for cls in 0..c {
                    vals[cls].0[lane] = row[cls];
                }
            }
            let sh = m.tree_shifts[ti] as u32;
            for (cls, v) in vals.iter().enumerate() {
                acc.add(cls, vrshrq_n_s8(*v, sh));
            }
        }
        self.write_scores(&acc, out, base, n, c);
    }

    fn write_scores(&self, acc: &Acc8, out: &mut [f32], base: usize, n: usize, c: usize) {
        for lane in 0..V_I8 {
            let i = base + lane;
            if i >= n {
                break;
            }
            for cls in 0..c {
                let total = self.m.base_i32[cls] + acc.lane(cls, lane);
                out[i * c + cls] = self.config.dq(total);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Op traces
// ---------------------------------------------------------------------------

/// Nodes visited per feature for a block: scan until *all* lanes are true
/// nodes (the vectorized break condition).
fn block_visits<T: Copy + PartialOrd>(
    m: &QsModel<T, impl Copy>,
    xt: &[T],
    v: usize,
) -> (u64, u64) {
    let mut visited = 0u64;
    let mut applied = 0u64;
    for k in 0..m.n_features {
        for idx in m.feature_range(k) {
            visited += 1;
            let any = (0..v).any(|lane| xt[k * v + lane] > m.thresholds[idx]);
            if any {
                applied += 1;
            } else {
                break;
            }
        }
    }
    (visited, applied)
}

fn vqs_trace_f32(m: &QsModel<f32, f32>, x: &[f32]) -> OpTrace {
    let d = m.n_features;
    let n = x.len() / d;
    let c = m.n_classes as u64;
    let mut tr = OpTrace::new();
    let mut xt = vec![0f32; d * V_F32];
    let regs_per_tree = if m.leaf_words == 32 { 1 } else { 2 };
    let mut base = 0;
    while base < n {
        transpose_block(x, d, n, base, V_F32, &mut xt);
        let (visited, applied) = block_visits(m, &xt, V_F32);
        tr.stream_load_bytes += visited * m.node_entry_bytes();
        tr.neon_fp += visited; // vcgtq_f32
        tr.cmp_fp += visited;
        tr.neon_horiz += visited; // vmaxvq
        tr.branch += visited;
        tr.neon_alu += applied * (2 * regs_per_tree + 1); // dup + and + bsl
        tr.store_bytes += 16 * regs_per_tree * m.n_trees as u64; // leafidx init
        // Scores.
        tr.scalar_alu += m.n_trees as u64 * V_F32 as u64; // tz + extracts
        tr.random_loads += m.n_trees as u64 * V_F32 as u64;
        tr.neon_fp += m.n_trees as u64 * c;
        // Transpose.
        tr.scalar_alu += (d * V_F32) as u64;
        base += V_F32;
    }
    tr
}

fn vqs_trace_flint(m: &QsModel<i32, f32>, x: &[f32]) -> OpTrace {
    let d = m.n_features;
    let n = x.len() / d;
    let c = m.n_classes as u64;
    let mut ex = Vec::new();
    crate::quant::flint::encode_batch_gt(x, &mut ex);
    let mut tr = OpTrace::new();
    // Feature encoding: one integer fixup + store per value (no FP).
    tr.scalar_alu += (n * d) as u64;
    tr.store_bytes += (n * d * std::mem::size_of::<i32>()) as u64;
    let mut xt = vec![0i32; d * V_F32];
    let regs_per_tree = if m.leaf_words == 32 { 1 } else { 2 };
    let mut base = 0;
    while base < n {
        transpose_block(&ex, d, n, base, V_F32, &mut xt);
        let (visited, applied) = block_visits(m, &xt, V_F32);
        tr.stream_load_bytes += visited * m.node_entry_bytes();
        tr.neon_alu += visited; // vcgtq_s32 (integer pipe)
        tr.cmp_int += visited;
        tr.neon_horiz += visited; // vmaxvq
        tr.branch += visited;
        tr.neon_alu += applied * (2 * regs_per_tree + 1); // dup + and + bsl
        tr.store_bytes += 16 * regs_per_tree * m.n_trees as u64;
        tr.scalar_alu += m.n_trees as u64 * V_F32 as u64;
        tr.random_loads += m.n_trees as u64 * V_F32 as u64;
        tr.neon_fp += m.n_trees as u64 * c; // f32 leaf adds, unchanged
        tr.scalar_alu += (d * V_F32) as u64;
        base += V_F32;
    }
    tr
}

fn vqs_trace_i16(m: &QsModel<i16, i16>, qx: &[i16], n: usize) -> OpTrace {
    let d = m.n_features;
    let c = m.n_classes as u64;
    let mut tr = OpTrace::new();
    let mut xt = vec![0i16; d * V_I16];
    let regs_per_tree: u64 = if m.leaf_words == 32 { 2 } else { 4 };
    let mut base = 0;
    while base < n {
        transpose_block(qx, d, n, base, V_I16, &mut xt);
        let (visited, applied) = block_visits(m, &xt, V_I16);
        tr.stream_load_bytes += visited * m.node_entry_bytes();
        tr.neon_alu += visited; // vcgtq_s16 (integer pipe)
        tr.cmp_int += visited;
        tr.neon_horiz += visited; // vmaxvq + widening
        tr.branch += visited;
        tr.neon_horiz += applied * regs_per_tree; // vmovl widen chain
        tr.neon_alu += applied * (2 * regs_per_tree + 1);
        tr.store_bytes += 16 * regs_per_tree * m.n_trees as u64;
        tr.scalar_alu += m.n_trees as u64 * V_I16 as u64;
        tr.random_loads += m.n_trees as u64 * V_I16 as u64;
        tr.neon_alu += m.n_trees as u64 * c; // vaddq_s16
        tr.scalar_alu += (d * V_I16) as u64;
        base += V_I16;
    }
    tr
}

fn vqs_trace_i8(m: &QsModel<i8, i8>, qx: &[i8], n: usize, mode: AccumMode) -> OpTrace {
    let d = m.n_features;
    let c = m.n_classes as u64;
    let mut tr = OpTrace::new();
    let mut xt = vec![0i8; d * V_I8];
    let regs_per_tree: u64 = if m.leaf_words == 32 { 4 } else { 8 };
    // Native: one vaddq_s8 per class; Widened: two vaddw_s8.
    let acc_adds: u64 = match mode {
        AccumMode::Native => 1,
        AccumMode::Widened => 2,
    };
    let mut base = 0;
    while base < n {
        transpose_block(qx, d, n, base, V_I8, &mut xt);
        let (visited, applied) = block_visits(m, &xt, V_I8);
        tr.stream_load_bytes += visited * m.node_entry_bytes();
        tr.neon_alu += visited; // vcgtq_s8 (integer pipe)
        tr.cmp_int += visited;
        tr.neon_horiz += visited; // vmaxvq
        tr.branch += visited;
        tr.neon_horiz += applied * regs_per_tree; // vmovl widen chain
        tr.neon_alu += applied * (2 * regs_per_tree + 1);
        tr.store_bytes += 16 * regs_per_tree * m.n_trees as u64;
        tr.scalar_alu += m.n_trees as u64 * V_I8 as u64;
        tr.random_loads += m.n_trees as u64 * V_I8 as u64;
        tr.neon_alu += m.n_trees as u64 * c * acc_adds;
        tr.scalar_alu += (d * V_I8) as u64;
        base += V_I8;
    }
    tr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};
    use crate::testing::assert_close;

    fn setup(leaves: usize, seed: u64, n: usize) -> (Forest, crate::data::Dataset) {
        // Train on a bigger sample so max_leaves=64 trees really exceed 32
        // leaves; evaluate on the first `n` rows.
        let ds = DatasetId::Magic.generate(n.max(900), seed);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 13,
                tree: TreeParams { max_leaves: leaves, min_samples_leaf: 2, mtry: 0 },
                seed,
                ..Default::default()
            },
        );
        (f, ds)
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn vqs_matches_reference_l32() {
        let (f, ds) = setup(32, 1, 203); // non-multiple of 4: tests padding
        let e = VqsEngine::new(&f);
        let x = &ds.x[..ds.d * 203];
        assert_close(&e.predict(x), &f.predict_batch(x), 1e-5, 1e-5).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn vqs_matches_reference_l64() {
        let (f, ds) = setup(64, 2, 120);
        assert!(f.max_leaves() > 32);
        let e = VqsEngine::new(&f);
        let x = &ds.x[..ds.d * 119];
        assert_close(&e.predict(x), &f.predict_batch(x), 1e-5, 1e-5).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn qvqs_matches_qforest_l32() {
        let (f, ds) = setup(32, 3, 101); // non-multiple of 8
        let qf = QForest::from_forest(&f, QuantConfig::paper_default());
        let e = QVqsEngine::new(&qf);
        let x = &ds.x[..ds.d * 101];
        assert_eq!(e.predict(x), qf.predict_batch(x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn qvqs_matches_qforest_l64() {
        let (f, ds) = setup(64, 4, 96);
        let qf = QForest::from_forest(&f, QuantConfig::paper_default());
        let e = QVqsEngine::new(&qf);
        let x = &ds.x[..ds.d * 93]; // non-multiple of 8
        assert_eq!(e.predict(x), qf.predict_batch(x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn flint_vqs_bit_identical_to_float_vqs() {
        // Both leaf widths, non-multiple-of-4 batches (padding lanes), and
        // adversarial features: the integer-compare engine must reproduce
        // the float engine bit-for-bit.
        for (leaves, seed, n) in [(32usize, 1u64, 203usize), (64, 2, 119)] {
            let (f, ds) = setup(leaves, seed, n.max(120));
            let fl = FlintVqsEngine::new(&f);
            let fe = VqsEngine::new(&f);
            assert_eq!(fl.name(), "flVQS");
            assert_eq!(fl.lanes(), V_F32);
            let x = &ds.x[..ds.d * n];
            assert_eq!(fl.predict(x), fe.predict(x), "L={leaves}");

            let mut adv = ds.x[..4 * ds.d].to_vec();
            adv[0] = f32::NAN;
            adv[ds.d] = -0.0;
            adv[2 * ds.d] = f32::from_bits(0x0000_0001);
            adv[3 * ds.d] = f32::NEG_INFINITY;
            assert_eq!(fl.predict(&adv), fe.predict(&adv), "L={leaves} adversarial");

            let tr = fl.count_ops(&ds.x[..4 * ds.d]);
            assert!(tr.cmp_int > 0);
            assert_eq!(tr.cmp_fp, 0);
            assert!(tr.neon_fp > 0); // f32 leaf adds stay on the FP pipe
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn single_instance_batch() {
        let (f, ds) = setup(32, 5, 40);
        let e = VqsEngine::new(&f);
        let got = e.predict(&ds.x[..ds.d]);
        let want = f.predict_batch(&ds.x[..ds.d]);
        assert_close(&got, &want, 1e-5, 1e-5).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn traces_present() {
        let (f, ds) = setup(32, 6, 32);
        let e = VqsEngine::new(&f);
        let tr = e.count_ops(&ds.x);
        assert!(tr.neon_fp > 0 && tr.neon_alu > 0);
        let qf = QForest::from_forest(&f, QuantConfig::paper_default());
        let qe = QVqsEngine::new(&qf);
        let qtr = qe.count_ops(&ds.x);
        assert!(qtr.neon_alu > 0);
        let qf8 = QForest::<i8>::from_forest(&f, crate::quant::choose_scale_i8(&f, 1.0));
        let qe8 = QVqs8Engine::new(&qf8);
        let qtr8 = qe8.count_ops(&ds.x);
        assert!(qtr8.neon_alu > 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8vqs_matches_qforest_l32() {
        let (f, ds) = setup(32, 8, 103); // non-multiple of 16: tests padding
        let qf = QForest::<i8>::from_forest(&f, crate::quant::choose_scale_i8(&f, 1.0));
        let e = QVqs8Engine::new(&qf);
        assert_eq!(e.name(), "q8VQS");
        assert_eq!(e.lanes(), 16);
        let x = &ds.x[..ds.d * 103];
        assert_eq!(e.predict(x), qf.predict_batch(x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8vqs_matches_qforest_l64() {
        // Seed 2 matches vqs_matches_reference_l64: known to exceed 32 leaves.
        let (f, ds) = setup(64, 2, 96);
        assert!(f.max_leaves() > 32);
        let qf = QForest::<i8>::from_forest(&f, crate::quant::choose_scale_i8(&f, 1.0));
        let e = QVqs8Engine::new(&qf);
        let x = &ds.x[..ds.d * 87]; // non-multiple of 16
        assert_eq!(e.predict(x), qf.predict_batch(x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8vqs_native_mode_on_rf() {
        // RF worst-case sum ≈ 1.0: the tier picks the native i8 accumulator.
        let (f, ds) = setup(32, 11, 40);
        let qf = QForest::<i8>::from_forest(&f, crate::quant::choose_scale_i8(&f, 1.0));
        let e = QVqs8Engine::new(&qf);
        assert_eq!(e.accum_mode(), AccumMode::Native);
        let x = &ds.x[..ds.d * 33];
        assert_eq!(e.predict(x), qf.predict_batch(x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8vqs_widened_mode_exact() {
        // Inflate leaf magnitudes so the worst-case sum cannot fit an i8
        // accumulator at a leaf-preserving scale: the engine must widen
        // i8→i16 and stay bit-exact with the i32-accumulating reference.
        let (mut f, ds) = setup(32, 10, 64);
        for t in &mut f.trees {
            for v in &mut t.leaf_values {
                *v *= 40.0;
            }
        }
        let cfg = crate::quant::choose_scale_i8(&f, 1.0);
        let qf = QForest::<i8>::from_forest(&f, cfg);
        let e = QVqs8Engine::new(&qf);
        assert_eq!(e.accum_mode(), AccumMode::Widened);
        let x = &ds.x[..ds.d * 64];
        assert_eq!(e.predict(x), qf.predict_batch(x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8vqs_per_tree_shifts_exact() {
        // Per-tree leaf scales: non-zero SRSHR shifts in the score loop,
        // still bit-exact with the shifted i32 reference (both L widths).
        for (leaves, seed, n) in [(32usize, 8u64, 103usize), (64, 2, 87)] {
            let (f, ds) = setup(leaves, seed, n.max(96));
            let cfg = crate::quant::choose_scale_i8_per_tree(&f, 1.0);
            let qf = QForest::<i8>::from_forest_per_tree(&f, cfg);
            assert!(qf.has_per_tree_scales(), "RF leaves should earn a shift");
            let e = QVqs8Engine::new(&qf);
            let x = &ds.x[..ds.d * n];
            assert_eq!(e.predict(x), qf.predict_batch(x), "L={leaves}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn qvqs_i16_per_tree_shifts_exact() {
        // The i16 tier supports per-tree scales through the same SRSHR
        // path (s16 lanes).
        let (f, ds) = setup(32, 9, 101);
        let cfg: crate::quant::QuantConfig =
            crate::quant::QuantConfig::new(crate::quant::choose_scale(&f, 1.0).scale / 64.0);
        let qf = QForest::from_forest_per_tree(&f, cfg);
        assert!(qf.has_per_tree_scales());
        let e = QVqsEngine::new(&qf);
        let x = &ds.x[..ds.d * 101];
        assert_eq!(e.predict(x), qf.predict_batch(x));
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn q8_single_instance_batch() {
        let (f, ds) = setup(32, 12, 40);
        let qf = QForest::<i8>::from_forest(&f, crate::quant::choose_scale_i8(&f, 1.0));
        let e = QVqs8Engine::new(&qf);
        assert_eq!(e.predict(&ds.x[..ds.d]), qf.predict_batch(&ds.x[..ds.d]));
    }
}
