//! XLA tensor engine: the L1/L2 AOT path exposed as an [`Engine`].
//!
//! The forest is encoded into the QuickScorer tensors the Pallas kernel
//! consumes (same encoding as `python/compile/forest.py::encode_qs`), the
//! HLO artifact is compiled on the PJRT CPU client, and batches execute as
//! one tensor call. This mirrors the "compile tree traversal to tensor ops"
//! line of related work the paper discusses (Nakandala et al. 2020) and lets
//! the coordinator route between Rust-native traversal and the AOT path.
//!
//! Threading: the `xla` crate's client types are `Rc`-based (`!Send`), so a
//! dedicated worker thread owns the runtime, executable and parameter
//! literals; the engine facade is a `Send + Sync` channel handle.

use std::sync::mpsc;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::Engine;
use crate::forest::Forest;
use crate::quant::QuantConfig;
use crate::runtime::{self, ArtifactDtype, ModelMeta, Runtime};

/// QuickScorer tensor encoding (Rust twin of Python `encode_qs`).
#[derive(Debug, Clone)]
pub struct QsTensors {
    pub thr: Vec<f32>,
    pub fid: Vec<i32>,
    pub mask_lo: Vec<u32>,
    pub mask_hi: Vec<u32>,
    pub leaves: Vec<f32>,
    pub m: usize,
    pub k: usize,
    pub leaf_words: usize,
    pub c: usize,
}

/// Encode a forest into dense `[M, K]` node tensors and a `[M, L, C]` leaf
/// table, padded to the artifact's static shape `(m_pad, k_pad, l_pad)`.
pub fn encode_qs_padded(
    f: &Forest,
    m_pad: usize,
    k_pad: usize,
    l_pad: usize,
) -> Result<QsTensors> {
    let max_nodes = f.trees.iter().map(|t| t.nodes.len()).max().unwrap_or(0);
    if f.n_trees() > m_pad || max_nodes > k_pad || f.max_leaves() > l_pad {
        bail!(
            "forest (M={}, K={}, L={}) exceeds artifact shape (M={m_pad}, K={k_pad}, L={l_pad})",
            f.n_trees(),
            max_nodes,
            f.max_leaves()
        );
    }
    let c = f.n_classes;
    let mut t = QsTensors {
        thr: vec![f32::INFINITY; m_pad * k_pad],
        fid: vec![0; m_pad * k_pad],
        mask_lo: vec![u32::MAX; m_pad * k_pad],
        mask_hi: vec![u32::MAX; m_pad * k_pad],
        leaves: vec![0.0; m_pad * l_pad * c],
        m: m_pad,
        k: k_pad,
        leaf_words: l_pad,
        c,
    };
    for (ti, tree) in f.trees.iter().enumerate() {
        let ranges = tree.left_leaf_ranges();
        for (ni, (node, &(b, e))) in tree.nodes.iter().zip(&ranges).enumerate() {
            let idx = ti * k_pad + ni;
            let mask = super::common::left_range_mask(b, e);
            t.thr[idx] = node.threshold;
            t.fid[idx] = node.feature as i32;
            t.mask_lo[idx] = mask as u32;
            t.mask_hi[idx] = (mask >> 32) as u32;
        }
        let dst = &mut t.leaves[ti * l_pad * c..];
        dst[..tree.leaf_values.len()].copy_from_slice(&tree.leaf_values);
    }
    Ok(t)
}

enum Job {
    Predict { x: Vec<f32>, n: usize, reply: mpsc::Sender<Result<Vec<f32>>> },
    Shutdown,
}

/// The AOT tensor engine.
pub struct TensorEngine {
    tx: Mutex<mpsc::Sender<Job>>,
    handle: Option<std::thread::JoinHandle<()>>,
    name: String,
    n_features: usize,
    n_classes: usize,
    batch: usize,
    base_score: Vec<f32>,
    /// Resident bytes of the encoded QS tensors held by the worker (the
    /// parameter literals mirror these buffers).
    memory_bytes: usize,
}

impl TensorEngine {
    /// Build from an artifact (by manifest name) and the forest to serve.
    /// The forest must fit the artifact's static shapes.
    pub fn from_artifact(
        artifacts_dir: &std::path::Path,
        model_name: &str,
        forest: &Forest,
    ) -> Result<TensorEngine> {
        let metas = runtime::load_manifest(artifacts_dir)?;
        let meta = metas
            .iter()
            .find(|m| m.name == model_name)
            .with_context(|| format!("artifact '{model_name}' not in manifest"))?
            .clone();
        if forest.n_features != meta.d || forest.n_classes != meta.c {
            bail!(
                "forest (d={}, c={}) does not match artifact (d={}, c={})",
                forest.n_features,
                forest.n_classes,
                meta.d,
                meta.c
            );
        }
        let tensors = encode_qs_padded(forest, meta.n_trees, meta.k, meta.leaf_words)?;
        let scalar_bytes = match meta.dtype {
            ArtifactDtype::F32 => 4,
            ArtifactDtype::I16 => 2,
        };
        let memory_bytes = tensors.thr.len() * scalar_bytes // thresholds (quantized for i16)
            + tensors.fid.len() * 4
            + tensors.mask_lo.len() * 4
            + tensors.mask_hi.len() * 4
            + tensors.leaves.len() * scalar_bytes;
        let (tx, rx) = mpsc::channel::<Job>();
        let (init_tx, init_rx) = mpsc::channel::<Result<()>>();
        let dir = artifacts_dir.to_path_buf();
        let meta2 = meta.clone();
        let handle = std::thread::Builder::new()
            .name(format!("tensor-engine-{model_name}"))
            .spawn(move || worker(dir, meta2, tensors, rx, init_tx))
            .context("spawning tensor worker")?;
        init_rx.recv().context("tensor worker died during init")??;
        Ok(TensorEngine {
            tx: Mutex::new(tx),
            handle: Some(handle),
            name: format!("XLA:{model_name}"),
            n_features: meta.d,
            n_classes: meta.c,
            batch: meta.batch,
            base_score: forest.base_score.clone(),
            memory_bytes,
        })
    }
}

/// Worker owning all `!Send` XLA state.
fn worker(
    dir: std::path::PathBuf,
    meta: ModelMeta,
    t: QsTensors,
    rx: mpsc::Receiver<Job>,
    init_tx: mpsc::Sender<Result<()>>,
) {
    // --- init ---------------------------------------------------------
    let setup = (|| -> Result<_> {
        let rt = Runtime::cpu(&dir)?;
        let model = rt.load(&meta)?;
        let quant: QuantConfig = QuantConfig::new(meta.scale);
        // Parameter literals are built once.
        let mk = [t.m, t.k];
        let fid = runtime::lit_i32(&t.fid, &mk)?;
        let mlo = runtime::lit_u32(&t.mask_lo, &mk)?;
        let mhi = runtime::lit_u32(&t.mask_hi, &mk)?;
        let (thr, leaves) = match meta.dtype {
            ArtifactDtype::F32 => (
                runtime::lit_f32(&t.thr, &mk)?,
                runtime::lit_f32(&t.leaves, &[t.m, t.leaf_words, t.c])?,
            ),
            ArtifactDtype::I16 => {
                let qthr: Vec<i16> = t.thr.iter().map(|&v| quant.q(v)).collect();
                let qleaves: Vec<i16> = t.leaves.iter().map(|&v| quant.q(v)).collect();
                (
                    runtime::lit_i16(&qthr, &mk)?,
                    runtime::lit_i16(&qleaves, &[t.m, t.leaf_words, t.c])?,
                )
            }
        };
        Ok((rt, model, quant, thr, fid, mlo, mhi, leaves))
    })();
    let (_rt, model, quant, thr, fid, mlo, mhi, leaves) = match setup {
        Ok(v) => {
            let _ = init_tx.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = init_tx.send(Err(e));
            return;
        }
    };

    // --- serve ---------------------------------------------------------
    let b = meta.batch;
    let d = meta.d;
    let c = meta.c;
    while let Ok(job) = rx.recv() {
        match job {
            Job::Shutdown => break,
            Job::Predict { x, n, reply } => {
                let result = (|| -> Result<Vec<f32>> {
                    debug_assert_eq!(x.len(), b * d);
                    let out = match meta.dtype {
                        ArtifactDtype::F32 => {
                            let xl = runtime::lit_f32(&x, &[b, d])?;
                            let lit = model.execute(&[
                                xl,
                                thr.clone(),
                                fid.clone(),
                                mlo.clone(),
                                mhi.clone(),
                                leaves.clone(),
                            ])?;
                            lit.to_vec::<f32>()?
                        }
                        ArtifactDtype::I16 => {
                            let qx: Vec<i16> = x.iter().map(|&v| quant.q(v)).collect();
                            let xl = runtime::lit_i16(&qx, &[b, d])?;
                            let lit = model.execute(&[
                                xl,
                                thr.clone(),
                                fid.clone(),
                                mlo.clone(),
                                mhi.clone(),
                                leaves.clone(),
                            ])?;
                            lit.to_vec::<i32>()?.iter().map(|&v| quant.dq(v)).collect()
                        }
                    };
                    Ok(out[..n * c].to_vec())
                })();
                let _ = reply.send(result);
            }
        }
    }
}

impl Drop for TensorEngine {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(Job::Shutdown);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Engine for TensorEngine {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn lanes(&self) -> usize {
        self.batch
    }

    fn n_features(&self) -> usize {
        self.n_features
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let d = self.n_features;
        let c = self.n_classes;
        let n = x.len() / d;
        let b = self.batch;
        let mut base = 0usize;
        while base < n {
            let chunk = (n - base).min(b);
            // Pad the chunk to the artifact's static batch.
            let mut xb = vec![0f32; b * d];
            xb[..chunk * d].copy_from_slice(&x[base * d..(base + chunk) * d]);
            let (reply_tx, reply_rx) = mpsc::channel();
            {
                let tx = self.tx.lock().expect("tensor engine poisoned");
                tx.send(Job::Predict { x: xb, n: chunk, reply: reply_tx })
                    .expect("tensor worker gone");
            }
            let scores = reply_rx
                .recv()
                .expect("tensor worker gone")
                .expect("tensor execution failed");
            for i in 0..chunk {
                for cls in 0..c {
                    out[(base + i) * c + cls] = scores[i * c + cls] + self.base_score[cls];
                }
            }
            base += chunk;
        }
    }

    fn memory_bytes(&self) -> usize {
        self.memory_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::io::load;

    fn artifacts() -> std::path::PathBuf {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts().join("manifest.json").exists()
    }

    #[test]
    fn tensor_engine_matches_rust_reference() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // Load the same fixture forest the artifact was compiled against.
        let metas = runtime::load_manifest(&artifacts()).unwrap();
        let meta = metas.iter().find(|m| m.name == "rf_f32_b64").unwrap();
        let forest = load(&artifacts().join(&meta.forest)).unwrap();
        let eng = TensorEngine::from_artifact(&artifacts(), "rf_f32_b64", &forest).unwrap();

        let mut rng = crate::util::Pcg32::seeded(77);
        let n = 100; // non-multiple of the artifact batch
        let x: Vec<f32> = (0..n * forest.n_features).map(|_| rng.f32()).collect();
        let got = eng.predict(&x);
        let want = forest.predict_batch(&x);
        crate::testing::assert_close(&got, &want, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn tensor_engine_i16_close_to_quant_reference() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let metas = runtime::load_manifest(&artifacts()).unwrap();
        let meta = metas.iter().find(|m| m.name == "rf_i16_b64").unwrap();
        let forest = load(&artifacts().join(&meta.forest)).unwrap();
        let eng = TensorEngine::from_artifact(&artifacts(), "rf_i16_b64", &forest).unwrap();

        let qf = crate::quant::QForest::<i16>::from_forest(
            &forest,
            crate::quant::QuantConfig::new(meta.scale),
        );
        let mut rng = crate::util::Pcg32::seeded(78);
        let n = 64;
        let x: Vec<f32> = (0..n * forest.n_features).map(|_| rng.f32()).collect();
        let got = eng.predict(&x);
        let want = qf.predict_batch(&x);
        crate::testing::assert_close(&got, &want, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn rejects_oversized_forest() {
        // A forest with more trees than the pad must fail.
        let mut f2 = crate::forest::Forest::new(2, 1, crate::forest::Task::Ranking);
        for _ in 0..5 {
            f2.trees.push(crate::forest::Tree::leaf(vec![0.0]));
        }
        assert!(encode_qs_padded(&f2, 4, 4, 32).is_err());
        // An empty forest fits anything.
        let f = crate::forest::Forest::new(9, 2, crate::forest::Task::Classification);
        assert!(encode_qs_padded(&f, 4, 4, 32).is_ok());
    }
}
