//! Dynamic early-exit ensemble scoring (DESIGN.md §11).
//!
//! Daghero et al. (PAPERS.md) observe that most samples do not need the
//! whole forest: score trees in a *confidence order* and stop a sample as
//! soon as its partial argmax is decided. [`EarlyExitEngine`] wraps any
//! `(EngineKind, Precision)` variant from the registry: the ordered forest
//! is cut into geometrically growing stages, each stage is a normal
//! [`Engine`] over a sub-forest, and between stages every still-active row
//! is tested against a margin bound:
//!
//! * **Exact** — exit when the leading class's margin exceeds the maximum
//!   mass the remaining trees could move between any two classes, plus a
//!   float-rounding slack. The final argmax (including the first-index
//!   tie-break of [`Forest::argmax`]) is *guaranteed* identical to scoring
//!   every stage ([`EarlyExitMode::Off`]) — enforced by
//!   `rust/tests/early_exit_exact.rs`.
//! * **Approx** — exit when the margin exceeds `frac` × that remaining
//!   mass. Faster, probabilistic; the selector gates it behind the same
//!   ≥ 99% calibration-agreement rule as any quantized tier.
//!
//! The wrapper is precision-orthogonal: quantized tiers are built with an
//! explicit full-forest scale so every stage (and the bound derivation)
//! sees exactly the quantization full scoring would use. Per-row outputs of
//! every registry engine are batch-composition independent, so compacting
//! the active rows between stages — and row-sharding the wrapper under
//! [`crate::exec::ParallelEngine`] — cannot change any row's scores.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::engine::{build, variant_name, Engine, EngineKind, Precision};
use crate::forest::{Forest, Task};
use crate::quant::{choose_scale, choose_scale_i8, QuantConfig};

/// Early-exit policy (`--early-exit {off,exact,approx}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EarlyExitMode {
    /// Score every stage — the reference the exact contract is stated
    /// against (same stage order and accumulation, no exits).
    Off,
    /// Exit only when the argmax is provably decided.
    Exact,
    /// Exit when the margin clears `frac` × the remaining attainable mass.
    Approx,
}

impl EarlyExitMode {
    pub fn name(&self) -> &'static str {
        match self {
            EarlyExitMode::Off => "off",
            EarlyExitMode::Exact => "exact",
            EarlyExitMode::Approx => "approx",
        }
    }

    pub fn from_name(s: &str) -> Option<EarlyExitMode> {
        match s.to_ascii_lowercase().as_str() {
            "off" => Some(EarlyExitMode::Off),
            "exact" => Some(EarlyExitMode::Exact),
            "approx" => Some(EarlyExitMode::Approx),
            _ => None,
        }
    }
}

/// Default approx-mode margin fraction.
pub const DEFAULT_APPROX_FRAC: f64 = 0.2;

/// Trees per stage start at ⌈T/16⌉ and double — early stages are cheap
/// (most exits happen there), late stages amortize per-stage overhead.
const STAGE_GROWTH: usize = 2;

struct Stage {
    engine: Box<dyn Engine>,
    n_trees: usize,
}

/// An early-exit wrapper around one engine variant. Build via
/// [`build_early_exit`].
pub struct EarlyExitEngine {
    stages: Vec<Stage>,
    /// Σ over trees *after* stage `i` of each tree's maximum inter-class
    /// leaf gap (in the tier's dequantized value domain) — the most the
    /// remaining forest can move any class difference.
    gap_after: Vec<f64>,
    /// Float-rounding slack added to `gap_after` in exact mode (§11): the
    /// partial sums compared are f32, so a margin must clear the remaining
    /// mass by more than every rounding step could contribute.
    slack_after: Vec<f64>,
    mode: EarlyExitMode,
    frac: f64,
    order: Vec<usize>,
    n_features: usize,
    n_classes: usize,
    total_trees: usize,
    lanes: usize,
    name: String,
    rows_scored: AtomicU64,
    trees_evaluated: AtomicU64,
}

/// Build an early-exit wrapper over `(kind, precision)` for a
/// classification forest.
///
/// `calibration` (row-major, may be empty) derives the confidence order:
/// trees are sorted by how often their own leaf argmax agrees with the
/// full-forest float argmax, most-agreeing first (ties keep the original
/// index order; an empty calibration keeps the identity order). Quantized
/// tiers are pinned to the full-forest scale (`choose_scale` /
/// `choose_scale_i8`) so staging cannot change the quantization.
pub fn build_early_exit(
    kind: EngineKind,
    precision: Precision,
    forest: &Forest,
    calibration: &[f32],
    mode: EarlyExitMode,
) -> anyhow::Result<EarlyExitEngine> {
    anyhow::ensure!(
        forest.task == Task::Classification && forest.n_classes >= 2,
        "early exit needs a classification forest with >= 2 classes \
         (got {:?}, {} classes): the exit test is an argmax margin",
        forest.task,
        forest.n_classes
    );
    anyhow::ensure!(!forest.trees.is_empty(), "early exit over an empty forest");
    let d = forest.n_features;
    let c = forest.n_classes;
    anyhow::ensure!(
        calibration.len() % d == 0,
        "calibration length {} is not a multiple of n_features {d}",
        calibration.len()
    );
    let t = forest.n_trees();

    let order = confidence_order(forest, calibration);

    // The quantization every stage shares, chosen once from the *full*
    // forest — per-stage auto-scaling would quantize differently from full
    // scoring and break the bound derivation.
    let quant: Option<QuantConfig> = match precision {
        Precision::F32 | Precision::F32Flint => None,
        Precision::I16 => Some(choose_scale(forest, 1.0)),
        Precision::I8 => Some(QuantConfig::new(choose_scale_i8(forest, 1.0).scale)),
    };
    // A tree's contribution in the value domain the engine actually sums:
    // f32 tiers add the stored leaf, int tiers add the quantized leaf
    // dequantized at the shared global scale (zero per-tree shifts on this
    // build path).
    let eff = |v: f32| -> f64 {
        match (precision, quant) {
            (Precision::I16, Some(cfg)) => {
                cfg.q(v) as i32 as f64 / cfg.scale as f64
            }
            (Precision::I8, Some(cfg)) => {
                let cfg8 = QuantConfig::<i8>::new(cfg.scale);
                cfg8.q(v) as i32 as f64 / cfg8.scale as f64
            }
            _ => v as f64,
        }
    };

    // Per ordered tree: the largest inter-class gap any single leaf can
    // contribute, and the largest |value| (for the rounding slack).
    let mut gap_t = Vec::with_capacity(t);
    let mut hi_t = Vec::with_capacity(t);
    for &ti in &order {
        let tree = &forest.trees[ti];
        let mut gap = 0f64;
        let mut hi = 0f64;
        for leaf in 0..tree.n_leaves {
            let row = tree.leaf_row(leaf);
            let mut lo_v = f64::INFINITY;
            let mut hi_v = f64::NEG_INFINITY;
            for &v in row {
                let e = eff(v);
                lo_v = lo_v.min(e);
                hi_v = hi_v.max(e);
                hi = hi.max(e.abs());
            }
            gap = gap.max(hi_v - lo_v);
        }
        gap_t.push(gap);
        hi_t.push(hi);
    }

    // Stage sizes: ⌈T/16⌉, then doubling until the forest is covered.
    let mut sizes = Vec::new();
    let mut covered = 0usize;
    let mut next = t.div_ceil(16).max(1);
    while covered < t {
        let k = next.min(t - covered);
        sizes.push(k);
        covered += k;
        next = (next * STAGE_GROWTH).max(1);
    }

    // One inner engine per stage over a sub-forest in confidence order.
    // Stage 0 keeps the base score; later stages contribute trees only, so
    // the summed output is exactly one full scoring pass.
    let mut stages = Vec::with_capacity(sizes.len());
    let mut at = 0usize;
    for (si, &k) in sizes.iter().enumerate() {
        let mut sub = Forest::new(d, c, forest.task);
        sub.trees = order[at..at + k].iter().map(|&ti| forest.trees[ti].clone()).collect();
        if si == 0 {
            sub.base_score = forest.base_score.clone();
        }
        let engine = build(kind, precision, &sub, quant)?;
        stages.push(Stage { engine, n_trees: k });
        at += k;
    }

    // Suffix bounds at each stage boundary. The slack covers every f32
    // rounding step between the partial sum inspected at the boundary and
    // the final sum: ≤ (trees + stage adds + base) additions per class,
    // each off by ≤ ε·|operand| — bounded with generous headroom (§11).
    let hi_total: f64 = hi_t.iter().sum();
    let base_abs = forest.base_score.iter().fold(0f64, |m, &b| m.max((b as f64).abs()));
    let adds = (t + 2 * sizes.len() + 4) as f64;
    let n_stages = sizes.len();
    let mut gap_after = vec![0f64; n_stages];
    let mut slack_after = vec![0f64; n_stages];
    let mut boundary = t; // trees scored once stage `i` completes
    let mut suffix_gap = 0f64;
    let mut suffix_hi = 0f64;
    for i in (0..n_stages).rev() {
        gap_after[i] = suffix_gap;
        slack_after[i] =
            adds * 4.0 * (f32::EPSILON as f64) * (hi_total + base_abs + suffix_hi + 1.0) + 1e-9;
        boundary -= sizes[i];
        for j in boundary..boundary + sizes[i] {
            suffix_gap += gap_t[j];
            suffix_hi += hi_t[j];
        }
    }

    let prefix = match mode {
        EarlyExitMode::Off => "e0",
        EarlyExitMode::Exact => "ee",
        EarlyExitMode::Approx => "ea",
    };
    let lanes = stages[0].engine.lanes();
    Ok(EarlyExitEngine {
        name: format!("{prefix}{}", variant_name(kind, precision)),
        stages,
        gap_after,
        slack_after,
        mode,
        frac: DEFAULT_APPROX_FRAC,
        order,
        n_features: d,
        n_classes: c,
        total_trees: t,
        lanes,
        rows_scored: AtomicU64::new(0),
        trees_evaluated: AtomicU64::new(0),
    })
}

/// Trees sorted most-confident first: by calibration argmax agreement with
/// the full-forest float argmax (descending), ties by original index.
/// Identity order when the calibration batch is empty.
fn confidence_order(forest: &Forest, calibration: &[f32]) -> Vec<usize> {
    let d = forest.n_features;
    let c = forest.n_classes;
    let n = if d == 0 { 0 } else { calibration.len() / d };
    let mut order: Vec<usize> = (0..forest.n_trees()).collect();
    if n == 0 {
        return order;
    }
    let reference = Forest::argmax(&forest.predict_batch(calibration), c);
    let mut agree = vec![0usize; forest.n_trees()];
    for (i, row) in calibration.chunks(d).enumerate() {
        for (ti, tree) in forest.trees.iter().enumerate() {
            let leaf = tree.leaf_row(tree.exit_leaf(row));
            // Same strict-`>` first-index tie-break as `Forest::argmax`.
            let mut best = 0usize;
            for (j, &v) in leaf.iter().enumerate() {
                if v > leaf[best] {
                    best = j;
                }
            }
            if best as u32 == reference[i] {
                agree[ti] += 1;
            }
        }
    }
    order.sort_by(|&a, &b| agree[b].cmp(&agree[a]).then(a.cmp(&b)));
    order
}

impl EarlyExitEngine {
    /// Override the approx-mode margin fraction (ignored in other modes).
    pub fn with_frac(mut self, frac: f64) -> Self {
        self.frac = frac.max(0.0);
        self
    }

    pub fn mode(&self) -> EarlyExitMode {
        self.mode
    }

    /// The calibration-derived tree order (original indices).
    pub fn order(&self) -> &[usize] {
        &self.order
    }

    pub fn stage_sizes(&self) -> Vec<usize> {
        self.stages.iter().map(|s| s.n_trees).collect()
    }

    pub fn total_trees(&self) -> usize {
        self.total_trees
    }

    /// Cumulative `(rows scored, tree evaluations)` since build/reset. One
    /// tree evaluation = one tree applied to one row, so full scoring costs
    /// `rows × total_trees`.
    pub fn counters(&self) -> (u64, u64) {
        (self.rows_scored.load(Ordering::Relaxed), self.trees_evaluated.load(Ordering::Relaxed))
    }

    pub fn reset_counters(&self) {
        self.rows_scored.store(0, Ordering::Relaxed);
        self.trees_evaluated.store(0, Ordering::Relaxed);
    }

    /// Mean trees evaluated per row since build/reset (= `total_trees`
    /// when nothing exited).
    pub fn mean_trees_evaluated(&self) -> f64 {
        let (rows, trees) = self.counters();
        if rows == 0 {
            0.0
        } else {
            trees as f64 / rows as f64
        }
    }

    /// Margin of the current leader over the runner-up, in f64 over the f32
    /// partial sums. Non-finite sums yield a non-exiting margin (fail-safe:
    /// the row scores the whole forest).
    fn margin(row: &[f32]) -> f64 {
        let mut best = f64::NEG_INFINITY;
        let mut second = f64::NEG_INFINITY;
        for &v in row {
            let v = v as f64;
            if v > best {
                second = best;
                best = v;
            } else if v > second {
                second = v;
            }
        }
        best - second
    }
}

impl Engine for EarlyExitEngine {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn lanes(&self) -> usize {
        self.lanes
    }

    fn n_features(&self) -> usize {
        self.n_features
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let d = self.n_features;
        let c = self.n_classes;
        let n = if d == 0 { 0 } else { x.len() / d };
        let mut trees = 0u64;
        let mut active: Vec<usize> = (0..n).collect();
        let mut xs: Vec<f32> = Vec::new();
        let mut os: Vec<f32> = Vec::new();
        for (si, stage) in self.stages.iter().enumerate() {
            if active.is_empty() {
                break;
            }
            trees += (stage.n_trees * active.len()) as u64;
            if si == 0 {
                // Every row is active: the stage engine overwrites `out`
                // directly (base score included), no gather needed.
                stage.engine.predict_batch(x, out);
            } else {
                xs.clear();
                for &r in &active {
                    xs.extend_from_slice(&x[r * d..(r + 1) * d]);
                }
                os.clear();
                os.resize(active.len() * c, 0.0);
                stage.engine.predict_batch(&xs, &mut os);
                for (k, &r) in active.iter().enumerate() {
                    for j in 0..c {
                        out[r * c + j] += os[k * c + j];
                    }
                }
            }
            if si + 1 == self.stages.len() {
                break;
            }
            let bound = match self.mode {
                EarlyExitMode::Off => continue,
                EarlyExitMode::Exact => self.gap_after[si] + self.slack_after[si],
                EarlyExitMode::Approx => self.frac * self.gap_after[si],
            };
            // Strict `>`: at the bound the runner-up could still tie, and a
            // tie must resolve by final index order, not by exit timing.
            // NaN margins compare false and fall through to full scoring.
            active.retain(|&r| !(Self::margin(&out[r * c..(r + 1) * c]) > bound));
        }
        self.rows_scored.fetch_add(n as u64, Ordering::Relaxed);
        self.trees_evaluated.fetch_add(trees, Ordering::Relaxed);
    }

    fn memory_bytes(&self) -> usize {
        self.stages.iter().map(|s| s.engine.memory_bytes()).sum()
    }

    fn cost_counters(&self) -> Option<(u64, u64)> {
        Some(self.counters())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::Tree;

    /// A forest of depth-0 trees (every row hits leaf 0) — exercises the
    /// staging/margin machinery without training, so Miri can run it.
    fn leaf_forest(leaves: &[&[f32]]) -> Forest {
        let c = leaves[0].len();
        let mut f = Forest::new(2, c, Task::Classification);
        for l in leaves {
            f.trees.push(Tree::leaf(l.to_vec()));
        }
        f
    }

    #[test]
    fn mode_names_roundtrip() {
        for m in [EarlyExitMode::Off, EarlyExitMode::Exact, EarlyExitMode::Approx] {
            assert_eq!(EarlyExitMode::from_name(m.name()), Some(m));
        }
        assert_eq!(EarlyExitMode::from_name("EXACT"), Some(EarlyExitMode::Exact));
        assert_eq!(EarlyExitMode::from_name("nope"), None);
    }

    #[test]
    fn rejects_non_classification_and_empty() {
        let f = Forest::new(2, 1, Task::Ranking);
        assert!(build_early_exit(EngineKind::Naive, Precision::F32, &f, &[], EarlyExitMode::Exact)
            .is_err());
        let empty = Forest::new(2, 2, Task::Classification);
        assert!(
            build_early_exit(EngineKind::Naive, Precision::F32, &empty, &[], EarlyExitMode::Exact)
                .is_err()
        );
    }

    #[test]
    fn exact_exits_after_dominant_tree() {
        // One decisive tree + 7 tiny corrections: after stage 0 (1 tree)
        // the margin (100) provably exceeds everything the remaining trees
        // can move (7 × 0.001), so every row exits at the first boundary.
        let mut leaves: Vec<&[f32]> = vec![&[100.0, 0.0]];
        let tiny: &[f32] = &[0.001, 0.0];
        leaves.extend(std::iter::repeat(tiny).take(7));
        let f = leaf_forest(&leaves);
        let ee = build_early_exit(EngineKind::Naive, Precision::F32, &f, &[], EarlyExitMode::Exact)
            .unwrap();
        assert_eq!(ee.stage_sizes(), vec![1, 2, 4, 1]);
        let x = [0.3f32, 0.7, 0.9, 0.1, 0.5, 0.5];
        let scores = ee.predict(&x);
        let (rows, trees) = ee.counters();
        assert_eq!(rows, 3);
        assert_eq!(trees, 3, "every row must exit after the 1-tree stage 0");
        assert!(ee.mean_trees_evaluated() < f.n_trees() as f64);
        // Argmax identical to scoring every stage.
        let off = build_early_exit(EngineKind::Naive, Precision::F32, &f, &[], EarlyExitMode::Off)
            .unwrap();
        assert_eq!(
            Forest::argmax(&scores, 2),
            Forest::argmax(&off.predict(&x), 2)
        );
        assert_eq!(off.counters().1, 3 * f.n_trees() as u64);
    }

    #[test]
    fn tie_margin_forest_never_exits_early() {
        // Two classes within one leaf weight everywhere: the margin can
        // never provably clear the remaining mass, so exact mode scores the
        // whole forest and the tie resolves by index — never by exit
        // timing.
        let l: &[f32] = &[0.5, 0.5];
        let f = leaf_forest(&vec![l; 6]);
        let ee = build_early_exit(EngineKind::Naive, Precision::F32, &f, &[], EarlyExitMode::Exact)
            .unwrap();
        let x = [0.1f32, 0.9, 0.6, 0.4];
        let scores = ee.predict(&x);
        assert_eq!(ee.counters(), (2, 12), "tie rows must score all 6 trees");
        assert_eq!(Forest::argmax(&scores, 2), vec![0, 0]);
    }

    #[test]
    fn calibration_orders_agreeing_trees_first() {
        // Full-forest argmax is class 0; t0 votes class 1 and must sort
        // last despite being first in the forest.
        let f = leaf_forest(&[&[0.0, 1.0], &[5.0, 0.0], &[1.0, 0.0]]);
        let calibration = [0.2f32, 0.8, 0.7, 0.3];
        let ee = build_early_exit(
            EngineKind::Naive,
            Precision::F32,
            &f,
            &calibration,
            EarlyExitMode::Exact,
        )
        .unwrap();
        assert_eq!(ee.order(), &[1, 2, 0]);
        assert_eq!(ee.stage_sizes().iter().sum::<usize>(), 3);
        // Empty calibration keeps the identity order.
        let id =
            build_early_exit(EngineKind::Naive, Precision::F32, &f, &[], EarlyExitMode::Exact)
                .unwrap();
        assert_eq!(id.order(), &[0, 1, 2]);
    }

    #[test]
    fn approx_frac_and_names() {
        let f = leaf_forest(&[&[1.0, 0.0], &[0.4, 0.2], &[0.3, 0.1]]);
        let ee = build_early_exit(EngineKind::Naive, Precision::F32, &f, &[], EarlyExitMode::Approx)
            .unwrap()
            .with_frac(0.1);
        assert_eq!(ee.name(), "eaNA");
        assert_eq!(ee.mode(), EarlyExitMode::Approx);
        let exact =
            build_early_exit(EngineKind::Rs, Precision::I8, &f, &[], EarlyExitMode::Exact).unwrap();
        assert_eq!(exact.name(), "eeq8RS");
        let off =
            build_early_exit(EngineKind::Vqs, Precision::I16, &f, &[], EarlyExitMode::Off).unwrap();
        assert_eq!(off.name(), "e0qVQS");
    }

    #[test]
    fn counters_reset_and_cost_counters_surface() {
        let f = leaf_forest(&[&[2.0, 0.0], &[0.1, 0.0]]);
        let ee = build_early_exit(EngineKind::Naive, Precision::F32, &f, &[], EarlyExitMode::Exact)
            .unwrap();
        let _ = ee.predict(&[0.5, 0.5]);
        assert_eq!(ee.cost_counters(), Some(ee.counters()));
        assert!(ee.counters().0 > 0);
        ee.reset_counters();
        assert_eq!(ee.counters(), (0, 0));
        assert_eq!(ee.mean_trees_evaluated(), 0.0);
    }

    /// Exact mode must agree with Off (same stages, no exits) on trained
    /// forests for every registry variant — the in-module edition of the
    /// `early_exit_exact.rs` property suite's core claim.
    #[test]
    #[cfg_attr(miri, ignore)] // heavy: trains a forest; no unsafe for Miri to check
    fn exact_matches_off_across_variants() {
        use crate::data::DatasetId;
        use crate::forest::builder::{train_random_forest, RfParams, TreeParams};
        let ds = DatasetId::Magic.generate(500, 0xEE9);
        let f = train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees: 12,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 0 },
                ..Default::default()
            },
        );
        let calibration = &ds.x[..ds.d * 64];
        let x = &ds.x[ds.d * 64..ds.d * 192];
        for (kind, precision) in crate::engine::all_variants_with_i8() {
            let ee = build_early_exit(kind, precision, &f, calibration, EarlyExitMode::Exact)
                .unwrap();
            let off =
                build_early_exit(kind, precision, &f, calibration, EarlyExitMode::Off).unwrap();
            assert_eq!(
                Forest::argmax(&ee.predict(x), f.n_classes),
                Forest::argmax(&off.predict(x), f.n_classes),
                "{}: exact argmax diverged from full scoring",
                ee.name()
            );
            assert!(ee.mean_trees_evaluated() <= f.n_trees() as f64);
        }
    }
}
