//! Version-matrix bench registry: named engine configurations pinned to
//! the PR that introduced them, so historical tiers stay measurable next
//! to new ones (`bench --exp smoke --matrix` times every entry and appends
//! one perf-history series per config). Enum-iterated — adding a tier
//! means adding a variant here, and every count/coverage assertion derives
//! from [`MatrixConfig::ALL`], never from a literal.

use crate::engine::{self, Engine, EngineKind, Precision};
use crate::forest::Forest;

/// One named configuration in the version matrix. Each maps to the
/// (engine kind, precision, build path) that headlined the PR it is named
/// after; the build paths are the same public entry points the CLI and
/// selector use, so a matrix row measures exactly what that PR shipped.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MatrixConfig {
    /// PR 1 baseline: RapidScorer at plain f32.
    Pr1F32,
    /// PR 2 int16 tier with the saturation-fixed *global* §5 scale.
    Pr2I16Global,
    /// PR 4 int8 tier under the `quantize_i8_auto` policy (global scale,
    /// upgraded to per-tree leaf scales exactly when that provably restores
    /// a native i8 accumulator).
    Pr4I8PerTree,
    /// PR 5 int16 tier with per-tree leaf scales
    /// ([`engine::build_i16_per_tree`]).
    Pr5I16PerTree,
    /// PR 8 FLInt carrier tier: integer threshold compares, f32 leaves,
    /// bit-identical to [`MatrixConfig::Pr1F32`].
    Pr8Flint,
}

impl MatrixConfig {
    /// Every config, oldest first — the iteration order of the matrix
    /// table and of the `matrix/<name>` perf-history series.
    pub const ALL: [MatrixConfig; 5] = [
        MatrixConfig::Pr1F32,
        MatrixConfig::Pr2I16Global,
        MatrixConfig::Pr4I8PerTree,
        MatrixConfig::Pr5I16PerTree,
        MatrixConfig::Pr8Flint,
    ];

    /// Stable series name (also the table row label).
    pub fn name(&self) -> &'static str {
        match self {
            MatrixConfig::Pr1F32 => "pr1-f32",
            MatrixConfig::Pr2I16Global => "pr2-i16-global",
            MatrixConfig::Pr4I8PerTree => "pr4-i8-per-tree",
            MatrixConfig::Pr5I16PerTree => "pr5-i16-per-tree",
            MatrixConfig::Pr8Flint => "pr8-flint",
        }
    }

    /// Traversal strategy this config times. Quantized tiers use VQS (the
    /// SIMD engine their PRs centered on); float-semantics tiers use RS
    /// (the paper's headline engine).
    pub fn kind(&self) -> EngineKind {
        match self {
            MatrixConfig::Pr1F32 | MatrixConfig::Pr8Flint => EngineKind::Rs,
            MatrixConfig::Pr2I16Global => EngineKind::Rs,
            MatrixConfig::Pr4I8PerTree | MatrixConfig::Pr5I16PerTree => EngineKind::Vqs,
        }
    }

    /// Numeric tier of this config.
    pub fn precision(&self) -> Precision {
        match self {
            MatrixConfig::Pr1F32 => Precision::F32,
            MatrixConfig::Pr2I16Global | MatrixConfig::Pr5I16PerTree => Precision::I16,
            MatrixConfig::Pr4I8PerTree => Precision::I8,
            MatrixConfig::Pr8Flint => Precision::F32Flint,
        }
    }

    /// Build the configured engine through the same entry point the PR
    /// shipped: `engine::build` with `quant=None` (global i16 scale /
    /// auto-policy i8), or the dedicated per-tree i16 path.
    pub fn build(&self, forest: &Forest) -> anyhow::Result<Box<dyn Engine>> {
        match self {
            MatrixConfig::Pr5I16PerTree => engine::build_i16_per_tree(self.kind(), forest),
            _ => engine::build(self.kind(), self.precision(), forest, None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;

    fn small_forest() -> Forest {
        let ds = DatasetId::Magic.generate(256, 0xA7);
        let (train, _) = ds.split(0.2, 7);
        super::super::harness::cached_rf(&train, 4, 16)
    }

    #[test]
    fn names_are_unique_and_stable() {
        let names: Vec<&str> = MatrixConfig::ALL.iter().map(|c| c.name()).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), MatrixConfig::ALL.len(), "duplicate matrix names");
        // The registry is the source of truth for downstream series names —
        // renaming a config orphans its perf history, so pin the set.
        assert!(names.contains(&"pr2-i16-global"));
        assert!(names.contains(&"pr4-i8-per-tree"));
        assert!(names.contains(&"pr8-flint"));
    }

    #[test]
    fn every_config_builds_and_predicts() {
        let f = small_forest();
        let x: Vec<f32> = (0..4 * f.n_features).map(|i| (i as f32 * 0.37).sin()).collect();
        for c in MatrixConfig::ALL {
            let e = c.build(&f).unwrap_or_else(|e| panic!("{} failed to build: {e}", c.name()));
            assert_eq!(
                e.name(),
                engine::variant_name(c.kind(), c.precision()),
                "{} built the wrong variant",
                c.name()
            );
            let y = e.predict(&x);
            assert_eq!(y.len(), 4 * f.n_classes);
            assert!(y.iter().all(|v| v.is_finite()), "{} non-finite scores", c.name());
        }
    }

    #[test]
    fn flint_config_is_bit_identical_to_f32_config() {
        let f = small_forest();
        let x: Vec<f32> = (0..16 * f.n_features).map(|i| (i as f32 * 0.61).cos()).collect();
        let ef = MatrixConfig::Pr1F32.build(&f).unwrap();
        let efl = MatrixConfig::Pr8Flint.build(&f).unwrap();
        assert_eq!(ef.predict(&x), efl.predict(&x), "pr8-flint must match pr1-f32 bit-for-bit");
    }
}
