//! Benchmark harness (DESIGN.md system S17): workload preparation, timing,
//! table formatting, and the experiment implementations that regenerate
//! every table and figure of the paper's evaluation (§6).
//!
//! `criterion` is unavailable offline, so `rust/benches/*.rs` are
//! `harness = false` binaries that call into [`experiments`]; results print
//! to stdout and are archived under `results/`.

pub mod experiments;
pub mod harness;
pub mod matrix;

pub use harness::{time_per_instance, Scale, TableWriter};
