//! Experiment implementations — one function per paper table/figure
//! (DESIGN.md §3 experiment index). Each returns the rendered text that the
//! `rust/benches/*` binaries print and archive under `results/`.
//!
//! Per-device numbers are cost-model *estimates* (we have no ARM hardware;
//! see `crate::device`); the "host" group is measured wall-clock on this
//! machine. The claims under reproduction are relative orderings and
//! speedup factors, not absolute µs.

use crate::data::{ranking::msn_like, DatasetId};
use crate::device::{model_working_set, DeviceProfile};
use crate::engine::{all_variants, variant_name, Engine, EngineKind, Precision};
use crate::forest::Forest;
use crate::quant::{
    accuracy_with_parts, choose_scale, choose_scale_i8, merge, QForest, QuantConfig, QuantParts,
};
use crate::stats::cd_analysis;

use super::harness::{
    build_engine_arc, cached_gbt_ranking, classification_workloads, eval_batch,
    forest_prefix, time_per_instance, Scale, TableWriter,
};

/// µs/instance for one engine: host measurement + per-device estimates.
struct Timing {
    host: f64,
    devices: Vec<f64>,
}

fn measure(
    engine: &dyn Engine,
    x: &[f32],
    forest: &Forest,
    precision: Precision,
    devices: &[DeviceProfile],
    repeats: usize,
) -> Timing {
    let host = time_per_instance(engine, x, repeats);
    let n = x.len() / engine.n_features();
    // Trace a subset (counting walks are slow) and scale per instance.
    let trace_n = n.clamp(1, 128);
    let trace = engine.count_ops(&x[..trace_n * engine.n_features()]);
    let bytes = precision.scalar_bytes();
    let ws = model_working_set(
        forest.n_nodes(),
        forest.n_trees(),
        forest.max_leaves().next_power_of_two().max(32),
        forest.n_classes,
        bytes,
    );
    let devices = devices
        .iter()
        .map(|d| d.estimate_us(&trace, ws) / trace_n as f64)
        .collect();
    Timing { host, devices }
}

fn fmt_speedup(us: f64, na_us: f64) -> String {
    format!("{us:.1} ({:.1}x)", na_us / us)
}

// ---------------------------------------------------------------------------
// Table 2 — ranking runtimes (MSN-like GBT, float engines)
// ---------------------------------------------------------------------------

/// Paper Table 2: runtime per instance for QS/VQS/RS/IE/NA on the ranking
/// forests, per device, over tree counts × {32, 64} leaves.
pub fn table2(scale: &Scale) -> String {
    let devices = DeviceProfile::paper_devices();
    let kinds = [EngineKind::Rs, EngineKind::Vqs, EngineKind::Qs, EngineKind::IfElse, EngineKind::Naive];
    let eval = msn_like(scale.eval_n / 10 + 1, 10, 0xEE);
    let mut out = String::new();
    out.push_str(&format!(
        "Table 2 reproduction (scale={}, trees={:?})\n\
         ranking runtime per instance in µs (speedup vs NA in parens)\n\n",
        scale.name, scale.ranking_trees
    ));

    // Train the largest forest once per leaf count; prefixes give the rest.
    for &leaves in &[32usize, 64] {
        let max_trees = *scale.ranking_trees.iter().max().unwrap();
        let full = cached_gbt_ranking(scale.msn_queries, scale.msn_docs, max_trees, leaves);
        // rows: per device then host; columns: tree counts.
        for (di, dev_name) in devices
            .iter()
            .map(|d| d.name.to_string())
            .chain(["host (measured)".to_string()])
            .enumerate()
        {
            out.push_str(&format!("== L={leaves}  {dev_name} ==\n"));
            let mut tw = TableWriter::new(vec![5; 1 + scale.ranking_trees.len()].into_iter()
                .enumerate().map(|(i, _)| if i == 0 { 5 } else { 18 }).collect());
            let mut header = vec!["".to_string()];
            header.extend(scale.ranking_trees.iter().map(|t| t.to_string()));
            tw.row(&header);
            tw.sep();

            // Collect timings: engine × tree-count.
            let mut na_times = vec![0f64; scale.ranking_trees.len()];
            let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
            for kind in kinds {
                let mut vals = Vec::new();
                for &nt in &scale.ranking_trees {
                    let f = forest_prefix(&full, nt);
                    let Some(engine) = build_engine_arc(kind, Precision::F32, &f) else {
                        vals.push(f64::NAN);
                        continue;
                    };
                    let x = &eval.x[..scale.eval_n.min(eval.n) * eval.d];
                    let t = measure(engine.as_ref(), x, &f, Precision::F32, &devices, scale.repeats);
                    let us = if di < devices.len() { t.devices[di] } else { t.host };
                    vals.push(us);
                }
                if kind == EngineKind::Naive {
                    na_times = vals.clone();
                }
                rows.push((kind.short().to_string(), vals));
            }
            for (name, vals) in rows {
                let mut cells = vec![name.clone()];
                for (i, &v) in vals.iter().enumerate() {
                    cells.push(if name == "NA" {
                        format!("{v:.1} (-)")
                    } else {
                        fmt_speedup(v, na_times[i])
                    });
                }
                tw.row(&cells);
            }
            out.push_str(&tw.finish());
            out.push('\n');
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Table 3 — accuracy under quantization
// ---------------------------------------------------------------------------

/// Paper Table 3: accuracy of the four {float,int16}² split/leaf combos.
pub fn table3(scale: &Scale) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Table 3 reproduction (scale={}, RF {} trees, 64 leaves, s=2^15)\n\n",
        scale.name, scale.cls_trees
    ));
    let mut tw = TableWriter::new(vec![8, 14, 14, 14, 14]);
    tw.row_str(&["dataset", "f-split/f-leaf", "f-split/q-leaf", "q-split/f-leaf", "q-split/q-leaf"]);
    tw.sep();
    let cfg = QuantConfig::paper_default();
    for id in DatasetId::ALL {
        let ds = id.generate(id.default_n(), 0xD5 ^ 64);
        let (train, test) = ds.split(0.2, 7);
        let f = super::harness::cached_rf(&train, scale.cls_trees, 64);
        let accs = [
            QuantParts::NONE,
            QuantParts::LEAVES_ONLY,
            QuantParts::SPLITS_ONLY,
            QuantParts::BOTH,
        ]
        .map(|p| accuracy_with_parts(&f, cfg, p, &test.x, &test.labels));
        tw.row(&[
            id.name().to_string(),
            format!("{:.2}%", accs[0] * 100.0),
            format!("{:.2}%", accs[1] * 100.0),
            format!("{:.2}%", accs[2] * 100.0),
            format!("{:.2}%", accs[3] * 100.0),
        ]);
    }
    out.push_str(&tw.finish());
    out
}

// ---------------------------------------------------------------------------
// Table 4 — node merging
// ---------------------------------------------------------------------------

/// Paper Table 4: % unique nodes kept after RapidScorer merging, float vs
/// quantized, over tree counts.
pub fn table4(scale: &Scale) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Table 4 reproduction (scale={}, trees={:?}, 64 leaves)\n\
         %% of unique nodes kept after merging equivalent nodes\n\n",
        scale.name, scale.merge_trees
    ));
    let mut tw = TableWriter::new(vec![8, 6, 9, 9, 9, 9]);
    let mut header = vec!["dataset".to_string(), "type".to_string()];
    header.extend(scale.merge_trees.iter().map(|t| t.to_string()));
    tw.row(&header);
    tw.sep();
    let cfg = QuantConfig::paper_default();
    let max_trees = *scale.merge_trees.iter().max().unwrap();
    for id in DatasetId::ALL {
        let ds = id.generate(id.default_n(), 0xD5 ^ 64);
        let (train, _) = ds.split(0.2, 7);
        let full = super::harness::cached_rf(&train, max_trees.max(scale.cls_trees), 64);
        for (ty, quant) in [("float", false), ("quant", true)] {
            let mut cells = vec![id.name().to_string(), ty.to_string()];
            for &nt in &scale.merge_trees {
                let f = forest_prefix(&full, nt);
                let frac = if quant {
                    merge::unique_node_fraction_quant(&QForest::from_forest(&f, cfg))
                } else {
                    merge::unique_node_fraction(&f)
                };
                cells.push(format!("{:.1}%", frac * 100.0));
            }
            tw.row(&cells);
        }
    }
    out.push_str(&tw.finish());
    out
}

// ---------------------------------------------------------------------------
// Table 5 — classification runtimes (10 engines × 5 datasets × devices)
// ---------------------------------------------------------------------------

/// The Table-5 measurement matrix: per device (+host), engine × dataset
/// µs/instance. Shared by `table5` and `fig2`.
pub struct Table5Data {
    pub engines: Vec<String>,
    pub datasets: Vec<String>,
    /// `[device][engine][dataset]` µs/instance; devices = paper devices ++ host.
    pub us: Vec<Vec<Vec<f64>>>,
    pub device_names: Vec<String>,
}

pub fn table5_data(scale: &Scale, max_leaves: usize) -> Table5Data {
    let devices = DeviceProfile::paper_devices();
    let workloads = classification_workloads(scale, max_leaves);
    let variants = all_variants();
    let engines: Vec<String> =
        variants.iter().map(|&(k, p)| variant_name(k, p)).collect();
    let datasets: Vec<String> = workloads.iter().map(|(ds, _)| ds.name.clone()).collect();
    let n_dev = devices.len() + 1;
    let mut us = vec![vec![vec![f64::NAN; datasets.len()]; engines.len()]; n_dev];

    for (dsi, (ds, f)) in workloads.iter().enumerate() {
        let x = eval_batch(ds, scale.eval_n);
        for (ei, &(kind, precision)) in variants.iter().enumerate() {
            let Some(engine) = build_engine_arc(kind, precision, f) else { continue };
            let t = measure(engine.as_ref(), &x, f, precision, &devices, scale.repeats);
            for di in 0..devices.len() {
                us[di][ei][dsi] = t.devices[di];
            }
            us[devices.len()][ei][dsi] = t.host;
        }
    }
    let mut device_names: Vec<String> = devices.iter().map(|d| d.name.to_string()).collect();
    device_names.push("host (measured)".into());
    Table5Data { engines, datasets, us, device_names }
}

/// Paper Table 5: classification runtime/instance, all ten engine variants.
pub fn table5(scale: &Scale, max_leaves: usize) -> String {
    let data = table5_data(scale, max_leaves);
    let mut out = String::new();
    out.push_str(&format!(
        "Table 5 reproduction (scale={}, RF {} trees, {max_leaves} leaves)\n\
         runtime per instance in µs (speedup vs float NA in parens)\n\n",
        scale.name, scale.cls_trees
    ));
    let na_idx = data.engines.iter().position(|e| e == "NA").unwrap();
    for (di, dev) in data.device_names.iter().enumerate() {
        out.push_str(&format!("== {dev} ==\n"));
        let mut widths = vec![6usize];
        widths.extend(std::iter::repeat(16).take(data.datasets.len()));
        let mut tw = TableWriter::new(widths);
        let mut header = vec!["".to_string()];
        header.extend(data.datasets.iter().cloned());
        tw.row(&header);
        tw.sep();
        for (ei, en) in data.engines.iter().enumerate() {
            let mut cells = vec![en.clone()];
            for dsi in 0..data.datasets.len() {
                let v = data.us[di][ei][dsi];
                let na = data.us[di][na_idx][dsi];
                cells.push(if en == "NA" {
                    format!("{v:.1} (-)")
                } else {
                    fmt_speedup(v, na)
                });
            }
            tw.row(&cells);
        }
        out.push_str(&tw.finish());
        out.push('\n');
    }
    out
}

// ---------------------------------------------------------------------------
// Figure 1 — average speedup over tree counts
// ---------------------------------------------------------------------------

/// Paper Figure 1: mean speedup over NA as a function of the number of
/// trees; float panel (top) and quantized panel (bottom). Averaged over the
/// 5 datasets × {32, 64} leaves × the two device estimates.
pub fn fig1(scale: &Scale) -> String {
    let devices = DeviceProfile::paper_devices();
    let variants = all_variants();
    let mut out = String::new();
    out.push_str(&format!(
        "Figure 1 reproduction (scale={}, trees={:?})\n\
         mean speedup vs float NA (± std) across 5 datasets x {{32,64}} leaves x 2 devices\n\n",
        scale.name, scale.fig_trees
    ));

    // Pre-train the max forest per (dataset, leaves).
    let max_trees = *scale.fig_trees.iter().max().unwrap();
    let mut workloads = Vec::new();
    for &leaves in &[32usize, 64] {
        for id in DatasetId::ALL {
            let ds = id.generate(id.default_n(), 0xD5 ^ leaves as u64);
            let (train, _) = ds.split(0.2, 7);
            let f = super::harness::cached_rf(&train, max_trees.max(scale.cls_trees), leaves);
            workloads.push((ds, f));
        }
    }

    for (panel, precisions) in
        [("float engines", Precision::F32), ("quantized engines", Precision::I16)]
    {
        out.push_str(&format!("-- {panel} --\n"));
        let mut widths = vec![7usize];
        widths.extend(std::iter::repeat(14).take(variants.len() / 2));
        let mut tw = TableWriter::new(widths);
        let names: Vec<String> = variants
            .iter()
            .filter(|&&(_, p)| p == precisions)
            .map(|&(k, p)| variant_name(k, p))
            .collect();
        let mut header = vec!["trees".to_string()];
        header.extend(names.iter().cloned());
        tw.row(&header);
        tw.sep();
        for &nt in &scale.fig_trees {
            let mut cells = vec![nt.to_string()];
            for &(kind, precision) in variants.iter().filter(|&&(_, p)| p == precisions) {
                let mut speedups = Vec::new();
                for (ds, full) in &workloads {
                    let f = forest_prefix(full, nt);
                    let x = eval_batch(ds, scale.eval_n / 2);
                    let Some(engine) = build_engine_arc(kind, precision, &f) else { continue };
                    let Some(na) = build_engine_arc(EngineKind::Naive, Precision::F32, &f)
                    else {
                        continue;
                    };
                    let te = measure(engine.as_ref(), &x, &f, precision, &devices, scale.repeats);
                    let tn = measure(na.as_ref(), &x, &f, Precision::F32, &devices, scale.repeats);
                    for di in 0..devices.len() {
                        speedups.push(tn.devices[di] / te.devices[di]);
                    }
                }
                let n = speedups.len() as f64;
                let mean = speedups.iter().sum::<f64>() / n;
                let std = (speedups.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n)
                    .sqrt();
                cells.push(format!("{mean:.2}±{std:.2}"));
            }
            tw.row(&cells);
        }
        out.push_str(&tw.finish());
        out.push('\n');
    }
    out
}

// ---------------------------------------------------------------------------
// Figure 2 — critical-difference diagrams
// ---------------------------------------------------------------------------

/// Paper Figure 2: CD diagram of the ten engines per device, ranks over the
/// classification datasets (5 datasets × {32, 64} leaves = 10 rows).
pub fn fig2(scale: &Scale) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Figure 2 reproduction (scale={}): critical-difference diagrams\n\
         (avg rank of runtime/instance; lower rank = faster; p = 0.95)\n\n",
        scale.name
    ));
    let d32 = table5_data(scale, 32);
    let d64 = table5_data(scale, 64);
    for (di, dev) in d32.device_names.iter().enumerate() {
        // rows = dataset × leaves, columns = engines
        let mut rows = Vec::new();
        for data in [&d32, &d64] {
            for dsi in 0..data.datasets.len() {
                rows.push(
                    (0..data.engines.len()).map(|ei| data.us[di][ei][dsi]).collect::<Vec<f64>>(),
                );
            }
        }
        let cd = cd_analysis(&d32.engines, &rows, 0.05);
        out.push_str(&format!("== {dev} ==\n{}\n", cd.render()));
    }
    out
}

// ---------------------------------------------------------------------------
// Ablation — RapidScorer design choices
// ---------------------------------------------------------------------------

/// Extra B: RS ablation — node merging on/off, vs VQS (no epitome/transpose)
/// and QS (scalar). Shows where RapidScorer's wins come from.
pub fn ablation_rs(scale: &Scale) -> String {
    use crate::engine::rapidscorer::RsEngine;
    let mut out = String::new();
    out.push_str(&format!(
        "RS ablation (scale={}): merging & layout contributions, host µs/instance\n\n",
        scale.name
    ));
    let mut tw = TableWriter::new(vec![8, 10, 14, 12, 10, 10]);
    tw.row_str(&["dataset", "RS", "RS(no-merge)", "groups/nodes", "VQS", "QS"]);
    tw.sep();
    for id in [DatasetId::Adult, DatasetId::Eeg, DatasetId::Magic, DatasetId::Mnist] {
        let ds = id.generate(id.default_n(), 0xAB);
        let (train, _) = ds.split(0.2, 7);
        let f = super::harness::cached_rf(&train, scale.cls_trees, 64);
        let x = eval_batch(&ds, scale.eval_n);
        let rs = RsEngine::new(&f);
        let rs_nm = RsEngine::new_unmerged(&f);
        let vqs = build_engine_arc(EngineKind::Vqs, Precision::F32, &f).unwrap();
        let qs = build_engine_arc(EngineKind::Qs, Precision::F32, &f).unwrap();
        let t_rs = time_per_instance(&rs, &x, scale.repeats);
        let t_nm = time_per_instance(&rs_nm, &x, scale.repeats);
        let t_v = time_per_instance(vqs.as_ref(), &x, scale.repeats);
        let t_q = time_per_instance(qs.as_ref(), &x, scale.repeats);
        tw.row(&[
            id.name().to_string(),
            format!("{t_rs:.1}"),
            format!("{t_nm:.1}"),
            format!("{:.1}%", 100.0 * rs.model().n_groups() as f64 / f.n_nodes() as f64),
            format!("{t_v:.1}"),
            format!("{t_q:.1}"),
        ]);
    }
    out.push_str(&tw.finish());
    out
}

// ---------------------------------------------------------------------------
// Extra A — rust engines vs the AOT tensor path
// ---------------------------------------------------------------------------

/// Extra A: native Rust engines vs the XLA tensor engine on the artifact
/// fixture forest (requires `make artifacts`).
pub fn tensor_vs_native(repeats: usize) -> anyhow::Result<String> {
    use crate::engine::tensor::TensorEngine;
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    let metas = crate::runtime::load_manifest(&dir)?;
    let meta = metas
        .iter()
        .find(|m| m.name == "rf_f32_b64")
        .ok_or_else(|| anyhow::anyhow!("fixture artifact missing"))?;
    let forest = crate::forest::io::load(&dir.join(&meta.forest))?;

    let mut rng = crate::util::Pcg32::seeded(0xAA);
    let n = meta.batch * 8;
    let x: Vec<f32> = (0..n * forest.n_features).map(|_| rng.f32()).collect();

    let mut out = String::new();
    out.push_str("Tensor (XLA/PJRT, AOT pallas kernel) vs native Rust engines\n");
    out.push_str(&format!(
        "fixture: M={} L={} d={} C={} batch={}\n\n",
        meta.n_trees, meta.leaf_words, meta.d, meta.c, meta.batch
    ));
    let mut tw = TableWriter::new(vec![14, 14]);
    tw.row_str(&["engine", "µs/instance"]);
    tw.sep();

    let tensor = TensorEngine::from_artifact(&dir, "rf_f32_b64", &forest)?;
    let t = time_per_instance(&tensor, &x, repeats);
    tw.row(&["XLA".to_string(), format!("{t:.2}")]);

    for kind in [EngineKind::Rs, EngineKind::Vqs, EngineKind::Qs, EngineKind::Naive] {
        if let Some(e) = build_engine_arc(kind, Precision::F32, &forest) {
            let te = time_per_instance(e.as_ref(), &x, repeats);
            tw.row(&[kind.short().to_string(), format!("{te:.2}")]);
        }
    }
    out.push_str(&tw.finish());
    Ok(out)
}


// ---------------------------------------------------------------------------
// Extra C — model memory footprint & energy
// ---------------------------------------------------------------------------

/// Extra C: resident model bytes per engine (the paper's memory-footprint
/// discussion: RapidScorer's epitome compactness, int16 halving) plus
/// estimated energy per inference on each device.
pub fn memory_energy(scale: &Scale) -> String {
    let devices = DeviceProfile::paper_devices();
    let mut out = String::new();
    out.push_str(&format!(
        "Model memory & energy (scale={}, RF {} trees x 64 leaves)\n\n",
        scale.name, scale.cls_trees
    ));
    for id in [DatasetId::Adult, DatasetId::Magic] {
        let ds = id.generate(id.default_n(), 0xD5 ^ 64);
        let (train, _) = ds.split(0.2, 7);
        let f = super::harness::cached_rf(&train, scale.cls_trees, 64);
        let x = eval_batch(&ds, scale.eval_n / 2);
        out.push_str(&format!(
            "== {} ({} nodes) ==\n",
            id.name(),
            f.n_nodes()
        ));
        let mut tw = TableWriter::new(vec![6, 12, 14, 16]);
        tw.row_str(&["engine", "model KiB", "µJ/inst (A53)", "µJ/inst (Exynos)"]);
        tw.sep();
        for (kind, precision) in all_variants() {
            let Some(e) = build_engine_arc(kind, precision, &f) else { continue };
            let kib = e.memory_bytes() as f64 / 1024.0;
            let trace_n = 64.min(x.len() / e.n_features());
            let trace = e.count_ops(&x[..trace_n * e.n_features()]);
            let ws = e.memory_bytes() as f64;
            let uj: Vec<f64> = devices
                .iter()
                .map(|d| d.estimate_energy_uj(&trace, ws) / trace_n as f64)
                .collect();
            tw.row(&[
                variant_name(kind, precision),
                format!("{kib:.1}"),
                format!("{:.2}", uj[0]),
                format!("{:.2}", uj[1]),
            ]);
        }
        out.push_str(&tw.finish());
        out.push('\n');
    }
    out.push_str(
        "notes: quantized models are ~half the float size (int16 payloads);\n\
         RapidScorer stores merged groups + epitomes instead of per-node\n\
         masks, so its size shrinks with the dataset's merge rate (adult\n\
         vs magic).\n",
    );
    out
}

// ---------------------------------------------------------------------------
// Extra D — thread scaling (the exec runtime)
// ---------------------------------------------------------------------------

/// Extra D: thread-scaling of the row-sharded [`crate::exec::ParallelEngine`]
/// across engines × forest shapes — the paper's engines exploit SIMD lanes
/// within one core; this measures the multi-core axis on top. Results are
/// archived both as text (`results/scaling.txt` via the caller) and as
/// machine-readable JSON (`results/scaling.json`) with per-thread-count
/// µs/instance and speedups vs 1 thread.
pub fn scaling(
    scale: &Scale,
    max_threads: usize,
    precision: Option<Precision>,
    pin: bool,
) -> String {
    use crate::exec::{ParallelEngine, PoolConfig};
    use crate::util::Json;

    let budgets = crate::coordinator::thread_budgets(max_threads);
    let ds = DatasetId::Magic.generate(DatasetId::Magic.default_n(), 0xD5 ^ 64);
    let (train, _) = ds.split(0.2, 7);
    let shapes = [((scale.cls_trees / 4).max(1), 32usize), (scale.cls_trees, 64)];
    // Default mix, or a whole tier when `--precision` narrows the sweep.
    let variants: Vec<(EngineKind, Precision)> = match precision {
        None => vec![
            (EngineKind::Rs, Precision::F32),
            (EngineKind::Vqs, Precision::F32),
            (EngineKind::Qs, Precision::F32),
            (EngineKind::Rs, Precision::I16),
        ],
        Some(Precision::I8) => crate::engine::i8_variants(),
        Some(p) => [EngineKind::Rs, EngineKind::Vqs, EngineKind::Qs, EngineKind::Naive]
            .iter()
            .map(|&k| (k, p))
            .collect(),
    };

    let mut out = String::new();
    out.push_str(&format!(
        "Thread-scaling experiment (scale={}, dataset=magic, batch={} rows)\n\
         row-sharded ParallelEngine (ShardPolicy::Exact) vs serial, host µs/instance\n\
         (speedup vs 1 thread in parens)\n\n",
        scale.name, scale.eval_n
    ));
    let mut records = Vec::new();
    for (trees, leaves) in shapes {
        let f = super::harness::cached_rf(&train, trees, leaves);
        let x = eval_batch(&ds, scale.eval_n);
        out.push_str(&format!("== forest: {trees} trees x {leaves} leaves ==\n"));
        let mut widths = vec![6usize];
        widths.extend(vec![15usize; budgets.len()]);
        let mut tw = TableWriter::new(widths);
        let mut header = vec!["engine".to_string()];
        header.extend(budgets.iter().map(|t| format!("{t}t")));
        tw.row(&header);
        tw.sep();
        for &(kind, precision) in &variants {
            let Some(serial) = build_engine_arc(kind, precision, &f) else { continue };
            let base_us = time_per_instance(serial.as_ref(), &x, scale.repeats);
            let mut us_list = Vec::new();
            for &t in &budgets {
                if t <= 1 {
                    us_list.push(base_us);
                    continue;
                }
                // Wrap the already-built serial engine: same Exact row
                // sharding as build_parallel, without repeating RS/QS
                // model preparation per thread count. `--pin` anchors the
                // workers to the detected topology's clusters.
                let e = ParallelEngine::wrap_with(
                    serial.clone(),
                    PoolConfig::new(t).pin(pin),
                );
                us_list.push(time_per_instance(&e, &x, scale.repeats));
            }
            let mut cells = vec![variant_name(kind, precision)];
            for (i, &us) in us_list.iter().enumerate() {
                cells.push(if i == 0 {
                    format!("{us:.2}")
                } else {
                    format!("{us:.2} ({:.2}x)", us_list[0] / us)
                });
            }
            tw.row(&cells);
            records.push(Json::from_pairs(vec![
                ("engine", Json::Str(variant_name(kind, precision))),
                ("dataset", Json::Str("magic".to_string())),
                ("trees", Json::Num(trees as f64)),
                ("leaves", Json::Num(leaves as f64)),
                ("batch", Json::Num((x.len() / ds.d) as f64)),
                ("threads", Json::array_usize(&budgets)),
                (
                    "us_per_instance",
                    Json::Arr(us_list.iter().map(|&u| Json::Num(u)).collect()),
                ),
                (
                    "speedup_vs_1t",
                    Json::Arr(us_list.iter().map(|&u| Json::Num(us_list[0] / u)).collect()),
                ),
            ]));
        }
        out.push_str(&tw.finish());
        out.push('\n');
    }
    let host_par =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let report = Json::from_pairs(vec![
        ("experiment", Json::Str("scaling".to_string())),
        ("scale", Json::Str(scale.name.to_string())),
        ("host_parallelism", Json::Num(host_par as f64)),
        ("policy", Json::Str("exact-row-sharding".to_string())),
        ("pinned", Json::Bool(pin)),
        ("results", Json::Arr(records)),
    ]);
    archive_json("scaling", &report);
    out.push_str("archived JSON: results/scaling.json\n");
    out
}

// ---------------------------------------------------------------------------
// Extra E — int16 vs int8 precision tiers
// ---------------------------------------------------------------------------

/// Extra E: the precision-tier comparison the int8 tier exists for — host
/// µs/instance and accuracy of all five i16-vs-i8 engine pairs
/// (NA/IE/QS/VQS/RS) on synthetic classification datasets, each tier's
/// node-merge statistic, the i8 accumulator mode, and a
/// **per-tree-vs-global scale ablation** (accuracy + accumulator mode under
/// `choose_scale_i8_per_tree`, plus a synthetic big-forest demo of the
/// Widened → Native flip). Text goes to `results/int8.txt` (via the
/// caller's `archive`), machine-readable JSON to `results/int8_tiers.json`.
pub fn int8_tiers(scale: &Scale) -> String {
    use crate::quant::choose_scale_i8_per_tree;
    use crate::util::Json;

    let pairs: Vec<(EngineKind, &str)> =
        EngineKind::ALL.iter().map(|&k| (k, k.short())).collect();
    let mut out = String::new();
    out.push_str(&format!(
        "int16 vs int8 precision tiers (scale={}, RF {} trees x 64 leaves)\n\
         host µs/instance per engine pair; accuracy via the naive reference\n\n",
        scale.name, scale.cls_trees
    ));
    let mut records = Vec::new();
    for id in [DatasetId::Magic, DatasetId::Eeg, DatasetId::Adult] {
        let ds = id.generate(id.default_n(), 0xD5 ^ 64);
        let (train, test) = ds.split(0.2, 7);
        let f = super::harness::cached_rf(&train, scale.cls_trees, 64);
        let x = eval_batch(&ds, scale.eval_n);

        let cfg16 = choose_scale(&f, 1.0);
        let qf16 = QForest::from_forest(&f, cfg16);
        let cfg8 = choose_scale_i8(&f, 1.0);
        let qf8 = QForest::<i8>::from_forest(&f, cfg8);
        // Per-tree-vs-global ablation: same forest, per-tree leaf scales.
        let cfg8pt = choose_scale_i8_per_tree(&f, 1.0);
        let qf8pt = QForest::<i8>::from_forest_per_tree(&f, cfg8pt);

        let acc_f = f.accuracy(&test.x, &test.labels);
        let acc16 = accuracy_of(&qf16.predict_batch(&test.x), &test.labels, f.n_classes);
        let acc8 = accuracy_of(&qf8.predict_batch(&test.x), &test.labels, f.n_classes);
        let acc8pt =
            accuracy_of(&qf8pt.predict_batch(&test.x), &test.labels, f.n_classes);
        let merge16 = merge::unique_node_fraction_quant(&qf16);
        let merge8 = merge::unique_node_fraction_quant(&qf8);

        out.push_str(&format!(
            "== {} ==\n\
             accuracy: float {:.2}% | i16 {:.2}% (s={:.0}) | i8 {:.2}% (s={:.1}, {} accumulation)\n\
             per-tree i8 scales: {:.2}% (s={:.1}, {} accumulation) vs global {}\n\
             unique nodes after merging: i16 {:.1}%, i8 {:.1}%\n",
            id.name(),
            100.0 * acc_f,
            100.0 * acc16,
            cfg16.scale,
            100.0 * acc8,
            cfg8.scale,
            qf8.accum_mode().as_str(),
            100.0 * acc8pt,
            cfg8pt.scale,
            qf8pt.accum_mode().as_str(),
            qf8.accum_mode().as_str(),
            100.0 * merge16,
            100.0 * merge8,
        ));
        let mut tw = TableWriter::new(vec![8, 13, 13, 10]);
        tw.row_str(&["engine", "i16 µs/inst", "i8 µs/inst", "speedup"]);
        tw.sep();
        let mut engines_json = Vec::new();
        for &(kind, name) in &pairs {
            let Some(e16) = build_engine_arc(kind, Precision::I16, &f) else { continue };
            // Explicit carrier scale = global quantization, exactly the
            // config the scale_i8/accum_mode_i8 fields above describe
            // (`build(.., None)` would silently auto-upgrade to per-tree
            // scales on forests whose global analysis widens, and the
            // timing row would mislabel what it measured).
            let carrier: QuantConfig = QuantConfig::new(cfg8.scale);
            let Ok(e8) = crate::engine::build(kind, Precision::I8, &f, Some(carrier))
            else {
                continue;
            };
            let t16 = time_per_instance(e16.as_ref(), &x, scale.repeats);
            let t8 = time_per_instance(e8.as_ref(), &x, scale.repeats);
            tw.row(&[
                name.to_string(),
                format!("{t16:.2}"),
                format!("{t8:.2}"),
                format!("{:.2}x", t16 / t8),
            ]);
            engines_json.push(Json::from_pairs(vec![
                ("engine", Json::Str(name.to_string())),
                ("i16_us_per_instance", Json::Num(t16)),
                ("i8_us_per_instance", Json::Num(t8)),
                ("i8_speedup_vs_i16", Json::Num(t16 / t8)),
            ]));
        }
        out.push_str(&tw.finish());
        out.push('\n');
        records.push(Json::from_pairs(vec![
            ("dataset", Json::Str(id.name().to_string())),
            ("trees", Json::Num(f.n_trees() as f64)),
            ("accuracy_float", Json::Num(acc_f)),
            ("accuracy_i16", Json::Num(acc16)),
            ("accuracy_i8", Json::Num(acc8)),
            ("accuracy_i8_per_tree", Json::Num(acc8pt)),
            ("accuracy_delta_i16_vs_float", Json::Num(acc16 - acc_f)),
            ("accuracy_delta_i8_vs_i16", Json::Num(acc8 - acc16)),
            ("accuracy_delta_per_tree_vs_global_i8", Json::Num(acc8pt - acc8)),
            ("scale_i16", Json::Num(cfg16.scale as f64)),
            ("scale_i8", Json::Num(cfg8.scale as f64)),
            ("scale_i8_per_tree", Json::Num(cfg8pt.scale as f64)),
            ("accum_mode_i8", Json::Str(qf8.accum_mode().as_str().to_string())),
            (
                "accum_mode_i8_per_tree",
                Json::Str(qf8pt.accum_mode().as_str().to_string()),
            ),
            ("unique_node_fraction_i16", Json::Num(merge16)),
            ("unique_node_fraction_i8", Json::Num(merge8)),
            ("engines", Json::Arr(engines_json)),
        ]));
    }
    // Synthetic big-forest flip demo: RF-style 1/M leaves at a tree count
    // where the global leaf floor exceeds the native i8 budget. Global
    // scaling must widen; per-tree scales restore native accumulation
    // (ROADMAP item; DESIGN.md §6).
    let flip = {
        use crate::forest::{Task, Tree};
        let mut f = Forest::new(2, 1, Task::Ranking);
        for i in 0..60 {
            f.trees.push(Tree::leaf(vec![(1.0 + (i % 3) as f32) / 90.0]));
        }
        let qg = QForest::<i8>::from_forest(&f, choose_scale_i8(&f, 1.0));
        let qp = QForest::<i8>::from_forest_per_tree(&f, choose_scale_i8_per_tree(&f, 1.0));
        out.push_str(&format!(
            "flip demo (60 trees, leaves ≤ 1/30): global → {} accumulation, \
             per-tree → {} accumulation\n",
            qg.accum_mode().as_str(),
            qp.accum_mode().as_str()
        ));
        Json::from_pairs(vec![
            ("trees", Json::Num(60.0)),
            ("accum_mode_global", Json::Str(qg.accum_mode().as_str().to_string())),
            ("accum_mode_per_tree", Json::Str(qp.accum_mode().as_str().to_string())),
        ])
    };
    let report = Json::from_pairs(vec![
        ("experiment", Json::Str("int8_tiers".to_string())),
        ("scale", Json::Str(scale.name.to_string())),
        ("per_tree_flip_demo", flip),
        ("results", Json::Arr(records)),
    ]);
    archive_json("int8_tiers", &report);
    out.push_str("archived JSON: results/int8_tiers.json\n");
    out
}

// ---------------------------------------------------------------------------
// ISSUE 8 — FLInt carrier tier: f32 vs flint latency per engine family
// ---------------------------------------------------------------------------

/// ISSUE 8 headline: per-engine f32-vs-FLInt latency. The FLInt carrier
/// ([`crate::quant::flint`]) moves every threshold compare to the integer
/// pipe while leaves stay f32, so outputs are bit-identical to the float
/// tier by construction — asserted here on the measured batch (the real
/// contract lives in `rust/tests/flint_exact.rs`), which is why the table
/// has no accuracy column. Machine-readable JSON to `results/flint.json`.
pub fn flint(scale: &Scale, smoke: bool) -> String {
    use crate::util::Json;

    let eval_n = if smoke { scale.eval_n.min(64) } else { scale.eval_n };
    let repeats = if smoke { 1 } else { scale.repeats };
    let ds = DatasetId::Magic.generate(DatasetId::Magic.default_n(), 0xD5 ^ 64);
    let (train, _) = ds.split(0.2, 7);
    let f = super::harness::cached_rf(&train, scale.cls_trees, 64);
    let x = eval_batch(&ds, eval_n);
    let n = x.len() / ds.d;

    let mut out = String::new();
    out.push_str(&format!(
        "FLInt carrier vs f32 (scale={}, RF {} trees x 64 leaves, {} rows)\n\
         integer threshold compares, float leaves/accumulation; outputs are\n\
         bit-identical to f32 (asserted per engine), so this is pure latency\n\n",
        scale.name, scale.cls_trees, n
    ));
    let mut tw = TableWriter::new(vec![8, 13, 15, 10]);
    tw.row_str(&["engine", "f32 µs/inst", "flint µs/inst", "speedup"]);
    tw.sep();
    let mut engines_json = Vec::new();
    for kind in EngineKind::ALL {
        let Some(ef) = build_engine_arc(kind, Precision::F32, &f) else { continue };
        let Some(efl) = build_engine_arc(kind, Precision::F32Flint, &f) else { continue };
        // Bit-identity sanity on the batch we are about to time — catches a
        // bench-side build mix-up, not a substitute for the property tests.
        assert_eq!(
            ef.predict(&x),
            efl.predict(&x),
            "{}: FLInt diverged from its f32 twin",
            kind.short()
        );
        let tf = time_per_instance(ef.as_ref(), &x, repeats);
        let tfl = time_per_instance(efl.as_ref(), &x, repeats);
        tw.row(&[
            kind.short().to_string(),
            format!("{tf:.2}"),
            format!("{tfl:.2}"),
            format!("{:.2}x", tf / tfl),
        ]);
        engines_json.push(Json::from_pairs(vec![
            ("engine", Json::Str(kind.short().to_string())),
            ("f32_us_per_instance", Json::Num(tf)),
            ("flint_us_per_instance", Json::Num(tfl)),
            ("flint_speedup_vs_f32", Json::Num(tf / tfl)),
            ("bit_identical", Json::Bool(true)),
        ]));
    }
    out.push_str(&tw.finish());
    let report = Json::from_pairs(vec![
        ("experiment", Json::Str("flint".to_string())),
        ("scale", Json::Str(scale.name.to_string())),
        ("dataset", Json::Str("magic".to_string())),
        ("trees", Json::Num(f.n_trees() as f64)),
        ("rows", Json::Num(n as f64)),
        ("engines", Json::Arr(engines_json)),
    ]);
    archive_json("flint", &report);
    out.push_str("\narchived JSON: results/flint.json\n");
    out
}

// ---------------------------------------------------------------------------
// Extra J — dynamic early exit (ISSUE 9)
// ---------------------------------------------------------------------------

/// Extra J: the early-exit ablation. Exact mode per headline engine —
/// argmax asserted identical to full staged scoring (mode `Off`), the
/// trees-evaluated reduction is the payoff — then the approx threshold
/// sweep trading argmax agreement for fewer trees. Machine-readable JSON to
/// `results/early_exit.json`; the `magic/ee*` perf-history gate series live
/// in [`smoke`]. `only` (CLI `--early-exit`) narrows the ablation to one
/// mode's rows.
pub fn early_exit(
    scale: &Scale,
    smoke: bool,
    only: Option<crate::engine::EarlyExitMode>,
) -> String {
    use crate::engine::{build_early_exit, EarlyExitMode};
    use crate::util::Json;

    let eval_n = if smoke { scale.eval_n.min(64) } else { scale.eval_n };
    let repeats = if smoke { 1 } else { scale.repeats };
    let ds = DatasetId::Magic.generate(DatasetId::Magic.default_n(), 0xD5 ^ 64);
    let (train, _) = ds.split(0.2, 7);
    let f = super::harness::cached_rf(&train, scale.cls_trees, 64);
    let x = eval_batch(&ds, eval_n);
    let n = x.len() / ds.d;
    let cal_rows = train.n.min(256);
    let cal = &train.x[..train.d * cal_rows];
    let total = f.n_trees() as f64;
    // Agreement is reported against plain full-forest scoring — the same
    // float reference the selector gates on.
    let ref_argmax = Forest::argmax(&f.predict_batch(&x), f.n_classes);
    let agreement = |scores: &[f32]| {
        let got = Forest::argmax(scores, f.n_classes);
        got.iter().zip(&ref_argmax).filter(|(a, b)| a == b).count() as f64
            / ref_argmax.len().max(1) as f64
    };
    let want = |mode: EarlyExitMode| only.map_or(true, |m| m == mode);

    let mut out = String::new();
    out.push_str(&format!(
        "Early-exit ablation (scale={}, RF {} trees x 64 leaves, {n} rows, \
         calibration {cal_rows} rows)\nexact: argmax provably identical to \
         full staged scoring (asserted); approx: exit when the margin beats \
         frac x remaining mass — agreement is the cost\n\n",
        scale.name, scale.cls_trees,
    ));
    let mut tw = TableWriter::new(vec![7, 6, 8, 10, 10, 9, 8]);
    tw.row_str(&["mode", "frac", "engine", "µs/inst", "trees/row", "%forest", "agree%"]);
    tw.sep();
    let mut rows_json = Vec::new();
    // Best approx cell clearing the selector's ≥99% gate (headline).
    let mut best_approx: Option<(f64, f64)> = None; // (frac_trees, frac)

    if want(EarlyExitMode::Exact) {
        for kind in [EngineKind::Rs, EngineKind::Vqs] {
            let Ok(off) = build_early_exit(kind, Precision::F32, &f, cal, EarlyExitMode::Off)
            else {
                continue;
            };
            let Ok(ee) = build_early_exit(kind, Precision::F32, &f, cal, EarlyExitMode::Exact)
            else {
                continue;
            };
            let got = ee.predict(&x);
            // The exact-mode guarantee, observed on the benchmark forest:
            // identical argmax to scoring every stage (satellite 1 proves
            // this across tiers/threads; the bench keeps it honest here).
            assert_eq!(
                Forest::argmax(&got, f.n_classes),
                Forest::argmax(&off.predict(&x), f.n_classes),
                "{}: exact early exit changed the argmax",
                kind.short()
            );
            ee.reset_counters();
            let _ = ee.predict(&x);
            let mean_trees = ee.mean_trees_evaluated();
            let us = time_per_instance(&ee, &x, repeats);
            let agree = agreement(&got);
            tw.row(&[
                "exact".to_string(),
                "-".to_string(),
                kind.short().to_string(),
                format!("{us:.2}"),
                format!("{mean_trees:.1}"),
                format!("{:.1}", 100.0 * mean_trees / total),
                format!("{:.1}", 100.0 * agree),
            ]);
            rows_json.push(Json::from_pairs(vec![
                ("mode", Json::Str("exact".to_string())),
                ("frac", Json::Null),
                ("engine", Json::Str(kind.short().to_string())),
                ("us_per_instance", Json::Num(us)),
                ("mean_trees_evaluated", Json::Num(mean_trees)),
                ("frac_trees", Json::Num(mean_trees / total)),
                ("agreement", Json::Num(agree)),
            ]));
        }
    }

    if want(EarlyExitMode::Approx) {
        for frac in [0.05, 0.1, 0.2, 0.3, 0.5] {
            let Ok(ee) =
                build_early_exit(EngineKind::Rs, Precision::F32, &f, cal, EarlyExitMode::Approx)
            else {
                continue;
            };
            let ee = ee.with_frac(frac);
            let got = ee.predict(&x);
            let agree = agreement(&got);
            ee.reset_counters();
            let _ = ee.predict(&x);
            let mean_trees = ee.mean_trees_evaluated();
            let us = time_per_instance(&ee, &x, repeats);
            if agree >= 0.99 && best_approx.map_or(true, |(ft, _)| mean_trees / total < ft) {
                best_approx = Some((mean_trees / total, frac));
            }
            tw.row(&[
                "approx".to_string(),
                format!("{frac:.2}"),
                EngineKind::Rs.short().to_string(),
                format!("{us:.2}"),
                format!("{mean_trees:.1}"),
                format!("{:.1}", 100.0 * mean_trees / total),
                format!("{:.1}", 100.0 * agree),
            ]);
            rows_json.push(Json::from_pairs(vec![
                ("mode", Json::Str("approx".to_string())),
                ("frac", Json::Num(frac)),
                ("engine", Json::Str(EngineKind::Rs.short().to_string())),
                ("us_per_instance", Json::Num(us)),
                ("mean_trees_evaluated", Json::Num(mean_trees)),
                ("frac_trees", Json::Num(mean_trees / total)),
                ("agreement", Json::Num(agree)),
            ]));
        }
    }

    out.push_str(&tw.finish());
    if let Some((ft, frac)) = best_approx {
        out.push_str(&format!(
            "\nheadline: approx frac={frac:.2} evaluates {:.1}% of the forest per row \
             at ≥99% argmax agreement\n",
            100.0 * ft
        ));
    }
    let report = Json::from_pairs(vec![
        ("experiment", Json::Str("early_exit".to_string())),
        ("scale", Json::Str(scale.name.to_string())),
        ("smoke", Json::Bool(smoke)),
        ("dataset", Json::Str("magic".to_string())),
        ("trees", Json::Num(total)),
        ("rows", Json::Num(n as f64)),
        ("calibration_rows", Json::Num(cal_rows as f64)),
        ("configs", Json::Arr(rows_json)),
    ]);
    archive_json("early_exit", &report);
    out.push_str("archived JSON: results/early_exit.json\n");
    out
}

// ---------------------------------------------------------------------------
// Extra F — serving: shared pool vs per-deployment pools
// ---------------------------------------------------------------------------

/// Extra F: the fused serving path under multi-model contention — N
/// concurrent closed-loop clients against a two-model `Server` (one i16 and
/// one i8 deployment), comparing the refactored layout (one shared
/// `threads`-worker pool with per-deployment budgets) against the
/// pre-fusion layout emulated as one private `threads`-worker pool per
/// deployment (2× core oversubscription). Reports p50/p99 request latency
/// and throughput per model; machine-readable JSON to
/// `results/serving.json`.
pub fn serving(scale: &Scale, threads: usize) -> String {
    use crate::coordinator::{BatchConfig, Deployment, Server};
    use crate::util::Json;
    use std::sync::Arc;
    use std::time::Duration;

    let threads = threads.max(1);
    let ds = DatasetId::Magic.generate(DatasetId::Magic.default_n(), 0xD5 ^ 64);
    let (train, _) = ds.split(0.2, 7);
    let f = super::harness::cached_rf(&train, scale.cls_trees, 64);
    let n_clients = 4usize;
    let per_client = (scale.eval_n * 8).max(64);
    let shared_budget = threads.div_ceil(2);
    let cfg = |budget: usize| BatchConfig {
        max_batch: 64,
        max_delay: Duration::from_micros(300),
        queue_cap: 65_536,
        workers: 1,
        exec_threads: budget,
        drain_timeout: None,
        adaptive: true,
    };

    let mut out = String::new();
    out.push_str(&format!(
        "Serving benchmark (scale={}, RF {} trees x 64 leaves)\n\
         {n_clients} closed-loop clients x {per_client} requests over two deployments\n\
         (VQS i16 + VQS i8): shared {threads}-worker pool (budget {shared_budget}/model)\n\
         vs one private {threads}-worker pool per deployment (pre-fusion layout)\n\n",
        scale.name, scale.cls_trees,
    ));

    // Closed-loop driver with a small pipeline window per client; clients
    // alternate between the deployments so both models see the same load.
    let drive = |deps: Vec<Arc<Deployment>>| -> f64 {
        let sw = crate::util::Stopwatch::start();
        std::thread::scope(|s| {
            for cid in 0..n_clients {
                let deps = deps.clone();
                let ds = &ds;
                // scope() joins every spawned thread on exit.
                let _ = s.spawn(move || {
                    let mut inflight = Vec::with_capacity(32);
                    for r in 0..per_client {
                        let dep = &deps[(cid + r) % deps.len()];
                        let row = ds.row((cid * per_client + r) % ds.n).to_vec();
                        if let Ok(rx) = dep.batcher.submit(row) {
                            inflight.push(rx);
                        }
                        if inflight.len() >= 32 {
                            for rx in inflight.drain(..) {
                                let _ = rx.recv();
                            }
                        }
                    }
                    for rx in inflight.drain(..) {
                        let _ = rx.recv();
                    }
                });
            }
        });
        sw.micros() / 1e6
    };

    let mut records = Vec::new();
    let mut tw = TableWriter::new(vec![15, 10, 10, 10, 10, 10]);
    tw.row_str(&["mode", "model", "req/s", "p50 µs", "p99 µs", "rejected"]);
    tw.sep();
    for (mode, shared) in [("shared-pool", true), ("separate-pools", false)] {
        // Servers are kept alive until their metrics are read.
        let mut servers: Vec<Arc<Server>> = Vec::new();
        let mut deps: Vec<Arc<Deployment>> = Vec::new();
        if shared {
            let server = Arc::new(Server::with_pool_size(threads));
            server
                .deploy("i16", &f, EngineKind::Vqs, Precision::I16, cfg(shared_budget))
                .expect("deploy i16");
            server
                .deploy("i8", &f, EngineKind::Vqs, Precision::I8, cfg(shared_budget))
                .expect("deploy i8");
            deps.push(server.model("i16").unwrap());
            deps.push(server.model("i8").unwrap());
            servers.push(server);
        } else {
            for (name, precision) in [("i16", Precision::I16), ("i8", Precision::I8)] {
                let server = Arc::new(Server::with_pool_size(threads));
                server
                    .deploy(name, &f, EngineKind::Vqs, precision, cfg(threads))
                    .expect("deploy");
                deps.push(server.model(name).unwrap());
                servers.push(server);
            }
        }
        let wall_s = drive(deps.clone());
        let mut total_done = 0u64;
        let mut models_json = Vec::new();
        for dep in &deps {
            let m = &dep.batcher.metrics;
            let done = m.completed.load(std::sync::atomic::Ordering::Relaxed);
            let rej = m.rejected.load(std::sync::atomic::Ordering::Relaxed);
            total_done += done;
            let lat = m.latency_summary();
            tw.row(&[
                mode.to_string(),
                dep.engine_name.clone(),
                format!("{:.0}", done as f64 / wall_s),
                format!("{:.0}", lat.median),
                format!("{:.0}", lat.p99),
                format!("{rej}"),
            ]);
            models_json.push(Json::from_pairs(vec![
                ("engine", Json::Str(dep.engine_name.clone())),
                ("completed", Json::Num(done as f64)),
                ("rejected", Json::Num(rej as f64)),
                ("throughput_rps", Json::Num(done as f64 / wall_s)),
                ("p50_us", Json::Num(lat.median)),
                ("p99_us", Json::Num(lat.p99)),
            ]));
        }
        records.push(Json::from_pairs(vec![
            ("mode", Json::Str(mode.to_string())),
            ("wall_s", Json::Num(wall_s)),
            ("total_throughput_rps", Json::Num(total_done as f64 / wall_s)),
            ("models", Json::Arr(models_json)),
        ]));
    }
    out.push_str(&tw.finish());
    let report = Json::from_pairs(vec![
        ("experiment", Json::Str("serving".to_string())),
        ("scale", Json::Str(scale.name.to_string())),
        ("pool_threads", Json::Num(threads as f64)),
        ("clients", Json::Num(n_clients as f64)),
        ("requests_per_client", Json::Num(per_client as f64)),
        ("modes", Json::Arr(records)),
    ]);
    archive_json("serving", &report);
    out.push_str("\narchived JSON: results/serving.json\n");
    out
}

// ---------------------------------------------------------------------------
// Extra G — adaptive, affinity-aware execution (ISSUE 5)
// ---------------------------------------------------------------------------

/// Extra G: the adaptive-execution grid — {static, adaptive} plans ×
/// {unpinned, pinned} workers × {claim-1, claim-k} on a **synthetic
/// big.LITTLE topology** (3:1 weights over a homogeneous host's cores, so
/// the static planner's prior is deliberately wrong and only measurement
/// can fix it). Reports rows/s per cell, the pinned-worker and re-plan
/// counts, and the claim amortization ratio; the headline number is
/// adaptive+pinned+claim-k over static+unpinned+claim-1. Text to
/// `results/adaptive.txt` (via the caller's `archive`), JSON to
/// `results/adaptive.json`. `smoke` shrinks the batch/iteration counts for
/// CI while still crossing at least one re-plan boundary.
pub fn adaptive(scale: &Scale, threads: usize, smoke: bool) -> String {
    use crate::exec::parallel::REPLAN_EVERY_PREDICTS;
    use crate::exec::{CoreTopology, ParallelEngine, PoolConfig, DEFAULT_CLAIM_LIMIT};
    use crate::util::Json;
    use std::collections::BTreeMap;
    use std::sync::Arc;

    let threads = threads.max(2);
    let n_big = threads.div_ceil(2);
    let n_little = (threads - n_big).max(1);
    let ds = DatasetId::Magic.generate(DatasetId::Magic.default_n(), 0xD5 ^ 64);
    let (train, _) = ds.split(0.2, 7);
    let f = super::harness::cached_rf(&train, scale.cls_trees, 64);
    let rows = if smoke { scale.eval_n.min(64) } else { scale.eval_n };
    let x = eval_batch(&ds, rows);
    let serial: Arc<dyn Engine> =
        build_engine_arc(EngineKind::Rs, Precision::F32, &f).expect("RS buildable");
    // Warmup crosses ≥ 2 re-plan boundaries so the adaptive cells measure
    // the *converged* plan, not the transient.
    let warmup = if smoke { 2 * REPLAN_EVERY_PREDICTS } else { 4 * REPLAN_EVERY_PREDICTS };
    let iters = if smoke { 6u64 } else { 24 };

    let mut out = String::new();
    out.push_str(&format!(
        "Adaptive execution grid (scale={}, RF {} trees x 64 leaves, batch={rows} rows)\n\
         synthetic big.LITTLE topology: {n_big}+{n_little} cores, 3:1 weights — a wrong\n\
         prior on this host, so static plans are mis-sized and adaptive plans must\n\
         recover from measured shard throughput ({threads}-worker pools)\n\n",
        scale.name, scale.cls_trees,
    ));
    let mut tw = TableWriter::new(vec![10, 10, 8, 12, 8, 8, 12]);
    tw.row_str(&["plan", "workers", "claim", "rows/s", "pinned", "replans", "tasks/claim"]);
    tw.sep();

    let mut throughput: BTreeMap<String, f64> = BTreeMap::new();
    let mut records = Vec::new();
    for adaptive_plan in [false, true] {
        for pin in [false, true] {
            for claim_limit in [1usize, DEFAULT_CLAIM_LIMIT] {
                let topo = CoreTopology::synthetic_big_little(n_big, n_little, 3.0);
                let engine = ParallelEngine::wrap_with(
                    serial.clone(),
                    PoolConfig::new(threads)
                        .topology(topo)
                        .pin(pin)
                        .claim_limit(claim_limit),
                )
                .with_adaptive(adaptive_plan);
                let mut scores = vec![0f32; rows * serial.n_classes()];
                for _ in 0..warmup {
                    engine.predict_batch(&x, &mut scores);
                }
                let sw = crate::util::Stopwatch::start();
                for _ in 0..iters {
                    engine.predict_batch(&x, &mut scores);
                }
                let secs = sw.micros() / 1e6;
                let rps = (rows as u64 * iters) as f64 / secs.max(1e-9);
                let pinned = engine.pool().pool().pinned_workers();
                let replans = engine.feedback().replans();
                let cs = engine.pool().pool().claim_stats();
                let (claims, tasks) = (cs.claims, cs.claimed_tasks);
                let tasks_per_claim =
                    if claims > 0 { tasks as f64 / claims as f64 } else { 0.0 };
                let plan_s = if adaptive_plan { "adaptive" } else { "static" };
                let pin_s = if pin { "pinned" } else { "unpinned" };
                let label = format!("{plan_s}+{pin_s}+claim{claim_limit}");
                tw.row(&[
                    plan_s.to_string(),
                    pin_s.to_string(),
                    format!("{claim_limit}"),
                    format!("{rps:.0}"),
                    format!("{pinned}"),
                    format!("{replans}"),
                    format!("{tasks_per_claim:.2}"),
                ]);
                throughput.insert(label.clone(), rps);
                records.push(Json::from_pairs(vec![
                    ("cell", Json::Str(label)),
                    ("adaptive", Json::Bool(adaptive_plan)),
                    ("pin_requested", Json::Bool(pin)),
                    ("pinned_workers", Json::Num(pinned as f64)),
                    ("claim_limit", Json::Num(claim_limit as f64)),
                    ("rows_per_s", Json::Num(rps)),
                    ("replans", Json::Num(replans as f64)),
                    ("claims", Json::Num(claims as f64)),
                    ("claimed_tasks", Json::Num(tasks as f64)),
                    ("tasks_per_claim", Json::Num(tasks_per_claim)),
                    ("give_backs", Json::Num(cs.give_backs as f64)),
                ]));
            }
        }
    }
    out.push_str(&tw.finish());
    let base = throughput["static+unpinned+claim1"];
    let best = throughput[&format!("adaptive+pinned+claim{DEFAULT_CLAIM_LIMIT}")];
    let gain = best / base.max(1e-9);
    out.push_str(&format!(
        "\nheadline: adaptive+pinned+claim{DEFAULT_CLAIM_LIMIT} vs static+unpinned+claim1 \
         = {gain:.2}x\n(expected ≥ 1.0: the adaptive plan re-learns the true core speeds \
         the 3:1 prior misstates)\n",
    ));
    let report = Json::from_pairs(vec![
        ("experiment", Json::Str("adaptive".to_string())),
        ("scale", Json::Str(scale.name.to_string())),
        ("smoke", Json::Bool(smoke)),
        ("pool_threads", Json::Num(threads as f64)),
        ("topology", Json::Str(format!("synthetic big.LITTLE {n_big}+{n_little} (3:1)"))),
        ("batch_rows", Json::Num(rows as f64)),
        ("headline_gain", Json::Num(gain)),
        ("cells", Json::Arr(records)),
    ]);
    archive_json("adaptive", &report);
    out.push_str("archived JSON: results/adaptive.json\n");
    out
}

// ---------------------------------------------------------------------------
// Extra I — fault-tolerant serving: overload + degradation (ISSUE 10)
// ---------------------------------------------------------------------------

/// Injected per-row cost for the overload experiment's primary engine:
/// capacity becomes exactly `threads × 1e6 / OVERLOAD_STALL_US` rows/s on
/// any host, so "2× offered load" means the same thing on a laptop and in
/// CI.
const OVERLOAD_STALL_US: u64 = 50;

/// Wraps a real engine with a deterministic per-row stall (scores are the
/// inner engine's, bit for bit) — the experiment's stand-in for a primary
/// tier that is accurate but too expensive for the offered load, which is
/// the situation degradation exists for.
struct SlowEngine {
    inner: std::sync::Arc<dyn Engine>,
    per_row: std::time::Duration,
}

impl Engine for SlowEngine {
    fn name(&self) -> String {
        format!("slow({})", self.inner.name())
    }
    fn lanes(&self) -> usize {
        self.inner.lanes()
    }
    fn n_features(&self) -> usize {
        self.inner.n_features()
    }
    fn n_classes(&self) -> usize {
        self.inner.n_classes()
    }
    fn predict_batch(&self, x: &[f32], out: &mut [f32]) {
        let rows = x.len() / self.inner.n_features().max(1);
        std::thread::sleep(self.per_row * rows as u32);
        self.inner.predict_batch(x, out);
    }
}

/// Extra I: overload behaviour with and without graceful degradation
/// (ISSUE 10 acceptance). An open-loop driver offers {1×, 2×, 4×} the
/// primary tier's capacity against one deployment whose every request
/// carries a 25 ms deadline; each cell reports completed throughput,
/// server-side p50/p99, the shed rate, and argmax agreement with the float
/// reference. With degradation off, the pool backlog grows for as long as
/// the overload lasts and p99 grows with it; with degradation armed the
/// controller must flip to the selector-ranked fallback within
/// milliseconds and hold a bounded p99 at ≥ 99% agreement — the numbers
/// the chaos gate asserts. JSON to `results/overload.json`; `--smoke`
/// additionally appends the `magic/ovl_p99` and `magic/ovl_rps` series to
/// the tracked perf history.
pub fn overload(scale: &Scale, threads: usize, smoke: bool) -> String {
    use crate::obs::bench_data::{self, BenchRecord};

    let (mut out, report) = overload_impl(scale, threads, smoke);
    archive_json("overload", &report);
    out.push_str("\narchived JSON: results/overload.json\n");
    if smoke {
        let num = |k: &str| report.get(k).and_then(|j| j.as_f64()).unwrap_or(0.0);
        let records = vec![
            BenchRecord::new("magic/ovl_p99", num("gate_p99_us"), 0.0, "µs/req"),
            BenchRecord::new("magic/ovl_rps", num("gate_rps"), 0.0, "req/s"),
        ];
        match bench_data::append(&bench_data::default_path(), "overload", &records) {
            Ok(()) => {
                out.push_str("gate series appended: magic/ovl_p99, magic/ovl_rps\n");
            }
            Err(e) => out.push_str(&format!("gate series append failed: {e}\n")),
        }
    }
    out
}

/// The measured grid behind [`overload`], returned with its JSON report so
/// the unit test can assert on cells without touching `results/` or the
/// tracked bench history.
fn overload_impl(scale: &Scale, threads: usize, smoke: bool) -> (String, crate::util::Json) {
    use crate::coordinator::{BatchConfig, DegradeConfig, ServeError, Server};
    use crate::util::Json;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let threads = threads.max(2);
    let ds = DatasetId::Magic.generate(DatasetId::Magic.default_n(), 0xD5 ^ 64);
    let (train, _) = ds.split(0.2, 7);
    let f = super::harness::cached_rf(&train, scale.cls_trees, 64);
    let ref_labels = Forest::argmax(&f.predict_batch(&ds.x), f.n_classes);
    let cal = &train.x[..train.d * train.n.min(256)];

    let capacity_rps = threads as f64 * 1e6 / OVERLOAD_STALL_US as f64;
    let deadline = Duration::from_millis(25);
    let cell_dur =
        if smoke { Duration::from_millis(300) } else { Duration::from_millis(1500) };
    let n_senders = 4usize;
    let loads = [1.0f64, 2.0, 4.0];

    let mut out = String::new();
    out.push_str(&format!(
        "Overload + graceful degradation (scale={}, RF {} trees x 64 leaves)\n\
         primary stalled {OVERLOAD_STALL_US} µs/row → capacity {capacity_rps:.0} req/s \
         on {threads} exec threads;\n\
         open-loop offered load at {{1x, 2x, 4x}} capacity for {} ms/cell, 25 ms \
         deadline per request\n\n",
        scale.name,
        scale.cls_trees,
        cell_dur.as_millis(),
    ));
    let mut tw = TableWriter::new(vec![9, 6, 9, 9, 8, 9, 10, 8]);
    tw.row_str(&["degrade", "load", "offered", "done", "shed%", "p50 µs", "p99 µs", "agree%"]);
    tw.sep();

    let mut cells = Vec::new();
    let mut gate = (0.0f64, 0.0f64);
    for degrade_on in [false, true] {
        for mult in loads {
            let server = Arc::new(Server::with_pool_size(threads));
            let inner = build_engine_arc(EngineKind::Naive, Precision::F32, &f)
                .expect("naive engine buildable");
            let slow: Arc<dyn Engine> = Arc::new(SlowEngine {
                inner,
                per_row: Duration::from_micros(OVERLOAD_STALL_US),
            });
            server
                .deploy_engine(
                    "magic",
                    &f,
                    slow,
                    BatchConfig {
                        max_batch: 64,
                        max_delay: Duration::from_micros(300),
                        queue_cap: 8192,
                        workers: 1,
                        exec_threads: threads,
                        drain_timeout: Some(Duration::from_secs(5)),
                        adaptive: false,
                    },
                )
                .expect("deploy");
            if degrade_on {
                // Aggressive thresholds: the cells last fractions of a
                // second, so the controller must react in milliseconds and
                // (min_dwell, exit_after) never flap back mid-cell.
                server
                    .enable_degrade(
                        "magic",
                        &f,
                        cal,
                        DegradeConfig {
                            queue_high: 16,
                            p99_high_us: 10_000.0,
                            enter_after: 1,
                            exit_after: 10_000,
                            min_dwell: Duration::from_secs(60),
                            poll_every: Duration::from_millis(2),
                        },
                    )
                    .expect("degradation fallback exists");
            }
            let dep = server.model("magic").expect("deployed");

            let offered = AtomicU64::new(0);
            let rejected = AtomicU64::new(0);
            let rate_per_sender = capacity_rps * mult / n_senders as f64;
            let (pairs_tx, pairs_rx) = std::sync::mpsc::channel();
            let sw = crate::util::Stopwatch::start();
            let (scored, agree, shed, other) = std::thread::scope(|s| {
                for sid in 0..n_senders {
                    let pairs_tx = pairs_tx.clone();
                    let dep = dep.clone();
                    let (ds, offered, rejected) = (&ds, &offered, &rejected);
                    let _ = s.spawn(move || {
                        // Deficit pacing: send whatever the offered rate
                        // says is due, then nap — robust to coarse sleep
                        // granularity, and the bursts model open-loop
                        // arrivals.
                        let t0 = Instant::now();
                        let mut sent = 0u64;
                        while t0.elapsed() < cell_dur {
                            let due =
                                (rate_per_sender * t0.elapsed().as_secs_f64()) as u64;
                            while sent < due {
                                let i = (sid * 7919 + sent as usize) % ds.n;
                                offered.fetch_add(1, Ordering::SeqCst);
                                let d = Instant::now() + deadline;
                                match dep
                                    .batcher
                                    .submit_with_deadline(ds.row(i).to_vec(), Some(d))
                                {
                                    Ok(rx) => drop(pairs_tx.send((i, rx))),
                                    Err(_) => {
                                        rejected.fetch_add(1, Ordering::SeqCst);
                                    }
                                }
                                sent += 1;
                            }
                            std::thread::sleep(Duration::from_micros(500));
                        }
                    });
                }
                drop(pairs_tx);
                // This thread is the collector: every admitted request gets
                // exactly one reply (scored, shed, or failed), so draining
                // them all makes the cell's metrics complete — the wall
                // clock deliberately includes the post-overload backlog
                // drain, which is most of what degradation removes.
                let (mut scored, mut agree, mut shed, mut other) = (0u64, 0u64, 0u64, 0u64);
                for (i, rx) in pairs_rx {
                    match rx.recv() {
                        Ok(Ok(scores)) => {
                            scored += 1;
                            if Forest::argmax(&scores, f.n_classes)[0] == ref_labels[i] {
                                agree += 1;
                            }
                        }
                        Ok(Err(ServeError::DeadlineExceeded)) => shed += 1,
                        _ => other += 1,
                    }
                }
                (scored, agree, shed, other)
            });
            let wall_s = sw.micros() / 1e6;

            let offered_n = offered.load(Ordering::SeqCst);
            let rejected_n = rejected.load(Ordering::SeqCst);
            let lat = dep.batcher.metrics.latency_summary();
            let shed_rate = if offered_n > 0 {
                (offered_n - scored) as f64 / offered_n as f64
            } else {
                0.0
            };
            let agreement = if scored > 0 { agree as f64 / scored as f64 } else { 0.0 };
            let (entered, fallback) = match dep.degrade() {
                Some(d) => (Some(d.entries() > 0), Some(d.fallback_name().to_string())),
                None => (None, None),
            };
            if degrade_on && mult == loads[loads.len() - 1] {
                gate = (lat.p99, scored as f64 / wall_s.max(1e-9));
            }
            let mode = match entered {
                Some(true) => "on*",
                Some(false) => "on",
                None => "off",
            };
            tw.row(&[
                mode.to_string(),
                format!("{mult:.0}x"),
                format!("{offered_n}"),
                format!("{scored}"),
                format!("{:.1}", 100.0 * shed_rate),
                format!("{:.0}", lat.median),
                format!("{:.0}", lat.p99),
                format!("{:.1}", 100.0 * agreement),
            ]);
            cells.push(Json::from_pairs(vec![
                ("degrade", Json::Bool(degrade_on)),
                ("load_multiple", Json::Num(mult)),
                ("offered", Json::Num(offered_n as f64)),
                ("completed", Json::Num(scored as f64)),
                ("rejected", Json::Num(rejected_n as f64)),
                ("shed_deadline", Json::Num(shed as f64)),
                ("other_errors", Json::Num(other as f64)),
                ("throughput_rps", Json::Num(scored as f64 / wall_s.max(1e-9))),
                ("p50_us", Json::Num(lat.median)),
                ("p99_us", Json::Num(lat.p99)),
                ("shed_rate", Json::Num(shed_rate)),
                ("agreement", Json::Num(agreement)),
                ("entered_degraded", entered.map(Json::Bool).unwrap_or(Json::Null)),
                ("fallback", fallback.map(Json::Str).unwrap_or(Json::Null)),
            ]));
        }
    }
    out.push_str(&tw.finish());
    out.push_str(
        "\n(on* = the controller entered degraded mode during the cell. Admission and\n\
         flush-time deadlines bound the *batcher* queue; under sustained overload the\n\
         latency reservoir is the pool backlog behind already-flushed batches, which\n\
         only degradation — more capacity, not more shedding — can bound.)\n",
    );
    let report = Json::from_pairs(vec![
        ("experiment", Json::Str("overload".to_string())),
        ("scale", Json::Str(scale.name.to_string())),
        ("smoke", Json::Bool(smoke)),
        ("pool_threads", Json::Num(threads as f64)),
        ("stall_us_per_row", Json::Num(OVERLOAD_STALL_US as f64)),
        ("capacity_rps", Json::Num(capacity_rps)),
        ("deadline_ms", Json::Num(25.0)),
        ("gate_p99_us", Json::Num(gate.0)),
        ("gate_rps", Json::Num(gate.1)),
        ("cells", Json::Arr(cells)),
    ]);
    (out, report)
}

// ---------------------------------------------------------------------------
// Extra H — observability (ISSUE 6)
// ---------------------------------------------------------------------------

/// Extra H1: the perf-history smoke grid. A handful of fast, stable series
/// — µs/instance for the headline engine tiers plus serving throughput and
/// tail latency through the fused batcher — appended to `data_path` in
/// github-action-benchmark format (`crate::obs::bench_data`). CI's
/// bench-history job runs this on every push to `main` against the tracked
/// `dev/bench/data.js`; `bench --gate` then compares PRs against the
/// rolling median.
pub fn smoke(scale: &Scale, data_path: &std::path::Path, matrix: bool) -> anyhow::Result<String> {
    use crate::coordinator::{BatchConfig, Server};
    use crate::obs::bench_data::{self, BenchRecord};
    use crate::util::Summary;
    use std::time::Duration;

    let ds = DatasetId::Magic.generate(DatasetId::Magic.default_n(), 0xD5 ^ 64);
    let (train, _) = ds.split(0.2, 7);
    let f = super::harness::cached_rf(&train, scale.cls_trees, 64);
    let x = eval_batch(&ds, scale.eval_n);
    let mut records = Vec::new();

    // Engine latencies: one series per headline tier (float, int16, int8,
    // and the FLInt carrier so the PR gate tracks it from this PR on).
    let tiers = [
        (EngineKind::Rs, Precision::F32),
        (EngineKind::Vqs, Precision::F32),
        (EngineKind::Rs, Precision::I16),
        (EngineKind::Vqs, Precision::I8),
        (EngineKind::Rs, Precision::F32Flint),
        (EngineKind::Vqs, Precision::F32Flint),
    ];
    for (kind, precision) in tiers {
        let Some(e) = build_engine_arc(kind, precision, &f) else { continue };
        let runs: Vec<f64> = (0..scale.repeats.max(3))
            .map(|_| time_per_instance(e.as_ref(), &x, 1))
            .collect();
        let s = Summary::of(&runs);
        records.push(BenchRecord::new(
            &format!("magic/{}", variant_name(kind, precision)),
            s.mean,
            s.std,
            "µs/instance",
        ));
    }

    // Early-exit series: exact-mode staged scoring over the headline float
    // engines — the `magic/eeRS` / `magic/eeVQS` gate series track the
    // exit machinery's latency from this PR on (argmax-identical to full
    // scoring by construction, so these are pure-latency series too).
    {
        use crate::engine::{build_early_exit, EarlyExitMode};
        let cal = &train.x[..train.d * train.n.min(256)];
        for kind in [EngineKind::Rs, EngineKind::Vqs] {
            let Ok(e) = build_early_exit(kind, Precision::F32, &f, cal, EarlyExitMode::Exact)
            else {
                continue;
            };
            let runs: Vec<f64> = (0..scale.repeats.max(3))
                .map(|_| time_per_instance(&e, &x, 1))
                .collect();
            let s = Summary::of(&runs);
            records.push(BenchRecord::new(
                &format!("magic/{}", e.name()),
                s.mean,
                s.std,
                "µs/instance",
            ));
        }
    }

    // `--matrix`: additionally time every named config in the version
    // matrix (`crate::bench::matrix`), one stable `matrix/<name>` series
    // each, so historical tiers stay comparable next to new ones.
    if matrix {
        for c in super::matrix::MatrixConfig::ALL {
            let e = c.build(&f)?;
            let runs: Vec<f64> = (0..scale.repeats.max(3))
                .map(|_| time_per_instance(e.as_ref(), &x, 1))
                .collect();
            let s = Summary::of(&runs);
            records.push(BenchRecord::new(
                &format!("matrix/{}", c.name()),
                s.mean,
                s.std,
                "µs/instance",
            ));
        }
    }

    // Serving throughput (a `/s` unit, so the gate also covers the
    // bigger-is-better direction) and tail latency via one deployment.
    {
        let server = Server::with_pool_size(2);
        let cfg = BatchConfig {
            max_batch: 64,
            max_delay: Duration::from_micros(300),
            queue_cap: 65_536,
            workers: 1,
            exec_threads: 2,
            drain_timeout: None,
            adaptive: true,
        };
        server.deploy("smoke", &f, EngineKind::Vqs, Precision::I16, cfg)?;
        let dep = server.model("smoke").expect("deployed");
        let n_req = (scale.eval_n * 4).max(256);
        let sw = crate::util::Stopwatch::start();
        let mut inflight = Vec::with_capacity(64);
        for i in 0..n_req {
            if let Ok(rx) = dep.batcher.submit(ds.row(i % ds.n).to_vec()) {
                inflight.push(rx);
            }
            if inflight.len() >= 64 {
                for rx in inflight.drain(..) {
                    let _ = rx.recv();
                }
            }
        }
        for rx in inflight.drain(..) {
            let _ = rx.recv();
        }
        let rps = n_req as f64 / (sw.micros() / 1e6).max(1e-9);
        let lat = dep.batcher.metrics.latency_summary();
        records.push(BenchRecord::new("serving/throughput", rps, 0.0, "req/s"));
        records.push(BenchRecord::new("serving/p99_latency", lat.p99, lat.std, "µs/req"));
    }

    bench_data::append(data_path, "smoke", &records)?;
    let mut out = String::new();
    out.push_str(&format!(
        "Perf-history smoke grid (scale={}) appended to {}\n\n",
        scale.name,
        data_path.display()
    ));
    let mut tw = TableWriter::new(vec![24, 14, 14]);
    tw.row_str(&["series", "value", "unit"]);
    tw.sep();
    for r in &records {
        tw.row(&[r.name.clone(), format!("{:.3}", r.value), r.unit.clone()]);
    }
    out.push_str(&tw.finish());
    out.push_str("\nrun `arbors bench --gate` to check these against the rolling median\n");
    Ok(out)
}

/// Extra H2: the observability overhead harness (ISSUE 6 acceptance: with
/// tracing *disabled* the serving path must stay within ~2% of the
/// uninstrumented baseline — every span site collapses to one relaxed
/// atomic load). Drives the same closed-loop serving workload twice,
/// tracing off then on, and reports both throughputs, the enabled-tracing
/// overhead, and how many spans the enabled run recorded.
pub fn obs(scale: &Scale, threads: usize) -> String {
    use crate::coordinator::{BatchConfig, Server};
    use crate::obs::span;
    use std::time::Duration;

    let threads = threads.max(2);
    let ds = DatasetId::Magic.generate(DatasetId::Magic.default_n(), 0xD5 ^ 64);
    let (train, _) = ds.split(0.2, 7);
    let f = super::harness::cached_rf(&train, scale.cls_trees, 64);
    let server = Server::with_pool_size(threads);
    let cfg = BatchConfig {
        max_batch: 64,
        max_delay: Duration::from_micros(300),
        queue_cap: 65_536,
        workers: 1,
        exec_threads: threads,
        drain_timeout: None,
        adaptive: true,
    };
    server.deploy("obs", &f, EngineKind::Vqs, Precision::I16, cfg).expect("deploy");
    let dep = server.model("obs").expect("deployed");
    let n_req = (scale.eval_n * 8).max(512);

    let drive = |n: usize| -> f64 {
        let sw = crate::util::Stopwatch::start();
        let mut inflight = Vec::with_capacity(64);
        for i in 0..n {
            if let Ok(rx) = dep.batcher.submit(ds.row(i % ds.n).to_vec()) {
                inflight.push(rx);
            }
            if inflight.len() >= 64 {
                for rx in inflight.drain(..) {
                    let _ = rx.recv();
                }
            }
        }
        for rx in inflight.drain(..) {
            let _ = rx.recv();
        }
        n as f64 / (sw.micros() / 1e6).max(1e-9)
    };

    span::set_enabled(false);
    let _ = drive(n_req / 4); // warmup
    let off_rps = drive(n_req);
    span::set_enabled(true);
    span::clear();
    let on_rps = drive(n_req);
    let spans_recorded: usize = span::snapshot().iter().map(|(_, s)| s.len()).sum();
    span::set_enabled(false);
    span::clear();

    let overhead_pct = (off_rps / on_rps.max(1e-9) - 1.0) * 100.0;
    format!(
        "Observability overhead harness (scale={}, {threads}-worker pool, {n_req} requests)\n\
         closed-loop serving through the fused batcher, VQS i16\n\n\
         tracing off: {off_rps:.0} req/s  (the production configuration)\n\
         tracing on:  {on_rps:.0} req/s  ({spans_recorded} spans recorded, rings cap at {})\n\
         enabled-tracing overhead: {overhead_pct:+.1}%\n\n\
         budget: with tracing disabled every span site is one relaxed atomic\n\
         load, so the off configuration *is* the pre-instrumentation serving\n\
         path (DESIGN.md §8 overhead contract).\n",
        scale.name,
        crate::obs::span::RING_CAP,
    )
}

/// Extra H3: engine micro-profile — the `neon::trace` op counters wired
/// into the obs export. For every engine tier in the registry
/// ([`crate::engine::all_variants_with_i8`]; nothing hard-coded) reports
/// SIMD-ops/row, branches/row and total ops/row alongside measured host
/// µs/instance; machine-readable JSON (one key per
/// [`crate::neon::OpTrace`] counter) to `results/engine_micro.json`.
pub fn engine_micro(scale: &Scale) -> String {
    use crate::util::Json;

    let ds = DatasetId::Magic.generate(DatasetId::Magic.default_n(), 0xD5 ^ 64);
    let (train, _) = ds.split(0.2, 7);
    let f = super::harness::cached_rf(&train, scale.cls_trees, 64);
    let x = eval_batch(&ds, scale.eval_n);
    let n = x.len() / ds.d;
    let trace_n = n.clamp(1, 128);

    let mut out = String::new();
    out.push_str(&format!(
        "Engine micro-profile (scale={}, dataset=magic, RF {} trees x 64 leaves)\n\
         dynamic op counts per row from count_ops traces; host µs/instance measured\n\n",
        scale.name, scale.cls_trees
    ));
    let mut tw = TableWriter::new(vec![8, 10, 12, 12, 12]);
    tw.row_str(&["engine", "µs/inst", "simd/row", "branch/row", "total/row"]);
    tw.sep();
    let mut records = Vec::new();
    for (kind, precision) in crate::engine::all_variants_with_i8() {
        let Some(e) = build_engine_arc(kind, precision, &f) else { continue };
        let us = time_per_instance(e.as_ref(), &x, scale.repeats);
        let trace = e.count_ops(&x[..trace_n * ds.d]);
        let per_row = |v: u64| v as f64 / trace_n as f64;
        tw.row(&[
            variant_name(kind, precision),
            format!("{us:.2}"),
            format!("{:.0}", per_row(trace.simd_ops())),
            format!("{:.0}", per_row(trace.branch)),
            format!("{:.0}", per_row(trace.total_ops())),
        ]);
        let mut jr = Json::obj();
        jr.set("engine", Json::Str(variant_name(kind, precision)));
        jr.set("us_per_instance", Json::Num(us));
        // Every raw counter, named by the trace's own counter list.
        for (name, v) in trace.counters() {
            jr.set(name, Json::Num(per_row(v)));
        }
        jr.set("simd_ops_per_row", Json::Num(per_row(trace.simd_ops())));
        jr.set("total_ops_per_row", Json::Num(per_row(trace.total_ops())));
        records.push(jr);
    }
    out.push_str(&tw.finish());
    let report = Json::from_pairs(vec![
        ("experiment", Json::Str("engine_micro".to_string())),
        ("scale", Json::Str(scale.name.to_string())),
        ("dataset", Json::Str("magic".to_string())),
        ("trace_rows", Json::Num(trace_n as f64)),
        ("results", Json::Arr(records)),
    ]);
    archive_json("engine_micro", &report);
    out.push_str("\narchived JSON: results/engine_micro.json\n");
    out
}

/// Argmax accuracy of a score matrix against labels.
fn accuracy_of(scores: &[f32], labels: &[u32], n_classes: usize) -> f64 {
    let preds = Forest::argmax(scores, n_classes);
    let correct = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
    correct as f64 / labels.len() as f64
}

/// Archive a result under `results/<name>.txt`.
pub fn archive(name: &str, text: &str) {
    let path = super::harness::results_dir().join(format!("{name}.txt"));
    if let Err(e) = std::fs::write(&path, text) {
        eprintln!("warning: could not archive {name}: {e}");
    }
}

/// Archive a machine-readable JSON report under `results/<name>.json`.
pub fn archive_json(name: &str, j: &crate::util::Json) {
    let path = super::harness::results_dir().join(format!("{name}.json"));
    if let Err(e) = std::fs::write(&path, j.pretty()) {
        eprintln!("warning: could not archive {name}: {e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Scale {
        Scale {
            name: "test",
            ranking_trees: vec![8],
            cls_trees: 8,
            fig_trees: vec![4, 8],
            merge_trees: vec![4, 8],
            eval_n: 48,
            repeats: 1,
            msn_queries: 12,
            msn_docs: 8,
        }
    }

    #[test]
    fn table3_runs() {
        let s = table3(&quick());
        assert!(s.contains("magic") && s.contains('%'));
    }

    #[test]
    fn table4_runs() {
        let s = table4(&quick());
        assert!(s.contains("eeg") && s.contains("quant"));
    }

    #[test]
    fn table5_runs_and_has_all_engines() {
        let s = table5(&quick(), 32);
        for e in ["RS", "VQS", "QS", "IE", "NA", "qRS", "qVQS", "qQS", "qIE", "qNA"] {
            assert!(s.contains(e), "{e} missing:\n{s}");
        }
    }

    #[test]
    fn memory_energy_runs() {
        let s = memory_energy(&quick());
        assert!(s.contains("model KiB") && s.contains("qRS"));
    }

    #[test]
    fn ablation_runs() {
        let s = ablation_rs(&quick());
        assert!(s.contains("no-merge") || s.contains("RS(no-merge)"));
    }

    #[test]
    fn int8_tiers_runs_and_reports() {
        let s = int8_tiers(&quick());
        assert!(s.contains("i16") && s.contains("i8"), "{s}");
        // All five engine families have i8 rows now.
        for e in ["NA", "IE", "QS", "VQS", "RS"] {
            assert!(s.contains(e), "{e} row missing:\n{s}");
        }
        assert!(s.contains("per-tree"), "{s}");
        assert!(s.contains("int8_tiers.json"), "{s}");
        let path = super::super::harness::results_dir().join("int8_tiers.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::Json::parse(&text).unwrap();
        let results = j.get("results").and_then(|v| v.as_arr()).unwrap();
        assert!(results.len() >= 2, "need at least two datasets");
        for r in results {
            assert!(r.get("accuracy_i8_per_tree").and_then(|v| v.as_f64()).is_some());
            assert!(r.get("accum_mode_i8_per_tree").and_then(|v| v.as_str()).is_some());
        }
        // The flip demo must actually demonstrate the flip.
        let flip = j.get("per_tree_flip_demo").unwrap();
        assert_eq!(flip.get("accum_mode_global").and_then(|v| v.as_str()), Some("widened"));
        assert_eq!(
            flip.get("accum_mode_per_tree").and_then(|v| v.as_str()),
            Some("native")
        );
    }

    #[test]
    fn serving_runs_and_reports_json() {
        let s = serving(&quick(), 2);
        assert!(s.contains("shared-pool") && s.contains("separate-pools"), "{s}");
        assert!(s.contains("serving.json"), "{s}");
        let path = super::super::harness::results_dir().join("serving.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::Json::parse(&text).unwrap();
        assert_eq!(j.get("experiment").and_then(|v| v.as_str()), Some("serving"));
        let modes = j.get("modes").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(modes.len(), 2);
        for m in modes {
            let models = m.get("models").and_then(|v| v.as_arr()).unwrap();
            assert_eq!(models.len(), 2, "one i16 + one i8 deployment per mode");
            for model in models {
                assert!(model.get("throughput_rps").and_then(|v| v.as_f64()).unwrap() > 0.0);
            }
        }
    }

    #[test]
    fn overload_degrade_enters_and_holds_agreement() {
        // `overload_impl` (not `overload`): the unit test must not write
        // `results/overload.json` or append to the tracked bench history.
        let (s, report) = overload_impl(&quick(), 2, true);
        assert!(s.contains("degrade") && s.contains("agree%"), "{s}");
        let cells = report.get("cells").and_then(|v| v.as_arr()).expect("cells");
        assert_eq!(cells.len(), 6, "2 degrade modes x 3 load multiples");
        // ISSUE 10 acceptance, asserted on the degrade-on 4x cell: the
        // controller enters degraded mode, keeps completing requests with
        // >= 99% argmax agreement, and holds a bounded p99.
        let cell = cells
            .iter()
            .filter(|c| c.get("degrade").and_then(|v| v.as_bool()) == Some(true))
            .next_back()
            .expect("degrade-on cells present");
        assert_eq!(cell.get("load_multiple").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(
            cell.get("entered_degraded").and_then(|v| v.as_bool()),
            Some(true),
            "4x overload with queue_high=16 and 2ms polls must enter degraded mode: {}",
            cell.dump()
        );
        let completed = cell.get("completed").and_then(|v| v.as_f64()).unwrap();
        assert!(completed > 0.0, "degraded cell must still complete requests");
        let agreement = cell.get("agreement").and_then(|v| v.as_f64()).unwrap();
        assert!(agreement >= 0.99, "fallback agreement {agreement} below the 99% gate");
        let p99 = cell.get("p99_us").and_then(|v| v.as_f64()).unwrap();
        assert!(p99 < 250_000.0, "p99 {p99} µs is not bounded under overload");
        // Contrast cell: with degradation off at 4x the backlog drain
        // dominates, so completed throughput cannot beat the stalled
        // primary's capacity.
        let off = cells
            .iter()
            .find(|c| {
                c.get("degrade").and_then(|v| v.as_bool()) == Some(false)
                    && c.get("load_multiple").and_then(|v| v.as_f64()) == Some(4.0)
            })
            .expect("degrade-off 4x cell");
        let cap = report.get("capacity_rps").and_then(|v| v.as_f64()).unwrap();
        let off_rps = off.get("throughput_rps").and_then(|v| v.as_f64()).unwrap();
        assert!(
            off_rps <= cap * 1.5,
            "degrade-off throughput {off_rps:.0} should be capacity-bound (~{cap:.0})"
        );
    }

    #[test]
    fn adaptive_runs_and_reports_json() {
        let s = adaptive(&quick(), 2, true);
        assert!(s.contains("adaptive") && s.contains("static"), "{s}");
        assert!(s.contains("headline"), "{s}");
        assert!(s.contains("adaptive.json"), "{s}");
        let path = super::super::harness::results_dir().join("adaptive.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::Json::parse(&text).unwrap();
        assert_eq!(j.get("experiment").and_then(|v| v.as_str()), Some("adaptive"));
        assert!(j.get("headline_gain").and_then(|v| v.as_f64()).unwrap() > 0.0);
        let cells = j.get("cells").and_then(|v| v.as_arr()).unwrap();
        // The full 2×2×2 grid ran.
        assert_eq!(cells.len(), 8);
        for c in cells {
            assert!(c.get("rows_per_s").and_then(|v| v.as_f64()).unwrap() > 0.0);
        }
        // Adaptive cells actually re-planned; claim-k cells actually
        // batch-claimed more than one task per lock.
        let k = crate::exec::DEFAULT_CLAIM_LIMIT;
        let find = |name: String| {
            cells
                .iter()
                .find(|c| c.get("cell").and_then(|v| v.as_str()) == Some(name.as_str()))
                .unwrap()
        };
        assert!(
            find(format!("adaptive+pinned+claim{k}"))
                .get("replans")
                .and_then(|v| v.as_f64())
                .unwrap()
                >= 1.0
        );
        assert!(
            find(format!("adaptive+unpinned+claim{k}"))
                .get("tasks_per_claim")
                .and_then(|v| v.as_f64())
                .unwrap()
                >= 1.0
        );
    }

    #[test]
    fn smoke_appends_history_and_passes_gate() {
        use crate::obs::bench_data;
        let path = std::env::temp_dir()
            .join(format!("arbors_smoke_exp_{}.js", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let s = smoke(&quick(), &path, false).unwrap();
        assert!(s.contains("serving/throughput"), "{s}");
        assert!(s.contains("req/s"), "{s}");
        // The FLInt carrier series joined the gate history this PR.
        assert!(s.contains("magic/flRS"), "flint series missing:\n{s}");
        let data = bench_data::load(&path);
        bench_data::validate(&data).unwrap();
        let entries = data.get("entries").and_then(|e| e.get("smoke")).unwrap();
        assert_eq!(entries.as_arr().unwrap().len(), 1, "one entry per run");
        // Engine-tier series are present alongside the serving ones.
        let benches =
            entries.as_arr().unwrap()[0].get("benches").and_then(|b| b.as_arr()).unwrap();
        assert!(benches.len() >= 6, "engine tiers (incl. flint) + serving series");
        // A single entry has no baseline, so the gate passes deterministically.
        bench_data::gate(&path).expect("fresh history must pass the gate");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn smoke_matrix_appends_one_series_per_config() {
        use crate::bench::matrix::MatrixConfig;
        use crate::obs::bench_data;
        let path = std::env::temp_dir()
            .join(format!("arbors_smoke_matrix_{}.js", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let s = smoke(&quick(), &path, true).unwrap();
        let data = bench_data::load(&path);
        let entries = data.get("entries").and_then(|e| e.get("smoke")).unwrap();
        let benches =
            entries.as_arr().unwrap()[0].get("benches").and_then(|b| b.as_arr()).unwrap();
        // Every registry config produced its series — count derived from
        // the enum, never a literal.
        for c in MatrixConfig::ALL {
            let name = format!("matrix/{}", c.name());
            assert!(
                benches
                    .iter()
                    .any(|b| b.get("name").and_then(|v| v.as_str()) == Some(name.as_str())),
                "{name} series missing:\n{s}"
            );
        }
        let n_matrix = benches
            .iter()
            .filter(|b| {
                b.get("name").and_then(|v| v.as_str()).is_some_and(|n| n.starts_with("matrix/"))
            })
            .count();
        assert_eq!(n_matrix, MatrixConfig::ALL.len());
        bench_data::gate(&path).expect("fresh history must pass the gate");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn flint_runs_and_reports() {
        let s = flint(&quick(), true);
        assert!(s.contains("flint µs/inst"), "{s}");
        // All five families appear (bit-identity asserted inside).
        for e in ["NA", "IE", "QS", "VQS", "RS"] {
            assert!(s.contains(e), "{e} row missing:\n{s}");
        }
        assert!(s.contains("flint.json"), "{s}");
        let path = super::super::harness::results_dir().join("flint.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::Json::parse(&text).unwrap();
        assert_eq!(j.get("experiment").and_then(|v| v.as_str()), Some("flint"));
        let engines = j.get("engines").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(engines.len(), EngineKind::ALL.len(), "one row per engine family");
        for e in engines {
            assert!(e.get("f32_us_per_instance").and_then(|v| v.as_f64()).unwrap() > 0.0);
            assert!(e.get("flint_us_per_instance").and_then(|v| v.as_f64()).unwrap() > 0.0);
            assert_eq!(e.get("bit_identical").and_then(|v| v.as_bool()), Some(true));
        }
    }

    #[test]
    fn obs_reports_overhead_and_restores_disabled() {
        // Flips the process-global tracing state: serialize with the span
        // tests via their shared lock.
        let _g = crate::obs::span::TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let s = obs(&quick(), 2);
        assert!(s.contains("tracing off"), "{s}");
        assert!(s.contains("tracing on"), "{s}");
        assert!(s.contains("overhead"), "{s}");
        assert!(!crate::obs::span::enabled(), "harness must re-disable tracing");
        // The enabled run actually recorded spans from the serving path.
        let recorded: Vec<&str> = s.split_whitespace().collect();
        let idx = recorded.iter().position(|w| *w == "spans").expect("span count printed");
        let count: usize =
            recorded[idx - 1].trim_start_matches('(').parse().expect("numeric span count");
        assert!(count > 0, "enabled run must record spans:\n{s}");
    }

    #[test]
    fn engine_micro_reports_simd_ops_per_tier() {
        let s = engine_micro(&quick());
        assert!(s.contains("simd/row"), "{s}");
        assert!(s.contains("engine_micro.json"), "{s}");
        let path = super::super::harness::results_dir().join("engine_micro.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::Json::parse(&text).unwrap();
        let results = j.get("results").and_then(|v| v.as_arr()).unwrap();
        // Every registry tier produced a row (the registry is the source of
        // truth — no hard-coded variant count).
        assert_eq!(results.len(), crate::engine::all_variants_with_i8().len());
        let counter_names: Vec<&str> = crate::neon::OpTrace::default()
            .counters()
            .into_iter()
            .map(|(n, _)| n)
            .collect();
        for r in results {
            let name = r.get("engine").and_then(|v| v.as_str()).unwrap();
            for k in &counter_names {
                assert!(r.get(k).is_some(), "{name} missing counter {k}");
            }
            assert!(r.get("simd_ops_per_row").and_then(|v| v.as_f64()).is_some());
            assert!(
                r.get("total_ops_per_row").and_then(|v| v.as_f64()).unwrap() > 0.0,
                "{name} must execute some ops"
            );
        }
        // SIMD engines vectorize; the scalar naive float engine does not.
        let simd_of = |n: &str| {
            results
                .iter()
                .find(|r| r.get("engine").and_then(|v| v.as_str()) == Some(n))
                .and_then(|r| r.get("simd_ops_per_row"))
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("engine {n} missing"))
        };
        assert!(simd_of("VQS") > 0.0, "VQS is a SIMD engine");
        assert!(simd_of("RS") > 0.0, "RS is a SIMD engine");
        assert_eq!(simd_of("NA"), 0.0, "naive float engine is scalar");
    }

    #[test]
    fn scaling_runs_and_reports_json() {
        let s = scaling(&quick(), 2, None, false);
        assert!(s.contains("2t"), "{s}");
        assert!(s.contains("qRS"), "{s}");
        assert!(s.contains("scaling.json"), "{s}");
        let path = super::super::harness::results_dir().join("scaling.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::Json::parse(&text).unwrap();
        assert_eq!(j.get("experiment").and_then(|v| v.as_str()), Some("scaling"));
        assert!(!j.get("results").and_then(|v| v.as_arr()).unwrap().is_empty());
    }
}
