//! Timing and workload helpers for the benchmark suite.

use std::path::PathBuf;
use std::sync::Arc;

use crate::data::{Dataset, DatasetId};
use crate::engine::Engine;
use crate::forest::builder::{
    train_gbt, train_random_forest, GbtParams, RfParams, TreeParams,
};
use crate::forest::{io, Forest};
use crate::util::Stopwatch;

/// Experiment scale. The paper's full forest sizes take hours to train on
/// this testbed; the default scale preserves every *shape* (who wins, by
/// what factor, where crossovers fall) at tractable sizes. Set
/// `ARBORS_SCALE=full` for paper-scale runs and `ARBORS_SCALE=quick` for
/// smoke runs.
#[derive(Debug, Clone)]
pub struct Scale {
    pub name: &'static str,
    /// Tree counts for the ranking experiment (paper: 1k/5k/10k/20k).
    pub ranking_trees: Vec<usize>,
    /// RF size for Tables 3 & 5 (paper: 1024).
    pub cls_trees: usize,
    /// Tree counts for Figure 1 (paper: 100..1000).
    pub fig_trees: Vec<usize>,
    /// Tree counts for Table 4 (paper: 128/256/512/1024).
    pub merge_trees: Vec<usize>,
    /// Instances timed per measurement.
    pub eval_n: usize,
    /// Median-of-k repeats.
    pub repeats: usize,
    /// Ranking training rows (queries × docs).
    pub msn_queries: usize,
    pub msn_docs: usize,
}

impl Scale {
    pub fn from_env() -> Scale {
        match std::env::var("ARBORS_SCALE").as_deref() {
            Ok("full") => Scale {
                name: "full",
                ranking_trees: vec![1000, 5000, 10000, 20000],
                cls_trees: 1024,
                fig_trees: vec![128, 256, 512, 1024],
                merge_trees: vec![128, 256, 512, 1024],
                eval_n: 1024,
                repeats: 5,
                msn_queries: 300,
                msn_docs: 25,
            },
            Ok("quick") => Scale {
                name: "quick",
                ranking_trees: vec![32, 64],
                cls_trees: 64,
                fig_trees: vec![16, 32, 64],
                merge_trees: vec![16, 32, 64],
                eval_n: 128,
                repeats: 2,
                msn_queries: 40,
                msn_docs: 15,
            },
            _ => Scale {
                name: "default",
                ranking_trees: vec![100, 250, 500, 1000],
                cls_trees: 256,
                fig_trees: vec![32, 64, 128, 256],
                merge_trees: vec![32, 64, 128, 256],
                eval_n: 512,
                repeats: 3,
                msn_queries: 100,
                msn_docs: 20,
            },
        }
    }
}

/// Model cache directory (gitignored) so each forest trains exactly once
/// across bench invocations.
pub fn model_cache_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("models")
}

/// Results directory for archived bench outputs.
pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&dir).ok();
    dir
}

/// Train (or load from cache) an RF for a classification dataset.
pub fn cached_rf(ds: &Dataset, n_trees: usize, max_leaves: usize) -> Forest {
    let key = format!("rf_{}_t{}_l{}_n{}", ds.name, n_trees, max_leaves, ds.n);
    io::cached(&model_cache_dir(), &key, || {
        train_random_forest(
            &ds.x,
            &ds.labels,
            ds.d,
            ds.n_classes,
            RfParams {
                n_trees,
                tree: TreeParams { max_leaves, min_samples_leaf: 2, mtry: 0 },
                seed: 0x5eed ^ n_trees as u64,
                ..Default::default()
            },
        )
    })
}

/// Train (or load) a GBT ranking model on the MSN-like data.
pub fn cached_gbt_ranking(
    queries: usize,
    docs: usize,
    n_trees: usize,
    max_leaves: usize,
) -> Forest {
    let key = format!("gbt_msn_q{queries}x{docs}_t{n_trees}_l{max_leaves}");
    io::cached(&model_cache_dir(), &key, || {
        let ds = crate::data::ranking::msn_like(queries, docs, 0x35b1);
        train_gbt(
            &ds.x,
            &ds.relevance,
            ds.d,
            GbtParams {
                n_trees,
                tree: TreeParams { max_leaves, min_samples_leaf: 2, mtry: 32 },
                learning_rate: 0.1,
                subsample: 0.7,
                seed: 0xb005,
            },
        )
    })
}

/// A forest prefix (first `k` trees) — valid for runtime benchmarking
/// because RF trees are i.i.d. and boosting prefixes are proper models;
/// leaf scaling is uniform so argmax/runtime are unaffected.
pub fn forest_prefix(f: &Forest, k: usize) -> Forest {
    let mut out = f.clone();
    out.trees.truncate(k);
    out
}

/// Median wall-clock µs per instance for an engine on a batch.
pub fn time_per_instance(engine: &dyn Engine, x: &[f32], repeats: usize) -> f64 {
    let n = x.len() / engine.n_features();
    let mut out = vec![0f32; n * engine.n_classes()];
    engine.predict_batch(x, &mut out); // warmup
    let mut times: Vec<f64> = (0..repeats.max(1))
        .map(|_| {
            let sw = Stopwatch::start();
            engine.predict_batch(x, &mut out);
            sw.micros() / n as f64
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Build an evaluation batch from a dataset (first `n` rows, cycled).
pub fn eval_batch(ds: &Dataset, n: usize) -> Vec<f32> {
    let mut x = Vec::with_capacity(n * ds.d);
    for i in 0..n {
        x.extend_from_slice(ds.row(i % ds.n));
    }
    x
}

/// Standard classification workloads at a given tree count.
pub fn classification_workloads(scale: &Scale, max_leaves: usize) -> Vec<(Dataset, Forest)> {
    DatasetId::ALL
        .iter()
        .map(|id| {
            let ds = id.generate(id.default_n(), 0xD5 ^ max_leaves as u64);
            let (train, _test) = ds.split(0.2, 7);
            let f = cached_rf(&train, scale.cls_trees, max_leaves);
            (ds, f)
        })
        .collect()
}

/// Simple fixed-width table writer for bench output.
pub struct TableWriter {
    widths: Vec<usize>,
    out: String,
}

impl TableWriter {
    pub fn new(widths: Vec<usize>) -> TableWriter {
        TableWriter { widths, out: String::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        for (i, cell) in cells.iter().enumerate() {
            let w = self.widths.get(i).copied().unwrap_or(12);
            self.out.push_str(&format!("{cell:>w$} "));
        }
        self.out.push('\n');
    }

    pub fn row_str(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn sep(&mut self) {
        let total: usize = self.widths.iter().sum::<usize>() + self.widths.len();
        self.out.push_str(&"-".repeat(total));
        self.out.push('\n');
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Pre-built engine handle for sweeps.
pub fn build_engine_arc(
    kind: crate::engine::EngineKind,
    precision: crate::engine::Precision,
    forest: &Forest,
) -> Option<Arc<dyn Engine>> {
    crate::engine::build(kind, precision, forest, None).ok().map(Arc::from)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineKind, Precision};

    #[test]
    fn scales_parse() {
        let s = Scale::from_env();
        assert!(!s.ranking_trees.is_empty());
    }

    #[test]
    fn timing_positive() {
        let ds = DatasetId::Magic.generate(300, 91);
        let f = cached_rf(&ds, 4, 8);
        let e = build_engine_arc(EngineKind::Naive, Precision::F32, &f).unwrap();
        let x = eval_batch(&ds, 64);
        let t = time_per_instance(e.as_ref(), &x, 2);
        assert!(t > 0.0);
    }

    #[test]
    fn prefix_is_valid_forest() {
        let ds = DatasetId::Magic.generate(300, 92);
        let f = cached_rf(&ds, 8, 8);
        let p = forest_prefix(&f, 3);
        assert_eq!(p.n_trees(), 3);
        p.validate().unwrap();
    }

    #[test]
    fn table_writer_aligns() {
        let mut t = TableWriter::new(vec![6, 8]);
        t.row_str(&["a", "b"]);
        t.sep();
        let s = t.finish();
        assert!(s.contains('a') && s.contains('-'));
    }
}
