//! Minimal JSON value model, parser and writer.
//!
//! `serde`/`serde_json` are not available in the offline build environment, so
//! model (de)serialization, artifact manifests and bench reports use this
//! small, dependency-free implementation. It supports the full JSON grammar
//! (RFC 8259) minus exotic number edge cases, plus pretty printing.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error produced by [`Json::parse`].
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------- constructors
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn array_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn array_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ------------------------------------------------------------- accessors
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that returns an error naming the missing key — for loaders.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError { pos: 0, msg: format!("missing key '{key}'") })
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<f32> {
        self.as_f64().map(|n| n as f32)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?.iter().map(|v| v.as_f32()).collect()
    }

    pub fn to_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // --------------------------------------------------------------- parsing
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // --------------------------------------------------------------- writing
    /// Compact single-line serialization.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        // Integral values print without a fractional part, which keeps model
        // files (node indices, bitmask words) readable and compact.
        let _ = fmt::Write::write_fmt(out, format_args!("{}", n as i64));
    } else if n.is_finite() {
        let _ = fmt::Write::write_fmt(out, format_args!("{}", n));
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are rare in our files; map lone
                            // surrogates to the replacement character.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2, "{s}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": [true, null]}], "d": -2.25e-1}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("d").unwrap().as_f64().unwrap(), -0.225);
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "[1] x"] {
            assert!(Json::parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".to_string());
        let out = v.dump();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn every_control_char_escapes_and_roundtrips() {
        for cp in 0u32..0x20 {
            let c = char::from_u32(cp).unwrap();
            let v = Json::Str(format!("a{c}b"));
            let out = v.dump();
            // The writer must never emit a raw control byte.
            assert!(out.bytes().all(|b| b >= 0x20), "raw control byte in {out:?}");
            let expected = match c {
                '\n' => "\"a\\nb\"".to_string(),
                '\r' => "\"a\\rb\"".to_string(),
                '\t' => "\"a\\tb\"".to_string(),
                _ => format!("\"a\\u{cp:04x}b\""),
            };
            assert_eq!(out, expected, "codepoint {cp:#04x}");
            assert_eq!(Json::parse(&out).unwrap(), v, "codepoint {cp:#04x}");
        }
    }

    #[test]
    fn quotes_and_backslashes() {
        // Adversarial backslash/quote runs, including a trailing backslash and
        // sequences that would change meaning if escaping were off by one.
        let cases = ["\"", "\\", "\\\"", "\"\\", "a\\", "\\\\\\", "\\u0041", "end\"", "\\n"];
        for s in cases {
            let v = Json::Str(s.to_string());
            let out = v.dump();
            assert_eq!(Json::parse(&out).unwrap(), v, "case {s:?} -> {out:?}");
        }
        // The literal two characters `\n` must not collapse into a newline.
        assert_eq!(Json::Str("\\n".into()).dump(), r#""\\n""#);
        assert_eq!(Json::Str("\"".into()).dump(), r#""\"""#);
        assert_eq!(Json::Str("\\".into()).dump(), r#""\\""#);
    }

    #[test]
    fn parser_accepts_all_short_escapes() {
        let v = Json::parse(r#""q\" s\\ sol\/ b\b f\f n\n r\r t\t uA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "q\" s\\ sol/ b\u{8} f\u{c} n\n r\r t\t uA");
        // \b and \f have no short form on output; they round-trip via \uXXXX.
        let back = Json::Str("\u{8}\u{c}".to_string());
        assert_eq!(back.dump(), r#""\u0008\u000c""#);
        assert_eq!(Json::parse(&back.dump()).unwrap(), back);
    }

    #[test]
    fn non_ascii_passes_through_unescaped() {
        // Multi-byte UTF-8 (2, 3 and 4 byte sequences) is emitted raw, not
        // \u-escaped, and survives a round trip — including as object keys.
        let s = "é → 木 🌲";
        let v = Json::from_pairs(vec![(s, Json::Str(s.to_string()))]);
        let out = v.dump();
        assert!(out.contains(s), "non-ascii was escaped in {out:?}");
        let v2 = Json::parse(&out).unwrap();
        assert_eq!(v2.get(s).unwrap().as_str().unwrap(), s);
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn f32_vec_helpers() {
        let v = Json::array_f32(&[1.0, 2.5, -3.0]);
        assert_eq!(v.to_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn large_ints_stable() {
        let v = Json::Num(4294967295.0); // u32::MAX as a bitmask word
        assert_eq!(Json::parse(&v.dump()).unwrap().as_f64().unwrap(), 4294967295.0);
    }
}
