//! Timing utilities shared by the bench harness, the coordinator metrics and
//! the examples.

use std::time::Instant;

/// Stopwatch measuring elapsed wall-clock time in microseconds.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn micros(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e6
    }

    pub fn millis(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let us = self.micros();
        self.start = Instant::now();
        us
    }
}

/// Simple summary statistics over a sample of measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub median: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for an empty slice.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, median: 0.0, p95: 0.0, p99: 0.0, max: 0.0 };
        }
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            median: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, `q` in `[0,1]`.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        let a = sw.micros();
        let b = sw.micros();
        assert!(b >= a);
    }
}
