//! Deterministic pseudo-random number generation.
//!
//! The crates.io registry is not reachable in the build environment, so this
//! module provides the small slice of `rand` that the rest of the crate needs:
//! a fast, seedable PCG-XSH-RR 32-bit generator plus the usual derived
//! distributions (uniform ranges, Gaussians, shuffles, subsampling).
//!
//! Everything in the repository that involves randomness (dataset synthesis,
//! forest training, property tests, workload generation) goes through [`Pcg32`]
//! with an explicit seed, so every experiment is exactly reproducible.

/// PCG-XSH-RR 64/32 generator (O'Neill, 2014).
///
/// 64-bit state, 64-bit stream, 32-bit output. Passes BigCrush; tiny and fast,
/// which matters because the trainers draw millions of samples.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a single seed (stream 0xda3e39cb94b95bdb).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent child generator; used to hand each tree in a
    /// forest / each worker thread its own stream.
    pub fn split(&mut self) -> Self {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Self::new(seed, stream)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` using Lemire's rejection method
    /// (unbiased).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul128(x, bound);
            if lo >= bound || lo >= x.wrapping_neg() % bound {
                return hi as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; the pair is not
    /// cached to keep the generator state a pure function of draw count).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[inline]
fn mul128(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_bounds_and_covers() {
        let mut rng = Pcg32::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_unit_interval() {
        let mut rng = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let v = rng.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(5);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::seeded(9);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }

    #[test]
    fn split_streams_independent() {
        let mut parent = Pcg32::seeded(1234);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }
}
