//! Dependency-free substrate utilities: JSON, PRNG, timing.
//!
//! These exist because the offline build environment has no access to
//! crates.io; they implement exactly the surface the rest of the crate needs
//! (see DESIGN.md §1, "Substitutions").

pub mod json;
pub mod rng;
pub mod timer;

pub use json::Json;
pub use rng::Pcg32;
pub use timer::{percentile, Stopwatch, Summary};
