//! Minimal command-line argument parser (no `clap` offline).
//!
//! Grammar: `arbors <command> [positional...] [--key value]... [--switch]...`
//! Flags may use `--key=value` or `--key value`. Unknown flags are collected
//! and reported by `finish()` so typos fail loudly.

use std::collections::{BTreeMap, BTreeSet};

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: BTreeSet<String>,
    consumed: std::cell::RefCell<BTreeSet<String>>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(iter: impl IntoIterator<Item = String>) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with("--") {
                args.command = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    args.flags.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    args.switches.insert(stripped.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// String flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().insert(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Numeric flag with default.
    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    /// Optional numeric flag: `None` when absent (for flags whose default
    /// is computed, e.g. `serve --budget` defaulting to the pool size).
    pub fn usize_opt(&self, key: &str) -> anyhow::Result<Option<usize>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    /// Boolean switch.
    pub fn switch(&self, key: &str) -> bool {
        self.consumed.borrow_mut().insert(key.to_string());
        self.switches.contains(key)
    }

    /// Error on unknown flags (call after reading all expected ones).
    pub fn finish(&self) -> anyhow::Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !consumed.contains(k.as_str()))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unknown flag(s): {unknown:?}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn basic_grammar() {
        // Note: a bare token after `--switch` would parse as its value, so
        // positionals come before switches (documented grammar).
        let a = parse("train file.json --dataset magic --trees 64 --quant");
        assert_eq!(a.command, "train");
        assert_eq!(a.get("dataset"), Some("magic"));
        assert_eq!(a.usize_or("trees", 1).unwrap(), 64);
        assert!(a.switch("quant"));
        assert_eq!(a.positional, vec!["file.json"]);
        a.finish().unwrap();
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --exp=table5");
        assert_eq!(a.get("exp"), Some("table5"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flags_rejected() {
        let a = parse("train --bogus 1");
        let _ = a.get("dataset");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_number_reported() {
        let a = parse("x --trees nope");
        assert!(a.usize_or("trees", 1).is_err());
    }

    #[test]
    fn optional_numeric_flag() {
        let a = parse("serve --budget 3");
        assert_eq!(a.usize_opt("budget").unwrap(), Some(3));
        assert_eq!(a.usize_opt("threads").unwrap(), None);
        let bad = parse("serve --budget x");
        assert!(bad.usize_opt("budget").is_err());
    }

    #[test]
    fn switch_vs_flag_disambiguation() {
        let a = parse("x --quant --out file");
        assert!(a.switch("quant"));
        assert_eq!(a.get("out"), Some("file"));
    }
}
