//! # arbors — fast inference of tree ensembles
//!
//! A reproduction of *"Fast Inference of Tree Ensembles on ARM Devices"*
//! (Koschel, Buschjäger, Lucchese, Morik, 2023) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Engines** ([`engine`]): the paper's five traversal strategies —
//!   Naive (NA), If-Else (IE), QuickScorer (QS), V-QuickScorer (VQS),
//!   RapidScorer (RS) — each in float32, int16 **and** int8 fixed-point
//!   variants (precision tiers, [`quant::QuantInt`]; the i8 tier adds
//!   per-tree leaf scales with rounding shifts at sum time), plus a
//!   fourth *virtual* tier: the FLInt carrier ([`quant::flint`]), which
//!   runs threshold compares on the integer SIMD pipe via an
//!   order-preserving `f32→i32` map while staying bit-identical to f32.
//!   The SIMD engines execute the paper's ARM NEON algorithms on a
//!   bit-exact NEON simulator ([`neon`]).
//! * **Execution runtime** ([`exec`]): a sharded, work-stealing parallel
//!   execution layer — a std-only worker pool with cluster pinning
//!   ([`exec::affinity`]) and fairness-preserving batch claiming, a
//!   big.LITTLE-aware shard planner (row / tree / hybrid) whose row-plan
//!   weights adapt to measured shard throughput ([`exec::Feedback`]), and
//!   a [`exec::ParallelEngine`] wrapper that multiplies any engine across
//!   cores while staying bit-exact with the serial implementation under
//!   its default policy — including across adaptive re-plans.
//! * **Coordinator** ([`coordinator`]): a serving layer with dynamic
//!   batching fused onto one server-shared work-stealing pool (request
//!   chunks flow straight onto worker queues; per-deployment thread
//!   budgets with weighted fair stealing), a model registry, and an
//!   engine auto-selector (serial and threaded candidates).
//! * **Tensor path** ([`runtime`], `engine::tensor`): forests AOT-compiled
//!   through JAX/Pallas to HLO and executed via PJRT.
//! * **Observability** ([`obs`]): request→lane span tracing (chrome-trace
//!   export), log-bucketed histogram metrics, pool/scheduler introspection
//!   (`stats --json`), and per-commit perf history with a rolling-median
//!   regression gate (`dev/bench/data.js`, `bench --gate`).
//! * **Substrates**: forest trainers ([`forest::builder`]), synthetic
//!   datasets ([`data`]), quantization ([`quant`]), per-device cost models
//!   ([`device`]), rank statistics ([`stats`]), and utility layers built
//!   from scratch for the offline environment ([`util`], [`testing`]).
//!
//! See DESIGN.md for the full system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod neon;
pub mod device;
pub mod engine;
pub mod exec;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod stats;
pub mod forest;
pub mod testing;
pub mod util;
