//! Synthetic classification dataset generators (see `data/mod.rs` docs for
//! how each maps onto its real counterpart).
//!
//! Labels come from a hidden "teacher": class prototypes plus noise with a
//! tuned label-noise rate, so Random Forests reach accuracies in the same
//! band the paper reports (Table 3: 74–89%) and so accuracy *degrades
//! measurably* when quantization destroys informative thresholds.

use super::Dataset;
use crate::util::Pcg32;

/// Shared prototype-based generator core.
///
/// `informative` features carry class signal (prototype + sigma·noise); the
/// rest are pure noise. `label_noise` flips labels uniformly. `post` lets a
/// caller reshape raw feature values (binarize, grid-quantize, inject
/// outliers) before the dataset-level min-max normalization.
fn prototype_data(
    name: &str,
    n: usize,
    d: usize,
    n_classes: usize,
    informative: usize,
    sigma: f64,
    label_noise: f64,
    seed: u64,
    post: impl Fn(&mut Pcg32, usize, usize, f32) -> f32,
) -> Dataset {
    let mut rng = Pcg32::seeded(seed ^ 0xa5a5_0000);
    // Class prototypes over the informative features.
    let protos: Vec<Vec<f64>> = (0..n_classes)
        .map(|_| (0..informative).map(|_| rng.normal()).collect())
        .collect();

    let mut x = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let true_class = rng.below(n_classes);
        let label =
            if rng.bool(label_noise) { rng.below(n_classes) } else { true_class } as u32;
        for f in 0..d {
            let raw = if f < informative {
                protos[true_class][f] + sigma * rng.normal()
            } else {
                rng.normal()
            };
            x.push(post(&mut rng, i, f, raw as f32));
        }
        labels.push(label);
    }
    Dataset { name: name.to_string(), x, labels, n, d, n_classes }
}

/// Magic04-like: 10 smooth continuous features, 2 classes, moderate overlap.
pub fn magic_like(n: usize, seed: u64) -> Dataset {
    prototype_data("magic", n, 10, 2, 8, 1.15, 0.02, seed, |_, _, _, v| v)
}

/// Adult-like: 108 features of which ~100 are one-hot binary (the real Adult
/// dataset after one-hot encoding); 8 "numeric" features stay continuous.
/// Binary features give every split the same threshold (0.5 after
/// normalization) → RapidScorer merges aggressively (paper Table 4: 6%
/// unique nodes).
pub fn adult_like(n: usize, seed: u64) -> Dataset {
    prototype_data("adult", n, 108, 2, 40, 1.3, 0.06, seed, |rng, _, f, v| {
        if f < 8 {
            v // numeric block
        } else {
            // One-hot block: threshold the latent value so the feature is
            // informative but binary; sparsity like one-hot categories.
            let cut = 0.4 + 0.1 * ((f % 7) as f32);
            if v > cut || rng.bool(0.02) {
                1.0
            } else {
                0.0
            }
        }
    })
}

/// EEG-like: 14 continuous features whose informative variation lives in a
/// narrow band, plus rare extreme outliers (the real EEG eye-state data has
/// sensor glitches up to ~7×10⁵ against a ~4000–4600 operating range).
/// After min-max normalization the informative thresholds land within a
/// ~6×10⁻³ interval, i.e. only a couple hundred distinct ⌊2¹⁵·x⌋ values —
/// int16 quantization then collides formerly-distinct thresholds, which is
/// exactly the paper's EEG anomaly (Table 3 accuracy drop, Table 4 merge
/// collapse).
pub fn eeg_like(n: usize, seed: u64) -> Dataset {
    let mut ds = prototype_data("eeg", n, 14, 2, 12, 1.8, 0.06, seed, |rng, _, _, v| {
        // Operating band: integer ADC counts 4300 ± ~250 — discrete levels
        // (so float thresholds already collide somewhat, as in the paper's
        // 52% float uniqueness) within a tiny fraction of the min-max range
        // (so int16 quantization collides them much harder).
        let base = (4300.0 + 18.0 * v).round();
        if rng.bool(0.0015) {
            // Sensor glitch: huge outlier that will dominate min-max range.
            if rng.bool(0.5) {
                715_897.0
            } else {
                86.0
            }
        } else {
            base
        }
    });
    // Ensure at least one high and one low outlier exist so the normalized
    // band is stable across sample sizes.
    if ds.n >= 2 {
        ds.x[0] = 715_897.0;
        ds.x[ds.d + 1 % ds.d] = 86.0;
    }
    ds
}

/// MNIST-like: 784 pixel features on a 256-level grid, 10 classes, with the
/// outer border mostly zero (like real digit images).
pub fn mnist_like(n: usize, seed: u64) -> Dataset {
    grid_image_like("mnist", n, seed, 0.25)
}

/// Fashion-MNIST-like: same shape as MNIST but denser images (garments fill
/// more of the frame than digit strokes).
pub fn fashion_like(n: usize, seed: u64) -> Dataset {
    grid_image_like("fashion", n, seed, 0.55)
}

fn grid_image_like(name: &str, n: usize, seed: u64, density: f64) -> Dataset {
    let d = 784;
    let n_classes = 10;
    // Class confusability: pairs of classes share most of their template
    // (like 4/9 or shirt/pullover), plus label noise — keeps RF accuracy in
    // the paper's 80-90% band instead of a saturated 100%.
    let label_noise = 0.06;
    let mut rng = Pcg32::seeded(seed ^ 0x1a6e);
    // Per-class "stroke template": mean intensity per pixel.
    let side = 28usize;
    let mut templates = vec![vec![0f32; d]; n_classes];
    for t in templates.iter_mut() {
        // A few random blobs per class.
        for _ in 0..4 {
            let cx = rng.range(4, side - 4) as f64;
            let cy = rng.range(4, side - 4) as f64;
            let r = 1.5 + 3.0 * rng.f64();
            for yy in 0..side {
                for xx in 0..side {
                    let dist2 = ((xx as f64 - cx).powi(2) + (yy as f64 - cy).powi(2)) / (r * r);
                    if dist2 < 1.0 {
                        t[yy * side + xx] += ((1.0 - dist2) * 200.0) as f32;
                    }
                }
            }
        }
    }
    // Make classes 2k and 2k+1 near-twins: blend their templates.
    for k in 0..n_classes / 2 {
        let a = templates[2 * k].clone();
        let b = templates[2 * k + 1].clone();
        for p in 0..d {
            templates[2 * k][p] = 0.7 * a[p] + 0.3 * b[p];
            templates[2 * k + 1][p] = 0.3 * a[p] + 0.7 * b[p];
        }
    }
    let mut x = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let class = rng.below(n_classes);
        let label = if rng.bool(label_noise) { rng.below(n_classes) } else { class };
        labels.push(label as u32);
        for p in 0..d {
            let border = {
                let (px, py) = (p % side, p / side);
                px < 3 || px >= side - 3 || py < 3 || py >= side - 3
            };
            let mean = templates[class][p];
            let v = if border && !rng.bool(0.01) {
                0.0
            } else if mean > 0.0 || rng.bool(density * 0.2) {
                (mean as f64 + 70.0 * rng.normal()).clamp(0.0, 255.0)
            } else {
                0.0
            };
            // Snap to the 256-level pixel grid: quantization-proof spacing.
            x.push((v.round() as f32).clamp(0.0, 255.0));
        }
    }
    Dataset { name: name.to_string(), x, labels, n, d, n_classes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::builder::{train_random_forest, RfParams, TreeParams};

    #[test]
    fn adult_is_mostly_binary() {
        let ds = adult_like(300, 7);
        let mut binary_feats = 0;
        for f in 8..ds.d {
            let distinct: std::collections::BTreeSet<u32> =
                (0..ds.n).map(|i| ds.x[i * ds.d + f].to_bits()).collect();
            if distinct.len() <= 2 {
                binary_feats += 1;
            }
        }
        assert!(binary_feats >= 95, "only {binary_feats} binary features");
    }

    #[test]
    fn eeg_band_is_narrow_after_normalization() {
        let mut ds = eeg_like(2000, 3);
        ds.normalize();
        // Most values should live in a tiny band; compute the interquartile
        // spread of feature 2.
        let mut col: Vec<f32> = (0..ds.n).map(|i| ds.x[i * ds.d + 2]).collect();
        col.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let iqr = col[(ds.n * 3) / 4] - col[ds.n / 4];
        assert!(iqr < 1e-3, "iqr = {iqr} (band not narrow)");
    }

    #[test]
    fn mnist_pixels_on_grid() {
        let ds = mnist_like(50, 1);
        assert!(ds.x.iter().all(|&v| v == v.round() && (0.0..=255.0).contains(&v)));
    }

    #[test]
    fn learnable_by_rf() {
        // Every dataset must be learnable well above chance by a small RF —
        // otherwise the accuracy tables (Table 3) would be meaningless.
        for (ds, chance) in [
            (super::super::DatasetId::Magic.generate(1500, 11), 0.5),
            (super::super::DatasetId::Adult.generate(1500, 11), 0.5),
            (super::super::DatasetId::Eeg.generate(1500, 11), 0.5),
        ] {
            let (train, test) = ds.split(0.25, 1);
            let f = train_random_forest(
                &train.x,
                &train.labels,
                train.d,
                train.n_classes,
                RfParams {
                    n_trees: 24,
                    tree: TreeParams { max_leaves: 32, min_samples_leaf: 2, mtry: 0 },
                    ..Default::default()
                },
            );
            let acc = f.accuracy(&test.x, &test.labels);
            assert!(acc > chance + 0.15, "{}: acc {acc}", ds.name);
        }
    }
}
