//! Tiny CSV reader/writer so users can bring their own datasets to the CLI
//! (`arbors train --data file.csv`) and export predictions.
//!
//! Format: optional header row, comma-separated numeric fields, label in the
//! last column for classification data. No quoting (numeric data only).

use std::path::Path;

use super::Dataset;

/// Write a dataset to CSV with a generated header (`f0..f{d-1},label`).
pub fn write_dataset(ds: &Dataset, path: &Path) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = String::new();
    for f in 0..ds.d {
        out.push_str(&format!("f{f},"));
    }
    out.push_str("label\n");
    for i in 0..ds.n {
        for v in ds.row(i) {
            out.push_str(&format!("{v},"));
        }
        out.push_str(&format!("{}\n", ds.labels[i]));
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Read a CSV of numeric features with the label in the last column.
/// A non-numeric first row is treated as a header and skipped.
pub fn read_dataset(path: &Path, name: &str) -> anyhow::Result<Dataset> {
    let text = std::fs::read_to_string(path)?;
    let mut x = Vec::new();
    let mut labels = Vec::new();
    let mut d = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        if lineno == 0 && fields[0].parse::<f32>().is_err() {
            continue; // header
        }
        if fields.len() < 2 {
            anyhow::bail!("{path:?}:{}: need at least one feature + label", lineno + 1);
        }
        let row_d = fields.len() - 1;
        if d == 0 {
            d = row_d;
        } else if d != row_d {
            anyhow::bail!("{path:?}:{}: ragged row ({row_d} vs {d} features)", lineno + 1);
        }
        for f in &fields[..row_d] {
            x.push(
                f.parse::<f32>()
                    .map_err(|_| anyhow::anyhow!("{path:?}:{}: bad number '{f}'", lineno + 1))?,
            );
        }
        labels.push(
            fields[row_d]
                .parse::<f32>()
                .map_err(|_| anyhow::anyhow!("{path:?}:{}: bad label", lineno + 1))? as u32,
        );
    }
    if labels.is_empty() {
        anyhow::bail!("{path:?}: empty dataset");
    }
    let n = labels.len();
    let n_classes = labels.iter().copied().max().unwrap_or(0) as usize + 1;
    Ok(Dataset { name: name.to_string(), x, labels, n, d, n_classes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetId;

    #[test]
    fn roundtrip() {
        let ds = DatasetId::Magic.generate(40, 2);
        let path = std::env::temp_dir().join(format!("arbors_csv_{}.csv", std::process::id()));
        write_dataset(&ds, &path).unwrap();
        let ds2 = read_dataset(&path, "magic").unwrap();
        assert_eq!(ds.n, ds2.n);
        assert_eq!(ds.d, ds2.d);
        assert_eq!(ds.labels, ds2.labels);
        for (a, b) in ds.x.iter().zip(&ds2.x) {
            assert!((a - b).abs() < 1e-5);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_ragged() {
        let path = std::env::temp_dir().join(format!("arbors_rag_{}.csv", std::process::id()));
        std::fs::write(&path, "1,2,0\n1,0\n").unwrap();
        assert!(read_dataset(&path, "x").is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn skips_header() {
        let path = std::env::temp_dir().join(format!("arbors_hdr_{}.csv", std::process::id()));
        std::fs::write(&path, "a,b,label\n0.5,1.5,1\n").unwrap();
        let ds = read_dataset(&path, "x").unwrap();
        assert_eq!(ds.n, 1);
        assert_eq!(ds.d, 2);
        assert_eq!(ds.labels, vec![1]);
        std::fs::remove_file(&path).ok();
    }
}
