//! MSN/MSLR-like learning-to-rank dataset (paper §6, Q1).
//!
//! The real MSN dataset has 136 features per query-document pair and graded
//! relevance labels 0–4 grouped by query. The generator reproduces that
//! shape: a hidden scoring function (sparse linear + pairwise interactions +
//! per-query bias) produces a latent score that is bucketed into the five
//! relevance grades.

use super::Dataset;
use crate::util::Pcg32;

/// A query-grouped ranking dataset.
#[derive(Debug, Clone)]
pub struct RankingDataset {
    /// Row-major `[n × d]` feature matrix.
    pub x: Vec<f32>,
    /// Graded relevance 0..=4 per row (stored as f32 — regression target).
    pub relevance: Vec<f32>,
    /// Query id per row (rows of one query are contiguous).
    pub query_ids: Vec<u32>,
    pub n: usize,
    pub d: usize,
}

impl RankingDataset {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Offsets of each query group: `groups()[q]..groups()[q+1]`.
    pub fn groups(&self) -> Vec<usize> {
        let mut out = vec![0usize];
        for i in 1..self.n {
            if self.query_ids[i] != self.query_ids[i - 1] {
                out.push(i);
            }
        }
        out.push(self.n);
        out
    }

    /// View as a plain dataset (for feature normalization reuse).
    pub fn as_dataset(&self) -> Dataset {
        Dataset {
            name: "msn".into(),
            x: self.x.clone(),
            labels: self.relevance.iter().map(|&r| r as u32).collect(),
            n: self.n,
            d: self.d,
            n_classes: 5,
        }
    }

    /// NDCG@k averaged over queries for a score vector (higher = better).
    pub fn ndcg(&self, scores: &[f32], k: usize) -> f64 {
        assert_eq!(scores.len(), self.n);
        let groups = self.groups();
        let mut total = 0f64;
        let mut n_q = 0usize;
        for w in groups.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            let m = hi - lo;
            let mut order: Vec<usize> = (lo..hi).collect();
            order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
            let dcg: f64 = order
                .iter()
                .take(k.min(m))
                .enumerate()
                .map(|(r, &i)| (2f64.powf(self.relevance[i] as f64) - 1.0) / (r as f64 + 2.0).log2())
                .sum();
            let mut ideal: Vec<f32> = self.relevance[lo..hi].to_vec();
            ideal.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let idcg: f64 = ideal
                .iter()
                .take(k.min(m))
                .enumerate()
                .map(|(r, &rel)| (2f64.powf(rel as f64) - 1.0) / (r as f64 + 2.0).log2())
                .sum();
            if idcg > 0.0 {
                total += dcg / idcg;
                n_q += 1;
            }
        }
        if n_q == 0 {
            0.0
        } else {
            total / n_q as f64
        }
    }
}

/// Generate an MSLR-shaped ranking dataset: `n_queries` queries ×
/// `docs_per_query` documents, 136 features in `[0,1]`, relevance 0–4.
pub fn msn_like(n_queries: usize, docs_per_query: usize, seed: u64) -> RankingDataset {
    let d = 136;
    let mut rng = Pcg32::seeded(seed ^ MSN_SEED_SALT);
    // Hidden scorer: sparse linear weights + a few interaction pairs.
    let mut w = vec![0f64; d];
    for i in rng.sample_indices(d, 24) {
        w[i] = rng.normal();
    }
    let pairs: Vec<(usize, usize, f64)> =
        (0..8).map(|_| (rng.below(d), rng.below(d), rng.normal())).collect();

    let n = n_queries * docs_per_query;
    let mut x = Vec::with_capacity(n * d);
    let mut relevance = Vec::with_capacity(n);
    let mut query_ids = Vec::with_capacity(n);

    for q in 0..n_queries {
        let qbias = 0.4 * rng.normal();
        for _ in 0..docs_per_query {
            let row_start = x.len();
            for _ in 0..d {
                x.push(rng.f32());
            }
            let row = &x[row_start..row_start + d];
            let mut s = qbias;
            // Centered terms so the latent score is ~N(0, 1.5) regardless of
            // the drawn weights — keeps all five grades populated.
            for (i, &v) in row.iter().enumerate() {
                s += w[i] * (v as f64 - 0.5);
            }
            for &(a, b, c) in &pairs {
                s += c * ((row[a] as f64) * (row[b] as f64) - 0.25);
            }
            s += 0.3 * rng.normal();
            // Bucket latent score into grades with an uneven prior like the
            // real MSLR label distribution (mostly 0/1, few 4s).
            let rel = if s < -0.8 {
                0.0
            } else if s < 0.2 {
                1.0
            } else if s < 1.0 {
                2.0
            } else if s < 1.8 {
                3.0
            } else {
                4.0
            };
            relevance.push(rel);
            query_ids.push(q as u32);
        }
    }
    RankingDataset { x, relevance, query_ids, n, d }
}

/// Seed salt so ranking data never collides with a classification stream.
const MSN_SEED_SALT: u64 = 0x35b1;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::builder::{train_gbt, GbtParams, TreeParams};

    #[test]
    fn shape_and_grouping() {
        let ds = msn_like(10, 20, 1);
        assert_eq!(ds.n, 200);
        assert_eq!(ds.d, 136);
        assert_eq!(ds.groups().len(), 11);
        assert!(ds.relevance.iter().all(|&r| (0.0..=4.0).contains(&r)));
    }

    #[test]
    fn grades_are_diverse() {
        let ds = msn_like(40, 25, 2);
        let mut seen = [false; 5];
        for &r in &ds.relevance {
            seen[r as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 4, "{seen:?}");
    }

    #[test]
    fn ndcg_of_perfect_ranking_is_one() {
        let ds = msn_like(5, 10, 3);
        let scores: Vec<f32> = ds.relevance.clone();
        let ndcg = ds.ndcg(&scores, 10);
        assert!((ndcg - 1.0).abs() < 1e-9, "{ndcg}");
    }

    #[test]
    fn gbt_beats_random_ranking() {
        let ds = msn_like(30, 20, 5);
        let f = train_gbt(
            &ds.x,
            &ds.relevance,
            ds.d,
            GbtParams {
                n_trees: 40,
                tree: TreeParams { max_leaves: 16, min_samples_leaf: 2, mtry: 24 },
                learning_rate: 0.2,
                ..Default::default()
            },
        );
        let pred = f.predict_batch(&ds.x);
        let model_ndcg = ds.ndcg(&pred, 10);
        let mut rng = crate::util::Pcg32::seeded(1);
        let random: Vec<f32> = (0..ds.n).map(|_| rng.f32()).collect();
        let random_ndcg = ds.ndcg(&random, 10);
        assert!(
            model_ndcg > random_ndcg + 0.1,
            "model {model_ndcg} vs random {random_ndcg}"
        );
    }

    #[test]
    fn deterministic() {
        let a = msn_like(3, 5, 9);
        let b = msn_like(3, 5, 9);
        assert_eq!(a.x, b.x);
        assert_eq!(a.relevance, b.relevance);
    }
}
