//! Datasets: containers, normalization, splits, and the synthetic generators
//! standing in for the paper's public datasets (offline environment — see
//! DESIGN.md §1 "Substitutions").
//!
//! Each generator is matched to its real counterpart in dimensionality, class
//! count and — crucially for the paper's findings — *threshold distribution*:
//!
//! * `magic_like`  — d=10, C=2, smooth continuous features (Magic04).
//! * `adult_like`  — d=108, C=2, mostly one-hot binary features (Adult after
//!   one-hot encoding), so split thresholds collapse onto ~one value per
//!   feature → heavy RapidScorer node merging (paper Table 4: 6% unique).
//! * `eeg_like`    — d=14, C=2, continuous with extreme outliers; min-max
//!   normalization squeezes the informative range into a tiny band, so int16
//!   fixed-point quantization collides thresholds → the paper's EEG accuracy
//!   drop (Table 3) and merge collapse (Table 4).
//! * `mnist_like` / `fashion_like` — d=784, C=10, pixel features on a 256
//!   level grid (levels spaced 1/255 ≫ 2⁻¹⁵, so quantization is lossless,
//!   matching the paper's unchanged MNIST/Fashion rows).
//! * `msn_like`    — learning-to-rank: 136 features, graded relevance 0–4,
//!   query groups (MSLR-WEB10K shape).

pub mod csv;
pub mod ranking;
pub mod synth;

pub use ranking::RankingDataset;

use crate::util::Pcg32;

/// A dense classification dataset (row-major features).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    /// Row-major `[n × d]`.
    pub x: Vec<f32>,
    pub labels: Vec<u32>,
    pub n: usize,
    pub d: usize,
    pub n_classes: usize,
}

impl Dataset {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Min-max normalize every feature to `[0, 1]` in place; constant
    /// features map to 0. Returns the per-feature `(min, max)` used, so the
    /// same affine map can be applied at serving time.
    ///
    /// This is the preprocessing the paper's fixed-point pipeline assumes:
    /// `q(x) = ⌊s·x⌋` with `s = 2^15` stored in an int16 requires `|x| ≤ 1`.
    pub fn normalize(&mut self) -> Vec<(f32, f32)> {
        let mut ranges = vec![(f32::INFINITY, f32::NEG_INFINITY); self.d];
        for i in 0..self.n {
            for f in 0..self.d {
                let v = self.x[i * self.d + f];
                ranges[f].0 = ranges[f].0.min(v);
                ranges[f].1 = ranges[f].1.max(v);
            }
        }
        for i in 0..self.n {
            for f in 0..self.d {
                let (lo, hi) = ranges[f];
                let v = &mut self.x[i * self.d + f];
                *v = if hi > lo { (*v - lo) / (hi - lo) } else { 0.0 };
            }
        }
        ranges
    }

    /// Apply a previously computed normalization to a feature row.
    pub fn apply_normalization(row: &mut [f32], ranges: &[(f32, f32)]) {
        for (v, &(lo, hi)) in row.iter_mut().zip(ranges) {
            *v = if hi > lo { ((*v - lo) / (hi - lo)).clamp(0.0, 1.0) } else { 0.0 };
        }
    }

    /// Deterministic shuffled `train/test` split; `test_frac` in `(0,1)`.
    pub fn split(&self, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
        let mut idx: Vec<usize> = (0..self.n).collect();
        let mut rng = Pcg32::seeded(seed);
        rng.shuffle(&mut idx);
        let n_test = ((self.n as f64) * test_frac).round() as usize;
        let (test_idx, train_idx) = idx.split_at(n_test);
        (self.subset(train_idx, "train"), self.subset(test_idx, "test"))
    }

    fn subset(&self, idx: &[usize], suffix: &str) -> Dataset {
        let mut x = Vec::with_capacity(idx.len() * self.d);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            labels.push(self.labels[i]);
        }
        Dataset {
            name: format!("{}-{}", self.name, suffix),
            x,
            labels,
            n: idx.len(),
            d: self.d,
            n_classes: self.n_classes,
        }
    }

    /// Class frequency histogram.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_classes];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }
}

/// The five classification benchmarks by paper name.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetId {
    Magic,
    Adult,
    Eeg,
    Mnist,
    Fashion,
}

impl DatasetId {
    pub const ALL: [DatasetId; 5] =
        [DatasetId::Magic, DatasetId::Mnist, DatasetId::Adult, DatasetId::Eeg, DatasetId::Fashion];

    pub fn name(&self) -> &'static str {
        match self {
            DatasetId::Magic => "magic",
            DatasetId::Adult => "adult",
            DatasetId::Eeg => "eeg",
            DatasetId::Mnist => "mnist",
            DatasetId::Fashion => "fashion",
        }
    }

    pub fn from_name(s: &str) -> Option<DatasetId> {
        Self::ALL.iter().copied().find(|d| d.name() == s)
    }

    /// Generate the dataset at its default size (normalized to `[0,1]`).
    pub fn generate(&self, n: usize, seed: u64) -> Dataset {
        let mut ds = match self {
            DatasetId::Magic => synth::magic_like(n, seed),
            DatasetId::Adult => synth::adult_like(n, seed),
            DatasetId::Eeg => synth::eeg_like(n, seed),
            DatasetId::Mnist => synth::mnist_like(n, seed),
            DatasetId::Fashion => synth::fashion_like(n, seed),
        };
        ds.normalize();
        ds
    }

    /// Default sample count used by the experiment suite (scaled-down
    /// stand-ins for the real dataset sizes).
    pub fn default_n(&self) -> usize {
        match self {
            DatasetId::Magic => 6000,
            DatasetId::Adult => 6000,
            DatasetId::Eeg => 6000,
            DatasetId::Mnist => 3000,
            DatasetId::Fashion => 3000,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_generators_shape() {
        for id in DatasetId::ALL {
            let ds = id.generate(200, 1);
            assert_eq!(ds.n, 200);
            assert_eq!(ds.x.len(), ds.n * ds.d);
            assert_eq!(ds.labels.len(), ds.n);
            assert!(ds.labels.iter().all(|&l| (l as usize) < ds.n_classes));
            // normalized
            assert!(ds.x.iter().all(|&v| (0.0..=1.0).contains(&v)), "{}", id.name());
        }
    }

    #[test]
    fn expected_dims() {
        assert_eq!(DatasetId::Magic.generate(50, 0).d, 10);
        assert_eq!(DatasetId::Adult.generate(50, 0).d, 108);
        assert_eq!(DatasetId::Eeg.generate(50, 0).d, 14);
        assert_eq!(DatasetId::Mnist.generate(50, 0).d, 784);
        assert_eq!(DatasetId::Fashion.generate(50, 0).d, 784);
        assert_eq!(DatasetId::Mnist.generate(50, 0).n_classes, 10);
    }

    #[test]
    fn split_partitions() {
        let ds = DatasetId::Magic.generate(500, 3);
        let (train, test) = ds.split(0.2, 9);
        assert_eq!(train.n + test.n, 500);
        assert_eq!(test.n, 100);
        assert_eq!(train.d, ds.d);
    }

    #[test]
    fn deterministic() {
        let a = DatasetId::Eeg.generate(100, 42);
        let b = DatasetId::Eeg.generate(100, 42);
        assert_eq!(a.x, b.x);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn both_classes_present() {
        for id in DatasetId::ALL {
            let ds = id.generate(400, 5);
            let counts = ds.class_counts();
            let nonzero = counts.iter().filter(|&&c| c > 0).count();
            assert!(nonzero >= 2, "{}: {counts:?}", id.name());
        }
    }
}
