//! Per-device cost models (DESIGN.md system S7).
//!
//! The paper benchmarks on a Raspberry Pi 3B+ (ARM Cortex-A53) and an
//! Odroid-XU4 (Samsung Exynos 5422: Cortex-A15 big + A7 LITTLE). Neither is
//! available here, so per-device runtimes are **estimated**: engines emit
//! exact dynamic operation counts ([`crate::neon::OpTrace`]) and a
//! [`DeviceProfile`] — effective cycles-per-operation tables derived from the
//! ARM Cortex-A53/A15/A7 software optimization guides — converts a trace
//! into an estimated runtime.
//!
//! What the model is *for*: reproducing the paper's **relative** findings —
//! which engine wins on which microarchitecture and why (Tables 2/5,
//! Figures 1/2). The key asymmetries it encodes:
//!
//! * **A53** (in-order dual-issue, 64-bit NEON datapath): every 128-bit NEON
//!   op splits into two 64-bit micro-ops; modest mispredict penalty; small
//!   caches → random loads are expensive for large models.
//! * **A15** (big core of the Exynos 5422; 3-wide out-of-order, two full
//!   128-bit NEON pipes): NEON throughput ~4× the A53 per cycle, deep OoO
//!   hides scalar latency, but the mispredict penalty is larger.
//! * **A7** (LITTLE core; in-order, half-width NEON): provided for
//!   completeness / energy-style what-ifs.
//!
//! These asymmetries are exactly what the paper observes informally: "there
//! seem to be some architectural differences between the Cortex A53 and the
//! Exynos 5422 that impact the performance of the implementations" (§6.1).

use crate::neon::OpTrace;

/// Effective-cost table for one microarchitecture.
///
/// Costs are *reciprocal throughputs* in cycles (already folded with issue
/// width), not latencies — appropriate for the long independent op streams
/// these engines execute. Memory is modeled with a 3-level working-set
/// interpolation.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: &'static str,
    pub clock_ghz: f64,
    // Scalar pipes.
    pub scalar_alu: f64,
    pub scalar_fp: f64,
    pub branch: f64,
    pub branch_miss_extra: f64,
    // NEON pipes (per 128-bit op).
    pub neon_alu: f64,
    pub neon_mul: f64,
    pub neon_fp: f64,
    pub neon_horiz: f64,
    // Memory.
    pub stream_bytes_per_cycle: f64,
    pub l1_kb: f64,
    pub l2_kb: f64,
    pub l1_load_cycles: f64,
    pub l2_load_cycles: f64,
    pub mem_load_cycles: f64,
    pub store_bytes_per_cycle: f64,
    /// Active core power in watts (from the paper's Table 1 current draws
    /// at nominal voltage) — used for energy-per-inference estimates.
    pub power_w: f64,
    /// Cores of this class on the device (all of the paper's targets are
    /// 4-core parts / 4+4 clusters) — caps the useful thread budget when the
    /// selector scores threaded candidates.
    pub cores: usize,
}

impl DeviceProfile {
    /// Raspberry Pi 3B+ — Broadcom BCM2837B0, 4×Cortex-A53 @ 1.4 GHz.
    /// In-order dual-issue; the NEON unit is 64 bits wide, so each Q-form op
    /// costs ~2 cycles; 32 KB L1D, 512 KB shared L2.
    pub fn cortex_a53() -> DeviceProfile {
        DeviceProfile {
            name: "rpi3b+ (Cortex-A53)",
            clock_ghz: 1.4,
            scalar_alu: 0.6,
            scalar_fp: 1.2,
            branch: 0.8,
            branch_miss_extra: 8.0,
            neon_alu: 2.0,
            neon_mul: 2.5,
            neon_fp: 2.0,
            neon_horiz: 3.0,
            stream_bytes_per_cycle: 4.0,
            l1_kb: 32.0,
            l2_kb: 512.0,
            l1_load_cycles: 3.0,
            l2_load_cycles: 15.0,
            mem_load_cycles: 110.0,
            store_bytes_per_cycle: 4.0,
            power_w: 1.3, // ~260 mA @ 5 V (paper Table 1, Raspberry Pi 3B)
            cores: 4,
        }
    }

    /// Odroid-XU4 big cluster — Samsung Exynos 5422, 4×Cortex-A15 @ 2.0 GHz.
    /// 3-wide out-of-order with two 128-bit NEON pipes; 32 KB L1D, 2 MB L2.
    pub fn exynos_5422_big() -> DeviceProfile {
        DeviceProfile {
            name: "odroid-xu4 (Exynos 5422 / A15)",
            clock_ghz: 2.0,
            scalar_alu: 0.35,
            scalar_fp: 0.6,
            branch: 0.5,
            branch_miss_extra: 15.0,
            neon_alu: 0.6,
            neon_mul: 1.0,
            neon_fp: 0.6,
            neon_horiz: 1.5,
            stream_bytes_per_cycle: 8.0,
            l1_kb: 32.0,
            l2_kb: 2048.0,
            l1_load_cycles: 4.0,
            l2_load_cycles: 21.0,
            mem_load_cycles: 150.0,
            store_bytes_per_cycle: 8.0,
            power_w: 3.8, // A15 cluster under sustained load
            cores: 4,
        }
    }

    /// Odroid-XU4 LITTLE cluster — 4×Cortex-A7 @ 1.4 GHz (in-order 2-wide,
    /// 64-bit NEON).
    pub fn exynos_5422_little() -> DeviceProfile {
        DeviceProfile {
            name: "odroid-xu4 LITTLE (A7)",
            clock_ghz: 1.4,
            scalar_alu: 0.8,
            scalar_fp: 1.8,
            branch: 1.0,
            branch_miss_extra: 8.0,
            neon_alu: 2.4,
            neon_mul: 3.5,
            neon_fp: 2.8,
            neon_horiz: 3.5,
            stream_bytes_per_cycle: 2.5,
            l1_kb: 32.0,
            l2_kb: 512.0,
            l1_load_cycles: 3.0,
            l2_load_cycles: 18.0,
            mem_load_cycles: 140.0,
            store_bytes_per_cycle: 2.5,
            power_w: 0.9, // A7 LITTLE cluster
            cores: 4,
        }
    }

    /// Both devices the paper evaluates (A53 + Exynos big cluster).
    pub fn paper_devices() -> Vec<DeviceProfile> {
        vec![Self::cortex_a53(), Self::exynos_5422_big()]
    }

    /// Relative single-core throughput proxy (clock over scalar-FP
    /// reciprocal throughput) — used by [`crate::exec`]'s shard planner to
    /// weight big.LITTLE partitions. Only ratios between profiles matter:
    /// A15 ≈ 3.3, A53 ≈ 1.2, A7 ≈ 0.8.
    pub fn relative_speed(&self) -> f64 {
        self.clock_ghz / self.scalar_fp
    }

    /// Effective cycles for one data-dependent load, given the model's
    /// resident working-set size: interpolates hit probabilities across the
    /// cache hierarchy (a random touch into a working set W hits L1 with
    /// probability ~min(1, L1/W), etc.).
    pub fn random_load_cycles(&self, working_set_bytes: f64) -> f64 {
        let w_kb = working_set_bytes / 1024.0;
        let p1 = (self.l1_kb / w_kb).min(1.0);
        let p2 = ((self.l2_kb / w_kb).min(1.0) - p1).max(0.0);
        let pm = (1.0 - p1 - p2).max(0.0);
        p1 * self.l1_load_cycles + p2 * self.l2_load_cycles + pm * self.mem_load_cycles
    }

    /// Estimated cycles for an op trace with a given model working set.
    pub fn estimate_cycles(&self, t: &OpTrace, working_set_bytes: f64) -> f64 {
        let rl = self.random_load_cycles(working_set_bytes);
        t.scalar_alu as f64 * self.scalar_alu
            + t.scalar_fp as f64 * self.scalar_fp
            + t.branch as f64 * self.branch
            + t.branch_mispredictable as f64 * self.branch_miss_extra
            + t.neon_alu as f64 * self.neon_alu
            + t.neon_mul as f64 * self.neon_mul
            + t.neon_fp as f64 * self.neon_fp
            + t.neon_horiz as f64 * self.neon_horiz
            + t.stream_load_bytes as f64 / self.stream_bytes_per_cycle
            + t.random_loads as f64 * rl
            + t.store_bytes as f64 / self.store_bytes_per_cycle
    }

    /// Estimated microseconds for an op trace.
    pub fn estimate_us(&self, t: &OpTrace, working_set_bytes: f64) -> f64 {
        self.estimate_cycles(t, working_set_bytes) / (self.clock_ghz * 1000.0)
    }

    /// Estimated energy in microjoules (µs × W = µJ) — IoT deployments care
    /// about joules per inference at least as much as latency (paper §1,
    /// Table 1's power column).
    pub fn estimate_energy_uj(&self, t: &OpTrace, working_set_bytes: f64) -> f64 {
        self.estimate_us(t, working_set_bytes) * self.power_w
    }
}

/// Approximate resident model bytes per engine family, used as the working
/// set for random-load costing.
pub fn model_working_set(n_nodes: usize, n_trees: usize, leaf_words: usize, n_classes: usize, bytes_per_scalar: usize) -> f64 {
    // node lists + leaf table + leafidx scratch.
    let nodes = n_nodes * (bytes_per_scalar + 4 + 8);
    let leaves = n_trees * leaf_words * n_classes * bytes_per_scalar;
    let scratch = n_trees * 8;
    (nodes + leaves + scratch) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> OpTrace {
        OpTrace {
            scalar_alu: 1000,
            scalar_fp: 500,
            branch: 800,
            branch_mispredictable: 100,
            neon_alu: 400,
            neon_mul: 10,
            neon_fp: 300,
            neon_horiz: 50,
            stream_load_bytes: 64_000,
            random_loads: 2_000,
            store_bytes: 8_000,
            ..Default::default()
        }
    }

    #[test]
    fn estimates_positive_and_ordered() {
        let t = sample_trace();
        let a53 = DeviceProfile::cortex_a53();
        let a15 = DeviceProfile::exynos_5422_big();
        let small = 16.0 * 1024.0;
        let us53 = a53.estimate_us(&t, small);
        let us15 = a15.estimate_us(&t, small);
        assert!(us53 > 0.0 && us15 > 0.0);
        // The big OoO core at a higher clock should be faster on the same
        // trace with a cache-resident working set.
        assert!(us15 < us53, "a15 {us15} vs a53 {us53}");
    }

    #[test]
    fn random_load_cost_grows_with_working_set() {
        let a53 = DeviceProfile::cortex_a53();
        let small = a53.random_load_cycles(8.0 * 1024.0);
        let medium = a53.random_load_cycles(256.0 * 1024.0);
        let big = a53.random_load_cycles(64.0 * 1024.0 * 1024.0);
        assert!(small < medium && medium < big);
        assert!(small >= a53.l1_load_cycles);
        assert!(big <= a53.mem_load_cycles);
    }

    #[test]
    fn neon_gap_bigger_on_a15() {
        // The defining asymmetry: NEON ops are relatively cheaper on the
        // A15 than on the A53 (two 128-bit pipes vs a 64-bit datapath).
        let a53 = DeviceProfile::cortex_a53();
        let a15 = DeviceProfile::exynos_5422_big();
        let neon_ratio_a53 = a53.neon_fp / a53.scalar_fp;
        let neon_ratio_a15 = a15.neon_fp / a15.scalar_fp;
        assert!(neon_ratio_a15 < neon_ratio_a53);
    }

    #[test]
    fn energy_scales_with_power() {
        let t = sample_trace();
        let a53 = DeviceProfile::cortex_a53();
        let a7 = DeviceProfile::exynos_5422_little();
        let ws = 32.0 * 1024.0;
        assert!((a53.estimate_energy_uj(&t, ws) - a53.estimate_us(&t, ws) * 1.3).abs() < 1e-9);
        // The LITTLE core is slower but sips power: on a compute-light trace
        // it can win on energy even while losing on latency.
        assert!(a7.power_w < a53.power_w);
    }

    #[test]
    fn working_set_helper() {
        let ws = model_working_set(1000, 64, 32, 2, 4);
        assert!(ws > 16_000.0);
    }

    #[test]
    fn relative_speed_orders_cores() {
        let a15 = DeviceProfile::exynos_5422_big();
        let a53 = DeviceProfile::cortex_a53();
        let a7 = DeviceProfile::exynos_5422_little();
        assert!(a15.relative_speed() > a53.relative_speed());
        assert!(a53.relative_speed() > a7.relative_speed());
        assert_eq!(a53.cores, 4);
    }
}
