//! Core topology: how many workers to run, how much work each deserves —
//! and, since the affinity work, *which physical cores* each worker should
//! be pinned to.
//!
//! Every ARM target in the paper's Table 1 is a 4-core part, and the
//! Odroid-XU4's Exynos 5422 is heterogeneous (4×A15 big + 4×A7 LITTLE).
//! Equal-size shards on such a part leave the big cores idle while the
//! LITTLE cores finish — so the shard planner weights shard sizes by core
//! class. A [`CoreTopology`] is the minimal description the planner needs:
//! an ordered list of core classes (fastest first), each with a count, a
//! relative throughput weight, and the physical core IDs backing it
//! ([`CoreClass::core_ids`]; may be empty when unknown, in which case
//! pinning degrades to a no-op for that class).
//!
//! # Detection
//!
//! [`CoreTopology::from_sysfs`] parses the Linux per-CPU capacity hints —
//! `/sys/devices/system/cpu/cpu*/cpu_capacity` (arm64 DVFS-normalized
//! capacity) with `cpu*/cpufreq/cpuinfo_max_freq` as the fallback metric —
//! and clusters cores whose metric is within 5% into one class, fastest
//! class first. [`CoreTopology::detect`] uses that result only when it is
//! genuinely heterogeneous (≥ 2 classes, e.g. big.LITTLE or P/E-core
//! parts); on homogeneous hosts it keeps the conservative
//! `available_parallelism` answer, which also respects cgroup CPU quotas
//! that raw `/sys` enumeration would overcount.

use std::path::Path;

use crate::device::DeviceProfile;

/// One class of cores (e.g. the big cluster of a big.LITTLE part).
#[derive(Debug, Clone)]
pub struct CoreClass {
    pub name: String,
    pub count: usize,
    /// Relative single-core throughput (any positive unit; only ratios
    /// between classes matter).
    pub weight: f64,
    /// Physical core IDs backing this class — the affinity mask pool
    /// workers assigned here are pinned to. Empty when unknown (synthetic
    /// device-profile topologies on a foreign host): those workers stay
    /// unpinned.
    pub core_ids: Vec<usize>,
}

/// An ordered set of core classes, fastest first.
#[derive(Debug, Clone)]
pub struct CoreTopology {
    pub classes: Vec<CoreClass>,
}

/// One pool worker's placement: which class it belongs to (index into
/// [`CoreTopology::classes`]) and the weight its shards are sized by.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkerAssignment {
    pub class: usize,
    pub weight: f64,
}

impl CoreTopology {
    /// `n` identical cores (the common case on servers and the Pi's A53),
    /// backed by core IDs `0..n`.
    pub fn homogeneous(n: usize) -> CoreTopology {
        let n = n.max(1);
        CoreTopology {
            classes: vec![CoreClass {
                name: "core".into(),
                count: n,
                weight: 1.0,
                core_ids: (0..n).collect(),
            }],
        }
    }

    /// The host machine. Prefers the sysfs capacity topology when it is
    /// heterogeneous (see module docs); falls back to
    /// `std::thread::available_parallelism` otherwise.
    pub fn detect() -> CoreTopology {
        if let Some(t) = Self::from_sysfs() {
            if t.classes.len() >= 2 {
                return t;
            }
        }
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::homogeneous(n)
    }

    /// Parse the host's `/sys/devices/system/cpu` capacity/frequency hints
    /// into a topology with real core IDs. `None` when the tree is absent
    /// (non-Linux, sandboxed container).
    pub fn from_sysfs() -> Option<CoreTopology> {
        Self::from_sysfs_root(Path::new("/sys/devices/system/cpu"))
    }

    /// [`CoreTopology::from_sysfs`] against an arbitrary root — the
    /// testable core of the parser (tests synthesize fake `cpuN/` trees).
    pub fn from_sysfs_root(root: &Path) -> Option<CoreTopology> {
        let read_num = |p: &Path| -> Option<f64> {
            std::fs::read_to_string(p).ok()?.trim().parse::<f64>().ok()
        };
        // (core id, speed metric): DVFS-normalized capacity when present
        // (arm64 big.LITTLE exports it), max cpufreq otherwise, 1.0 when
        // the kernel exports neither (metrics only compare within one
        // host, so mixing units across hosts is not a concern).
        let mut cores: Vec<(usize, f64)> = Vec::new();
        for entry in std::fs::read_dir(root).ok()? {
            // A single unreadable/racy entry must not abort the whole
            // parse (the other cpuN dirs are still authoritative).
            let Ok(entry) = entry else { continue };
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let Some(id) = name.strip_prefix("cpu").and_then(|s| s.parse::<usize>().ok())
            else {
                continue;
            };
            let dir = entry.path();
            let metric = read_num(&dir.join("cpu_capacity"))
                .or_else(|| read_num(&dir.join("cpufreq/cpuinfo_max_freq")))
                .unwrap_or(1.0);
            cores.push((id, metric.max(1e-9)));
        }
        if cores.is_empty() {
            return None;
        }
        // Fastest first; cluster cores whose metric is within 5% of the
        // class head (absorbs per-core turbo-bin jitter on homogeneous
        // parts without merging genuinely different clusters).
        cores.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        let fastest = cores[0].1;
        let mut classes: Vec<CoreClass> = Vec::new();
        for (id, metric) in cores {
            match classes.last_mut() {
                Some(class) if metric >= 0.95 * class.weight * fastest => {
                    class.count += 1;
                    class.core_ids.push(id);
                }
                _ => classes.push(CoreClass {
                    name: format!("class{}", classes.len()),
                    count: 1,
                    // Normalized so the fastest class has weight 1.0.
                    weight: metric / fastest,
                    core_ids: vec![id],
                }),
            }
        }
        Some(CoreTopology { classes })
    }

    /// A homogeneous topology for one device profile (e.g. 4×A53). No core
    /// IDs: this describes a *target* device, not the host, so pinning is
    /// not meaningful.
    pub fn from_profile(p: &DeviceProfile, count: usize) -> CoreTopology {
        CoreTopology {
            classes: vec![CoreClass {
                name: p.name.to_string(),
                count: count.max(1),
                weight: p.relative_speed(),
                core_ids: Vec::new(),
            }],
        }
    }

    /// A big.LITTLE topology: big cluster first, weighted by each profile's
    /// relative speed (per §6's architectural discussion, the A15 sustains
    /// roughly 3× the per-core throughput of the A7). Core IDs are assigned
    /// synthetically (big `0..n_big`, LITTLE after) so the topology can
    /// also drive pinning experiments on a host with enough cores.
    pub fn big_little(
        big: &DeviceProfile,
        n_big: usize,
        little: &DeviceProfile,
        n_little: usize,
    ) -> CoreTopology {
        let n_big = n_big.max(1);
        let n_little = n_little.max(1);
        CoreTopology {
            classes: vec![
                CoreClass {
                    name: big.name.to_string(),
                    count: n_big,
                    weight: big.relative_speed(),
                    core_ids: (0..n_big).collect(),
                },
                CoreClass {
                    name: little.name.to_string(),
                    count: n_little,
                    weight: little.relative_speed(),
                    core_ids: (n_big..n_big + n_little).collect(),
                },
            ],
        }
    }

    /// A synthetic big.LITTLE topology with an explicit weight ratio —
    /// the `bench --exp adaptive` harness uses this to hand the *static*
    /// planner deliberately wrong weights on a homogeneous host (the
    /// adaptive planner must recover from measurement). Core IDs are
    /// `0..n_big` / `n_big..n_big+n_little`.
    pub fn synthetic_big_little(n_big: usize, n_little: usize, ratio: f64) -> CoreTopology {
        let n_big = n_big.max(1);
        let n_little = n_little.max(1);
        CoreTopology {
            classes: vec![
                CoreClass {
                    name: "synthetic-big".into(),
                    count: n_big,
                    weight: ratio.max(1e-6),
                    core_ids: (0..n_big).collect(),
                },
                CoreClass {
                    name: "synthetic-little".into(),
                    count: n_little,
                    weight: 1.0,
                    core_ids: (n_big..n_big + n_little).collect(),
                },
            ],
        }
    }

    /// The paper's Odroid-XU4 (4×A15 + 4×A7).
    pub fn odroid_xu4() -> CoreTopology {
        Self::big_little(
            &DeviceProfile::exynos_5422_big(),
            4,
            &DeviceProfile::exynos_5422_little(),
            4,
        )
    }

    /// Total core count.
    pub fn cores(&self) -> usize {
        self.classes.iter().map(|c| c.count).sum()
    }

    /// Per-worker placements for a thread budget: workers are assigned to
    /// the fastest classes first; a budget beyond the core count
    /// oversubscribes round-robin (each extra worker reuses a class in
    /// order). This is the one definition both the shard weights
    /// ([`CoreTopology::worker_weights`]) and the pool's pinning masks
    /// derive from, so a weight always describes the class its worker is
    /// pinned to.
    pub fn worker_assignments(&self, budget: usize) -> Vec<WorkerAssignment> {
        let budget = budget.max(1);
        let mut flat: Vec<WorkerAssignment> = Vec::new();
        for (ci, class) in self.classes.iter().enumerate() {
            flat.extend(
                std::iter::repeat(WorkerAssignment { class: ci, weight: class.weight })
                    .take(class.count),
            );
        }
        if flat.is_empty() {
            flat.push(WorkerAssignment { class: 0, weight: 1.0 });
        }
        (0..budget).map(|i| flat[i % flat.len()]).collect()
    }

    /// Per-worker weights for a thread budget (see
    /// [`CoreTopology::worker_assignments`]).
    pub fn worker_weights(&self, budget: usize) -> Vec<f64> {
        self.worker_assignments(budget).into_iter().map(|a| a.weight).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_weights_equal() {
        let t = CoreTopology::homogeneous(4);
        assert_eq!(t.cores(), 4);
        let w = t.worker_weights(4);
        assert_eq!(w, vec![1.0; 4]);
        assert_eq!(t.classes[0].core_ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn big_little_big_first_and_heavier() {
        let t = CoreTopology::odroid_xu4();
        assert_eq!(t.cores(), 8);
        let w = t.worker_weights(8);
        // First four workers land on the big cluster and get more weight.
        assert!(w[0] > w[4], "big {} vs little {}", w[0], w[4]);
        assert_eq!(w[0], w[3]);
        assert_eq!(w[4], w[7]);
        // The paper-derived ratio should be substantial but sane.
        let ratio = w[0] / w[4];
        assert!(ratio > 1.5 && ratio < 10.0, "ratio {ratio}");
        // Assignments point workers at their class (and its pin mask).
        let a = t.worker_assignments(8);
        assert_eq!(a[0].class, 0);
        assert_eq!(a[7].class, 1);
        assert_eq!(t.classes[0].core_ids, vec![0, 1, 2, 3]);
        assert_eq!(t.classes[1].core_ids, vec![4, 5, 6, 7]);
    }

    #[test]
    fn oversubscription_cycles() {
        let t = CoreTopology::homogeneous(2);
        assert_eq!(t.worker_weights(5).len(), 5);
        let a = t.worker_assignments(5);
        assert_eq!(a[0].class, a[2].class);
    }

    #[test]
    fn detect_nonzero() {
        assert!(CoreTopology::detect().cores() >= 1);
    }

    #[test]
    fn synthetic_big_little_shape() {
        let t = CoreTopology::synthetic_big_little(2, 2, 3.0);
        assert_eq!(t.cores(), 4);
        assert_eq!(t.classes[0].weight / t.classes[1].weight, 3.0);
        assert_eq!(t.classes[1].core_ids, vec![2, 3]);
    }

    fn fake_sysfs(caps: &[(usize, Option<u64>, Option<u64>)]) -> std::path::PathBuf {
        // Unique per content hash so parallel tests never collide.
        let mut tag = 0u64;
        for &(id, c, f) in caps {
            tag = tag
                .wrapping_mul(31)
                .wrapping_add(id as u64)
                .wrapping_add(c.unwrap_or(7))
                .wrapping_add(f.unwrap_or(13));
        }
        let root = std::env::temp_dir().join(format!("arbors-sysfs-{tag:x}"));
        let _ = std::fs::remove_dir_all(&root);
        for &(id, cap, freq) in caps {
            let dir = root.join(format!("cpu{id}"));
            std::fs::create_dir_all(dir.join("cpufreq")).unwrap();
            if let Some(c) = cap {
                std::fs::write(dir.join("cpu_capacity"), format!("{c}\n")).unwrap();
            }
            if let Some(f) = freq {
                std::fs::write(dir.join("cpufreq/cpuinfo_max_freq"), format!("{f}\n"))
                    .unwrap();
            }
        }
        root
    }

    #[test]
    fn sysfs_parses_big_little_capacities() {
        // A 2+2 part: capacity 1024 big cores (ids 2,3), 430 LITTLE (0,1).
        let root = fake_sysfs(&[
            (0, Some(430), Some(1_400_000)),
            (1, Some(430), Some(1_400_000)),
            (2, Some(1024), Some(2_000_000)),
            (3, Some(1024), Some(2_000_000)),
        ]);
        let t = CoreTopology::from_sysfs_root(&root).unwrap();
        assert_eq!(t.classes.len(), 2, "{t:?}");
        assert_eq!(t.classes[0].core_ids, vec![2, 3], "big cluster first");
        assert_eq!(t.classes[1].core_ids, vec![0, 1]);
        assert_eq!(t.classes[0].weight, 1.0);
        assert!((t.classes[1].weight - 430.0 / 1024.0).abs() < 1e-9);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn sysfs_falls_back_to_max_freq_and_merges_jitter() {
        // No cpu_capacity; max freqs within 5% collapse into one class.
        let root = fake_sysfs(&[
            (0, None, Some(3_000_000)),
            (1, None, Some(2_950_000)),
            (2, None, Some(3_000_000)),
        ]);
        let t = CoreTopology::from_sysfs_root(&root).unwrap();
        assert_eq!(t.classes.len(), 1, "{t:?}");
        assert_eq!(t.classes[0].count, 3);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn sysfs_missing_root_is_none() {
        let root = std::env::temp_dir().join("arbors-sysfs-definitely-missing");
        assert!(CoreTopology::from_sysfs_root(&root).is_none());
    }
}
