//! Core topology: how many workers to run and how much work each deserves.
//!
//! Every ARM target in the paper's Table 1 is a 4-core part, and the
//! Odroid-XU4's Exynos 5422 is heterogeneous (4×A15 big + 4×A7 LITTLE).
//! Equal-size shards on such a part leave the big cores idle while the
//! LITTLE cores finish — so the shard planner weights shard sizes by core
//! class. A [`CoreTopology`] is the minimal description the planner needs:
//! an ordered list of core classes (fastest first), each with a count and a
//! relative throughput weight.

use crate::device::DeviceProfile;

/// One class of cores (e.g. the big cluster of a big.LITTLE part).
#[derive(Debug, Clone)]
pub struct CoreClass {
    pub name: String,
    pub count: usize,
    /// Relative single-core throughput (any positive unit; only ratios
    /// between classes matter).
    pub weight: f64,
}

/// An ordered set of core classes, fastest first.
#[derive(Debug, Clone)]
pub struct CoreTopology {
    pub classes: Vec<CoreClass>,
}

impl CoreTopology {
    /// `n` identical cores (the common case on servers and the Pi's A53).
    pub fn homogeneous(n: usize) -> CoreTopology {
        CoreTopology {
            classes: vec![CoreClass { name: "core".into(), count: n.max(1), weight: 1.0 }],
        }
    }

    /// The host machine, via `std::thread::available_parallelism`.
    pub fn detect() -> CoreTopology {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::homogeneous(n)
    }

    /// A homogeneous topology for one device profile (e.g. 4×A53).
    pub fn from_profile(p: &DeviceProfile, count: usize) -> CoreTopology {
        CoreTopology {
            classes: vec![CoreClass {
                name: p.name.to_string(),
                count: count.max(1),
                weight: p.relative_speed(),
            }],
        }
    }

    /// A big.LITTLE topology: big cluster first, weighted by each profile's
    /// relative speed (per §6's architectural discussion, the A15 sustains
    /// roughly 3× the per-core throughput of the A7).
    pub fn big_little(
        big: &DeviceProfile,
        n_big: usize,
        little: &DeviceProfile,
        n_little: usize,
    ) -> CoreTopology {
        CoreTopology {
            classes: vec![
                CoreClass {
                    name: big.name.to_string(),
                    count: n_big.max(1),
                    weight: big.relative_speed(),
                },
                CoreClass {
                    name: little.name.to_string(),
                    count: n_little.max(1),
                    weight: little.relative_speed(),
                },
            ],
        }
    }

    /// The paper's Odroid-XU4 (4×A15 + 4×A7).
    pub fn odroid_xu4() -> CoreTopology {
        Self::big_little(
            &DeviceProfile::exynos_5422_big(),
            4,
            &DeviceProfile::exynos_5422_little(),
            4,
        )
    }

    /// Total core count.
    pub fn cores(&self) -> usize {
        self.classes.iter().map(|c| c.count).sum()
    }

    /// Per-worker weights for a thread budget: workers are assigned to the
    /// fastest cores first; a budget beyond the core count oversubscribes
    /// round-robin (each extra worker reuses a class in order).
    pub fn worker_weights(&self, budget: usize) -> Vec<f64> {
        let budget = budget.max(1);
        let mut flat: Vec<f64> = Vec::new();
        for class in &self.classes {
            flat.extend(std::iter::repeat(class.weight).take(class.count));
        }
        if flat.is_empty() {
            flat.push(1.0);
        }
        (0..budget).map(|i| flat[i % flat.len()]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_weights_equal() {
        let t = CoreTopology::homogeneous(4);
        assert_eq!(t.cores(), 4);
        let w = t.worker_weights(4);
        assert_eq!(w, vec![1.0; 4]);
    }

    #[test]
    fn big_little_big_first_and_heavier() {
        let t = CoreTopology::odroid_xu4();
        assert_eq!(t.cores(), 8);
        let w = t.worker_weights(8);
        // First four workers land on the big cluster and get more weight.
        assert!(w[0] > w[4], "big {} vs little {}", w[0], w[4]);
        assert_eq!(w[0], w[3]);
        assert_eq!(w[4], w[7]);
        // The paper-derived ratio should be substantial but sane.
        let ratio = w[0] / w[4];
        assert!(ratio > 1.5 && ratio < 10.0, "ratio {ratio}");
    }

    #[test]
    fn oversubscription_cycles() {
        let t = CoreTopology::homogeneous(2);
        assert_eq!(t.worker_weights(5).len(), 5);
    }

    #[test]
    fn detect_nonzero() {
        assert!(CoreTopology::detect().cores() >= 1);
    }
}
