//! Work-stealing worker pools: the server-shared [`SharedPool`] with
//! per-deployment thread budgets, and the standalone [`WorkerPool`] facade.
//!
//! `rayon`/`crossbeam` are unavailable offline, so this implements the small
//! core the execution and serving layers need: N persistent workers, one
//! FIFO task queue per registered *deployment* (a [`PoolClient`]), and a
//! budget-aware claim rule that decides which deployment a free worker
//! serves next. One `SharedPool` is owned by a whole
//! [`crate::coordinator::Server`]; every deployed model registers a client
//! on it instead of spawning a private pool, so a multi-model edge device
//! runs exactly one set of exec threads.
//!
//! # Budgets and stealing
//!
//! Each client registers with a thread *budget* — the number of workers it
//! is entitled to under contention. The claim rule has two tiers:
//!
//! 1. **Under budget first.** Deployments with queued work and
//!    `active < budget` are served before anything else; among them the one
//!    with the smallest weighted virtual time (`vtime`, advanced by
//!    `1/budget` per claimed task) wins, so service rates converge to the
//!    budget ratios even when instantaneous concurrency cannot express them
//!    (e.g. a 1-worker pool shared by two deployments).
//! 2. **Steal only from idle budgets.** A deployment whose budget is
//!    exhausted may claim a worker only when tier 1 is empty — i.e. every
//!    other deployment with remaining budget has nothing queued. The spare
//!    capacity a steal consumes is therefore always some idle deployment's
//!    budget, and is handed back the moment that deployment enqueues work
//!    (its tasks re-enter tier 1 and win the next free workers).
//!
//! # Design notes
//!
//! * Queues live behind one pool-wide `Mutex` rather than lock-free
//!   Chase–Lev deques. Tasks here are *shards* — tens of microseconds to
//!   milliseconds of tree traversal — so a ~20 ns lock is noise; in
//!   exchange the scheduler is obviously correct and fully safe code.
//! * Workers catch task panics, so a poisoned shard can neither kill a
//!   worker thread nor deadlock a submitter; [`PoolClient::run`] re-panics
//!   on the submitting thread after the whole job has drained.
//! * A client's drop marks its queue closed and discards still-queued
//!   tasks; in-flight tasks finish first (serving tears deployments down
//!   only after draining, see `coordinator::batcher`).

use std::collections::{BTreeMap, VecDeque};
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A unit of work submitted to a pool.
pub type Task = Box<dyn FnOnce() + Send + 'static>;

/// `Send`-able raw `*mut f32` wrapper for handing disjoint slice ranges to
/// pool tasks (used by `exec::parallel` and the fused batcher). Safety
/// rests on two caller-enforced invariants: the ranges written through the
/// pointer never overlap across concurrently running tasks, and the
/// pointee buffer outlives every task (readers synchronize with a
/// completion latch/counter before touching it).
#[derive(Clone, Copy)]
pub struct MutPtr(pub *mut f32);
unsafe impl Send for MutPtr {}

/// Process-wide count of exec worker threads ever spawned. Monotone by
/// design (never decremented on join): tests assert that deploying more
/// models onto a server adds **zero** new worker threads.
static WORKERS_SPAWNED: AtomicUsize = AtomicUsize::new(0);

/// See [`WORKERS_SPAWNED`].
pub fn worker_threads_spawned() -> usize {
    WORKERS_SPAWNED.load(Ordering::SeqCst)
}

/// Per-deployment scheduling state.
struct DeploymentQueue {
    queue: VecDeque<Task>,
    /// Worker entitlement under contention (≥ 1).
    budget: usize,
    /// Workers currently executing this deployment's tasks.
    active: usize,
    /// Set when the owning client dropped; the entry is removed once the
    /// last in-flight task finishes.
    closed: bool,
    /// Weighted-fair virtual time: advanced by `1/budget` per claim, so
    /// under contention claim counts converge to budget ratios.
    vtime: f64,
}

#[derive(Default)]
struct PoolState {
    deployments: BTreeMap<u64, DeploymentQueue>,
}

/// Lowest-vtime deployment with queued work in the given tier
/// (`under == true`: still under budget; `false`: budget exhausted).
fn pick(deployments: &BTreeMap<u64, DeploymentQueue>, under: bool) -> Option<u64> {
    let mut best: Option<(u64, f64)> = None;
    for (&tag, d) in deployments {
        if d.queue.is_empty() || (d.active < d.budget) != under {
            continue;
        }
        if best.map_or(true, |(_, bv)| d.vtime < bv) {
            best = Some((tag, d.vtime));
        }
    }
    best.map(|(tag, _)| tag)
}

impl PoolState {
    /// Claim one task for a free worker (see module docs for the rule).
    fn claim(&mut self) -> Option<(u64, Task)> {
        let tag = pick(&self.deployments, true).or_else(|| pick(&self.deployments, false))?;
        let d = self.deployments.get_mut(&tag).expect("picked tag exists");
        let task = d.queue.pop_front().expect("picked queue non-empty");
        d.active += 1;
        d.vtime += 1.0 / d.budget as f64;
        Some((tag, task))
    }
}

struct Shared {
    state: Mutex<PoolState>,
    wakeup: Condvar,
    shutdown: AtomicBool,
    next_tag: AtomicU64,
    /// Live registered clients (deployments).
    registered: AtomicUsize,
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let (tag, task) = {
            let mut state = shared.state.lock().unwrap();
            loop {
                if let Some(claimed) = state.claim() {
                    break claimed;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                state = shared.wakeup.wait(state).unwrap();
            }
        };
        // Panics must not kill the worker: `run` observes them via its
        // latch wrapper; `spawn` callers handle completion themselves
        // (e.g. the batcher's chunk guard).
        let _ = panic::catch_unwind(AssertUnwindSafe(task));
        let mut state = shared.state.lock().unwrap();
        let gone = match state.deployments.get_mut(&tag) {
            Some(d) => {
                d.active -= 1;
                d.closed && d.active == 0 && d.queue.is_empty()
            }
            None => false,
        };
        if gone {
            state.deployments.remove(&tag);
        }
    }
}

/// Completion latch for one blocking job ([`PoolClient::run`]).
struct Latch {
    state: Mutex<LatchState>,
    done: Condvar,
}

struct LatchState {
    remaining: usize,
    panicked: bool,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            state: Mutex::new(LatchState { remaining: n, panicked: false }),
            done: Condvar::new(),
        }
    }

    fn complete(&self, panicked: bool) {
        let mut s = self.state.lock().unwrap();
        s.remaining -= 1;
        s.panicked |= panicked;
        if s.remaining == 0 {
            self.done.notify_all();
        }
    }

    /// Wait for the whole job; report whether any task panicked.
    fn wait(&self) -> bool {
        let mut s = self.state.lock().unwrap();
        while s.remaining > 0 {
            s = self.done.wait(s).unwrap();
        }
        s.panicked
    }
}

/// A pool of work-stealing workers shared by many deployments.
///
/// Workers are *additional* threads: a pool with `threads` workers runs
/// that many, and a thread blocking in [`PoolClient::run`] does not execute
/// tasks, so `threads` is the total compute parallelism available to every
/// registered deployment combined.
pub struct SharedPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl SharedPool {
    /// Spawn a pool with `threads` workers (min 1).
    pub fn new(threads: usize) -> Arc<SharedPool> {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState::default()),
            wakeup: Condvar::new(),
            shutdown: AtomicBool::new(false),
            next_tag: AtomicU64::new(0),
            registered: AtomicUsize::new(0),
        });
        let workers = (0..threads)
            .map(|w| {
                let shared = shared.clone();
                WORKERS_SPAWNED.fetch_add(1, Ordering::SeqCst);
                std::thread::Builder::new()
                    .name(format!("exec-worker-{w}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn exec worker")
            })
            .collect();
        Arc::new(SharedPool { shared, workers, threads })
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Live registered clients (deployments sharing this pool).
    pub fn registered(&self) -> usize {
        self.shared.registered.load(Ordering::SeqCst)
    }

    /// Register a deployment with a thread `budget` (clamped to ≥ 1; may
    /// exceed [`SharedPool::threads`], in which case it is simply never the
    /// binding constraint). The client's vtime joins the live virtual
    /// clock at its first [`PoolClient::spawn`] (see the catch-up rule
    /// there), so the initial value here is immaterial.
    ///
    /// Associated function (the client keeps the pool alive, so it needs
    /// the `Arc`, and `self: &Arc<Self>` receivers are not stable Rust).
    pub fn register(pool: &Arc<SharedPool>, label: &str, budget: usize) -> PoolClient {
        let tag = pool.shared.next_tag.fetch_add(1, Ordering::Relaxed);
        let budget = budget.max(1);
        {
            let mut state = pool.shared.state.lock().unwrap();
            state.deployments.insert(
                tag,
                DeploymentQueue {
                    queue: VecDeque::new(),
                    budget,
                    active: 0,
                    closed: false,
                    vtime: 0.0,
                },
            );
        }
        pool.shared.registered.fetch_add(1, Ordering::SeqCst);
        PoolClient { pool: pool.clone(), tag, budget, label: label.to_string() }
    }
}

impl Drop for SharedPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // Wake everyone so parked workers observe the flag.
        let _guard = self.shared.state.lock().unwrap();
        self.shared.wakeup.notify_all();
        drop(_guard);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A deployment's handle onto a [`SharedPool`]: the tagged queue tasks are
/// submitted through. Dropping the client unregisters the deployment
/// (still-queued tasks are discarded; in-flight tasks finish).
pub struct PoolClient {
    pool: Arc<SharedPool>,
    tag: u64,
    budget: usize,
    label: String,
}

impl PoolClient {
    /// This deployment's thread budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// The label the client registered under (diagnostics only).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// The pool this client is registered on.
    pub fn pool(&self) -> &Arc<SharedPool> {
        &self.pool
    }

    /// Enqueue a batch of tasks, fire-and-forget. Callers that need
    /// completion signalling wrap the tasks themselves (see
    /// `coordinator::batcher`); callers that need blocking semantics use
    /// [`PoolClient::run`].
    pub fn spawn(&self, tasks: Vec<Task>) {
        if tasks.is_empty() {
            return;
        }
        let mut state = self.pool.shared.state.lock().unwrap();
        // WFQ catch-up: a deployment going idle → backlogged must not
        // replay service time it never used — a stale-low vtime would let
        // it monopolize every freed worker until it "caught up", starving
        // the deployments that were busy all along. Raise it to the floor
        // of the currently-backlogged vtimes before enqueueing.
        let idle = state
            .deployments
            .get(&self.tag)
            .map_or(true, |d| d.queue.is_empty() && d.active == 0);
        if idle {
            let floor = state
                .deployments
                .values()
                .filter(|d| !d.queue.is_empty() || d.active > 0)
                .map(|d| d.vtime)
                .fold(f64::INFINITY, f64::min);
            if floor.is_finite() {
                let d = state.deployments.get_mut(&self.tag).expect("client is registered");
                d.vtime = d.vtime.max(floor);
            }
        }
        let d = state.deployments.get_mut(&self.tag).expect("client is registered");
        for t in tasks {
            d.queue.push_back(t);
        }
        self.pool.shared.wakeup.notify_all();
    }

    /// Run a job: execute every task on the pool, blocking until all have
    /// finished. Panics (after the job has fully drained) if any task
    /// panicked. Concurrent `run` calls from different threads are safe.
    pub fn run(&self, tasks: Vec<Task>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        let latch = Arc::new(Latch::new(n));
        let wrapped: Vec<Task> = tasks
            .into_iter()
            .map(|task| {
                let latch = latch.clone();
                Box::new(move || {
                    let result = panic::catch_unwind(AssertUnwindSafe(task));
                    latch.complete(result.is_err());
                }) as Task
            })
            .collect();
        self.spawn(wrapped);
        if latch.wait() {
            panic!("exec worker task panicked");
        }
    }
}

impl Drop for PoolClient {
    fn drop(&mut self) {
        {
            let mut state = self.pool.shared.state.lock().unwrap();
            let gone = match state.deployments.get_mut(&self.tag) {
                Some(d) => {
                    d.closed = true;
                    d.queue.clear();
                    d.active == 0
                }
                None => false,
            };
            if gone {
                state.deployments.remove(&self.tag);
            }
        }
        self.pool.shared.registered.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A standalone pool with a single anonymous deployment — the facade the
/// [`crate::exec::ParallelEngine`] and one-off callers use. Equivalent to
/// `SharedPool::new(threads)` plus one client with `budget == threads`.
pub struct WorkerPool {
    client: PoolClient,
}

impl WorkerPool {
    /// Spawn a pool with `threads` workers (min 1).
    pub fn new(threads: usize) -> WorkerPool {
        let pool = SharedPool::new(threads);
        let client = SharedPool::register(&pool, "standalone", threads.max(1));
        WorkerPool { client }
    }

    /// Number of workers.
    pub fn threads(&self) -> usize {
        self.client.pool().threads()
    }

    /// See [`PoolClient::run`].
    pub fn run(&self, tasks: Vec<Task>) {
        self.client.run(tasks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(4);
        let hits = Arc::new(AtomicU64::new(0));
        let tasks: Vec<Task> = (0..500)
            .map(|i| {
                let hits = hits.clone();
                Box::new(move || {
                    hits.fetch_add(i + 1, Ordering::Relaxed);
                }) as Task
            })
            .collect();
        pool.run(tasks);
        // Sum 1..=500 — each task ran exactly once.
        assert_eq!(hits.load(Ordering::Relaxed), 500 * 501 / 2);
    }

    #[test]
    fn stealing_drains_imbalanced_load() {
        // One long task plus many short ones: with work conservation, total
        // wall time is bounded by the long task, and everything completes.
        let pool = WorkerPool::new(4);
        let done = Arc::new(AtomicU64::new(0));
        let mut tasks: Vec<Task> = Vec::new();
        for i in 0..64 {
            let done = done.clone();
            tasks.push(Box::new(move || {
                if i == 0 {
                    std::thread::sleep(Duration::from_millis(20));
                }
                done.fetch_add(1, Ordering::Relaxed);
            }));
        }
        pool.run(tasks);
        assert_eq!(done.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn sequential_jobs_reuse_workers() {
        let pool = WorkerPool::new(2);
        let hits = Arc::new(AtomicU64::new(0));
        for _ in 0..20 {
            let tasks: Vec<Task> = (0..8)
                .map(|_| {
                    let hits = hits.clone();
                    Box::new(move || {
                        hits.fetch_add(1, Ordering::Relaxed);
                    }) as Task
                })
                .collect();
            pool.run(tasks);
        }
        assert_eq!(hits.load(Ordering::Relaxed), 160);
    }

    #[test]
    fn concurrent_submitters() {
        let pool = Arc::new(WorkerPool::new(4));
        let hits = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            let hits = hits.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    let tasks: Vec<Task> = (0..16)
                        .map(|_| {
                            let hits = hits.clone();
                            Box::new(move || {
                                hits.fetch_add(1, Ordering::Relaxed);
                            }) as Task
                        })
                        .collect();
                    pool.run(tasks);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(hits.load(Ordering::Relaxed), 4 * 10 * 16);
    }

    #[test]
    fn task_panic_propagates_after_drain() {
        let pool = WorkerPool::new(2);
        let done = Arc::new(AtomicU64::new(0));
        let mut tasks: Vec<Task> = Vec::new();
        for i in 0..16 {
            let done = done.clone();
            tasks.push(Box::new(move || {
                if i == 3 {
                    panic!("boom");
                }
                done.fetch_add(1, Ordering::Relaxed);
            }));
        }
        let result = panic::catch_unwind(AssertUnwindSafe(|| pool.run(tasks)));
        assert!(result.is_err());
        // Every non-panicking task still ran (no abandoned work).
        assert_eq!(done.load(Ordering::Relaxed), 15);
        // The pool survives for the next job.
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = hits.clone();
        pool.run(vec![Box::new(move || {
            h2.fetch_add(1, Ordering::Relaxed);
        })]);
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = WorkerPool::new(1);
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        pool.run(vec![Box::new(move || {
            h.fetch_add(7, Ordering::Relaxed);
        })]);
        assert_eq!(hits.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn register_unregister_tracks_clients() {
        let pool = SharedPool::new(2);
        assert_eq!(pool.registered(), 0);
        let a = SharedPool::register(&pool, "a", 1);
        let b = SharedPool::register(&pool, "b", 2);
        assert_eq!(pool.registered(), 2);
        assert_eq!(a.budget(), 1);
        assert_eq!(b.label(), "b");
        drop(a);
        assert_eq!(pool.registered(), 1);
        drop(b);
        assert_eq!(pool.registered(), 0);
        // Re-registering after drain works.
        let c = SharedPool::register(&pool, "c", 9);
        assert_eq!(c.budget(), 9);
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        c.run(vec![Box::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        })]);
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn idle_budgets_are_stolen() {
        // A budget-1 client alone on a 4-worker pool may exceed its budget:
        // the other budgets are idle, so their workers steal its work.
        let pool = SharedPool::new(4);
        let _other = SharedPool::register(&pool, "idle", 3);
        let solo = SharedPool::register(&pool, "solo", 1);
        let active = Arc::new(AtomicU64::new(0));
        let peak = Arc::new(AtomicU64::new(0));
        let tasks: Vec<Task> = (0..32)
            .map(|_| {
                let active = active.clone();
                let peak = peak.clone();
                Box::new(move || {
                    let a = active.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(a, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(5));
                    active.fetch_sub(1, Ordering::SeqCst);
                }) as Task
            })
            .collect();
        solo.run(tasks);
        assert!(peak.load(Ordering::SeqCst) > 1, "no stealing beyond budget");
    }

    #[test]
    fn weighted_fair_claiming_respects_budgets() {
        // One worker shared by budgets 1 and 3: claim counts must converge
        // to ~1:3, even though instantaneous concurrency is always 1.
        let pool = SharedPool::new(1);
        let a = SharedPool::register(&pool, "a", 1);
        let b = SharedPool::register(&pool, "b", 3);
        let order = Arc::new(Mutex::new(Vec::new()));
        let done = Arc::new(AtomicU64::new(0));
        // Hold the only worker while both queues fill.
        let gate = Arc::new(AtomicBool::new(false));
        {
            let gate = gate.clone();
            a.spawn(vec![Box::new(move || {
                while !gate.load(Ordering::Acquire) {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }) as Task]);
        }
        let mk = |who: char| -> Task {
            let order = order.clone();
            let done = done.clone();
            Box::new(move || {
                order.lock().unwrap().push(who);
                done.fetch_add(1, Ordering::SeqCst);
            })
        };
        a.spawn((0..8).map(|_| mk('a')).collect());
        b.spawn((0..8).map(|_| mk('b')).collect());
        gate.store(true, Ordering::Release);
        while done.load(Ordering::SeqCst) < 16 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let order = order.lock().unwrap();
        let b_first_8 = order[..8].iter().filter(|&&c| c == 'b').count();
        assert!(
            b_first_8 >= 5,
            "budget-3 deployment got only {b_first_8}/8 of the first claims: {order:?}"
        );
        assert_eq!(order.len(), 16);
    }

    #[test]
    fn idle_deployment_cannot_replay_unused_vtime() {
        // Regression: before the spawn-time catch-up, a long-idle client
        // kept a stale-low vtime and monopolized every freed worker until
        // it "caught up" with the busy client's service history.
        let pool = SharedPool::new(1);
        let a = SharedPool::register(&pool, "busy", 1);
        let b = SharedPool::register(&pool, "bursty", 1);
        // `a` accumulates service history while `b` sits idle.
        for _ in 0..50 {
            let h = Arc::new(AtomicU64::new(0));
            let hh = h.clone();
            a.run(vec![Box::new(move || {
                hh.fetch_add(1, Ordering::Relaxed);
            }) as Task]);
        }
        // Hold the worker, queue 4 tasks each, release: b's burst must
        // interleave with a's (~1:1 at equal budgets), not sweep the queue.
        let gate = Arc::new(AtomicBool::new(false));
        {
            let gate = gate.clone();
            a.spawn(vec![Box::new(move || {
                while !gate.load(Ordering::Acquire) {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }) as Task]);
        }
        let order = Arc::new(Mutex::new(Vec::new()));
        let done = Arc::new(AtomicU64::new(0));
        let mk = |who: char| -> Task {
            let order = order.clone();
            let done = done.clone();
            Box::new(move || {
                order.lock().unwrap().push(who);
                done.fetch_add(1, Ordering::SeqCst);
            })
        };
        a.spawn((0..4).map(|_| mk('a')).collect());
        b.spawn((0..4).map(|_| mk('b')).collect());
        gate.store(true, Ordering::Release);
        while done.load(Ordering::SeqCst) < 8 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let order = order.lock().unwrap();
        let b_first_4 = order[..4].iter().filter(|&&c| c == 'b').count();
        assert!(
            b_first_4 <= 3,
            "bursty deployment must not sweep the first slots: {order:?}"
        );
    }

    #[test]
    fn dropped_client_discards_queued_tasks() {
        // Queue work behind a blocker, then drop the client: queued tasks
        // are discarded, in-flight ones finish, and the pool stays healthy.
        let pool = SharedPool::new(1);
        let victim = SharedPool::register(&pool, "victim", 1);
        let survivor = SharedPool::register(&pool, "survivor", 1);
        let gate = Arc::new(AtomicBool::new(false));
        let ran = Arc::new(AtomicU64::new(0));
        {
            let gate = gate.clone();
            let ran = ran.clone();
            victim.spawn(vec![Box::new(move || {
                while !gate.load(Ordering::Acquire) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                ran.fetch_add(1, Ordering::SeqCst);
            }) as Task]);
        }
        // Wait for the blocker to be claimed so it is in-flight, not queued.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while pool.shared.state.lock().unwrap().deployments.values().all(|d| d.active == 0) {
            assert!(std::time::Instant::now() < deadline, "blocker never claimed");
            std::thread::sleep(Duration::from_millis(1));
        }
        {
            let ran = ran.clone();
            victim.spawn(vec![Box::new(move || {
                ran.fetch_add(100, Ordering::SeqCst);
            }) as Task]);
        }
        drop(victim); // discards the queued task, keeps the in-flight one
        gate.store(true, Ordering::Release);
        // The survivor still gets service.
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        survivor.run(vec![Box::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        })]);
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        // In-flight blocker ran; the queued task never did.
        assert_eq!(ran.load(Ordering::SeqCst), 1);
        assert_eq!(pool.registered(), 1);
    }

    #[test]
    fn spawned_thread_counter_monotone() {
        // `>=`: other tests in this binary spawn pools concurrently.
        let before = worker_threads_spawned();
        let _pool = SharedPool::new(3);
        assert!(worker_threads_spawned() - before >= 3);
    }
}
